//! Collective–network co-design for LLM inference (paper §6.3 Expr 2).
//!
//! ```sh
//! cargo run --release --example codesign_inference
//! ```
//!
//! Fixes the workload parallelization and lets COSMIC co-design the
//! collective algorithms and the network for two GPT3-175B inference
//! profiles: a decode-heavy Chat service and a prefill-heavy QA service.
//! The paper's observation to reproduce: inference prefers
//! latency-optimized collectives (Direct/RHD/DBT) over bandwidth-
//! optimized Ring, because decode-phase messages are tiny.

use cosmic::agents::AgentKind;
use cosmic::dse::{Objective, WorkloadSpec};
use cosmic::harness::{make_env, scoped_search};
use cosmic::pss::SearchScope;
use cosmic::sim::presets;
use cosmic::workload::models::presets as models;
use cosmic::workload::ExecutionMode;

fn service(name: &str, decode_steps: f64) {
    let gpt = models::gpt3_175b().with_simulated_layers(4);
    let workloads = vec![
        WorkloadSpec::inference(gpt.clone(), 64, ExecutionMode::InferencePrefill, 1.0),
        WorkloadSpec::inference(gpt, 64, ExecutionMode::InferenceDecode, decode_steps),
    ];
    let mut env = make_env(presets::system2(), workloads, Objective::PerfPerBwPerNpu);
    let r = scoped_search(&mut env, SearchScope::CollectiveNetwork, AgentKind::Aco, 800, 13);
    let point = env.pss.schema.decode(&r.run.best_genome).expect("decode best");
    let (cluster, par) = env.pss.materialize(&point).expect("materialize best");

    println!("\n--- {name} (1 prefill + {decode_steps} decode steps per request) ---");
    println!("best reward:     {:.4e}", r.run.best_reward);
    println!("request latency: {:.2} ms", r.best_latency_us / 1e3);
    println!("topology:        {}", cluster.topology);
    println!(
        "collectives:     {} chunks={} {} {}",
        cluster.collectives.algo_notation(),
        cluster.collectives.chunks,
        cluster.collectives.scheduling.name(),
        cluster.collectives.multidim.name()
    );
    println!("workload (fixed): {par}");
    let rings = cluster
        .collectives
        .algorithms
        .iter()
        .filter(|a| matches!(a, cosmic::collective::CollAlgo::Ring))
        .count();
    println!(
        "ring dims: {rings}/4 -> {}",
        if rings <= 2 { "latency-optimized (matches paper)" } else { "bandwidth-leaning" }
    );
}

fn main() {
    println!("Collective-network co-design for GPT3-175B inference on System 2");
    service("Chat", 512.0);
    service("QA", 32.0);
}
