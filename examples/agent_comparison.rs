//! Compare the four search agents on one DSE problem (paper §6.4).
//!
//! ```sh
//! cargo run --release --example agent_comparison
//! ```
//!
//! Runs RW, GA, ACO and BO with identical budgets on the same
//! environment and prints final reward, steps-to-peak and invalid-eval
//! counts — the Figure 10 summary. Also demonstrates swapping the BO
//! surrogate for the XLA-compiled artifact when available.

use cosmic::agents::{AgentKind, BayesOpt};
use cosmic::dse::{DseConfig, DseRunner, Objective, WorkloadSpec};
use cosmic::harness::{make_env, print_table};
use cosmic::pss::SearchScope;
use cosmic::runtime::{GpSurrogate, Runtime};
use cosmic::sim::presets;
use cosmic::workload::models::presets as models;
use std::path::Path;

const STEPS: u64 = 600;

fn main() {
    let model = models::gpt3_13b().with_simulated_layers(4);
    let mut rows = Vec::new();
    for agent in AgentKind::ALL {
        let mut env = make_env(
            presets::system1(),
            vec![WorkloadSpec::training(model.clone(), 1024)],
            Objective::PerfPerBwPerNpu,
        );
        let t0 = std::time::Instant::now();
        let r = DseRunner::new(DseConfig::new(agent, STEPS, 99), SearchScope::FullStack)
            .run(&mut env);
        rows.push(vec![
            agent.name().to_string(),
            format!("{:.4e}", r.best_reward),
            format!("{}", r.steps_to_peak),
            format!("{}", r.invalid),
            format!("{:.2}s", t0.elapsed().as_secs_f64()),
        ]);
    }
    print_table(
        "Agent comparison (GPT3-13B, System 1, full-stack, 600 steps)",
        &["agent", "best reward", "steps to peak", "invalid", "wall"],
        &rows,
    );

    // BO with the AOT-compiled GP surrogate (Layer 2 artifact) when the
    // artifacts are built; identical math to the Rust fallback.
    if Path::new("artifacts/gp_surrogate.hlo.txt").exists() {
        match Runtime::cpu() {
            Ok(rt) => {
                let gp = GpSurrogate::load(Some(&rt.client), Path::new("artifacts"), 0.4);
                println!(
                    "\nBO with {} surrogate:",
                    if gp.is_xla() { "XLA (PJRT)" } else { "rust" }
                );
                let mut env = make_env(
                    presets::system1(),
                    vec![WorkloadSpec::training(model, 1024)],
                    Objective::PerfPerBwPerNpu,
                );
                let space = env.pss.build_space(SearchScope::FullStack);
                let mut bo = BayesOpt::new(space, 64, 99).with_surrogate(Box::new(gp));
                let r = DseRunner::new(DseConfig::new(AgentKind::Bo, 150, 99), SearchScope::FullStack)
                    .run_with_agent(&mut env, &mut bo);
                println!("best reward {:.4e} at step {}", r.best_reward, r.steps_to_peak);
            }
            Err(e) => println!("PJRT unavailable: {e:#}"),
        }
    } else {
        println!("\n(artifacts not built; run `make artifacts` to try the XLA-backed BO)");
    }
}
