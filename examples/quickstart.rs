//! Quickstart: simulate one distributed-training design point.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Builds Table 3's System 1 (512 TPUv5p-like NPUs), trains GPT3-13B
//! with a hand-picked parallelization, and prints the simulator's
//! latency/memory/utilization report.

use cosmic::prelude::*;

fn main() {
    // 1. A target cluster: Table 3's System 1 preset. Presets are plain
    //    data — build your own ClusterConfig for custom fabrics.
    let cluster = cosmic::sim::presets::system1();
    println!("cluster: {} ({} NPUs)", cluster.topology, cluster.npus());

    // 2. A workload: GPT3-13B from Table 2, simulating 4 layers with
    //    post-scaling (the paper's own trick to bound simulation time).
    let model = cosmic::workload::models::presets::gpt3_13b().with_simulated_layers(4);
    println!("model:   {} ({:.1}B params)", model.name, model.total_params() as f64 / 1e9);

    // 3. A parallelization: DP=64, SP=1, PP=1; TP is derived (=8 here);
    //    ZeRO weight sharding on.
    let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).expect("valid par");
    println!("par:     {par}");

    // 4. Simulate one training iteration at global batch 1024.
    let report = Simulator::new()
        .run(&cluster, &model, &par, 1024, ExecutionMode::Training)
        .expect("valid design point");

    println!("\niteration latency : {:>10.2} ms", report.latency_us / 1e3);
    println!("compute time      : {:>10.2} ms", report.compute_us / 1e3);
    println!("blocking comm     : {:>10.2} ms", report.comm_blocking_us / 1e3);
    println!("exposed grad sync : {:>10.2} ms", report.comm_exposed_us / 1e3);
    println!("memory per NPU    : {:>10.2} GB", report.memory.total() / 1e9);
    println!("cluster throughput: {:>10.1} TFLOP/s", report.achieved_tflops);
    println!("comm fraction     : {:>10.1} %", report.comm_fraction() * 100.0);

    // 5. The §5.4 memory constraint in action: drop sharding and the
    //    same design point becomes invalid.
    let dense = Parallelization::derive(cluster.npus(), 64, 1, 1, false).unwrap();
    match Simulator::new().run(&cluster, &model, &dense, 1024, ExecutionMode::Training) {
        Err(e) => println!("\nwithout weight sharding: rejected ({e:?})"),
        Ok(r) => println!("\nwithout weight sharding: {:.2} GB/NPU", r.memory.total() / 1e9),
    }
}
