//! End-to-end driver: proves all three layers compose on a real small
//! workload (the EXPERIMENTS.md §E2E run).
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Pipeline exercised, in order:
//! 1. **Runtime (PJRT)** — load both AOT artifacts (L1 Pallas roofline
//!    kernel inside the L2 cost-model graph; L2 GP surrogate) and verify
//!    the XLA path is live.
//! 2. **Analytical pre-filter** — sample 256 random valid full-stack
//!    candidates, score the whole batch in ONE XLA execution, and check
//!    the ranking against the discrete-event simulator (Spearman-ish
//!    top-bucket agreement).
//! 3. **Full DSE** — run the paper's headline experiment in miniature:
//!    GPT3-175B on System 2, workload-only vs full-stack, with the BO
//!    agent's posterior evaluated through the XLA GP artifact.
//! 4. Report the headline metric (full-stack / single-stack improvement)
//!    and the convergence curve.

use cosmic::agents::{AgentKind, BayesOpt};
use cosmic::dse::prefilter::{pack_batch, Candidate};
use cosmic::dse::{DseConfig, DseRunner, Objective, WorkloadSpec};
use cosmic::harness::{make_env, scoped_search};
use cosmic::pss::SearchScope;
use cosmic::runtime::{GpSurrogate, Runtime};
use cosmic::sim::presets;
use cosmic::util::Rng;
use cosmic::workload::models::presets as models;
use cosmic::workload::ExecutionMode;
use std::path::Path;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("=== COSMIC end-to-end driver ===\n");

    // ---- 1. runtime + artifacts ----
    let dir = Path::new("artifacts");
    let rt = Runtime::cpu().expect("PJRT CPU client");
    println!("[1] PJRT platform: {}", rt.platform());
    let (cost_model, gp) = rt.load_models(dir);
    println!("    cost_model artifact: {}", if cost_model.is_xla() { "XLA" } else { "rust fallback" });
    println!("    gp_surrogate artifact: {}", if gp.is_xla() { "XLA" } else { "rust fallback" });

    // ---- 2. batched analytical pre-filter through XLA ----
    let model = models::gpt3_175b().with_simulated_layers(4);
    let env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model.clone(), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let space = env.pss.build_space(SearchScope::FullStack);
    let mut rng = Rng::seed_from_u64(2025);
    let mut designs = Vec::new();
    while designs.len() < 256 {
        if let Some(g) = space.random_valid_genome(&mut rng, 500) {
            // Keep only simulatable candidates (the §5.4 memory check
            // also applies to the pre-filter's comparison baseline).
            if env.latency_us(&g).is_none() {
                continue;
            }
            if let Ok(point) = env.pss.schema.decode_valid(&g) {
                if let Ok(cp) = env.pss.materialize(&point) {
                    designs.push((g, cp));
                }
            }
        }
    }
    let candidates: Vec<Candidate> = designs
        .iter()
        .map(|(_, (cluster, par))| Candidate { cluster, par })
        .collect();
    let (batch, n) =
        pack_batch(&model, 2048, ExecutionMode::Training, &candidates).expect("pack");
    let t_batch = Instant::now();
    let estimates = cost_model.evaluate(&batch).expect("xla batch eval");
    let batch_us = t_batch.elapsed().as_secs_f64() * 1e6;
    println!("\n[2] analytical pre-filter: {n} candidates in one XLA call = {batch_us:.0} us");

    // Rank agreement: the analytically-best decile should be clearly
    // better under full simulation than the analytically-worst decile.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| estimates[a].partial_cmp(&estimates[b]).unwrap());
    let sim_latency = |idx: usize| env.latency_us(&designs[idx].0).unwrap_or(f64::INFINITY);
    let top: f64 = order[..16].iter().map(|&i| sim_latency(i)).sum::<f64>() / 16.0;
    let bottom: f64 = order[n - 16..].iter().map(|&i| sim_latency(i)).sum::<f64>() / 16.0;
    println!(
        "    simulator check: best-decile mean {:.1} ms vs worst-decile mean {:.1} ms -> {}",
        top / 1e3,
        bottom / 1e3,
        if top < bottom { "ranking agrees" } else { "ranking DISAGREES" }
    );

    // ---- 3. the headline DSE, with the XLA GP inside BO ----
    println!("\n[3] headline DSE: GPT3-175B on System 2, perf-per-BW/NPU");
    let mut env_wl = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model.clone(), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let wl_only = scoped_search(&mut env_wl, SearchScope::WorkloadOnly, AgentKind::Ga, 500, 1);
    println!(
        "    workload-only: best reward {:.4e} (latency {:.1} ms)",
        wl_only.run.best_reward,
        wl_only.best_latency_us / 1e3
    );

    let mut env_full = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model.clone(), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    // GA broad search + BO (XLA GP surrogate) refinement share the env.
    let ga = DseRunner::new(DseConfig::new(AgentKind::Ga, 1200, 1), SearchScope::FullStack)
        .run(&mut env_full);
    let bo_space = env_full.pss.build_space(SearchScope::FullStack);
    let mut bo = BayesOpt::new(bo_space, 64, 1)
        .with_surrogate(Box::new(GpSurrogate::load(Some(&rt.client), dir, 0.4)));
    let bo_run = DseRunner::new(DseConfig::new(AgentKind::Bo, 300, 1), SearchScope::FullStack)
        .run_with_agent(&mut env_full, &mut bo);
    let full_best = ga.best_reward.max(bo_run.best_reward);
    let improvement = full_best / wl_only.run.best_reward.max(1e-300);
    println!(
        "    full-stack:   best reward {:.4e} (GA) / {:.4e} (BO+XLA-GP)",
        ga.best_reward, bo_run.best_reward
    );

    // ---- 4. headline ----
    println!("\n[4] headline: full-stack / workload-only = {improvement:.2}x");
    println!("    (paper: 1.50-48.41x on System 1, 3.15-17.67x on System 2)");
    println!("    convergence (GA best-so-far, every 200 steps):");
    for (i, v) in ga.reward_curve().iter().enumerate() {
        if i % 200 == 0 || i + 1 == ga.history.len() {
            println!("      step {:>5}: {v:.4e}", i + 1);
        }
    }
    println!(
        "\nall layers composed: Pallas kernel -> JAX graph -> HLO text -> PJRT -> rust DSE. \
         total {:.1}s",
        t0.elapsed().as_secs_f64()
    );
    assert!(improvement >= 1.0, "full-stack must not lose to workload-only");
    println!("E2E OK");
}
