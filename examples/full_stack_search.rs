//! Full-stack DSE (the paper's §6.1 experiment, in miniature).
//!
//! ```sh
//! cargo run --release --example full_stack_search
//! ```
//!
//! Runs a GA-driven full-stack search for GPT3-175B training on
//! System 2 under the perf-per-BW/NPU reward, then re-runs the same
//! budget restricted to each single stack and prints the paper's
//! headline comparison (full-stack vs isolated optimization).

use cosmic::agents::AgentKind;
use cosmic::dse::{Objective, WorkloadSpec};
use cosmic::harness::{make_env, print_table, scoped_search};
use cosmic::pss::SearchScope;
use cosmic::sim::presets;
use cosmic::workload::models::presets as models;

fn main() {
    let model = models::gpt3_175b().with_simulated_layers(4);
    let scopes = [
        SearchScope::WorkloadOnly,
        SearchScope::CollectiveOnly,
        SearchScope::NetworkOnly,
        SearchScope::FullStack,
    ];

    let mut rows = Vec::new();
    let mut results = Vec::new();
    for scope in scopes {
        let mut env = make_env(
            presets::system2(),
            vec![WorkloadSpec::training(model.clone(), 2048)],
            Objective::PerfPerBwPerNpu,
        );
        let steps = if scope == SearchScope::FullStack { 1500 } else { 500 };
        let r = scoped_search(&mut env, scope, AgentKind::Ga, steps, 7);
        println!(
            "{:<16} best reward {:.4e} (peak at step {}, {} invalid, {:.2}s)",
            scope.name(),
            r.run.best_reward,
            r.run.steps_to_peak,
            r.run.invalid,
            r.wall_secs
        );
        rows.push(vec![
            scope.name().to_string(),
            format!("{:.4e}", r.run.best_reward),
            format!("{:.1}", r.best_latency_us / 1e3),
        ]);
        results.push((scope, r.run.best_reward));
    }

    let full = results.last().unwrap().1;
    for (i, (_, reward)) in results.iter().enumerate() {
        rows[i].push(format!("{:.2}x", full / reward.max(1e-300)));
    }
    print_table(
        "Full-stack vs single-stack optimization (GPT3-175B, System 2)",
        &["scope", "best reward", "best latency (ms)", "full-stack advantage"],
        &rows,
    );
    println!(
        "\npaper's headline: full-stack delivers 1.50-48.41x (Sys 1) / 3.15-17.67x (Sys 2)\n\
         over isolated single-stack optimization; the shape to check here is that the\n\
         full-stack row dominates every other row."
    );
}
