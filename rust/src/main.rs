//! `cosmic` — the CLI leader for the COSMIC framework.
//!
//! Subcommands:
//!
//! - `simulate` — run the end-to-end simulator on one design point.
//! - `search`   — run an agent-driven DSE (the paper's §6 experiments).
//! - `space`    — report the PsA design-space cardinality (Table 1).
//! - `runtime`  — probe the PJRT runtime and artifact status.
//!
//! Argument parsing is hand-rolled (`clap` is not vendored offline; see
//! DESIGN.md §Substitutions).

use cosmic::agents::AgentKind;
use cosmic::dse::{DseConfig, DseRunner, Environment, Objective, WorkloadSpec};
use cosmic::psa::{design_space_size, paper_table4_schema, space::exhaustive_search_years};
use cosmic::pss::{Pss, SearchScope};
use cosmic::sim::{presets, Simulator};
use cosmic::workload::models::presets as models;
use cosmic::workload::{ExecutionMode, Parallelization};
use std::collections::HashMap;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return ExitCode::FAILURE;
    };
    let opts = parse_opts(&args[1..]);
    let result = match cmd.as_str() {
        "simulate" => cmd_simulate(&opts),
        "search" => cmd_search(&opts),
        "space" => cmd_space(&opts),
        "runtime" => cmd_runtime(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            print_usage();
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    eprintln!(
        "cosmic — full-stack co-design of distributed ML systems

USAGE:
  cosmic simulate [--system 1|2|3] [--model NAME] [--batch N]
                  [--dp N --sp N --pp N --shard 0|1] [--layers N] [--mode train|prefill|decode]
  cosmic search   [--system 1|2|3] [--model NAME] [--batch N] [--agent RW|GA|ACO|BO]
                  [--scope full|workload|collective|network] [--steps N] [--seed N]
                  [--objective bw|cost|latency]
  cosmic space    [--npus N] [--dims N]
  cosmic runtime

MODELS: GPT3-175B GPT3-13B ViT-Base ViT-Large"
    );
}

type Opts = HashMap<String, String>;

fn parse_opts(args: &[String]) -> Opts {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let value = args.get(i + 1).cloned().unwrap_or_default();
            map.insert(key.to_string(), value);
            i += 2;
        } else {
            i += 1;
        }
    }
    map
}

fn opt_u64(opts: &Opts, key: &str, default: u64) -> u64 {
    opts.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn opt_str<'a>(opts: &'a Opts, key: &str, default: &'a str) -> &'a str {
    opts.get(key).map(String::as_str).unwrap_or(default)
}

fn load_system(opts: &Opts) -> Result<cosmic::sim::ClusterConfig, String> {
    let idx = opt_u64(opts, "system", 2) as usize;
    presets::by_index(idx).ok_or_else(|| format!("no system preset {idx}"))
}

fn load_model(opts: &Opts) -> Result<cosmic::workload::ModelConfig, String> {
    let name = opt_str(opts, "model", "GPT3-175B");
    let layers = opt_u64(opts, "layers", 4);
    models::by_name(name)
        .map(|m| m.with_simulated_layers(layers))
        .ok_or_else(|| format!("unknown model '{name}'"))
}

fn cmd_simulate(opts: &Opts) -> Result<(), String> {
    let cluster = load_system(opts)?;
    let model = load_model(opts)?;
    let batch = opt_u64(opts, "batch", 2048);
    let mode = match opt_str(opts, "mode", "train") {
        "train" => ExecutionMode::Training,
        "prefill" => ExecutionMode::InferencePrefill,
        "decode" => ExecutionMode::InferenceDecode,
        m => return Err(format!("unknown mode '{m}'")),
    };
    let par = Parallelization::derive(
        cluster.npus(),
        opt_u64(opts, "dp", 64),
        opt_u64(opts, "sp", 4),
        opt_u64(opts, "pp", 1),
        opt_u64(opts, "shard", 1) != 0,
    )?;
    println!("system: {} ({} NPUs)", cluster.topology, cluster.npus());
    println!("model:  {} (simulating {} layers)", model.name, model.simulated_layers);
    println!("par:    {par}");
    match Simulator::new().run(&cluster, &model, &par, batch, mode) {
        Ok(r) => {
            println!("latency:        {:>12.3} ms", r.latency_us / 1e3);
            println!("compute:        {:>12.3} ms", r.compute_us / 1e3);
            println!("comm blocking:  {:>12.3} ms", r.comm_blocking_us / 1e3);
            println!("comm exposed:   {:>12.3} ms", r.comm_exposed_us / 1e3);
            println!("memory/NPU:     {:>12.3} GB", r.memory.total() / 1e9);
            println!("microbatches:   {:>12}", r.microbatches);
            println!("cluster TFLOPs: {:>12.1}", r.achieved_tflops);
            Ok(())
        }
        Err(e) => Err(format!("invalid design point: {e:?}")),
    }
}

fn cmd_search(opts: &Opts) -> Result<(), String> {
    let cluster = load_system(opts)?;
    let model = load_model(opts)?;
    let batch = opt_u64(opts, "batch", 2048);
    let steps = opt_u64(opts, "steps", 300);
    let seed = opt_u64(opts, "seed", 42);
    let agent = AgentKind::from_name(opt_str(opts, "agent", "GA"))
        .ok_or_else(|| "unknown agent".to_string())?;
    let scope = match opt_str(opts, "scope", "full") {
        "full" => SearchScope::FullStack,
        "workload" => SearchScope::WorkloadOnly,
        "collective" => SearchScope::CollectiveOnly,
        "network" => SearchScope::NetworkOnly,
        "workload+network" => SearchScope::WorkloadNetwork,
        "collective+network" => SearchScope::CollectiveNetwork,
        s => return Err(format!("unknown scope '{s}'")),
    };
    let objective = Objective::from_name(opt_str(opts, "objective", "bw"))
        .ok_or_else(|| "unknown objective".to_string())?;

    let npus = cluster.npus();
    let baseline_par = Parallelization::derive(npus, npus.min(64), 1, 1, true)?;
    let pss =
        Pss::new(paper_table4_schema(npus, cluster.topology.num_dims()), cluster, baseline_par);
    let mut env = Environment::new(pss, vec![WorkloadSpec::training(model, batch)], objective);

    println!(
        "search: agent={} scope={} objective={} steps={steps} seed={seed}",
        agent.name(),
        scope.name(),
        objective.name()
    );
    let started = std::time::Instant::now();
    let result = DseRunner::new(DseConfig::new(agent, steps, seed), scope).run(&mut env);
    let elapsed = started.elapsed();
    println!(
        "done in {:.2}s  ({:.0} evals/s, {} invalid, {} cache hits)",
        elapsed.as_secs_f64(),
        env.evals() as f64 / elapsed.as_secs_f64().max(1e-9),
        result.invalid,
        env.cache_hits()
    );
    println!(
        "best reward: {:.6e} (first reached at step {})",
        result.best_reward, result.steps_to_peak
    );
    if !result.best_genome.is_empty() {
        let point = env.pss.schema.decode(&result.best_genome)?;
        let (best_cluster, best_par) = env.pss.materialize(&point)?;
        println!("best design:");
        println!("  topology:   {}", best_cluster.topology);
        println!(
            "  collective: {} chunks={} {} {}",
            best_cluster.collectives.algo_notation(),
            best_cluster.collectives.chunks,
            best_cluster.collectives.scheduling.name(),
            best_cluster.collectives.multidim.name()
        );
        println!("  workload:   {best_par}");
    }
    Ok(())
}

fn cmd_space(opts: &Opts) -> Result<(), String> {
    let npus = opt_u64(opts, "npus", 1024);
    let dims = opt_u64(opts, "dims", 4) as usize;
    let schema = cosmic::psa::paper_table1_schema(npus, dims);
    let points = design_space_size(&schema, npus);
    println!("PsA design space for {npus} NPUs, {dims}D network (Table 1 schema):");
    for p in &schema.params {
        println!("  {:<24} [{:<10}] {:>8} points", p.name, p.stack.name(), p.cardinality());
    }
    println!("total: {points:.3e} potential designs");
    println!(
        "exhaustive search at 1 s/point: {:.3e} years",
        exhaustive_search_years(points, 1.0)
    );
    Ok(())
}

fn cmd_runtime() -> Result<(), String> {
    let dir = cosmic::runtime::default_artifact_dir();
    println!("artifact dir: {}", dir.display());
    match cosmic::runtime::Runtime::cpu() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            let (cm, gp) = rt.load_models(&dir);
            println!(
                "cost_model:   {}",
                if cm.is_xla() { "XLA artifact" } else { "rust fallback" }
            );
            println!(
                "gp_surrogate: {}",
                if gp.is_xla() { "XLA artifact" } else { "rust fallback" }
            );
            let out = cm
                .evaluate(&cosmic::runtime::CostBatch::zeros())
                .map_err(|e| e.to_string())?;
            println!(
                "smoke eval:   {} configs -> all-zero ok = {}",
                out.len(),
                out.iter().all(|&x| x == 0.0)
            );
            Ok(())
        }
        Err(e) => Err(format!("PJRT client unavailable: {e:#}")),
    }
}
