//! `cosmic` — the CLI leader for the COSMIC framework.
//!
//! Subcommands:
//!
//! - `simulate` — run the end-to-end simulator on one design point,
//!   optionally exporting a Chrome-trace timeline (`--trace`).
//! - `search`   — run an agent-driven DSE (the paper's §6 experiments),
//!   optionally writing run telemetry (`--telemetry`).
//! - `space`    — report the PsA design-space cardinality (Table 1).
//! - `validate-json` — check files against the built-in JSON validator.
//! - `runtime`  — probe the PJRT runtime and artifact status.
//!
//! Argument parsing is hand-rolled (`clap` is not vendored offline; see
//! DESIGN.md §Substitutions).

use cosmic::agents::AgentKind;
use cosmic::dse::{
    DseConfig, DseRunner, Environment, Objective, RobustAggregate, SearchStrategy, WorkloadSpec,
};
use cosmic::faults::{FaultScenario, ScenarioSuite};
use cosmic::netsim::FidelityMode;
use cosmic::obs::{Recorder, SearchObserver};
use cosmic::psa::{
    design_space_size, paper_table4_schema, space::exhaustive_search_years, with_checkpoint_param,
};
use cosmic::pss::{Pss, SearchScope};
use cosmic::sim::{presets, Simulator};
use cosmic::workload::models::presets as models;
use cosmic::workload::{ExecutionMode, Parallelization};
use std::collections::HashMap;
use std::process::ExitCode;
use std::sync::Arc;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return ExitCode::FAILURE;
    };
    let result = match cmd.as_str() {
        "simulate" => parse_opts(&args[1..], SIMULATE_FLAGS).and_then(|o| cmd_simulate(&o)),
        "search" => parse_opts(&args[1..], SEARCH_FLAGS).and_then(|o| cmd_search(&o)),
        "space" => parse_opts(&args[1..], SPACE_FLAGS).and_then(|o| cmd_space(&o)),
        "validate-json" => cmd_validate_json(&args[1..]),
        "runtime" => parse_opts(&args[1..], &[]).and_then(|_| cmd_runtime()),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => Err(format!("unknown command '{other}'")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            print_usage();
            ExitCode::FAILURE
        }
    }
}

fn print_usage() {
    eprintln!(
        "cosmic — full-stack co-design of distributed ML systems

USAGE:
  cosmic simulate [--system 1|2|3] [--model NAME] [--batch N]
                  [--dp N --sp N --pp N --shard 0|1] [--layers N] [--mode train|prefill|decode]
                  [--fidelity analytical|flow|packet] [--chunk-precedence 0|1] [--trace FILE.json]
                  [--faults SEED] [--ckpt ITERS]
                  [--traffic none|constant|diurnal|bursty|FILE.json] [--traffic-seed N]
  cosmic search   [--system 1|2|3] [--model NAME] [--batch N] [--agent RW|GA|ACO|BO]
                  [--scope full|workload|collective|network] [--steps N] [--seed N]
                  [--objective bw|cost|latency]
                  [--strategy genome|analytical|flow|packet|staged|staged-packet]
                  [--chunk-precedence 0|1|knob] [--promote K] [--packet-top K]
                  [--cache-cap N] [--progress N] [--telemetry FILE.json]
                  [--robust expected|worst] [--scenarios K] [--faults-seed N]
                  [--traffic PROFILE|FILE.json] [--traffic-seed N] [--traffic-traces K]
  cosmic space    [--npus N] [--dims N]
  cosmic validate-json FILE...
  cosmic runtime

MODELS: GPT3-175B GPT3-13B ViT-Base ViT-Large"
    );
}

type Opts = HashMap<String, String>;

/// The value-taking flags each subcommand accepts (without the `--`).
const SIMULATE_FLAGS: &[&str] = &[
    "system",
    "model",
    "batch",
    "dp",
    "sp",
    "pp",
    "shard",
    "layers",
    "mode",
    "fidelity",
    "chunk-precedence",
    "trace",
    "faults",
    "ckpt",
    "traffic",
    "traffic-seed",
];
const SEARCH_FLAGS: &[&str] = &[
    "system",
    "model",
    "batch",
    "agent",
    "scope",
    "steps",
    "seed",
    "objective",
    "strategy",
    "chunk-precedence",
    "promote",
    "packet-top",
    "cache-cap",
    "progress",
    "telemetry",
    "robust",
    "scenarios",
    "faults-seed",
    "traffic",
    "traffic-seed",
    "traffic-traces",
];
const SPACE_FLAGS: &[&str] = &["npus", "dims"];

/// Strict flag parser: every token must form a known `--flag value`
/// pair. Unknown flags, missing values, stray positionals and repeated
/// flags all error with the offending token — a typo exits nonzero
/// instead of silently running with defaults.
fn parse_opts(args: &[String], known: &[&str]) -> Result<Opts, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            return Err(format!(
                "unexpected argument '{}' (flags look like --key value)",
                args[i]
            ));
        };
        if !known.contains(&key) {
            return Err(format!("unknown flag '--{key}'"));
        }
        let Some(value) = args.get(i + 1) else {
            return Err(format!("flag '--{key}' is missing its value"));
        };
        if value.starts_with("--") {
            return Err(format!("flag '--{key}' is missing its value (got '{value}')"));
        }
        if map.insert(key.to_string(), value.clone()).is_some() {
            return Err(format!("flag '--{key}' given twice"));
        }
        i += 2;
    }
    Ok(map)
}

fn opt_u64(opts: &Opts, key: &str, default: u64) -> Result<u64, String> {
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| format!("flag '--{key}' needs an unsigned integer, got '{v}'")),
    }
}

fn opt_str<'a>(opts: &'a Opts, key: &str, default: &'a str) -> &'a str {
    opts.get(key).map(String::as_str).unwrap_or(default)
}

fn load_system(opts: &Opts) -> Result<cosmic::sim::ClusterConfig, String> {
    let idx = opt_u64(opts, "system", 2)? as usize;
    presets::by_index(idx).ok_or_else(|| format!("no system preset {idx}"))
}

fn load_model(opts: &Opts) -> Result<cosmic::workload::ModelConfig, String> {
    let name = opt_str(opts, "model", "GPT3-175B");
    let layers = opt_u64(opts, "layers", 4)?;
    models::by_name(name)
        .map(|m| m.with_simulated_layers(layers))
        .ok_or_else(|| format!("unknown model '{name}'"))
}

/// Resolve `--traffic`: a named profile ("none" | "constant" |
/// "diurnal" | "bursty", seeded generators) or a path to a replay JSON
/// file written by `TrafficTrace::to_json`.
fn load_traffic(spec: &str, seed: u64, dims: usize) -> Result<cosmic::netsim::TrafficTrace, String> {
    if std::path::Path::new(spec).is_file() {
        let text = std::fs::read_to_string(spec).map_err(|e| format!("read {spec}: {e}"))?;
        cosmic::netsim::TrafficTrace::from_json(&text).map_err(|e| format!("{spec}: {e}"))
    } else {
        cosmic::netsim::TrafficTrace::from_profile(spec, seed, dims)
    }
}

fn cmd_simulate(opts: &Opts) -> Result<(), String> {
    let cluster = load_system(opts)?;
    let model = load_model(opts)?;
    let batch = opt_u64(opts, "batch", 2048)?;
    let mode = match opt_str(opts, "mode", "train") {
        "train" => ExecutionMode::Training,
        "prefill" => ExecutionMode::InferencePrefill,
        "decode" => ExecutionMode::InferenceDecode,
        m => return Err(format!("unknown mode '{m}'")),
    };
    let par = Parallelization::derive(
        cluster.npus(),
        opt_u64(opts, "dp", 64)?,
        opt_u64(opts, "sp", 4)?,
        opt_u64(opts, "pp", 1)?,
        opt_u64(opts, "shard", 1)? != 0,
    )?;
    let fidelity = match opt_str(opts, "fidelity", "analytical") {
        "analytical" => FidelityMode::Analytical,
        "flow" => FidelityMode::FlowLevel,
        "packet" => FidelityMode::Packet,
        f => return Err(format!("unknown fidelity '{f}'")),
    };
    let mut sim = Simulator::new().with_fidelity(fidelity);
    match opt_str(opts, "chunk-precedence", "0") {
        "0" => {}
        "1" => {
            if fidelity != FidelityMode::FlowLevel {
                return Err(
                    "--chunk-precedence 1 needs --fidelity flow (the analytical and packet \
                     rungs ignore the mode)"
                        .to_string(),
                );
            }
            sim = sim.with_flow_config(
                cosmic::netsim::FlowLevelConfig::default().with_chunk_precedence(true),
            );
            println!("chunk precedence: on (per-chunk flow FIFO drain)");
        }
        other => return Err(format!("--chunk-precedence needs 0 or 1, got '{other}'")),
    }
    let recorder = opts.get("trace").map(|_| Arc::new(Recorder::new()));
    if let Some(rec) = &recorder {
        sim = sim.with_trace_sink(Arc::clone(rec));
    }
    if let Some(v) = opts.get("faults") {
        let seed: u64 = v.parse().map_err(|_| format!("--faults needs a seed, got '{v}'"))?;
        let scenario = FaultScenario::from_seed(seed, cluster.topology.num_dims());
        let degraded_dims = (0..cluster.topology.num_dims())
            .filter(|&d| scenario.links.bw_factor(d) < 1.0 || scenario.links.lat_factor(d) > 1.0)
            .count();
        println!(
            "faults: {} (straggler x{:.2}, {} degraded dims, MTBF/device {:.0} h)",
            scenario.name,
            scenario.stragglers.worst_multiplier(),
            degraded_dims,
            scenario.failures.device_mtbf_hours
        );
        sim = sim.with_faults(Arc::new(scenario));
    }
    if let Some(v) = opts.get("ckpt") {
        let iters: u64 = v.parse().map_err(|_| format!("--ckpt needs iterations, got '{v}'"))?;
        sim = sim.with_checkpoint_interval(Some(iters));
    }
    if let Some(spec) = opts.get("traffic") {
        let seed = opt_u64(opts, "traffic-seed", 7)?;
        let trace = load_traffic(spec, seed, cluster.topology.num_dims())?;
        let means = trace.period_means();
        println!(
            "traffic: {} (fingerprint {:016x}, mean util {})",
            trace.profile(),
            trace.fingerprint(),
            means.iter().map(|u| format!("{:.0}%", u * 100.0)).collect::<Vec<_>>().join("/")
        );
        sim = sim.with_traffic(Arc::new(trace));
    }
    println!("system: {} ({} NPUs)", cluster.topology, cluster.npus());
    println!("model:  {} (simulating {} layers)", model.name, model.simulated_layers);
    println!("par:    {par}");
    match sim.run(&cluster, &model, &par, batch, mode) {
        Ok(r) => {
            println!("latency:        {:>12.3} ms", r.latency_us / 1e3);
            println!("compute:        {:>12.3} ms", r.compute_us / 1e3);
            println!("comm blocking:  {:>12.3} ms", r.comm_blocking_us / 1e3);
            println!("comm exposed:   {:>12.3} ms", r.comm_exposed_us / 1e3);
            println!("memory/NPU:     {:>12.3} GB", r.memory.total() / 1e9);
            println!("microbatches:   {:>12}", r.microbatches);
            println!("cluster TFLOPs: {:>12.1}", r.achieved_tflops);
            if let Some(g) = &r.goodput {
                println!("ckpt interval:  {:>12.1} s", g.checkpoint_interval_s);
                println!("cluster MTBF:   {:>12.1} s", g.cluster_mtbf_s);
                println!("efficiency:     {:>12.4}", g.efficiency);
                println!("goodput TFLOPs: {:>12.1}", g.goodput_tflops);
            }
            if let (Some(rec), Some(path)) = (&recorder, opts.get("trace")) {
                let json = cosmic::obs::chrome_trace_json(&rec.spans());
                cosmic::util::json::validate(&json)
                    .map_err(|e| format!("internal: trace JSON invalid: {e}"))?;
                std::fs::write(path, &json).map_err(|e| format!("write {path}: {e}"))?;
                println!("trace:          {:>12} spans -> {path}", rec.span_count());
            }
            Ok(())
        }
        Err(e) => Err(format!("invalid design point: {e:?}")),
    }
}

fn cmd_search(opts: &Opts) -> Result<(), String> {
    let cluster = load_system(opts)?;
    let model = load_model(opts)?;
    let batch = opt_u64(opts, "batch", 2048)?;
    let steps = opt_u64(opts, "steps", 300)?;
    let seed = opt_u64(opts, "seed", 42)?;
    let agent = AgentKind::from_name(opt_str(opts, "agent", "GA"))
        .ok_or_else(|| "unknown agent".to_string())?;
    let scope = match opt_str(opts, "scope", "full") {
        "full" => SearchScope::FullStack,
        "workload" => SearchScope::WorkloadOnly,
        "collective" => SearchScope::CollectiveOnly,
        "network" => SearchScope::NetworkOnly,
        "workload+network" => SearchScope::WorkloadNetwork,
        "collective+network" => SearchScope::CollectiveNetwork,
        s => return Err(format!("unknown scope '{s}'")),
    };
    let objective = Objective::from_name(opt_str(opts, "objective", "bw"))
        .ok_or_else(|| "unknown objective".to_string())?;
    let strategy = match opt_str(opts, "strategy", "genome") {
        "genome" => SearchStrategy::GenomeFidelity,
        "analytical" => SearchStrategy::Fixed(FidelityMode::Analytical),
        "flow" => SearchStrategy::Fixed(FidelityMode::FlowLevel),
        "packet" => SearchStrategy::Fixed(FidelityMode::Packet),
        "staged" => SearchStrategy::Staged { promote_top_k: opt_u64(opts, "promote", 8)? as usize },
        "staged-packet" => SearchStrategy::StagedPacket {
            promote_top_k: opt_u64(opts, "promote", 8)? as usize,
            packet_top_k: opt_u64(opts, "packet-top", 3)? as usize,
        },
        s => return Err(format!("unknown strategy '{s}'")),
    };

    let robust = opts
        .get("robust")
        .map(|v| {
            RobustAggregate::from_name(v)
                .ok_or_else(|| format!("unknown robust aggregate '{v}' (expected|worst)"))
        })
        .transpose()?;
    let scenarios = opt_u64(opts, "scenarios", 4)? as usize;
    let faults_seed = opt_u64(opts, "faults-seed", 7)?;
    let traffic = opts.get("traffic").cloned();
    let traffic_seed = opt_u64(opts, "traffic-seed", 7)?;
    let traffic_k = opt_u64(opts, "traffic-traces", 2)? as usize;

    let chunk_prec = opt_str(opts, "chunk-precedence", "0");
    if !matches!(chunk_prec, "0" | "1" | "knob") {
        return Err(format!("--chunk-precedence needs 0|1|knob, got '{chunk_prec}'"));
    }

    let npus = cluster.npus();
    let dims = cluster.topology.num_dims();
    let baseline_par = Parallelization::derive(npus, npus.min(64), 1, 1, true)?;
    // Robust searches co-optimize the checkpoint interval, so the knob
    // joins the action space alongside the paper's Table 4 parameters.
    let mut schema = paper_table4_schema(npus, dims);
    if robust.is_some() {
        schema = with_checkpoint_param(schema);
    }
    if chunk_prec == "knob" {
        schema = cosmic::psa::with_chunk_precedence_param(schema);
    }
    let pss = Pss::new(schema, cluster, baseline_par);
    let mut env = Environment::new(pss, vec![WorkloadSpec::training(model, batch)], objective);
    match chunk_prec {
        "1" => {
            // Force the per-chunk drain for every flow-level evaluation
            // (whatever routes a genome there: the fidelity knob, a
            // fixed flow strategy, or staged promotion).
            env = env.with_flow_config(
                cosmic::netsim::FlowLevelConfig::default().with_chunk_precedence(true),
            );
            println!("chunk precedence: on for flow-level evaluations");
        }
        "knob" => println!("chunk precedence: searched (PsA \"Chunk Precedence\" knob)"),
        _ => {}
    }
    if let Some(aggregate) = robust {
        env = env.with_scenarios(ScenarioSuite::generate(faults_seed, scenarios, dims), aggregate);
    }
    if let Some(spec) = &traffic {
        env = env.with_traffic_seed(traffic_seed);
        if std::path::Path::new(spec).is_file() {
            // Replay mode: one pinned trace instead of a seeded sweep.
            let trace = load_traffic(spec, traffic_seed, dims)?;
            println!(
                "traffic: replay {} (fingerprint {:016x})",
                trace.profile(),
                trace.fingerprint()
            );
            env = env.with_traffic(Arc::new(trace));
        } else {
            let aggregate = robust.unwrap_or_default();
            let suite = cosmic::netsim::TrafficSuite::generate(spec, traffic_seed, traffic_k, dims)?;
            println!(
                "traffic: aggregate={} suite=nominal+{traffic_k} profile={spec} \
                 traffic-seed={traffic_seed}",
                aggregate.name()
            );
            env = env.with_traffic_suite(suite, aggregate);
        }
    }
    let cache_cap = opt_u64(opts, "cache-cap", 0)? as usize;
    if cache_cap > 0 {
        env = env.with_eval_cache_capacity(cache_cap, cache_cap);
    }
    let progress = opt_u64(opts, "progress", 0)?;
    let telemetry = opts.get("telemetry").cloned();
    let observer = (progress > 0 || telemetry.is_some())
        .then(|| Arc::new(SearchObserver::new().with_progress(progress)));

    println!(
        "search: agent={} scope={} objective={} steps={steps} seed={seed}",
        agent.name(),
        scope.name(),
        objective.name()
    );
    if let Some(aggregate) = robust {
        println!(
            "robust: aggregate={} suite=nominal+{scenarios} faults-seed={faults_seed}",
            aggregate.name()
        );
    }
    let started = std::time::Instant::now();
    let mut runner =
        DseRunner::new(DseConfig::new(agent, steps, seed), scope).with_strategy(strategy);
    if let Some(obs) = &observer {
        runner = runner.with_observer(Arc::clone(obs));
    }
    let result = runner.run(&mut env);
    let elapsed = started.elapsed();
    println!(
        "done in {:.2}s  ({:.0} evals/s, {} invalid, {} cache hits)",
        elapsed.as_secs_f64(),
        env.evals() as f64 / elapsed.as_secs_f64().max(1e-9),
        result.invalid,
        env.cache_hits()
    );
    let cs = env.eval_cache_stats();
    println!(
        "cache: memo {}h/{}e; trace {}h/{}m ({} evicted); coll {}h/{}m ({} evicted)",
        env.cache_hits(),
        env.evals(),
        cs.trace_hits,
        cs.trace_misses,
        cs.trace_evictions,
        cs.coll_hits,
        cs.coll_misses,
        cs.coll_evictions
    );
    println!(
        "fidelity spend: {} flow-level / {} packet-level / {} total evals",
        result.flow_evals, result.packet_evals, result.evals
    );
    if traffic.is_some() {
        println!("traffic spend: {} evaluations swept the co-tenant trace(s)", env.traffic_evals());
    }
    if !result.finalists.is_empty() {
        println!("finalists (screening reward -> flow-level reward):");
        for (g, screen, flow) in &result.finalists {
            println!("  {screen:.6e} -> {flow:.6e}  {g:?}");
        }
    }
    if !result.packet_finalists.is_empty() {
        println!("packet finalists (flow-level reward -> packet reward):");
        for (g, flow, pkt) in &result.packet_finalists {
            println!("  {flow:.6e} -> {pkt:.6e}  {g:?}");
        }
    }
    println!(
        "best reward: {:.6e} (first reached at step {})",
        result.best_reward, result.steps_to_peak
    );
    if let Some(obs) = &observer {
        env.export_metrics(&obs.metrics);
        obs.metrics.set_gauge("dse.best_reward", result.best_reward);
        obs.metrics.set_gauge("dse.steps_to_peak", result.steps_to_peak as f64);
        if let Some(path) = &telemetry {
            let json = obs.telemetry_json();
            cosmic::util::json::validate(&json)
                .map_err(|e| format!("internal: telemetry JSON invalid: {e}"))?;
            std::fs::write(path, &json).map_err(|e| format!("write {path}: {e}"))?;
            println!("telemetry -> {path}");
        }
    }
    if !result.best_genome.is_empty() {
        let point = env.pss.schema.decode(&result.best_genome)?;
        let (best_cluster, best_par) = env.pss.materialize(&point)?;
        println!("best design:");
        println!("  topology:   {}", best_cluster.topology);
        println!(
            "  collective: {} chunks={} {} {}",
            best_cluster.collectives.algo_notation(),
            best_cluster.collectives.chunks,
            best_cluster.collectives.scheduling.name(),
            best_cluster.collectives.multidim.name()
        );
        println!("  workload:   {best_par}");
        if robust.is_some() {
            match env.evaluate_suite(&result.best_genome, None) {
                Ok(suite) => {
                    println!("scenario breakdown of the best design:");
                    println!(
                        "  {:<12} {:>12} {:>8} {:>12} {:>14}",
                        "scenario", "latency ms", "eff", "goodput TF", "reward"
                    );
                    for s in &suite.scores {
                        println!(
                            "  {:<12} {:>12.3} {:>8.4} {:>12.1} {:>14.6e}",
                            s.scenario,
                            s.latency_us / 1e3,
                            s.efficiency,
                            s.goodput_tflops,
                            s.reward
                        );
                    }
                    println!("  {} reward: {:.6e}", suite.aggregate.name(), suite.reward);
                }
                Err(e) => println!("scenario breakdown unavailable: {e}"),
            }
        }
    }
    Ok(())
}

fn cmd_space(opts: &Opts) -> Result<(), String> {
    let npus = opt_u64(opts, "npus", 1024)?;
    let dims = opt_u64(opts, "dims", 4)? as usize;
    let schema = cosmic::psa::paper_table1_schema(npus, dims);
    let points = design_space_size(&schema, npus);
    println!("PsA design space for {npus} NPUs, {dims}D network (Table 1 schema):");
    for p in &schema.params {
        println!("  {:<24} [{:<10}] {:>8} points", p.name, p.stack.name(), p.cardinality());
    }
    println!("total: {points:.3e} potential designs");
    println!(
        "exhaustive search at 1 s/point: {:.3e} years",
        exhaustive_search_years(points, 1.0)
    );
    Ok(())
}

fn cmd_validate_json(paths: &[String]) -> Result<(), String> {
    if paths.is_empty() {
        return Err("validate-json needs at least one file argument".to_string());
    }
    for p in paths {
        let text = std::fs::read_to_string(p).map_err(|e| format!("read {p}: {e}"))?;
        cosmic::util::json::validate(&text).map_err(|e| format!("{p}: {e}"))?;
        println!("{p}: valid JSON ({} bytes)", text.len());
    }
    Ok(())
}

fn cmd_runtime() -> Result<(), String> {
    let dir = cosmic::runtime::default_artifact_dir();
    println!("artifact dir: {}", dir.display());
    match cosmic::runtime::Runtime::cpu() {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            let (cm, gp) = rt.load_models(&dir);
            println!(
                "cost_model:   {}",
                if cm.is_xla() { "XLA artifact" } else { "rust fallback" }
            );
            println!(
                "gp_surrogate: {}",
                if gp.is_xla() { "XLA artifact" } else { "rust fallback" }
            );
            let out = cm
                .evaluate(&cosmic::runtime::CostBatch::zeros())
                .map_err(|e| e.to_string())?;
            println!(
                "smoke eval:   {} configs -> all-zero ok = {}",
                out.len(),
                out.iter().all(|&x| x == 0.0)
            );
            Ok(())
        }
        Err(e) => Err(format!("PJRT client unavailable: {e:#}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn parser_accepts_known_pairs_and_defaults() {
        let o = parse_opts(&argv(&["--batch", "64", "--model", "ViT-Base"]), SIMULATE_FLAGS)
            .unwrap();
        assert_eq!(o.get("batch").map(String::as_str), Some("64"));
        assert_eq!(o.get("model").map(String::as_str), Some("ViT-Base"));
        assert_eq!(opt_u64(&o, "batch", 0).unwrap(), 64);
        assert_eq!(opt_u64(&o, "layers", 9).unwrap(), 9); // absent -> default
        assert!(parse_opts(&[], SEARCH_FLAGS).unwrap().is_empty());
    }

    #[test]
    fn parser_rejects_unknown_flag_with_token() {
        let e = parse_opts(&argv(&["--bogus", "1"]), SIMULATE_FLAGS).unwrap_err();
        assert!(e.contains("--bogus"), "{e}");
        // A flag valid for one command is still rejected for another.
        let e = parse_opts(&argv(&["--agent", "GA"]), SPACE_FLAGS).unwrap_err();
        assert!(e.contains("--agent"), "{e}");
    }

    #[test]
    fn parser_rejects_missing_value() {
        let e = parse_opts(&argv(&["--batch"]), SIMULATE_FLAGS).unwrap_err();
        assert!(e.contains("--batch"), "{e}");
        let e = parse_opts(&argv(&["--batch", "--model"]), SIMULATE_FLAGS).unwrap_err();
        assert!(e.contains("--batch"), "{e}");
    }

    #[test]
    fn parser_rejects_positionals_and_duplicates() {
        let e = parse_opts(&argv(&["stray"]), SEARCH_FLAGS).unwrap_err();
        assert!(e.contains("stray"), "{e}");
        let e = parse_opts(&argv(&["--seed", "1", "--seed", "2"]), SEARCH_FLAGS).unwrap_err();
        assert!(e.contains("--seed"), "{e}");
    }

    #[test]
    fn malformed_numeric_names_flag_and_token() {
        let o = parse_opts(&argv(&["--batch", "twelve"]), SIMULATE_FLAGS).unwrap();
        let e = opt_u64(&o, "batch", 0).unwrap_err();
        assert!(e.contains("--batch") && e.contains("twelve"), "{e}");
        let o = parse_opts(&argv(&["--steps", "-3"]), SEARCH_FLAGS).unwrap();
        assert!(opt_u64(&o, "steps", 0).is_err(), "negative must not parse as u64");
    }

    #[test]
    fn chunk_precedence_flag_is_known_where_it_applies() {
        assert!(parse_opts(&argv(&["--chunk-precedence", "1"]), SIMULATE_FLAGS).is_ok());
        assert!(parse_opts(&argv(&["--chunk-precedence", "knob"]), SEARCH_FLAGS).is_ok());
        assert!(parse_opts(&argv(&["--chunk-precedence", "1"]), SPACE_FLAGS).is_err());
    }

    #[test]
    fn traffic_spec_resolves_profiles_and_rejects_garbage() {
        assert!(load_traffic("diurnal", 7, 3).is_ok());
        assert!(load_traffic("none", 7, 3).unwrap().is_nominal());
        assert!(load_traffic("rushhour", 7, 3).is_err());
        assert!(load_traffic("/no/such/file.json", 7, 3).is_err());
    }
}
