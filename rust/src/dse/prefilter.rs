//! Batched analytical pre-filter: rank candidate design points with the
//! AOT-compiled L1/L2 cost model before paying for the detailed
//! discrete-event simulation.
//!
//! The DSE inner loop can score thousands of candidates; the analytical
//! estimate (roofline compute + alpha-beta collectives, no overlap or
//! pipelining) is a coarse but *monotone-enough* proxy. This module
//! packs a batch of materialized design points into the fixed-shape
//! [`CostBatch`] the artifact expects; `CostModel::evaluate` then runs
//! the whole batch through XLA (or the bit-identical Rust fallback).

use crate::collective::CollectiveKind;
use crate::runtime::{CostBatch, BATCH, DIMS, OPS};
use crate::sim::ClusterConfig;
use crate::topology::DimCost;
use crate::workload::{
    generate_trace, group_dim_costs, CommGroup, ExecutionMode, ModelConfig, Parallelization,
    TraceOp,
};

/// One candidate: a fully materialized design point.
pub struct Candidate<'a> {
    pub cluster: &'a ClusterConfig,
    pub par: &'a Parallelization,
}

/// Pack up to [`BATCH`] candidates into a [`CostBatch`]. Returns the
/// batch and the number of real (non-padding) rows. Padding rows are
/// all-zero and score 0.
///
/// Packing scheme per candidate:
/// - `flops/bytes[0..OPS)`: the per-microbatch compute ops of stage 0's
///   forward+backward trace, aggregated round-robin into `OPS` classes
///   and scaled by the microbatch count and layer re-scale.
/// - per network dimension `d < DIMS`: alpha steps/volume of every
///   collective in the trace whose group spans `d`, accumulated with
///   the per-dim algorithm's alpha-beta factors (chunking ignored — the
///   pre-filter is deliberately cruder than the simulator).
pub fn pack_batch(
    model: &ModelConfig,
    batch_size: u64,
    mode: ExecutionMode,
    candidates: &[Candidate<'_>],
) -> Result<(CostBatch, usize), String> {
    if candidates.len() > BATCH {
        return Err(format!("{} candidates exceed artifact batch {BATCH}", candidates.len()));
    }
    let mut cb = CostBatch::zeros();
    // Roofline constants come from the first candidate's device (all
    // candidates in one DSE share the compute knob — it is fixed per
    // target system in the paper).
    if let Some(first) = candidates.first() {
        cb.peak_flops_us = (first.cluster.compute.peak_tflops * 1e6) as f32;
        cb.mem_bytes_us = (first.cluster.compute.local_mem_bw_gbps * 1e3) as f32;
    }
    for (i, cand) in candidates.iter().enumerate() {
        let trace = generate_trace(model, cand.par, batch_size, mode)?;
        let stage = &trace.stages[0];
        let scale = trace.layer_scale * trace.microbatches as f64;
        let mut op_class = 0usize;
        for op in stage.forward.iter().chain(stage.backward.iter()) {
            match op {
                TraceOp::Compute { flops, bytes, .. } => {
                    cb.flops[i * OPS + op_class] += (*flops * scale) as f32;
                    cb.bytes[i * OPS + op_class] += (*bytes * scale) as f32;
                    op_class = (op_class + 1) % OPS;
                }
                TraceOp::Collective { kind, group, bytes, .. } => {
                    accumulate_collective(&mut cb, i, cand, *kind, *group, *bytes * scale);
                }
                TraceOp::P2p { bytes } => {
                    // Treat as a 2-member ring transfer on the outermost dim.
                    let d = cand.cluster.topology.num_dims().min(DIMS) - 1;
                    let dim = DimCost::from_dim(&cand.cluster.topology.dims[d]);
                    cb.steps[i * DIMS + d] += 1.0;
                    cb.alpha_us[i * DIMS + d] = dim.alpha_us as f32;
                    cb.volume[i * DIMS + d] += (*bytes * scale) as f32;
                    cb.beta[i * DIMS + d] = dim.beta_bytes_per_us as f32;
                }
            }
        }
    }
    Ok((cb, candidates.len()))
}

fn accumulate_collective(
    cb: &mut CostBatch,
    i: usize,
    cand: &Candidate<'_>,
    kind: CollectiveKind,
    group: CommGroup,
    bytes: f64,
) {
    let strides = cand.par.strides();
    let (stride, size) = match group {
        CommGroup::Tp => (strides.tp, cand.par.tp),
        CommGroup::Sp => (strides.sp, cand.par.sp),
        CommGroup::Dp => (strides.dp, cand.par.dp),
        CommGroup::DpSp => (strides.sp, cand.par.sp * cand.par.dp),
    };
    if size <= 1 {
        return;
    }
    let mut remaining = bytes;
    for (dim, d) in group_dim_costs(&cand.cluster.topology, stride, size) {
        if d >= DIMS {
            continue;
        }
        let algo = cand.cluster.collectives.algorithms[d];
        // Same closed forms as collective::algorithms, folded into the
        // artifact's (steps*alpha + volume/beta) shape.
        let t = crate::collective::collective_time_us(algo, kind, &dim, remaining);
        let alpha = dim.alpha_us.max(1e-6);
        // Decompose t into an alpha part (steps) and a beta part (volume).
        let beta_part = remaining / dim.beta_bytes_per_us;
        let alpha_part = (t - beta_part).max(0.0);
        cb.steps[i * DIMS + d] += (alpha_part / alpha) as f32;
        cb.alpha_us[i * DIMS + d] = alpha as f32;
        cb.volume[i * DIMS + d] += remaining as f32;
        cb.beta[i * DIMS + d] = dim.beta_bytes_per_us as f32;
        // Hierarchical shrink, as in the baseline multi-dim schedule.
        remaining /= dim.npus as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{cost_model_ref, CostModel};
    use crate::sim::{presets, Simulator};
    use crate::workload::models::presets as wl;
    use std::path::Path;

    #[test]
    fn pack_batch_respects_capacity() {
        let cluster = presets::system1();
        let par = Parallelization::derive(512, 64, 1, 1, true).unwrap();
        let model = wl::gpt3_13b().with_simulated_layers(2);
        let cands: Vec<Candidate> =
            (0..3).map(|_| Candidate { cluster: &cluster, par: &par }).collect();
        let (cb, n) = pack_batch(&model, 1024, ExecutionMode::Training, &cands).unwrap();
        assert_eq!(n, 3);
        assert!(cb.validate().is_ok());
        // Rows beyond n are zero-padding.
        let out = cost_model_ref(&cb);
        assert!(out[0] > 0.0);
        assert_eq!(out[3], 0.0);
    }

    #[test]
    fn prefilter_ranks_like_the_simulator_on_extremes() {
        // A clearly bad parallelization (tiny DP, giant TP over slow
        // dims) must rank worse than a balanced one in both the
        // analytical estimate and the full simulation.
        let cluster = presets::system2();
        let good = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        let bad = Parallelization::derive(1024, 1, 1, 1, true).unwrap(); // TP=1024
        let model = wl::gpt3_175b().with_simulated_layers(4);
        let cands = vec![
            Candidate { cluster: &cluster, par: &good },
            Candidate { cluster: &cluster, par: &bad },
        ];
        let (cb, _) = pack_batch(&model, 2048, ExecutionMode::Training, &cands).unwrap();
        let est = cost_model_ref(&cb);
        let sim = Simulator::new();
        let sim_good =
            sim.run(&cluster, &model, &good, 2048, ExecutionMode::Training).unwrap().latency_us;
        let sim_bad =
            sim.run(&cluster, &model, &bad, 2048, ExecutionMode::Training).unwrap().latency_us;
        assert!(sim_bad > sim_good);
        assert!(est[1] > est[0], "prefilter: bad={} good={}", est[1], est[0]);
    }

    #[test]
    fn xla_and_fallback_agree_on_packed_batches() {
        let cm = CostModel::load(None, Path::new("/nonexistent"));
        let cluster = presets::system1();
        let par = Parallelization::derive(512, 32, 2, 1, true).unwrap();
        let model = wl::vit_large().with_simulated_layers(4);
        let cands: Vec<Candidate> =
            (0..8).map(|_| Candidate { cluster: &cluster, par: &par }).collect();
        let (cb, _) = pack_batch(&model, 1024, ExecutionMode::Training, &cands).unwrap();
        let out = cm.evaluate(&cb).unwrap();
        let reference = cost_model_ref(&cb);
        for (a, b) in out.iter().zip(&reference) {
            assert!((a - b).abs() <= 1e-3 * b.abs().max(1.0));
        }
    }
}
