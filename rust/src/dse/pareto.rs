//! Multi-objective analysis: Pareto frontiers over evaluated designs.
//!
//! The paper optimizes one regularized scalar at a time (§5.4), but its
//! §6.4 point — many distinct configurations with equivalent reward —
//! is naturally a multi-objective statement: designs trade latency
//! against provisioned bandwidth and dollar cost. This module extracts
//! the non-dominated set over arbitrary metric vectors (all metrics
//! minimized), used by the ablation bench and available to downstream
//! users for co-design trade-off studies.

/// One evaluated design: an opaque id plus its metric vector
/// (all metrics are minimized).
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    pub id: usize,
    pub metrics: Vec<f64>,
}

impl ParetoPoint {
    pub fn new(id: usize, metrics: Vec<f64>) -> Self {
        Self { id, metrics }
    }

    /// Does `self` dominate `other` (≤ on every metric, < on at least
    /// one)?
    pub fn dominates(&self, other: &ParetoPoint) -> bool {
        debug_assert_eq!(self.metrics.len(), other.metrics.len());
        let mut strictly = false;
        for (a, b) in self.metrics.iter().zip(&other.metrics) {
            if a > b {
                return false;
            }
            if a < b {
                strictly = true;
            }
        }
        strictly
    }
}

/// Extract the Pareto frontier (non-dominated points), sorted by the
/// first metric. Duplicate metric vectors keep the first occurrence.
/// O(n²) pairwise — fine for DSE result sets (≤ thousands).
pub fn pareto_frontier(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut frontier: Vec<ParetoPoint> = Vec::new();
    'outer: for p in points {
        if p.metrics.iter().any(|m| !m.is_finite()) {
            continue;
        }
        let mut i = 0;
        while i < frontier.len() {
            if frontier[i].dominates(p) || frontier[i].metrics == p.metrics {
                continue 'outer; // dominated or duplicate
            }
            if p.dominates(&frontier[i]) {
                frontier.swap_remove(i);
            } else {
                i += 1;
            }
        }
        frontier.push(p.clone());
    }
    frontier.sort_by(|a, b| a.metrics[0].partial_cmp(&b.metrics[0]).unwrap());
    frontier
}

/// Hypervolume indicator in 2D (area dominated relative to a reference
/// point; both metrics minimized). A standard scalar summary for
/// comparing frontiers.
pub fn hypervolume_2d(frontier: &[ParetoPoint], reference: (f64, f64)) -> f64 {
    let mut pts: Vec<(f64, f64)> = frontier
        .iter()
        .filter(|p| p.metrics.len() >= 2)
        .map(|p| (p.metrics[0], p.metrics[1]))
        .filter(|(x, y)| *x <= reference.0 && *y <= reference.1)
        .collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut hv = 0.0;
    let mut prev_y = reference.1;
    for (x, y) in pts {
        if y < prev_y {
            hv += (reference.0 - x) * (prev_y - y);
            prev_y = y;
        }
    }
    hv
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(id: usize, m: &[f64]) -> ParetoPoint {
        ParetoPoint::new(id, m.to_vec())
    }

    #[test]
    fn dominance_semantics() {
        let a = p(0, &[1.0, 1.0]);
        let b = p(1, &[2.0, 2.0]);
        let c = p(2, &[1.0, 2.0]);
        assert!(a.dominates(&b));
        assert!(a.dominates(&c));
        assert!(!b.dominates(&a));
        assert!(!c.dominates(&a));
        // Equal vectors do not dominate each other.
        assert!(!a.dominates(&p(3, &[1.0, 1.0])));
    }

    #[test]
    fn frontier_drops_dominated() {
        let pts = vec![
            p(0, &[1.0, 5.0]),
            p(1, &[2.0, 4.0]),
            p(2, &[3.0, 3.0]),
            p(3, &[2.5, 4.5]), // dominated by id=1
            p(4, &[5.0, 5.0]), // dominated by everything
        ];
        let f = pareto_frontier(&pts);
        let ids: Vec<usize> = f.iter().map(|x| x.id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn frontier_handles_duplicates_and_nan() {
        let pts = vec![
            p(0, &[1.0, 1.0]),
            p(1, &[1.0, 1.0]),
            p(2, &[f64::NAN, 0.0]),
            p(3, &[0.5, 2.0]),
        ];
        let f = pareto_frontier(&pts);
        assert_eq!(f.len(), 2);
        assert!(f.iter().any(|x| x.id == 0));
        assert!(f.iter().any(|x| x.id == 3));
    }

    #[test]
    fn frontier_sorted_by_first_metric() {
        let pts = vec![p(0, &[3.0, 1.0]), p(1, &[1.0, 3.0]), p(2, &[2.0, 2.0])];
        let f = pareto_frontier(&pts);
        let xs: Vec<f64> = f.iter().map(|x| x.metrics[0]).collect();
        assert_eq!(xs, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn hypervolume_known_case() {
        // Single point (1,1) vs reference (3,3): area = 2*2 = 4.
        let f = vec![p(0, &[1.0, 1.0])];
        assert!((hypervolume_2d(&f, (3.0, 3.0)) - 4.0).abs() < 1e-12);
        // Two-point staircase.
        let f = vec![p(0, &[1.0, 2.0]), p(1, &[2.0, 1.0])];
        // (3-1)*(3-2) + (3-2)*(2-1) = 2 + 1 = 3.
        assert!((hypervolume_2d(&f, (3.0, 3.0)) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn hypervolume_ignores_out_of_reference() {
        let f = vec![p(0, &[5.0, 5.0])];
        assert_eq!(hypervolume_2d(&f, (3.0, 3.0)), 0.0);
    }

    #[test]
    fn bigger_frontier_no_smaller_hypervolume() {
        let small = vec![p(0, &[2.0, 2.0])];
        let big = vec![p(0, &[2.0, 2.0]), p(1, &[1.0, 2.5])];
        let r = (4.0, 4.0);
        assert!(hypervolume_2d(&big, r) >= hypervolume_2d(&small, r));
    }
}
