//! LIBRA-style network dollar-cost model (paper §5.4, cost model of [59]).
//!
//! The "Runtime per Network Cost" reward regularizes the search with the
//! dollar cost of the network build-out. Following LIBRA, cost is
//! dominated by link bandwidth-capacity and switch silicon:
//!
//! `cost = Σ_dim  links(dim) · bw(dim) · $per(GB/s, kind)  +  switches(dim) · $switch(radix, bw)`
//!
//! where `links(dim)` counts physical links across the whole cluster for
//! that dimension and the per-GB/s rate reflects the technology tier —
//! short-reach electrical (Ring/FC intra-dim) is cheap, switched fabrics
//! pay for ports and crossbar silicon.

use crate::topology::{DimKind, Topology};

/// $ per GB/s of point-to-point link capacity (arbitrary but fixed units;
/// only *relative* cost matters to the reward shape).
pub const LINK_COST_PER_GBPS: f64 = 1.0;
/// $ per GB/s of a switch port (NPU-side plus switch-side SerDes).
pub const SWITCH_PORT_COST_PER_GBPS: f64 = 2.0;
/// Fixed switch-chassis cost per port (radix tax).
pub const SWITCH_CHASSIS_PER_PORT: f64 = 50.0;

/// Physical links across the whole cluster for one dimension of `n` NPUs
/// appearing in `groups` parallel instances.
fn links_in_dim(kind: DimKind, n: u64, groups: u64) -> u64 {
    let per_group = match kind {
        DimKind::Ring => {
            if n <= 1 {
                0
            } else if n == 2 {
                1
            } else {
                n
            }
        }
        DimKind::Switch => n, // NPU-to-switch links
        DimKind::FullyConnected => n * n.saturating_sub(1) / 2,
    };
    per_group * groups
}

/// Total network dollar cost of a topology.
pub fn network_cost(topo: &Topology) -> f64 {
    let total = topo.total_npus();
    let mut cost = 0.0;
    for (d, dim) in topo.dims.iter().enumerate() {
        let groups = total / dim.npus;
        let links = links_in_dim(dim.kind, dim.npus, groups) as f64;
        let _ = d;
        match dim.kind {
            DimKind::Switch => {
                // Ports: one per NPU per group, plus switch chassis tax.
                let ports = (dim.npus * groups) as f64;
                cost += ports * dim.bandwidth_gbps * SWITCH_PORT_COST_PER_GBPS;
                cost += ports * SWITCH_CHASSIS_PER_PORT;
            }
            _ => {
                cost += links * dim.bandwidth_gbps * LINK_COST_PER_GBPS;
            }
        }
    }
    cost
}

/// Cost normalized per NPU — convenient for cross-system comparisons.
pub fn network_cost_per_npu(topo: &Topology) -> f64 {
    network_cost(topo) / topo.total_npus().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::NetworkDim;

    fn topo(kind: DimKind, n: u64, bw: f64) -> Topology {
        Topology::new(vec![NetworkDim::new(kind, n, bw, 1.0)])
    }

    #[test]
    fn ring_cost_scales_with_links_and_bw() {
        let a = network_cost(&topo(DimKind::Ring, 8, 100.0));
        assert!((a - 8.0 * 100.0).abs() < 1e-9);
        let b = network_cost(&topo(DimKind::Ring, 8, 200.0));
        assert!((b - 2.0 * a).abs() < 1e-9);
    }

    #[test]
    fn fc_is_quadratic_in_group_size() {
        let small = network_cost(&topo(DimKind::FullyConnected, 4, 100.0));
        let big = network_cost(&topo(DimKind::FullyConnected, 8, 100.0));
        // 4 NPUs: 6 links; 8 NPUs: 28 links.
        assert!((small - 600.0).abs() < 1e-9);
        assert!((big - 2800.0).abs() < 1e-9);
    }

    #[test]
    fn switch_pays_port_and_chassis_tax() {
        let c = network_cost(&topo(DimKind::Switch, 8, 100.0));
        let expect = 8.0 * 100.0 * SWITCH_PORT_COST_PER_GBPS + 8.0 * SWITCH_CHASSIS_PER_PORT;
        assert!((c - expect).abs() < 1e-9);
    }

    #[test]
    fn switch_costs_more_than_ring_same_bw() {
        let ring = network_cost(&topo(DimKind::Ring, 8, 100.0));
        let switch = network_cost(&topo(DimKind::Switch, 8, 100.0));
        assert!(switch > ring);
    }

    #[test]
    fn multi_dim_cost_sums_and_counts_groups() {
        let t = Topology::from_arrays(
            &[DimKind::Ring, DimKind::Ring],
            &[4, 4],
            &[100.0, 100.0],
            &[1.0, 1.0],
        );
        // 16 NPUs: dim0 has 4 groups of ring-4 (4 links each) = 16 links;
        // dim1 same. Total 32 links * 100 GB/s.
        assert!((network_cost(&t) - 3200.0).abs() < 1e-9);
        assert!((network_cost_per_npu(&t) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn two_npu_ring_is_single_link() {
        let c = network_cost(&topo(DimKind::Ring, 2, 100.0));
        assert!((c - 100.0).abs() < 1e-9);
    }
}
