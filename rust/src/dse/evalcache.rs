//! Cross-evaluation caching: the DSE throughput layer.
//!
//! A full-stack search evaluates millions of genomes, but the expensive
//! artifacts inside one evaluation are shared far more widely than the
//! genome memo can see:
//!
//! - **Traces** depend only on `(model, parallelization, batch, mode)` —
//!   every genome that differs only in topology / collective / fidelity
//!   knobs instantiates the *same* workload trace.
//! - **Collective costs** depend only on the [`crate::sim::CollKey`]
//!   tuple (backend tag, topology fingerprint, algorithm assignment,
//!   kind, communicator stride/size, bytes, chunks, fault-scenario
//!   fingerprint) — every layer of every trace, across every genome
//!   with the same network/collective stack, re-prices the same handful
//!   of collectives. Fault scenarios that degrade links join the key
//!   (and the backend tag, via the fault view), so a robust suite never
//!   cross-contaminates its scenarios' costs.
//!
//! [`EvalCache`] memoizes both, sharded behind `Mutex`es so
//! `Environment::evaluate_batch` worker threads hit disjoint locks. The
//! cache is *exact*: keys cover every input the cached value depends
//! on, so cached and uncached evaluation produce bit-identical
//! [`crate::dse::StepOutcome`]s (asserted by the end-to-end tests).
//!
//! Capacity is optionally bounded ([`EvalCache::with_capacity`]): each
//! shard keeps a FIFO "clock" queue and evicts with the second-chance
//! policy — an entry touched since it last reached the queue front is
//! recycled instead of dropped, so the hot working set (the traces and
//! collectives the search keeps revisiting) survives while one-off
//! artifacts age out. Evictions are counted in [`EvalCacheStats`] and
//! surfaced through the search telemetry.

use crate::sim::{CollCostMemo, CollKey};
use crate::workload::{generate_trace, ExecutionMode, ModelConfig, Parallelization, Trace};
use std::collections::{HashMap, VecDeque};
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shard count (power of two; shards are `Mutex`-guarded so concurrent
/// evaluation threads mostly hit disjoint locks).
const SHARDS: usize = 16;

/// Everything the Workload Trace Generator reads, fingerprinted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct TraceKey {
    model: u64,
    dp: u64,
    sp: u64,
    pp: u64,
    tp: u64,
    weight_sharded: bool,
    batch: u64,
    mode: ExecutionMode,
}

impl TraceKey {
    fn new(model: &ModelConfig, par: &Parallelization, batch: u64, mode: ExecutionMode) -> Self {
        Self {
            model: model.fingerprint(),
            dp: par.dp,
            sp: par.sp,
            pp: par.pp,
            tp: par.tp,
            weight_sharded: par.weight_sharded,
            batch,
            mode,
        }
    }
}

fn shard_of<K: Hash>(key: &K) -> usize {
    (crate::util::hash64(|h| key.hash(h)) as usize) % SHARDS
}

/// Per-shard capacity for a whole-cache budget of `total` entries.
/// `0` means unbounded; otherwise every shard gets at least one slot.
fn per_shard_cap(total: usize) -> usize {
    if total == 0 {
        0
    } else {
        total.div_ceil(SHARDS).max(1)
    }
}

/// One cache shard: a hash map paired with a FIFO "clock" queue
/// implementing second-chance eviction. `cap == 0` means unbounded.
///
/// Invariant: every key in `map` appears exactly once in `queue` (keys
/// enter the queue only on first insert and are re-pushed only when the
/// clock hand recycles them), so the eviction sweep terminates — each
/// pass either clears a reference bit, drops a stale entry, or evicts.
#[derive(Debug)]
struct Shard<K, V> {
    map: HashMap<K, (V, bool)>,
    queue: VecDeque<K>,
    cap: usize,
}

impl<K: Eq + Hash + Clone, V: Clone> Shard<K, V> {
    fn new(cap: usize) -> Self {
        Self { map: HashMap::new(), queue: VecDeque::new(), cap }
    }

    /// Lookup that sets the entry's second-chance bit.
    fn get(&mut self, key: &K) -> Option<V> {
        self.map.get_mut(key).map(|slot| {
            slot.1 = true;
            slot.0.clone()
        })
    }

    /// Insert `value` under `key`; the first insert wins a race (if the
    /// key is already present the stored value is returned instead).
    /// Returns the surviving value and how many entries were evicted to
    /// make room.
    fn insert_or_get(&mut self, key: K, value: V) -> (V, u64) {
        if let Some(slot) = self.map.get_mut(&key) {
            slot.1 = true;
            return (slot.0.clone(), 0);
        }
        self.map.insert(key.clone(), (value.clone(), false));
        self.queue.push_back(key);
        let mut evicted = 0;
        if self.cap > 0 {
            while self.map.len() > self.cap {
                let Some(candidate) = self.queue.pop_front() else {
                    break;
                };
                match self.map.get_mut(&candidate) {
                    Some((_, referenced)) if *referenced => {
                        // Second chance: clear the bit, recycle to the back.
                        *referenced = false;
                        self.queue.push_back(candidate);
                    }
                    Some(_) => {
                        self.map.remove(&candidate);
                        evicted += 1;
                    }
                    None => {} // stale queue entry; drop it
                }
            }
        }
        (value, evicted)
    }

    fn clear(&mut self) {
        self.map.clear();
        self.queue.clear();
    }
}

/// Hit/miss/eviction counters of one [`EvalCache`] (monotone since
/// construction or the last [`EvalCache::clear`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EvalCacheStats {
    pub trace_hits: u64,
    pub trace_misses: u64,
    pub trace_evictions: u64,
    pub coll_hits: u64,
    pub coll_misses: u64,
    pub coll_evictions: u64,
}

/// The persistent, sharded, thread-safe cross-evaluation memo. One
/// instance lives inside each `Environment` and survives the whole
/// search; independent `Environment`s (different simulators, fabrics,
/// budgets) each get their own — key scoping is handled by the backend
/// tag inside [`CollKey`] and the full [`TraceKey`].
#[derive(Debug)]
pub struct EvalCache {
    traces: Vec<Mutex<Shard<TraceKey, Arc<Trace>>>>,
    colls: Vec<Mutex<Shard<CollKey, f64>>>,
    trace_hits: AtomicU64,
    trace_misses: AtomicU64,
    trace_evictions: AtomicU64,
    coll_hits: AtomicU64,
    coll_misses: AtomicU64,
    coll_evictions: AtomicU64,
}

impl Default for EvalCache {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalCache {
    /// An unbounded cache (the default): nothing is ever evicted.
    pub fn new() -> Self {
        Self::with_capacity(0, 0)
    }

    /// A bounded cache holding at most roughly `trace_cap` traces and
    /// `coll_cap` collective costs (`0` = unbounded). Budgets are split
    /// evenly across shards (rounded up, minimum one slot per shard),
    /// so the effective ceiling can exceed the request by up to
    /// `SHARDS - 1` entries.
    pub fn with_capacity(trace_cap: usize, coll_cap: usize) -> Self {
        let tc = per_shard_cap(trace_cap);
        let cc = per_shard_cap(coll_cap);
        Self {
            traces: (0..SHARDS).map(|_| Mutex::new(Shard::new(tc))).collect(),
            colls: (0..SHARDS).map(|_| Mutex::new(Shard::new(cc))).collect(),
            trace_hits: AtomicU64::new(0),
            trace_misses: AtomicU64::new(0),
            trace_evictions: AtomicU64::new(0),
            coll_hits: AtomicU64::new(0),
            coll_misses: AtomicU64::new(0),
            coll_evictions: AtomicU64::new(0),
        }
    }

    /// The instantiated trace for `(model, par, batch, mode)`, generated
    /// on first request and shared (via `Arc`) afterwards. Generation
    /// errors are returned but not cached — they are cheap to recompute
    /// and the genome memo absorbs repeats.
    pub fn trace(
        &self,
        model: &ModelConfig,
        par: &Parallelization,
        batch: u64,
        mode: ExecutionMode,
    ) -> Result<Arc<Trace>, String> {
        let key = TraceKey::new(model, par, batch, mode);
        let shard = &self.traces[shard_of(&key)];
        if let Some(hit) = shard.lock().unwrap().get(&key) {
            self.trace_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit);
        }
        // Generate outside the lock: instantiation is the expensive part
        // and must not serialize the other shard users. A racing thread
        // may generate the same trace; both results are identical and
        // the first insert wins.
        let trace = Arc::new(generate_trace(model, par, batch, mode)?);
        self.trace_misses.fetch_add(1, Ordering::Relaxed);
        let (kept, evicted) = shard.lock().unwrap().insert_or_get(key, trace);
        if evicted > 0 {
            self.trace_evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        Ok(kept)
    }

    /// A [`CollCostMemo`] view over the shared collective-cost shards,
    /// handed to [`crate::sim::Simulator::price`].
    pub fn coll_memo(&self) -> SharedCollMemo<'_> {
        SharedCollMemo { cache: self }
    }

    pub fn stats(&self) -> EvalCacheStats {
        EvalCacheStats {
            trace_hits: self.trace_hits.load(Ordering::Relaxed),
            trace_misses: self.trace_misses.load(Ordering::Relaxed),
            trace_evictions: self.trace_evictions.load(Ordering::Relaxed),
            coll_hits: self.coll_hits.load(Ordering::Relaxed),
            coll_misses: self.coll_misses.load(Ordering::Relaxed),
            coll_evictions: self.coll_evictions.load(Ordering::Relaxed),
        }
    }

    /// Drop every cached artifact and reset the counters. Capacity
    /// limits are retained.
    pub fn clear(&self) {
        for s in &self.traces {
            s.lock().unwrap().clear();
        }
        for s in &self.colls {
            s.lock().unwrap().clear();
        }
        self.trace_hits.store(0, Ordering::Relaxed);
        self.trace_misses.store(0, Ordering::Relaxed);
        self.trace_evictions.store(0, Ordering::Relaxed);
        self.coll_hits.store(0, Ordering::Relaxed);
        self.coll_misses.store(0, Ordering::Relaxed);
        self.coll_evictions.store(0, Ordering::Relaxed);
    }
}

/// Borrowed [`CollCostMemo`] adapter over an [`EvalCache`].
pub struct SharedCollMemo<'a> {
    cache: &'a EvalCache,
}

impl CollCostMemo for SharedCollMemo<'_> {
    fn cost_us(&mut self, key: &CollKey, compute: &mut dyn FnMut() -> f64) -> f64 {
        let shard = &self.cache.colls[shard_of(key)];
        if let Some(v) = shard.lock().unwrap().get(key) {
            self.cache.coll_hits.fetch_add(1, Ordering::Relaxed);
            return v;
        }
        // Price outside the lock; duplicate computation on a race is
        // deterministic, so whichever insert lands is the same value.
        let v = compute();
        self.cache.coll_misses.fetch_add(1, Ordering::Relaxed);
        let (kept, evicted) = shard.lock().unwrap().insert_or_get(*key, v);
        if evicted > 0 {
            self.cache.coll_evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        kept
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::presets as wl;

    fn par() -> Parallelization {
        Parallelization::derive(64, 8, 1, 1, true).unwrap()
    }

    fn coll_key(topology: u64) -> CollKey {
        CollKey {
            backend: 1,
            topology,
            algos: 3,
            policy: crate::collective::MultiDimPolicy::Baseline,
            kind: crate::collective::CollectiveKind::AllReduce,
            stride: 1,
            size: 8,
            bytes: 1e6f64.to_bits(),
            chunks: 4,
            scenario: 0,
        }
    }

    #[test]
    fn trace_cache_hits_on_repeat_and_shares_storage() {
        let cache = EvalCache::new();
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let a = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        let b = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the first trace");
        let s = cache.stats();
        assert_eq!((s.trace_hits, s.trace_misses), (1, 1));
    }

    #[test]
    fn trace_cache_distinguishes_inputs() {
        let cache = EvalCache::new();
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let a = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        let b = cache.trace(&m, &par(), 128, ExecutionMode::Training).unwrap();
        let c = cache.trace(&m, &par(), 64, ExecutionMode::InferencePrefill).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.stats().trace_misses, 3);
    }

    #[test]
    fn trace_cache_matches_direct_generation() {
        let cache = EvalCache::new();
        let m = wl::gpt3_175b().with_simulated_layers(4);
        let p = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        let cached = cache.trace(&m, &p, 2048, ExecutionMode::Training).unwrap();
        let direct = generate_trace(&m, &p, 2048, ExecutionMode::Training).unwrap();
        assert_eq!(*cached, direct);
    }

    #[test]
    fn trace_errors_are_propagated_not_cached() {
        let cache = EvalCache::new();
        let m = wl::vit_base();
        let p = Parallelization::derive(512, 512, 1, 1, false).unwrap();
        // batch < dp is a generation error.
        assert!(cache.trace(&m, &p, 256, ExecutionMode::Training).is_err());
        assert_eq!(cache.stats().trace_misses, 0);
    }

    #[test]
    fn coll_memo_computes_once_per_key() {
        let cache = EvalCache::new();
        let key = coll_key(2);
        let mut calls = 0;
        let mut memo = cache.coll_memo();
        let a = memo.cost_us(&key, &mut || {
            calls += 1;
            42.0
        });
        let b = memo.cost_us(&key, &mut || {
            calls += 1;
            42.0
        });
        assert_eq!((a, b, calls), (42.0, 42.0, 1));
        let s = cache.stats();
        assert_eq!((s.coll_hits, s.coll_misses), (1, 1));
    }

    #[test]
    fn clear_resets_everything() {
        let cache = EvalCache::new();
        let m = wl::gpt3_13b().with_simulated_layers(2);
        cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        cache.clear();
        assert_eq!(cache.stats(), EvalCacheStats::default());
        cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        assert_eq!(cache.stats().trace_misses, 1);
    }

    #[test]
    fn shard_second_chance_prefers_referenced_entries() {
        let mut s: Shard<u32, u32> = Shard::new(2);
        assert_eq!(s.insert_or_get(1, 10), (10, 0));
        assert_eq!(s.insert_or_get(2, 20), (20, 0));
        assert_eq!(s.get(&1), Some(10)); // set 1's second-chance bit
        let (v, evicted) = s.insert_or_get(3, 30);
        assert_eq!((v, evicted), (30, 1));
        assert_eq!(s.get(&1), Some(10), "referenced entry survives the sweep");
        assert_eq!(s.get(&2), None, "unreferenced entry is the victim");
        assert_eq!(s.get(&3), Some(30));
    }

    #[test]
    fn shard_insert_or_get_keeps_first_value() {
        let mut s: Shard<u32, u32> = Shard::new(0);
        assert_eq!(s.insert_or_get(7, 70), (70, 0));
        assert_eq!(s.insert_or_get(7, 71), (70, 0), "first insert wins");
        assert_eq!(s.map.len(), 1);
        assert_eq!(s.queue.len(), 1);
    }

    #[test]
    fn unbounded_shard_never_evicts() {
        let mut s: Shard<u32, u32> = Shard::new(0);
        let total: u64 = (0..100).map(|i| s.insert_or_get(i, i).1).sum();
        assert_eq!(total, 0);
        assert_eq!(s.map.len(), 100);
    }

    #[test]
    fn bounded_trace_cache_evicts_and_stays_correct() {
        // trace_cap = 1 → one slot per shard; 20 distinct keys over 16
        // shards guarantee at least one collision, hence evictions.
        let cache = EvalCache::with_capacity(1, 0);
        let m = wl::gpt3_13b().with_simulated_layers(2);
        let p = par();
        for i in 0..20u64 {
            cache.trace(&m, &p, 64 * (i + 1), ExecutionMode::Training).unwrap();
        }
        assert!(cache.stats().trace_evictions > 0, "capacity 1 must evict");
        // An evicted key regenerates to exactly the direct result.
        let again = cache.trace(&m, &p, 64, ExecutionMode::Training).unwrap();
        let direct = generate_trace(&m, &p, 64, ExecutionMode::Training).unwrap();
        assert_eq!(*again, direct);
    }

    #[test]
    fn bounded_coll_cache_counts_evictions_and_recomputes() {
        let cache = EvalCache::with_capacity(0, 1);
        let mut memo = cache.coll_memo();
        for i in 0..40 {
            let v = memo.cost_us(&coll_key(i), &mut || i as f64);
            assert_eq!(v, i as f64);
        }
        assert!(cache.stats().coll_evictions > 0, "capacity 1 must evict");
        // Re-pricing any key — evicted or not — stays deterministic.
        let v = memo.cost_us(&coll_key(0), &mut || 0.0);
        assert_eq!(v, 0.0);
    }

    #[test]
    fn coll_keys_differing_only_in_scenario_do_not_collide() {
        // Deliberate-collision regression: two scenarios degrade the same
        // physical collective differently, so a cache that ignored the
        // scenario fingerprint would serve one scenario's cost to the
        // other. Keys identical except `scenario` must keep both values.
        let cache = EvalCache::new();
        let mut memo = cache.coll_memo();
        let nominal = coll_key(7);
        let degraded = CollKey { scenario: 0xFA17, ..nominal };
        let a = memo.cost_us(&nominal, &mut || 100.0);
        let b = memo.cost_us(&degraded, &mut || 250.0);
        assert_eq!((a, b), (100.0, 250.0), "scenario field must split the key space");
        // Repeats hit their own entry, never the sibling's.
        assert_eq!(memo.cost_us(&nominal, &mut || f64::NAN), 100.0);
        assert_eq!(memo.cost_us(&degraded, &mut || f64::NAN), 250.0);
        let s = cache.stats();
        assert_eq!((s.coll_hits, s.coll_misses), (2, 2));
    }

    #[test]
    fn trace_key_is_scenario_free_by_design() {
        // Traces depend only on the workload, never on the fault
        // scenario: a robust evaluation of K+1 scenarios generates the
        // trace once and shares it across all of them.
        let cache = EvalCache::new();
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let a = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        let b = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.stats().trace_misses, 1, "one generation serves every scenario");
    }

    #[test]
    fn capacity_survives_clear() {
        let cache = EvalCache::with_capacity(1, 0);
        let m = wl::gpt3_13b().with_simulated_layers(2);
        let p = par();
        for i in 0..20u64 {
            cache.trace(&m, &p, 64 * (i + 1), ExecutionMode::Training).unwrap();
        }
        cache.clear();
        assert_eq!(cache.stats(), EvalCacheStats::default());
        for i in 0..20u64 {
            cache.trace(&m, &p, 64 * (i + 1), ExecutionMode::Training).unwrap();
        }
        assert!(cache.stats().trace_evictions > 0, "bound persists across clear");
    }
}
