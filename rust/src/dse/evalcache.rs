//! Cross-evaluation caching: the DSE throughput layer.
//!
//! A full-stack search evaluates millions of genomes, but the expensive
//! artifacts inside one evaluation are shared far more widely than the
//! genome memo can see:
//!
//! - **Traces** depend only on `(model, parallelization, batch, mode)` —
//!   every genome that differs only in topology / collective / fidelity
//!   knobs instantiates the *same* workload trace.
//! - **Collective costs** depend only on the [`crate::sim::CollKey`]
//!   tuple (backend tag, topology fingerprint, algorithm assignment,
//!   kind, communicator stride/size, bytes, chunks) — every layer of
//!   every trace, across every genome with the same network/collective
//!   stack, re-prices the same handful of collectives.
//!
//! [`EvalCache`] memoizes both, sharded behind `Mutex`es so
//! `Environment::evaluate_batch` worker threads hit disjoint locks. The
//! cache is *exact*: keys cover every input the cached value depends
//! on, so cached and uncached evaluation produce bit-identical
//! [`crate::dse::StepOutcome`]s (asserted by the end-to-end tests).

use crate::sim::{CollCostMemo, CollKey};
use crate::workload::{generate_trace, ExecutionMode, ModelConfig, Parallelization, Trace};
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shard count (power of two; shards are `Mutex`-guarded so concurrent
/// evaluation threads mostly hit disjoint locks).
const SHARDS: usize = 16;

/// Everything the Workload Trace Generator reads, fingerprinted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct TraceKey {
    model: u64,
    dp: u64,
    sp: u64,
    pp: u64,
    tp: u64,
    weight_sharded: bool,
    batch: u64,
    mode: ExecutionMode,
}

impl TraceKey {
    fn new(model: &ModelConfig, par: &Parallelization, batch: u64, mode: ExecutionMode) -> Self {
        Self {
            model: model.fingerprint(),
            dp: par.dp,
            sp: par.sp,
            pp: par.pp,
            tp: par.tp,
            weight_sharded: par.weight_sharded,
            batch,
            mode,
        }
    }
}

fn shard_of<K: Hash>(key: &K) -> usize {
    (crate::util::hash64(|h| key.hash(h)) as usize) % SHARDS
}

/// Hit/miss counters of one [`EvalCache`] (monotone since construction
/// or the last [`EvalCache::clear`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EvalCacheStats {
    pub trace_hits: u64,
    pub trace_misses: u64,
    pub coll_hits: u64,
    pub coll_misses: u64,
}

/// The persistent, sharded, thread-safe cross-evaluation memo. One
/// instance lives inside each `Environment` and survives the whole
/// search; independent `Environment`s (different simulators, fabrics,
/// budgets) each get their own — key scoping is handled by the backend
/// tag inside [`CollKey`] and the full [`TraceKey`].
#[derive(Debug)]
pub struct EvalCache {
    traces: Vec<Mutex<HashMap<TraceKey, Arc<Trace>>>>,
    colls: Vec<Mutex<HashMap<CollKey, f64>>>,
    trace_hits: AtomicU64,
    trace_misses: AtomicU64,
    coll_hits: AtomicU64,
    coll_misses: AtomicU64,
}

impl Default for EvalCache {
    fn default() -> Self {
        Self::new()
    }
}

impl EvalCache {
    pub fn new() -> Self {
        Self {
            traces: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            colls: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            trace_hits: AtomicU64::new(0),
            trace_misses: AtomicU64::new(0),
            coll_hits: AtomicU64::new(0),
            coll_misses: AtomicU64::new(0),
        }
    }

    /// The instantiated trace for `(model, par, batch, mode)`, generated
    /// on first request and shared (via `Arc`) afterwards. Generation
    /// errors are returned but not cached — they are cheap to recompute
    /// and the genome memo absorbs repeats.
    pub fn trace(
        &self,
        model: &ModelConfig,
        par: &Parallelization,
        batch: u64,
        mode: ExecutionMode,
    ) -> Result<Arc<Trace>, String> {
        let key = TraceKey::new(model, par, batch, mode);
        let shard = &self.traces[shard_of(&key)];
        if let Some(hit) = shard.lock().unwrap().get(&key) {
            self.trace_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::clone(hit));
        }
        // Generate outside the lock: instantiation is the expensive part
        // and must not serialize the other shard users. A racing thread
        // may generate the same trace; both results are identical and
        // the first insert wins.
        let trace = Arc::new(generate_trace(model, par, batch, mode)?);
        self.trace_misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = shard.lock().unwrap();
        let entry = guard.entry(key).or_insert_with(|| Arc::clone(&trace));
        Ok(Arc::clone(entry))
    }

    /// A [`CollCostMemo`] view over the shared collective-cost shards,
    /// handed to [`crate::sim::Simulator::price`].
    pub fn coll_memo(&self) -> SharedCollMemo<'_> {
        SharedCollMemo { cache: self }
    }

    pub fn stats(&self) -> EvalCacheStats {
        EvalCacheStats {
            trace_hits: self.trace_hits.load(Ordering::Relaxed),
            trace_misses: self.trace_misses.load(Ordering::Relaxed),
            coll_hits: self.coll_hits.load(Ordering::Relaxed),
            coll_misses: self.coll_misses.load(Ordering::Relaxed),
        }
    }

    /// Drop every cached artifact and reset the counters.
    pub fn clear(&self) {
        for s in &self.traces {
            s.lock().unwrap().clear();
        }
        for s in &self.colls {
            s.lock().unwrap().clear();
        }
        self.trace_hits.store(0, Ordering::Relaxed);
        self.trace_misses.store(0, Ordering::Relaxed);
        self.coll_hits.store(0, Ordering::Relaxed);
        self.coll_misses.store(0, Ordering::Relaxed);
    }
}

/// Borrowed [`CollCostMemo`] adapter over an [`EvalCache`].
pub struct SharedCollMemo<'a> {
    cache: &'a EvalCache,
}

impl CollCostMemo for SharedCollMemo<'_> {
    fn cost_us(&mut self, key: &CollKey, compute: &mut dyn FnMut() -> f64) -> f64 {
        let shard = &self.cache.colls[shard_of(key)];
        if let Some(v) = shard.lock().unwrap().get(key) {
            self.cache.coll_hits.fetch_add(1, Ordering::Relaxed);
            return *v;
        }
        // Price outside the lock; duplicate computation on a race is
        // deterministic, so whichever insert lands is the same value.
        let v = compute();
        self.cache.coll_misses.fetch_add(1, Ordering::Relaxed);
        shard.lock().unwrap().insert(*key, v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::presets as wl;

    fn par() -> Parallelization {
        Parallelization::derive(64, 8, 1, 1, true).unwrap()
    }

    #[test]
    fn trace_cache_hits_on_repeat_and_shares_storage() {
        let cache = EvalCache::new();
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let a = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        let b = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the first trace");
        let s = cache.stats();
        assert_eq!((s.trace_hits, s.trace_misses), (1, 1));
    }

    #[test]
    fn trace_cache_distinguishes_inputs() {
        let cache = EvalCache::new();
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let a = cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        let b = cache.trace(&m, &par(), 128, ExecutionMode::Training).unwrap();
        let c = cache.trace(&m, &par(), 64, ExecutionMode::InferencePrefill).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.stats().trace_misses, 3);
    }

    #[test]
    fn trace_cache_matches_direct_generation() {
        let cache = EvalCache::new();
        let m = wl::gpt3_175b().with_simulated_layers(4);
        let p = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        let cached = cache.trace(&m, &p, 2048, ExecutionMode::Training).unwrap();
        let direct = generate_trace(&m, &p, 2048, ExecutionMode::Training).unwrap();
        assert_eq!(*cached, direct);
    }

    #[test]
    fn trace_errors_are_propagated_not_cached() {
        let cache = EvalCache::new();
        let m = wl::vit_base();
        let p = Parallelization::derive(512, 512, 1, 1, false).unwrap();
        // batch < dp is a generation error.
        assert!(cache.trace(&m, &p, 256, ExecutionMode::Training).is_err());
        assert_eq!(cache.stats().trace_misses, 0);
    }

    #[test]
    fn coll_memo_computes_once_per_key() {
        let cache = EvalCache::new();
        let key = CollKey {
            backend: 1,
            topology: 2,
            algos: 3,
            policy: crate::collective::MultiDimPolicy::Baseline,
            kind: crate::collective::CollectiveKind::AllReduce,
            stride: 1,
            size: 8,
            bytes: 1e6f64.to_bits(),
            chunks: 4,
        };
        let mut calls = 0;
        let mut memo = cache.coll_memo();
        let a = memo.cost_us(&key, &mut || {
            calls += 1;
            42.0
        });
        let b = memo.cost_us(&key, &mut || {
            calls += 1;
            42.0
        });
        assert_eq!((a, b, calls), (42.0, 42.0, 1));
        let s = cache.stats();
        assert_eq!((s.coll_hits, s.coll_misses), (1, 1));
    }

    #[test]
    fn clear_resets_everything() {
        let cache = EvalCache::new();
        let m = wl::gpt3_13b().with_simulated_layers(2);
        cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        cache.clear();
        assert_eq!(cache.stats(), EvalCacheStats::default());
        cache.trace(&m, &par(), 64, ExecutionMode::Training).unwrap();
        assert_eq!(cache.stats().trace_misses, 1);
    }
}
