//! The paper's reward functions (§5.4).
//!
//! COSMIC minimizes total ML runtime, regularized so the agent does not
//! simply max out every network resource:
//!
//! - **Runtime per BW/NPU**:
//!   `reward = 1 / sqrt((latency · Σ(BW per Dim) − 1)²)`
//! - **Runtime per Network Cost**:
//!   `reward = 1 / sqrt((latency · network_cost − 1)²)`
//!
//! (the `−1` offset is the paper's divide-by-zero guard). Invalid
//! configurations — §5.4's >24 GB/NPU memory violations, constraint
//! violations, non-materializable points — receive reward 0.

use super::cost::network_cost;
use crate::sim::SimReport;
use crate::topology::Topology;

/// Optimization objective (which regularized reward to maximize).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    /// Perf per aggregate bandwidth per NPU.
    PerfPerBwPerNpu,
    /// Perf per network dollar cost.
    PerfPerNetworkCost,
    /// Raw performance (1/latency) — used by the Figure 4 spread studies.
    RawLatency,
}

impl Objective {
    pub const ALL: [Objective; 3] =
        [Objective::PerfPerBwPerNpu, Objective::PerfPerNetworkCost, Objective::RawLatency];

    pub fn name(&self) -> &'static str {
        match self {
            Objective::PerfPerBwPerNpu => "perf-per-bw-npu",
            Objective::PerfPerNetworkCost => "perf-per-cost",
            Objective::RawLatency => "raw-latency",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "perf-per-bw-npu" | "bw" | "bw-npu" => Some(Objective::PerfPerBwPerNpu),
            "perf-per-cost" | "cost" => Some(Objective::PerfPerNetworkCost),
            "raw-latency" | "latency" | "raw" => Some(Objective::RawLatency),
            _ => None,
        }
    }

    /// The scalar the reward divides latency by (the paper's
    /// "regulation metric"); 1.0 for raw latency.
    pub fn regulator(&self, topo: &Topology) -> f64 {
        match self {
            Objective::PerfPerBwPerNpu => topo.sum_bw_per_dim(),
            Objective::PerfPerNetworkCost => network_cost(topo),
            Objective::RawLatency => 1.0,
        }
    }

    /// The paper's reward. `latency` in seconds (converted from the
    /// simulator's microseconds by the caller via [`reward_from_report`]).
    pub fn reward(&self, latency_s: f64, topo: &Topology) -> f64 {
        if !latency_s.is_finite() || latency_s <= 0.0 {
            return 0.0;
        }
        let product = latency_s * self.regulator(topo);
        // 1 / sqrt((x - 1)^2) == 1 / |x - 1|, the paper's exact form.
        let denom = (product - 1.0).abs().max(1e-12);
        1.0 / denom
    }
}

/// Reward of a successful simulation under `objective`.
pub fn reward_from_report(objective: Objective, report: &SimReport, topo: &Topology) -> f64 {
    objective.reward(report.latency_us / 1e6, topo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DimKind, NetworkDim};

    fn topo() -> Topology {
        Topology::new(vec![
            NetworkDim::new(DimKind::Ring, 4, 100.0, 1.0),
            NetworkDim::new(DimKind::Switch, 8, 50.0, 1.0),
        ])
    }

    #[test]
    fn lower_latency_higher_reward_above_knee() {
        let t = topo();
        for obj in Objective::ALL {
            // Past the product>1 knee, less latency must help.
            let hi = obj.reward(10.0, &t);
            let lo = obj.reward(100.0, &t);
            assert!(hi > lo, "{}: {hi} !> {lo}", obj.name());
        }
    }

    #[test]
    fn invalid_latency_is_zero() {
        let t = topo();
        assert_eq!(Objective::PerfPerBwPerNpu.reward(0.0, &t), 0.0);
        assert_eq!(Objective::PerfPerBwPerNpu.reward(f64::NAN, &t), 0.0);
        assert_eq!(Objective::PerfPerBwPerNpu.reward(-1.0, &t), 0.0);
    }

    #[test]
    fn bw_regulator_is_sum_of_dim_bandwidths() {
        let t = topo();
        assert_eq!(Objective::PerfPerBwPerNpu.regulator(&t), 150.0);
        assert_eq!(Objective::RawLatency.regulator(&t), 1.0);
    }

    #[test]
    fn more_bandwidth_penalized_at_equal_latency() {
        let lean = topo();
        let mut fat = topo();
        fat.dims[0].bandwidth_gbps = 1000.0;
        let latency = 1.0;
        let r_lean = Objective::PerfPerBwPerNpu.reward(latency, &lean);
        let r_fat = Objective::PerfPerBwPerNpu.reward(latency, &fat);
        assert!(r_lean > r_fat, "over-provisioned bw must be penalized");
    }

    #[test]
    fn cost_objective_penalizes_expensive_fabric() {
        let cheap = topo();
        let mut pricey = topo();
        pricey.dims[0].kind = DimKind::FullyConnected;
        let r_cheap = Objective::PerfPerNetworkCost.reward(1.0, &cheap);
        let r_pricey = Objective::PerfPerNetworkCost.reward(1.0, &pricey);
        assert!(r_cheap > r_pricey);
    }

    #[test]
    fn from_name_roundtrips() {
        for o in Objective::ALL {
            assert_eq!(Objective::from_name(o.name()), Some(o));
        }
        assert_eq!(Objective::from_name("bogus"), None);
    }
}
