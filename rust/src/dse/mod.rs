//! Design-space exploration: the agent⇄environment loop (paper §4.4's
//! "well-defined agent-environment interaction loop").
//!
//! [`Environment`] wraps the simulator behind the PSS: agents submit
//! genomes, the environment materializes, simulates, and returns the
//! §5.4 reward. [`DseRunner`] drives an agent for a step budget, records
//! the full reward history (Figure 10's convergence curves), the best
//! design points (Tables 5/6, Figure 9), and evaluation statistics.

pub mod cost;
pub mod pareto;
pub mod prefilter;
pub mod reward;

pub use cost::{network_cost, network_cost_per_npu};
pub use reward::{reward_from_report, Objective};

use crate::agents::{Agent, AgentKind};
use crate::netsim::{FidelityMode, FlowLevelConfig};
use crate::pss::{Pss, SearchScope};
use crate::sim::{ClusterConfig, SimReport, Simulator};
use crate::util::parallel_map;
use crate::workload::{ExecutionMode, ModelConfig, Parallelization};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// One workload the environment optimizes for (Table 6 Expr 1 optimizes
/// an ensemble of all four Table 2 models at once).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub model: ModelConfig,
    pub batch: u64,
    pub mode: ExecutionMode,
    /// Latency multiplier: how many times this phase repeats per request
    /// (e.g. one decode step spec with weight 512 models a 512-token
    /// chat generation; Table 6 Expr 2).
    pub weight: f64,
}

impl WorkloadSpec {
    pub fn training(model: ModelConfig, batch: u64) -> Self {
        Self { model, batch, mode: ExecutionMode::Training, weight: 1.0 }
    }

    pub fn inference(model: ModelConfig, batch: u64, mode: ExecutionMode, weight: f64) -> Self {
        Self { model, batch, mode, weight }
    }
}

/// Cache shard count (power of two; shards are `Mutex`-guarded so batch
/// evaluation threads hit disjoint locks).
const CACHE_SHARDS: usize = 16;

/// The memoized result of one evaluation: everything needed to replay
/// the outcome except the (large) per-workload reports, which are
/// re-materialized on demand for the final best point.
#[derive(Debug, Clone)]
struct CachedEval {
    reward: f64,
    invalid_reason: Option<String>,
}

/// The environment side of the loop (PSS "Environment Side
/// Configuration"): cost model + action/observation spaces + constraints.
pub struct Environment {
    pub pss: Pss,
    /// The default (analytical-fidelity) simulator.
    pub simulator: Simulator,
    /// The flow-level twin, used when a genome's PsA fidelity knob (or a
    /// caller via [`Environment::evaluate_with`]) asks for congestion.
    flow_simulator: Simulator,
    pub workloads: Vec<WorkloadSpec>,
    pub objective: Objective,
    /// Sharded memo of evaluations keyed by genome — the DSE hot-path
    /// cache, safe to consult from `evaluate_batch` worker threads.
    cache: Vec<Mutex<HashMap<Vec<usize>, CachedEval>>>,
    evals: AtomicU64,
    cache_hits: AtomicU64,
    invalid: AtomicU64,
}

/// Outcome of evaluating one genome.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    pub reward: f64,
    /// Reports per workload (empty if the point was invalid *or* served
    /// from the memo cache — see [`RunResult::best_reports`]).
    pub reports: Vec<SimReport>,
    pub invalid_reason: Option<String>,
}

impl Environment {
    pub fn new(pss: Pss, workloads: Vec<WorkloadSpec>, objective: Objective) -> Self {
        assert!(!workloads.is_empty());
        Self {
            pss,
            simulator: Simulator::new(),
            flow_simulator: Simulator::new().with_fidelity(FidelityMode::FlowLevel),
            workloads,
            objective,
            cache: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            evals: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            invalid: AtomicU64::new(0),
        }
    }

    /// Reconfigure the flow-level twin's fabric (oversubscription /
    /// background load) — builder style.
    pub fn with_flow_config(mut self, config: FlowLevelConfig) -> Self {
        let mut sim = Simulator::new().with_flow_config(config);
        sim.mem_budget_bytes = self.simulator.mem_budget_bytes;
        self.flow_simulator = sim;
        self
    }

    /// Genomes evaluated (cache misses).
    pub fn evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    /// Evaluations served from the memo cache.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Evaluations that scored zero (constraint/memory/config rejects).
    pub fn invalid(&self) -> u64 {
        self.invalid.load(Ordering::Relaxed)
    }

    fn shard_of(&self, genome: &[usize]) -> usize {
        let mut h = DefaultHasher::new();
        genome.hash(&mut h);
        (h.finish() as usize) % self.cache.len()
    }

    fn cache_lookup(&self, genome: &[usize]) -> Option<StepOutcome> {
        let shard = self.cache[self.shard_of(genome)].lock().unwrap();
        shard.get(genome).map(|hit| {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
            StepOutcome {
                reward: hit.reward,
                reports: Vec::new(),
                invalid_reason: hit.invalid_reason.clone(),
            }
        })
    }

    fn cache_store(&self, genome: &[usize], outcome: &StepOutcome) {
        let mut shard = self.cache[self.shard_of(genome)].lock().unwrap();
        if shard
            .insert(
                genome.to_vec(),
                CachedEval {
                    reward: outcome.reward,
                    invalid_reason: outcome.invalid_reason.clone(),
                },
            )
            .is_none()
        {
            self.evals.fetch_add(1, Ordering::Relaxed);
            if outcome.reward == 0.0 {
                self.invalid.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Evaluate a genome end to end: decode → constraint-check →
    /// materialize → simulate each workload → reward. Invalid points
    /// score 0 (the paper discards them). Repeat lookups are served from
    /// the memo cache with their full outcome (reward *and* invalid
    /// reason) — only the reports are elided.
    pub fn evaluate(&self, genome: &[usize]) -> StepOutcome {
        if let Some(hit) = self.cache_lookup(genome) {
            return hit;
        }
        let outcome = self.evaluate_uncached(genome);
        self.cache_store(genome, &outcome);
        outcome
    }

    /// Evaluate a batch of genomes, fanning cache misses out across OS
    /// threads (the agents' `ask()` batches are embarrassingly parallel;
    /// the simulator is pure). Order is preserved.
    pub fn evaluate_batch(&self, genomes: &[Vec<usize>]) -> Vec<StepOutcome> {
        let mut out: Vec<Option<StepOutcome>> =
            genomes.iter().map(|g| self.cache_lookup(g)).collect();
        // Deduplicate misses so a batch with repeats evaluates once.
        let mut miss_positions: HashMap<&[usize], Vec<usize>> = HashMap::new();
        for (i, g) in genomes.iter().enumerate() {
            if out[i].is_none() {
                miss_positions.entry(g.as_slice()).or_default().push(i);
            }
        }
        let mut misses: Vec<(&[usize], Vec<usize>)> = miss_positions.into_iter().collect();
        // HashMap order is nondeterministic; restore batch order.
        misses.sort_by_key(|(_, positions)| positions[0]);
        let results = parallel_map(&misses, |(g, _)| self.evaluate_uncached(g));
        for ((g, positions), outcome) in misses.iter().zip(results.into_iter()) {
            self.cache_store(g, &outcome);
            // The first occurrence carries the full outcome (as a serial
            // evaluate would); later duplicates mirror cache hits.
            for &i in positions.iter().skip(1) {
                out[i] = Some(StepOutcome {
                    reward: outcome.reward,
                    reports: Vec::new(),
                    invalid_reason: outcome.invalid_reason.clone(),
                });
            }
            out[positions[0]] = Some(outcome);
        }
        out.into_iter().map(|o| o.expect("batch slot unfilled")).collect()
    }

    /// Evaluation without the memo cache (used by the bench harness to
    /// time the true hot path). Honors the genome's PsA fidelity knob
    /// when the schema carries one.
    pub fn evaluate_uncached(&self, genome: &[usize]) -> StepOutcome {
        let point = match self.pss.schema.decode_valid(genome) {
            Ok(p) => p,
            Err(e) => {
                return StepOutcome { reward: 0.0, reports: Vec::new(), invalid_reason: Some(e) }
            }
        };
        let (cluster, par) = match self.pss.materialize(&point) {
            Ok(x) => x,
            Err(e) => {
                return StepOutcome { reward: 0.0, reports: Vec::new(), invalid_reason: Some(e) }
            }
        };
        let sim = match self.pss.fidelity_of(&point) {
            FidelityMode::FlowLevel => &self.flow_simulator,
            FidelityMode::Analytical => &self.simulator,
        };
        self.simulate_point(sim, &cluster, &par)
    }

    /// Evaluate a genome at an explicitly chosen fidelity, bypassing the
    /// cache and the genome's own fidelity knob — the re-ranking hook:
    /// screen with [`FidelityMode::Analytical`], then re-score finalists
    /// with [`FidelityMode::FlowLevel`].
    pub fn evaluate_with(&self, genome: &[usize], fidelity: FidelityMode) -> StepOutcome {
        let point = match self.pss.schema.decode_valid(genome) {
            Ok(p) => p,
            Err(e) => {
                return StepOutcome { reward: 0.0, reports: Vec::new(), invalid_reason: Some(e) }
            }
        };
        let (cluster, par) = match self.pss.materialize(&point) {
            Ok(x) => x,
            Err(e) => {
                return StepOutcome { reward: 0.0, reports: Vec::new(), invalid_reason: Some(e) }
            }
        };
        let sim = match fidelity {
            FidelityMode::FlowLevel => &self.flow_simulator,
            FidelityMode::Analytical => &self.simulator,
        };
        self.simulate_point(sim, &cluster, &par)
    }

    fn simulate_point(
        &self,
        sim: &Simulator,
        cluster: &ClusterConfig,
        par: &Parallelization,
    ) -> StepOutcome {
        let mut reports = Vec::with_capacity(self.workloads.len());
        let mut total_latency_us = 0.0;
        for w in &self.workloads {
            match sim.run(cluster, &w.model, par, w.batch, w.mode) {
                Ok(rep) => {
                    total_latency_us += rep.latency_us * w.weight;
                    reports.push(rep);
                }
                Err(e) => {
                    return StepOutcome {
                        reward: 0.0,
                        reports: Vec::new(),
                        invalid_reason: Some(format!("{e:?}")),
                    }
                }
            }
        }
        let reward = self.objective.reward(total_latency_us / 1e6, &cluster.topology);
        StepOutcome { reward, reports, invalid_reason: None }
    }

    /// Latency (us) of a genome, ignoring the regularizer — used by the
    /// Figure 4 spread studies. `None` if invalid.
    pub fn latency_us(&self, genome: &[usize]) -> Option<f64> {
        let out = self.evaluate_uncached(genome);
        if out.invalid_reason.is_some() {
            None
        } else {
            Some(out.reports.iter().map(|r| r.latency_us).sum())
        }
    }
}

/// One step of a DSE run.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub reward: f64,
    /// Running best reward after this step (Figure 10's y-axis).
    pub best_so_far: f64,
}

/// Full result of a DSE run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub agent: &'static str,
    pub history: Vec<StepRecord>,
    pub best_reward: f64,
    pub best_genome: Vec<usize>,
    /// Per-workload reports of the best design, re-materialized after
    /// the run (cache hits during the search elide reports).
    pub best_reports: Vec<SimReport>,
    /// Step at which the final best was first reached (paper §6.4 quotes
    /// RW 652 / GA 440 / ACO 297 / BO 680 on their setup).
    pub steps_to_peak: u64,
    pub evals: u64,
    pub invalid: u64,
}

impl RunResult {
    /// Top-k distinct genomes by reward from the recorded bests.
    pub fn reward_curve(&self) -> Vec<f64> {
        self.history.iter().map(|s| s.best_so_far).collect()
    }
}

/// DSE configuration: which agent, how many steps, seed.
#[derive(Debug, Clone, Copy)]
pub struct DseConfig {
    pub agent: AgentKind,
    pub steps: u64,
    pub seed: u64,
}

impl DseConfig {
    pub fn new(agent: AgentKind, steps: u64, seed: u64) -> Self {
        Self { agent, steps, seed }
    }
}

/// Drives one agent against one environment for a step budget. A *step*
/// is one genome evaluation (agents with populations consume several
/// steps per `ask`).
pub struct DseRunner {
    pub config: DseConfig,
    pub scope: SearchScope,
}

impl DseRunner {
    pub fn new(config: DseConfig, scope: SearchScope) -> Self {
        Self { config, scope }
    }

    /// Run the search; also tracks distinct near-optimal genomes for the
    /// Figure 9 diversity analysis.
    pub fn run(&self, env: &mut Environment) -> RunResult {
        let space = env.pss.build_space(self.scope);
        let mut agent = self.config.agent.build(space, self.config.seed);
        self.run_with_agent(env, agent.as_mut())
    }

    /// Run with a caller-constructed agent (custom hyper-parameters or an
    /// XLA-backed BO surrogate). Each `ask()` batch is evaluated through
    /// [`Environment::evaluate_batch`], so population agents fan out
    /// across cores.
    pub fn run_with_agent(&self, env: &mut Environment, agent: &mut dyn Agent) -> RunResult {
        let mut history = Vec::with_capacity(self.config.steps as usize);
        let mut best_reward = 0.0f64;
        let mut best_genome: Vec<usize> = Vec::new();
        let mut steps_to_peak = 0u64;
        let mut step = 0u64;
        let evals0 = env.evals();
        let invalid0 = env.invalid();

        loop {
            let proposals = agent.ask();
            // Never evaluate past the step budget: the tail of an
            // over-full final batch is dropped (the agent is told only
            // the rewards of what actually ran, as before).
            let remaining = (self.config.steps - step) as usize;
            let take = proposals.len().min(remaining);
            let outcomes = env.evaluate_batch(&proposals[..take]);
            let mut results = Vec::with_capacity(take);
            for (g, out) in proposals[..take].iter().zip(outcomes.iter()) {
                step += 1;
                if out.reward > best_reward {
                    best_reward = out.reward;
                    best_genome = g.clone();
                    steps_to_peak = step;
                }
                history.push(StepRecord { step, reward: out.reward, best_so_far: best_reward });
                results.push((g.clone(), out.reward));
            }
            agent.tell(&results);
            if step >= self.config.steps {
                break;
            }
        }

        // Re-materialize the winning design's reports (cache hits elide
        // them during the search).
        let best_reports = if best_genome.is_empty() {
            Vec::new()
        } else {
            env.evaluate_uncached(&best_genome).reports
        };

        RunResult {
            agent: agent.name(),
            history,
            best_reward,
            best_genome,
            best_reports,
            steps_to_peak,
            evals: env.evals() - evals0,
            invalid: env.invalid() - invalid0,
        }
    }
}

/// Convenience: run one (agent, scope, objective) experiment on a Table 3
/// system preset with a single training workload.
pub fn run_experiment(
    pss: Pss,
    workloads: Vec<WorkloadSpec>,
    objective: Objective,
    scope: SearchScope,
    config: DseConfig,
) -> (RunResult, Environment) {
    let mut env = Environment::new(pss, workloads, objective);
    let result = DseRunner::new(config, scope).run(&mut env);
    (result, env)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::sim::presets;
    use crate::workload::models::presets as wl;
    use crate::workload::Parallelization;

    fn make_env(objective: Objective) -> Environment {
        let pss = Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        );
        let model = wl::gpt3_175b().with_simulated_layers(4);
        Environment::new(pss, vec![WorkloadSpec::training(model, 2048)], objective)
    }

    #[test]
    fn baseline_genome_evaluates_positive() {
        let env = make_env(Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        let out = env.evaluate(&g);
        assert!(out.reward > 0.0, "baseline should be valid: {:?}", out.invalid_reason);
        assert_eq!(out.reports.len(), 1);
    }

    #[test]
    fn cache_hits_on_repeat() {
        let env = make_env(Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        env.evaluate(&g);
        let evals = env.evals();
        env.evaluate(&g);
        assert_eq!(env.evals(), evals);
        assert_eq!(env.cache_hits(), 1);
    }

    #[test]
    fn invalid_genome_rewards_zero() {
        let env = make_env(Objective::PerfPerBwPerNpu);
        let mut g = env.pss.baseline_genome();
        g[0] = 11; // DP=2048 > NPUs
        let out = env.evaluate(&g);
        assert_eq!(out.reward, 0.0);
        assert!(out.invalid_reason.is_some());
    }

    #[test]
    fn cache_hit_preserves_invalid_reason() {
        // Regression: a hit used to return `invalid_reason: None`, so
        // repeated lookups of a rejected point silently looked valid.
        let env = make_env(Objective::PerfPerBwPerNpu);
        let mut g = env.pss.baseline_genome();
        g[0] = 11; // DP=2048 > NPUs
        let first = env.evaluate(&g);
        let second = env.evaluate(&g);
        assert_eq!(env.cache_hits(), 1);
        assert_eq!(first.reward, second.reward);
        assert!(second.invalid_reason.is_some(), "hit dropped the invalid reason");
    }

    #[test]
    fn evaluate_batch_matches_serial_and_dedups() {
        let serial_env = make_env(Objective::PerfPerBwPerNpu);
        let batch_env = make_env(Objective::PerfPerBwPerNpu);
        let space = serial_env.pss.build_space(SearchScope::FullStack);
        let mut rng = crate::util::Rng::seed_from_u64(11);
        let mut genomes: Vec<Vec<usize>> = (0..24)
            .filter_map(|_| space.random_valid_genome(&mut rng, 500))
            .collect();
        assert!(genomes.len() > 4);
        let dup = genomes[0].clone();
        genomes.push(dup); // duplicate inside one batch
        let serial: Vec<f64> = genomes.iter().map(|g| serial_env.evaluate(g).reward).collect();
        let batch: Vec<f64> =
            batch_env.evaluate_batch(&genomes).iter().map(|o| o.reward).collect();
        assert_eq!(serial, batch);
        // Duplicates must not cost extra evaluations.
        let unique: std::collections::HashSet<&Vec<usize>> = genomes.iter().collect();
        assert_eq!(batch_env.evals(), unique.len() as u64);
    }

    #[test]
    fn runner_materializes_best_reports() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let cfg = DseConfig::new(AgentKind::Ga, 40, 42);
        let result = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
        assert!(result.best_reward > 0.0);
        assert_eq!(result.best_reports.len(), env.workloads.len());
        assert!(result.best_reports[0].latency_us > 0.0);
    }

    #[test]
    fn runner_improves_or_holds_best() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let cfg = DseConfig::new(AgentKind::Ga, 60, 42);
        let result = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
        assert_eq!(result.history.len(), 60);
        assert!(result.best_reward > 0.0);
        // best_so_far is monotone non-decreasing.
        let curve = result.reward_curve();
        assert!(curve.windows(2).all(|w| w[1] >= w[0]));
        assert!(result.steps_to_peak >= 1 && result.steps_to_peak <= 60);
    }

    #[test]
    fn all_agents_complete_short_runs() {
        for kind in AgentKind::ALL {
            let mut env = make_env(Objective::PerfPerNetworkCost);
            let cfg = DseConfig::new(kind, 25, 7);
            let r = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
            assert_eq!(r.history.len(), 25, "{}", kind.name());
            assert!(r.best_reward >= 0.0);
        }
    }

    #[test]
    fn workload_only_scope_keeps_network_fixed() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let cfg = DseConfig::new(AgentKind::Rw, 20, 3);
        let result = DseRunner::new(cfg, SearchScope::WorkloadOnly).run(&mut env);
        // The best genome's network slots must equal the baseline's.
        let base = env.pss.baseline_genome();
        let net_slots = env.pss.schema.stack_slots(crate::psa::Stack::Network);
        if !result.best_genome.is_empty() {
            for s in net_slots {
                assert_eq!(result.best_genome[s], base[s]);
            }
        }
    }

    #[test]
    fn multi_model_environment_sums_latency() {
        let pss = Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 8, 8, 1, true).unwrap(),
        );
        let w = vec![
            WorkloadSpec::training(wl::vit_base().with_simulated_layers(4), 1024),
            WorkloadSpec::training(wl::vit_large().with_simulated_layers(4), 1024),
        ];
        let env = Environment::new(pss, w, Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        let out = env.evaluate(&g);
        assert_eq!(out.reports.len(), 2, "{:?}", out.invalid_reason);
        let sum: f64 = out.reports.iter().map(|r| r.latency_us).sum();
        assert!(sum > 0.0);
    }
}
