//! Design-space exploration: the agent⇄environment loop (paper §4.4's
//! "well-defined agent-environment interaction loop").
//!
//! [`Environment`] wraps the simulator behind the PSS: agents submit
//! genomes, the environment materializes, simulates, and returns the
//! §5.4 reward. [`DseRunner`] drives an agent for a step budget, records
//! the full reward history (Figure 10's convergence curves), the best
//! design points (Tables 5/6, Figure 9), and evaluation statistics.

pub mod cost;
pub mod evalcache;
pub mod pareto;
pub mod prefilter;
pub mod reward;

pub use cost::{network_cost, network_cost_per_npu};
pub use evalcache::{EvalCache, EvalCacheStats};
pub use reward::{reward_from_report, Objective};

use crate::agents::{Agent, AgentKind};
use crate::faults::{FaultScenario, ScenarioSuite};
use crate::netsim::{FidelityMode, FlowLevelConfig, TrafficSuite, TrafficTrace};
use crate::obs::{
    invalid_category, CacheOutcome, MetricsRegistry, Rung, SearchObserver, SearchStepRecord,
};
use crate::pss::{Pss, SearchScope};
use crate::sim::{ClusterConfig, CollCostMemo, Invalid, LocalCollMemo, SimReport, Simulator};
use crate::util::parallel_map_catch;
use crate::workload::{ExecutionMode, ModelConfig, Parallelization};
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One workload the environment optimizes for (Table 6 Expr 1 optimizes
/// an ensemble of all four Table 2 models at once).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub model: ModelConfig,
    pub batch: u64,
    pub mode: ExecutionMode,
    /// Latency multiplier: how many times this phase repeats per request
    /// (e.g. one decode step spec with weight 512 models a 512-token
    /// chat generation; Table 6 Expr 2).
    pub weight: f64,
}

impl WorkloadSpec {
    pub fn training(model: ModelConfig, batch: u64) -> Self {
        Self { model, batch, mode: ExecutionMode::Training, weight: 1.0 }
    }

    pub fn inference(model: ModelConfig, batch: u64, mode: ExecutionMode, weight: f64) -> Self {
        Self { model, batch, mode, weight }
    }
}

/// Cache shard count (power of two; shards are `Mutex`-guarded so batch
/// evaluation threads hit disjoint locks).
const CACHE_SHARDS: usize = 16;

/// The memoized result of one evaluation: everything needed to replay
/// the outcome except the (large) per-workload reports, which are
/// re-materialized on demand for the final best point.
#[derive(Debug, Clone)]
struct CachedEval {
    reward: f64,
    invalid_reason: Option<String>,
}

/// Tag for the fidelity a memoized outcome was evaluated at (0 = the
/// genome's own PsA knob, 1 = forced Analytical, 2 = forced FlowLevel,
/// 3 = forced Packet). The genome memo keeps one shard group per tag,
/// so staged screening and re-ranking never read each other's rewards.
const FIDELITY_TAGS: usize = 4;

fn fidelity_tag(forced: Option<FidelityMode>) -> u8 {
    match forced {
        None => 0,
        Some(FidelityMode::Analytical) => 1,
        Some(FidelityMode::FlowLevel) => 2,
        Some(FidelityMode::Packet) => 3,
    }
}

/// How a robust (scenario-suite) evaluation folds per-scenario rewards
/// into the single scalar the agents optimize.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RobustAggregate {
    /// Mean reward over the suite — optimize expected goodput under the
    /// scenario distribution.
    #[default]
    Expected,
    /// Minimum reward over the suite — optimize the worst case, the
    /// conservative deployment posture.
    WorstCase,
}

impl RobustAggregate {
    pub fn name(&self) -> &'static str {
        match self {
            RobustAggregate::Expected => "expected",
            RobustAggregate::WorstCase => "worst",
        }
    }

    /// Parse a CLI spelling (`--robust expected|worst`).
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "expected" | "mean" => Some(RobustAggregate::Expected),
            "worst" | "worst-case" | "min" => Some(RobustAggregate::WorstCase),
            _ => None,
        }
    }

    /// Fold per-scenario rewards into one scalar (`0.0` for an empty
    /// suite, matching the invalid-point reward).
    pub fn combine(&self, rewards: &[f64]) -> f64 {
        if rewards.is_empty() {
            return 0.0;
        }
        match self {
            RobustAggregate::Expected => rewards.iter().sum::<f64>() / rewards.len() as f64,
            RobustAggregate::WorstCase => rewards.iter().copied().fold(f64::INFINITY, f64::min),
        }
    }
}

/// Robust-mode state: the fault suite plus each scenario pre-wrapped in
/// an `Arc` so per-evaluation simulator clones share one allocation.
struct RobustConfig {
    suite: ScenarioSuite,
    aggregate: RobustAggregate,
    scenarios: Vec<Arc<FaultScenario>>,
}

/// Traffic-mode state: the co-tenant trace suite every evaluation sweeps,
/// plus the fold. Composes with [`RobustConfig`] as a cross-join: each
/// fault scenario runs every trace, traces fold first (this aggregate),
/// then scenarios fold (the fault aggregate).
struct TrafficConfig {
    suite: TrafficSuite,
    aggregate: RobustAggregate,
}

/// The environment side of the loop (PSS "Environment Side
/// Configuration"): cost model + action/observation spaces + constraints.
pub struct Environment {
    pub pss: Pss,
    /// The default (analytical-fidelity) simulator.
    pub simulator: Simulator,
    /// The flow-level twin, used when a genome's PsA fidelity knob (or a
    /// caller via [`Environment::evaluate_with`]) asks for congestion.
    flow_simulator: Simulator,
    /// The chunk-precedence flow twin: the flow fabric with
    /// [`FlowLevelConfig::with_chunk_precedence`] on, used when a
    /// genome's PsA "Chunk Precedence" knob asks for the per-chunk
    /// drain. Kept as its own simulator so the two modes' backends carry
    /// distinct cache tags and never share memoized collective costs.
    chunked_flow_simulator: Simulator,
    /// The packet-level twin, the most expensive rung (staged-packet
    /// finalists, or a genome/caller asking for `FidelityMode::Packet`).
    packet_simulator: Simulator,
    pub workloads: Vec<WorkloadSpec>,
    pub objective: Objective,
    /// Sharded memo of evaluations keyed by genome, one shard group per
    /// fidelity tag — the DSE hot-path cache, safe to consult from
    /// `evaluate_batch` worker threads.
    cache: Vec<Mutex<HashMap<Vec<usize>, CachedEval>>>,
    /// Cross-evaluation cache of traces and collective costs shared by
    /// *all* evaluations (including forced-fidelity ones): see
    /// [`evalcache::EvalCache`].
    eval_cache: EvalCache,
    /// Robust mode: when set, every evaluation runs the whole fault
    /// suite and aggregates — see [`Environment::with_scenarios`].
    robust: Option<RobustConfig>,
    /// Traffic mode: when set, every evaluation sweeps the co-tenant
    /// trace suite — see [`Environment::with_traffic_suite`].
    traffic: Option<TrafficConfig>,
    /// Seed for traces requested by the genome's PsA "Traffic Profile"
    /// knob ([`crate::psa::with_traffic_param`]).
    traffic_seed: u64,
    evals: AtomicU64,
    cache_hits: AtomicU64,
    invalid: AtomicU64,
    flow_evals: AtomicU64,
    packet_evals: AtomicU64,
    eval_panics: AtomicU64,
    suite_evals: AtomicU64,
    traffic_evals: AtomicU64,
}

/// Outcome of evaluating one genome.
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutcome {
    pub reward: f64,
    /// Reports per workload (empty if the point was invalid *or* served
    /// from the memo cache — see [`RunResult::best_reports`]).
    pub reports: Vec<SimReport>,
    pub invalid_reason: Option<String>,
}

/// One scenario's share of a robust evaluation
/// ([`Environment::evaluate_suite`]).
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioScore {
    /// Scenario name (`"nominal"`, `"seed42"`, …).
    pub scenario: String,
    /// The §5.4 reward under this scenario (goodput-adjusted latency).
    pub reward: f64,
    /// Weighted raw iteration latency (us) — faults already slow this
    /// via stragglers and link degradation.
    pub latency_us: f64,
    /// Checkpoint/restart efficiency in `(0, 1]`: the fraction of
    /// wall-clock doing useful work (exactly `1.0` for the nominal
    /// scenario).
    pub efficiency: f64,
    /// Delivered useful compute across workloads (TFLOPs/s).
    pub goodput_tflops: f64,
}

/// The per-scenario breakdown plus the aggregated robust reward.
#[derive(Debug, Clone, PartialEq)]
pub struct SuiteOutcome {
    /// Per-scenario scores, nominal first (suite order).
    pub scores: Vec<ScenarioScore>,
    /// The aggregated reward the search optimizes.
    pub reward: f64,
    pub aggregate: RobustAggregate,
}

impl Environment {
    pub fn new(pss: Pss, workloads: Vec<WorkloadSpec>, objective: Objective) -> Self {
        assert!(!workloads.is_empty());
        Self {
            pss,
            simulator: Simulator::new(),
            flow_simulator: Simulator::new().with_fidelity(FidelityMode::FlowLevel),
            chunked_flow_simulator: Simulator::new()
                .with_flow_config(FlowLevelConfig::default().with_chunk_precedence(true)),
            packet_simulator: Simulator::new().with_fidelity(FidelityMode::Packet),
            workloads,
            objective,
            cache: (0..CACHE_SHARDS * FIDELITY_TAGS).map(|_| Mutex::new(HashMap::new())).collect(),
            eval_cache: EvalCache::new(),
            robust: None,
            traffic: None,
            traffic_seed: 0,
            evals: AtomicU64::new(0),
            cache_hits: AtomicU64::new(0),
            invalid: AtomicU64::new(0),
            flow_evals: AtomicU64::new(0),
            packet_evals: AtomicU64::new(0),
            eval_panics: AtomicU64::new(0),
            suite_evals: AtomicU64::new(0),
            traffic_evals: AtomicU64::new(0),
        }
    }

    /// Reconfigure the flow-level twin's fabric (oversubscription /
    /// background load) — builder style.
    pub fn with_flow_config(mut self, config: FlowLevelConfig) -> Self {
        let mut sim = Simulator::new().with_flow_config(config.clone());
        sim.mem_budget_bytes = self.simulator.mem_budget_bytes;
        self.flow_simulator = sim;
        // The chunked twin tracks the same fabric with the mode forced
        // on, so the PsA knob toggles precedence without losing the
        // configured oversubscription/background load.
        let mut chunked = Simulator::new().with_flow_config(config.with_chunk_precedence(true));
        chunked.mem_budget_bytes = self.simulator.mem_budget_bytes;
        self.chunked_flow_simulator = chunked;
        self
    }

    /// Reconfigure the packet-level twin's fabric and packet parameters
    /// (MTU, queue depth, ECMP width, seed) — builder style.
    pub fn with_packet_config(mut self, config: crate::netsim::PacketLevelConfig) -> Self {
        let mut sim = Simulator::new().with_packet_config(config);
        sim.mem_budget_bytes = self.simulator.mem_budget_bytes;
        self.packet_simulator = sim;
        self
    }

    /// Bound the cross-evaluation cache (builder style): retain at most
    /// roughly `trace_cap` traces and `coll_cap` collective costs, with
    /// unreferenced entries aging out second-chance style. `0` leaves
    /// the corresponding side unbounded (the default).
    pub fn with_eval_cache_capacity(mut self, trace_cap: usize, coll_cap: usize) -> Self {
        self.eval_cache = EvalCache::with_capacity(trace_cap, coll_cap);
        self
    }

    /// Enable robust mode (builder style): every evaluation — whatever
    /// the [`SearchStrategy`] — runs the whole `suite` and folds the
    /// per-scenario rewards with `aggregate`. The per-evaluation
    /// simulators are rebuilt from the current base simulators on each
    /// call, so this composes with [`Environment::with_flow_config`] in
    /// either order. Genome-memo and cross-evaluation cache keys stay
    /// correct: the fault link view changes the backend `cache_tag` and
    /// the collective keys' scenario fingerprint, so scenarios never
    /// share collective costs they shouldn't (traces, which depend only
    /// on the workload, *are* shared — deliberately).
    pub fn with_scenarios(mut self, suite: ScenarioSuite, aggregate: RobustAggregate) -> Self {
        assert!(!suite.is_empty(), "scenario suite needs at least the nominal scenario");
        let scenarios = suite.scenarios.iter().cloned().map(Arc::new).collect();
        self.robust = Some(RobustConfig { suite, aggregate, scenarios });
        self
    }

    /// The active fault suite and aggregate, if robust mode is on.
    pub fn scenario_suite(&self) -> Option<(&ScenarioSuite, RobustAggregate)> {
        self.robust.as_ref().map(|r| (&r.suite, r.aggregate))
    }

    /// Pin one co-tenant traffic trace on every evaluation (builder
    /// style) — the deterministic "simulate under this load" mode. A
    /// nominal trace is accepted and is a no-op (the backend wrapper is
    /// skipped), so callers can thread an optional trace unconditionally.
    /// Equivalent to [`Environment::with_traffic_suite`] with a
    /// single-member suite.
    pub fn with_traffic(self, trace: Arc<TrafficTrace>) -> Self {
        self.with_traffic_suite(TrafficSuite { traces: vec![trace] }, RobustAggregate::Expected)
    }

    /// Enable traffic-sweep mode (builder style): every evaluation runs
    /// each trace of `suite` and folds the per-trace rewards with
    /// `aggregate`. Composes with [`Environment::with_scenarios`] as a
    /// cross-join — each fault scenario runs every trace; traces fold
    /// first (with this aggregate), then scenarios fold (with the fault
    /// aggregate) — so `Expected∘Expected` is the grand mean and
    /// `WorstCase∘WorstCase` the grand minimum. Cache keys stay correct:
    /// the trace fingerprint flows into the backend `cache_tag` and the
    /// collective keys' `traffic` field. When a suite is active it takes
    /// precedence over the genome's PsA "Traffic Profile" knob.
    pub fn with_traffic_suite(mut self, suite: TrafficSuite, aggregate: RobustAggregate) -> Self {
        assert!(!suite.is_empty(), "traffic suite needs at least one trace");
        self.traffic = Some(TrafficConfig { suite, aggregate });
        self
    }

    /// Seed for traces generated on demand by the genome's PsA
    /// "Traffic Profile" knob (builder style; default 0).
    pub fn with_traffic_seed(mut self, seed: u64) -> Self {
        self.traffic_seed = seed;
        self
    }

    /// The active traffic suite and aggregate, if traffic mode is on.
    pub fn traffic_suite(&self) -> Option<(&TrafficSuite, RobustAggregate)> {
        self.traffic.as_ref().map(|t| (&t.suite, t.aggregate))
    }

    /// Genomes evaluated (cache misses).
    pub fn evals(&self) -> u64 {
        self.evals.load(Ordering::Relaxed)
    }

    /// Evaluations served from the memo cache.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits.load(Ordering::Relaxed)
    }

    /// Evaluations that scored zero (constraint/memory/config rejects).
    pub fn invalid(&self) -> u64 {
        self.invalid.load(Ordering::Relaxed)
    }

    /// Evaluations that ran the flow-level simulator (the expensive
    /// rung) — the denominator of the staged-search budget claims.
    pub fn flow_evals(&self) -> u64 {
        self.flow_evals.load(Ordering::Relaxed)
    }

    /// Evaluations that ran the packet-level simulator (the most
    /// expensive rung).
    pub fn packet_evals(&self) -> u64 {
        self.packet_evals.load(Ordering::Relaxed)
    }

    /// Batch evaluations that panicked and were isolated to an invalid
    /// outcome instead of aborting the run (see
    /// [`crate::util::parallel_map_catch`]).
    pub fn eval_panics(&self) -> u64 {
        self.eval_panics.load(Ordering::Relaxed)
    }

    /// Robust evaluations: each one runs the full scenario suite.
    pub fn suite_evals(&self) -> u64 {
        self.suite_evals.load(Ordering::Relaxed)
    }

    /// Evaluations that simulated under co-tenant traffic (a configured
    /// suite or a genome traffic knob) — the traffic-sweep cost counter.
    pub fn traffic_evals(&self) -> u64 {
        self.traffic_evals.load(Ordering::Relaxed)
    }

    /// Hit/miss counters of the cross-evaluation trace/collective cache.
    pub fn eval_cache_stats(&self) -> EvalCacheStats {
        self.eval_cache.stats()
    }

    /// Whether `(genome, fidelity)` is already memoized. A pure peek —
    /// no counters move — so instrumentation can classify upcoming
    /// evaluations as hits or misses without perturbing the stats.
    pub fn is_cached(&self, genome: &[usize], forced: Option<FidelityMode>) -> bool {
        let tag = fidelity_tag(forced);
        self.cache[self.shard_of(genome, tag)].lock().unwrap().contains_key(genome)
    }

    /// Export the environment's evaluation and cache counters into a
    /// [`MetricsRegistry`] as absolute values — call once, at the end
    /// of a run (repeated calls overwrite, so the registry always holds
    /// the latest totals).
    pub fn export_metrics(&self, metrics: &MetricsRegistry) {
        metrics.set_counter("env.evals", self.evals());
        metrics.set_counter("env.cache_hits", self.cache_hits());
        metrics.set_counter("env.invalid", self.invalid());
        metrics.set_counter("env.flow_evals", self.flow_evals());
        metrics.set_counter("env.packet_evals", self.packet_evals());
        metrics.set_counter("env.eval_panics", self.eval_panics());
        metrics.set_counter("env.suite_evals", self.suite_evals());
        metrics.set_counter("env.traffic_evals", self.traffic_evals());
        if let Some((suite, _)) = self.scenario_suite() {
            metrics.set_counter("env.fault_scenarios", suite.len() as u64);
        }
        if let Some((suite, _)) = self.traffic_suite() {
            metrics.set_counter("env.traffic_traces", suite.len() as u64);
        }
        let s = self.eval_cache_stats();
        metrics.set_counter("evalcache.trace_hits", s.trace_hits);
        metrics.set_counter("evalcache.trace_misses", s.trace_misses);
        metrics.set_counter("evalcache.trace_evictions", s.trace_evictions);
        metrics.set_counter("evalcache.coll_hits", s.coll_hits);
        metrics.set_counter("evalcache.coll_misses", s.coll_misses);
        metrics.set_counter("evalcache.coll_evictions", s.coll_evictions);
        let rate = |hits: u64, misses: u64| {
            let total = hits + misses;
            if total == 0 {
                0.0
            } else {
                hits as f64 / total as f64
            }
        };
        metrics.set_gauge("env.memo_hit_rate", rate(self.cache_hits(), self.evals()));
        metrics.set_gauge("evalcache.trace_hit_rate", rate(s.trace_hits, s.trace_misses));
        metrics.set_gauge("evalcache.coll_hit_rate", rate(s.coll_hits, s.coll_misses));
    }

    fn shard_of(&self, genome: &[usize], tag: u8) -> usize {
        let h = crate::util::hash64(|h| genome.hash(h)) as usize;
        h % CACHE_SHARDS + (tag as usize) * CACHE_SHARDS
    }

    fn cache_lookup(&self, genome: &[usize], tag: u8) -> Option<StepOutcome> {
        let shard = self.cache[self.shard_of(genome, tag)].lock().unwrap();
        shard.get(genome).map(|hit| {
            self.cache_hits.fetch_add(1, Ordering::Relaxed);
            StepOutcome {
                reward: hit.reward,
                reports: Vec::new(),
                invalid_reason: hit.invalid_reason.clone(),
            }
        })
    }

    fn cache_store(&self, genome: &[usize], tag: u8, outcome: &StepOutcome) {
        let mut shard = self.cache[self.shard_of(genome, tag)].lock().unwrap();
        if shard
            .insert(
                genome.to_vec(),
                CachedEval {
                    reward: outcome.reward,
                    invalid_reason: outcome.invalid_reason.clone(),
                },
            )
            .is_none()
        {
            self.evals.fetch_add(1, Ordering::Relaxed);
            if outcome.reward == 0.0 {
                self.invalid.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Evaluate a genome end to end: decode → constraint-check →
    /// materialize → simulate each workload → reward. Invalid points
    /// score 0 (the paper discards them). Repeat lookups are served from
    /// the memo cache with their full outcome (reward *and* invalid
    /// reason) — only the reports are elided.
    pub fn evaluate(&self, genome: &[usize]) -> StepOutcome {
        self.evaluate_memo(genome, None)
    }

    /// Evaluate a genome at an explicitly chosen fidelity, overriding the
    /// genome's own PsA knob — the re-ranking hook: screen with
    /// [`FidelityMode::Analytical`], then re-score finalists with
    /// [`FidelityMode::FlowLevel`]. Bypasses the genome memo so the full
    /// per-workload reports always come back (trace/collective artifacts
    /// still flow through the cross-evaluation cache, so repeats stay
    /// cheap); batch re-scoring that only needs rewards should use
    /// [`Environment::evaluate_batch_at`], which is memoized.
    pub fn evaluate_with(&self, genome: &[usize], fidelity: FidelityMode) -> StepOutcome {
        self.evaluate_raw(genome, Some(fidelity), true)
    }

    fn evaluate_memo(&self, genome: &[usize], forced: Option<FidelityMode>) -> StepOutcome {
        let tag = fidelity_tag(forced);
        if let Some(hit) = self.cache_lookup(genome, tag) {
            return hit;
        }
        let outcome = self.evaluate_raw(genome, forced, true);
        self.cache_store(genome, tag, &outcome);
        outcome
    }

    /// Evaluate a batch of genomes, fanning cache misses out across OS
    /// threads (the agents' `ask()` batches are embarrassingly parallel;
    /// the simulator is pure). Order is preserved.
    pub fn evaluate_batch(&self, genomes: &[Vec<usize>]) -> Vec<StepOutcome> {
        self.evaluate_batch_at(genomes, None)
    }

    /// [`Environment::evaluate_batch`] with an optional forced fidelity —
    /// the staged runner's screening (`Some(Analytical)`) and promotion
    /// (`Some(FlowLevel)`) entry point.
    pub fn evaluate_batch_at(
        &self,
        genomes: &[Vec<usize>],
        forced: Option<FidelityMode>,
    ) -> Vec<StepOutcome> {
        let tag = fidelity_tag(forced);
        let mut out: Vec<Option<StepOutcome>> =
            genomes.iter().map(|g| self.cache_lookup(g, tag)).collect();
        // Deduplicate misses so a batch with repeats evaluates once.
        let mut miss_positions: HashMap<&[usize], Vec<usize>> = HashMap::new();
        for (i, g) in genomes.iter().enumerate() {
            if out[i].is_none() {
                miss_positions.entry(g.as_slice()).or_default().push(i);
            }
        }
        let mut misses: Vec<(&[usize], Vec<usize>)> = miss_positions.into_iter().collect();
        // HashMap order is nondeterministic; restore batch order.
        misses.sort_by_key(|(_, positions)| positions[0]);
        let results = parallel_map_catch(&misses, |(g, _)| self.evaluate_raw(g, forced, true));
        for ((g, positions), result) in misses.iter().zip(results.into_iter()) {
            let outcome = match result {
                Ok(outcome) => {
                    self.cache_store(g, tag, &outcome);
                    outcome
                }
                // A panicked evaluation is isolated to its own slot: it
                // scores like an invalid point (reward 0, categorized
                // reason) but is *not* memoized — a retry re-evaluates.
                Err(msg) => {
                    self.eval_panics.fetch_add(1, Ordering::Relaxed);
                    StepOutcome {
                        reward: 0.0,
                        reports: Vec::new(),
                        invalid_reason: Some(format!("Panic({msg})")),
                    }
                }
            };
            // The first occurrence carries the full outcome (as a serial
            // evaluate would); later duplicates mirror cache hits.
            for &i in positions.iter().skip(1) {
                out[i] = Some(StepOutcome {
                    reward: outcome.reward,
                    reports: Vec::new(),
                    invalid_reason: outcome.invalid_reason.clone(),
                });
            }
            out[positions[0]] = Some(outcome);
        }
        out.into_iter().map(|o| o.expect("batch slot unfilled")).collect()
    }

    /// Evaluation bypassing every cache — the genome memo *and* the
    /// cross-evaluation trace/collective cache (used by the bench
    /// harness to time the true cold path, and by tests as the ground
    /// truth cached evaluation must match bit for bit). Honors the
    /// genome's PsA fidelity knob when the schema carries one.
    pub fn evaluate_uncached(&self, genome: &[usize]) -> StepOutcome {
        self.evaluate_raw(genome, None, false)
    }

    /// Evaluation through the shared cross-evaluation cache but without
    /// the genome memo: every call re-runs decode, materialization and
    /// pricing, reusing cached traces and collective costs. This is the
    /// cache-warm hot path the `eval_throughput` bench measures.
    pub fn evaluate_nomemo(&self, genome: &[usize]) -> StepOutcome {
        self.evaluate_raw(genome, None, true)
    }

    /// The one true evaluation ladder (decode → materialize → pick rung
    /// → simulate), shared by the cached, forced-fidelity and uncached
    /// entry points.
    fn evaluate_raw(
        &self,
        genome: &[usize],
        forced: Option<FidelityMode>,
        use_eval_cache: bool,
    ) -> StepOutcome {
        let point = match self.pss.schema.decode_valid(genome) {
            Ok(p) => p,
            Err(e) => {
                return StepOutcome { reward: 0.0, reports: Vec::new(), invalid_reason: Some(e) }
            }
        };
        let (cluster, par) = match self.pss.materialize(&point) {
            Ok(x) => x,
            Err(e) => {
                return StepOutcome { reward: 0.0, reports: Vec::new(), invalid_reason: Some(e) }
            }
        };
        let fidelity = forced.unwrap_or_else(|| self.pss.fidelity_of(&point));
        let chunked = self.pss.chunk_precedence_of(&point);
        let knob_trace = match self.knob_trace(&point, &cluster) {
            Ok(t) => t,
            Err(e) => {
                return StepOutcome { reward: 0.0, reports: Vec::new(), invalid_reason: Some(e) }
            }
        };
        if self.traffic.is_some() || knob_trace.is_some() {
            self.traffic_evals.fetch_add(1, Ordering::Relaxed);
        }
        let mut priced_any = false;
        let outcome = if let Some(robust) = &self.robust {
            self.suite_evals.fetch_add(1, Ordering::Relaxed);
            let ckpt = self.pss.checkpoint_interval_of(&point);
            match self.robust_outcomes(
                robust,
                knob_trace.as_ref(),
                &cluster,
                &par,
                ckpt,
                fidelity,
                chunked,
                use_eval_cache,
                &mut priced_any,
            ) {
                Err(invalid) => invalid,
                Ok(outcomes) => {
                    let rewards: Vec<f64> = outcomes.iter().map(|o| o.reward).collect();
                    let reward = robust.aggregate.combine(&rewards);
                    // The nominal scenario's reports (index 0) stand in
                    // for the point's reports, mirroring the fault-free
                    // shape callers expect.
                    let reports =
                        outcomes.into_iter().next().map(|o| o.reports).unwrap_or_default();
                    StepOutcome { reward, reports, invalid_reason: None }
                }
            }
        } else {
            let sim = self.sim_for(fidelity, chunked);
            self.simulate_traffic_point(
                sim,
                knob_trace.as_ref(),
                &cluster,
                &par,
                use_eval_cache,
                &mut priced_any,
            )
        };
        // Count flow/packet-level *simulations*, not attempts:
        // preflight/trace rejects never touch the expensive backends.
        if priced_any && matches!(fidelity, FidelityMode::FlowLevel) {
            self.flow_evals.fetch_add(1, Ordering::Relaxed);
        }
        if priced_any && matches!(fidelity, FidelityMode::Packet) {
            self.packet_evals.fetch_add(1, Ordering::Relaxed);
        }
        outcome
    }

    /// The trace the genome's PsA "Traffic Profile" knob asks for, if
    /// any. `None` when the schema has no knob, the knob sits on its
    /// "None" slot, or a configured suite overrides it
    /// ([`Environment::with_traffic_suite`] takes precedence).
    fn knob_trace(
        &self,
        point: &crate::psa::DesignPoint,
        cluster: &ClusterConfig,
    ) -> Result<Option<Arc<TrafficTrace>>, String> {
        if self.traffic.is_some() {
            return Ok(None);
        }
        match self.pss.traffic_profile_of(point) {
            None => Ok(None),
            Some(profile) => TrafficTrace::from_profile(
                profile,
                self.traffic_seed,
                cluster.topology.dims.len(),
            )
            .map(|t| Some(Arc::new(t))),
        }
    }

    /// [`Environment::simulate_point`] under the active traffic mode:
    /// sweep the configured suite (fold rewards with its aggregate; the
    /// head trace — nominal, for generated suites — supplies the
    /// reports), or attach the genome-knob trace, or run traffic-free.
    fn simulate_traffic_point(
        &self,
        sim: &Simulator,
        knob_trace: Option<&Arc<TrafficTrace>>,
        cluster: &ClusterConfig,
        par: &Parallelization,
        use_eval_cache: bool,
        priced_any: &mut bool,
    ) -> StepOutcome {
        if let Some(tc) = &self.traffic {
            let mut rewards = Vec::with_capacity(tc.suite.len());
            let mut reports = Vec::new();
            for (i, trace) in tc.suite.traces.iter().enumerate() {
                let ts = sim.clone().with_traffic(Arc::clone(trace));
                let out = self.simulate_point(&ts, cluster, par, use_eval_cache, priced_any);
                if out.invalid_reason.is_some() {
                    return out;
                }
                if i == 0 {
                    reports = out.reports;
                }
                rewards.push(out.reward);
            }
            StepOutcome { reward: tc.aggregate.combine(&rewards), reports, invalid_reason: None }
        } else if let Some(trace) = knob_trace {
            let ts = sim.clone().with_traffic(Arc::clone(trace));
            self.simulate_point(&ts, cluster, par, use_eval_cache, priced_any)
        } else {
            self.simulate_point(sim, cluster, par, use_eval_cache, priced_any)
        }
    }

    fn simulate_point(
        &self,
        sim: &Simulator,
        cluster: &ClusterConfig,
        par: &Parallelization,
        use_eval_cache: bool,
        priced_any: &mut bool,
    ) -> StepOutcome {
        let mut reports = Vec::with_capacity(self.workloads.len());
        let mut total_latency_us = 0.0;
        let mut shared_memo = self.eval_cache.coll_memo();
        let mut local_memo = LocalCollMemo::default();
        for w in &self.workloads {
            // Cached and uncached evaluations run the exact same stages
            // on the exact same inputs; they differ only in where trace
            // and collective artifacts come from — the shared cross-
            // evaluation cache vs fresh generation plus a genome-local
            // memo — so outcomes are bit-identical.
            let run: Result<SimReport, Invalid> =
                match sim.preflight(cluster, &w.model, par, w.batch, w.mode) {
                    Err(e) => Err(e),
                    Ok(mem) => {
                        let trace = if use_eval_cache {
                            self.eval_cache
                                .trace(&w.model, par, w.batch, w.mode)
                                .map_err(Invalid::Config)
                        } else {
                            crate::workload::generate_trace(&w.model, par, w.batch, w.mode)
                                .map(Arc::new)
                                .map_err(Invalid::Config)
                        };
                        match trace {
                            Err(e) => Err(e),
                            Ok(trace) => {
                                *priced_any = true;
                                let memo: &mut dyn CollCostMemo = if use_eval_cache {
                                    &mut shared_memo
                                } else {
                                    &mut local_memo
                                };
                                Ok(sim.price(cluster, par, &trace, mem, w.mode, memo))
                            }
                        }
                    }
                };
            match run {
                Ok(rep) => {
                    // Goodput-adjusted effective latency: a scenario
                    // delivering efficiency e needs 1/e wall-clock per
                    // useful iteration. Fault-free reports carry no
                    // goodput (e = 1) and the nominal scenario's
                    // efficiency is exactly 1.0, so `x / 1.0` keeps both
                    // bit-identical to the historical reward.
                    let eff = rep.goodput.map(|g| g.efficiency).unwrap_or(1.0);
                    total_latency_us += rep.latency_us * w.weight / eff.max(1e-12);
                    reports.push(rep);
                }
                Err(e) => {
                    return StepOutcome {
                        reward: 0.0,
                        reports: Vec::new(),
                        invalid_reason: Some(format!("{e:?}")),
                    }
                }
            }
        }
        let reward = self.objective.reward(total_latency_us / 1e6, &cluster.topology);
        StepOutcome { reward, reports, invalid_reason: None }
    }

    /// The base simulator for one evaluation: the fidelity rung, with
    /// the flow rung split by the design point's chunk-precedence
    /// choice. The analytical and packet rungs ignore the flag.
    fn sim_for(&self, fidelity: FidelityMode, chunked: bool) -> &Simulator {
        match fidelity {
            FidelityMode::FlowLevel if chunked => &self.chunked_flow_simulator,
            FidelityMode::FlowLevel => &self.flow_simulator,
            FidelityMode::Packet => &self.packet_simulator,
            FidelityMode::Analytical => &self.simulator,
        }
    }

    /// Run one materialized design through every scenario of the suite
    /// at one fidelity. `Ok` carries one outcome per scenario (nominal
    /// first, reports attached); `Err` carries the invalid outcome (a
    /// design rejected under any scenario is rejected outright — the
    /// preflight and trace stages are scenario-independent, so in
    /// practice all scenarios agree).
    #[allow(clippy::too_many_arguments)]
    fn robust_outcomes(
        &self,
        robust: &RobustConfig,
        knob_trace: Option<&Arc<TrafficTrace>>,
        cluster: &ClusterConfig,
        par: &Parallelization,
        ckpt: Option<u64>,
        fidelity: FidelityMode,
        chunked: bool,
        use_eval_cache: bool,
        priced_any: &mut bool,
    ) -> Result<Vec<StepOutcome>, StepOutcome> {
        let base = self.sim_for(fidelity, chunked);
        let mut outcomes = Vec::with_capacity(robust.scenarios.len());
        for scenario in &robust.scenarios {
            let sim =
                base.clone().with_faults(Arc::clone(scenario)).with_checkpoint_interval(ckpt);
            // Traffic crosses the suite: each scenario sweeps every trace
            // (folded by the traffic aggregate) before scenarios fold.
            let out = self
                .simulate_traffic_point(&sim, knob_trace, cluster, par, use_eval_cache, priced_any);
            if out.invalid_reason.is_some() {
                return Err(out);
            }
            outcomes.push(out);
        }
        Ok(outcomes)
    }

    /// Score one genome against the configured fault suite, scenario by
    /// scenario — the detailed view behind the robust reward (the CLI's
    /// per-scenario table). Errors if robust mode is off
    /// ([`Environment::with_scenarios`]) or the genome is invalid.
    /// Bypasses the genome memo (full reports are needed) but reuses the
    /// cross-evaluation cache, so re-scoring a searched point is cheap.
    pub fn evaluate_suite(
        &self,
        genome: &[usize],
        forced: Option<FidelityMode>,
    ) -> Result<SuiteOutcome, String> {
        let robust = self
            .robust
            .as_ref()
            .ok_or_else(|| "robust mode is off (Environment::with_scenarios)".to_string())?;
        let point = self.pss.schema.decode_valid(genome)?;
        let (cluster, par) = self.pss.materialize(&point)?;
        let fidelity = forced.unwrap_or_else(|| self.pss.fidelity_of(&point));
        let chunked = self.pss.chunk_precedence_of(&point);
        let ckpt = self.pss.checkpoint_interval_of(&point);
        let knob_trace = self.knob_trace(&point, &cluster)?;
        if self.traffic.is_some() || knob_trace.is_some() {
            self.traffic_evals.fetch_add(1, Ordering::Relaxed);
        }
        let mut priced_any = false;
        self.suite_evals.fetch_add(1, Ordering::Relaxed);
        let outcomes = self
            .robust_outcomes(
                robust,
                knob_trace.as_ref(),
                &cluster,
                &par,
                ckpt,
                fidelity,
                chunked,
                true,
                &mut priced_any,
            )
            .map_err(|inv| inv.invalid_reason.unwrap_or_else(|| "invalid design".to_string()))?;
        let mut scores = Vec::with_capacity(outcomes.len());
        for (scenario, out) in robust.suite.scenarios.iter().zip(outcomes.iter()) {
            let mut raw_us = 0.0;
            let mut effective_us = 0.0;
            let mut goodput_tflops = 0.0;
            for (w, rep) in self.workloads.iter().zip(out.reports.iter()) {
                let eff = rep.goodput.map(|g| g.efficiency).unwrap_or(1.0);
                raw_us += rep.latency_us * w.weight;
                effective_us += rep.latency_us * w.weight / eff.max(1e-12);
                goodput_tflops += rep.goodput.map(|g| g.goodput_tflops).unwrap_or(0.0);
            }
            scores.push(ScenarioScore {
                scenario: scenario.name.clone(),
                reward: out.reward,
                latency_us: raw_us,
                efficiency: if effective_us > 0.0 { raw_us / effective_us } else { 0.0 },
                goodput_tflops,
            });
        }
        let rewards: Vec<f64> = scores.iter().map(|s| s.reward).collect();
        Ok(SuiteOutcome {
            scores,
            reward: robust.aggregate.combine(&rewards),
            aggregate: robust.aggregate,
        })
    }

    /// Latency (us) of a genome, ignoring the regularizer — used by the
    /// Figure 4 spread studies. `None` if invalid.
    pub fn latency_us(&self, genome: &[usize]) -> Option<f64> {
        let out = self.evaluate_uncached(genome);
        if out.invalid_reason.is_some() {
            None
        } else {
            Some(out.reports.iter().map(|r| r.latency_us).sum())
        }
    }
}

/// One step of a DSE run.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: u64,
    pub reward: f64,
    /// Running best reward after this step (Figure 10's y-axis).
    pub best_so_far: f64,
}

/// Full result of a DSE run.
///
/// For [`SearchStrategy::Staged`] runs, `history` records the
/// *screening-rung* (analytical) rewards while `best_reward` is the
/// promoted winner's *flow-level* reward — on a congested fabric the
/// final best is therefore typically below the screening curve's
/// plateau. Single-fidelity strategies keep the two consistent.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub agent: &'static str,
    pub history: Vec<StepRecord>,
    pub best_reward: f64,
    pub best_genome: Vec<usize>,
    /// Per-workload reports of the best design, re-materialized after
    /// the run (cache hits during the search elide reports).
    pub best_reports: Vec<SimReport>,
    /// Step at which the final best was first reached (paper §6.4 quotes
    /// RW 652 / GA 440 / ACO 297 / BO 680 on their setup).
    pub steps_to_peak: u64,
    pub evals: u64,
    pub invalid: u64,
    /// Flow-level simulations this run spent (staged runs budget these:
    /// `promote_top_k` instead of one per step).
    pub flow_evals: u64,
    /// Packet-level simulations this run spent (staged-packet runs
    /// budget these: `packet_top_k` instead of one per step).
    pub packet_evals: u64,
    /// Staged runs only: the promoted finalists as
    /// `(genome, screening reward, flow-level reward)`, best-screened
    /// first. Empty for single-fidelity strategies.
    pub finalists: Vec<(Vec<usize>, f64, f64)>,
    /// Staged-packet runs only: the packet-rung finalists as
    /// `(genome, flow-level reward, packet reward)`, best-at-flow
    /// first. Empty for every other strategy.
    pub packet_finalists: Vec<(Vec<usize>, f64, f64)>,
}

impl RunResult {
    /// Top-k distinct genomes by reward from the recorded bests.
    pub fn reward_curve(&self) -> Vec<f64> {
        self.history.iter().map(|s| s.best_so_far).collect()
    }
}

/// DSE configuration: which agent, how many steps, seed.
#[derive(Debug, Clone, Copy)]
pub struct DseConfig {
    pub agent: AgentKind,
    pub steps: u64,
    pub seed: u64,
}

impl DseConfig {
    pub fn new(agent: AgentKind, steps: u64, seed: u64) -> Self {
        Self { agent, steps, seed }
    }
}

/// How the runner spends its simulation-fidelity budget (the active
/// counterpart of the passive PsA "Network Fidelity" knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearchStrategy {
    /// Evaluate every genome at its own PsA-knob fidelity (schemas
    /// without the knob resolve to Analytical) — the historical mode.
    #[default]
    GenomeFidelity,
    /// Force every evaluation to one rung, ignoring the knob.
    Fixed(FidelityMode),
    /// Multi-fidelity staging: screen the whole search on the cheap
    /// Analytical rung while maintaining the running top-K genomes, then
    /// re-score only those finalists with FlowLevel and return the
    /// flow-level winner. Spends `promote_top_k` flow-level simulations
    /// instead of one per step.
    Staged { promote_top_k: usize },
    /// Three-rung staging: Analytical screen, FlowLevel re-score of the
    /// running top-K, then a Packet re-score of the `packet_top_k` best
    /// flow-level finalists — the packet reward picks the winner.
    /// Spends `promote_top_k` flow-level plus `packet_top_k`
    /// packet-level simulations.
    StagedPacket { promote_top_k: usize, packet_top_k: usize },
}

/// Running top-K distinct genomes by screening reward (K is small, so
/// linear insertion beats a heap — and keeps order deterministic).
struct TopK {
    k: usize,
    /// Slots that do not affect a forced-fidelity evaluation (the PsA
    /// "Network Fidelity" knob, dead under staged screening): finalists
    /// differing only there are one physical design and must not spend
    /// two promotion slots.
    dead_slots: Vec<usize>,
    /// `(reward, first step seen, genome, canonical genome)`, best
    /// first. Ties keep the earlier entry first (stable insertion below
    /// the last strictly greater reward).
    entries: Vec<(f64, u64, Vec<usize>, Vec<usize>)>,
}

impl TopK {
    fn new(k: usize, dead_slots: Vec<usize>) -> Self {
        Self { k: k.max(1), dead_slots, entries: Vec::with_capacity(k.max(1) + 1) }
    }

    /// The genome with dead slots zeroed — the design identity key.
    fn canon(&self, genome: &[usize]) -> Vec<usize> {
        let mut c = genome.to_vec();
        for &s in &self.dead_slots {
            if s < c.len() {
                c[s] = 0;
            }
        }
        c
    }

    fn offer(&mut self, reward: f64, step: u64, genome: &[usize]) {
        if reward <= 0.0 {
            return;
        }
        if self.entries.len() == self.k && reward <= self.entries[self.k - 1].0 {
            return;
        }
        let canon = self.canon(genome);
        if self.entries.iter().any(|(_, _, _, c)| *c == canon) {
            return;
        }
        let pos = self.entries.partition_point(|(r, _, _, _)| *r >= reward);
        self.entries.insert(pos, (reward, step, genome.to_vec(), canon));
        self.entries.truncate(self.k);
    }
}

/// Drives one agent against one environment for a step budget. A *step*
/// is one genome evaluation (agents with populations consume several
/// steps per `ask`).
pub struct DseRunner {
    pub config: DseConfig,
    pub scope: SearchScope,
    pub strategy: SearchStrategy,
    /// Optional telemetry sink: when attached, every evaluated step is
    /// recorded into its timeline and metrics. `None` (the default)
    /// keeps the search loop observation-free.
    observer: Option<Arc<SearchObserver>>,
}

impl DseRunner {
    pub fn new(config: DseConfig, scope: SearchScope) -> Self {
        Self { config, scope, strategy: SearchStrategy::default(), observer: None }
    }

    /// Select a [`SearchStrategy`] (builder style).
    pub fn with_strategy(mut self, strategy: SearchStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Attach a [`SearchObserver`] (builder style).
    pub fn with_observer(mut self, observer: Arc<SearchObserver>) -> Self {
        self.observer = Some(observer);
        self
    }

    /// Run the search; also tracks distinct near-optimal genomes for the
    /// Figure 9 diversity analysis.
    pub fn run(&self, env: &mut Environment) -> RunResult {
        let space = env.pss.build_space(self.scope);
        let mut agent = self.config.agent.build(space, self.config.seed);
        self.run_with_agent(env, agent.as_mut())
    }

    /// Run with a caller-constructed agent (custom hyper-parameters or an
    /// XLA-backed BO surrogate). Each `ask()` batch is evaluated through
    /// [`Environment::evaluate_batch_at`], so population agents fan out
    /// across cores.
    pub fn run_with_agent(&self, env: &mut Environment, agent: &mut dyn Agent) -> RunResult {
        let screen_fidelity = match self.strategy {
            SearchStrategy::GenomeFidelity => None,
            SearchStrategy::Fixed(f) => Some(f),
            SearchStrategy::Staged { .. } | SearchStrategy::StagedPacket { .. } => {
                Some(FidelityMode::Analytical)
            }
        };
        let rung = match screen_fidelity {
            None => Rung::GenomeKnob,
            Some(FidelityMode::Analytical) => Rung::Analytical,
            Some(FidelityMode::FlowLevel) => Rung::FlowLevel,
            Some(FidelityMode::Packet) => Rung::Packet,
        };
        let mut topk = match self.strategy {
            SearchStrategy::Staged { promote_top_k }
            | SearchStrategy::StagedPacket { promote_top_k, .. } => {
                // Under forced-fidelity screening the PsA fidelity knob is
                // dead: canonicalize it away so one physical design never
                // occupies two promotion slots.
                let dead = env.pss.schema.param_slots(crate::psa::builders::names::NET_FIDELITY);
                Some(TopK::new(promote_top_k, dead))
            }
            _ => None,
        };
        let mut history = Vec::with_capacity(self.config.steps as usize);
        let mut best_reward = 0.0f64;
        let mut best_genome: Vec<usize> = Vec::new();
        let mut steps_to_peak = 0u64;
        let mut step = 0u64;
        let evals0 = env.evals();
        let invalid0 = env.invalid();
        let flow0 = env.flow_evals();
        let packet0 = env.packet_evals();

        loop {
            let proposals = agent.ask();
            // Never evaluate past the step budget: the tail of an
            // over-full final batch is dropped (the agent is told only
            // the rewards of what actually ran, as before).
            let remaining = (self.config.steps - step) as usize;
            let take = proposals.len().min(remaining);
            // Peek the memo *before* evaluating so each step can be
            // classified as a cache hit or miss; done only when an
            // observer is attached, keeping the hot path untouched.
            let precached: Option<Vec<bool>> = self.observer.as_ref().map(|_| {
                proposals[..take].iter().map(|g| env.is_cached(g, screen_fidelity)).collect()
            });
            let batch_start = self.observer.as_ref().map(|_| Instant::now());
            let outcomes = env.evaluate_batch_at(&proposals[..take], screen_fidelity);
            let batch_wall_us = batch_start.map_or(0.0, |t| t.elapsed().as_secs_f64() * 1e6);
            let mut results = Vec::with_capacity(take);
            for (i, (g, out)) in proposals[..take].iter().zip(outcomes.iter()).enumerate() {
                step += 1;
                if out.reward > best_reward {
                    best_reward = out.reward;
                    best_genome = g.clone();
                    steps_to_peak = step;
                }
                if let Some(t) = topk.as_mut() {
                    t.offer(out.reward, step, g);
                }
                history.push(StepRecord { step, reward: out.reward, best_so_far: best_reward });
                if let Some(obs) = self.observer.as_deref() {
                    obs.record_step(
                        SearchStepRecord {
                            step,
                            genome_fp: crate::util::hash64(|h| g.hash(h)),
                            rung,
                            reward: out.reward,
                            best_so_far: best_reward,
                            cache: if precached.as_ref().is_some_and(|p| p[i]) {
                                CacheOutcome::Hit
                            } else {
                                CacheOutcome::Miss
                            },
                            wall_us: batch_wall_us / take as f64,
                            invalid_kind: out.invalid_reason.as_deref().map(invalid_category),
                        },
                        self.config.steps,
                    );
                }
                results.push((g.clone(), out.reward));
            }
            agent.tell(&results);
            if step >= self.config.steps {
                break;
            }
        }

        // Staged promotion: re-score the surviving finalists on the
        // flow-level rung and let *that* reward pick the winner. The
        // screening argmax is always among the finalists, so the staged
        // flow-level result can never lose to "screen analytically, then
        // re-rank just the argmax".
        let mut finalists: Vec<(Vec<usize>, f64, f64)> = Vec::new();
        let mut packet_finalists: Vec<(Vec<usize>, f64, f64)> = Vec::new();
        let mut report_fidelity: Option<FidelityMode> = screen_fidelity;
        if let Some(topk) = topk {
            let genomes: Vec<Vec<usize>> =
                topk.entries.iter().map(|(_, _, g, _)| g.clone()).collect();
            if !genomes.is_empty() {
                let outcomes = env.evaluate_batch_at(&genomes, Some(FidelityMode::FlowLevel));
                best_reward = 0.0;
                best_genome = Vec::new();
                for ((screen_reward, first_step, genome, _), out) in
                    topk.entries.iter().zip(outcomes.iter())
                {
                    if out.reward > best_reward {
                        best_reward = out.reward;
                        best_genome = genome.clone();
                        steps_to_peak = *first_step;
                    }
                    finalists.push((genome.clone(), *screen_reward, out.reward));
                }
            }
            report_fidelity = Some(FidelityMode::FlowLevel);
            // Staged-packet: promote the best flow-level finalists one
            // rung further and let the packet reward pick the winner.
            if let SearchStrategy::StagedPacket { packet_top_k, .. } = self.strategy {
                let mut by_flow: Vec<usize> = (0..finalists.len()).collect();
                by_flow.sort_by(|&a, &b| {
                    finalists[b].2.partial_cmp(&finalists[a].2).unwrap_or(std::cmp::Ordering::Equal)
                });
                by_flow.truncate(packet_top_k.max(1));
                let genomes: Vec<Vec<usize>> =
                    by_flow.iter().map(|&i| finalists[i].0.clone()).collect();
                if !genomes.is_empty() {
                    let outcomes = env.evaluate_batch_at(&genomes, Some(FidelityMode::Packet));
                    best_reward = 0.0;
                    best_genome = Vec::new();
                    for (&i, out) in by_flow.iter().zip(outcomes.iter()) {
                        if out.reward > best_reward {
                            best_reward = out.reward;
                            best_genome = finalists[i].0.clone();
                            steps_to_peak = topk.entries[i].1;
                        }
                        packet_finalists.push((finalists[i].0.clone(), finalists[i].2, out.reward));
                    }
                    report_fidelity = Some(FidelityMode::Packet);
                }
            }
        }
        if let Some(obs) = self.observer.as_deref() {
            if !finalists.is_empty() {
                let fps: Vec<(u64, f64, f64)> = finalists
                    .iter()
                    .map(|(g, screen, flow)| (crate::util::hash64(|h| g.hash(h)), *screen, *flow))
                    .collect();
                obs.record_finalists(&fps);
            }
        }

        // Snapshot the search's spend *before* re-materializing reports:
        // the report re-run below is bookkeeping, not search budget.
        let evals_spent = env.evals() - evals0;
        let invalid_spent = env.invalid() - invalid0;
        let flow_spent = env.flow_evals() - flow0;
        let packet_spent = env.packet_evals() - packet0;

        // Re-materialize the winning design's reports (cache hits elide
        // them during the search) at the fidelity that scored it.
        let best_reports = if best_genome.is_empty() {
            Vec::new()
        } else {
            env.evaluate_raw(&best_genome, report_fidelity, true).reports
        };

        RunResult {
            agent: agent.name(),
            history,
            best_reward,
            best_genome,
            best_reports,
            steps_to_peak,
            evals: evals_spent,
            invalid: invalid_spent,
            flow_evals: flow_spent,
            packet_evals: packet_spent,
            finalists,
            packet_finalists,
        }
    }
}

/// Convenience: run one (agent, scope, objective) experiment on a Table 3
/// system preset with a single training workload.
pub fn run_experiment(
    pss: Pss,
    workloads: Vec<WorkloadSpec>,
    objective: Objective,
    scope: SearchScope,
    config: DseConfig,
) -> (RunResult, Environment) {
    let mut env = Environment::new(pss, workloads, objective);
    let result = DseRunner::new(config, scope).run(&mut env);
    (result, env)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::sim::presets;
    use crate::workload::models::presets as wl;
    use crate::workload::Parallelization;

    fn make_env(objective: Objective) -> Environment {
        let pss = Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        );
        let model = wl::gpt3_175b().with_simulated_layers(4);
        Environment::new(pss, vec![WorkloadSpec::training(model, 2048)], objective)
    }

    #[test]
    fn baseline_genome_evaluates_positive() {
        let env = make_env(Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        let out = env.evaluate(&g);
        assert!(out.reward > 0.0, "baseline should be valid: {:?}", out.invalid_reason);
        assert_eq!(out.reports.len(), 1);
    }

    #[test]
    fn cache_hits_on_repeat() {
        let env = make_env(Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        env.evaluate(&g);
        let evals = env.evals();
        env.evaluate(&g);
        assert_eq!(env.evals(), evals);
        assert_eq!(env.cache_hits(), 1);
    }

    #[test]
    fn invalid_genome_rewards_zero() {
        let env = make_env(Objective::PerfPerBwPerNpu);
        let mut g = env.pss.baseline_genome();
        g[0] = 11; // DP=2048 > NPUs
        let out = env.evaluate(&g);
        assert_eq!(out.reward, 0.0);
        assert!(out.invalid_reason.is_some());
    }

    #[test]
    fn cache_hit_preserves_invalid_reason() {
        // Regression: a hit used to return `invalid_reason: None`, so
        // repeated lookups of a rejected point silently looked valid.
        let env = make_env(Objective::PerfPerBwPerNpu);
        let mut g = env.pss.baseline_genome();
        g[0] = 11; // DP=2048 > NPUs
        let first = env.evaluate(&g);
        let second = env.evaluate(&g);
        assert_eq!(env.cache_hits(), 1);
        assert_eq!(first.reward, second.reward);
        assert!(second.invalid_reason.is_some(), "hit dropped the invalid reason");
    }

    #[test]
    fn evaluate_batch_matches_serial_and_dedups() {
        let serial_env = make_env(Objective::PerfPerBwPerNpu);
        let batch_env = make_env(Objective::PerfPerBwPerNpu);
        let space = serial_env.pss.build_space(SearchScope::FullStack);
        let mut rng = crate::util::Rng::seed_from_u64(11);
        let mut genomes: Vec<Vec<usize>> = (0..24)
            .filter_map(|_| space.random_valid_genome(&mut rng, 500))
            .collect();
        assert!(genomes.len() > 4);
        let dup = genomes[0].clone();
        genomes.push(dup); // duplicate inside one batch
        let serial: Vec<f64> = genomes.iter().map(|g| serial_env.evaluate(g).reward).collect();
        let batch: Vec<f64> =
            batch_env.evaluate_batch(&genomes).iter().map(|o| o.reward).collect();
        assert_eq!(serial, batch);
        // Duplicates must not cost extra evaluations.
        let unique: std::collections::HashSet<&Vec<usize>> = genomes.iter().collect();
        assert_eq!(batch_env.evals(), unique.len() as u64);
    }

    #[test]
    fn runner_materializes_best_reports() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let cfg = DseConfig::new(AgentKind::Ga, 40, 42);
        let result = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
        assert!(result.best_reward > 0.0);
        assert_eq!(result.best_reports.len(), env.workloads.len());
        assert!(result.best_reports[0].latency_us > 0.0);
    }

    #[test]
    fn runner_improves_or_holds_best() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let cfg = DseConfig::new(AgentKind::Ga, 60, 42);
        let result = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
        assert_eq!(result.history.len(), 60);
        assert!(result.best_reward > 0.0);
        // best_so_far is monotone non-decreasing.
        let curve = result.reward_curve();
        assert!(curve.windows(2).all(|w| w[1] >= w[0]));
        assert!(result.steps_to_peak >= 1 && result.steps_to_peak <= 60);
    }

    #[test]
    fn all_agents_complete_short_runs() {
        for kind in AgentKind::ALL {
            let mut env = make_env(Objective::PerfPerNetworkCost);
            let cfg = DseConfig::new(kind, 25, 7);
            let r = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
            assert_eq!(r.history.len(), 25, "{}", kind.name());
            assert!(r.best_reward >= 0.0);
        }
    }

    #[test]
    fn workload_only_scope_keeps_network_fixed() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let cfg = DseConfig::new(AgentKind::Rw, 20, 3);
        let result = DseRunner::new(cfg, SearchScope::WorkloadOnly).run(&mut env);
        // The best genome's network slots must equal the baseline's.
        let base = env.pss.baseline_genome();
        let net_slots = env.pss.schema.stack_slots(crate::psa::Stack::Network);
        if !result.best_genome.is_empty() {
            for s in net_slots {
                assert_eq!(result.best_genome[s], base[s]);
            }
        }
    }

    #[test]
    fn cached_evaluation_bit_identical_to_uncached() {
        // The cross-evaluation cache must be exact: same decode →
        // materialize → price ladder, with trace/collective artifacts
        // merely short-circuited. Any drift here corrupts the search.
        let env = make_env(Objective::PerfPerBwPerNpu);
        let space = env.pss.build_space(SearchScope::FullStack);
        let mut rng = crate::util::Rng::seed_from_u64(3);
        let mut checked = 0;
        for _ in 0..30 {
            if let Some(g) = space.random_valid_genome(&mut rng, 500) {
                let cold = env.evaluate_uncached(&g);
                let warm = env.evaluate_nomemo(&g); // fills the shared cache
                let hot = env.evaluate_nomemo(&g); // trace+coll all hits
                assert_eq!(cold, warm, "cache fill diverged");
                assert_eq!(cold, hot, "cache hit diverged");
                assert_eq!(cold.reward.to_bits(), hot.reward.to_bits());
                checked += 1;
            }
        }
        assert!(checked > 5);
        let s = env.eval_cache_stats();
        assert!(s.trace_hits > 0, "trace cache never hit: {s:?}");
        assert!(s.coll_hits > 0, "collective cache never hit: {s:?}");
    }

    #[test]
    fn trace_cache_shares_across_network_knobs() {
        // Genomes that differ only in network-stack slots share one
        // trace: the workload knobs are identical.
        let env = make_env(Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        env.evaluate_nomemo(&g);
        let misses = env.eval_cache_stats().trace_misses;
        let mut g2 = g.clone();
        let bw_slots = env.pss.schema.stack_slots(crate::psa::Stack::Network);
        g2[*bw_slots.last().unwrap()] = 0; // move a bandwidth knob
        assert_ne!(g, g2);
        let out = env.evaluate_nomemo(&g2);
        assert!(out.invalid_reason.is_none(), "{:?}", out.invalid_reason);
        assert_eq!(
            env.eval_cache_stats().trace_misses,
            misses,
            "network-only change must not re-generate the trace"
        );
    }

    #[test]
    fn topk_keeps_best_distinct_sorted() {
        let mut t = TopK::new(3, Vec::new());
        t.offer(1.0, 1, &[1, 0]);
        t.offer(3.0, 2, &[3, 0]);
        t.offer(2.0, 3, &[2, 0]);
        t.offer(3.0, 4, &[3, 0]); // duplicate genome ignored
        t.offer(0.0, 5, &[0, 0]); // invalid ignored
        t.offer(4.0, 6, &[4, 0]); // evicts reward 1.0
        let rewards: Vec<f64> = t.entries.iter().map(|(r, _, _, _)| *r).collect();
        assert_eq!(rewards, vec![4.0, 3.0, 2.0]);
        let steps: Vec<u64> = t.entries.iter().map(|(_, s, _, _)| *s).collect();
        assert_eq!(steps, vec![6, 2, 3]);
    }

    #[test]
    fn topk_dead_slots_collapse_fidelity_twins() {
        // Genomes differing only in a dead slot are one physical design.
        let mut t = TopK::new(3, vec![1]);
        t.offer(3.0, 1, &[7, 0]);
        t.offer(3.0, 2, &[7, 1]); // fidelity twin — must not take a slot
        t.offer(2.0, 3, &[5, 1]);
        assert_eq!(t.entries.len(), 2);
        assert_eq!(t.entries[0].2, vec![7, 0]); // first-seen genome kept
        assert_eq!(t.entries[1].2, vec![5, 1]);
    }

    #[test]
    fn staged_runner_promotes_topk_and_picks_flow_winner() {
        let mut env = make_env(Objective::PerfPerBwPerNpu)
            .with_flow_config(FlowLevelConfig::oversubscribed(4.0));
        let cfg = DseConfig::new(AgentKind::Ga, 60, 42);
        let staged = DseRunner::new(cfg, SearchScope::FullStack)
            .with_strategy(SearchStrategy::Staged { promote_top_k: 5 })
            .run(&mut env);
        assert!(staged.best_reward > 0.0);
        assert!(!staged.finalists.is_empty() && staged.finalists.len() <= 5);
        assert!(staged.flow_evals <= 5, "staged spent {} flow evals", staged.flow_evals);
        // The winner carries the max flow-level reward over the finalists.
        let max_flow = staged.finalists.iter().map(|(_, _, f)| *f).fold(0.0, f64::max);
        assert_eq!(staged.best_reward, max_flow);
        // And the screening argmax survived into the finalists.
        let screen_max = staged.history.iter().map(|s| s.reward).fold(0.0, f64::max);
        assert!(staged.finalists.iter().any(|(_, screen, _)| *screen == screen_max));
        assert_eq!(staged.best_reports.len(), env.workloads.len());
    }

    #[test]
    fn staged_not_worse_than_rescored_analytical_argmax() {
        // Same seed => identical screening trajectories, and the staged
        // finalists include the analytical argmax — so staging can only
        // improve on "screen, then re-rank just the argmax".
        let cfg = DseConfig::new(AgentKind::Aco, 80, 7);
        let mut env_a = make_env(Objective::PerfPerBwPerNpu)
            .with_flow_config(FlowLevelConfig::oversubscribed(4.0));
        let single = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env_a);
        assert!(single.best_reward > 0.0);
        let rescored = env_a.evaluate_with(&single.best_genome, FidelityMode::FlowLevel).reward;

        let mut env_b = make_env(Objective::PerfPerBwPerNpu)
            .with_flow_config(FlowLevelConfig::oversubscribed(4.0));
        let staged = DseRunner::new(cfg, SearchScope::FullStack)
            .with_strategy(SearchStrategy::Staged { promote_top_k: 4 })
            .run(&mut env_b);
        assert!(
            staged.best_reward >= rescored,
            "staged {:.6e} lost to rescored analytical argmax {:.6e}",
            staged.best_reward,
            rescored
        );
    }

    #[test]
    fn staged_packet_promotes_flow_finalists_and_picks_packet_winner() {
        let mut env = make_env(Objective::PerfPerBwPerNpu)
            .with_flow_config(FlowLevelConfig::oversubscribed(4.0))
            .with_packet_config(crate::netsim::PacketLevelConfig::oversubscribed(4.0));
        let cfg = DseConfig::new(AgentKind::Ga, 60, 42);
        let r = DseRunner::new(cfg, SearchScope::FullStack)
            .with_strategy(SearchStrategy::StagedPacket { promote_top_k: 5, packet_top_k: 2 })
            .run(&mut env);
        assert!(r.best_reward > 0.0);
        assert!(!r.finalists.is_empty() && r.finalists.len() <= 5);
        assert!(!r.packet_finalists.is_empty() && r.packet_finalists.len() <= 2);
        assert!(r.packet_evals > 0 && r.packet_evals <= 2, "spent {}", r.packet_evals);
        // The winner carries the max packet reward over the finalists.
        let max_pkt = r.packet_finalists.iter().map(|(_, _, p)| *p).fold(0.0, f64::max);
        assert_eq!(r.best_reward, max_pkt);
        // Every packet finalist is one of the flow finalists, carrying
        // its flow-level reward along.
        for (g, flow, _) in &r.packet_finalists {
            assert!(r.finalists.iter().any(|(fg, _, fr)| fg == g && fr == flow));
        }
        assert_eq!(r.best_reports.len(), env.workloads.len());
    }

    #[test]
    fn staged_packet_is_bit_reproducible() {
        let cfg = DseConfig::new(AgentKind::Ga, 40, 9);
        let run = || {
            let mut env = make_env(Objective::PerfPerBwPerNpu)
                .with_flow_config(FlowLevelConfig::oversubscribed(4.0))
                .with_packet_config(crate::netsim::PacketLevelConfig::oversubscribed(4.0));
            DseRunner::new(cfg, SearchScope::FullStack)
                .with_strategy(SearchStrategy::StagedPacket { promote_top_k: 4, packet_top_k: 2 })
                .run(&mut env)
        };
        let a = run();
        let b = run();
        assert_eq!(a.best_genome, b.best_genome);
        assert_eq!(a.best_reward.to_bits(), b.best_reward.to_bits());
        assert_eq!(a.finalists, b.finalists);
        assert_eq!(a.packet_finalists, b.packet_finalists);
        assert_eq!(a.best_reports, b.best_reports);
    }

    #[test]
    fn fixed_strategy_forces_flow_fidelity() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let cfg = DseConfig::new(AgentKind::Rw, 48, 3);
        let r = DseRunner::new(cfg, SearchScope::FullStack)
            .with_strategy(SearchStrategy::Fixed(FidelityMode::FlowLevel))
            .run(&mut env);
        assert!(r.flow_evals > 0, "fixed flow strategy never ran the flow simulator");
        assert!(r.flow_evals <= r.evals);
        assert!(r.finalists.is_empty());
    }

    #[test]
    fn forced_fidelity_memo_is_isolated_per_rung() {
        let env = make_env(Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        let a = env.evaluate_batch_at(&[g.clone()], Some(FidelityMode::Analytical));
        let f = env.evaluate_batch_at(&[g.clone()], Some(FidelityMode::FlowLevel));
        assert_eq!(env.cache_hits(), 0, "different rungs must not share memo entries");
        // Repeat at the same rung is a memo hit.
        let f2 = env.evaluate_batch_at(&[g.clone()], Some(FidelityMode::FlowLevel));
        assert_eq!(env.cache_hits(), 1);
        assert_eq!(f[0].reward, f2[0].reward);
        assert!(a[0].reward > 0.0);
    }

    #[test]
    fn evaluate_with_always_returns_reports() {
        // Even after the same (genome, fidelity) was memoized by a batch
        // re-score, evaluate_with must hand back full reports.
        let env = make_env(Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        env.evaluate_batch_at(&[g.clone()], Some(FidelityMode::FlowLevel));
        let out = env.evaluate_with(&g, FidelityMode::FlowLevel);
        assert_eq!(out.reports.len(), env.workloads.len());
        assert!(out.reports[0].latency_us > 0.0);
    }

    #[test]
    fn multi_model_environment_sums_latency() {
        let pss = Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 8, 8, 1, true).unwrap(),
        );
        let w = vec![
            WorkloadSpec::training(wl::vit_base().with_simulated_layers(4), 1024),
            WorkloadSpec::training(wl::vit_large().with_simulated_layers(4), 1024),
        ];
        let env = Environment::new(pss, w, Objective::PerfPerBwPerNpu);
        let g = env.pss.baseline_genome();
        let out = env.evaluate(&g);
        assert_eq!(out.reports.len(), 2, "{:?}", out.invalid_reason);
        let sum: f64 = out.reports.iter().map(|r| r.latency_us).sum();
        assert!(sum > 0.0);
    }

    #[test]
    fn observer_records_every_step() {
        let mut env = make_env(Objective::PerfPerBwPerNpu);
        let obs = Arc::new(SearchObserver::new());
        let cfg = DseConfig::new(AgentKind::Rw, 30, 9);
        let r = DseRunner::new(cfg, SearchScope::FullStack)
            .with_observer(Arc::clone(&obs))
            .run(&mut env);
        assert_eq!(r.history.len(), 30);
        let tl = obs.timeline();
        assert_eq!(tl.steps.len(), 30);
        // Timeline steps mirror the runner's history exactly.
        for (rec, hist) in tl.steps.iter().zip(r.history.iter()) {
            assert_eq!(rec.step, hist.step);
            assert_eq!(rec.reward, hist.reward);
            assert_eq!(rec.best_so_far, hist.best_so_far);
        }
        let m = obs.metrics.snapshot();
        assert_eq!(m.counters.get("dse.steps"), Some(&30));
        let hits = m.counters.get("dse.evals.cache_hit").copied().unwrap_or(0);
        let misses = m.counters.get("dse.evals.cache_miss").copied().unwrap_or(0);
        assert_eq!(hits + misses, 30, "every step is a hit or a miss");
        env.export_metrics(&obs.metrics);
        assert_eq!(obs.metrics.counter("env.evals"), env.evals());
        crate::util::json::validate(&obs.telemetry_json()).unwrap();
    }

    #[test]
    fn observer_absence_leaves_run_identical() {
        let cfg = DseConfig::new(AgentKind::Ga, 40, 21);
        let mut env_plain = make_env(Objective::PerfPerBwPerNpu);
        let plain = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env_plain);
        let mut env_obs = make_env(Objective::PerfPerBwPerNpu);
        let obs = Arc::new(SearchObserver::new());
        let observed = DseRunner::new(cfg, SearchScope::FullStack)
            .with_observer(obs)
            .run(&mut env_obs);
        assert_eq!(plain.best_reward.to_bits(), observed.best_reward.to_bits());
        assert_eq!(plain.best_genome, observed.best_genome);
        assert_eq!(plain.history.len(), observed.history.len());
    }

    /// A paper schema extended with the checkpoint knob, no scenarios.
    fn make_ckpt_env(objective: Objective) -> Environment {
        let pss = Pss::new(
            crate::psa::with_checkpoint_param(paper_table4_schema(1024, 4)),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        );
        let model = wl::gpt3_175b().with_simulated_layers(4);
        Environment::new(pss, vec![WorkloadSpec::training(model, 2048)], objective)
    }

    fn make_robust_env(aggregate: RobustAggregate) -> Environment {
        make_ckpt_env(Objective::PerfPerBwPerNpu)
            .with_scenarios(ScenarioSuite::generate(7, 2, 4), aggregate)
    }

    #[test]
    fn robust_aggregates_combine_correctly() {
        assert_eq!(RobustAggregate::Expected.combine(&[1.0, 3.0]), 2.0);
        assert_eq!(RobustAggregate::WorstCase.combine(&[1.0, 3.0]), 1.0);
        assert_eq!(RobustAggregate::Expected.combine(&[]), 0.0);
        assert_eq!(RobustAggregate::WorstCase.combine(&[]), 0.0);
        assert_eq!(RobustAggregate::from_name("expected"), Some(RobustAggregate::Expected));
        assert_eq!(RobustAggregate::from_name("worst"), Some(RobustAggregate::WorstCase));
        assert_eq!(RobustAggregate::from_name("bogus"), None);
        assert_eq!(RobustAggregate::Expected.name(), "expected");
        assert_eq!(RobustAggregate::WorstCase.name(), "worst");
    }

    #[test]
    fn robust_reward_is_bounded_by_nominal() {
        // Faults only slow a design down, so: worst <= expected <= nominal.
        let plain = make_ckpt_env(Objective::PerfPerBwPerNpu);
        let g = plain.pss.baseline_genome();
        let nominal = plain.evaluate(&g).reward;
        let expected = make_robust_env(RobustAggregate::Expected).evaluate(&g).reward;
        let worst = make_robust_env(RobustAggregate::WorstCase).evaluate(&g).reward;
        assert!(nominal > 0.0 && expected > 0.0 && worst > 0.0);
        assert!(expected <= nominal, "expected {expected:.6e} > nominal {nominal:.6e}");
        assert!(worst <= expected, "worst {worst:.6e} > expected {expected:.6e}");
    }

    #[test]
    fn robust_evaluation_is_deterministic() {
        let env = make_robust_env(RobustAggregate::Expected);
        let g = env.pss.baseline_genome();
        let a = env.evaluate_nomemo(&g);
        let b = env.evaluate_nomemo(&g);
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
        assert_eq!(env.suite_evals(), 2);
        assert_eq!(env.eval_panics(), 0);
    }

    #[test]
    fn evaluate_suite_scores_every_scenario() {
        let env = make_robust_env(RobustAggregate::WorstCase);
        let g = env.pss.baseline_genome();
        let suite = env.evaluate_suite(&g, None).unwrap();
        assert_eq!(suite.scores.len(), 3); // nominal + 2 seeded
        assert_eq!(suite.scores[0].scenario, "nominal");
        assert_eq!(suite.scores[0].efficiency, 1.0);
        assert!(suite.scores[0].goodput_tflops > 0.0);
        let min = suite.scores.iter().map(|s| s.reward).fold(f64::INFINITY, f64::min);
        assert_eq!(suite.reward, min);
        for s in &suite.scores[1..] {
            assert!(s.reward <= suite.scores[0].reward, "{}: faults sped things up", s.scenario);
            assert!(s.efficiency > 0.0 && s.efficiency <= 1.0);
        }
        // Without a configured suite the detailed view refuses.
        let plain = make_env(Objective::PerfPerBwPerNpu);
        assert!(plain.evaluate_suite(&plain.pss.baseline_genome(), None).is_err());
    }

    #[test]
    fn checkpoint_knob_changes_robust_reward() {
        let env = make_robust_env(RobustAggregate::Expected);
        let g = env.pss.baseline_genome();
        let slots = env.pss.schema.param_slots(crate::psa::builders::names::CKPT_INTERVAL);
        assert_eq!(slots.len(), 1);
        let mut g2 = g.clone();
        g2[slots[0]] = 7; // 1024-iteration interval vs the baseline's 8
        let r1 = env.evaluate_nomemo(&g).reward;
        let r2 = env.evaluate_nomemo(&g2).reward;
        assert!(r1 > 0.0 && r2 > 0.0);
        assert_ne!(r1.to_bits(), r2.to_bits(), "checkpoint knob must flow into goodput");
        // Fault-free, the knob is inert: both genomes score identically.
        let plain = make_ckpt_env(Objective::PerfPerBwPerNpu);
        let p1 = plain.evaluate_nomemo(&g).reward;
        let p2 = plain.evaluate_nomemo(&g2).reward;
        assert_eq!(p1.to_bits(), p2.to_bits());
    }

    #[test]
    fn robust_runner_works_with_every_strategy() {
        for strategy in [
            SearchStrategy::GenomeFidelity,
            SearchStrategy::Fixed(FidelityMode::Analytical),
            SearchStrategy::Staged { promote_top_k: 2 },
            SearchStrategy::StagedPacket { promote_top_k: 2, packet_top_k: 1 },
        ] {
            let mut env = make_robust_env(RobustAggregate::Expected);
            let cfg = DseConfig::new(AgentKind::Rw, 8, 5);
            let r = DseRunner::new(cfg, SearchScope::FullStack)
                .with_strategy(strategy)
                .run(&mut env);
            assert_eq!(r.history.len(), 8, "{strategy:?}");
            assert!(env.suite_evals() > 0, "{strategy:?} never ran the suite");
        }
    }

    /// A paper schema extended with the traffic knob, no suites.
    fn make_traffic_knob_env() -> Environment {
        let pss = Pss::new(
            crate::psa::with_traffic_param(paper_table4_schema(1024, 4)),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        );
        let model = wl::gpt3_175b().with_simulated_layers(4);
        Environment::new(
            pss,
            vec![WorkloadSpec::training(model, 2048)],
            Objective::PerfPerBwPerNpu,
        )
    }

    #[test]
    fn nominal_traffic_is_bit_identical_to_traffic_free() {
        let plain = make_env(Objective::PerfPerBwPerNpu);
        let g = plain.pss.baseline_genome();
        let nominal = make_env(Objective::PerfPerBwPerNpu)
            .with_traffic(Arc::new(TrafficTrace::nominal()));
        let a = plain.evaluate_nomemo(&g);
        let b = nominal.evaluate_nomemo(&g);
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
        assert_eq!(a.reports, b.reports);
        // A nominal trace still counts as a traffic evaluation.
        assert_eq!(nominal.traffic_evals(), 1);
    }

    #[test]
    fn traffic_suite_reward_bounded_by_nominal() {
        let plain = make_env(Objective::PerfPerBwPerNpu);
        let g = plain.pss.baseline_genome();
        let nominal = plain.evaluate(&g).reward;
        let suite = || TrafficSuite::generate("diurnal", 11, 2, 4).unwrap();
        let expected = make_env(Objective::PerfPerBwPerNpu)
            .with_traffic_suite(suite(), RobustAggregate::Expected)
            .evaluate(&g)
            .reward;
        let worst = make_env(Objective::PerfPerBwPerNpu)
            .with_traffic_suite(suite(), RobustAggregate::WorstCase)
            .evaluate(&g)
            .reward;
        assert!(nominal > 0.0 && expected > 0.0 && worst > 0.0);
        assert!(expected <= nominal, "expected {expected:.6e} > nominal {nominal:.6e}");
        assert!(worst <= expected, "worst {worst:.6e} > expected {expected:.6e}");
    }

    #[test]
    fn traffic_suite_evaluation_is_deterministic() {
        let env = make_env(Objective::PerfPerBwPerNpu)
            .with_traffic_suite(TrafficSuite::generate("bursty", 5, 2, 4).unwrap(), RobustAggregate::Expected);
        let g = env.pss.baseline_genome();
        let a = env.evaluate_nomemo(&g);
        let b = env.evaluate_nomemo(&g);
        assert_eq!(a.reward.to_bits(), b.reward.to_bits());
        assert_eq!(env.traffic_evals(), 2);
        assert_eq!(env.eval_panics(), 0);
    }

    #[test]
    fn traffic_knob_prices_the_requested_profile() {
        let env = make_traffic_knob_env().with_traffic_seed(7);
        let g = env.pss.baseline_genome(); // knob defaults to "None"
        let idle = env.evaluate_nomemo(&g);
        assert!(idle.reward > 0.0, "{:?}", idle.invalid_reason);
        assert_eq!(env.traffic_evals(), 0, "knob at None must stay traffic-free");
        let slots = env.pss.schema.param_slots(crate::psa::builders::names::TRAFFIC_PROFILE);
        assert_eq!(slots.len(), 1);
        let mut busy = g.clone();
        busy[slots[0]] = 2; // Diurnal
        let loaded = env.evaluate_nomemo(&busy);
        assert!(loaded.reward > 0.0, "{:?}", loaded.invalid_reason);
        assert_eq!(env.traffic_evals(), 1);
        assert!(
            loaded.reward < idle.reward,
            "co-tenant load must cost: {} !< {}",
            loaded.reward,
            idle.reward
        );
        // The knob trace is seeded by the environment: a different seed
        // prices a different co-tenant.
        let reseeded = make_traffic_knob_env().with_traffic_seed(8).evaluate_nomemo(&busy);
        assert_ne!(loaded.reward.to_bits(), reseeded.reward.to_bits());
    }

    #[test]
    fn traffic_crosses_fault_scenarios() {
        // Robust × traffic: each fault scenario sweeps every trace, so
        // the combined posture is never better than faults alone.
        let g = make_robust_env(RobustAggregate::Expected).pss.baseline_genome();
        let faults_only = make_robust_env(RobustAggregate::Expected).evaluate(&g).reward;
        let crossed_env = make_robust_env(RobustAggregate::Expected)
            .with_traffic_suite(TrafficSuite::generate("constant", 9, 2, 4).unwrap(), RobustAggregate::Expected);
        let crossed = crossed_env.evaluate(&g).reward;
        assert!(faults_only > 0.0 && crossed > 0.0);
        assert!(crossed <= faults_only, "traffic sped up faults: {crossed} > {faults_only}");
        assert_eq!(crossed_env.suite_evals(), 1);
        assert_eq!(crossed_env.traffic_evals(), 1);
        // Determinism across a fresh cross-joined environment.
        let again = make_robust_env(RobustAggregate::Expected)
            .with_traffic_suite(TrafficSuite::generate("constant", 9, 2, 4).unwrap(), RobustAggregate::Expected)
            .evaluate(&g)
            .reward;
        assert_eq!(crossed.to_bits(), again.to_bits());
    }

    #[test]
    fn traffic_runner_completes_and_exports_metrics() {
        let mut env = make_env(Objective::PerfPerBwPerNpu)
            .with_traffic_suite(TrafficSuite::generate("diurnal", 3, 1, 4).unwrap(), RobustAggregate::WorstCase);
        let cfg = DseConfig::new(AgentKind::Rw, 10, 5);
        let r = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
        assert_eq!(r.history.len(), 10);
        assert!(env.traffic_evals() > 0, "search never swept the traffic suite");
        let metrics = MetricsRegistry::new();
        env.export_metrics(&metrics);
        assert_eq!(metrics.counter("env.traffic_evals"), env.traffic_evals());
        assert_eq!(metrics.counter("env.traffic_traces"), 2);
    }

    #[test]
    fn bounded_eval_cache_env_matches_unbounded() {
        // Eviction must never change results — an evicted artifact is
        // simply regenerated on the next request.
        let unbounded = make_env(Objective::PerfPerBwPerNpu);
        let bounded = make_env(Objective::PerfPerBwPerNpu).with_eval_cache_capacity(2, 8);
        let space = unbounded.pss.build_space(SearchScope::FullStack);
        let mut rng = crate::util::Rng::seed_from_u64(17);
        let genomes: Vec<Vec<usize>> =
            (0..20).filter_map(|_| space.random_valid_genome(&mut rng, 500)).collect();
        assert!(genomes.len() > 5);
        for g in &genomes {
            assert_eq!(unbounded.evaluate_nomemo(g), bounded.evaluate_nomemo(g));
        }
    }
}
