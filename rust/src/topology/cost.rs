//! Alpha-beta link cost primitives shared by the collective cost model and
//! the discrete-event simulator.
//!
//! Every message transfer over one dimension is modelled as
//! `t = alpha + size / beta` where `alpha` is the per-hop latency of the
//! dimension and `beta` its per-link bandwidth. Switch dimensions add one
//! switch traversal (2 hops of latency); FullyConnected is a single direct
//! hop; Ring hops are counted by the collective algorithm itself.

use super::NetworkDim;

/// Time (microseconds) to push `bytes` over one link of `dim`.
///
/// Bandwidth is GB/s = bytes/microsecond × 1e3, so
/// `us = bytes / (bw_gbps * 1e3)`.
pub fn link_time_us(dim: &NetworkDim, bytes: f64) -> f64 {
    dim.latency_us + bytes / (dim.bandwidth_gbps * 1e3)
}

/// Per-dimension alpha/beta pair resolved from a [`NetworkDim`], with the
/// topology-kind hop adjustments baked in. This is what the collective
/// algorithms consume.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DimCost {
    /// Effective per-message latency (us) including switch traversal.
    pub alpha_us: f64,
    /// Link bandwidth in bytes per microsecond.
    pub beta_bytes_per_us: f64,
    /// NPUs along the dimension.
    pub npus: u64,
}

impl DimCost {
    pub fn from_dim(dim: &NetworkDim) -> Self {
        let hop_mult = match dim.kind {
            // Through a switch: NPU -> switch -> NPU = 2 latency hops.
            super::DimKind::Switch => 2.0,
            _ => 1.0,
        };
        Self {
            alpha_us: dim.latency_us * hop_mult,
            beta_bytes_per_us: dim.bandwidth_gbps * 1e3,
            npus: dim.npus,
        }
    }

    /// Serial transfer of `bytes` point-to-point along this dimension.
    pub fn xfer_us(&self, bytes: f64) -> f64 {
        self.alpha_us + bytes / self.beta_bytes_per_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DimKind, NetworkDim};

    #[test]
    fn link_time_has_alpha_and_beta_terms() {
        let d = NetworkDim::new(DimKind::Ring, 4, 100.0, 1.0);
        // 100 GB/s = 1e5 bytes/us; 1e5 bytes -> 1us transfer + 1us latency.
        let t = link_time_us(&d, 1e5);
        assert!((t - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_costs_alpha_only() {
        let d = NetworkDim::new(DimKind::Ring, 4, 100.0, 0.7);
        assert!((link_time_us(&d, 0.0) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn switch_doubles_alpha() {
        let ring = NetworkDim::new(DimKind::Ring, 8, 100.0, 1.0);
        let sw = NetworkDim::new(DimKind::Switch, 8, 100.0, 1.0);
        assert!((DimCost::from_dim(&ring).alpha_us - 1.0).abs() < 1e-12);
        assert!((DimCost::from_dim(&sw).alpha_us - 2.0).abs() < 1e-12);
    }

    #[test]
    fn dimcost_xfer_matches_link_time_for_nonswitch() {
        let d = NetworkDim::new(DimKind::FullyConnected, 8, 250.0, 0.3);
        let c = DimCost::from_dim(&d);
        assert!((c.xfer_us(5e4) - link_time_us(&d, 5e4)).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_monotonicity() {
        let slow = DimCost::from_dim(&NetworkDim::new(DimKind::Ring, 4, 50.0, 1.0));
        let fast = DimCost::from_dim(&NetworkDim::new(DimKind::Ring, 4, 500.0, 1.0));
        assert!(fast.xfer_us(1e6) < slow.xfer_us(1e6));
    }
}
