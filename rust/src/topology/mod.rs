//! Multi-dimensional network topology substrate (paper §2.3, Figure 3).
//!
//! COSMIC abstracts physical cluster fabrics with the multi-dimensional
//! network representation of ASTRA-sim 2.0: a stack of *dimensions*, each
//! one of three building blocks — **Ring (RI)**, **Switch (SW)**, or
//! **FullyConnected (FC)** — with per-dimension link bandwidth and latency.
//! A 3D torus is `[RI, RI, RI]`; a DGX-like pod is `[SW]` or `[FC, SW]`;
//! the paper's System 2 is `[RI, FC, RI, SW]`.
//!
//! NPUs are addressed hierarchically: NPU `i`'s coordinate along dimension
//! `d` is `(i / stride(d)) % npus(d)` where `stride(d)` is the product of
//! the sizes of all lower dimensions. Collectives along a dimension involve
//! the `npus(d)` peers that share all other coordinates.

mod cost;

pub use cost::{link_time_us, DimCost};

use std::fmt;

/// Network dimension building block (Figure 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DimKind {
    /// Ring: each NPU has two neighbours; bisection = 2 links.
    Ring,
    /// Switch: all NPUs connect to a central crossbar; full bisection
    /// through the switch, one switch hop of latency.
    Switch,
    /// FullyConnected: a dedicated link between every NPU pair.
    FullyConnected,
}

impl DimKind {
    /// Short name used in paper tables ("RI", "SW", "FC").
    pub fn short(&self) -> &'static str {
        match self {
            DimKind::Ring => "RI",
            DimKind::Switch => "SW",
            DimKind::FullyConnected => "FC",
        }
    }

    /// Parse the paper's short notation.
    pub fn from_short(s: &str) -> Option<Self> {
        match s.trim().to_ascii_uppercase().as_str() {
            "RI" | "RING" => Some(DimKind::Ring),
            "SW" | "SWITCH" => Some(DimKind::Switch),
            "FC" | "FULLYCONNECTED" => Some(DimKind::FullyConnected),
            _ => None,
        }
    }

    /// All building blocks, in the paper's canonical order.
    pub const ALL: [DimKind; 3] = [DimKind::Ring, DimKind::Switch, DimKind::FullyConnected];

    /// Number of unidirectional links per NPU this block requires along
    /// one dimension of `n` NPUs. Used by the LIBRA-style dollar-cost
    /// model (`dse::cost`).
    pub fn links_per_npu(&self, n: u64) -> u64 {
        match self {
            DimKind::Ring => {
                if n <= 1 {
                    0
                } else if n == 2 {
                    1
                } else {
                    2
                }
            }
            DimKind::Switch => 1,
            DimKind::FullyConnected => n.saturating_sub(1),
        }
    }
}

impl fmt::Display for DimKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.short())
    }
}

/// One dimension of a multi-dimensional network.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkDim {
    pub kind: DimKind,
    /// NPUs along this dimension (paper's "NPUs per Dim", {4, 8, 16}).
    pub npus: u64,
    /// Per-link bandwidth in GB/s (paper's "Bandwidth per Dim").
    pub bandwidth_gbps: f64,
    /// Per-hop link latency in microseconds.
    pub latency_us: f64,
}

impl NetworkDim {
    pub fn new(kind: DimKind, npus: u64, bandwidth_gbps: f64, latency_us: f64) -> Self {
        Self { kind, npus, bandwidth_gbps, latency_us }
    }
}

/// A full multi-dimensional topology: a stack of dimensions, innermost
/// (dimension 0, fastest/closest) first — matching the paper's
/// `[RI, RI, RI, SW]` notation where the leftmost entry is dim 0.
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub dims: Vec<NetworkDim>,
}

impl Topology {
    pub fn new(dims: Vec<NetworkDim>) -> Self {
        Self { dims }
    }

    /// Build from parallel arrays as the paper's tables give them.
    pub fn from_arrays(kinds: &[DimKind], npus: &[u64], bw_gbps: &[f64], latency_us: &[f64]) -> Self {
        assert_eq!(kinds.len(), npus.len());
        assert_eq!(kinds.len(), bw_gbps.len());
        assert_eq!(kinds.len(), latency_us.len());
        Self {
            dims: kinds
                .iter()
                .zip(npus)
                .zip(bw_gbps)
                .zip(latency_us)
                .map(|(((k, n), b), l)| NetworkDim::new(*k, *n, *b, *l))
                .collect(),
        }
    }

    /// Total NPUs = product of per-dimension sizes.
    pub fn total_npus(&self) -> u64 {
        self.dims.iter().map(|d| d.npus).product()
    }

    pub fn num_dims(&self) -> usize {
        self.dims.len()
    }

    /// Stride of dimension `d`: product of sizes of dimensions `< d`.
    pub fn stride(&self, d: usize) -> u64 {
        self.dims[..d].iter().map(|x| x.npus).product()
    }

    /// Coordinate of `npu` along dimension `d`.
    pub fn coord(&self, npu: u64, d: usize) -> u64 {
        (npu / self.stride(d)) % self.dims[d].npus
    }

    /// Full coordinate vector of `npu`.
    pub fn coords(&self, npu: u64) -> Vec<u64> {
        (0..self.dims.len()).map(|d| self.coord(npu, d)).collect()
    }

    /// NPU id from a coordinate vector (inverse of [`coords`]).
    pub fn npu_of(&self, coords: &[u64]) -> u64 {
        assert_eq!(coords.len(), self.dims.len());
        coords
            .iter()
            .enumerate()
            .map(|(d, c)| {
                assert!(*c < self.dims[d].npus, "coord out of range");
                c * self.stride(d)
            })
            .sum()
    }

    /// The peer group of `npu` along dimension `d`: all NPUs sharing every
    /// other coordinate. Sorted ascending; contains `npu` itself.
    pub fn dim_group(&self, npu: u64, d: usize) -> Vec<u64> {
        let stride = self.stride(d);
        let base = npu - self.coord(npu, d) * stride;
        (0..self.dims[d].npus).map(|c| base + c * stride).collect()
    }

    /// Aggregate injection bandwidth per NPU (GB/s): Σ over dims of
    /// links_per_npu × link bw. Used for the BW/NPU reward denominator.
    pub fn bw_per_npu(&self) -> f64 {
        self.dims
            .iter()
            .map(|d| d.kind.links_per_npu(d.npus) as f64 * d.bandwidth_gbps)
            .sum()
    }

    /// Sum of per-dimension link bandwidths — the paper's
    /// `Σ (BW per Dim)` reward term (Table 4 allocates one bw value per
    /// dim, so the sum is over dims, not links).
    pub fn sum_bw_per_dim(&self) -> f64 {
        self.dims.iter().map(|d| d.bandwidth_gbps).sum()
    }

    /// A stable structural fingerprint (kinds, sizes, bandwidths,
    /// latencies). Two topologies with equal fingerprints resolve every
    /// communicator span to the same [`DimCost`]s — the topology half of
    /// the cross-evaluation collective-cost cache key.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::Hash;
        crate::util::hash64(|h| {
            self.dims.len().hash(h);
            for d in &self.dims {
                (d.kind as u8, d.npus, d.bandwidth_gbps.to_bits(), d.latency_us.to_bits())
                    .hash(h);
            }
        })
    }

    /// Paper-style notation, e.g. `[RI, FC, RI, SW]`.
    pub fn notation(&self) -> String {
        let inner: Vec<&str> = self.dims.iter().map(|d| d.kind.short()).collect();
        format!("[{}]", inner.join(", "))
    }

    /// Sanity-check structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.dims.is_empty() {
            return Err("topology must have at least one dimension".into());
        }
        for (i, d) in self.dims.iter().enumerate() {
            if d.npus < 2 {
                return Err(format!("dim {i}: npus must be >= 2, got {}", d.npus));
            }
            if d.bandwidth_gbps <= 0.0 {
                return Err(format!("dim {i}: bandwidth must be > 0"));
            }
            if d.latency_us < 0.0 {
                return Err(format!("dim {i}: latency must be >= 0"));
            }
        }
        Ok(())
    }
}

impl fmt::Display for Topology {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} ({} NPUs; bw {:?} GB/s)",
            self.notation(),
            self.total_npus(),
            self.dims.iter().map(|d| d.bandwidth_gbps).collect::<Vec<_>>()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn torus3d() -> Topology {
        Topology::from_arrays(
            &[DimKind::Ring, DimKind::Ring, DimKind::Ring],
            &[4, 4, 4],
            &[200.0, 100.0, 50.0],
            &[0.5, 1.0, 2.0],
        )
    }

    #[test]
    fn total_npus_is_product() {
        assert_eq!(torus3d().total_npus(), 64);
    }

    #[test]
    fn strides_are_cumulative_products() {
        let t = torus3d();
        assert_eq!(t.stride(0), 1);
        assert_eq!(t.stride(1), 4);
        assert_eq!(t.stride(2), 16);
    }

    #[test]
    fn coords_roundtrip() {
        let t = torus3d();
        for npu in 0..t.total_npus() {
            let c = t.coords(npu);
            assert_eq!(t.npu_of(&c), npu);
        }
    }

    #[test]
    fn dim_group_contains_self_and_is_sorted() {
        let t = torus3d();
        for npu in [0u64, 17, 63] {
            for d in 0..3 {
                let g = t.dim_group(npu, d);
                assert_eq!(g.len(), 4);
                assert!(g.contains(&npu));
                assert!(g.windows(2).all(|w| w[0] < w[1]));
            }
        }
    }

    #[test]
    fn dim_group_members_share_other_coords() {
        let t = torus3d();
        let g = t.dim_group(37, 1);
        for m in g {
            assert_eq!(t.coord(m, 0), t.coord(37, 0));
            assert_eq!(t.coord(m, 2), t.coord(37, 2));
        }
    }

    #[test]
    fn links_per_npu_by_kind() {
        assert_eq!(DimKind::Ring.links_per_npu(4), 2);
        assert_eq!(DimKind::Ring.links_per_npu(2), 1);
        assert_eq!(DimKind::Switch.links_per_npu(16), 1);
        assert_eq!(DimKind::FullyConnected.links_per_npu(8), 7);
    }

    #[test]
    fn notation_matches_paper_style() {
        let t = Topology::from_arrays(
            &[DimKind::Ring, DimKind::FullyConnected, DimKind::Ring, DimKind::Switch],
            &[4, 8, 4, 8],
            &[375.0, 175.0, 150.0, 100.0],
            &[0.5; 4],
        );
        assert_eq!(t.notation(), "[RI, FC, RI, SW]");
        assert_eq!(t.total_npus(), 1024);
    }

    #[test]
    fn validate_rejects_degenerate() {
        let mut t = torus3d();
        t.dims[0].npus = 1;
        assert!(t.validate().is_err());
        let mut t = torus3d();
        t.dims[1].bandwidth_gbps = 0.0;
        assert!(t.validate().is_err());
        assert!(torus3d().validate().is_ok());
        assert!(Topology::new(vec![]).validate().is_err());
    }

    #[test]
    fn short_roundtrip() {
        for k in DimKind::ALL {
            assert_eq!(DimKind::from_short(k.short()), Some(k));
        }
        assert_eq!(DimKind::from_short("bogus"), None);
    }

    #[test]
    fn bw_per_npu_sums_links() {
        let t = torus3d();
        // Ring of 4 => 2 links/NPU each dim.
        assert!((t.bw_per_npu() - (2.0 * 200.0 + 2.0 * 100.0 + 2.0 * 50.0)).abs() < 1e-9);
        assert!((t.sum_bw_per_dim() - 350.0).abs() < 1e-9);
    }
}
