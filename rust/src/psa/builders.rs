//! The paper's PsA schemas (Tables 1 and 4) as ready-made builders.

use super::{Constraint, Domain, ParamDef, Schema, Stack};

/// Canonical parameter names used throughout the crate (the PSS resolves
/// design points into simulator inputs by these names).
pub mod names {
    pub const DP: &str = "DP";
    pub const PP: &str = "PP";
    pub const SP: &str = "SP";
    pub const WEIGHT_SHARDED: &str = "Weight Sharded";
    pub const SCHED_POLICY: &str = "Scheduling Policy";
    pub const COLL_ALGO: &str = "Collective Algorithm";
    pub const CHUNKS: &str = "Chunks per Collective";
    pub const MULTIDIM_COLL: &str = "Multi-dim Collective";
    pub const TOPOLOGY: &str = "Topology";
    pub const NPUS_PER_DIM: &str = "NPUs per Dim";
    pub const BW_PER_DIM: &str = "Bandwidth per Dim";
    /// The netsim fidelity knob (optional; see [`super::with_fidelity_param`]).
    pub const NET_FIDELITY: &str = "Network Fidelity";
    /// The resilience checkpoint-interval knob, in iterations between
    /// checkpoints (optional; see [`super::with_checkpoint_param`]).
    pub const CKPT_INTERVAL: &str = "Checkpoint Interval";
    /// The multi-tenant traffic-profile knob (optional; see
    /// [`super::with_traffic_param`]).
    pub const TRAFFIC_PROFILE: &str = "Traffic Profile";
    /// The flow-level chunk-precedence knob (optional; see
    /// [`super::with_chunk_precedence_param`]).
    pub const CHUNK_PRECEDENCE: &str = "Chunk Precedence";
}

/// Append the netsim "Network Fidelity" knob ({Analytical, FlowLevel,
/// Packet}) to any schema. The paper's Table 1/4 schemas ship without
/// it (their cardinalities are asserted against the paper); opting in
/// widens every agent's action space by one slot and lets the search
/// trade simulation cost for congestion awareness — the PSS resolves
/// the knob to the matching [`crate::netsim::NetworkBackend`] at
/// evaluation time.
pub fn with_fidelity_param(mut schema: Schema) -> Schema {
    schema.params.push(ParamDef::scalar(
        names::NET_FIDELITY,
        Stack::Network,
        Domain::cats(&["Analytical", "FlowLevel", "Packet"]),
    ));
    schema
}

/// Append the resilience "Checkpoint Interval" knob (iterations between
/// checkpoints, powers of two) to any schema. Like the fidelity knob it
/// is opt-in — the paper's Table 1/4 schemas ship without it. Under a
/// fault suite (`cosmic search --robust`, or
/// `Environment::with_scenarios`) the PSS resolves the knob into the
/// goodput model: short intervals burn time writing checkpoints, long
/// ones lose more work per failure, and the Young/Daly optimum depends
/// on the scenario's MTBF — so the best setting co-varies with every
/// other stack and is worth searching.
pub fn with_checkpoint_param(mut schema: Schema) -> Schema {
    schema.params.push(ParamDef::scalar(
        names::CKPT_INTERVAL,
        Stack::Workload,
        Domain::Ints(vec![8, 16, 32, 64, 128, 256, 512, 1024]),
    ));
    schema
}

/// Append the multi-tenant "Traffic Profile" knob ({None, Constant,
/// Diurnal, Bursty}) to any schema. Opt-in like the fidelity and
/// checkpoint knobs — the paper's Table 1/4 schemas ship without it.
/// The PSS resolves the profile (with the environment's traffic seed)
/// into a [`crate::netsim::TrafficTrace`] at evaluation time, letting
/// the search compare design points under the co-tenant contention
/// pattern they would actually face.
pub fn with_traffic_param(mut schema: Schema) -> Schema {
    schema.params.push(ParamDef::scalar(
        names::TRAFFIC_PROFILE,
        Stack::Network,
        Domain::cats(&["None", "Constant", "Diurnal", "Bursty"]),
    ));
    schema
}

/// Append the flow-level "Chunk Precedence" knob ({Off, On}) to any
/// schema. Opt-in like the other netsim knobs. When a design point's
/// fidelity resolves to the flow rung, "On" swaps the overlap drain's
/// steady-state chunk tail for the per-(job, dim) chunk FIFO precedence
/// model ([`crate::netsim::FlowLevelConfig::with_chunk_precedence`]) —
/// sharper multi-collective overlap at a modest event-count cost. The
/// analytical and packet rungs ignore the knob (the packet rung already
/// serializes at packet granularity).
pub fn with_chunk_precedence_param(mut schema: Schema) -> Schema {
    schema.params.push(ParamDef::scalar(
        names::CHUNK_PRECEDENCE,
        Stack::Network,
        Domain::cats(&["Off", "On"]),
    ));
    schema
}

/// Table 1's schema: the motivation-section design space for a 4D network
/// with 1,024 NPUs (`7.69e13` raw points).
pub fn paper_table1_schema(npus: u64, dims: usize) -> Schema {
    let max = npus as i64;
    Schema::new(
        vec![
            ParamDef::scalar(names::DP, Stack::Workload, Domain::pow2(1, max)),
            ParamDef::scalar(names::PP, Stack::Workload, Domain::pow2(1, max)),
            ParamDef::scalar(names::SP, Stack::Workload, Domain::pow2(1, max)),
            ParamDef::scalar(names::WEIGHT_SHARDED, Stack::Workload, Domain::Bool),
            ParamDef::scalar(
                names::SCHED_POLICY,
                Stack::Collective,
                Domain::cats(&["LIFO", "FIFO"]),
            ),
            ParamDef::multidim(
                names::COLL_ALGO,
                Stack::Collective,
                Domain::cats(&["Ring", "Direct", "RHD", "DBT"]),
                dims,
            ),
            ParamDef::scalar(
                names::CHUNKS,
                Stack::Collective,
                Domain::Ints((1..=32).collect()),
            ),
            ParamDef::scalar(
                names::MULTIDIM_COLL,
                Stack::Collective,
                Domain::cats(&["Baseline", "BlueConnect"]),
            ),
            ParamDef::multidim(
                names::TOPOLOGY,
                Stack::Network,
                Domain::cats(&["Ring", "Switch", "FC"]),
                dims,
            ),
            ParamDef::multidim(
                names::NPUS_PER_DIM,
                Stack::Network,
                Domain::Ints(vec![4, 8, 16]),
                dims,
            ),
            ParamDef::multidim(
                names::BW_PER_DIM,
                Stack::Network,
                Domain::Ints(vec![100, 200, 300, 400, 500]),
                dims,
            ),
        ],
        vec![
            Constraint::ProductDividesLimit {
                params: vec![names::DP.into(), names::SP.into(), names::PP.into()],
                limit: npus,
            },
            Constraint::MultiProductEq { param: names::NPUS_PER_DIM.into(), limit: npus },
        ],
    )
}

/// Table 4's schema: the evaluation PsA. Differences vs Table 1: DP/SP
/// range to 2048, PP restricted to {1,2,4}, chunks to {2,4,8,16}, and
/// bandwidth steps of 50 from 50..=500.
pub fn paper_table4_schema(npus: u64, dims: usize) -> Schema {
    Schema::new(
        vec![
            ParamDef::scalar(names::DP, Stack::Workload, Domain::pow2(1, 2048)),
            ParamDef::scalar(names::PP, Stack::Workload, Domain::Ints(vec![1, 2, 4])),
            ParamDef::scalar(names::SP, Stack::Workload, Domain::pow2(1, 2048)),
            ParamDef::scalar(names::WEIGHT_SHARDED, Stack::Workload, Domain::Bool),
            ParamDef::scalar(
                names::SCHED_POLICY,
                Stack::Collective,
                Domain::cats(&["LIFO", "FIFO"]),
            ),
            ParamDef::multidim(
                names::COLL_ALGO,
                Stack::Collective,
                Domain::cats(&["Ring", "Direct", "RHD", "DBT"]),
                dims,
            ),
            ParamDef::scalar(names::CHUNKS, Stack::Collective, Domain::Ints(vec![2, 4, 8, 16])),
            ParamDef::scalar(
                names::MULTIDIM_COLL,
                Stack::Collective,
                Domain::cats(&["Baseline", "BlueConnect"]),
            ),
            ParamDef::multidim(
                names::TOPOLOGY,
                Stack::Network,
                Domain::cats(&["Ring", "Switch", "FC"]),
                dims,
            ),
            ParamDef::multidim(
                names::NPUS_PER_DIM,
                Stack::Network,
                Domain::Ints(vec![4, 8, 16]),
                dims,
            ),
            ParamDef::multidim(
                names::BW_PER_DIM,
                Stack::Network,
                Domain::Ints((1..=10).map(|k| k * 50).collect()),
                dims,
            ),
        ],
        vec![
            Constraint::ProductDividesLimit {
                params: vec![names::DP.into(), names::SP.into(), names::PP.into()],
                limit: npus,
            },
            Constraint::MultiProductEq { param: names::NPUS_PER_DIM.into(), limit: npus },
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_schema_has_all_knobs() {
        let s = paper_table1_schema(1024, 4);
        for n in [
            names::DP,
            names::PP,
            names::SP,
            names::WEIGHT_SHARDED,
            names::SCHED_POLICY,
            names::COLL_ALGO,
            names::CHUNKS,
            names::MULTIDIM_COLL,
            names::TOPOLOGY,
            names::NPUS_PER_DIM,
            names::BW_PER_DIM,
        ] {
            assert!(s.param(n).is_some(), "missing {n}");
        }
        // 4 scalar workload + 1 + 4 + 1 + 1 + 4 + 4 + 4 slots
        assert_eq!(s.genome_len(), 4 + 1 + 4 + 1 + 1 + 4 + 4 + 4);
    }

    #[test]
    fn table1_cardinalities_match_paper() {
        let s = paper_table1_schema(1024, 4);
        assert_eq!(s.param(names::COLL_ALGO).unwrap().cardinality(), 256.0); // 4^4
        assert_eq!(s.param(names::TOPOLOGY).unwrap().cardinality(), 81.0); // 3^4
        assert_eq!(s.param(names::NPUS_PER_DIM).unwrap().cardinality(), 81.0);
        assert_eq!(s.param(names::BW_PER_DIM).unwrap().cardinality(), 625.0); // 5^4
        assert_eq!(s.param(names::CHUNKS).unwrap().cardinality(), 32.0);
    }

    #[test]
    fn table4_restrictions() {
        let s = paper_table4_schema(1024, 4);
        assert_eq!(s.param(names::PP).unwrap().domain, Domain::Ints(vec![1, 2, 4]));
        assert_eq!(s.param(names::CHUNKS).unwrap().domain, Domain::Ints(vec![2, 4, 8, 16]));
        assert_eq!(s.param(names::BW_PER_DIM).unwrap().domain.cardinality(), 10);
        assert_eq!(s.param(names::DP).unwrap().domain.cardinality(), 12); // 1..2048
    }

    #[test]
    fn constraints_present() {
        let s = paper_table4_schema(1024, 4);
        assert_eq!(s.constraints.len(), 2);
    }

    #[test]
    fn fidelity_param_appends_one_network_slot() {
        let base = paper_table4_schema(1024, 4);
        let with = with_fidelity_param(paper_table4_schema(1024, 4));
        assert_eq!(with.genome_len(), base.genome_len() + 1);
        let p = with.param(names::NET_FIDELITY).expect("fidelity knob present");
        assert_eq!(p.stack, Stack::Network);
        assert_eq!(p.domain.cardinality(), 3);
        // The paper schemas stay untouched.
        assert!(base.param(names::NET_FIDELITY).is_none());
    }

    #[test]
    fn checkpoint_param_appends_one_workload_slot() {
        let base = paper_table4_schema(1024, 4);
        let with = with_checkpoint_param(paper_table4_schema(1024, 4));
        assert_eq!(with.genome_len(), base.genome_len() + 1);
        let p = with.param(names::CKPT_INTERVAL).expect("checkpoint knob present");
        assert_eq!(p.stack, Stack::Workload);
        assert_eq!(p.domain.cardinality(), 8);
        assert!(base.param(names::CKPT_INTERVAL).is_none());
        // Knobs compose: fidelity + checkpoint together.
        let both = with_checkpoint_param(with_fidelity_param(paper_table4_schema(1024, 4)));
        assert_eq!(both.genome_len(), base.genome_len() + 2);
    }

    #[test]
    fn traffic_param_appends_one_network_slot() {
        let base = paper_table4_schema(1024, 4);
        let with = with_traffic_param(paper_table4_schema(1024, 4));
        assert_eq!(with.genome_len(), base.genome_len() + 1);
        let p = with.param(names::TRAFFIC_PROFILE).expect("traffic knob present");
        assert_eq!(p.stack, Stack::Network);
        assert_eq!(p.domain.cardinality(), 4);
        assert!(base.param(names::TRAFFIC_PROFILE).is_none());
        // All three opt-in knobs compose.
        let all = with_traffic_param(with_checkpoint_param(with_fidelity_param(
            paper_table4_schema(1024, 4),
        )));
        assert_eq!(all.genome_len(), base.genome_len() + 3);
    }

    #[test]
    fn chunk_precedence_param_appends_one_network_slot() {
        let base = paper_table4_schema(1024, 4);
        let with = with_chunk_precedence_param(paper_table4_schema(1024, 4));
        assert_eq!(with.genome_len(), base.genome_len() + 1);
        let p = with.param(names::CHUNK_PRECEDENCE).expect("chunk-precedence knob present");
        assert_eq!(p.stack, Stack::Network);
        assert_eq!(p.domain.cardinality(), 2);
        assert!(base.param(names::CHUNK_PRECEDENCE).is_none());
        // Composes with the other opt-in netsim knobs.
        let both = with_chunk_precedence_param(with_fidelity_param(paper_table4_schema(1024, 4)));
        assert_eq!(both.genome_len(), base.genome_len() + 2);
    }
}
