//! Design-space cardinality accounting (paper §3.2, Table 1) and
//! constraint-aware random sampling helpers shared by the agents.

use super::builders::names;
use super::{Constraint, Domain, Schema};
use crate::util::Rng;
use crate::workload::enumerate_parallelizations;

/// A schema constraint compiled to raw genome-slot lookups, so validity
/// probes skip building a `DesignPoint` (string-keyed map) entirely —
/// the agents' rejection loops call this thousands of times per second
/// (EXPERIMENTS.md §Perf iteration 3).
#[derive(Debug, Clone)]
enum FastConstraint {
    /// product over (slot, value-table) pairs divides `limit`.
    ProductDividesLimit { slots: Vec<(usize, Vec<i64>)>, limit: u64 },
    /// product over the multi-dim param's slots equals `limit`.
    MultiProductEq { slots: Vec<(usize, Vec<i64>)>, limit: u64 },
}

impl FastConstraint {
    fn compile(schema: &Schema) -> Vec<FastConstraint> {
        let slots = schema.slots();
        let slot_of = |name: &str, dim: usize| -> Option<(usize, Vec<i64>)> {
            for (i, s) in slots.iter().enumerate() {
                let p = &schema.params[s.param];
                if p.name == name && s.dim == dim {
                    if let Domain::Ints(v) = &p.domain {
                        return Some((i, v.clone()));
                    }
                }
            }
            None
        };
        schema
            .constraints
            .iter()
            .filter_map(|c| match c {
                Constraint::ProductDividesLimit { params, limit } => {
                    let slots: Option<Vec<_>> =
                        params.iter().map(|n| slot_of(n, 0)).collect();
                    slots.map(|slots| FastConstraint::ProductDividesLimit {
                        slots,
                        limit: *limit,
                    })
                }
                Constraint::MultiProductEq { param, limit } => {
                    let p = schema.param(param)?;
                    let slots: Option<Vec<_>> =
                        (0..p.dims).map(|d| slot_of(param, d)).collect();
                    slots.map(|slots| FastConstraint::MultiProductEq { slots, limit: *limit })
                }
            })
            .collect()
    }

    fn holds(&self, genome: &[usize]) -> bool {
        match self {
            FastConstraint::ProductDividesLimit { slots, limit } => {
                let mut product: u64 = 1;
                for (slot, values) in slots {
                    product = product.saturating_mul(values[genome[*slot]].max(1) as u64);
                }
                product <= *limit && limit % product == 0
            }
            FastConstraint::MultiProductEq { slots, limit } => {
                let mut product: u64 = 1;
                for (slot, values) in slots {
                    product = product.saturating_mul(values[genome[*slot]].max(1) as u64);
                }
                product == *limit
            }
        }
    }
}

/// A schema plus its genome layout, with sampling utilities. Agents hold
/// one of these (built for them by the PSS).
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub schema: Schema,
    /// Per-slot cardinalities (cached).
    pub slot_cards: Vec<usize>,
    /// Slots the current search scope may mutate; the rest are frozen to
    /// the baseline genome (single-stack search, §6.1).
    pub free_slots: Vec<usize>,
    /// Baseline genome supplying values for frozen slots.
    pub baseline: Vec<usize>,
    /// Constraints compiled to raw-slot form (perf fast path).
    fast_constraints: Vec<FastConstraint>,
}

impl DesignSpace {
    pub fn new(schema: Schema, free_slots: Vec<usize>, baseline: Vec<usize>) -> Self {
        let slot_cards = schema.slots().iter().map(|s| s.cardinality).collect();
        assert_eq!(baseline.len(), schema.genome_len());
        let fast_constraints = FastConstraint::compile(&schema);
        Self { schema, slot_cards, free_slots, baseline, fast_constraints }
    }

    /// All slots free.
    pub fn unconstrained(schema: Schema, baseline: Vec<usize>) -> Self {
        let n = schema.genome_len();
        Self::new(schema, (0..n).collect(), baseline)
    }

    /// Uniform random genome over the free slots (frozen slots keep the
    /// baseline value). Does not constraint-check.
    pub fn random_genome(&self, rng: &mut Rng) -> Vec<usize> {
        let mut g = self.baseline.clone();
        for &s in &self.free_slots {
            g[s] = rng.gen_range(self.slot_cards[s]);
        }
        g
    }

    /// Random *valid* genome: rejection-sample until the constraints hold
    /// (bounded attempts — the paper's constraints keep acceptance high
    /// because NPUs-per-dim products over {4,8,16} hit the target often).
    pub fn random_valid_genome(&self, rng: &mut Rng, max_tries: usize) -> Option<Vec<usize>> {
        for _ in 0..max_tries {
            let g = self.random_genome(rng);
            if self.is_valid(&g) {
                return Some(g);
            }
        }
        None
    }

    /// Mutate one free slot of `genome` to a random different value.
    pub fn mutate_one(&self, genome: &[usize], rng: &mut Rng) -> Vec<usize> {
        let mut g = genome.to_vec();
        if self.free_slots.is_empty() {
            return g;
        }
        let s = self.free_slots[rng.gen_range(self.free_slots.len())];
        let card = self.slot_cards[s];
        if card > 1 {
            let mut v = rng.gen_range(card);
            while v == g[s] {
                v = rng.gen_range(card);
            }
            g[s] = v;
        }
        g
    }

    /// Is the genome valid under the schema constraints? Uses the
    /// compiled raw-slot fast path (no `DesignPoint` allocation); the
    /// result is identical to `schema.decode_valid(genome).is_ok()` —
    /// see the `fast_path_matches_decode_valid` test.
    pub fn is_valid(&self, genome: &[usize]) -> bool {
        if genome.len() != self.slot_cards.len() {
            return false;
        }
        for (g, card) in genome.iter().zip(&self.slot_cards) {
            if g >= card {
                return false;
            }
        }
        self.fast_constraints.iter().all(|c| c.holds(genome))
    }

    /// Raw (unconstrained) cardinality of the free subspace.
    pub fn free_cardinality(&self) -> f64 {
        self.free_slots.iter().map(|&s| self.slot_cards[s] as f64).product()
    }
}

/// The paper's Table 1 accounting: the workload triple is counted
/// *constrained* (286 valid (DP,SP,PP) combos for 1,024 NPUs), everything
/// else raw. Reproduces `7.69e13` for the Table 1 schema.
pub fn design_space_size(schema: &Schema, npus: u64) -> f64 {
    let pp_cap = match &schema.param(names::PP).map(|p| &p.domain) {
        Some(Domain::Ints(v)) => *v.iter().max().unwrap_or(&1) as u64,
        _ => npus,
    };
    let workload_combos = enumerate_parallelizations(npus, pp_cap, &[false]).len() as f64;
    let mut total = workload_combos;
    for p in &schema.params {
        match p.name.as_str() {
            names::DP | names::PP | names::SP => {} // folded into combos
            _ => total *= p.cardinality(),
        }
    }
    total
}

/// Exhaustive-search time estimate (paper: "2.44e6 years at 1 s/point").
pub fn exhaustive_search_years(points: f64, secs_per_point: f64) -> f64 {
    points * secs_per_point / (3600.0 * 24.0 * 365.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table1_schema;

    #[test]
    fn table1_total_matches_paper_769e13() {
        let s = paper_table1_schema(1024, 4);
        let n = design_space_size(&s, 1024);
        // Paper: ~7.69e13. 286 * 2 * 2 * 256 * 32 * 2 * 81 * 81 * 625.
        let expect = 286.0 * 2.0 * 2.0 * 256.0 * 32.0 * 2.0 * 81.0 * 81.0 * 625.0;
        assert!((n - expect).abs() / expect < 1e-12, "n={n:.4e}");
        assert!(n > 7.6e13 && n < 7.8e13, "n={n:.4e}");
    }

    #[test]
    fn exhaustive_years_matches_paper() {
        let s = paper_table1_schema(1024, 4);
        let years = exhaustive_search_years(design_space_size(&s, 1024), 1.0);
        assert!(years > 2.3e6 && years < 2.5e6, "years={years:.3e}");
    }

    fn space() -> DesignSpace {
        let schema = paper_table1_schema(64, 2);
        let baseline = vec![0; schema.genome_len()];
        // Fix baseline to a valid NPUs-per-dim: need product = 64 -> [4,16]
        let mut b = baseline;
        // find NPUs per Dim slots: params order — index them via stack_slots
        let slots = schema.slots();
        let mut npu_slots = vec![];
        for (i, s) in slots.iter().enumerate() {
            if schema.params[s.param].name == names::NPUS_PER_DIM {
                npu_slots.push(i);
            }
        }
        b[npu_slots[0]] = 0; // 4
        b[npu_slots[1]] = 2; // 16
        DesignSpace::unconstrained(schema, b)
    }

    #[test]
    fn random_valid_genome_respects_constraints() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(7);
        let g = sp.random_valid_genome(&mut rng, 10_000).expect("should find valid");
        assert!(sp.is_valid(&g));
    }

    #[test]
    fn mutate_changes_exactly_one_slot() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(3);
        let g = sp.baseline.clone();
        let m = sp.mutate_one(&g, &mut rng);
        let diff = g.iter().zip(&m).filter(|(a, b)| a != b).count();
        assert_eq!(diff, 1);
    }

    #[test]
    fn frozen_slots_stay_at_baseline() {
        let schema = paper_table1_schema(64, 2);
        let n = schema.genome_len();
        let baseline = vec![0; n];
        let free = vec![0, 1]; // only DP, PP free
        let sp = DesignSpace::new(schema, free.clone(), baseline.clone());
        let mut rng = Rng::seed_from_u64(11);
        for _ in 0..20 {
            let g = sp.random_genome(&mut rng);
            for i in 0..n {
                if !free.contains(&i) {
                    assert_eq!(g[i], baseline[i], "slot {i} moved");
                }
            }
        }
    }

    #[test]
    fn fast_path_matches_decode_valid() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(99);
        for _ in 0..2000 {
            let g = sp.random_genome(&mut rng);
            assert_eq!(
                sp.is_valid(&g),
                sp.schema.decode_valid(&g).is_ok(),
                "fast path diverged on {g:?}"
            );
        }
    }

    #[test]
    fn free_cardinality_products_free_slots() {
        let schema = paper_table1_schema(64, 2);
        let n = schema.genome_len();
        let sp = DesignSpace::new(schema.clone(), vec![0], vec![0; n]);
        // slot 0 is DP with pow2(1,64) = 7 values
        assert_eq!(sp.free_cardinality(), 7.0);
    }
}
