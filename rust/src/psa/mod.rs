//! Parameter Set Architecture (PsA) — paper §4.
//!
//! The PsA is the paper's central abstraction: *"analogous to how an ISA
//! defines the interface between software and hardware, the PsA defines
//! the interaction between search agents and the underlying system"*. It
//! is a schema with three components (§4.2):
//!
//! - **Parameter Set** — the searchable parameters, spanning the
//!   workload, collective, network (and compute) stacks;
//! - **Value Range** — the valid values of each parameter;
//! - **Constraints** — cross-parameter dependencies (e.g.
//!   `product(DP,SP,PP) ≤ NPUs`, `product(NPUs-per-dim) = NPUs`).
//!
//! Agents never see domain objects: they see a fixed-length integer
//! *genome* (one index per parameter slot). [`Schema::decode`] maps a
//! genome to a [`DesignPoint`]; the PSS (`crate::pss`) maps design points
//! to simulator inputs. This is exactly the decoupling the paper claims:
//! adding a parameter to the schema automatically widens every agent's
//! action space without touching agent code.

pub mod builders;
pub mod space;

pub use builders::{
    paper_table1_schema, paper_table4_schema, with_checkpoint_param, with_chunk_precedence_param,
    with_fidelity_param, with_traffic_param,
};
pub use space::{design_space_size, DesignSpace};

use std::collections::HashMap;
use std::fmt;

/// Which design stack a parameter belongs to (paper Figure 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stack {
    Workload,
    Collective,
    Network,
    Compute,
}

impl Stack {
    pub const ALL: [Stack; 4] = [Stack::Workload, Stack::Collective, Stack::Network, Stack::Compute];

    pub fn name(&self) -> &'static str {
        match self {
            Stack::Workload => "workload",
            Stack::Collective => "collective",
            Stack::Network => "network",
            Stack::Compute => "compute",
        }
    }
}

impl fmt::Display for Stack {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// The value domain of one parameter (the schema's "Value Range").
#[derive(Debug, Clone, PartialEq)]
pub enum Domain {
    /// An ordered list of integers (e.g. `{1, 2, 4, …, 1024}`).
    Ints(Vec<i64>),
    /// Categorical labels (e.g. `{LIFO, FIFO}` or `{Ring, Direct, …}`).
    Cats(Vec<String>),
    /// Boolean flag.
    Bool,
}

impl Domain {
    pub fn cats<S: AsRef<str>>(labels: &[S]) -> Self {
        Domain::Cats(labels.iter().map(|s| s.as_ref().to_string()).collect())
    }

    /// Powers of two from `lo` to `hi` inclusive.
    pub fn pow2(lo: i64, hi: i64) -> Self {
        let mut v = Vec::new();
        let mut x = lo.max(1);
        while x <= hi {
            v.push(x);
            x *= 2;
        }
        Domain::Ints(v)
    }

    /// Number of admissible values.
    pub fn cardinality(&self) -> usize {
        match self {
            Domain::Ints(v) => v.len(),
            Domain::Cats(v) => v.len(),
            Domain::Bool => 2,
        }
    }
}

/// A parameter definition: name, stack, domain, and multiplicity
/// (`dims > 1` is the paper's "MultiDim" parameters — one slot per
/// network dimension).
#[derive(Debug, Clone, PartialEq)]
pub struct ParamDef {
    pub name: String,
    pub stack: Stack,
    pub domain: Domain,
    pub dims: usize,
}

impl ParamDef {
    pub fn scalar(name: &str, stack: Stack, domain: Domain) -> Self {
        Self { name: name.to_string(), stack, domain, dims: 1 }
    }

    pub fn multidim(name: &str, stack: Stack, domain: Domain, dims: usize) -> Self {
        assert!(dims >= 1);
        Self { name: name.to_string(), stack, domain, dims }
    }

    /// Total raw configurations this parameter contributes.
    pub fn cardinality(&self) -> f64 {
        (self.domain.cardinality() as f64).powi(self.dims as i32)
    }
}

/// A concrete value assignment for one parameter.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Int(i64),
    /// Categorical choice as (index, label).
    Cat(usize, String),
    Bool(bool),
    MultiInt(Vec<i64>),
    MultiCat(Vec<usize>),
}

impl ParamValue {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            ParamValue::Int(v) => Some(*v),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            ParamValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_cat(&self) -> Option<usize> {
        match self {
            ParamValue::Cat(i, _) => Some(*i),
            _ => None,
        }
    }
    pub fn as_multi_int(&self) -> Option<&[i64]> {
        match self {
            ParamValue::MultiInt(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_multi_cat(&self) -> Option<&[usize]> {
        match self {
            ParamValue::MultiCat(v) => Some(v),
            _ => None,
        }
    }
}

/// Cross-parameter constraints (the schema's third component).
#[derive(Debug, Clone, PartialEq)]
pub enum Constraint {
    /// `product(params…) ≤ limit` *and* the product divides `limit`
    /// (the paper's `product(DP, SP, PP) ≤ NPUs`; divisibility is implied
    /// by the residual-TP derivation).
    ProductDividesLimit { params: Vec<String>, limit: u64 },
    /// The product over a MultiInt parameter's entries equals `limit`
    /// (the paper's `product(NPUs per Dim) = NPUs`).
    MultiProductEq { param: String, limit: u64 },
}

/// A decoded design point: named parameter values.
#[derive(Debug, Clone, PartialEq)]
pub struct DesignPoint {
    pub values: HashMap<String, ParamValue>,
}

impl DesignPoint {
    pub fn get(&self, name: &str) -> Option<&ParamValue> {
        self.values.get(name)
    }

    pub fn int(&self, name: &str) -> i64 {
        self.values.get(name).and_then(|v| v.as_int()).unwrap_or_else(|| {
            panic!("design point missing int param '{name}'")
        })
    }

    pub fn boolean(&self, name: &str) -> bool {
        self.values
            .get(name)
            .and_then(|v| v.as_bool())
            .unwrap_or_else(|| panic!("design point missing bool param '{name}'"))
    }

    pub fn cat(&self, name: &str) -> usize {
        self.values
            .get(name)
            .and_then(|v| v.as_cat())
            .unwrap_or_else(|| panic!("design point missing cat param '{name}'"))
    }

    pub fn multi_int(&self, name: &str) -> &[i64] {
        self.values
            .get(name)
            .and_then(|v| v.as_multi_int())
            .unwrap_or_else(|| panic!("design point missing multi-int param '{name}'"))
    }

    pub fn multi_cat(&self, name: &str) -> &[usize] {
        self.values
            .get(name)
            .and_then(|v| v.as_multi_cat())
            .unwrap_or_else(|| panic!("design point missing multi-cat param '{name}'"))
    }
}

/// The PsA schema: parameters + constraints, with genome encode/decode.
#[derive(Debug, Clone, PartialEq)]
pub struct Schema {
    pub params: Vec<ParamDef>,
    pub constraints: Vec<Constraint>,
}

/// One genome slot: which parameter and which of its dims it indexes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slot {
    pub param: usize,
    pub dim: usize,
    pub cardinality: usize,
}

impl Schema {
    pub fn new(params: Vec<ParamDef>, constraints: Vec<Constraint>) -> Self {
        Self { params, constraints }
    }

    pub fn param(&self, name: &str) -> Option<&ParamDef> {
        self.params.iter().find(|p| p.name == name)
    }

    /// The flattened genome layout: each MultiDim parameter contributes
    /// `dims` slots.
    pub fn slots(&self) -> Vec<Slot> {
        let mut out = Vec::new();
        for (pi, p) in self.params.iter().enumerate() {
            for d in 0..p.dims {
                out.push(Slot { param: pi, dim: d, cardinality: p.domain.cardinality() });
            }
        }
        out
    }

    pub fn genome_len(&self) -> usize {
        self.params.iter().map(|p| p.dims).sum()
    }

    /// Decode a genome (one domain index per slot) into a [`DesignPoint`].
    /// Returns `Err` on length mismatch or out-of-range indices — agents
    /// can never construct invalid *values*, only violate constraints.
    pub fn decode(&self, genome: &[usize]) -> Result<DesignPoint, String> {
        if genome.len() != self.genome_len() {
            return Err(format!(
                "genome length {} != schema slots {}",
                genome.len(),
                self.genome_len()
            ));
        }
        let mut values = HashMap::new();
        let mut idx = 0;
        for p in &self.params {
            let card = p.domain.cardinality();
            let slice = &genome[idx..idx + p.dims];
            for &g in slice {
                if g >= card {
                    return Err(format!("param '{}': index {g} out of range {card}", p.name));
                }
            }
            let value = if p.dims == 1 {
                match &p.domain {
                    Domain::Ints(v) => ParamValue::Int(v[slice[0]]),
                    Domain::Cats(v) => ParamValue::Cat(slice[0], v[slice[0]].clone()),
                    Domain::Bool => ParamValue::Bool(slice[0] == 1),
                }
            } else {
                match &p.domain {
                    Domain::Ints(v) => {
                        ParamValue::MultiInt(slice.iter().map(|&g| v[g]).collect())
                    }
                    Domain::Cats(_) => ParamValue::MultiCat(slice.to_vec()),
                    Domain::Bool => {
                        return Err(format!("param '{}': multi-dim bool unsupported", p.name))
                    }
                }
            };
            values.insert(p.name.clone(), value);
            idx += p.dims;
        }
        Ok(DesignPoint { values })
    }

    /// Check the schema's constraints against a decoded point.
    pub fn check_constraints(&self, point: &DesignPoint) -> Result<(), String> {
        for c in &self.constraints {
            match c {
                Constraint::ProductDividesLimit { params, limit } => {
                    let mut product: u64 = 1;
                    for name in params {
                        let v = point
                            .get(name)
                            .and_then(|v| v.as_int())
                            .ok_or_else(|| format!("constraint references missing '{name}'"))?;
                        product = product.saturating_mul(v.max(1) as u64);
                    }
                    if product > *limit {
                        return Err(format!(
                            "product({}) = {product} exceeds {limit}",
                            params.join(", ")
                        ));
                    }
                    if limit % product != 0 {
                        return Err(format!(
                            "product({}) = {product} does not divide {limit}",
                            params.join(", ")
                        ));
                    }
                }
                Constraint::MultiProductEq { param, limit } => {
                    let v = point
                        .get(param)
                        .and_then(|v| v.as_multi_int())
                        .ok_or_else(|| format!("constraint references missing '{param}'"))?;
                    let product: u64 = v.iter().map(|&x| x.max(1) as u64).product();
                    if product != *limit {
                        return Err(format!("product({param}) = {product} != {limit}"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Decode + constraint-check in one step.
    pub fn decode_valid(&self, genome: &[usize]) -> Result<DesignPoint, String> {
        let p = self.decode(genome)?;
        self.check_constraints(&p)?;
        Ok(p)
    }

    /// Parameters belonging to `stack`.
    pub fn stack_params(&self, stack: Stack) -> Vec<&ParamDef> {
        self.params.iter().filter(|p| p.stack == stack).collect()
    }

    /// Slot indices whose owning parameter satisfies `pred` — the one
    /// place genome positions are derived from the slot layout.
    fn slots_where(&self, pred: impl Fn(&ParamDef) -> bool) -> Vec<usize> {
        self.slots()
            .iter()
            .enumerate()
            .filter(|(_, s)| pred(&self.params[s.param]))
            .map(|(i, _)| i)
            .collect()
    }

    /// Slot indices (genome positions) belonging to `stack`.
    pub fn stack_slots(&self, stack: Stack) -> Vec<usize> {
        self.slots_where(|p| p.stack == stack)
    }

    /// Slot indices (genome positions) of the named parameter — empty if
    /// the schema does not carry it.
    pub fn param_slots(&self, name: &str) -> Vec<usize> {
        self.slots_where(|p| p.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_schema() -> Schema {
        Schema::new(
            vec![
                ParamDef::scalar("DP", Stack::Workload, Domain::pow2(1, 8)),
                ParamDef::scalar("Sched", Stack::Collective, Domain::cats(&["LIFO", "FIFO"])),
                ParamDef::scalar("Shard", Stack::Workload, Domain::Bool),
                ParamDef::multidim("BW", Stack::Network, Domain::Ints(vec![50, 100]), 2),
                ParamDef::multidim("NPUs", Stack::Network, Domain::Ints(vec![2, 4]), 2),
            ],
            vec![
                Constraint::ProductDividesLimit { params: vec!["DP".into()], limit: 8 },
                Constraint::MultiProductEq { param: "NPUs".into(), limit: 8 },
            ],
        )
    }

    #[test]
    fn pow2_domain() {
        assert_eq!(Domain::pow2(1, 1024).cardinality(), 11);
        assert_eq!(Domain::pow2(2, 16), Domain::Ints(vec![2, 4, 8, 16]));
    }

    #[test]
    fn genome_len_counts_multidim_slots() {
        let s = toy_schema();
        assert_eq!(s.genome_len(), 1 + 1 + 1 + 2 + 2);
        assert_eq!(s.slots().len(), 7);
    }

    #[test]
    fn decode_roundtrips_values() {
        let s = toy_schema();
        let p = s.decode(&[2, 0, 1, 1, 0, 1, 0]).unwrap();
        assert_eq!(p.int("DP"), 4);
        assert_eq!(p.cat("Sched"), 0);
        assert!(p.boolean("Shard"));
        assert_eq!(p.multi_int("BW"), &[100, 50]);
        assert_eq!(p.multi_int("NPUs"), &[4, 2]);
    }

    #[test]
    fn decode_rejects_bad_genomes() {
        let s = toy_schema();
        assert!(s.decode(&[0; 6]).is_err()); // wrong length
        assert!(s.decode(&[9, 0, 0, 0, 0, 0, 0]).is_err()); // out of range
    }

    #[test]
    fn constraints_enforced() {
        let s = toy_schema();
        // NPUs product = 4*2 = 8 -> ok; DP=4 divides 8 -> ok.
        assert!(s.decode_valid(&[2, 0, 1, 1, 0, 1, 0]).is_ok());
        // NPUs product = 2*2 = 4 != 8 -> constraint violation.
        assert!(s.decode_valid(&[2, 0, 1, 1, 0, 0, 0]).is_err());
    }

    #[test]
    fn stack_masking() {
        let s = toy_schema();
        assert_eq!(s.stack_params(Stack::Workload).len(), 2);
        assert_eq!(s.stack_slots(Stack::Network), vec![3, 4, 5, 6]);
        assert_eq!(s.stack_slots(Stack::Compute), Vec::<usize>::new());
    }

    #[test]
    fn param_cardinality_includes_dims() {
        let s = toy_schema();
        assert_eq!(s.param("BW").unwrap().cardinality(), 4.0); // 2^2
        assert_eq!(s.param("DP").unwrap().cardinality(), 4.0); // {1,2,4,8}
    }
}
