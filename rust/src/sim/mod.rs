//! End-to-end distributed-ML simulator (the ASTRA-sim substrate).
//!
//! Given a cluster (topology + collective config + compute device), a
//! model, a parallelization and a batch size, [`Simulator`] predicts the
//! end-to-end iteration latency:
//!
//! 1. the WTG instantiates the symbolic trace (`workload::trace`);
//! 2. the §5.4 memory constraint is checked (`workload::memory`);
//! 3. per-microbatch forward/backward timelines are built: roofline
//!    compute ops serialize on the compute stream, *blocking* collectives
//!    (TP/SP) serialize with them at their multi-dimensional alpha-beta
//!    cost;
//! 4. microbatches compose into a 1F1B-style pipeline makespan;
//! 5. *overlappable* gradient collectives (DP / ZeRO) are issued as the
//!    backward pass retires layers and drain through the network backend
//!    — serially under the LIFO/FIFO policy on the [`Analytical`] rung,
//!    or as concurrent max-min-shared flows on the [`FlowLevel`] rung —
//!    and the exposed tail (what the next iteration's forward must still
//!    wait for, layer by layer) is added to the iteration latency;
//! 6. latency and memory re-scale by the simulated-layer factor
//!    (Table 2 footnote).
//!
//! All network costs route through the pluggable [`NetworkBackend`]
//! (see [`crate::netsim`]); [`Simulator::with_backend`] /
//! [`Simulator::with_fidelity`] select the rung.

pub mod presets;

pub use crate::netsim::engine;
pub use crate::netsim::EventQueue;

use crate::collective::{CollAlgo, CollectiveConfig, CollectiveKind, MultiDimPolicy};
use crate::compute::{ComputeDevice, MEM_LIMIT_BYTES};
use crate::faults::{goodput_of, FaultScenario, FaultView, Goodput};
use crate::netsim::backend::collapse_per_layer;
use crate::netsim::{
    serial_drain, serial_drain_detailed, Analytical, CollectiveCall, FidelityMode, FlowLevel,
    NetworkBackend, OverlapCall, TrafficTrace, TrafficView,
};
use crate::obs::{tracks, NoopSink, TraceSink, Track};
use crate::topology::{DimCost, Topology};
use crate::workload::{
    footprint, generate_trace, group_dim_costs, CommGroup, ExecutionMode, MemoryFootprint,
    ModelConfig, Parallelization, Trace, TraceOp,
};
use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

/// Cache key of one priced multi-dimensional collective. Together the
/// fields pin down every input the cost depends on: the backend's
/// pricing state ([`NetworkBackend::cache_tag`]), the topology the
/// communicator spans ([`Topology::fingerprint`] + rank-space
/// stride/size, which determine the spanned dimensions), and the
/// collective-stack knobs. Keys are valid *across* evaluations, so one
/// [`CollCostMemo`] may be shared by a whole DSE sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CollKey {
    /// Backend pricing fingerprint ([`NetworkBackend::cache_tag`]).
    /// Backend-side modes that change pricing fold in here — e.g. the
    /// flow rung's chunk-precedence drain
    /// ([`crate::netsim::FlowLevelConfig::with_chunk_precedence`])
    /// hashes into the tag, so chunked and steady-state evaluations of
    /// the same collective never share a memoized cost.
    pub backend: u64,
    /// Topology fingerprint ([`Topology::fingerprint`]).
    pub topology: u64,
    /// Fingerprint of the per-dimension algorithm assignment.
    pub algos: u64,
    pub policy: MultiDimPolicy,
    pub kind: CollectiveKind,
    /// Communicator rank-space stride (with `size`, this determines the
    /// spanned dimensions for a given topology).
    pub stride: u64,
    /// Communicator size (ranks).
    pub size: u64,
    /// Per-NPU payload bytes, exact bit pattern.
    pub bytes: u64,
    pub chunks: u32,
    /// Fault-scenario link-degradation fingerprint
    /// ([`crate::faults::LinkFaults::fingerprint`]); `0` on fault-free
    /// runs *and* under nominal-link scenarios, so those share entries.
    /// Belt-and-suspenders with the [`FaultView`] `cache_tag` (which
    /// already flows into `backend`): fault-scenario evaluations can
    /// never alias nominal ones even if a backend tag collides.
    pub scenario: u64,
    /// Traffic-trace fingerprint
    /// ([`crate::netsim::TrafficTrace::fingerprint`]); `0` with no
    /// trace attached *and* under the nominal trace, so those share
    /// entries. Same belt-and-suspenders role as `scenario`: without
    /// this component one trace's collective costs could be served to
    /// another evaluation.
    pub traffic: u64,
}

/// The collective-cost memo consulted by [`Simulator::price`]: `cost_us`
/// returns the cached cost for `key` or computes, stores and returns it.
/// [`LocalCollMemo`] is the per-run default; `cosmic::dse::EvalCache`
/// provides a sharded, thread-safe memo shared across evaluations.
pub trait CollCostMemo {
    fn cost_us(&mut self, key: &CollKey, compute: &mut dyn FnMut() -> f64) -> f64;
}

/// Per-run hashed memo: traces repeat the same (kind, group, bytes)
/// collective once per layer, so even a run-local memo removes ~4x
/// redundant alpha-beta walks.
#[derive(Debug, Default)]
pub struct LocalCollMemo {
    map: HashMap<CollKey, f64>,
}

impl CollCostMemo for LocalCollMemo {
    fn cost_us(&mut self, key: &CollKey, compute: &mut dyn FnMut() -> f64) -> f64 {
        if let Some(v) = self.map.get(key) {
            return *v;
        }
        let v = compute();
        self.map.insert(*key, v);
        v
    }
}

fn algos_fingerprint(algos: &[CollAlgo]) -> u64 {
    crate::util::hash64(|h| {
        algos.len().hash(h);
        for a in algos {
            (*a as u8).hash(h);
        }
    })
}

/// A complete cluster design point: the three non-workload stacks.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub topology: Topology,
    pub collectives: CollectiveConfig,
    pub compute: ComputeDevice,
}

impl ClusterConfig {
    pub fn validate(&self) -> Result<(), String> {
        self.topology.validate()?;
        self.collectives.validate(self.topology.num_dims())?;
        self.compute.validate()?;
        Ok(())
    }

    pub fn npus(&self) -> u64 {
        self.topology.total_npus()
    }
}

/// Why a design point was rejected.
#[derive(Debug, Clone, PartialEq)]
pub enum Invalid {
    /// Per-NPU memory footprint exceeds the §5.4 budget.
    Memory { required_gb: f64, budget_gb: f64 },
    /// Structural error (non-dividing parallelization, bad config...).
    Config(String),
}

/// Simulation output.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// End-to-end iteration latency (us), re-scaled to the full model.
    pub latency_us: f64,
    /// Pure compute time on the critical path (us, re-scaled).
    pub compute_us: f64,
    /// Blocking (TP/SP/P2P) communication on the critical path (us).
    pub comm_blocking_us: f64,
    /// Exposed (non-overlapped) gradient-sync tail (us).
    pub comm_exposed_us: f64,
    /// Per-NPU memory footprint.
    pub memory: MemoryFootprint,
    /// Microbatches in the pipeline schedule.
    pub microbatches: u64,
    /// Cluster-wide achieved TFLOP/s (all NPUs).
    pub achieved_tflops: f64,
    /// Resilience accounting (throughput net of lost work + checkpoint
    /// overhead). `None` on fault-free runs — the pre-fault pipeline is
    /// bit-identical — and `Some` whenever a
    /// [`crate::faults::FaultScenario`] is attached, even the nominal
    /// one (where efficiency is exactly `1.0`).
    pub goodput: Option<Goodput>,
}

impl SimReport {
    /// Fraction of the iteration spent on exposed communication.
    pub fn comm_fraction(&self) -> f64 {
        if self.latency_us <= 0.0 {
            0.0
        } else {
            (self.comm_blocking_us + self.comm_exposed_us) / self.latency_us
        }
    }
}

/// The simulator. Holds no per-run mutable state: `run` is pure, so one
/// instance may be shared across a DSE sweep (and across threads).
#[derive(Debug, Clone)]
pub struct Simulator {
    /// Per-NPU memory budget in bytes (paper: 24 GB).
    pub mem_budget_bytes: f64,
    /// The *effective* network model: `base_backend`, wrapped in a
    /// [`FaultView`] when the active scenario degrades links.
    backend: Arc<dyn NetworkBackend>,
    /// The configured backend before fault wrapping (what
    /// [`Simulator::with_backend`] set); analytical by default.
    base_backend: Arc<dyn NetworkBackend>,
    /// Span consumer (see [`crate::obs`]); the disabled [`NoopSink`] by
    /// default, so pricing takes the identical code path.
    sink: Arc<dyn TraceSink>,
    /// Active fault scenario; `None` = fault-free (reports carry no
    /// goodput and price bit-identically to the pre-fault pipeline).
    faults: Option<Arc<FaultScenario>>,
    /// Checkpoint interval in iterations for goodput accounting;
    /// `None` = the scenario's Young/Daly optimum.
    ckpt_interval_iters: Option<u64>,
    /// Active co-tenant traffic trace; `None` = the job has the fabric
    /// to itself (prices bit-identically to the pre-traffic pipeline).
    traffic: Option<Arc<TrafficTrace>>,
}

impl Default for Simulator {
    fn default() -> Self {
        let backend: Arc<dyn NetworkBackend> = Arc::new(Analytical);
        Self {
            mem_budget_bytes: MEM_LIMIT_BYTES,
            backend: Arc::clone(&backend),
            base_backend: backend,
            sink: Arc::new(NoopSink),
            faults: None,
            ckpt_interval_iters: None,
            traffic: None,
        }
    }
}

impl Simulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Recompute the effective backend after the base backend, the
    /// fault scenario or the traffic trace changed — builders compose
    /// in any order. Traffic wraps outermost: the trace shapes the
    /// fabric the *degraded* network presents (co-tenants contend for
    /// the faulted links too).
    fn refresh_backend(&mut self) {
        let faulted = match &self.faults {
            Some(f) => FaultView::wrap(Arc::clone(&self.base_backend), &f.links),
            None => Arc::clone(&self.base_backend),
        };
        self.backend = match &self.traffic {
            Some(t) => TrafficView::wrap(faulted, Arc::clone(t)),
            None => faulted,
        };
    }

    /// Swap the network backend (builder style).
    pub fn with_backend(mut self, backend: Arc<dyn NetworkBackend>) -> Self {
        self.base_backend = backend;
        self.refresh_backend();
        self
    }

    /// Attach a fault scenario: compute phases stretch by the straggler
    /// factor, the network prices through a link-degrading
    /// [`FaultView`], and reports gain a [`Goodput`] record. The
    /// nominal scenario reproduces the fault-free report bit for bit
    /// (modulo the attached goodput, whose efficiency is exactly 1).
    pub fn with_faults(mut self, scenario: Arc<FaultScenario>) -> Self {
        self.faults = Some(scenario);
        self.refresh_backend();
        self
    }

    /// Detach any fault scenario (back to the fault-free fast path).
    pub fn without_faults(mut self) -> Self {
        self.faults = None;
        self.refresh_backend();
        self
    }

    /// Force the checkpoint interval (iterations) used by goodput
    /// accounting; `None` restores the Young/Daly optimum.
    pub fn with_checkpoint_interval(mut self, iters: Option<u64>) -> Self {
        self.ckpt_interval_iters = iters;
        self
    }

    /// The active fault scenario, if any.
    pub fn faults(&self) -> Option<&FaultScenario> {
        self.faults.as_deref()
    }

    /// Attach a co-tenant traffic trace: every fidelity rung prices
    /// against the trace's time-varying per-dimension utilization
    /// through a [`TrafficView`]. The nominal (all-idle) trace — and
    /// detaching via [`Simulator::without_traffic`] — reproduces the
    /// traffic-free report bit for bit.
    pub fn with_traffic(mut self, trace: Arc<TrafficTrace>) -> Self {
        self.traffic = Some(trace);
        self.refresh_backend();
        self
    }

    /// Detach any traffic trace (back to the sole-tenant fast path).
    pub fn without_traffic(mut self) -> Self {
        self.traffic = None;
        self.refresh_backend();
        self
    }

    /// The active traffic trace, if any.
    pub fn traffic(&self) -> Option<&TrafficTrace> {
        self.traffic.as_deref()
    }

    /// Select a fidelity rung with its default backend configuration.
    pub fn with_fidelity(self, mode: FidelityMode) -> Self {
        self.with_backend(mode.default_backend())
    }

    /// Select the flow-level backend with an explicit fabric config.
    pub fn with_flow_config(self, config: crate::netsim::FlowLevelConfig) -> Self {
        self.with_backend(Arc::new(FlowLevel::new(config)))
    }

    /// Select the packet-level backend with explicit packet parameters.
    pub fn with_packet_config(self, config: crate::netsim::PacketLevelConfig) -> Self {
        self.with_backend(Arc::new(crate::netsim::PacketLevel::new(config)))
    }

    /// The active network backend.
    pub fn backend(&self) -> &dyn NetworkBackend {
        self.backend.as_ref()
    }

    /// Attach a trace sink (e.g. [`crate::obs::Recorder`]). Spans cover
    /// the priced timeline — iteration, pipeline slots, per-op
    /// compute/collective phases, gradient drain — in *unscaled*
    /// simulated microseconds. Emission never feeds back into pricing:
    /// a run with any sink returns the same [`SimReport`] bits as one
    /// with the default [`NoopSink`].
    pub fn with_trace_sink(mut self, sink: Arc<dyn TraceSink>) -> Self {
        self.sink = sink;
        self
    }

    /// The active trace sink.
    pub fn trace_sink(&self) -> &dyn TraceSink {
        self.sink.as_ref()
    }

    /// The communicator group's rank-space stride and size.
    fn group_stride_size(par: &Parallelization, group: CommGroup) -> (u64, u64) {
        let strides = par.strides();
        match group {
            CommGroup::Tp => (strides.tp, par.tp),
            CommGroup::Sp => (strides.sp, par.sp),
            CommGroup::Dp => (strides.dp, par.dp),
            // [TP, SP, DP, PP] layout makes DPxSP contiguous at SP's stride.
            CommGroup::DpSp => (strides.sp, par.sp * par.dp),
        }
    }

    /// Cost of one collective of `kind` over the communicator `group`.
    fn collective_cost_us(
        &self,
        cluster: &ClusterConfig,
        par: &Parallelization,
        kind: CollectiveKind,
        group: CommGroup,
        bytes: f64,
    ) -> f64 {
        let (stride, size) = Self::group_stride_size(par, group);
        if size <= 1 {
            return 0.0;
        }
        let span = group_dim_costs(&cluster.topology, stride, size);
        if span.is_empty() {
            return 0.0;
        }
        let algos: Vec<_> = span.iter().map(|(_, d)| cluster.collectives.algorithms[*d]).collect();
        self.backend.collective_time_us(&CollectiveCall {
            kind,
            policy: cluster.collectives.multidim,
            algos: &algos,
            span: &span,
            topology: &cluster.topology,
            bytes,
            chunks: cluster.collectives.chunks,
        })
    }

    /// Emit per-phase child spans of one blocking collective. Only the
    /// Baseline composition lays phases out sequentially; BlueConnect
    /// overlaps them, which has no faithful single-track rendering, so
    /// only the parent span is drawn there. Purely descriptive — the
    /// priced cost comes from the memoized `coll_cost` path.
    #[allow(clippy::too_many_arguments)]
    fn trace_phases(
        &self,
        cluster: &ClusterConfig,
        par: &Parallelization,
        kind: CollectiveKind,
        group: CommGroup,
        bytes: f64,
        start_us: f64,
        track: Track,
    ) {
        if cluster.collectives.multidim != MultiDimPolicy::Baseline {
            return;
        }
        let (stride, size) = Self::group_stride_size(par, group);
        if size <= 1 {
            return;
        }
        let span = group_dim_costs(&cluster.topology, stride, size);
        if span.is_empty() {
            return;
        }
        let algos: Vec<CollAlgo> =
            span.iter().map(|(_, d)| cluster.collectives.algorithms[*d]).collect();
        let phases = self.backend.phase_times_us(&CollectiveCall {
            kind,
            policy: cluster.collectives.multidim,
            algos: &algos,
            span: &span,
            topology: &cluster.topology,
            bytes,
            chunks: cluster.collectives.chunks,
        });
        let mut t = start_us;
        for (dim, dur) in phases {
            self.sink.span(track, &format!("phase dim{dim}"), t, t + dur);
            t += dur;
        }
    }

    /// Point-to-point transfer between adjacent pipeline stages.
    fn p2p_cost_us(&self, cluster: &ClusterConfig, par: &Parallelization, bytes: f64) -> f64 {
        if par.pp <= 1 {
            return 0.0;
        }
        let span = group_dim_costs(&cluster.topology, par.strides().pp, par.pp);
        match span.first() {
            Some((dim, _)) => dim.xfer_us(bytes),
            None => 0.0,
        }
    }

    /// Simulate one design point. Returns `Err(Invalid)` for rejected
    /// configurations (the DSE maps those to zero reward).
    ///
    /// This is [`Simulator::preflight`] → [`generate_trace`] →
    /// [`Simulator::price`] with a fresh per-run memo; callers that
    /// evaluate many related points (the DSE hot path) should run the
    /// stages themselves, reusing cached traces and a shared
    /// [`CollCostMemo`] (see `cosmic::dse::EvalCache`).
    pub fn run(
        &self,
        cluster: &ClusterConfig,
        model: &ModelConfig,
        par: &Parallelization,
        batch: u64,
        mode: ExecutionMode,
    ) -> Result<SimReport, Invalid> {
        let mem = self.preflight(cluster, model, par, batch, mode)?;
        let trace = generate_trace(model, par, batch, mode).map_err(Invalid::Config)?;
        Ok(self.price(cluster, par, &trace, mem, mode, &mut LocalCollMemo::default()))
    }

    /// Stage 1 of a run: structural validation plus the §5.4 memory
    /// constraint. Cheap and allocation-light — the screening gate
    /// before any trace is built or priced.
    pub fn preflight(
        &self,
        cluster: &ClusterConfig,
        model: &ModelConfig,
        par: &Parallelization,
        batch: u64,
        mode: ExecutionMode,
    ) -> Result<MemoryFootprint, Invalid> {
        cluster.validate().map_err(Invalid::Config)?;
        par.validate(cluster.npus()).map_err(Invalid::Config)?;
        let mem = footprint(model, par, batch, mode);
        if !mem.fits(self.mem_budget_bytes) {
            return Err(Invalid::Memory {
                required_gb: mem.total() / 1e9,
                budget_gb: self.mem_budget_bytes / 1e9,
            });
        }
        Ok(mem)
    }

    /// Stage 3 of a run: price an instantiated trace on the network and
    /// compute substrate. The trace may come straight from
    /// [`generate_trace`] or from a cross-evaluation cache (it depends
    /// only on `(model, parallelization, batch, mode)`, not on the
    /// cluster). All collective costs route through `memo`, so a shared
    /// memo amortizes the alpha-beta walks across evaluations.
    pub fn price(
        &self,
        cluster: &ClusterConfig,
        par: &Parallelization,
        trace: &Trace,
        mem: MemoryFootprint,
        mode: ExecutionMode,
        memo: &mut dyn CollCostMemo,
    ) -> SimReport {
        let stage = &trace.stages[0];
        let tracing = self.sink.enabled();

        // Lockstep SPMD: every collective waits for its slowest
        // participant, so per-group straggler multipliers collapse to
        // the max (see `collective::straggler_factor`). 1.0 on the
        // fault-free path — and `x * 1.0` is exact in IEEE 754, so the
        // scaling below preserves bit-identity when no faults are set.
        let straggler =
            self.faults.as_ref().map(|f| f.stragglers.worst_multiplier()).unwrap_or(1.0);

        let backend_fp = self.backend.cache_tag();
        let topo_fp = cluster.topology.fingerprint();
        let algos_fp = algos_fingerprint(&cluster.collectives.algorithms);
        let scenario_fp = self.faults.as_ref().map(|f| f.links.fingerprint()).unwrap_or(0);
        let traffic_fp = self.traffic.as_ref().map(|t| t.fingerprint()).unwrap_or(0);
        let mut coll_cost = |kind: CollectiveKind, group: CommGroup, bytes: f64| -> f64 {
            let (stride, size) = Self::group_stride_size(par, group);
            let key = CollKey {
                backend: backend_fp,
                topology: topo_fp,
                algos: algos_fp,
                policy: cluster.collectives.multidim,
                kind,
                stride,
                size,
                bytes: bytes.to_bits(),
                chunks: cluster.collectives.chunks,
                scenario: scenario_fp,
                traffic: traffic_fp,
            };
            memo.cost_us(&key, &mut || self.collective_cost_us(cluster, par, kind, group, bytes))
        };

        // --- per-microbatch stage timelines ---
        let mut f_compute = 0.0; // forward compute
        let mut f_blocking = 0.0; // forward blocking comm
        let mut p2p_bytes = 0.0;
        let mut flops_per_micro = 0.0;
        for op in &stage.forward {
            match op {
                TraceOp::Compute { flops, bytes, .. } => {
                    f_compute += cluster.compute.op_time_us(*flops, *bytes) * straggler;
                    flops_per_micro += *flops;
                }
                TraceOp::Collective { kind, group, bytes, overlappable: false, .. } => {
                    f_blocking += coll_cost(*kind, *group, *bytes);
                }
                TraceOp::Collective { .. } => {}
                TraceOp::P2p { bytes } => p2p_bytes = *bytes,
            }
        }
        let mut b_compute = 0.0;
        let mut b_blocking = 0.0;
        let mut grad_bytes: Vec<(u64, CollectiveKind, CommGroup, f64)> = Vec::new();
        for op in &stage.backward {
            match op {
                TraceOp::Compute { flops, bytes, .. } => {
                    b_compute += cluster.compute.op_time_us(*flops, *bytes) * straggler;
                    flops_per_micro += *flops;
                }
                TraceOp::Collective { kind, group, bytes, overlappable, layer } => {
                    if *overlappable {
                        grad_bytes.push((*layer, *kind, *group, *bytes));
                    } else {
                        b_blocking += coll_cost(*kind, *group, *bytes);
                    }
                }
                TraceOp::P2p { .. } => {}
            }
        }

        let f_micro = f_compute + f_blocking;
        let b_micro = b_compute + b_blocking;
        let p2p = self.p2p_cost_us(cluster, par, p2p_bytes);

        // --- pipeline makespan (1F1B-style: fill + steady state) ---
        let m = trace.microbatches as f64;
        let pp = par.pp as f64;
        let pipeline_us = match mode {
            ExecutionMode::Training => {
                (m + pp - 1.0) * (f_micro + b_micro) + 2.0 * (pp - 1.0) * p2p
            }
            _ => (m + pp - 1.0) * f_micro + (pp - 1.0) * p2p,
        };

        // --- overlappable gradient sync (once per iteration) ---
        // The backward pass of the *last* microbatch retires layers in
        // reverse order; each retirement issues that layer's gradient
        // collective(s). The network backend drains them — serially
        // under the LIFO/FIFO policy (analytical) or as concurrent
        // max-min-shared flows (flow-level); the next iteration's
        // forward needs layer l's gradients after a slack of l/L * f_micro.
        let layers = stage.layers.max(1);
        let mut exposed_us = 0.0;
        if !grad_bytes.is_empty() && matches!(mode, ExecutionMode::Training) {
            let bwd_start = pipeline_us - b_micro;
            let completions = if self.backend.drain_is_serial() {
                // Serial-resource backends price each job independently:
                // route the durations through the cross-evaluation memo
                // (same keys as blocking collectives) and sweep the
                // arrivals, instead of re-walking alpha-beta costs in
                // the backend on every drain.
                let tuples: Vec<(u64, f64, f64)> = grad_bytes
                    .iter()
                    .map(|(layer, kind, group, bytes)| {
                        let frac = (layers - layer) as f64 / layers as f64;
                        (*layer, bwd_start + frac * b_compute, coll_cost(*kind, *group, *bytes))
                    })
                    .collect();
                if tracing {
                    let detailed = serial_drain_detailed(&tuples, cluster.collectives.scheduling);
                    for &(layer, start, finish) in &detailed {
                        self.sink.span(
                            tracks::SERIAL_DRAIN,
                            &format!("grad L{layer} drain"),
                            start,
                            finish,
                        );
                    }
                    collapse_per_layer(detailed.into_iter().map(|(l, _, f)| (l, f)))
                } else {
                    serial_drain(&tuples, cluster.collectives.scheduling)
                }
            } else {
                // Holistic backends (flow-level contention) see all jobs
                // at once; per-job costs are not separable, so nothing
                // here is memoizable across evaluations.
                // Resolve each distinct communicator group's span once.
                let mut group_spans: Vec<(CommGroup, Vec<(DimCost, usize)>, Vec<CollAlgo>)> =
                    Vec::with_capacity(2);
                for (_, _, group, _) in &grad_bytes {
                    if !group_spans.iter().any(|(g, _, _)| g == group) {
                        let (stride, size) = Self::group_stride_size(par, *group);
                        let span = group_dim_costs(&cluster.topology, stride, size);
                        let algos: Vec<CollAlgo> =
                            span.iter().map(|(_, d)| cluster.collectives.algorithms[*d]).collect();
                        group_spans.push((*group, span, algos));
                    }
                }
                let jobs: Vec<OverlapCall> = grad_bytes
                    .iter()
                    .map(|(layer, kind, group, bytes)| {
                        let (_, span, algos) =
                            group_spans.iter().find(|(g, _, _)| g == group).unwrap();
                        let frac = (layers - layer) as f64 / layers as f64;
                        OverlapCall {
                            layer: *layer,
                            issue_us: bwd_start + frac * b_compute,
                            call: CollectiveCall {
                                kind: *kind,
                                policy: cluster.collectives.multidim,
                                algos,
                                span,
                                topology: &cluster.topology,
                                bytes: *bytes,
                                chunks: cluster.collectives.chunks,
                            },
                        }
                    })
                    .collect();
                if tracing {
                    self.backend.drain_overlapped_traced(
                        &jobs,
                        cluster.collectives.scheduling,
                        self.sink.as_ref(),
                    )
                } else {
                    self.backend.drain_overlapped(&jobs, cluster.collectives.scheduling)
                }
            };
            if tracing {
                // Per-layer [issue, done] gradient-sync windows.
                for &(layer, done_us) in &completions {
                    let frac = (layers - layer) as f64 / layers as f64;
                    let issue = bwd_start + frac * b_compute;
                    self.sink.span(
                        tracks::GRAD_SYNC,
                        &format!("grad sync L{layer}"),
                        issue,
                        done_us.max(issue),
                    );
                }
            }
            // Exposed tail: completion minus (iteration end + fwd slack).
            for (layer, done_us) in completions {
                let slack = layer as f64 / layers as f64 * f_micro;
                let exposure = done_us - pipeline_us - slack;
                if exposure > exposed_us {
                    exposed_us = exposure;
                }
            }
        }

        // --- trace emission (skipped entirely when the sink is off) ---
        // Timestamps are unscaled simulated us; the layer-scale
        // extrapolation below multiplies the report, not the timeline.
        // Emission only *reads* priced quantities (collective costs come
        // back out of the warm memo), so it cannot perturb the report.
        if tracing {
            let training = matches!(mode, ExecutionMode::Training);
            let iter_end = pipeline_us + exposed_us;
            self.sink.span(tracks::PIPELINE, "iteration", 0.0, iter_end);
            if exposed_us > 0.0 {
                self.sink.span(tracks::PIPELINE, "exposed grad tail", pipeline_us, iter_end);
            }
            // Active fault-scenario elements, one span each over the
            // iteration window. The nominal scenario (and the
            // fault-free path) emits none, keeping traced output
            // aligned with the plain pipeline.
            if let Some(f) = &self.faults {
                for (g, mult) in f.stragglers.group_multipliers.iter().enumerate() {
                    if *mult > 1.0 {
                        self.sink.span(
                            tracks::FAULTS,
                            &format!("straggler group {g} x{mult:.2}"),
                            0.0,
                            iter_end,
                        );
                    }
                }
                for d in 0..f.links.bandwidth_factor.len().max(f.links.latency_factor.len()) {
                    let bw = f.links.bw_factor(d);
                    let lat = f.links.lat_factor(d);
                    if bw < 1.0 || lat > 1.0 {
                        self.sink.span(
                            tracks::FAULTS,
                            &format!("degraded link dim{d} bw x{bw:.2} lat x{lat:.2}"),
                            0.0,
                            iter_end,
                        );
                    }
                }
                if f.failures.device_mtbf_hours.is_finite() {
                    self.sink.span(
                        tracks::FAULTS,
                        &format!(
                            "failures: mtbf/device {:.0} h, ckpt {:.0} s, restart {:.0} s",
                            f.failures.device_mtbf_hours,
                            f.failures.checkpoint_write_s,
                            f.failures.restart_s
                        ),
                        0.0,
                        iter_end,
                    );
                }
            }
            // Co-tenant traffic intervals, one span per busy trace
            // segment per dimension over the iteration window, capped
            // like the pipeline slots so a fine trace over a long
            // iteration cannot blow up the trace file. The nominal
            // trace (and the traffic-free path) emits none.
            if let Some(t) = &self.traffic {
                for d in 0..t.num_dims() {
                    for (s, e, u) in t.segments_in(d, 0.0, iter_end, 256) {
                        if u > 0.0 {
                            self.sink.span(
                                tracks::traffic_dim(d),
                                &format!("co-tenant dim{d} {:.0}%", u * 100.0),
                                s,
                                e.min(iter_end),
                            );
                        }
                    }
                }
            }
            // 1F1B pipeline slots, capped so a huge microbatch count
            // cannot blow up the trace file.
            let slots = ((m + pp - 1.0) as u64).min(256);
            let slot_us = if training { f_micro + b_micro } else { f_micro };
            for k in 0..slots {
                let t0 = k as f64 * slot_us;
                self.sink.span(tracks::PIPELINE, &format!("slot {k} fwd"), t0, t0 + f_micro);
                if training {
                    self.sink.span(
                        tracks::PIPELINE,
                        &format!("slot {k} bwd"),
                        t0 + f_micro,
                        t0 + slot_us,
                    );
                }
            }
            // Per-op walk of the first microbatch's forward...
            let mut tf = 0.0;
            for op in &stage.forward {
                match op {
                    TraceOp::Compute { name, flops, bytes } => {
                        let d = cluster.compute.op_time_us(*flops, *bytes) * straggler;
                        self.sink.span(tracks::FWD_OPS, &format!("fwd {name}"), tf, tf + d);
                        tf += d;
                    }
                    TraceOp::Collective { kind, group, bytes, overlappable: false, .. } => {
                        let d = coll_cost(*kind, *group, *bytes);
                        self.sink.span(
                            tracks::FWD_OPS,
                            &format!("fwd {kind} {group:?}"),
                            tf,
                            tf + d,
                        );
                        self.trace_phases(cluster, par, *kind, *group, *bytes, tf, tracks::FWD_OPS);
                        tf += d;
                    }
                    _ => {}
                }
            }
            // ...and of the last microbatch's backward (whose layer
            // retirements issue the gradient drain traced above).
            if training {
                let mut tb = pipeline_us - b_micro;
                for op in &stage.backward {
                    match op {
                        TraceOp::Compute { name, flops, bytes } => {
                            let d = cluster.compute.op_time_us(*flops, *bytes) * straggler;
                            self.sink.span(tracks::BWD_OPS, &format!("bwd {name}"), tb, tb + d);
                            tb += d;
                        }
                        TraceOp::Collective { kind, group, bytes, overlappable: false, .. } => {
                            let d = coll_cost(*kind, *group, *bytes);
                            self.sink.span(
                                tracks::BWD_OPS,
                                &format!("bwd {kind} {group:?}"),
                                tb,
                                tb + d,
                            );
                            self.trace_phases(
                                cluster,
                                par,
                                *kind,
                                *group,
                                *bytes,
                                tb,
                                tracks::BWD_OPS,
                            );
                            tb += d;
                        }
                        _ => {}
                    }
                }
            }
        }

        let scale = trace.layer_scale;
        let latency_us = (pipeline_us + exposed_us) * scale;
        let compute_us = (f_compute + b_compute) * m * scale;
        let comm_blocking_us = ((f_blocking + b_blocking) * m + 2.0 * (pp - 1.0) * p2p) * scale;
        let total_flops = flops_per_micro * m * scale * cluster.npus() as f64;
        let achieved_tflops =
            if latency_us > 0.0 { total_flops / (latency_us * 1e6) } else { 0.0 };

        // Resilience accounting: only when a scenario is attached, so
        // fault-free reports stay bit-identical to the pre-fault
        // pipeline (goodput = None and no other field is touched).
        let goodput = self.faults.as_ref().map(|f| {
            goodput_of(
                latency_us / 1e6,
                achieved_tflops,
                cluster.npus(),
                &f.failures,
                self.ckpt_interval_iters,
            )
        });

        SimReport {
            latency_us,
            compute_us,
            comm_blocking_us,
            comm_exposed_us: exposed_us * scale,
            memory: mem,
            microbatches: trace.microbatches,
            achieved_tflops,
            goodput,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{CollAlgo, MultiDimPolicy, SchedulingPolicy};
    use crate::topology::DimKind;
    use crate::workload::models::presets as wl;

    fn small_cluster(policy: SchedulingPolicy) -> ClusterConfig {
        ClusterConfig {
            topology: Topology::from_arrays(
                &[DimKind::Ring, DimKind::Switch],
                &[4, 16],
                &[200.0, 100.0],
                &[0.5, 1.0],
            ),
            collectives: CollectiveConfig::new(
                policy,
                vec![CollAlgo::Ring, CollAlgo::Rhd],
                4,
                MultiDimPolicy::Baseline,
            ),
            compute: ComputeDevice::new(100.0, 1000.0, 32.0),
        }
    }

    fn par(npus: u64, dp: u64, sp: u64, pp: u64, ws: bool) -> Parallelization {
        Parallelization::derive(npus, dp, sp, pp, ws).unwrap()
    }

    #[test]
    fn valid_run_produces_positive_latency() {
        let c = small_cluster(SchedulingPolicy::Fifo);
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let r = Simulator::new()
            .run(&c, &m, &par(64, 8, 1, 1, true), 64, ExecutionMode::Training)
            .unwrap();
        assert!(r.latency_us > 0.0);
        assert!(r.compute_us > 0.0);
        assert!(r.achieved_tflops > 0.0);
        assert!(r.comm_fraction() >= 0.0 && r.comm_fraction() <= 1.0);
    }

    #[test]
    fn memory_violation_is_invalid() {
        let c = small_cluster(SchedulingPolicy::Fifo);
        let m = wl::gpt3_175b(); // full 96 layers, unsharded pure DP
        let err = Simulator::new()
            .run(&c, &m, &par(64, 64, 1, 1, false), 64, ExecutionMode::Training)
            .unwrap_err();
        assert!(matches!(err, Invalid::Memory { .. }));
    }

    #[test]
    fn mismatched_parallelization_is_config_error() {
        let c = small_cluster(SchedulingPolicy::Fifo);
        let m = wl::vit_base();
        let bad = Parallelization::derive(32, 32, 1, 1, false).unwrap();
        let err = Simulator::new().run(&c, &m, &bad, 256, ExecutionMode::Training).unwrap_err();
        assert!(matches!(err, Invalid::Config(_)));
    }

    #[test]
    fn lifo_no_worse_than_fifo_on_gradient_tail() {
        // LIFO finishes the last-issued (earliest-layer) gradients first,
        // which is exactly what the next iteration needs first.
        let m = wl::gpt3_13b().with_simulated_layers(8);
        let p = par(64, 64, 1, 1, true);
        let fifo = Simulator::new()
            .run(&small_cluster(SchedulingPolicy::Fifo), &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        let lifo = Simulator::new()
            .run(&small_cluster(SchedulingPolicy::Lifo), &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        assert!(
            lifo.comm_exposed_us <= fifo.comm_exposed_us + 1e-9,
            "lifo={} fifo={}",
            lifo.comm_exposed_us,
            fifo.comm_exposed_us
        );
    }

    #[test]
    fn more_bandwidth_is_not_slower() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let slow = small_cluster(SchedulingPolicy::Fifo);
        let mut fast = slow.clone();
        for d in &mut fast.topology.dims {
            d.bandwidth_gbps *= 10.0;
        }
        let rs = Simulator::new().run(&slow, &m, &p, 64, ExecutionMode::Training).unwrap();
        let rf = Simulator::new().run(&fast, &m, &p, 64, ExecutionMode::Training).unwrap();
        assert!(rf.latency_us <= rs.latency_us + 1e-9);
    }

    #[test]
    fn inference_faster_than_training() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 4, 1, 1, true);
        let sim = Simulator::new();
        let c = small_cluster(SchedulingPolicy::Fifo);
        let train = sim.run(&c, &m, &p, 64, ExecutionMode::Training).unwrap();
        let infer = sim.run(&c, &m, &p, 64, ExecutionMode::InferencePrefill).unwrap();
        assert!(infer.latency_us < train.latency_us);
    }

    #[test]
    fn latency_scales_with_batch() {
        let m = wl::vit_large().with_simulated_layers(4);
        let p = par(64, 16, 1, 1, true);
        let sim = Simulator::new();
        let c = small_cluster(SchedulingPolicy::Fifo);
        let small = sim.run(&c, &m, &p, 1024, ExecutionMode::Training).unwrap();
        let big = sim.run(&c, &m, &p, 4096, ExecutionMode::Training).unwrap();
        assert!(big.latency_us > small.latency_us);
    }

    #[test]
    fn report_is_deterministic() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 2, 1, true);
        let c = small_cluster(SchedulingPolicy::Lifo);
        let sim = Simulator::new();
        let a = sim.run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        let b = sim.run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn pipeline_reduces_memory_but_adds_fill() {
        let m = wl::gpt3_175b().with_simulated_layers(8);
        let sim = Simulator::new();
        let c = ClusterConfig {
            topology: Topology::from_arrays(
                &[DimKind::Ring, DimKind::Switch, DimKind::Switch],
                &[4, 16, 16],
                &[200.0, 100.0, 50.0],
                &[0.5, 1.0, 1.0],
            ),
            collectives: CollectiveConfig::new(
                SchedulingPolicy::Fifo,
                vec![CollAlgo::Ring, CollAlgo::Rhd, CollAlgo::Rhd],
                4,
                MultiDimPolicy::Baseline,
            ),
            compute: ComputeDevice::new(459.0, 2765.0, 32.0),
        };
        let no_pp = sim
            .run(&c, &m, &par(1024, 16, 1, 1, true), 2048, ExecutionMode::Training)
            .unwrap();
        let with_pp = sim
            .run(&c, &m, &par(1024, 16, 1, 4, true), 2048, ExecutionMode::Training)
            .unwrap();
        assert!(with_pp.memory.total() < no_pp.memory.total());
        assert!(with_pp.microbatches > no_pp.microbatches);
    }

    #[test]
    fn staged_pipeline_matches_run_bit_for_bit() {
        let c = small_cluster(SchedulingPolicy::Fifo);
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 2, 1, true);
        let sim = Simulator::new();
        let direct = sim.run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        let mem = sim.preflight(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        let trace = generate_trace(&m, &p, 128, ExecutionMode::Training).unwrap();
        let mut memo = LocalCollMemo::default();
        let staged = sim.price(&c, &p, &trace, mem, ExecutionMode::Training, &mut memo);
        assert_eq!(direct, staged);
        // Re-pricing against the warm memo stays bit-identical.
        let again = sim.price(&c, &p, &trace, mem, ExecutionMode::Training, &mut memo);
        assert_eq!(direct, again);
    }

    #[test]
    fn shared_memo_isolates_different_clusters() {
        // One memo priced against two clusters must reproduce each
        // cluster's independent result — the CollKey fingerprints carry
        // the full pricing context.
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let c1 = small_cluster(SchedulingPolicy::Fifo);
        let mut c2 = c1.clone();
        c2.topology.dims[1].bandwidth_gbps *= 2.0;
        let mut c3 = c1.clone();
        c3.collectives.chunks = 8;
        let sim = Simulator::new();
        let mut memo = LocalCollMemo::default();
        for c in [&c1, &c2, &c3] {
            let fresh = sim.run(c, &m, &p, 128, ExecutionMode::Training).unwrap();
            let mem = sim.preflight(c, &m, &p, 128, ExecutionMode::Training).unwrap();
            let trace = generate_trace(&m, &p, 128, ExecutionMode::Training).unwrap();
            let shared = sim.price(c, &p, &trace, mem, ExecutionMode::Training, &mut memo);
            assert_eq!(fresh, shared, "memo leaked across clusters");
        }
    }

    #[test]
    fn flow_level_backend_matches_analytical_when_uncongested() {
        // Blocking-collective-only workload (TP, no DP gradient drain):
        // the flow-level rung must reproduce the analytical numbers.
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 1, 1, 1, false); // tp=64, no overlappable grads
        let c = small_cluster(SchedulingPolicy::Fifo);
        let a = Simulator::new().run(&c, &m, &p, 64, ExecutionMode::Training).unwrap();
        let f = Simulator::new()
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .run(&c, &m, &p, 64, ExecutionMode::Training)
            .unwrap();
        let rel = (a.latency_us - f.latency_us).abs() / a.latency_us;
        assert!(rel < 1e-9, "analytical={} flow={}", a.latency_us, f.latency_us);
    }

    #[test]
    fn oversubscribed_fabric_is_strictly_slower() {
        use crate::netsim::FlowLevelConfig;
        let m = wl::gpt3_13b().with_simulated_layers(4);
        // TP spans both dims (tp=64) -> every blocking all-reduce
        // crosses the Switch dim, so oversubscription must show up.
        let p = par(64, 1, 1, 1, false);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let fair = Simulator::new()
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .run(&c, &m, &p, 64, ExecutionMode::Training)
            .unwrap();
        let congested = Simulator::new()
            .with_flow_config(FlowLevelConfig::oversubscribed(8.0))
            .run(&c, &m, &p, 64, ExecutionMode::Training)
            .unwrap();
        assert!(
            congested.comm_blocking_us > fair.comm_blocking_us,
            "congested={} fair={}",
            congested.comm_blocking_us,
            fair.comm_blocking_us
        );
        assert!(congested.latency_us > fair.latency_us);
    }

    #[test]
    fn tracing_does_not_perturb_the_report() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 2, 1, true);
        let c = small_cluster(SchedulingPolicy::Lifo);
        let plain = Simulator::new().run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        let rec = Arc::new(crate::obs::Recorder::new());
        let traced = Simulator::new()
            .with_trace_sink(rec.clone())
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        assert_eq!(plain, traced, "a recording sink must be bit-invisible to pricing");
        let spans = rec.spans();
        assert!(spans.iter().any(|s| s.name == "iteration"));
        assert!(spans.iter().any(|s| s.name.starts_with("fwd ")));
        assert!(spans.iter().any(|s| s.name.starts_with("grad sync")));
        assert!(spans.iter().all(|s| s.start_us.is_finite() && s.end_us >= s.start_us - 1e-9));
    }

    #[test]
    fn flow_level_drain_is_deterministic() {
        let m = wl::gpt3_13b().with_simulated_layers(8);
        let p = par(64, 64, 1, 1, true);
        let c = small_cluster(SchedulingPolicy::Lifo);
        let sim = Simulator::new().with_fidelity(crate::netsim::FidelityMode::FlowLevel);
        let a = sim.run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        let b = sim.run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn nominal_scenario_is_bit_identical_to_fault_free() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 2, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let plain = Simulator::new().run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        assert!(plain.goodput.is_none());
        let nominal = Simulator::new()
            .with_faults(Arc::new(FaultScenario::nominal()))
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        let g = nominal.goodput.expect("scenario attached => goodput attached");
        assert_eq!(g.efficiency, 1.0);
        assert_eq!(g.goodput_tflops, nominal.achieved_tflops);
        let mut stripped = nominal.clone();
        stripped.goodput = None;
        assert_eq!(plain, stripped, "nominal scenario must price bit-identically");
    }

    #[test]
    fn faults_never_speed_up_either_rung() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let scenario = Arc::new(FaultScenario::from_seed(3, c.topology.num_dims()));
        for mode in [
            crate::netsim::FidelityMode::Analytical,
            crate::netsim::FidelityMode::FlowLevel,
        ] {
            let plain = Simulator::new()
                .with_fidelity(mode)
                .run(&c, &m, &p, 128, ExecutionMode::Training)
                .unwrap();
            let faulted = Simulator::new()
                .with_fidelity(mode)
                .with_faults(Arc::clone(&scenario))
                .run(&c, &m, &p, 128, ExecutionMode::Training)
                .unwrap();
            assert!(
                faulted.latency_us >= plain.latency_us - 1e-9,
                "{mode:?}: faulted {} < plain {}",
                faulted.latency_us,
                plain.latency_us
            );
            let g = faulted.goodput.unwrap();
            assert!(g.efficiency > 0.0 && g.efficiency < 1.0);
            assert!(g.goodput_tflops < faulted.achieved_tflops);
        }
    }

    #[test]
    fn builder_order_does_not_matter_for_faults() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let scenario = Arc::new(FaultScenario::from_seed(11, c.topology.num_dims()));
        let a = Simulator::new()
            .with_faults(Arc::clone(&scenario))
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        let b = Simulator::new()
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .with_faults(Arc::clone(&scenario))
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        assert_eq!(a, b);
        // ...and detaching restores the fault-free report exactly.
        let plain = Simulator::new()
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        let detached = Simulator::new()
            .with_faults(scenario)
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .without_faults()
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        assert_eq!(plain, detached);
    }

    #[test]
    fn shared_memo_isolates_fault_scenarios() {
        // One memo shared across fault-free, nominal-scenario and
        // degraded-scenario pricing must reproduce each independent
        // result — the scenario fingerprint keys the collective costs.
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let sims = [
            Simulator::new(),
            Simulator::new().with_faults(Arc::new(FaultScenario::nominal())),
            Simulator::new().with_faults(Arc::new(FaultScenario::from_seed(3, 2))),
            Simulator::new().with_faults(Arc::new(FaultScenario::from_seed(5, 2))),
        ];
        let mut memo = LocalCollMemo::default();
        for sim in &sims {
            let fresh = sim.run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
            let mem = sim.preflight(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
            let trace = generate_trace(&m, &p, 128, ExecutionMode::Training).unwrap();
            let shared = sim.price(&c, &p, &trace, mem, ExecutionMode::Training, &mut memo);
            assert_eq!(fresh, shared, "memo leaked across fault scenarios");
        }
    }

    #[test]
    fn nominal_trace_is_bit_identical_to_traffic_free() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 2, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        for mode in [
            crate::netsim::FidelityMode::Analytical,
            crate::netsim::FidelityMode::FlowLevel,
        ] {
            let plain = Simulator::new()
                .with_fidelity(mode)
                .run(&c, &m, &p, 128, ExecutionMode::Training)
                .unwrap();
            let nominal = Simulator::new()
                .with_fidelity(mode)
                .with_traffic(Arc::new(TrafficTrace::nominal()))
                .run(&c, &m, &p, 128, ExecutionMode::Training)
                .unwrap();
            assert_eq!(plain, nominal, "{mode:?}: nominal trace must price bit-identically");
        }
    }

    #[test]
    fn traffic_never_speeds_up_any_rung() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let trace = Arc::new(TrafficTrace::diurnal(7, c.topology.num_dims()));
        for mode in [
            crate::netsim::FidelityMode::Analytical,
            crate::netsim::FidelityMode::FlowLevel,
        ] {
            let plain = Simulator::new()
                .with_fidelity(mode)
                .run(&c, &m, &p, 128, ExecutionMode::Training)
                .unwrap();
            let busy = Simulator::new()
                .with_fidelity(mode)
                .with_traffic(Arc::clone(&trace))
                .run(&c, &m, &p, 128, ExecutionMode::Training)
                .unwrap();
            assert!(
                busy.latency_us >= plain.latency_us - 1e-9,
                "{mode:?}: busy {} < plain {}",
                busy.latency_us,
                plain.latency_us
            );
        }
    }

    #[test]
    fn builder_order_does_not_matter_for_traffic() {
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let trace = Arc::new(TrafficTrace::bursty(5, c.topology.num_dims()));
        let scenario = Arc::new(FaultScenario::from_seed(11, c.topology.num_dims()));
        let a = Simulator::new()
            .with_traffic(Arc::clone(&trace))
            .with_faults(Arc::clone(&scenario))
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        let b = Simulator::new()
            .with_fidelity(crate::netsim::FidelityMode::FlowLevel)
            .with_faults(Arc::clone(&scenario))
            .with_traffic(Arc::clone(&trace))
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        assert_eq!(a, b);
        // ...and detaching restores the traffic-free report exactly.
        let plain = Simulator::new().run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
        let detached = Simulator::new()
            .with_traffic(trace)
            .without_traffic()
            .run(&c, &m, &p, 128, ExecutionMode::Training)
            .unwrap();
        assert_eq!(plain, detached);
    }

    #[test]
    fn shared_memo_isolates_traffic_traces() {
        // One memo shared across traffic-free, nominal-trace and two
        // busy-trace pricings must reproduce each independent result —
        // the traffic fingerprint keys the collective costs.
        let m = wl::gpt3_13b().with_simulated_layers(4);
        let p = par(64, 8, 1, 1, true);
        let c = small_cluster(SchedulingPolicy::Fifo);
        let sims = [
            Simulator::new(),
            Simulator::new().with_traffic(Arc::new(TrafficTrace::nominal())),
            Simulator::new().with_traffic(Arc::new(TrafficTrace::uniform(2, 0.3))),
            Simulator::new().with_traffic(Arc::new(TrafficTrace::uniform(2, 0.6))),
            Simulator::new().with_traffic(Arc::new(TrafficTrace::diurnal(3, 2))),
        ];
        let mut memo = LocalCollMemo::default();
        for sim in &sims {
            let fresh = sim.run(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
            let mem = sim.preflight(&c, &m, &p, 128, ExecutionMode::Training).unwrap();
            let trace = generate_trace(&m, &p, 128, ExecutionMode::Training).unwrap();
            let shared = sim.price(&c, &p, &trace, mem, ExecutionMode::Training, &mut memo);
            assert_eq!(fresh, shared, "memo leaked across traffic traces");
        }
    }
}
