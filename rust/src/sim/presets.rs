//! Table 3 baseline systems.
//!
//! Three target clusters differing in NPU count and real-world analog:
//! - **System 1** — 512 Google TPUv5p devices.
//! - **System 2** — the 4D 1,024-NPU cluster of Themis [43].
//! - **System 3** — a 2,048-NPU NVIDIA H100 proxy.
//!
//! Table 3 gives per-dim topology kind, NPU count and bandwidth, plus the
//! compute knob (peak TFLOPS, local memory bandwidth). Per-dim link
//! latencies are not listed in the paper; we use 0.25/0.5/1.0/2.0 us
//! (growing outward — intra-board to scale-out), consistent with the
//! NVLink/IB-class fabrics the systems proxy.

use super::ClusterConfig;
use crate::collective::{CollAlgo, CollectiveConfig, MultiDimPolicy, SchedulingPolicy};
use crate::compute::presets as compute;
use crate::topology::{DimKind, Topology};

/// Default per-dimension latencies (us), innermost first.
pub const DIM_LATENCY_US: [f64; 4] = [0.25, 0.5, 1.0, 2.0];

/// System 1: 512 TPUv5p-like NPUs, `[RI, RI, RI, SW]`.
pub fn system1() -> ClusterConfig {
    ClusterConfig {
        topology: Topology::from_arrays(
            &[DimKind::Ring, DimKind::Ring, DimKind::Ring, DimKind::Switch],
            &[4, 4, 4, 8],
            &[200.0, 200.0, 200.0, 50.0],
            &DIM_LATENCY_US,
        ),
        collectives: CollectiveConfig::new(
            SchedulingPolicy::Fifo,
            vec![CollAlgo::Ring, CollAlgo::Ring, CollAlgo::Ring, CollAlgo::Rhd],
            2,
            MultiDimPolicy::Baseline,
        ),
        compute: compute::system1(),
    }
}

/// System 2: 1,024 NPUs, `[RI, FC, RI, SW]` (Themis-like 4D cluster).
pub fn system2() -> ClusterConfig {
    ClusterConfig {
        topology: Topology::from_arrays(
            &[DimKind::Ring, DimKind::FullyConnected, DimKind::Ring, DimKind::Switch],
            &[4, 8, 4, 8],
            &[375.0, 175.0, 150.0, 100.0],
            &DIM_LATENCY_US,
        ),
        collectives: CollectiveConfig::new(
            SchedulingPolicy::Fifo,
            vec![CollAlgo::Ring, CollAlgo::Direct, CollAlgo::Ring, CollAlgo::Rhd],
            2,
            MultiDimPolicy::Baseline,
        ),
        compute: compute::system2(),
    }
}

/// System 3: 2,048 H100-like NPUs, `[FC, SW, RI, RI]`.
pub fn system3() -> ClusterConfig {
    ClusterConfig {
        topology: Topology::from_arrays(
            &[DimKind::FullyConnected, DimKind::Switch, DimKind::Ring, DimKind::Ring],
            &[8, 16, 4, 4],
            &[900.0, 100.0, 50.0, 12.5],
            &DIM_LATENCY_US,
        ),
        collectives: CollectiveConfig::new(
            SchedulingPolicy::Fifo,
            vec![CollAlgo::Direct, CollAlgo::Rhd, CollAlgo::Ring, CollAlgo::Ring],
            2,
            MultiDimPolicy::Baseline,
        ),
        compute: compute::system3(),
    }
}

/// Look a system up by 1-based index as the paper numbers them.
pub fn by_index(i: usize) -> Option<ClusterConfig> {
    match i {
        1 => Some(system1()),
        2 => Some(system2()),
        3 => Some(system3()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npu_counts_match_paper() {
        assert_eq!(system1().npus(), 512);
        assert_eq!(system2().npus(), 1024);
        assert_eq!(system3().npus(), 2048);
    }

    #[test]
    fn all_presets_validate() {
        for i in 1..=3 {
            by_index(i).unwrap().validate().unwrap();
        }
        assert!(by_index(0).is_none());
        assert!(by_index(4).is_none());
    }

    #[test]
    fn table3_topologies() {
        assert_eq!(system1().topology.notation(), "[RI, RI, RI, SW]");
        assert_eq!(system2().topology.notation(), "[RI, FC, RI, SW]");
        assert_eq!(system3().topology.notation(), "[FC, SW, RI, RI]");
    }

    #[test]
    fn table3_collective_algorithms() {
        assert_eq!(system1().collectives.algo_notation(), "[RI, RI, RI, RHD]");
        assert_eq!(system2().collectives.algo_notation(), "[RI, DI, RI, RHD]");
        assert_eq!(system3().collectives.algo_notation(), "[DI, RHD, RI, RI]");
    }

    #[test]
    fn table3_bandwidths() {
        let bw: Vec<f64> = system3().topology.dims.iter().map(|d| d.bandwidth_gbps).collect();
        assert_eq!(bw, vec![900.0, 100.0, 50.0, 12.5]);
    }
}
