//! Parameter Set Scheduler (PSS) — paper §4.3.
//!
//! The PSS automates what would otherwise be manual, error-prone agent
//! and environment configuration:
//!
//! - **Agent side** — it derives each agent's action space from the PsA
//!   schema: genome layout, per-slot cardinalities, and which slots are
//!   *free* under the current search scope (single-stack baselines freeze
//!   the other stacks at the target system's values — §6.1).
//! - **Environment side** — it materializes a decoded [`DesignPoint`]
//!   into the simulator's inputs ([`ClusterConfig`] +
//!   [`Parallelization`]), so the environment "receives design parameters
//!   as input and estimates desired performance metrics".

use crate::collective::{CollAlgo, CollectiveConfig, MultiDimPolicy, SchedulingPolicy};
use crate::netsim::FidelityMode;
use crate::psa::builders::names;
use crate::psa::{DesignPoint, DesignSpace, Domain, Schema, Stack};
use crate::sim::presets::DIM_LATENCY_US;
use crate::sim::ClusterConfig;
use crate::topology::{DimKind, Topology};
use crate::workload::Parallelization;

/// Which stacks the agent may touch (paper §6.1's four scenarios, plus
/// the §6.3 co-design pairings).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SearchScope {
    WorkloadOnly,
    CollectiveOnly,
    NetworkOnly,
    FullStack,
    /// §6.3 Experiment 1: workload + network, collectives fixed.
    WorkloadNetwork,
    /// §6.3 Experiment 2: collective + network, workload fixed.
    CollectiveNetwork,
    /// Figure 4(b): workload + network.
    WorkloadCollective,
}

impl SearchScope {
    pub fn stacks(&self) -> Vec<Stack> {
        match self {
            SearchScope::WorkloadOnly => vec![Stack::Workload],
            SearchScope::CollectiveOnly => vec![Stack::Collective],
            SearchScope::NetworkOnly => vec![Stack::Network],
            SearchScope::FullStack => vec![Stack::Workload, Stack::Collective, Stack::Network],
            SearchScope::WorkloadNetwork => vec![Stack::Workload, Stack::Network],
            SearchScope::CollectiveNetwork => vec![Stack::Collective, Stack::Network],
            SearchScope::WorkloadCollective => vec![Stack::Workload, Stack::Collective],
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SearchScope::WorkloadOnly => "workload-only",
            SearchScope::CollectiveOnly => "collective-only",
            SearchScope::NetworkOnly => "network-only",
            SearchScope::FullStack => "full-stack",
            SearchScope::WorkloadNetwork => "workload+network",
            SearchScope::CollectiveNetwork => "collective+network",
            SearchScope::WorkloadCollective => "workload+collective",
        }
    }
}

/// The scheduler. Construct once per experiment from the schema and the
/// baseline system; hand [`DesignSpace`]s to agents and materialize their
/// proposals for the environment.
#[derive(Debug, Clone)]
pub struct Pss {
    pub schema: Schema,
    pub baseline_cluster: ClusterConfig,
    pub baseline_par: Parallelization,
}

impl Pss {
    pub fn new(schema: Schema, baseline_cluster: ClusterConfig, baseline_par: Parallelization) -> Self {
        Self { schema, baseline_cluster, baseline_par }
    }

    /// Encode the baseline system into a genome (nearest domain value for
    /// each knob). This genome seeds agents and supplies frozen-slot
    /// values for single-stack scopes.
    pub fn baseline_genome(&self) -> Vec<usize> {
        let mut g = Vec::with_capacity(self.schema.genome_len());
        for p in &self.schema.params {
            for d in 0..p.dims {
                g.push(self.baseline_slot_index(&p.name, &p.domain, d));
            }
        }
        g
    }

    fn baseline_slot_index(&self, name: &str, domain: &Domain, dim: usize) -> usize {
        let topo = &self.baseline_cluster.topology;
        let coll = &self.baseline_cluster.collectives;
        let par = &self.baseline_par;
        match name {
            names::DP => nearest_int(domain, par.dp as i64),
            names::PP => nearest_int(domain, par.pp as i64),
            names::SP => nearest_int(domain, par.sp as i64),
            names::WEIGHT_SHARDED => par.weight_sharded as usize,
            names::SCHED_POLICY => match coll.scheduling {
                SchedulingPolicy::Lifo => 0,
                SchedulingPolicy::Fifo => 1,
            },
            names::COLL_ALGO => {
                let algo = coll.algorithms.get(dim).copied().unwrap_or(CollAlgo::Ring);
                match algo {
                    CollAlgo::Ring => 0,
                    CollAlgo::Direct => 1,
                    CollAlgo::Rhd => 2,
                    CollAlgo::Dbt => 3,
                }
            }
            names::CHUNKS => nearest_int(domain, coll.chunks as i64),
            names::MULTIDIM_COLL => match coll.multidim {
                MultiDimPolicy::Baseline => 0,
                MultiDimPolicy::BlueConnect => 1,
            },
            names::TOPOLOGY => {
                let kind = topo.dims.get(dim).map(|d| d.kind).unwrap_or(DimKind::Ring);
                match kind {
                    DimKind::Ring => 0,
                    DimKind::Switch => 1,
                    DimKind::FullyConnected => 2,
                }
            }
            names::NPUS_PER_DIM => {
                nearest_int(domain, topo.dims.get(dim).map(|d| d.npus as i64).unwrap_or(4))
            }
            names::BW_PER_DIM => nearest_int(
                domain,
                topo.dims.get(dim).map(|d| d.bandwidth_gbps as i64).unwrap_or(100),
            ),
            _ => 0,
        }
    }

    /// Build the action space for `scope`: free slots are those of the
    /// scope's stacks, the rest frozen at the baseline genome.
    pub fn build_space(&self, scope: SearchScope) -> DesignSpace {
        let mut free = Vec::new();
        for stack in scope.stacks() {
            free.extend(self.schema.stack_slots(stack));
        }
        free.sort_unstable();
        DesignSpace::new(self.schema.clone(), free, self.baseline_genome())
    }

    /// Materialize a decoded design point into simulator inputs. The
    /// compute device always comes from the baseline (the paper fixes the
    /// compute knob per target system).
    pub fn materialize(
        &self,
        point: &DesignPoint,
    ) -> Result<(ClusterConfig, Parallelization), String> {
        // --- network stack ---
        let kinds: Vec<DimKind> = point
            .multi_cat(names::TOPOLOGY)
            .iter()
            .map(|&i| match i {
                0 => DimKind::Ring,
                1 => DimKind::Switch,
                _ => DimKind::FullyConnected,
            })
            .collect();
        let npus_per_dim: Vec<u64> =
            point.multi_int(names::NPUS_PER_DIM).iter().map(|&v| v as u64).collect();
        let bw: Vec<f64> = point.multi_int(names::BW_PER_DIM).iter().map(|&v| v as f64).collect();
        let lat: Vec<f64> = (0..kinds.len())
            .map(|d| DIM_LATENCY_US.get(d).copied().unwrap_or(2.0))
            .collect();
        let topology = Topology::from_arrays(&kinds, &npus_per_dim, &bw, &lat);
        let npus = topology.total_npus();

        // --- collective stack ---
        let scheduling = match point.cat(names::SCHED_POLICY) {
            0 => SchedulingPolicy::Lifo,
            _ => SchedulingPolicy::Fifo,
        };
        let algorithms: Vec<CollAlgo> = point
            .multi_cat(names::COLL_ALGO)
            .iter()
            .map(|&i| match i {
                0 => CollAlgo::Ring,
                1 => CollAlgo::Direct,
                2 => CollAlgo::Rhd,
                _ => CollAlgo::Dbt,
            })
            .collect();
        let chunks = point.int(names::CHUNKS) as u32;
        let multidim = match point.cat(names::MULTIDIM_COLL) {
            0 => MultiDimPolicy::Baseline,
            _ => MultiDimPolicy::BlueConnect,
        };
        let collectives = CollectiveConfig::new(scheduling, algorithms, chunks, multidim);

        // --- workload stack ---
        let par = Parallelization::derive(
            npus,
            point.int(names::DP) as u64,
            point.int(names::SP) as u64,
            point.int(names::PP) as u64,
            point.boolean(names::WEIGHT_SHARDED),
        )?;

        let cluster =
            ClusterConfig { topology, collectives, compute: self.baseline_cluster.compute };
        cluster.validate()?;
        Ok((cluster, par))
    }

    /// The netsim fidelity a design point asks for. Schemas without the
    /// optional "Network Fidelity" knob (the paper's Table 1/4 schemas)
    /// resolve to the analytical rung — the historical behavior.
    pub fn fidelity_of(&self, point: &DesignPoint) -> FidelityMode {
        match point.get(names::NET_FIDELITY).and_then(|v| v.as_cat()) {
            Some(1) => FidelityMode::FlowLevel,
            Some(2) => FidelityMode::Packet,
            _ => FidelityMode::Analytical,
        }
    }

    /// The checkpoint interval (iterations) a design point asks for,
    /// `None` when the schema lacks the optional "Checkpoint Interval"
    /// knob (see [`crate::psa::with_checkpoint_param`]) — goodput
    /// accounting then uses the scenario's Young/Daly optimum.
    pub fn checkpoint_interval_of(&self, point: &DesignPoint) -> Option<u64> {
        point.get(names::CKPT_INTERVAL).and_then(|v| v.as_int()).map(|v| v.max(1) as u64)
    }

    /// The traffic profile a design point asks for, `None` when the
    /// schema lacks the optional "Traffic Profile" knob (see
    /// [`crate::psa::with_traffic_param`]) or the point selects "None" —
    /// the job then has the fabric to itself. The environment turns the
    /// profile name into a seeded [`crate::netsim::TrafficTrace`] over
    /// the materialized topology's dimensions.
    pub fn traffic_profile_of(&self, point: &DesignPoint) -> Option<&'static str> {
        match point.get(names::TRAFFIC_PROFILE).and_then(|v| v.as_cat()) {
            Some(1) => Some("constant"),
            Some(2) => Some("diurnal"),
            Some(3) => Some("bursty"),
            _ => None,
        }
    }

    /// Whether a design point asks for chunk-level flow precedence.
    /// Schemas without the optional "Chunk Precedence" knob (see
    /// [`crate::psa::with_chunk_precedence_param`]) resolve to `false` —
    /// the steady-state flow drain, the historical behavior. Only
    /// meaningful when the point's fidelity is the flow rung; the other
    /// rungs ignore it.
    pub fn chunk_precedence_of(&self, point: &DesignPoint) -> bool {
        matches!(point.get(names::CHUNK_PRECEDENCE).and_then(|v| v.as_cat()), Some(1))
    }
}

/// Index of the closest value in an integer domain.
fn nearest_int(domain: &Domain, target: i64) -> usize {
    match domain {
        Domain::Ints(v) => v
            .iter()
            .enumerate()
            .min_by_key(|(_, &x)| (x - target).abs())
            .map(|(i, _)| i)
            .unwrap_or(0),
        Domain::Bool => (target != 0) as usize,
        Domain::Cats(_) => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::sim::presets;
    use crate::util::Rng;

    fn pss() -> Pss {
        let cluster = presets::system2();
        let par = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        Pss::new(paper_table4_schema(1024, 4), cluster, par)
    }

    #[test]
    fn baseline_genome_is_valid_and_roundtrips() {
        let p = pss();
        let g = p.baseline_genome();
        let point = p.schema.decode_valid(&g).expect("baseline must satisfy constraints");
        assert_eq!(point.int(names::DP), 64);
        assert_eq!(point.int(names::SP), 4);
        assert!(point.boolean(names::WEIGHT_SHARDED));
        // Topology round-trip: [RI, FC, RI, SW] with [4,8,4,8].
        let (cluster, par) = p.materialize(&point).unwrap();
        assert_eq!(cluster.topology.notation(), "[RI, FC, RI, SW]");
        assert_eq!(cluster.npus(), 1024);
        assert_eq!(par.tp, 4);
    }

    #[test]
    fn baseline_bandwidth_snaps_to_domain() {
        let p = pss();
        let g = p.baseline_genome();
        let point = p.schema.decode(&g).unwrap();
        // System 2 bw [375,175,150,100] snaps onto the 50-step grid.
        let bw = point.multi_int(names::BW_PER_DIM);
        assert_eq!(bw, &[350, 150, 150, 100]); // 375 is equidistant; nearest_int takes the lower
    }

    #[test]
    fn scope_masks_free_slots() {
        let p = pss();
        let wl = p.build_space(SearchScope::WorkloadOnly);
        let fs = p.build_space(SearchScope::FullStack);
        assert_eq!(wl.free_slots.len(), 4); // DP, PP, SP, shard
        assert!(fs.free_slots.len() > wl.free_slots.len());
        let cn = p.build_space(SearchScope::CollectiveNetwork);
        // collective: 1 + 4 + 1 + 1 = 7 slots; network: 4 + 4 + 4 = 12.
        assert_eq!(cn.free_slots.len(), 19);
    }

    #[test]
    fn materialized_random_points_simulate() {
        use crate::sim::Simulator;
        use crate::workload::models::presets as wl;
        use crate::workload::ExecutionMode;
        let p = pss();
        let space = p.build_space(SearchScope::FullStack);
        let mut rng = Rng::seed_from_u64(42);
        let sim = Simulator::new();
        let model = wl::gpt3_175b().with_simulated_layers(4);
        let mut ok = 0;
        for _ in 0..20 {
            if let Some(g) = space.random_valid_genome(&mut rng, 5000) {
                let point = p.schema.decode_valid(&g).unwrap();
                if let Ok((cluster, par)) = p.materialize(&point) {
                    if sim.run(&cluster, &model, &par, 2048, ExecutionMode::Training).is_ok() {
                        ok += 1;
                    }
                }
            }
        }
        assert!(ok > 0, "at least some sampled full-stack points must simulate");
    }

    #[test]
    fn materialize_rejects_parallelization_overflow() {
        let p = pss();
        let mut g = p.baseline_genome();
        // Crank DP to 2048 on a 1024-NPU cluster -> derive() must fail.
        g[0] = 11; // DP = 2048 in pow2(1, 2048)
        let point = p.schema.decode(&g).unwrap();
        assert!(p.materialize(&point).is_err());
    }

    #[test]
    fn fidelity_knob_resolves_and_defaults_analytical() {
        use crate::psa::with_fidelity_param;
        let cluster = presets::system2();
        let par = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        let p = Pss::new(with_fidelity_param(paper_table4_schema(1024, 4)), cluster, par);
        // Baseline genome: the appended knob defaults to slot 0.
        let g = p.baseline_genome();
        assert_eq!(g.len(), p.schema.genome_len());
        let point = p.schema.decode_valid(&g).unwrap();
        assert_eq!(p.fidelity_of(&point), FidelityMode::Analytical);
        // Flip the last slot to FlowLevel, then Packet.
        let mut g2 = g.clone();
        *g2.last_mut().unwrap() = 1;
        let point2 = p.schema.decode_valid(&g2).unwrap();
        assert_eq!(p.fidelity_of(&point2), FidelityMode::FlowLevel);
        let mut g3 = g.clone();
        *g3.last_mut().unwrap() = 2;
        let point3 = p.schema.decode_valid(&g3).unwrap();
        assert_eq!(p.fidelity_of(&point3), FidelityMode::Packet);
        // Materialization ignores the knob (same cluster either way).
        let (c1, _) = p.materialize(&point).unwrap();
        let (c2, _) = p.materialize(&point2).unwrap();
        assert_eq!(c1.topology, c2.topology);
        // Schemas without the knob default to analytical.
        let bare = pss();
        let bp = bare.schema.decode_valid(&bare.baseline_genome()).unwrap();
        assert_eq!(bare.fidelity_of(&bp), FidelityMode::Analytical);
    }

    #[test]
    fn checkpoint_knob_resolves_and_defaults_to_none() {
        use crate::psa::with_checkpoint_param;
        let cluster = presets::system2();
        let par = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        let p = Pss::new(with_checkpoint_param(paper_table4_schema(1024, 4)), cluster, par);
        let g = p.baseline_genome();
        assert_eq!(g.len(), p.schema.genome_len());
        let point = p.schema.decode_valid(&g).unwrap();
        // Baseline slot 0 = 8 iterations.
        assert_eq!(p.checkpoint_interval_of(&point), Some(8));
        let mut g2 = g.clone();
        *g2.last_mut().unwrap() = 4;
        let point2 = p.schema.decode_valid(&g2).unwrap();
        assert_eq!(p.checkpoint_interval_of(&point2), Some(128));
        // Materialization ignores the knob (same cluster either way).
        let (c1, _) = p.materialize(&point).unwrap();
        let (c2, _) = p.materialize(&point2).unwrap();
        assert_eq!(c1.topology, c2.topology);
        // Schemas without the knob resolve to None (Young/Daly default).
        let bare = pss();
        let bp = bare.schema.decode_valid(&bare.baseline_genome()).unwrap();
        assert_eq!(bare.checkpoint_interval_of(&bp), None);
    }

    #[test]
    fn traffic_knob_resolves_and_defaults_to_none() {
        use crate::psa::with_traffic_param;
        let cluster = presets::system2();
        let par = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        let p = Pss::new(with_traffic_param(paper_table4_schema(1024, 4)), cluster, par);
        let g = p.baseline_genome();
        assert_eq!(g.len(), p.schema.genome_len());
        let point = p.schema.decode_valid(&g).unwrap();
        // Baseline slot 0 = "None": sole tenant.
        assert_eq!(p.traffic_profile_of(&point), None);
        for (slot, profile) in [(1, "constant"), (2, "diurnal"), (3, "bursty")] {
            let mut g2 = g.clone();
            *g2.last_mut().unwrap() = slot;
            let point2 = p.schema.decode_valid(&g2).unwrap();
            assert_eq!(p.traffic_profile_of(&point2), Some(profile));
        }
        // Schemas without the knob resolve to None.
        let bare = pss();
        let bp = bare.schema.decode_valid(&bare.baseline_genome()).unwrap();
        assert_eq!(bare.traffic_profile_of(&bp), None);
    }

    #[test]
    fn chunk_precedence_knob_resolves_and_defaults_off() {
        use crate::psa::with_chunk_precedence_param;
        let cluster = presets::system2();
        let par = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        let p = Pss::new(with_chunk_precedence_param(paper_table4_schema(1024, 4)), cluster, par);
        let g = p.baseline_genome();
        assert_eq!(g.len(), p.schema.genome_len());
        let point = p.schema.decode_valid(&g).unwrap();
        // Baseline slot 0 = "Off": the historical steady-state drain.
        assert!(!p.chunk_precedence_of(&point));
        let mut g2 = g.clone();
        *g2.last_mut().unwrap() = 1;
        let point2 = p.schema.decode_valid(&g2).unwrap();
        assert!(p.chunk_precedence_of(&point2));
        // Materialization ignores the knob (same cluster either way).
        let (c1, _) = p.materialize(&point).unwrap();
        let (c2, _) = p.materialize(&point2).unwrap();
        assert_eq!(c1.topology, c2.topology);
        // Schemas without the knob resolve to Off.
        let bare = pss();
        let bp = bare.schema.decode_valid(&bare.baseline_genome()).unwrap();
        assert!(!bare.chunk_precedence_of(&bp));
    }

    #[test]
    fn nearest_int_picks_closest() {
        let d = Domain::Ints(vec![50, 100, 150, 200]);
        assert_eq!(nearest_int(&d, 160), 2);
        assert_eq!(nearest_int(&d, 40), 0);
        assert_eq!(nearest_int(&d, 1000), 3);
    }
}
