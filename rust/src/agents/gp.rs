//! Gaussian-process regression for the BO agent — the L2/L1 surrogate's
//! pure-Rust twin.
//!
//! RBF kernel over genomes normalized to the unit hypercube, fitted by a
//! jitter-stabilized Cholesky factorization. This module is the reference
//! implementation the AOT-compiled JAX surrogate (`artifacts/
//! gp_surrogate.hlo.txt`, built by `python/compile/model.py`) must agree
//! with — `runtime::tests` and the python test-suite check both against
//! the same fixtures.

/// Squared-exponential kernel: `σ² · exp(-‖a-b‖² / (2ℓ²))`.
fn rbf(a: &[f64], b: &[f64], lengthscale: f64, signal_var: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    signal_var * (-d2 / (2.0 * lengthscale * lengthscale)).exp()
}

/// In-place Cholesky of a symmetric positive-definite matrix (row-major
/// `n×n`). Returns the lower-triangular factor. Fails on non-PD input.
pub fn cholesky(mat: &[f64], n: usize) -> Result<Vec<f64>, String> {
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = mat[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(format!("matrix not PD at pivot {i} (sum={sum})"));
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Solve `L y = b` (forward) then `Lᵀ x = y` (backward).
pub fn cho_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * n + k] * y[k];
        }
        y[i] = sum / l[i * n + i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l[k * n + i] * x[k];
        }
        x[i] = sum / l[i * n + i];
    }
    x
}

/// A fitted Gaussian process.
pub struct Gp {
    x: Vec<Vec<f64>>,
    /// Cholesky factor of `K + σ_n² I`.
    chol: Vec<f64>,
    /// `(K + σ_n² I)^{-1} (y - mean)`.
    alpha: Vec<f64>,
    mean: f64,
    pub lengthscale: f64,
    pub signal_var: f64,
    pub noise_var: f64,
}

impl Gp {
    /// Fit on normalized inputs `x` (each in `[0,1]^d`) and targets `y`.
    pub fn fit(
        x: Vec<Vec<f64>>,
        y: &[f64],
        lengthscale: f64,
        signal_var: f64,
        noise_var: f64,
    ) -> Result<Self, String> {
        let n = x.len();
        if n == 0 || n != y.len() {
            return Err(format!("bad GP shapes: {n} inputs, {} targets", y.len()));
        }
        let mean = y.iter().sum::<f64>() / n as f64;
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = rbf(&x[i], &x[j], lengthscale, signal_var);
            }
            k[i * n + i] += noise_var + 1e-8; // jitter
        }
        let chol = cholesky(&k, n)?;
        let centered: Vec<f64> = y.iter().map(|v| v - mean).collect();
        let alpha = cho_solve(&chol, n, &centered);
        Ok(Self { x, chol, alpha, mean, lengthscale, signal_var, noise_var })
    }

    pub fn len(&self) -> usize {
        self.x.len()
    }

    pub fn is_empty(&self) -> bool {
        self.x.is_empty()
    }

    /// Posterior mean and variance at `q`.
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let n = self.x.len();
        let kq: Vec<f64> =
            self.x.iter().map(|xi| rbf(xi, q, self.lengthscale, self.signal_var)).collect();
        let mean = self.mean + kq.iter().zip(&self.alpha).map(|(a, b)| a * b).sum::<f64>();
        // var = k(q,q) - kqᵀ (K+σI)⁻¹ kq, via v = L⁻¹ kq.
        let mut v = vec![0.0; n];
        for i in 0..n {
            let mut sum = kq[i];
            for k in 0..i {
                sum -= self.chol[i * n + k] * v[k];
            }
            v[i] = sum / self.chol[i * n + i];
        }
        let kqq = self.signal_var;
        let var = (kqq - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (mean, var)
    }

    /// Expected improvement over `best` at `q` (maximization).
    pub fn expected_improvement(&self, q: &[f64], best: f64) -> f64 {
        let (mu, var) = self.predict(q);
        let sigma = var.sqrt();
        if sigma < 1e-12 {
            return (mu - best).max(0.0);
        }
        let z = (mu - best) / sigma;
        let (pdf, cdf) = (std_normal_pdf(z), std_normal_cdf(z));
        ((mu - best) * cdf + sigma * pdf).max(0.0)
    }
}

fn std_normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Abramowitz–Stegun-style erf approximation (max err ~1.5e-7).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

fn std_normal_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_of_identity() {
        let n = 3;
        let eye = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        let l = cholesky(&eye, n).unwrap();
        assert_eq!(l, eye);
    }

    #[test]
    fn cholesky_known_2x2() {
        // [[4, 2], [2, 3]] -> L = [[2, 0], [1, sqrt(2)]]
        let l = cholesky(&[4.0, 2.0, 2.0, 3.0], 2).unwrap();
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        assert!(cholesky(&[1.0, 2.0, 2.0, 1.0], 2).is_err());
    }

    #[test]
    fn cho_solve_inverts() {
        // A = [[4,2],[2,3]], b = [8, 7] -> x = [1.25, 1.5]
        let l = cholesky(&[4.0, 2.0, 2.0, 3.0], 2).unwrap();
        let x = cho_solve(&l, 2, &[8.0, 7.0]);
        assert!((4.0 * x[0] + 2.0 * x[1] - 8.0).abs() < 1e-10);
        assert!((2.0 * x[0] + 3.0 * x[1] - 7.0).abs() < 1e-10);
    }

    #[test]
    fn gp_interpolates_training_points() {
        let x = vec![vec![0.0], vec![0.5], vec![1.0]];
        let y = [1.0, 2.0, 3.0];
        let gp = Gp::fit(x, &y, 0.3, 1.0, 1e-6).unwrap();
        for (xi, yi) in [(0.0, 1.0), (0.5, 2.0), (1.0, 3.0)] {
            let (mu, var) = gp.predict(&[xi]);
            assert!((mu - yi).abs() < 0.05, "mu({xi})={mu} want {yi}");
            assert!(var < 0.01, "var at training point should be tiny, got {var}");
        }
    }

    #[test]
    fn gp_uncertainty_grows_away_from_data() {
        let x = vec![vec![0.0], vec![0.1]];
        let y = [0.0, 0.1];
        let gp = Gp::fit(x, &y, 0.1, 1.0, 1e-6).unwrap();
        let (_, var_near) = gp.predict(&[0.05]);
        let (_, var_far) = gp.predict(&[0.9]);
        assert!(var_far > var_near * 10.0, "near={var_near} far={var_far}");
    }

    #[test]
    fn ei_prefers_unexplored_high_mean() {
        let x = vec![vec![0.0], vec![1.0]];
        let y = [0.0, 1.0];
        let gp = Gp::fit(x, &y, 0.4, 1.0, 1e-6).unwrap();
        let ei_known_bad = gp.expected_improvement(&[0.0], 1.0);
        let ei_promising = gp.expected_improvement(&[0.8], 1.0);
        assert!(ei_promising > ei_known_bad);
    }

    #[test]
    fn erf_matches_known_values() {
        assert!((erf(0.0)).abs() < 1e-6); // A&S 7.1.26 approx
        assert!((erf(1.0) - 0.8427007929).abs() < 1e-6);
        assert!((erf(-1.0) + 0.8427007929).abs() < 1e-6);
        assert!((std_normal_cdf(0.0) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn gp_rejects_shape_mismatch() {
        assert!(Gp::fit(vec![vec![0.0]], &[1.0, 2.0], 0.3, 1.0, 1e-6).is_err());
        assert!(Gp::fit(vec![], &[], 0.3, 1.0, 1e-6).is_err());
    }
}
