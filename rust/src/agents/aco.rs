//! Ant Colony Optimization agent (paper §5.3, [9]).
//!
//! Each parameter slot keeps a pheromone vector over its domain values.
//! An ant constructs a genome by sampling each free slot proportionally to
//! `pheromone^greediness`; after evaluation, ants deposit pheromone on
//! the slots of high-reward genomes and all trails evaporate by `rho`.
//! The paper tunes the number of ants, the greediness factor, and the
//! evaporation rate.

use super::Agent;
use crate::psa::DesignSpace;
use crate::util::Rng;

pub struct AntColony {
    space: DesignSpace,
    rng: Rng,
    /// `pheromone[slot][value]`.
    pheromone: Vec<Vec<f64>>,
    pub ants: usize,
    pub greediness: f64,
    pub evaporation: f64,
    best: Option<(Vec<usize>, f64)>,
}

impl AntColony {
    pub fn new(space: DesignSpace, ants: usize, greediness: f64, evaporation: f64, seed: u64) -> Self {
        let pheromone = space.slot_cards.iter().map(|&c| vec![1.0; c]).collect();
        Self {
            space,
            rng: Rng::seed_from_u64(seed),
            pheromone,
            ants: ants.max(1),
            greediness,
            evaporation: evaporation.clamp(0.0, 1.0),
            best: None,
        }
    }

    fn construct(&mut self) -> Vec<usize> {
        let mut g = self.space.baseline.clone();
        let free = self.space.free_slots.clone();
        for &s in &free {
            let weights: Vec<f64> =
                self.pheromone[s].iter().map(|&p| p.powf(self.greediness)).collect();
            g[s] = self.rng.weighted_index(&weights);
        }
        g
    }

    /// Best genome observed so far (and its reward).
    pub fn best(&self) -> Option<&(Vec<usize>, f64)> {
        self.best.as_ref()
    }

    /// Current pheromone mass on a slot value (for tests/inspection).
    pub fn pheromone_at(&self, slot: usize, value: usize) -> f64 {
        self.pheromone[slot][value]
    }
}

impl Agent for AntColony {
    fn name(&self) -> &'static str {
        "ACO"
    }

    fn ask(&mut self) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(self.ants);
        for _ in 0..self.ants {
            // Construct until valid (bounded); fall back to random valid.
            let mut g = self.construct();
            for _ in 0..50 {
                if self.space.is_valid(&g) {
                    break;
                }
                g = self.construct();
            }
            if !self.space.is_valid(&g) {
                g = self
                    .space
                    .random_valid_genome(&mut self.rng, 2000)
                    .unwrap_or_else(|| self.space.baseline.clone());
            }
            out.push(g);
        }
        out
    }

    fn tell(&mut self, results: &[(Vec<usize>, f64)]) {
        // Evaporate.
        for trail in &mut self.pheromone {
            for p in trail.iter_mut() {
                *p *= 1.0 - self.evaporation;
                *p = p.max(1e-6); // keep exploration alive
            }
        }
        // Deposit proportional to reward; the iteration best deposits and
        // the global best reinforces (elitist ant system).
        for (g, r) in results {
            if *r <= 0.0 {
                continue;
            }
            for &s in &self.space.free_slots {
                self.pheromone[s][g[s]] += *r;
            }
            if self.best.as_ref().map(|(_, br)| *r > *br).unwrap_or(true) {
                self.best = Some((g.clone(), *r));
            }
        }
        if let Some((bg, br)) = self.best.clone() {
            for &s in &self.space.free_slots {
                self.pheromone[s][bg[s]] += br * 0.5;
            }
        }
    }

    fn space(&self) -> &DesignSpace {
        &self.space
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::pss::{Pss, SearchScope};
    use crate::sim::presets;
    use crate::workload::Parallelization;

    fn space() -> DesignSpace {
        Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        )
        .build_space(SearchScope::FullStack)
    }

    #[test]
    fn deposits_increase_pheromone_on_rewarded_values() {
        let sp = space();
        let slot = sp.free_slots[0];
        let mut aco = AntColony::new(sp, 4, 2.0, 0.1, 3);
        let proposals = aco.ask();
        let g = proposals[0].clone();
        let v = g[slot];
        let before = aco.pheromone_at(slot, v);
        aco.tell(&[(g, 10.0)]);
        let after = aco.pheromone_at(slot, v);
        assert!(after > before, "pheromone should grow: {before} -> {after}");
    }

    #[test]
    fn evaporation_decays_unrewarded_trails() {
        let sp = space();
        let slot = sp.free_slots[0];
        let mut aco = AntColony::new(sp, 2, 2.0, 0.5, 4);
        let before = aco.pheromone_at(slot, 0);
        // Tell with zero rewards: everything evaporates only.
        let proposals = aco.ask();
        let results: Vec<_> = proposals.into_iter().map(|g| (g, 0.0)).collect();
        aco.tell(&results);
        let after = aco.pheromone_at(slot, 0);
        assert!(after < before);
    }

    #[test]
    fn converges_to_rewarded_value_on_synthetic_objective() {
        let sp = space();
        let slot = sp.free_slots[0];
        let mut aco = AntColony::new(sp, 8, 2.0, 0.2, 5);
        // Reward only genomes with value 1 in the chosen slot.
        for _ in 0..30 {
            let proposals = aco.ask();
            let results: Vec<_> = proposals
                .into_iter()
                .map(|g| {
                    let r = if g[slot] == 1 { 1.0 } else { 0.01 };
                    (g, r)
                })
                .collect();
            aco.tell(&results);
        }
        // After 30 iterations most proposals should pick value 1.
        let proposals = aco.ask();
        let hits = proposals.iter().filter(|g| g[slot] == 1).count();
        assert!(hits * 2 >= proposals.len(), "{hits}/{} converged", proposals.len());
    }

    #[test]
    fn tracks_global_best() {
        let mut aco = AntColony::new(space(), 3, 2.0, 0.1, 6);
        let proposals = aco.ask();
        let g1 = proposals[0].clone();
        aco.tell(&[(g1.clone(), 5.0)]);
        assert_eq!(aco.best().unwrap().1, 5.0);
        let proposals = aco.ask();
        aco.tell(&[(proposals[0].clone(), 2.0)]);
        // Lower reward does not displace the best.
        assert_eq!(aco.best().unwrap().1, 5.0);
    }
}
