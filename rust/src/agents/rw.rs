//! Random Walker agent (paper §5.3, [39]).
//!
//! A population of independent walkers. Each step every walker mutates
//! one slot of its current position and moves there unconditionally — RW
//! "does not leverage history" (paper §6.4), so its reward curve is flat
//! on average and it finds good points purely by chance. The population
//! size is the only hyper-parameter the paper varies.

use super::Agent;
use crate::psa::DesignSpace;
use crate::util::Rng;

pub struct RandomWalker {
    space: DesignSpace,
    rng: Rng,
    walkers: Vec<Vec<usize>>,
}

impl RandomWalker {
    pub fn new(space: DesignSpace, population: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let walkers = (0..population.max(1))
            .map(|_| {
                space
                    .random_valid_genome(&mut rng, 2000)
                    .unwrap_or_else(|| space.baseline.clone())
            })
            .collect();
        Self { space, rng, walkers }
    }

    pub fn population(&self) -> usize {
        self.walkers.len()
    }
}

impl Agent for RandomWalker {
    fn name(&self) -> &'static str {
        "RW"
    }

    fn ask(&mut self) -> Vec<Vec<usize>> {
        let mut proposals = Vec::with_capacity(self.walkers.len());
        for w in &mut self.walkers {
            // Mutate until valid (bounded), else stay put.
            let mut next = self.space.mutate_one(w, &mut self.rng);
            for _ in 0..50 {
                if self.space.is_valid(&next) {
                    break;
                }
                next = self.space.mutate_one(w, &mut self.rng);
            }
            if !self.space.is_valid(&next) {
                next = w.clone();
            }
            *w = next.clone();
            proposals.push(next);
        }
        proposals
    }

    fn tell(&mut self, _results: &[(Vec<usize>, f64)]) {
        // Memoryless by design.
    }

    fn space(&self) -> &DesignSpace {
        &self.space
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::pss::{Pss, SearchScope};
    use crate::sim::presets;
    use crate::workload::Parallelization;

    fn space() -> DesignSpace {
        Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        )
        .build_space(SearchScope::FullStack)
    }

    #[test]
    fn proposals_match_population() {
        let mut rw = RandomWalker::new(space(), 5, 1);
        assert_eq!(rw.population(), 5);
        assert_eq!(rw.ask().len(), 5);
    }

    #[test]
    fn all_proposals_are_valid() {
        let mut rw = RandomWalker::new(space(), 6, 2);
        for _ in 0..5 {
            for g in rw.ask() {
                assert!(rw.space.is_valid(&g));
            }
        }
    }

    #[test]
    fn walkers_actually_move() {
        let mut rw = RandomWalker::new(space(), 1, 3);
        let a = rw.ask()[0].clone();
        let mut moved = false;
        for _ in 0..10 {
            if rw.ask()[0] != a {
                moved = true;
                break;
            }
        }
        assert!(moved);
    }

    #[test]
    fn zero_population_clamps_to_one() {
        let rw = RandomWalker::new(space(), 0, 4);
        assert_eq!(rw.population(), 1);
    }
}
