//! ML search agents (paper §5.3).
//!
//! Four agents, matching the paper's selection: **Random Walker** (RW),
//! **Genetic Algorithm** (GA), **Ant Colony Optimization** (ACO) and
//! **Bayesian Optimization** (BO). All speak the same [`Agent`] interface
//! — the PsA/PSS guarantee (§4.3) that *"any agent can be integrated
//! without modification"*: agents see only genomes (one integer index per
//! parameter slot) and scalar rewards; they never touch domain objects.
//!
//! The paper's agent hyper-parameters (§5.3): RW varies population size;
//! GA population size and mutation probability; ACO number of ants,
//! greediness and evaporation rate; BO the surrogate's random seed.

pub mod aco;
pub mod bo;
pub mod ga;
pub mod gp;
pub mod rw;

pub use aco::AntColony;
pub use bo::BayesOpt;
pub use ga::Genetic;
pub use rw::RandomWalker;

use crate::psa::DesignSpace;

/// The agent⇄environment contract: `ask` proposes genomes, `tell`
/// reports their rewards (same order). Invalid proposals receive reward 0
/// like any other bad configuration — agents must learn to avoid them.
pub trait Agent {
    fn name(&self) -> &'static str;

    /// Propose the next batch of genomes to evaluate.
    fn ask(&mut self) -> Vec<Vec<usize>>;

    /// Observe rewards for the genomes returned by the last `ask`.
    fn tell(&mut self, results: &[(Vec<usize>, f64)]);

    /// The action space the agent searches (set by the PSS).
    fn space(&self) -> &DesignSpace;
}

/// Agent kinds, for CLI/bench construction by name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AgentKind {
    Rw,
    Ga,
    Aco,
    Bo,
}

impl AgentKind {
    pub const ALL: [AgentKind; 4] = [AgentKind::Rw, AgentKind::Ga, AgentKind::Aco, AgentKind::Bo];

    pub fn name(&self) -> &'static str {
        match self {
            AgentKind::Rw => "RW",
            AgentKind::Ga => "GA",
            AgentKind::Aco => "ACO",
            AgentKind::Bo => "BO",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s.trim().to_ascii_uppercase().as_str() {
            "RW" | "RANDOM" | "RANDOM-WALKER" => Some(AgentKind::Rw),
            "GA" | "GENETIC" => Some(AgentKind::Ga),
            "ACO" | "ANT" | "ANT-COLONY" => Some(AgentKind::Aco),
            "BO" | "BAYES" | "BAYESIAN" => Some(AgentKind::Bo),
            _ => None,
        }
    }

    /// Construct the agent with paper-like default hyper-parameters.
    pub fn build(&self, space: DesignSpace, seed: u64) -> Box<dyn Agent> {
        match self {
            AgentKind::Rw => Box::new(RandomWalker::new(space, 8, seed)),
            AgentKind::Ga => Box::new(Genetic::new(space, 16, 0.15, seed)),
            AgentKind::Aco => Box::new(AntColony::new(space, 12, 2.0, 0.1, seed)),
            AgentKind::Bo => Box::new(BayesOpt::new(space, 64, seed)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::pss::{Pss, SearchScope};
    use crate::sim::presets;
    use crate::workload::Parallelization;

    fn space() -> DesignSpace {
        let pss = Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        );
        pss.build_space(SearchScope::FullStack)
    }

    #[test]
    fn from_name_roundtrips() {
        for k in AgentKind::ALL {
            assert_eq!(AgentKind::from_name(k.name()), Some(k));
        }
        assert_eq!(AgentKind::from_name("zzz"), None);
    }

    #[test]
    fn all_agents_ask_tell_cycle() {
        let sp = space();
        for kind in AgentKind::ALL {
            let mut agent = kind.build(sp.clone(), 42);
            for step in 0..3 {
                let proposals = agent.ask();
                assert!(!proposals.is_empty(), "{} step {step}: empty ask", kind.name());
                for g in &proposals {
                    assert_eq!(g.len(), sp.schema.genome_len(), "{}", kind.name());
                }
                let results: Vec<(Vec<usize>, f64)> =
                    proposals.into_iter().map(|g| (g, 0.5)).collect();
                agent.tell(&results);
            }
        }
    }

    #[test]
    fn agents_are_deterministic_given_seed() {
        let sp = space();
        for kind in AgentKind::ALL {
            let mut a = kind.build(sp.clone(), 7);
            let mut b = kind.build(sp.clone(), 7);
            let pa = a.ask();
            let pb = b.ask();
            assert_eq!(pa, pb, "{} not deterministic", kind.name());
        }
    }
}
