//! Bayesian Optimization agent (paper §5.3, [32]).
//!
//! GP surrogate (RBF over genomes normalized to the unit hypercube) +
//! Expected Improvement acquisition maximized over a random valid
//! candidate pool. The paper "randomizes the surrogate model by varying
//! the random seed of the underlying Gaussian process" — the seed here
//! drives both the initial design and the candidate pools.
//!
//! The GP fit/predict math has an AOT-compiled JAX twin
//! (`artifacts/gp_surrogate.hlo.txt`); when a [`runtime::GpSurrogate`]
//! hook is installed the posterior is evaluated through XLA, otherwise
//! the pure-Rust [`Gp`] is used. Both implement the same equations.

use super::gp::Gp;
use super::Agent;
use crate::psa::DesignSpace;
use crate::util::Rng;

/// Posterior evaluation hook — satisfied by `runtime::GpSurrogate` (XLA)
/// and by the built-in Rust GP. (Not `Send`: the PJRT client handle is
/// `Rc`-based; the DSE loop is single-threaded by design.)
pub trait Surrogate {
    /// Fit on (normalized xs, ys); return false if the fit failed.
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> bool;
    /// Posterior (mean, variance) at one normalized query.
    fn predict(&self, q: &[f64]) -> (f64, f64);
}

/// Default surrogate: the pure-Rust GP.
struct RustSurrogate {
    gp: Option<Gp>,
    lengthscale: f64,
}

impl Surrogate for RustSurrogate {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> bool {
        match Gp::fit(xs.to_vec(), ys, self.lengthscale, 1.0, 1e-4) {
            Ok(gp) => {
                self.gp = Some(gp);
                true
            }
            Err(_) => false,
        }
    }

    fn predict(&self, q: &[f64]) -> (f64, f64) {
        match &self.gp {
            Some(gp) => gp.predict(q),
            None => (0.0, 1.0),
        }
    }
}

pub struct BayesOpt {
    space: DesignSpace,
    rng: Rng,
    /// Observed (genome, normalized genome, reward).
    history: Vec<(Vec<usize>, Vec<f64>, f64)>,
    surrogate: Box<dyn Surrogate>,
    /// Candidate pool size per acquisition round.
    pub pool: usize,
    /// Initial random design before the GP kicks in.
    pub init_points: usize,
    /// Cap on GP training set (most recent + best kept).
    pub max_train: usize,
    asked_init: usize,
}

impl BayesOpt {
    pub fn new(space: DesignSpace, pool: usize, seed: u64) -> Self {
        let lengthscale = 0.2 * (space.free_slots.len().max(1) as f64).sqrt();
        Self {
            space,
            rng: Rng::seed_from_u64(seed),
            history: Vec::new(),
            surrogate: Box::new(RustSurrogate { gp: None, lengthscale }),
            pool: pool.max(8),
            init_points: 8,
            max_train: 160,
            asked_init: 0,
        }
    }

    /// Install a different surrogate (e.g. the XLA-backed one).
    pub fn with_surrogate(mut self, surrogate: Box<dyn Surrogate>) -> Self {
        self.surrogate = surrogate;
        self
    }

    /// Normalize a genome to the unit hypercube over free slots.
    fn normalize(&self, g: &[usize]) -> Vec<f64> {
        self.space
            .free_slots
            .iter()
            .map(|&s| {
                let card = self.space.slot_cards[s].max(2);
                g[s] as f64 / (card - 1) as f64
            })
            .collect()
    }

    fn best_reward(&self) -> f64 {
        self.history.iter().map(|(_, _, r)| *r).fold(f64::NEG_INFINITY, f64::max)
    }

    fn refit(&mut self) -> bool {
        if self.history.is_empty() {
            return false;
        }
        // Training subset: keep the best quarter + most recent.
        let mut idx: Vec<usize> = (0..self.history.len()).collect();
        if self.history.len() > self.max_train {
            idx.sort_by(|&a, &b| {
                self.history[b].2.partial_cmp(&self.history[a].2).unwrap()
            });
            let keep_best = self.max_train / 4;
            let mut chosen: Vec<usize> = idx[..keep_best].to_vec();
            let recent_start = self.history.len() - (self.max_train - keep_best);
            chosen.extend(recent_start..self.history.len());
            chosen.sort_unstable();
            chosen.dedup();
            idx = chosen;
        }
        let xs: Vec<Vec<f64>> = idx.iter().map(|&i| self.history[i].1.clone()).collect();
        let ys: Vec<f64> = idx.iter().map(|&i| self.history[i].2).collect();
        self.surrogate.fit(&xs, &ys)
    }

    fn acquisition(&self, q: &[f64], best: f64) -> f64 {
        let (mu, var) = self.surrogate.predict(q);
        let sigma = var.max(1e-12).sqrt();
        // Expected improvement (same closed form as Gp::expected_improvement,
        // but routed through the pluggable surrogate).
        let z = (mu - best) / sigma;
        let pdf = (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt();
        let cdf = 0.5 * (1.0 + erf_local(z / std::f64::consts::SQRT_2));
        ((mu - best) * cdf + sigma * pdf).max(0.0)
    }
}

fn erf_local(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

impl Agent for BayesOpt {
    fn name(&self) -> &'static str {
        "BO"
    }

    fn ask(&mut self) -> Vec<Vec<usize>> {
        // Phase 1: initial random design.
        if self.asked_init < self.init_points {
            self.asked_init += 1;
            let g = self
                .space
                .random_valid_genome(&mut self.rng, 2000)
                .unwrap_or_else(|| self.space.baseline.clone());
            return vec![g];
        }
        // Phase 2: fit GP, maximize EI over a random valid pool.
        if !self.refit() {
            let g = self
                .space
                .random_valid_genome(&mut self.rng, 2000)
                .unwrap_or_else(|| self.space.baseline.clone());
            return vec![g];
        }
        let best = self.best_reward();
        let mut best_g: Option<(Vec<usize>, f64)> = None;
        for _ in 0..self.pool {
            if let Some(g) = self.space.random_valid_genome(&mut self.rng, 200) {
                let q = self.normalize(&g);
                let ei = self.acquisition(&q, best);
                if best_g.as_ref().map(|(_, b)| ei > *b).unwrap_or(true) {
                    best_g = Some((g, ei));
                }
            }
        }
        vec![best_g.map(|(g, _)| g).unwrap_or_else(|| self.space.baseline.clone())]
    }

    fn tell(&mut self, results: &[(Vec<usize>, f64)]) {
        for (g, r) in results {
            let q = self.normalize(g);
            self.history.push((g.clone(), q, *r));
        }
    }

    fn space(&self) -> &DesignSpace {
        &self.space
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::pss::{Pss, SearchScope};
    use crate::sim::presets;
    use crate::workload::Parallelization;

    fn space() -> DesignSpace {
        Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        )
        .build_space(SearchScope::FullStack)
    }

    #[test]
    fn initial_design_then_model_based() {
        let mut bo = BayesOpt::new(space(), 16, 21);
        bo.init_points = 3;
        for _ in 0..5 {
            let p = bo.ask();
            assert_eq!(p.len(), 1);
            assert!(bo.space.is_valid(&p[0]));
            bo.tell(&[(p[0].clone(), 0.1)]);
        }
        assert!(bo.history.len() == 5);
    }

    #[test]
    fn normalization_maps_to_unit_cube() {
        let bo = BayesOpt::new(space(), 16, 1);
        let g = bo.space.baseline.clone();
        let q = bo.normalize(&g);
        assert_eq!(q.len(), bo.space.free_slots.len());
        assert!(q.iter().all(|&x| (0.0..=1.0).contains(&x)));
    }

    #[test]
    fn improves_on_synthetic_objective() {
        // Smooth objective over the normalized genome: BO should find
        // better points than its initial random design on average.
        let mut bo = BayesOpt::new(space(), 48, 33);
        bo.init_points = 6;
        let objective = |q: &[f64]| 1.0 - q.iter().map(|x| (x - 0.3).abs()).sum::<f64>() / q.len() as f64;
        let mut rewards = Vec::new();
        for _ in 0..40 {
            let g = bo.ask().pop().unwrap();
            let q = bo.normalize(&g);
            let r = objective(&q);
            rewards.push(r);
            bo.tell(&[(g, r)]);
        }
        let early: f64 = rewards[..6].iter().sum::<f64>() / 6.0;
        let late_best = rewards[6..].iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(late_best >= early, "late_best={late_best} early_mean={early}");
    }

    #[test]
    fn history_capping_keeps_fit_working() {
        let mut bo = BayesOpt::new(space(), 16, 5);
        bo.init_points = 2;
        bo.max_train = 20;
        for i in 0..60 {
            let g = bo.ask().pop().unwrap();
            bo.tell(&[(g, (i as f64 * 0.31).sin().abs())]);
        }
        assert_eq!(bo.history.len(), 60);
        assert!(bo.refit(), "refit must succeed with capped training set");
    }
}
