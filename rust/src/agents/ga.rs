//! Genetic Algorithm agent (paper §5.3, [21]).
//!
//! Classic generational GA over genomes: tournament selection, uniform
//! crossover, per-slot mutation, elitism of one. The paper tunes
//! population size and mutation probability; invalid offspring are
//! repaired by re-sampling the offending slots (bounded), else replaced
//! by a fresh valid genome.

use super::Agent;
use crate::psa::DesignSpace;
use crate::util::Rng;

pub struct Genetic {
    space: DesignSpace,
    rng: Rng,
    population: Vec<Vec<usize>>,
    fitness: Vec<f64>,
    pub mutation_prob: f64,
    generation: u64,
}

impl Genetic {
    pub fn new(space: DesignSpace, population: usize, mutation_prob: f64, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let population: Vec<Vec<usize>> = (0..population.max(2))
            .map(|_| {
                space
                    .random_valid_genome(&mut rng, 2000)
                    .unwrap_or_else(|| space.baseline.clone())
            })
            .collect();
        let fitness = vec![0.0; population.len()];
        Self { space, rng, population, fitness, mutation_prob, generation: 0 }
    }

    fn tournament(&mut self) -> usize {
        let a = self.rng.gen_range(self.population.len());
        let b = self.rng.gen_range(self.population.len());
        if self.fitness[a] >= self.fitness[b] {
            a
        } else {
            b
        }
    }

    fn crossover(&mut self, p1: usize, p2: usize) -> Vec<usize> {
        let (a, b) = (self.population[p1].clone(), self.population[p2].clone());
        let mut child = a;
        for (i, bv) in b.iter().enumerate() {
            if self.rng.gen_bool(0.5) {
                child[i] = *bv;
            }
        }
        child
    }

    fn mutate(&mut self, genome: &mut Vec<usize>) {
        // Iterate free slots; each flips with probability mutation_prob.
        let free = self.space.free_slots.clone();
        for s in free {
            if self.rng.gen_bool(self.mutation_prob) {
                let card = self.space.slot_cards[s];
                if card > 1 {
                    genome[s] = self.rng.gen_range(card);
                }
            }
        }
    }

    fn repair(&mut self, genome: Vec<usize>) -> Vec<usize> {
        if self.space.is_valid(&genome) {
            return genome;
        }
        let mut g = genome;
        for _ in 0..100 {
            g = self.space.mutate_one(&g, &mut self.rng);
            if self.space.is_valid(&g) {
                return g;
            }
        }
        self.space
            .random_valid_genome(&mut self.rng, 2000)
            .unwrap_or_else(|| self.space.baseline.clone())
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }
}

impl Agent for Genetic {
    fn name(&self) -> &'static str {
        "GA"
    }

    fn ask(&mut self) -> Vec<Vec<usize>> {
        if self.generation == 0 {
            // First generation: evaluate the random initial population.
            return self.population.clone();
        }
        // Elite carries over; the rest are offspring.
        let elite = self
            .fitness
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        let mut next = vec![self.population[elite].clone()];
        while next.len() < self.population.len() {
            let p1 = self.tournament();
            let p2 = self.tournament();
            let mut child = self.crossover(p1, p2);
            self.mutate(&mut child);
            next.push(self.repair(child));
        }
        self.population = next.clone();
        next
    }

    fn tell(&mut self, results: &[(Vec<usize>, f64)]) {
        // Results arrive in ask-order == population order.
        for (i, (_, reward)) in results.iter().enumerate() {
            if i < self.fitness.len() {
                self.fitness[i] = *reward;
            }
        }
        self.generation += 1;
    }

    fn space(&self) -> &DesignSpace {
        &self.space
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psa::paper_table4_schema;
    use crate::pss::{Pss, SearchScope};
    use crate::sim::presets;
    use crate::workload::Parallelization;

    fn space() -> DesignSpace {
        Pss::new(
            paper_table4_schema(1024, 4),
            presets::system2(),
            Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
        )
        .build_space(SearchScope::FullStack)
    }

    fn reward(g: &[usize]) -> f64 {
        // Synthetic smooth objective: prefer small slot indices.
        1.0 / (1.0 + g.iter().map(|&x| x as f64).sum::<f64>())
    }

    #[test]
    fn improves_on_synthetic_objective() {
        let mut ga = Genetic::new(space(), 24, 0.1, 11);
        let mut first_best = 0.0f64;
        let mut last_best = 0.0f64;
        for gen in 0..30 {
            let proposals = ga.ask();
            let results: Vec<(Vec<usize>, f64)> =
                proposals.into_iter().map(|g| (g.clone(), reward(&g))).collect();
            let best = results.iter().map(|r| r.1).fold(0.0, f64::max);
            if gen == 0 {
                first_best = best;
            }
            last_best = last_best.max(best);
            ga.tell(&results);
        }
        assert!(last_best >= first_best, "GA regressed: {last_best} < {first_best}");
    }

    #[test]
    fn elite_survives() {
        let mut ga = Genetic::new(space(), 8, 0.2, 5);
        let proposals = ga.ask();
        // Give genome 3 a huge reward.
        let results: Vec<(Vec<usize>, f64)> = proposals
            .iter()
            .enumerate()
            .map(|(i, g)| (g.clone(), if i == 3 { 100.0 } else { 0.1 }))
            .collect();
        let champion = proposals[3].clone();
        ga.tell(&results);
        let next = ga.ask();
        assert_eq!(next[0], champion, "elite must carry over as first member");
    }

    #[test]
    fn offspring_are_valid() {
        let mut ga = Genetic::new(space(), 10, 0.3, 9);
        let proposals = ga.ask();
        let results: Vec<(Vec<usize>, f64)> =
            proposals.into_iter().map(|g| (g.clone(), reward(&g))).collect();
        ga.tell(&results);
        for g in ga.ask() {
            assert!(ga.space.is_valid(&g));
        }
    }

    #[test]
    fn population_clamps_to_two() {
        let ga = Genetic::new(space(), 0, 0.1, 1);
        assert_eq!(ga.population.len(), 2);
    }
}
