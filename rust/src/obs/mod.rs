//! Observability: tracing, metrics and search telemetry.
//!
//! Dependency-free and zero-cost when disabled, this layer answers
//! *why* a configuration wins rather than only *how fast* it is:
//!
//! - [`trace`] — a [`TraceSink`] span API threaded through
//!   [`crate::sim::Simulator`] and the `netsim` backends. The default
//!   [`NoopSink`] is disabled, so pricing stays bit-identical to an
//!   un-instrumented run; attach a [`Recorder`] (see
//!   `cosmic simulate --trace out.json`) to capture the hierarchical
//!   timeline — iteration → pipeline slots → per-op compute/collective
//!   phases → per-dimension network drains — as Chrome/Perfetto JSON.
//! - [`metrics`] — a lock-sharded [`MetricsRegistry`] of counters,
//!   gauges and histograms (p50/p95/p99 via `util::stats`), snapshotted
//!   deterministically as text or JSON.
//! - [`timeline`] — a [`SearchTimeline`] of every DSE step (genome
//!   fingerprint, fidelity rung, reward, cache outcome, wall time) fed
//!   by a [`SearchObserver`] attached to [`crate::dse::DseRunner`]
//!   (see `cosmic search --telemetry telemetry.json`).

pub mod metrics;
pub mod timeline;
pub mod trace;

pub use metrics::{HistogramSummary, MetricsRegistry, MetricsSnapshot};
pub use timeline::{
    invalid_category, CacheOutcome, Rung, SearchObserver, SearchStepRecord, SearchTimeline,
};
pub use trace::{
    chrome_events, chrome_trace_json, tracks, ChromeEvent, NoopSink, Recorder, SpanRec, TraceSink,
    Track,
};
