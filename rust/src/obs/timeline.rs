//! Per-step DSE telemetry: a [`SearchTimeline`] of every evaluated
//! genome (fingerprint, fidelity rung, reward, cache outcome, wall
//! time) plus the [`SearchObserver`] that [`crate::dse::DseRunner`]
//! feeds when one is attached. Staged-search promotions stay
//! reconstructable post-hoc: finalists carry both their screening-rung
//! and flow-level rewards.

use super::metrics::MetricsRegistry;
use std::sync::Mutex;
use std::time::Instant;

/// The fidelity rung a step was evaluated at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rung {
    /// Fidelity chosen by the genome's own network-fidelity gene.
    GenomeKnob,
    /// Forced closed-form backend.
    Analytical,
    /// Forced flow-level backend.
    FlowLevel,
    /// Forced packet-level backend.
    Packet,
}

impl Rung {
    pub fn name(&self) -> &'static str {
        match self {
            Rung::GenomeKnob => "genome-knob",
            Rung::Analytical => "analytical",
            Rung::FlowLevel => "flow-level",
            Rung::Packet => "packet",
        }
    }

    fn counter_name(&self) -> &'static str {
        match self {
            Rung::GenomeKnob => "dse.evals.rung.genome_knob",
            Rung::Analytical => "dse.evals.rung.analytical",
            Rung::FlowLevel => "dse.evals.rung.flow_level",
            Rung::Packet => "dse.evals.rung.packet",
        }
    }
}

/// Whether the step was served from the per-genome memo.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    Hit,
    Miss,
}

/// One DSE step as the runner saw it.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchStepRecord {
    /// 1-based step index within the run.
    pub step: u64,
    /// [`crate::util::hash64`] fingerprint of the genome.
    pub genome_fp: u64,
    pub rung: Rung,
    pub reward: f64,
    pub best_so_far: f64,
    pub cache: CacheOutcome,
    /// Wall time attributed to this step (batch wall / batch size).
    pub wall_us: f64,
    /// Set when the genome was invalid; the category from
    /// [`invalid_category`].
    pub invalid_kind: Option<String>,
}

/// Ordered record of a whole search run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchTimeline {
    pub steps: Vec<SearchStepRecord>,
    /// Staged-search finalists as (genome fingerprint, screening-rung
    /// reward, flow-level reward).
    pub finalists: Vec<(u64, f64, f64)>,
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

impl SearchTimeline {
    /// Serialize as a JSON object with `steps` and `finalists` arrays.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n\"steps\":[");
        for (i, s) in self.steps.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let invalid = match &s.invalid_kind {
                Some(k) => format!("\"{k}\""),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "\n{{\"step\":{},\"genome_fp\":\"{:016x}\",\"rung\":\"{}\",\"reward\":{},\
                 \"best\":{},\"cache\":\"{}\",\"wall_us\":{},\"invalid\":{}}}",
                s.step,
                s.genome_fp,
                s.rung.name(),
                json_num(s.reward),
                json_num(s.best_so_far),
                match s.cache {
                    CacheOutcome::Hit => "hit",
                    CacheOutcome::Miss => "miss",
                },
                json_num(s.wall_us),
                invalid
            ));
        }
        out.push_str("\n],\n\"finalists\":[");
        for (i, (fp, screen, flow)) in self.finalists.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n{{\"genome_fp\":\"{:016x}\",\"screen_reward\":{},\"flow_reward\":{}}}",
                fp,
                json_num(*screen),
                json_num(*flow)
            ));
        }
        out.push_str("\n]\n}\n");
        out
    }
}

/// Reduce an invalid-genome reason to a low-cardinality counter label:
/// the leading alphanumeric run, lowercased (`"Memory { .. }"` →
/// `"memory"`, `"Config(..)"` → `"config"`), or `"other"`.
pub fn invalid_category(reason: &str) -> String {
    let cat: String =
        reason.chars().take_while(|c| c.is_ascii_alphanumeric()).collect::<String>().to_lowercase();
    if cat.is_empty() {
        "other".to_string()
    } else {
        cat
    }
}

/// Collects per-step records and aggregates them into a
/// [`MetricsRegistry`]; optionally prints a progress line every
/// `progress_every` steps (to stderr, keeping stdout parseable).
#[derive(Debug)]
pub struct SearchObserver {
    pub metrics: MetricsRegistry,
    timeline: Mutex<SearchTimeline>,
    progress_every: u64,
    started: Instant,
}

impl SearchObserver {
    pub fn new() -> Self {
        Self {
            metrics: MetricsRegistry::new(),
            timeline: Mutex::new(SearchTimeline::default()),
            progress_every: 0,
            started: Instant::now(),
        }
    }

    /// Print a progress line every `every` steps (0 = never).
    pub fn with_progress(mut self, every: u64) -> Self {
        self.progress_every = every;
        self
    }

    /// Record one step: appends to the timeline and updates step,
    /// cache-outcome, per-rung, reward and invalid-reason metrics.
    pub fn record_step(&self, rec: SearchStepRecord, total_steps: u64) {
        let m = &self.metrics;
        m.inc("dse.steps");
        m.inc(match rec.cache {
            CacheOutcome::Hit => "dse.evals.cache_hit",
            CacheOutcome::Miss => "dse.evals.cache_miss",
        });
        m.inc(rec.rung.counter_name());
        match &rec.invalid_kind {
            Some(kind) => m.inc(&format!("dse.invalid.{kind}")),
            None => m.observe("dse.reward", rec.reward),
        }
        m.observe("dse.step_wall_us", rec.wall_us);
        if self.progress_every > 0 && rec.step % self.progress_every == 0 {
            let secs = self.started.elapsed().as_secs_f64().max(1e-9);
            eprintln!(
                "[search] step {:>5}/{} reward {:>12.4e} best {:>12.4e} ({:.0} steps/s)",
                rec.step,
                total_steps,
                rec.reward,
                rec.best_so_far,
                rec.step as f64 / secs
            );
        }
        self.timeline.lock().unwrap().steps.push(rec);
    }

    /// Record staged-search finalists (fingerprint, screen reward,
    /// flow reward).
    pub fn record_finalists(&self, finalists: &[(u64, f64, f64)]) {
        self.metrics.add("dse.finalists", finalists.len() as u64);
        self.timeline.lock().unwrap().finalists.extend_from_slice(finalists);
    }

    /// Snapshot of the timeline recorded so far.
    pub fn timeline(&self) -> SearchTimeline {
        self.timeline.lock().unwrap().clone()
    }

    /// Combined `{"metrics": .., "timeline": ..}` JSON document — the
    /// payload behind `cosmic search --telemetry`.
    pub fn telemetry_json(&self) -> String {
        format!(
            "{{\n\"metrics\":{},\n\"timeline\":{}\n}}\n",
            self.metrics.snapshot().to_json(),
            self.timeline().to_json()
        )
    }
}

impl Default for SearchObserver {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(i: u64, cache: CacheOutcome, invalid: Option<&str>) -> SearchStepRecord {
        SearchStepRecord {
            step: i,
            genome_fp: 0xabcd + i,
            rung: Rung::Analytical,
            reward: 1.0 / i as f64,
            best_so_far: 1.0,
            cache,
            wall_us: 10.0,
            invalid_kind: invalid.map(invalid_category),
        }
    }

    #[test]
    fn invalid_categories_are_low_cardinality() {
        assert_eq!(invalid_category("Memory { need_bytes: 1.0, budget_bytes: 0.5 }"), "memory");
        assert_eq!(invalid_category("Config(\"tp too large\")"), "config");
        assert_eq!(invalid_category("!?"), "other");
    }

    #[test]
    fn observer_aggregates_steps() {
        let obs = SearchObserver::new();
        obs.record_step(step(1, CacheOutcome::Miss, None), 3);
        obs.record_step(step(2, CacheOutcome::Hit, None), 3);
        obs.record_step(step(3, CacheOutcome::Miss, Some("Memory { .. }")), 3);
        obs.record_finalists(&[(1, 0.5, 0.4)]);
        let m = &obs.metrics;
        assert_eq!(m.counter("dse.steps"), 3);
        assert_eq!(m.counter("dse.evals.cache_hit"), 1);
        assert_eq!(m.counter("dse.evals.cache_miss"), 2);
        assert_eq!(m.counter("dse.evals.rung.analytical"), 3);
        assert_eq!(m.counter("dse.invalid.memory"), 1);
        assert_eq!(m.counter("dse.finalists"), 1);
        let tl = obs.timeline();
        assert_eq!(tl.steps.len(), 3);
        assert_eq!(tl.finalists, vec![(1, 0.5, 0.4)]);
        // Rewards of invalid steps stay out of the reward histogram.
        assert_eq!(obs.metrics.snapshot().histograms["dse.reward"].count, 2);
        crate::util::json::validate(&obs.telemetry_json()).unwrap();
    }
}
