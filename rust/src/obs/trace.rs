//! Hierarchical timed spans and the Chrome-trace exporter.
//!
//! The simulator is analytical: nothing "runs", so span timestamps are
//! **simulated microseconds** (the same unit as [`crate::sim::SimReport::latency_us`],
//! before the layer-scale extrapolation), not wall time. A [`TraceSink`]
//! is threaded through [`crate::sim::Simulator`] and the network
//! backends; the default [`NoopSink`] reports `enabled() == false` so
//! every emission site is skipped and the priced report is bit-identical
//! to an un-instrumented run. A [`Recorder`] captures spans and exports
//! them as Chrome `chrome://tracing` / Perfetto JSON.
//!
//! Export guarantees (asserted by `tests/obs_trace.rs`):
//! - every `"B"` event has a matching `"E"` on the same pid/tid,
//! - timestamps are non-decreasing per track,
//! - overlapping spans on one track are nested by clamping a child's
//!   end to its enclosing span's end (the simulator only emits properly
//!   nested or disjoint spans per track, so clamping is a no-op there).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

/// A (process, thread) pair naming one horizontal lane in the trace UI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Track {
    pub pid: u32,
    pub tid: u32,
}

/// Well-known tracks. Constant pids/tids keep two runs of the same
/// configuration byte-comparable (the golden/determinism tests rely on
/// this).
pub mod tracks {
    use super::Track;

    /// Simulator-side process: pipeline schedule, per-op walks,
    /// gradient-sync windows.
    pub const SIM_PID: u32 = 1;
    /// Network-side process: drain admissions and per-dimension flows.
    pub const NET_PID: u32 = 2;
    /// Fault-injection process: active scenario elements (stragglers,
    /// degraded links, failure model) as iteration-wide spans.
    pub const FAULT_PID: u32 = 3;
    /// Co-tenant traffic process: busy intervals of the attached
    /// traffic trace, one lane per topology dimension.
    pub const TRAFFIC_PID: u32 = 4;

    /// Iteration window and per-microbatch pipeline slots.
    pub const PIPELINE: Track = Track { pid: SIM_PID, tid: 1 };
    /// Per-op forward walk of the first microbatch.
    pub const FWD_OPS: Track = Track { pid: SIM_PID, tid: 2 };
    /// Per-op backward walk of the last microbatch.
    pub const BWD_OPS: Track = Track { pid: SIM_PID, tid: 3 };
    /// Per-layer gradient-sync [issue, done] windows.
    pub const GRAD_SYNC: Track = Track { pid: SIM_PID, tid: 4 };
    /// Serialized (analytical) gradient drain: one busy span per job.
    pub const SERIAL_DRAIN: Track = Track { pid: NET_PID, tid: 1 };
    /// Active fault-scenario elements (see [`crate::faults`]).
    pub const FAULTS: Track = Track { pid: FAULT_PID, tid: 1 };
    /// First tid of the per-topology-dimension flow tracks.
    pub const NET_DIM_BASE: u32 = 16;
    /// First tid of the per-(dimension, ECMP path) packet-queue tracks.
    pub const NET_QUEUE_BASE: u32 = 64;
    /// Queue tracks reserved per dimension (paths beyond this fold onto
    /// the last track).
    pub const NET_QUEUE_PORTS: u32 = 8;

    /// Track showing flow occupancy of topology dimension `dim`.
    pub fn net_dim(dim: usize) -> Track {
        Track { pid: NET_PID, tid: NET_DIM_BASE + dim as u32 }
    }

    /// Track showing co-tenant traffic utilization intervals of
    /// topology dimension `dim`.
    pub fn traffic_dim(dim: usize) -> Track {
        Track { pid: TRAFFIC_PID, tid: 1 + dim as u32 }
    }

    /// Track showing packet-queue busy windows of `(dim, path)` on the
    /// packet-level rung.
    pub fn net_queue(dim: usize, path: usize) -> Track {
        let port = (path as u32).min(NET_QUEUE_PORTS - 1);
        Track { pid: NET_PID, tid: NET_QUEUE_BASE + dim as u32 * NET_QUEUE_PORTS + port }
    }

    /// Process name used in Chrome metadata events.
    pub fn process_name(pid: u32) -> &'static str {
        match pid {
            SIM_PID => "simulator",
            NET_PID => "network",
            FAULT_PID => "faults",
            TRAFFIC_PID => "traffic",
            _ => "cosmic",
        }
    }

    /// Thread name used in Chrome metadata events.
    pub fn thread_name(pid: u32, tid: u32) -> String {
        match (pid, tid) {
            (SIM_PID, 1) => "pipeline".to_string(),
            (SIM_PID, 2) => "fwd ops (microbatch 0)".to_string(),
            (SIM_PID, 3) => "bwd ops (last microbatch)".to_string(),
            (SIM_PID, 4) => "gradient sync".to_string(),
            (NET_PID, 1) => "serial drain".to_string(),
            (FAULT_PID, 1) => "fault injection".to_string(),
            (TRAFFIC_PID, t) => format!("co-tenant dim {}", t - 1),
            (NET_PID, t) if t >= NET_QUEUE_BASE => format!(
                "pkt queue dim {} port {}",
                (t - NET_QUEUE_BASE) / NET_QUEUE_PORTS,
                (t - NET_QUEUE_BASE) % NET_QUEUE_PORTS
            ),
            (NET_PID, t) if t >= NET_DIM_BASE => format!("net dim {}", t - NET_DIM_BASE),
            (_, t) => format!("track {t}"),
        }
    }
}

/// Consumer of timed spans. Implementations must be cheap to query:
/// every emission site guards on [`TraceSink::enabled`] before doing
/// any formatting work, so a disabled sink costs one virtual call per
/// instrumented region.
pub trait TraceSink: std::fmt::Debug + Send + Sync {
    /// Whether spans should be emitted at all.
    fn enabled(&self) -> bool;
    /// Record one closed span on `track` covering `[start_us, end_us]`.
    fn span(&self, track: Track, name: &str, start_us: f64, end_us: f64);
}

/// The default sink: disabled, drops everything.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn enabled(&self) -> bool {
        false
    }
    fn span(&self, _track: Track, _name: &str, _start_us: f64, _end_us: f64) {}
}

/// One recorded span.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRec {
    pub pid: u32,
    pub tid: u32,
    pub name: String,
    pub start_us: f64,
    pub end_us: f64,
}

/// A [`TraceSink`] that buffers spans for export.
#[derive(Debug, Default)]
pub struct Recorder {
    spans: Mutex<Vec<SpanRec>>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Snapshot of all recorded spans, in emission order.
    pub fn spans(&self) -> Vec<SpanRec> {
        self.spans.lock().unwrap().clone()
    }

    /// Number of spans recorded so far.
    pub fn span_count(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// Drop all recorded spans (the buffer is reused).
    pub fn clear(&self) {
        self.spans.lock().unwrap().clear();
    }

    /// Export everything recorded so far as Chrome-trace JSON.
    pub fn chrome_trace_json(&self) -> String {
        chrome_trace_json(&self.spans())
    }
}

impl TraceSink for Recorder {
    fn enabled(&self) -> bool {
        true
    }

    fn span(&self, track: Track, name: &str, start_us: f64, end_us: f64) {
        self.spans.lock().unwrap().push(SpanRec {
            pid: track.pid,
            tid: track.tid,
            name: name.to_string(),
            start_us,
            end_us,
        });
    }
}

/// One Chrome duration event ready for serialization (`ph` is `'B'` or
/// `'E'`). Exposed so tests can assert balance/monotonicity without
/// parsing JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct ChromeEvent {
    pub ph: char,
    pub ts: f64,
    pub pid: u32,
    pub tid: u32,
    pub name: String,
}

/// Lower spans to balanced `B`/`E` duration events, per track.
///
/// Per track, spans are sorted by (start asc, end desc, name) so an
/// enclosing span precedes its children; a stack then closes spans as
/// soon as the next start passes their end. A child whose end exceeds
/// its parent's is clamped to the parent end, which makes balance and
/// per-track timestamp monotonicity hold by construction for any input.
/// Non-finite spans are dropped; `end < start` is clamped to zero width.
pub fn chrome_events(spans: &[SpanRec]) -> Vec<ChromeEvent> {
    let mut by_track: BTreeMap<(u32, u32), Vec<&SpanRec>> = BTreeMap::new();
    for s in spans {
        if !s.start_us.is_finite() || !s.end_us.is_finite() {
            continue;
        }
        by_track.entry((s.pid, s.tid)).or_default().push(s);
    }
    let mut out = Vec::new();
    for ((pid, tid), mut group) in by_track {
        group.sort_by(|a, b| {
            a.start_us
                .partial_cmp(&b.start_us)
                .unwrap()
                .then(b.end_us.partial_cmp(&a.end_us).unwrap())
                .then_with(|| a.name.cmp(&b.name))
        });
        let mut open_ends: Vec<f64> = Vec::new();
        for s in group {
            while open_ends.last().is_some_and(|&end| end <= s.start_us) {
                let ts = open_ends.pop().unwrap();
                out.push(ChromeEvent { ph: 'E', ts, pid, tid, name: String::new() });
            }
            let mut end = s.end_us.max(s.start_us);
            if let Some(&parent_end) = open_ends.last() {
                end = end.min(parent_end);
            }
            out.push(ChromeEvent { ph: 'B', ts: s.start_us, pid, tid, name: s.name.clone() });
            open_ends.push(end);
        }
        while let Some(ts) = open_ends.pop() {
            out.push(ChromeEvent { ph: 'E', ts, pid, tid, name: String::new() });
        }
    }
    out
}

/// Serialize spans as a Chrome-trace / Perfetto JSON object
/// (`{"traceEvents": [...]}`), including process/thread-name metadata
/// for every track present. Deterministic for identical input.
pub fn chrome_trace_json(spans: &[SpanRec]) -> String {
    let events = chrome_events(spans);
    let mut pids: BTreeSet<u32> = BTreeSet::new();
    let mut seen: BTreeSet<(u32, u32)> = BTreeSet::new();
    for e in &events {
        pids.insert(e.pid);
        seen.insert((e.pid, e.tid));
    }
    let mut items: Vec<String> = Vec::with_capacity(events.len() + seen.len() + pids.len());
    for pid in &pids {
        items.push(format!(
            "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{},\"tid\":0,\
             \"args\":{{\"name\":\"{}\"}}}}",
            pid,
            escape(tracks::process_name(*pid))
        ));
    }
    for (pid, tid) in &seen {
        items.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":{},\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            pid,
            tid,
            escape(&tracks::thread_name(*pid, *tid))
        ));
    }
    for e in &events {
        items.push(match e.ph {
            'B' => format!(
                "{{\"name\":\"{}\",\"cat\":\"cosmic\",\"ph\":\"B\",\"ts\":{:.3},\
                 \"pid\":{},\"tid\":{}}}",
                escape(&e.name),
                e.ts,
                e.pid,
                e.tid
            ),
            _ => format!(
                "{{\"ph\":\"E\",\"ts\":{:.3},\"pid\":{},\"tid\":{}}}",
                e.ts, e.pid, e.tid
            ),
        });
    }
    let mut out = String::with_capacity(items.iter().map(|i| i.len() + 2).sum::<usize>() + 64);
    out.push_str("{\"traceEvents\":[");
    for (i, item) in items.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\n');
        out.push_str(item);
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

/// Escape a string for embedding in a JSON string literal.
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(tid: u32, name: &str, start: f64, end: f64) -> SpanRec {
        SpanRec {
            pid: 1,
            tid,
            name: name.to_string(),
            start_us: start,
            end_us: end,
        }
    }

    fn balance(events: &[ChromeEvent]) -> i64 {
        events.iter().map(|e| if e.ph == 'B' { 1 } else { -1 }).sum()
    }

    #[test]
    fn noop_sink_is_disabled() {
        assert!(!NoopSink.enabled());
    }

    #[test]
    fn nested_spans_emit_balanced_events() {
        let spans = vec![
            span(1, "outer", 0.0, 10.0),
            span(1, "inner", 2.0, 5.0),
            span(1, "tail", 6.0, 9.0),
        ];
        let ev = chrome_events(&spans);
        assert_eq!(balance(&ev), 0);
        // B outer, B inner, E inner, B tail, E tail, E outer.
        let phases: String = ev.iter().map(|e| e.ph).collect();
        assert_eq!(phases, "BBEBEE");
        for w in ev.windows(2) {
            assert!(w[0].ts <= w[1].ts, "timestamps must be monotone: {w:?}");
        }
    }

    #[test]
    fn child_overrunning_parent_is_clamped() {
        let spans = vec![span(1, "outer", 0.0, 5.0), span(1, "runaway", 1.0, 50.0)];
        let ev = chrome_events(&spans);
        assert_eq!(balance(&ev), 0);
        assert!(ev.iter().all(|e| e.ts <= 5.0));
    }

    #[test]
    fn tracks_are_independent_and_ordered() {
        let spans = vec![span(2, "b", 0.0, 1.0), span(1, "a", 0.0, 1.0)];
        let ev = chrome_events(&spans);
        assert_eq!(ev.len(), 4);
        assert!(ev[0].tid == 1 && ev[2].tid == 2, "tracks sorted by (pid, tid)");
    }

    #[test]
    fn non_finite_spans_are_dropped() {
        let spans = vec![span(1, "bad", f64::NAN, 1.0), span(1, "ok", 0.0, 1.0)];
        assert_eq!(chrome_events(&spans).len(), 2);
    }

    #[test]
    fn json_escapes_and_wraps() {
        let spans = vec![span(1, "quote\"back\\slash", 0.0, 1.0)];
        let json = chrome_trace_json(&spans);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("quote\\\"back\\\\slash"));
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("\"thread_name\""));
        crate::util::json::validate(&json).unwrap();
    }

    #[test]
    fn recorder_round_trip_and_clear() {
        let rec = Recorder::new();
        rec.span(tracks::PIPELINE, "x", 0.0, 1.0);
        assert_eq!(rec.span_count(), 1);
        assert_eq!(rec.spans()[0].name, "x");
        rec.clear();
        assert_eq!(rec.span_count(), 0);
    }
}
