//! Lock-sharded counters, gauges and histograms with deterministic
//! text/JSON snapshots.
//!
//! Shards are keyed by the metric-name hash so concurrent workers
//! (e.g. `util::par::parallel_map` evaluation batches) rarely contend
//! on one mutex. Quantiles reuse [`crate::util::stats::percentile_sorted`]
//! so histogram summaries agree bit-for-bit with the bench harness
//! statistics (asserted in `tests/obs_trace.rs`).

use crate::util::stats::percentile_sorted;
use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;
use std::sync::Mutex;

const SHARDS: usize = 8;

/// Sharded registry of named counters (monotonic `u64`), gauges
/// (last-write `f64`) and histograms (raw `f64` samples, summarized at
/// snapshot time).
#[derive(Debug)]
pub struct MetricsRegistry {
    counters: Vec<Mutex<HashMap<String, u64>>>,
    gauges: Vec<Mutex<HashMap<String, f64>>>,
    histograms: Vec<Mutex<HashMap<String, Vec<f64>>>>,
}

fn shard_of(name: &str) -> usize {
    (crate::util::hash64(|h| name.hash(h)) % SHARDS as u64) as usize
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self {
            counters: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            gauges: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            histograms: (0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    /// Increment counter `name` by one.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Increment counter `name` by `by`.
    pub fn add(&self, name: &str, by: u64) {
        let mut shard = self.counters[shard_of(name)].lock().unwrap();
        if let Some(v) = shard.get_mut(name) {
            *v += by;
        } else {
            shard.insert(name.to_string(), by);
        }
    }

    /// Overwrite counter `name` with an absolute value (used when
    /// exporting counters owned elsewhere, e.g. `Environment` atomics).
    pub fn set_counter(&self, name: &str, value: u64) {
        self.counters[shard_of(name)].lock().unwrap().insert(name.to_string(), value);
    }

    /// Current value of counter `name` (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters[shard_of(name)].lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Set gauge `name`.
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges[shard_of(name)].lock().unwrap().insert(name.to_string(), value);
    }

    /// Record one histogram sample under `name`.
    pub fn observe(&self, name: &str, value: f64) {
        let mut shard = self.histograms[shard_of(name)].lock().unwrap();
        if let Some(v) = shard.get_mut(name) {
            v.push(value);
        } else {
            shard.insert(name.to_string(), vec![value]);
        }
    }

    /// Deterministic point-in-time snapshot (names sorted, histograms
    /// summarized).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut snap = MetricsSnapshot::default();
        for shard in &self.counters {
            for (k, v) in shard.lock().unwrap().iter() {
                snap.counters.insert(k.clone(), *v);
            }
        }
        for shard in &self.gauges {
            for (k, v) in shard.lock().unwrap().iter() {
                snap.gauges.insert(k.clone(), *v);
            }
        }
        for shard in &self.histograms {
            for (k, v) in shard.lock().unwrap().iter() {
                if let Some(summary) = HistogramSummary::from_values(v) {
                    snap.histograms.insert(k.clone(), summary);
                }
            }
        }
        snap
    }
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        Self::new()
    }
}

/// p50/p95/p99 summary of one histogram's samples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistogramSummary {
    pub count: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl HistogramSummary {
    /// Summarize raw samples; `None` for an empty or all-non-finite set.
    pub fn from_values(values: &[f64]) -> Option<Self> {
        let mut v: Vec<f64> = values.iter().copied().filter(|x| x.is_finite()).collect();
        if v.is_empty() {
            return None;
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        Some(Self {
            count: n,
            min: v[0],
            max: v[n - 1],
            mean: v.iter().sum::<f64>() / n as f64,
            p50: percentile_sorted(&v, 50.0),
            p95: percentile_sorted(&v, 95.0),
            p99: percentile_sorted(&v, 99.0),
        })
    }
}

/// Snapshot of a [`MetricsRegistry`]; `BTreeMap`s keep serialization
/// order stable across runs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistogramSummary>,
}

/// Format a float as a JSON value (`null` for non-finite).
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl MetricsSnapshot {
    /// Serialize as a JSON object with `counters`/`gauges`/`histograms`
    /// sections.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n\"{}\":{}", escape(k), v));
        }
        out.push_str("},\n\"gauges\":{");
        for (i, (k, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\n\"{}\":{}", escape(k), json_num(*v)));
        }
        out.push_str("},\n\"histograms\":{");
        for (i, (k, h)) in self.histograms.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n\"{}\":{{\"count\":{},\"min\":{},\"max\":{},\"mean\":{},\
                 \"p50\":{},\"p95\":{},\"p99\":{}}}",
                escape(k),
                h.count,
                json_num(h.min),
                json_num(h.max),
                json_num(h.mean),
                json_num(h.p50),
                json_num(h.p95),
                json_num(h.p99)
            ));
        }
        out.push_str("}\n}\n");
        out
    }

    /// One `name value` line per metric, for terminal output.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, v) in &self.gauges {
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, h) in &self.histograms {
            out.push_str(&format!(
                "{k} count={} mean={:.4} p50={:.4} p95={:.4} p99={:.4}\n",
                h.count, h.mean, h.p50, h.p95, h.p99
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_overwrite() {
        let m = MetricsRegistry::new();
        m.inc("a");
        m.add("a", 4);
        assert_eq!(m.counter("a"), 5);
        m.set_counter("a", 2);
        assert_eq!(m.counter("a"), 2);
        assert_eq!(m.counter("never"), 0);
    }

    #[test]
    fn gauges_take_last_write() {
        let m = MetricsRegistry::new();
        m.set_gauge("g", 1.0);
        m.set_gauge("g", -2.5);
        assert_eq!(m.snapshot().gauges["g"], -2.5);
    }

    #[test]
    fn histogram_summary_matches_util_stats() {
        let m = MetricsRegistry::new();
        let data: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        for &x in &data {
            m.observe("h", x);
        }
        let h = m.snapshot().histograms["h"];
        let mut sorted = data.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(h.count, 100);
        assert_eq!(h.p50, percentile_sorted(&sorted, 50.0));
        assert_eq!(h.p95, percentile_sorted(&sorted, 95.0));
        assert_eq!(h.p99, percentile_sorted(&sorted, 99.0));
        assert_eq!(h.min, 1.0);
        assert_eq!(h.max, 100.0);
    }

    #[test]
    fn snapshot_is_sorted_and_json_valid() {
        let m = MetricsRegistry::new();
        m.inc("z.last");
        m.inc("a.first");
        m.set_gauge("mid", f64::NAN);
        m.observe("lat", 3.0);
        let snap = m.snapshot();
        let keys: Vec<&String> = snap.counters.keys().collect();
        assert_eq!(keys, ["a.first", "z.last"]);
        let json = snap.to_json();
        assert!(json.contains("\"mid\":null"));
        crate::util::json::validate(&json).unwrap();
        assert!(snap.to_text().contains("a.first 1"));
    }

    #[test]
    fn empty_histograms_are_omitted() {
        let m = MetricsRegistry::new();
        m.observe("nan-only", f64::NAN);
        assert!(m.snapshot().histograms.is_empty());
    }
}
