//! Seeded fault scenarios: straggler, link-degradation, and
//! device-failure models, plus the suite the robust DSE scores against.

use crate::util::{hash64, Rng};
use std::hash::Hash;

/// Per-device-group compute slowdown multipliers (`>= 1.0`; `1.0` =
/// healthy). In lockstep SPMD training every collective waits for its
/// slowest participant, so the groups collapse to the worst multiplier
/// (see [`crate::collective::straggler_factor`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StragglerModel {
    /// Compute-time multiplier per device group.
    pub group_multipliers: Vec<f64>,
}

impl StragglerModel {
    /// No stragglers: every group at `1.0`.
    pub fn nominal() -> Self {
        Self { group_multipliers: vec![1.0] }
    }

    /// True when no group is slowed at all.
    pub fn is_nominal(&self) -> bool {
        self.group_multipliers.iter().all(|&m| m <= 1.0)
    }

    /// The max-over-participants factor the whole lockstep iteration
    /// inherits (never below `1.0`).
    pub fn worst_multiplier(&self) -> f64 {
        crate::collective::straggler_factor(&self.group_multipliers)
    }
}

/// Per-topology-dimension link degradation: bandwidth multipliers in
/// `(0, 1]` and latency multipliers `>= 1.0`. Dimensions beyond the
/// stored vectors are treated as healthy.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkFaults {
    /// Bandwidth multiplier per dim (`1.0` = full rate).
    pub bandwidth_factor: Vec<f64>,
    /// Latency multiplier per dim (`1.0` = nominal).
    pub latency_factor: Vec<f64>,
}

impl LinkFaults {
    /// All links healthy.
    pub fn nominal() -> Self {
        Self { bandwidth_factor: Vec::new(), latency_factor: Vec::new() }
    }

    /// Bandwidth multiplier for `dim` (`1.0` when out of range).
    pub fn bw_factor(&self, dim: usize) -> f64 {
        self.bandwidth_factor.get(dim).copied().unwrap_or(1.0)
    }

    /// Latency multiplier for `dim` (`1.0` when out of range).
    pub fn lat_factor(&self, dim: usize) -> f64 {
        self.latency_factor.get(dim).copied().unwrap_or(1.0)
    }

    /// True when no dim is degraded.
    pub fn is_nominal(&self) -> bool {
        self.bandwidth_factor.iter().all(|&f| f >= 1.0)
            && self.latency_factor.iter().all(|&f| f <= 1.0)
    }

    /// Stable fingerprint of the degradation, `0` for nominal links —
    /// so nominal-link scenarios share collective-cost cache entries
    /// with plain fault-free runs (see `sim::CollKey::scenario`).
    pub fn fingerprint(&self) -> u64 {
        if self.is_nominal() {
            return 0;
        }
        hash64(|h| {
            0xFA17u64.hash(h);
            self.bandwidth_factor.len().hash(h);
            for f in &self.bandwidth_factor {
                f.to_bits().hash(h);
            }
            self.latency_factor.len().hash(h);
            for f in &self.latency_factor {
                f.to_bits().hash(h);
            }
        })
    }
}

/// Transient device failures: a per-device MTBF with checkpoint-restart
/// recovery costs, priced by the first-order Young/Daly model in
/// [`super::goodput`].
#[derive(Debug, Clone, PartialEq)]
pub struct FailureModel {
    /// Mean time between failures per device, in hours
    /// (`f64::INFINITY` = devices never fail).
    pub device_mtbf_hours: f64,
    /// Time to write one checkpoint, seconds.
    pub checkpoint_write_s: f64,
    /// Fixed restart/rollback cost after a failure, seconds.
    pub restart_s: f64,
}

impl FailureModel {
    /// Devices never fail; checkpointing is free and unnecessary.
    pub fn nominal() -> Self {
        Self { device_mtbf_hours: f64::INFINITY, checkpoint_write_s: 0.0, restart_s: 0.0 }
    }

    /// True when failures can never occur.
    pub fn is_nominal(&self) -> bool {
        self.device_mtbf_hours.is_infinite()
    }

    /// Cluster-level MTBF in seconds: independent failures shrink the
    /// mean time to *any* failure by the device count.
    pub fn cluster_mtbf_s(&self, npus: u64) -> f64 {
        self.device_mtbf_hours * 3600.0 / npus.max(1) as f64
    }
}

/// One deterministic failure world. Equal seeds yield bit-identical
/// scenarios; the nominal scenario prices bit-identically to the
/// fault-free path.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultScenario {
    /// Human-readable label (`"nominal"`, `"seed7"`, ...).
    pub name: String,
    /// The seed this scenario was drawn from (`0` for nominal).
    pub seed: u64,
    /// Straggler compute multipliers per device group.
    pub stragglers: StragglerModel,
    /// Per-dim link degradation.
    pub links: LinkFaults,
    /// Device-failure / checkpoint-restart model.
    pub failures: FailureModel,
}

/// Number of device groups the straggler draw partitions the cluster
/// into; only the max matters under lockstep execution, but keeping
/// groups makes scenarios interpretable in traces.
const STRAGGLER_GROUPS: usize = 4;

impl FaultScenario {
    /// The healthy cluster: no stragglers, no link faults, no failures.
    pub fn nominal() -> Self {
        Self {
            name: "nominal".to_string(),
            seed: 0,
            stragglers: StragglerModel::nominal(),
            links: LinkFaults::nominal(),
            failures: FailureModel::nominal(),
        }
    }

    /// Draw one scenario deterministically from `seed` for a topology
    /// with `dims` network dimensions. Equal `(seed, dims)` give
    /// bit-identical scenarios across runs and platforms.
    pub fn from_seed(seed: u64, dims: usize) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ 0xFA01_7D5E_ED00_C0DE);
        // Stragglers: each group slowed with prob 1/2, by up to +60%
        // (quadratic bias toward mild skew — severe stragglers are rare).
        let group_multipliers: Vec<f64> = (0..STRAGGLER_GROUPS)
            .map(|_| {
                if rng.gen_bool(0.5) {
                    let u = rng.gen_f64();
                    1.0 + 0.6 * u * u
                } else {
                    1.0
                }
            })
            .collect();
        // Links: each dim degraded with prob 0.4 — bandwidth down to
        // 40% of nominal, latency up to 3x.
        let mut bandwidth_factor = Vec::with_capacity(dims);
        let mut latency_factor = Vec::with_capacity(dims);
        for _ in 0..dims {
            if rng.gen_bool(0.4) {
                bandwidth_factor.push(1.0 - 0.6 * rng.gen_f64());
                latency_factor.push(1.0 + 2.0 * rng.gen_f64());
            } else {
                bandwidth_factor.push(1.0);
                latency_factor.push(1.0);
            }
        }
        // Failures: device MTBF log-uniform in ~[5e3, 1e5] hours,
        // checkpoint writes 10–120 s, restarts 30–300 s.
        let device_mtbf_hours = 10f64.powf(3.7 + 1.3 * rng.gen_f64());
        let checkpoint_write_s = 10.0 + 110.0 * rng.gen_f64();
        let restart_s = 30.0 + 270.0 * rng.gen_f64();
        Self {
            name: format!("seed{seed}"),
            seed,
            stragglers: StragglerModel { group_multipliers },
            links: LinkFaults { bandwidth_factor, latency_factor },
            failures: FailureModel { device_mtbf_hours, checkpoint_write_s, restart_s },
        }
    }

    /// True when the scenario degrades nothing (prices identically to
    /// the fault-free path, modulo the attached goodput record).
    pub fn is_nominal(&self) -> bool {
        self.stragglers.is_nominal() && self.links.is_nominal() && self.failures.is_nominal()
    }

    /// Stable fingerprint over every model parameter (bit patterns, not
    /// rounded values) — used by determinism tests and telemetry.
    pub fn fingerprint(&self) -> u64 {
        hash64(|h| {
            self.seed.hash(h);
            self.stragglers.group_multipliers.len().hash(h);
            for m in &self.stragglers.group_multipliers {
                m.to_bits().hash(h);
            }
            self.links.fingerprint().hash(h);
            self.failures.device_mtbf_hours.to_bits().hash(h);
            self.failures.checkpoint_write_s.to_bits().hash(h);
            self.failures.restart_s.to_bits().hash(h);
        })
    }

    /// Rescale every degradation by `severity`: `0.0` is nominal,
    /// `1.0` is this scenario, `> 1.0` amplifies it. Goodput is
    /// monotone non-increasing along a severity ladder (property-tested
    /// in `rust/tests/faults.rs`).
    pub fn scaled(&self, severity: f64) -> Self {
        let s = severity.max(0.0);
        let amp = |m: f64| 1.0 + (m - 1.0) * s;
        Self {
            name: format!("{}x{s:.2}", self.name),
            seed: self.seed,
            stragglers: StragglerModel {
                group_multipliers: self
                    .stragglers
                    .group_multipliers
                    .iter()
                    .map(|&m| amp(m))
                    .collect(),
            },
            links: LinkFaults {
                bandwidth_factor: self
                    .links
                    .bandwidth_factor
                    .iter()
                    .map(|&f| (1.0 - (1.0 - f) * s).max(0.05))
                    .collect(),
                latency_factor: self.links.latency_factor.iter().map(|&f| amp(f)).collect(),
            },
            failures: FailureModel {
                device_mtbf_hours: if s > 0.0 {
                    self.failures.device_mtbf_hours / s
                } else {
                    f64::INFINITY
                },
                checkpoint_write_s: self.failures.checkpoint_write_s,
                restart_s: self.failures.restart_s,
            },
        }
    }
}

/// The nominal scenario plus K seeded ones — the unit robust search
/// aggregates over. `scenarios[0]` is always nominal so reports and
/// baselines stay anchored to the healthy cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSuite {
    /// `[nominal, seeded #1, ..., seeded #K]`.
    pub scenarios: Vec<FaultScenario>,
}

impl ScenarioSuite {
    /// Nominal + `k` scenarios drawn deterministically from `seed` for
    /// a `dims`-dimensional topology.
    pub fn generate(seed: u64, k: usize, dims: usize) -> Self {
        let mut scenarios = Vec::with_capacity(k + 1);
        scenarios.push(FaultScenario::nominal());
        for i in 1..=k as u64 {
            scenarios.push(FaultScenario::from_seed(
                seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                dims,
            ));
        }
        Self { scenarios }
    }

    /// Number of scenarios including nominal.
    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    /// True when the suite holds no scenarios at all.
    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }

    /// Fingerprint over every member scenario.
    pub fn fingerprint(&self) -> u64 {
        hash64(|h| {
            self.scenarios.len().hash(h);
            for s in &self.scenarios {
                s.fingerprint().hash(h);
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_scenario() {
        let a = FaultScenario::from_seed(42, 3);
        let b = FaultScenario::from_seed(42, 3);
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let c = FaultScenario::from_seed(43, 3);
        assert_ne!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn nominal_is_nominal_everywhere() {
        let n = FaultScenario::nominal();
        assert!(n.is_nominal());
        assert!(n.stragglers.is_nominal());
        assert!(n.links.is_nominal());
        assert!(n.failures.is_nominal());
        assert_eq!(n.links.fingerprint(), 0);
        assert_eq!(n.stragglers.worst_multiplier(), 1.0);
    }

    #[test]
    fn seeded_scenario_factors_in_range() {
        for seed in 0..50u64 {
            let s = FaultScenario::from_seed(seed, 4);
            for &m in &s.stragglers.group_multipliers {
                assert!((1.0..=1.6).contains(&m), "straggler {m}");
            }
            for d in 0..4 {
                let bw = s.links.bw_factor(d);
                let lat = s.links.lat_factor(d);
                assert!((0.4..=1.0).contains(&bw), "bw {bw}");
                assert!((1.0..=3.0).contains(&lat), "lat {lat}");
            }
            assert!(s.failures.device_mtbf_hours >= 5e3 * 0.99);
            assert!(s.failures.device_mtbf_hours <= 1e5 * 1.01);
        }
    }

    #[test]
    fn scaled_zero_is_nominal_and_one_is_identity() {
        let s = FaultScenario::from_seed(7, 3);
        assert!(s.scaled(0.0).is_nominal());
        let id = s.scaled(1.0);
        assert_eq!(id.stragglers, s.stragglers);
        assert_eq!(id.links, s.links);
        assert_eq!(id.failures, s.failures);
    }

    #[test]
    fn suite_starts_nominal_and_is_deterministic() {
        let a = ScenarioSuite::generate(9, 3, 2);
        let b = ScenarioSuite::generate(9, 3, 2);
        assert_eq!(a, b);
        assert_eq!(a.len(), 4);
        assert!(a.scenarios[0].is_nominal());
        assert!(a.scenarios[1..].iter().any(|s| !s.is_nominal()));
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), ScenarioSuite::generate(10, 3, 2).fingerprint());
    }

    #[test]
    fn link_fingerprint_distinguishes_degradations() {
        let a = LinkFaults { bandwidth_factor: vec![0.5, 1.0], latency_factor: vec![1.0, 1.0] };
        let b = LinkFaults { bandwidth_factor: vec![1.0, 0.5], latency_factor: vec![1.0, 1.0] };
        assert_ne!(a.fingerprint(), 0);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }
}
