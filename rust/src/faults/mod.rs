//! Deterministic fault injection: seeded failure scenarios and the
//! resilience accounting that turns raw throughput into *goodput*.
//!
//! COSMIC's DSE scores every candidate configuration on a perfectly
//! healthy cluster; at scale, stragglers, flaky links, and device
//! failures dominate delivered throughput, and the nominal optimum is
//! often fragile under them. This module makes failure a first-class,
//! reproducible scenario axis:
//!
//! - [`FaultScenario`] — one deterministic failure world, drawn from a
//!   seed: per-device-group straggler compute multipliers, per-dim link
//!   bandwidth/latency degradation, and an MTBF-based device-failure
//!   model with checkpoint-restart recovery costs.
//! - [`ScenarioSuite`] — the nominal scenario plus K seeded ones, the
//!   unit over which robust search aggregates (see
//!   [`crate::dse::Environment::with_scenarios`]).
//! - [`FaultView`] — a [`crate::netsim::NetworkBackend`] wrapper that
//!   applies a scenario's link degradation underneath *any* fidelity
//!   rung (Analytical or FlowLevel) without the rung knowing.
//! - [`Goodput`] — throughput net of checkpoint overhead and lost work,
//!   with a Young/Daly optimal-interval baseline, attached to
//!   [`crate::sim::SimReport`] whenever a scenario is active.
//!
//! Everything is seed-reproducible: the same seed yields bit-identical
//! scenarios, and a simulation under the nominal scenario is
//! bit-identical to the fault-free path (gated in tests and in
//! `benches/eval_throughput.rs`).

mod goodput;
mod scenario;
mod view;

pub use goodput::{efficiency, goodput_of, young_daly_interval_s, Goodput};
pub use scenario::{FailureModel, FaultScenario, LinkFaults, ScenarioSuite, StragglerModel};
pub use view::FaultView;
