//! First-order goodput accounting under checkpoint/restart: the
//! classic Young/Daly model. A run checkpointing every `tau` seconds
//! with write cost `delta` spends `tau/(tau+delta)` of its time on
//! useful work; each failure (cluster MTBF `M`) loses on average half
//! an interval plus the restart cost, so the delivered fraction is
//!
//! `eff(tau) = tau/(tau+delta) * max(0, 1 - (tau/2 + restart)/M)`
//!
//! maximized near the Young/Daly interval `tau* = sqrt(2*delta*M)`.
//! Goodput is `achieved_tflops * eff` — throughput net of checkpoint
//! overhead and lost work.

use super::FailureModel;

/// Resilience accounting attached to a [`crate::sim::SimReport`] when a
/// fault scenario is active (`None` on fault-free runs, preserving
/// bit-identity with the pre-fault pipeline).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Goodput {
    /// Checkpoint interval used, seconds (Young/Daly optimum unless a
    /// checkpoint-interval knob forced one).
    pub checkpoint_interval_s: f64,
    /// Cluster-level MTBF, seconds (device MTBF / device count).
    pub cluster_mtbf_s: f64,
    /// Fraction of raw throughput delivered, in `[0, 1]`.
    pub efficiency: f64,
    /// `achieved_tflops * efficiency`.
    pub goodput_tflops: f64,
    /// Young/Daly optimal interval for this scenario, seconds — the
    /// baseline the checkpoint knob is judged against.
    pub young_daly_interval_s: f64,
    /// Efficiency at the Young/Daly interval.
    pub young_daly_efficiency: f64,
}

/// Young/Daly optimal checkpoint interval `sqrt(2 * delta * M)` in
/// seconds; infinite when the cluster never fails (never checkpoint).
pub fn young_daly_interval_s(checkpoint_write_s: f64, cluster_mtbf_s: f64) -> f64 {
    if !cluster_mtbf_s.is_finite() {
        return f64::INFINITY;
    }
    (2.0 * checkpoint_write_s.max(0.0) * cluster_mtbf_s).sqrt()
}

/// Delivered-work fraction for a checkpoint interval of `interval_s`
/// seconds. Exactly `1.0` when the cluster never fails and no
/// checkpoint overhead is paid; clamped to `[0, 1]` otherwise.
pub fn efficiency(
    interval_s: f64,
    checkpoint_write_s: f64,
    restart_s: f64,
    cluster_mtbf_s: f64,
) -> f64 {
    if cluster_mtbf_s <= 0.0 {
        return 0.0;
    }
    let delta = checkpoint_write_s.max(0.0);
    let ckpt = if delta <= 0.0 || interval_s.is_infinite() {
        1.0
    } else {
        interval_s / (interval_s + delta)
    };
    let lost = if cluster_mtbf_s.is_finite() {
        // An unbounded interval on a failing cluster still cannot lose
        // more than ~one MTBF of work per failure on average.
        let tau = if interval_s.is_finite() { interval_s } else { cluster_mtbf_s };
        (1.0 - (tau / 2.0 + restart_s.max(0.0)) / cluster_mtbf_s).max(0.0)
    } else {
        1.0
    };
    (ckpt * lost).clamp(0.0, 1.0)
}

/// Price one iteration's resilience: `iteration_s` is the simulated
/// iteration time, `achieved_tflops` the raw cluster throughput,
/// `interval_iters` the checkpoint-interval knob in iterations (`None`
/// = use the Young/Daly optimum).
pub fn goodput_of(
    iteration_s: f64,
    achieved_tflops: f64,
    npus: u64,
    failures: &FailureModel,
    interval_iters: Option<u64>,
) -> Goodput {
    let m = failures.cluster_mtbf_s(npus);
    let yd = young_daly_interval_s(failures.checkpoint_write_s, m);
    let tau = match interval_iters {
        Some(k) => k.max(1) as f64 * iteration_s,
        None => yd,
    };
    let eff = efficiency(tau, failures.checkpoint_write_s, failures.restart_s, m);
    Goodput {
        checkpoint_interval_s: tau,
        cluster_mtbf_s: m,
        efficiency: eff,
        goodput_tflops: achieved_tflops * eff,
        young_daly_interval_s: yd,
        young_daly_efficiency: efficiency(yd, failures.checkpoint_write_s, failures.restart_s, m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn failing() -> FailureModel {
        FailureModel { device_mtbf_hours: 2e4, checkpoint_write_s: 60.0, restart_s: 120.0 }
    }

    #[test]
    fn nominal_cluster_delivers_exactly_one() {
        let g = goodput_of(0.5, 123.456, 4096, &FailureModel::nominal(), None);
        assert_eq!(g.efficiency, 1.0);
        assert_eq!(g.goodput_tflops, 123.456);
        assert!(g.young_daly_interval_s.is_infinite());
    }

    #[test]
    fn failures_cost_throughput() {
        let g = goodput_of(0.5, 100.0, 4096, &failing(), None);
        assert!(g.efficiency > 0.0 && g.efficiency < 1.0);
        assert!(g.goodput_tflops < 100.0);
        assert!(g.cluster_mtbf_s > 0.0 && g.cluster_mtbf_s.is_finite());
    }

    #[test]
    fn young_daly_interval_is_near_optimal() {
        let f = failing();
        let m = f.cluster_mtbf_s(4096);
        let yd = young_daly_interval_s(f.checkpoint_write_s, m);
        let at = |tau: f64| efficiency(tau, f.checkpoint_write_s, f.restart_s, m);
        assert!(at(yd) >= at(yd * 0.25) - 1e-12);
        assert!(at(yd) >= at(yd * 4.0) - 1e-12);
    }

    #[test]
    fn efficiency_monotone_in_mtbf() {
        let f = failing();
        let mut prev = -1.0;
        for mtbf_s in [1e3, 1e4, 1e5, 1e6, 1e9] {
            let yd = young_daly_interval_s(f.checkpoint_write_s, mtbf_s);
            let e = efficiency(yd, f.checkpoint_write_s, f.restart_s, mtbf_s);
            assert!(e >= prev, "efficiency not monotone in MTBF: {e} < {prev}");
            prev = e;
        }
    }

    #[test]
    fn forced_interval_reported_in_seconds() {
        let g = goodput_of(2.0, 100.0, 16, &failing(), Some(32));
        assert_eq!(g.checkpoint_interval_s, 64.0);
        assert!(g.efficiency <= g.young_daly_efficiency + 1e-12);
    }

    #[test]
    fn dead_cluster_delivers_nothing() {
        assert_eq!(efficiency(10.0, 1.0, 1.0, 0.0), 0.0);
    }
}
