//! [`FaultView`]: apply a scenario's link degradation underneath any
//! [`NetworkBackend`] fidelity rung.
//!
//! The view rewrites each call's alpha/beta span (latency multiplied
//! up, bandwidth multiplied down, per spanned dimension) and hands the
//! inner backend a correspondingly degraded [`Topology`], so both the
//! Analytical closed forms and the FlowLevel congestion model price the
//! degraded fabric without knowing faults exist. `cache_tag` folds the
//! degradation fingerprint over the inner tag, keeping the cross-eval
//! collective-cost cache scenario-correct.

use super::LinkFaults;
use crate::collective::SchedulingPolicy;
use crate::netsim::{CollectiveCall, FidelityMode, NetworkBackend, OverlapCall};
use crate::obs::TraceSink;
use crate::topology::{DimCost, Topology};
use crate::util::hash64;
use std::hash::Hash;
use std::sync::Arc;

/// Link-degrading wrapper around an inner backend. Construct via
/// [`FaultView::wrap`], which skips wrapping entirely for nominal links
/// (zero cost when nothing is degraded, and maximal cache sharing).
#[derive(Debug)]
pub struct FaultView {
    inner: Arc<dyn NetworkBackend>,
    links: LinkFaults,
}

impl FaultView {
    /// Wrap `inner` under `links`; returns `inner` unchanged when the
    /// links are nominal.
    pub fn wrap(inner: Arc<dyn NetworkBackend>, links: &LinkFaults) -> Arc<dyn NetworkBackend> {
        if links.is_nominal() {
            inner
        } else {
            Arc::new(Self { inner, links: links.clone() })
        }
    }

    fn degraded_topology(&self, topo: &Topology) -> Topology {
        let mut t = topo.clone();
        for (d, dim) in t.dims.iter_mut().enumerate() {
            dim.bandwidth_gbps *= self.links.bw_factor(d);
            dim.latency_us *= self.links.lat_factor(d);
        }
        t
    }

    fn degraded_span(&self, span: &[(DimCost, usize)]) -> Vec<(DimCost, usize)> {
        span.iter()
            .map(|&(c, d)| {
                (
                    DimCost {
                        alpha_us: c.alpha_us * self.links.lat_factor(d),
                        beta_bytes_per_us: c.beta_bytes_per_us * self.links.bw_factor(d),
                        npus: c.npus,
                    },
                    d,
                )
            })
            .collect()
    }

    /// Degrade a drain's jobs, preserving span identity: jobs sharing
    /// one healthy span share one degraded span, so inner backends that
    /// memoize per span pointer (Analytical) keep their hit rate.
    fn drain_with(
        &self,
        jobs: &[OverlapCall<'_>],
        run: impl FnOnce(&[OverlapCall<'_>]) -> Vec<(u64, f64)>,
    ) -> Vec<(u64, f64)> {
        let Some(first) = jobs.first() else {
            return Vec::new();
        };
        let topo = self.degraded_topology(first.call.topology);
        let mut spans: Vec<(*const (DimCost, usize), Vec<(DimCost, usize)>)> = Vec::new();
        for j in jobs {
            let p = j.call.span.as_ptr();
            if !spans.iter().any(|(q, _)| *q == p) {
                spans.push((p, self.degraded_span(j.call.span)));
            }
        }
        let degraded: Vec<OverlapCall<'_>> = jobs
            .iter()
            .map(|j| {
                let p = j.call.span.as_ptr();
                let span = &spans.iter().find(|(q, _)| *q == p).expect("span interned").1;
                OverlapCall {
                    layer: j.layer,
                    issue_us: j.issue_us,
                    call: CollectiveCall { span, topology: &topo, ..j.call },
                }
            })
            .collect();
        run(&degraded)
    }
}

impl NetworkBackend for FaultView {
    fn name(&self) -> &'static str {
        "fault-view"
    }

    fn fidelity(&self) -> FidelityMode {
        self.inner.fidelity()
    }

    fn cache_tag(&self) -> u64 {
        hash64(|h| {
            0xFA17_u64.hash(h);
            self.inner.cache_tag().hash(h);
            self.links.fingerprint().hash(h);
        })
    }

    fn drain_is_serial(&self) -> bool {
        self.inner.drain_is_serial()
    }

    fn collective_time_us(&self, call: &CollectiveCall<'_>) -> f64 {
        let topo = self.degraded_topology(call.topology);
        let span = self.degraded_span(call.span);
        self.inner.collective_time_us(&CollectiveCall { span: &span, topology: &topo, ..*call })
    }

    fn drain_overlapped(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
    ) -> Vec<(u64, f64)> {
        self.drain_with(jobs, |degraded| self.inner.drain_overlapped(degraded, policy))
    }

    fn drain_overlapped_traced(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
        sink: &dyn TraceSink,
    ) -> Vec<(u64, f64)> {
        self.drain_with(jobs, |degraded| {
            self.inner.drain_overlapped_traced(degraded, policy, sink)
        })
    }

    fn phase_times_us(&self, call: &CollectiveCall<'_>) -> Vec<(usize, f64)> {
        let topo = self.degraded_topology(call.topology);
        let span = self.degraded_span(call.span);
        self.inner.phase_times_us(&CollectiveCall { span: &span, topology: &topo, ..*call })
    }

    fn with_dim_utilization(&self, util: &[f64]) -> Option<Arc<dyn NetworkBackend>> {
        // Shape the inner fabric and re-apply the same link degradation
        // on top, so a traffic trace and a fault scenario compose
        // regardless of which wrapper sits outermost.
        Some(FaultView::wrap(self.inner.with_dim_utilization(util)?, &self.links))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{CollAlgo, CollectiveKind, MultiDimPolicy};
    use crate::netsim::{Analytical, FlowLevel};
    use crate::topology::{DimKind, NetworkDim};

    fn topo() -> Topology {
        Topology {
            dims: vec![
                NetworkDim::new(DimKind::Ring, 4, 200.0, 1.0),
                NetworkDim::new(DimKind::Switch, 16, 100.0, 2.0),
            ],
        }
    }

    fn span_of(t: &Topology) -> Vec<(DimCost, usize)> {
        t.dims.iter().enumerate().map(|(d, dim)| (DimCost::from_dim(dim), d)).collect()
    }

    fn degraded() -> LinkFaults {
        LinkFaults { bandwidth_factor: vec![0.5, 1.0], latency_factor: vec![1.0, 2.0] }
    }

    fn call<'a>(
        span: &'a [(DimCost, usize)],
        t: &'a Topology,
        algos: &'a [CollAlgo],
    ) -> CollectiveCall<'a> {
        CollectiveCall {
            kind: CollectiveKind::AllReduce,
            policy: MultiDimPolicy::Baseline,
            algos,
            span,
            topology: t,
            bytes: 4.0e6,
            chunks: 4,
        }
    }

    #[test]
    fn nominal_links_skip_the_wrapper() {
        let inner: Arc<dyn NetworkBackend> = Arc::new(Analytical);
        let wrapped = FaultView::wrap(Arc::clone(&inner), &LinkFaults::nominal());
        assert_eq!(wrapped.cache_tag(), inner.cache_tag());
        assert_eq!(wrapped.name(), inner.name());
    }

    #[test]
    fn degraded_links_never_price_faster() {
        let t = topo();
        let span = span_of(&t);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&span, &t, &algos);
        for inner in [
            Arc::new(Analytical) as Arc<dyn NetworkBackend>,
            Arc::new(FlowLevel::default()) as Arc<dyn NetworkBackend>,
        ] {
            let healthy = inner.collective_time_us(&c);
            let view = FaultView::wrap(Arc::clone(&inner), &degraded());
            let faulted = view.collective_time_us(&c);
            assert!(
                faulted >= healthy,
                "{}: faulted {faulted} < healthy {healthy}",
                inner.name()
            );
        }
    }

    #[test]
    fn cache_tag_differs_from_inner_and_tracks_links() {
        let inner: Arc<dyn NetworkBackend> = Arc::new(Analytical);
        let a = FaultView::wrap(Arc::clone(&inner), &degraded());
        let mut other = degraded();
        other.bandwidth_factor[0] = 0.25;
        let b = FaultView::wrap(Arc::clone(&inner), &other);
        assert_ne!(a.cache_tag(), inner.cache_tag());
        assert_ne!(a.cache_tag(), b.cache_tag());
    }

    #[test]
    fn drain_matches_serial_semantics_on_analytical() {
        let t = topo();
        let span = span_of(&t);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let view = FaultView::wrap(Arc::new(Analytical), &degraded());
        let jobs: Vec<OverlapCall<'_>> = (0..3)
            .map(|i| OverlapCall {
                layer: i as u64,
                issue_us: i as f64 * 10.0,
                call: call(&span, &t, &algos),
            })
            .collect();
        let drained = view.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        assert_eq!(drained.len(), 3);
        let dur = view.collective_time_us(&jobs[0].call);
        let tuples: Vec<(u64, f64, f64)> =
            jobs.iter().map(|j| (j.layer, j.issue_us, dur)).collect();
        let expect = crate::netsim::serial_drain(&tuples, SchedulingPolicy::Fifo);
        for (a, b) in drained.iter().zip(expect.iter()) {
            assert_eq!(a.0, b.0);
            assert!((a.1 - b.1).abs() < 1e-9);
        }
        assert!(view.drain_overlapped(&[], SchedulingPolicy::Fifo).is_empty());
    }
}
