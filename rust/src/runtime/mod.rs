//! PJRT runtime bridge: load and execute the AOT-compiled JAX/Pallas
//! artifacts from the Rust hot path.
//!
//! Build-time python (`python/compile/aot.py`) lowers two computations to
//! **HLO text** (not serialized protos — jax ≥ 0.5 emits 64-bit ids the
//! crate's XLA rejects; the text parser reassigns them):
//!
//! - `artifacts/cost_model.hlo.txt` — the batched analytical cost model
//!   (L2 graph wrapping the L1 Pallas roofline kernel);
//! - `artifacts/gp_surrogate.hlo.txt` — the BO agent's GP posterior.
//!
//! This module compiles them once on a `PjRtClient::cpu()` and exposes
//! typed entry points. Every artifact has a pure-Rust twin in
//! [`fallback`]; [`CostModel`] and [`GpSurrogate`] transparently fall
//! back when artifacts are absent, and `tests` assert the two paths agree
//! to f32 tolerance.

pub mod fallback;

pub use fallback::{cost_model_ref, CostBatch, GpFallback, BATCH, DIMS, GP_FEATURES, GP_QUERY, GP_TRAIN, OPS};

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Default artifact directory (relative to the repo root / cwd).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("COSMIC_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A compiled XLA executable loaded from HLO text.
pub struct XlaModule {
    exe: xla::PjRtLoadedExecutable,
}

impl XlaModule {
    /// Load HLO text at `path` and compile it for the CPU PJRT client.
    pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text at {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("compiling HLO module")?;
        Ok(Self { exe })
    }

    /// Execute with f32 literals; returns the decomposed output tuple.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // jax lowers with return_tuple=True: decompose the 1-level tuple.
        Ok(result.to_tuple()?)
    }
}

fn literal_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    anyhow::ensure!(data.len() == rows * cols, "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}

fn literal_1d(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// The batched analytical cost model — XLA-backed when the artifact is
/// present, pure-Rust otherwise. This is the DSE pre-filter hot path.
pub enum CostModel {
    Xla { module: XlaModule },
    Fallback,
}

impl CostModel {
    /// Try to load the artifact; fall back silently if missing.
    pub fn load(client: Option<&xla::PjRtClient>, dir: &Path) -> Self {
        let path = dir.join("cost_model.hlo.txt");
        if let Some(client) = client {
            if path.exists() {
                match XlaModule::load(client, &path) {
                    Ok(module) => return CostModel::Xla { module },
                    Err(e) => eprintln!("cost_model artifact load failed ({e:#}); using fallback"),
                }
            }
        }
        CostModel::Fallback
    }

    pub fn is_xla(&self) -> bool {
        matches!(self, CostModel::Xla { .. })
    }

    /// Evaluate the batch, returning one estimated cost (us) per config.
    pub fn evaluate(&self, batch: &CostBatch) -> Result<Vec<f32>> {
        batch.validate().map_err(anyhow::Error::msg)?;
        match self {
            CostModel::Fallback => Ok(cost_model_ref(batch)),
            CostModel::Xla { module } => {
                let inputs = vec![
                    literal_2d(&batch.flops, BATCH, OPS)?,
                    literal_2d(&batch.bytes, BATCH, OPS)?,
                    literal_2d(&batch.steps, BATCH, DIMS)?,
                    literal_2d(&batch.volume, BATCH, DIMS)?,
                    literal_2d(&batch.alpha_us, BATCH, DIMS)?,
                    literal_2d(&batch.beta, BATCH, DIMS)?,
                    xla::Literal::scalar(batch.peak_flops_us),
                    xla::Literal::scalar(batch.mem_bytes_us),
                ];
                let mut out = module.run_f32(&inputs)?;
                anyhow::ensure!(!out.is_empty(), "cost model returned empty tuple");
                let total = out.remove(0).to_vec::<f32>()?;
                anyhow::ensure!(total.len() == BATCH, "bad output length {}", total.len());
                Ok(total)
            }
        }
    }
}

/// The GP surrogate — same dual-path structure. Implements the BO
/// agent's [`crate::agents::bo::Surrogate`] trait so it can be slotted
/// straight into [`crate::agents::BayesOpt::with_surrogate`].
pub struct GpSurrogate {
    backend: GpBackend,
    lengthscale: f32,
    noise: f32,
    /// Fitted training set, padded to the artifact shape.
    x_train: Vec<f32>,
    y_train: Vec<f32>,
    mask: Vec<f32>,
    y_mean: f32,
    fitted: bool,
}

enum GpBackend {
    Xla(XlaModule),
    Fallback,
}

impl GpSurrogate {
    pub fn load(client: Option<&xla::PjRtClient>, dir: &Path, lengthscale: f32) -> Self {
        let path = dir.join("gp_surrogate.hlo.txt");
        let backend = match client {
            Some(client) if path.exists() => match XlaModule::load(client, &path) {
                Ok(m) => GpBackend::Xla(m),
                Err(e) => {
                    eprintln!("gp artifact load failed ({e:#}); using fallback");
                    GpBackend::Fallback
                }
            },
            _ => GpBackend::Fallback,
        };
        Self {
            backend,
            lengthscale,
            noise: 1e-4,
            x_train: vec![0.0; GP_TRAIN * GP_FEATURES],
            y_train: vec![0.0; GP_TRAIN],
            mask: vec![0.0; GP_TRAIN],
            y_mean: 0.0,
            fitted: false,
        }
    }

    pub fn is_xla(&self) -> bool {
        matches!(self.backend, GpBackend::Xla(_))
    }

    /// Pad a normalized feature vector to `GP_FEATURES`.
    fn pad_features(q: &[f64]) -> Vec<f32> {
        let mut out = vec![0.0f32; GP_FEATURES];
        for (i, v) in q.iter().take(GP_FEATURES).enumerate() {
            out[i] = *v as f32;
        }
        out
    }

    /// Posterior at a batch of queries (padded to `GP_QUERY`).
    pub fn posterior(&self, queries: &[Vec<f64>]) -> Result<(Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(self.fitted, "GP surrogate not fitted");
        anyhow::ensure!(queries.len() <= GP_QUERY, "too many queries");
        let mut xq = vec![0.0f32; GP_QUERY * GP_FEATURES];
        for (i, q) in queries.iter().enumerate() {
            xq[i * GP_FEATURES..(i + 1) * GP_FEATURES].copy_from_slice(&Self::pad_features(q));
        }
        let (mut mean, var) = match &self.backend {
            GpBackend::Fallback => {
                let gp = GpFallback { lengthscale: self.lengthscale, noise: self.noise };
                gp.posterior(&self.x_train, &self.y_train, &self.mask, &xq)
            }
            GpBackend::Xla(module) => {
                let inputs = vec![
                    literal_2d(&self.x_train, GP_TRAIN, GP_FEATURES)?,
                    literal_1d(&self.y_train),
                    literal_1d(&self.mask),
                    literal_2d(&xq, GP_QUERY, GP_FEATURES)?,
                    xla::Literal::scalar(self.lengthscale),
                    xla::Literal::scalar(self.noise),
                ];
                let out = module.run_f32(&inputs)?;
                anyhow::ensure!(out.len() >= 2, "gp artifact must return (mean, var)");
                (out[0].to_vec::<f32>()?, out[1].to_vec::<f32>()?)
            }
        };
        for m in &mut mean {
            *m += self.y_mean;
        }
        Ok((mean, var))
    }
}

impl crate::agents::bo::Surrogate for GpSurrogate {
    fn fit(&mut self, xs: &[Vec<f64>], ys: &[f64]) -> bool {
        if xs.is_empty() || xs.len() != ys.len() {
            return false;
        }
        // Keep the most recent GP_TRAIN points (the BO agent already
        // subsets best+recent before calling fit).
        let start = xs.len().saturating_sub(GP_TRAIN);
        let xs = &xs[start..];
        let ys = &ys[start..];
        self.y_mean = (ys.iter().sum::<f64>() / ys.len() as f64) as f32;
        self.x_train.fill(0.0);
        self.y_train.fill(0.0);
        self.mask.fill(0.0);
        for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
            self.x_train[i * GP_FEATURES..(i + 1) * GP_FEATURES]
                .copy_from_slice(&Self::pad_features(x));
            self.y_train[i] = *y as f32 - self.y_mean;
            self.mask[i] = 1.0;
        }
        self.fitted = true;
        true
    }

    fn predict(&self, q: &[f64]) -> (f64, f64) {
        match self.posterior(std::slice::from_ref(&q.to_vec())) {
            Ok((mean, var)) => (mean[0] as f64, var[0] as f64),
            Err(_) => (0.0, 1.0),
        }
    }
}

/// Shared PJRT client handle. Creating a CPU client is cheap but not
/// free; hold one per process.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        Ok(Self { client: xla::PjRtClient::cpu()? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load both artifacts from `dir` (falling back where missing).
    pub fn load_models(&self, dir: &Path) -> (CostModel, GpSurrogate) {
        (
            CostModel::load(Some(&self.client), dir),
            GpSurrogate::load(Some(&self.client), dir, 0.5),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::bo::Surrogate;

    #[test]
    fn fallback_cost_model_without_artifacts() {
        let cm = CostModel::load(None, Path::new("/nonexistent"));
        assert!(!cm.is_xla());
        let out = cm.evaluate(&CostBatch::zeros()).unwrap();
        assert_eq!(out.len(), BATCH);
    }

    #[test]
    fn fallback_gp_fit_predict() {
        let mut gp = GpSurrogate::load(None, Path::new("/nonexistent"), 0.3);
        assert!(!gp.is_xla());
        let xs = vec![vec![0.0; 4], vec![1.0; 4]];
        let ys = [0.0, 1.0];
        assert!(gp.fit(&xs, &ys));
        let (m0, _) = gp.predict(&vec![0.0; 4]);
        let (m1, _) = gp.predict(&vec![1.0; 4]);
        assert!(m0 < m1, "m0={m0} m1={m1}");
    }

    #[test]
    fn gp_unfitted_predict_is_prior() {
        let gp = GpSurrogate::load(None, Path::new("/nonexistent"), 0.3);
        let (m, v) = gp.predict(&vec![0.5; 4]);
        assert_eq!((m, v), (0.0, 1.0));
    }

    #[test]
    fn gp_fit_rejects_bad_shapes() {
        let mut gp = GpSurrogate::load(None, Path::new("/nonexistent"), 0.3);
        assert!(!gp.fit(&[], &[]));
        assert!(!gp.fit(&[vec![0.0]], &[1.0, 2.0]));
    }

    // XLA-path tests live in rust/tests/xla_runtime.rs (they need the
    // artifacts built by `make artifacts`).
}
