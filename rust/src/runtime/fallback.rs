//! Pure-Rust twin of the AOT-compiled JAX/Pallas artifacts.
//!
//! The batched analytical cost model (L1 Pallas kernel `roofline.py` +
//! L2 graph `model.py::cost_model`) and the GP surrogate
//! (`model.py::gp_surrogate`) are both simple dense math; this module
//! implements the *identical* equations in Rust so that
//!
//! 1. the library works with no artifacts built (tests, offline), and
//! 2. the XLA path can be validated bit-for-bit (to f32 tolerance)
//!    against an independent implementation — `runtime::tests` and
//!    `python/tests/test_kernel.py` share the same fixtures.

/// Fixed artifact shapes (must match `python/compile/model.py`).
pub const BATCH: usize = 256; // candidate configs per call
pub const OPS: usize = 8; // operator classes per config
pub const DIMS: usize = 4; // network dimensions
pub const GP_TRAIN: usize = 64; // GP training points (padded)
pub const GP_QUERY: usize = 64; // GP query points (padded)
pub const GP_FEATURES: usize = 32; // normalized genome features (padded)

/// Inputs to one batched cost-model call (row-major `[BATCH, …]`).
#[derive(Debug, Clone)]
pub struct CostBatch {
    /// Per-op flops, `[BATCH * OPS]`.
    pub flops: Vec<f32>,
    /// Per-op HBM bytes, `[BATCH * OPS]`.
    pub bytes: Vec<f32>,
    /// Collective latency steps per dim, `[BATCH * DIMS]`.
    pub steps: Vec<f32>,
    /// Collective wire volume per dim (bytes), `[BATCH * DIMS]`.
    pub volume: Vec<f32>,
    /// Per-dim alpha (us), `[BATCH * DIMS]`.
    pub alpha_us: Vec<f32>,
    /// Per-dim beta (bytes/us), `[BATCH * DIMS]`.
    pub beta: Vec<f32>,
    /// Device peak (flops/us) — scalar broadcast.
    pub peak_flops_us: f32,
    /// Device memory bandwidth (bytes/us).
    pub mem_bytes_us: f32,
}

impl CostBatch {
    /// Zero-filled batch of the fixed artifact shape.
    pub fn zeros() -> Self {
        Self {
            flops: vec![0.0; BATCH * OPS],
            bytes: vec![0.0; BATCH * OPS],
            steps: vec![0.0; BATCH * DIMS],
            volume: vec![0.0; BATCH * DIMS],
            alpha_us: vec![0.0; BATCH * DIMS],
            beta: vec![1.0; BATCH * DIMS],
            peak_flops_us: 1.0,
            mem_bytes_us: 1.0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        let checks = [
            (self.flops.len(), BATCH * OPS, "flops"),
            (self.bytes.len(), BATCH * OPS, "bytes"),
            (self.steps.len(), BATCH * DIMS, "steps"),
            (self.volume.len(), BATCH * DIMS, "volume"),
            (self.alpha_us.len(), BATCH * DIMS, "alpha_us"),
            (self.beta.len(), BATCH * DIMS, "beta"),
        ];
        for (got, want, name) in checks {
            if got != want {
                return Err(format!("{name}: len {got} != {want}"));
            }
        }
        Ok(())
    }
}

/// The analytical estimate the Pallas kernel computes, per candidate:
///
/// `total[i] = Σ_k max(flops[i,k]/peak, bytes[i,k]/membw)
///           + Σ_d (steps[i,d]·alpha[i,d] + volume[i,d]/beta[i,d])`
pub fn cost_model_ref(batch: &CostBatch) -> Vec<f32> {
    let mut out = vec![0.0f32; BATCH];
    for i in 0..BATCH {
        let mut compute = 0.0f32;
        for k in 0..OPS {
            let f = batch.flops[i * OPS + k] / batch.peak_flops_us;
            let b = batch.bytes[i * OPS + k] / batch.mem_bytes_us;
            compute += f.max(b);
        }
        let mut comm = 0.0f32;
        for d in 0..DIMS {
            comm += batch.steps[i * DIMS + d] * batch.alpha_us[i * DIMS + d]
                + batch.volume[i * DIMS + d] / batch.beta[i * DIMS + d];
        }
        out[i] = compute + comm;
    }
    out
}

/// GP surrogate math identical to `model.py::gp_surrogate`: RBF kernel,
/// Cholesky solve, posterior mean/var at the queries. Padded rows are
/// marked by `mask` (1.0 = real, 0.0 = padding); padding contributes only
/// jitter to the diagonal.
pub struct GpFallback {
    pub lengthscale: f32,
    pub noise: f32,
}

impl GpFallback {
    /// `x_train: [GP_TRAIN * GP_FEATURES]`, `y: [GP_TRAIN]`,
    /// `mask: [GP_TRAIN]`, `x_query: [GP_QUERY * GP_FEATURES]`.
    /// Returns (mean `[GP_QUERY]`, var `[GP_QUERY]`).
    pub fn posterior(
        &self,
        x_train: &[f32],
        y: &[f32],
        mask: &[f32],
        x_query: &[f32],
    ) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(x_train.len(), GP_TRAIN * GP_FEATURES);
        assert_eq!(y.len(), GP_TRAIN);
        assert_eq!(mask.len(), GP_TRAIN);
        assert_eq!(x_query.len(), GP_QUERY * GP_FEATURES);
        let n = GP_TRAIN;
        let ls2 = 2.0 * self.lengthscale * self.lengthscale;

        // Masked RBF kernel: padded rows decouple into pure-noise rows.
        let mut k = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut d2 = 0.0f32;
                for f in 0..GP_FEATURES {
                    let diff = x_train[i * GP_FEATURES + f] - x_train[j * GP_FEATURES + f];
                    d2 += diff * diff;
                }
                k[i * n + j] = (-d2 / ls2).exp() * mask[i] * mask[j];
            }
            k[i * n + i] += self.noise + 1e-6;
            if mask[i] == 0.0 {
                k[i * n + i] += 1.0; // keep padded rows well-conditioned
            }
        }
        // Cholesky (f32, same as the f32 XLA path).
        let mut l = vec![0.0f32; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = k[i * n + j];
                for t in 0..j {
                    sum -= l[i * n + t] * l[j * n + t];
                }
                if i == j {
                    l[i * n + i] = sum.max(1e-12).sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        // alpha = K^-1 (y * mask)
        let ym: Vec<f32> = y.iter().zip(mask).map(|(a, m)| a * m).collect();
        let mut w = vec![0.0f32; n];
        for i in 0..n {
            let mut sum = ym[i];
            for t in 0..i {
                sum -= l[i * n + t] * w[t];
            }
            w[i] = sum / l[i * n + i];
        }
        let mut alpha = vec![0.0f32; n];
        for i in (0..n).rev() {
            let mut sum = w[i];
            for t in i + 1..n {
                sum -= l[t * n + i] * alpha[t];
            }
            alpha[i] = sum / l[i * n + i];
        }

        let mut mean = vec![0.0f32; GP_QUERY];
        let mut var = vec![0.0f32; GP_QUERY];
        for q in 0..GP_QUERY {
            let mut kq = vec![0.0f32; n];
            for i in 0..n {
                let mut d2 = 0.0f32;
                for f in 0..GP_FEATURES {
                    let diff = x_train[i * GP_FEATURES + f] - x_query[q * GP_FEATURES + f];
                    d2 += diff * diff;
                }
                kq[i] = (-d2 / ls2).exp() * mask[i];
            }
            mean[q] = kq.iter().zip(&alpha).map(|(a, b)| a * b).sum();
            // v = L^-1 kq
            let mut v = vec![0.0f32; n];
            for i in 0..n {
                let mut sum = kq[i];
                for t in 0..i {
                    sum -= l[i * n + t] * v[t];
                }
                v[i] = sum / l[i * n + i];
            }
            var[q] = (1.0 - v.iter().map(|x| x * x).sum::<f32>()).max(1e-9);
        }
        (mean, var)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_batch_costs_zero() {
        let b = CostBatch::zeros();
        let out = cost_model_ref(&b);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn roofline_max_is_respected() {
        let mut b = CostBatch::zeros();
        b.peak_flops_us = 10.0;
        b.mem_bytes_us = 5.0;
        b.flops[0] = 100.0; // 10 us compute
        b.bytes[0] = 10.0; // 2 us memory -> max = 10
        b.flops[OPS] = 10.0; // config 1: 1 us compute
        b.bytes[OPS] = 100.0; // 20 us memory -> max = 20
        let out = cost_model_ref(&b);
        assert!((out[0] - 10.0).abs() < 1e-6);
        assert!((out[1] - 20.0).abs() < 1e-6);
    }

    #[test]
    fn comm_term_is_alpha_beta() {
        let mut b = CostBatch::zeros();
        b.steps[0] = 3.0;
        b.alpha_us[0] = 2.0;
        b.volume[1] = 100.0;
        b.beta[1] = 50.0;
        let out = cost_model_ref(&b);
        assert!((out[0] - (6.0 + 2.0)).abs() < 1e-6);
    }

    #[test]
    fn validate_catches_bad_shapes() {
        let mut b = CostBatch::zeros();
        assert!(b.validate().is_ok());
        b.flops.pop();
        assert!(b.validate().is_err());
    }

    fn toy_gp_inputs() -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut x_train = vec![0.0f32; GP_TRAIN * GP_FEATURES];
        let mut y = vec![0.0f32; GP_TRAIN];
        let mut mask = vec![0.0f32; GP_TRAIN];
        // Three real points along feature 0: f(x) = x.
        for (i, xv) in [0.0f32, 0.5, 1.0].iter().enumerate() {
            x_train[i * GP_FEATURES] = *xv;
            y[i] = *xv;
            mask[i] = 1.0;
        }
        // Query at 0.25.
        let mut x_query = vec![0.0f32; GP_QUERY * GP_FEATURES];
        x_query[0] = 0.25;
        (x_train, y, mask, x_query)
    }

    #[test]
    fn gp_posterior_interpolates() {
        let (xt, y, mask, xq) = toy_gp_inputs();
        let gp = GpFallback { lengthscale: 0.3, noise: 1e-4 };
        let (mean, var) = gp.posterior(&xt, &y, &mask, &xq);
        assert!((mean[0] - 0.25).abs() < 0.1, "mean={}", mean[0]);
        assert!(var[0] < 0.2);
        // Unqueried padded rows produce prior-ish outputs, not NaN.
        assert!(mean.iter().all(|m| m.is_finite()));
        assert!(var.iter().all(|v| v.is_finite() && *v > 0.0));
    }

    #[test]
    fn gp_padding_is_inert() {
        // Same real points, different junk in padded x rows -> same
        // posterior (mask zeroes them out of the kernel).
        let (xt, y, mask, xq) = toy_gp_inputs();
        let mut xt2 = xt.clone();
        for i in 10..GP_TRAIN {
            for f in 0..GP_FEATURES {
                xt2[i * GP_FEATURES + f] = 0.77;
            }
        }
        let gp = GpFallback { lengthscale: 0.3, noise: 1e-4 };
        let (m1, v1) = gp.posterior(&xt, &y, &mask, &xq);
        let (m2, v2) = gp.posterior(&xt2, &y, &mask, &xq);
        assert!((m1[0] - m2[0]).abs() < 1e-5);
        assert!((v1[0] - v2[0]).abs() < 1e-5);
    }

    #[test]
    fn gp_matches_f64_reference_on_training_point() {
        let (xt, y, mask, mut xq) = toy_gp_inputs();
        xq[0] = 0.5; // exactly the second training point
        let gp = GpFallback { lengthscale: 0.3, noise: 1e-6 };
        let (mean, var) = gp.posterior(&xt, &y, &mask, &xq);
        assert!((mean[0] - 0.5).abs() < 0.05, "mean={}", mean[0]);
        assert!(var[0] < 0.05);
    }
}
