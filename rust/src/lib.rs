//! # COSMIC — full-stack co-design and optimization of distributed ML systems
//!
//! A reproduction of *"COSMIC: Enabling Full-Stack Co-Design and
//! Optimization of Distributed Machine Learning Systems"* (CS.DC 2025) as
//! a three-layer Rust + JAX + Pallas stack:
//!
//! - **Substrates** ([`topology`], [`collective`], [`compute`],
//!   [`workload`], [`sim`]) — an ASTRA-sim-like end-to-end distributed-ML
//!   simulator built from scratch.
//! - **Netsim** ([`netsim`]) — the pluggable network backend: a
//!   discrete-event core plus a flow-level max-min contention model
//!   behind the [`netsim::NetworkBackend`] trait, so the simulator can
//!   run at *analytical* fidelity (fast, congestion-blind) or
//!   *flow-level* fidelity (congestion-aware: switch oversubscription,
//!   background traffic, contending gradient collectives). Select with
//!   `Simulator::with_backend` / `with_fidelity`, or let agents search
//!   it via the PsA "Network Fidelity" knob.
//! - **PsA** ([`psa`]) — the Parameter Set Architecture: a schema of
//!   searchable parameters, value ranges and cross-parameter constraints
//!   that decouples domain experts from search-agent configuration.
//! - **PSS** ([`pss`]) — the Parameter Set Scheduler: derives agent
//!   action spaces and environment configuration from a PsA schema.
//! - **Agents** ([`agents`]) — Random Walker, Genetic Algorithm, Ant
//!   Colony Optimization and Bayesian Optimization search agents.
//! - **DSE** ([`dse`]) — the agent⇄environment loop, the paper's two
//!   reward functions, the LIBRA-style network dollar-cost model, run
//!   history/convergence tracking, plus the evaluation-throughput
//!   machinery: a cross-evaluation trace/collective-cost cache
//!   ([`dse::EvalCache`]) and the staged multi-fidelity search mode
//!   ([`dse::SearchStrategy::Staged`]: screen analytically, promote the
//!   running top-K to flow-level re-scoring).
//! - **Faults** ([`faults`]) — deterministic fault injection: seeded
//!   [`faults::FaultScenario`]s of compute stragglers, degraded links
//!   and MTBF device-failure models, applied across the whole stack
//!   (compute times, collective completion, both netsim fidelity rungs)
//!   with Young/Daly checkpoint-restart goodput accounting
//!   ([`sim::SimReport::goodput`]). Robust DSE optimizes expected or
//!   worst-case goodput over a [`faults::ScenarioSuite`]
//!   (`Environment::with_scenarios`, `cosmic search --robust`).
//! - **Runtime** ([`runtime`]) — the PJRT bridge that loads the
//!   AOT-compiled JAX/Pallas batched cost model and GP surrogate
//!   (`artifacts/*.hlo.txt`) plus a bit-equivalent pure-Rust fallback.
//! - **Obs** ([`obs`]) — dependency-free observability: a zero-cost
//!   [`obs::TraceSink`] capturing the simulator's hierarchical timeline
//!   (exported as Chrome/Perfetto JSON via `cosmic simulate --trace`),
//!   a lock-sharded [`obs::MetricsRegistry`] and a per-step
//!   [`obs::SearchTimeline`] of DSE runs (`cosmic search --telemetry`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use cosmic::prelude::*;
//!
//! let cluster = cosmic::sim::presets::system1();
//! let model = cosmic::workload::models::presets::gpt3_13b().with_simulated_layers(4);
//! let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).unwrap();
//! let report = Simulator::new()
//!     .run(&cluster, &model, &par, 1024, ExecutionMode::Training)
//!     .unwrap();
//! println!("iteration latency: {:.1} ms", report.latency_us / 1e3);
//!
//! // Same design point under flow-level contention (4:1 oversubscribed
//! // switch fabric):
//! use cosmic::netsim::FlowLevelConfig;
//! let congested = Simulator::new()
//!     .with_flow_config(FlowLevelConfig::oversubscribed(4.0))
//!     .run(&cluster, &model, &par, 1024, ExecutionMode::Training)
//!     .unwrap();
//! println!("under congestion:  {:.1} ms", congested.latency_us / 1e3);
//! ```

pub mod agents;
pub mod collective;
pub mod compute;
pub mod dse;
pub mod faults;
pub mod harness;
pub mod netsim;
pub mod obs;
pub mod psa;
pub mod util;
pub mod pss;
pub mod runtime;
pub mod sim;
pub mod topology;
pub mod workload;

/// Convenience re-exports for the common API surface.
pub mod prelude {
    pub use crate::collective::{
        CollAlgo, CollectiveConfig, CollectiveKind, MultiDimPolicy, SchedulingPolicy,
    };
    pub use crate::compute::ComputeDevice;
    pub use crate::dse::{
        DseConfig, DseRunner, Environment, EvalCache, Objective, RobustAggregate, SearchStrategy,
        WorkloadSpec,
    };
    pub use crate::faults::{FaultScenario, Goodput, ScenarioSuite};
    pub use crate::netsim::{FidelityMode, FlowLevelConfig, NetworkBackend};
    pub use crate::obs::{MetricsRegistry, Recorder, SearchObserver, TraceSink};
    pub use crate::psa::{DesignPoint, ParamDef, Schema, Stack};
    pub use crate::pss::{Pss, SearchScope};
    pub use crate::sim::{ClusterConfig, SimReport, Simulator};
    pub use crate::topology::{DimKind, NetworkDim, Topology};
    pub use crate::workload::{ExecutionMode, ModelConfig, Parallelization};
}
