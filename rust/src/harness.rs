//! Experiment harness shared by the `benches/` table/figure reproducers
//! and the examples: scoped DSE drivers, latency-spread sampling, and
//! plain-text table/series printers that mirror the paper's layout.
//!
//! (criterion is not vendored in this offline image; benches are
//! `harness = false` binaries that time with `std::time::Instant` and
//! print the paper-shaped rows — see DESIGN.md §Substitutions.)

use crate::agents::AgentKind;
use crate::dse::{
    DseConfig, DseRunner, Environment, Objective, RunResult, SearchStrategy, WorkloadSpec,
};
use crate::obs::SearchObserver;
use crate::psa::paper_table4_schema;
use crate::pss::{Pss, SearchScope};
use crate::sim::ClusterConfig;
use crate::sim::Simulator;
use crate::util::Rng;
use crate::workload::{enumerate_parallelizations, Parallelization};
use std::sync::Arc;
use std::time::Instant;

/// The default (un-optimized) baseline parallelization used as the
/// frozen workload value for collective-/network-only scopes: pure data
/// parallel with sharding, DP capped at 64.
pub fn default_baseline_par(npus: u64) -> Parallelization {
    Parallelization::derive(npus, npus.min(64), 1, 1, true).expect("baseline par")
}

/// An untuned-but-sane baseline parallelization: among all valid
/// (memory-fitting, simulatable) parallelizations of the first workload
/// on the target cluster, take the *median-latency* one. This is the
/// frozen workload value for collective-/network-only scopes -- the
/// paper's single-stack baselines assume the target system ships with a
/// workable but unoptimized configuration.
pub fn median_baseline_par(cluster: &ClusterConfig, workload: &WorkloadSpec) -> Parallelization {
    let sim = Simulator::new();
    let npus = cluster.npus();
    let mut scored: Vec<(f64, Parallelization)> = enumerate_parallelizations(npus, 4, &[true])
        .into_iter()
        .filter(|p| workload.batch >= p.dp)
        .filter_map(|p| {
            sim.run(cluster, &workload.model, &p, workload.batch, workload.mode)
                .ok()
                .map(|r| (r.latency_us, p))
        })
        .collect();
    if scored.is_empty() {
        return default_baseline_par(npus);
    }
    scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    scored[scored.len() / 2].1
}

/// Build the standard evaluation environment: Table 4 schema over the
/// given system, one or more training workloads, one objective. The
/// frozen-workload baseline is the median valid parallelization of the
/// first workload (see [`median_baseline_par`]).
pub fn make_env(
    cluster: ClusterConfig,
    workloads: Vec<WorkloadSpec>,
    objective: Objective,
) -> Environment {
    let npus = cluster.npus();
    let dims = cluster.topology.num_dims();
    let baseline = median_baseline_par(&cluster, &workloads[0]);
    let pss = Pss::new(paper_table4_schema(npus, dims), cluster, baseline);
    Environment::new(pss, workloads, objective)
}

/// Like [`make_env`], but with the netsim "Network Fidelity" knob in the
/// schema, so agents search the simulation-fidelity axis too (analytical
/// screening vs flow-level contention — see `crate::netsim`).
pub fn make_env_with_fidelity(
    cluster: ClusterConfig,
    workloads: Vec<WorkloadSpec>,
    objective: Objective,
) -> Environment {
    let npus = cluster.npus();
    let dims = cluster.topology.num_dims();
    let baseline = median_baseline_par(&cluster, &workloads[0]);
    let pss = Pss::new(
        crate::psa::with_fidelity_param(paper_table4_schema(npus, dims)),
        cluster,
        baseline,
    );
    Environment::new(pss, workloads, objective)
}

/// Like [`make_env`], but robust: the schema gains the resilience
/// "Checkpoint Interval" knob and every evaluation scores the whole
/// fault suite (nominal + `k` seeded scenarios from `faults_seed`),
/// aggregated per `aggregate` — the `cosmic search --robust` setup.
pub fn make_env_robust(
    cluster: ClusterConfig,
    workloads: Vec<WorkloadSpec>,
    objective: Objective,
    faults_seed: u64,
    k: usize,
    aggregate: crate::dse::RobustAggregate,
) -> Environment {
    let npus = cluster.npus();
    let dims = cluster.topology.num_dims();
    let baseline = median_baseline_par(&cluster, &workloads[0]);
    let pss = Pss::new(
        crate::psa::with_checkpoint_param(paper_table4_schema(npus, dims)),
        cluster,
        baseline,
    );
    Environment::new(pss, workloads, objective)
        .with_scenarios(crate::faults::ScenarioSuite::generate(faults_seed, k, dims), aggregate)
}

/// Like [`make_env`], but multi-tenant: every evaluation sweeps a
/// co-tenant trace suite (nominal + `k` seeded traces of `profile` from
/// `traffic_seed`), aggregated per `aggregate` — the `cosmic search
/// --traffic` setup. The schema stays the paper Table 4 one: an active
/// suite overrides the PsA "Traffic Profile" knob, so adding it here
/// would only pad the action space with a dead slot.
pub fn make_env_traffic(
    cluster: ClusterConfig,
    workloads: Vec<WorkloadSpec>,
    objective: Objective,
    profile: &str,
    traffic_seed: u64,
    k: usize,
    aggregate: crate::dse::RobustAggregate,
) -> Result<Environment, String> {
    let npus = cluster.npus();
    let dims = cluster.topology.num_dims();
    let suite = crate::netsim::TrafficSuite::generate(profile, traffic_seed, k, dims)?;
    let baseline = median_baseline_par(&cluster, &workloads[0]);
    let pss = Pss::new(paper_table4_schema(npus, dims), cluster, baseline);
    Ok(Environment::new(pss, workloads, objective)
        .with_traffic_suite(suite, aggregate)
        .with_traffic_seed(traffic_seed))
}

/// Outcome of one scoped search, with the quantities the paper reports.
#[derive(Debug, Clone)]
pub struct ScopedResult {
    pub scope: SearchScope,
    pub run: RunResult,
    /// End-to-end latency (us) of the best design (sum over workloads).
    pub best_latency_us: f64,
    pub wall_secs: f64,
}

/// Run one (scope, agent) search and resolve the best design's latency.
pub fn scoped_search(
    env: &mut Environment,
    scope: SearchScope,
    agent: AgentKind,
    steps: u64,
    seed: u64,
) -> ScopedResult {
    scoped_search_with(env, scope, agent, steps, seed, SearchStrategy::GenomeFidelity)
}

/// [`scoped_search`] under an explicit [`SearchStrategy`] — e.g.
/// `SearchStrategy::Staged { promote_top_k }` to screen on the
/// Analytical rung and re-score only the running top-K under flow-level
/// contention.
pub fn scoped_search_with(
    env: &mut Environment,
    scope: SearchScope,
    agent: AgentKind,
    steps: u64,
    seed: u64,
    strategy: SearchStrategy,
) -> ScopedResult {
    let started = Instant::now();
    let run = DseRunner::new(DseConfig::new(agent, steps, seed), scope)
        .with_strategy(strategy)
        .run(env);
    let wall_secs = started.elapsed().as_secs_f64();
    // The runner materializes best_reports at the fidelity that scored
    // the winner (flow level for staged runs), so sum those instead of
    // re-evaluating at the genome's own knob.
    let best_latency_us = if run.best_reports.is_empty() {
        f64::INFINITY
    } else {
        run.best_reports.iter().map(|r| r.latency_us).sum()
    };
    ScopedResult { scope, run, best_latency_us, wall_secs }
}

/// [`scoped_search_with`] with a [`SearchObserver`] attached: per-step
/// telemetry lands in the observer's timeline, and the environment's
/// evaluation/cache counters are exported into its metrics once the run
/// finishes.
pub fn scoped_search_observed(
    env: &mut Environment,
    scope: SearchScope,
    agent: AgentKind,
    steps: u64,
    seed: u64,
    strategy: SearchStrategy,
    observer: &Arc<SearchObserver>,
) -> ScopedResult {
    let started = Instant::now();
    let run = DseRunner::new(DseConfig::new(agent, steps, seed), scope)
        .with_strategy(strategy)
        .with_observer(Arc::clone(observer))
        .run(env);
    let wall_secs = started.elapsed().as_secs_f64();
    env.export_metrics(&observer.metrics);
    let best_latency_us = if run.best_reports.is_empty() {
        f64::INFINITY
    } else {
        run.best_reports.iter().map(|r| r.latency_us).sum()
    };
    ScopedResult { scope, run, best_latency_us, wall_secs }
}

/// Latency spread over random valid genomes in a scope (Figure 4):
/// returns (min, max, valid-sample count).
pub fn latency_spread(
    env: &Environment,
    scope: SearchScope,
    samples: usize,
    seed: u64,
) -> (f64, f64, usize) {
    let space = env.pss.build_space(scope);
    let mut rng = Rng::seed_from_u64(seed);
    let mut min = f64::INFINITY;
    let mut max: f64 = 0.0;
    let mut n = 0;
    for _ in 0..samples {
        if let Some(g) = space.random_valid_genome(&mut rng, 500) {
            if let Some(lat) = env.latency_us(&g) {
                min = min.min(lat);
                max = max.max(lat);
                n += 1;
            }
        }
    }
    (min, max, n)
}

/// Fixed-width table printer (paper-style rows).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line: Vec<String> =
        headers.iter().enumerate().map(|(i, h)| format!("{:<w$}", h, w = widths[i])).collect();
    println!("{}", line.join("  "));
    println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
    for row in rows {
        let line: Vec<String> = row
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect();
        println!("{}", line.join("  "));
    }
}

/// Print a reward-vs-step series at a fixed sampling interval
/// (Figure 10-style, one line per sample point).
pub fn print_series(name: &str, curve: &[f64], every: usize) {
    println!("\n--- {name} (best-so-far reward vs step) ---");
    for (i, v) in curve.iter().enumerate() {
        if i % every == 0 || i + 1 == curve.len() {
            println!("{name},{},{v:.6e}", i + 1);
        }
    }
}

/// Normalize each scope's best reward to the full-stack result (the
/// paper's Figures 6/7 bar normalization). Input: (label, best_reward);
/// the entry labelled `full_label` is the denominator.
pub fn normalize_to(rows: &[(String, f64)], full_label: &str) -> Vec<(String, f64)> {
    let full = rows
        .iter()
        .find(|(l, _)| l == full_label)
        .map(|(_, r)| *r)
        .unwrap_or(1.0)
        .max(1e-300);
    rows.iter().map(|(l, r)| (l.clone(), r / full)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::presets;
    use crate::workload::models::presets as wl;

    #[test]
    fn scoped_search_produces_finite_latency() {
        let mut env = make_env(
            presets::system1(),
            vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(4), 1024)],
            Objective::PerfPerBwPerNpu,
        );
        let r = scoped_search(&mut env, SearchScope::WorkloadOnly, AgentKind::Rw, 20, 1);
        assert!(r.best_latency_us.is_finite());
        assert!(r.run.best_reward > 0.0);
    }

    #[test]
    fn observed_search_exports_metrics() {
        let mut env = make_env(
            presets::system1(),
            vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(4), 1024)],
            Objective::PerfPerBwPerNpu,
        );
        let obs = Arc::new(SearchObserver::new());
        let r = scoped_search_observed(
            &mut env,
            SearchScope::WorkloadOnly,
            AgentKind::Rw,
            15,
            1,
            SearchStrategy::GenomeFidelity,
            &obs,
        );
        assert_eq!(r.run.history.len(), 15);
        assert_eq!(obs.timeline().steps.len(), 15);
        assert_eq!(obs.metrics.counter("env.evals"), env.evals());
    }

    #[test]
    fn traffic_env_searches_under_load() {
        let mut env = make_env_traffic(
            presets::system1(),
            vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(4), 1024)],
            Objective::PerfPerBwPerNpu,
            "diurnal",
            7,
            1,
            crate::dse::RobustAggregate::Expected,
        )
        .unwrap();
        let r = scoped_search(&mut env, SearchScope::WorkloadOnly, AgentKind::Rw, 10, 2);
        assert!(r.run.best_reward > 0.0);
        assert!(env.traffic_evals() > 0);
        assert!(make_env_traffic(
            presets::system1(),
            vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(4), 1024)],
            Objective::PerfPerBwPerNpu,
            "rushhour",
            7,
            1,
            crate::dse::RobustAggregate::Expected,
        )
        .is_err());
    }

    #[test]
    fn latency_spread_min_le_max() {
        let env = make_env(
            presets::system2(),
            vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(4), 1024)],
            Objective::RawLatency,
        );
        let (min, max, n) = latency_spread(&env, SearchScope::WorkloadOnly, 30, 5);
        assert!(n > 0);
        assert!(min <= max);
        assert!(min > 0.0);
    }

    #[test]
    fn normalize_divides_by_full() {
        let rows = vec![("a".to_string(), 2.0), ("full".to_string(), 4.0)];
        let out = normalize_to(&rows, "full");
        assert_eq!(out[0].1, 0.5);
        assert_eq!(out[1].1, 1.0);
    }

    #[test]
    fn baseline_par_valid_for_all_presets() {
        for i in 1..=3 {
            let c = presets::by_index(i).unwrap();
            let p = default_baseline_par(c.npus());
            assert!(p.validate(c.npus()).is_ok());
        }
    }
}
