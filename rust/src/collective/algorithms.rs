//! Analytical alpha-beta cost of collective algorithms over one network
//! dimension (paper §2.2).
//!
//! The four algorithms the paper searches over (Table 1/4's
//! `MultiDim {Ring, Direct, RHD, DBT}`) have well-known alpha-beta costs
//! for an `n`-NPU group moving a per-NPU buffer of `S` bytes:
//!
//! | algo | all-reduce time | character |
//! |---|---|---|
//! | Ring (RI)   | `2(n-1)α + 2S(n-1)/(n·β)`            | bandwidth-optimal, latency-heavy |
//! | Direct (DI) | `2α + 2S(n-1)/(n·β)` (n² messages)   | latency-optimal, needs all-to-all paths |
//! | RHD         | `2log₂(n)α + 2S(n-1)/(n·β)`          | log latency, bw-optimal for powers of two |
//! | DBT         | `2⌈log₂(n)⌉α + 2S/β` (two half-bw trees) | log latency, ~bw-optimal at scale |
//!
//! Reduce-Scatter and All-Gather are each "half" an All-Reduce; All-to-All
//! is inherently direct-exchange shaped. Non-power-of-two groups pay one
//! extra (α + S/β) round for RHD/DBT (the standard 3-phase trick).
//!
//! These closed forms are used in two places: (i) the L1 Pallas kernel and
//! its Rust fallback (`runtime::fallback`) evaluate them in batch as the
//! DSE pre-filter, and (ii) the chunk scheduler uses them as per-chunk
//! phase durations in the discrete-event simulator.

use crate::topology::DimCost;
use std::fmt;

/// Collective communication pattern (Figure 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    ReduceScatter,
    AllGather,
    AllReduce,
    AllToAll,
}

impl CollectiveKind {
    pub const ALL: [CollectiveKind; 4] = [
        CollectiveKind::ReduceScatter,
        CollectiveKind::AllGather,
        CollectiveKind::AllReduce,
        CollectiveKind::AllToAll,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            CollectiveKind::ReduceScatter => "reduce-scatter",
            CollectiveKind::AllGather => "all-gather",
            CollectiveKind::AllReduce => "all-reduce",
            CollectiveKind::AllToAll => "all-to-all",
        }
    }
}

impl fmt::Display for CollectiveKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Collective algorithm (paper's RI / DI / RHD / DBT).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollAlgo {
    Ring,
    Direct,
    Rhd,
    Dbt,
}

impl CollAlgo {
    pub const ALL: [CollAlgo; 4] = [CollAlgo::Ring, CollAlgo::Direct, CollAlgo::Rhd, CollAlgo::Dbt];

    /// Paper notation: RI / DI / RHD / DBT.
    pub fn short(&self) -> &'static str {
        match self {
            CollAlgo::Ring => "RI",
            CollAlgo::Direct => "DI",
            CollAlgo::Rhd => "RHD",
            CollAlgo::Dbt => "DBT",
        }
    }

    pub fn from_short(s: &str) -> Option<Self> {
        match s.trim().to_ascii_uppercase().as_str() {
            "RI" | "RING" => Some(CollAlgo::Ring),
            "DI" | "DIRECT" => Some(CollAlgo::Direct),
            "RHD" => Some(CollAlgo::Rhd),
            "DBT" => Some(CollAlgo::Dbt),
            _ => None,
        }
    }

    /// Figure 9's 1-based parameter index (1=RI, 2=DI, 3=RHD, 4=DBT).
    pub fn index(&self) -> usize {
        match self {
            CollAlgo::Ring => 1,
            CollAlgo::Direct => 2,
            CollAlgo::Rhd => 3,
            CollAlgo::Dbt => 4,
        }
    }
}

impl fmt::Display for CollAlgo {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.short())
    }
}

fn log2_ceil(n: u64) -> f64 {
    (64 - (n - 1).leading_zeros()) as f64
}

fn is_pow2(n: u64) -> bool {
    n.count_ones() == 1
}

/// Latency (α) term in microseconds for one *phase set* of the algorithm.
fn alpha_steps(algo: CollAlgo, kind: CollectiveKind, n: u64) -> f64 {
    let nf = n as f64;
    let log = log2_ceil(n);
    // Steps for the "one-sided" primitives (RS or AG); AR composes both.
    let one_sided = match algo {
        CollAlgo::Ring => nf - 1.0,
        CollAlgo::Direct => 1.0,
        CollAlgo::Rhd => log,
        CollAlgo::Dbt => log,
    };
    let extra = if matches!(algo, CollAlgo::Rhd | CollAlgo::Dbt) && !is_pow2(n) {
        1.0 // pre/post round for non-power-of-two groups
    } else {
        0.0
    };
    match kind {
        CollectiveKind::ReduceScatter | CollectiveKind::AllGather => one_sided + extra,
        CollectiveKind::AllReduce => 2.0 * one_sided + extra,
        // All-to-all: personalized exchange. Ring forwards n-1 steps;
        // direct is one shot; RHD/DBT degrade to log-structured exchange.
        CollectiveKind::AllToAll => match algo {
            CollAlgo::Ring => nf - 1.0,
            CollAlgo::Direct => 1.0,
            CollAlgo::Rhd | CollAlgo::Dbt => log + extra,
        },
    }
}

/// Bandwidth (β) term: bytes crossing the per-NPU link, as a multiple of
/// the per-NPU buffer size `S`.
fn beta_volume_factor(algo: CollAlgo, kind: CollectiveKind, n: u64) -> f64 {
    let nf = n as f64;
    let frac = (nf - 1.0) / nf;
    let one_sided = match algo {
        // RS/AG move S(n-1)/n for ring, direct, RHD alike.
        CollAlgo::Ring | CollAlgo::Direct | CollAlgo::Rhd => frac,
        // DBT does a full-buffer reduce+broadcast on two half-bandwidth
        // trees: effective volume ~= S per one-sided primitive.
        CollAlgo::Dbt => 1.0,
    };
    let extra = if matches!(algo, CollAlgo::Rhd | CollAlgo::Dbt) && !is_pow2(n) {
        1.0 / nf // remainder NPUs exchange one shard
    } else {
        0.0
    };
    match kind {
        CollectiveKind::ReduceScatter | CollectiveKind::AllGather => one_sided + extra,
        CollectiveKind::AllReduce => 2.0 * one_sided + extra,
        // All-to-all: every NPU sends S(n-1)/n regardless of algorithm,
        // but ring-style forwarding relays payload ~n/2 times on average.
        CollectiveKind::AllToAll => match algo {
            CollAlgo::Direct => frac,
            CollAlgo::Ring => frac * nf / 2.0,
            CollAlgo::Rhd | CollAlgo::Dbt => frac * log2_ceil(n) / 2.0 + extra,
        },
    }
}

/// The raw alpha-beta terms of one collective phase over an `n`-NPU
/// group: `(latency steps, wire-volume multiple of the per-NPU buffer)`.
/// Phase time is `steps * alpha + volume * S / beta`. Exposed for the
/// `netsim` phase planner, which needs the two terms separately to apply
/// congestion to the bandwidth term only.
pub fn alpha_beta_terms(algo: CollAlgo, kind: CollectiveKind, n: u64) -> (f64, f64) {
    (alpha_steps(algo, kind, n), beta_volume_factor(algo, kind, n))
}

/// Time (microseconds) for a collective of `bytes` per-NPU payload over a
/// group of `dim.npus` NPUs on one dimension, using `algo`.
///
/// `bytes` is the *per-NPU* buffer size (the paper's chunk size after
/// upstream dimensions have scattered it). Groups of 1 are free.
pub fn collective_time_us(algo: CollAlgo, kind: CollectiveKind, dim: &DimCost, bytes: f64) -> f64 {
    let n = dim.npus;
    if n <= 1 || bytes <= 0.0 {
        return 0.0;
    }
    let alpha = alpha_steps(algo, kind, n) * dim.alpha_us;
    let beta = beta_volume_factor(algo, kind, n) * bytes / dim.beta_bytes_per_us;
    alpha + beta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DimKind, NetworkDim};

    fn dim(n: u64, bw: f64, lat: f64) -> DimCost {
        DimCost::from_dim(&NetworkDim::new(DimKind::Ring, n, bw, lat))
    }

    const MB: f64 = 1e6;

    #[test]
    fn group_of_one_is_free() {
        let d = dim(1, 100.0, 1.0);
        for a in CollAlgo::ALL {
            for k in CollectiveKind::ALL {
                assert_eq!(collective_time_us(a, k, &d, MB), 0.0);
            }
        }
    }

    #[test]
    fn zero_bytes_is_free() {
        let d = dim(8, 100.0, 1.0);
        assert_eq!(collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &d, 0.0), 0.0);
    }

    #[test]
    fn ring_allreduce_matches_closed_form() {
        let d = dim(8, 100.0, 1.0);
        let s = 64.0 * MB;
        let expect = 2.0 * 7.0 * 1.0 + 2.0 * (7.0 / 8.0) * s / 1e5;
        let got = collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &d, s);
        assert!((got - expect).abs() < 1e-6, "got {got}, expect {expect}");
    }

    #[test]
    fn rhd_allreduce_matches_closed_form_pow2() {
        let d = dim(16, 100.0, 1.0);
        let s = 64.0 * MB;
        let expect = 2.0 * 4.0 * 1.0 + 2.0 * (15.0 / 16.0) * s / 1e5;
        let got = collective_time_us(CollAlgo::Rhd, CollectiveKind::AllReduce, &d, s);
        assert!((got - expect).abs() < 1e-6);
    }

    #[test]
    fn latency_ordering_small_messages() {
        // For tiny payloads latency dominates: direct < RHD/DBT < ring for
        // any non-trivial group — this is the paper's §6.3 observation that
        // inference (small decode messages) prefers DI/RHD/DBT over RI.
        let d = dim(16, 100.0, 2.0);
        let tiny = 1024.0;
        let t = |a| collective_time_us(a, CollectiveKind::AllReduce, &d, tiny);
        assert!(t(CollAlgo::Direct) < t(CollAlgo::Rhd));
        assert!(t(CollAlgo::Rhd) < t(CollAlgo::Ring));
        assert!(t(CollAlgo::Dbt) < t(CollAlgo::Ring));
    }

    #[test]
    fn ring_is_bandwidth_optimal_large_messages() {
        // For huge payloads on low-latency links, ring ties/beats DBT
        // (which moves 2S vs ring's 2S(n-1)/n).
        let d = dim(16, 100.0, 0.01);
        let huge = 1e9;
        let ring = collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &d, huge);
        let dbt = collective_time_us(CollAlgo::Dbt, CollectiveKind::AllReduce, &d, huge);
        assert!(ring < dbt);
    }

    #[test]
    fn allreduce_equals_rs_plus_ag_for_ring() {
        let d = dim(8, 200.0, 0.5);
        let s = 10.0 * MB;
        let ar = collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &d, s);
        let rs = collective_time_us(CollAlgo::Ring, CollectiveKind::ReduceScatter, &d, s);
        let ag = collective_time_us(CollAlgo::Ring, CollectiveKind::AllGather, &d, s);
        assert!((ar - (rs + ag)).abs() < 1e-6);
    }

    #[test]
    fn non_pow2_pays_extra_round_for_rhd() {
        // Same total NPUs, but 12 (non-pow2) pays the pre/post round.
        let d12 = dim(12, 100.0, 1.0);
        let alpha12 = alpha_steps(CollAlgo::Rhd, CollectiveKind::AllReduce, 12);
        // ceil(log2(12)) = 4 -> 2*4 + 1 extra = 9
        assert!((alpha12 - 9.0).abs() < 1e-12);
        assert!(collective_time_us(CollAlgo::Rhd, CollectiveKind::AllReduce, &d12, MB) > 0.0);
    }

    #[test]
    fn cost_scales_linearly_in_bytes_at_fixed_alpha() {
        let d = dim(8, 100.0, 0.0);
        let t1 = collective_time_us(CollAlgo::Ring, CollectiveKind::AllGather, &d, MB);
        let t2 = collective_time_us(CollAlgo::Ring, CollectiveKind::AllGather, &d, 2.0 * MB);
        assert!((t2 - 2.0 * t1).abs() < 1e-9);
    }

    #[test]
    fn all_to_all_direct_cheapest() {
        let d = dim(16, 100.0, 0.5);
        let s = 8.0 * MB;
        let t = |a| collective_time_us(a, CollectiveKind::AllToAll, &d, s);
        assert!(t(CollAlgo::Direct) < t(CollAlgo::Ring));
        assert!(t(CollAlgo::Direct) < t(CollAlgo::Rhd));
    }

    #[test]
    fn short_and_index_roundtrip() {
        for a in CollAlgo::ALL {
            assert_eq!(CollAlgo::from_short(a.short()), Some(a));
        }
        assert_eq!(CollAlgo::Ring.index(), 1);
        assert_eq!(CollAlgo::Dbt.index(), 4);
    }

    #[test]
    fn more_npus_more_latency_steps_for_ring() {
        let d4 = dim(4, 100.0, 1.0);
        let d16 = dim(16, 100.0, 1.0);
        let tiny = 8.0;
        let t4 = collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &d4, tiny);
        let t16 = collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &d16, tiny);
        assert!(t16 > t4);
    }
}
