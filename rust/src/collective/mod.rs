//! Collective communication substrate (paper §2.2, Figure 2).
//!
//! Distributed ML synchronizes NPUs with *collective communications* —
//! Reduce-Scatter, All-Gather, All-Reduce, All-to-All — executed in
//! fine-grained *chunks* by a *collective algorithm* (Ring, Direct,
//! Recursive Halving-Doubling, Double Binary Tree). This module provides:
//!
//! - [`algorithms`] — analytical alpha-beta cost of each (kind, algorithm)
//!   pair over one network dimension;
//! - [`multidim`] — composition of per-dimension phases into a
//!   multi-dimensional collective, either the **Baseline** hierarchical
//!   schedule or **BlueConnect**'s pipelined RS/AG decomposition;
//! - [`scheduler`] — the chunk-level collective scheduler (LIFO/FIFO
//!   policies, `chunks-per-collective` pipelining) used by the
//!   discrete-event simulator.

pub mod algorithms;
pub mod multidim;
pub mod scheduler;

pub use algorithms::{alpha_beta_terms, collective_time_us, CollAlgo, CollectiveKind};
pub use multidim::{
    compose_phases, multidim_collective_time_us, phase_plan, phase_plan_into, ChunkSchedule,
    MultiDimPolicy, PhaseSpec,
};
pub use scheduler::{ChunkScheduler, SchedulingPolicy};

/// Max-over-participants completion under heterogeneous slowdown: a
/// collective cannot finish before its slowest participant has
/// contributed, so in lockstep SPMD execution per-group straggler
/// multipliers collapse to the group maximum (never below `1.0`, the
/// healthy rate). Used by [`crate::faults::StragglerModel`] to scale
/// compute phases feeding each collective.
pub fn straggler_factor(multipliers: &[f64]) -> f64 {
    multipliers.iter().copied().fold(1.0, f64::max)
}

/// Full collective-stack configuration — the paper's "Collective Knob"
/// rows in Tables 1 and 4.
#[derive(Debug, Clone, PartialEq)]
pub struct CollectiveConfig {
    /// Chunk scheduling policy ({LIFO, FIFO}).
    pub scheduling: SchedulingPolicy,
    /// One algorithm per network dimension (MultiDim {RI, DI, RHD, DBT}).
    pub algorithms: Vec<CollAlgo>,
    /// Chunks per collective ({1..=32}; Table 4 restricts to {2,4,8,16}).
    pub chunks: u32,
    /// Multi-dimensional composition ({Baseline, BlueConnect}).
    pub multidim: MultiDimPolicy,
}

impl CollectiveConfig {
    pub fn new(
        scheduling: SchedulingPolicy,
        algorithms: Vec<CollAlgo>,
        chunks: u32,
        multidim: MultiDimPolicy,
    ) -> Self {
        Self { scheduling, algorithms, chunks, multidim }
    }

    /// Paper-style algorithm notation, e.g. `[RI, RHD, DBT, DBT]`.
    pub fn algo_notation(&self) -> String {
        let inner: Vec<&str> = self.algorithms.iter().map(|a| a.short()).collect();
        format!("[{}]", inner.join(", "))
    }

    pub fn validate(&self, num_dims: usize) -> Result<(), String> {
        if self.algorithms.len() != num_dims {
            return Err(format!(
                "collective config has {} algorithms but topology has {} dims",
                self.algorithms.len(),
                num_dims
            ));
        }
        if self.chunks == 0 || self.chunks > 32 {
            return Err(format!("chunks per collective must be in 1..=32, got {}", self.chunks));
        }
        Ok(())
    }
}

impl Default for CollectiveConfig {
    fn default() -> Self {
        Self {
            scheduling: SchedulingPolicy::Fifo,
            algorithms: vec![CollAlgo::Ring],
            chunks: 1,
            multidim: MultiDimPolicy::Baseline,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_checks_dims_and_chunks() {
        let c = CollectiveConfig::new(
            SchedulingPolicy::Lifo,
            vec![CollAlgo::Ring, CollAlgo::Rhd],
            4,
            MultiDimPolicy::Baseline,
        );
        assert!(c.validate(2).is_ok());
        assert!(c.validate(3).is_err());
        let mut bad = c.clone();
        bad.chunks = 0;
        assert!(bad.validate(2).is_err());
        let mut bad = c;
        bad.chunks = 64;
        assert!(bad.validate(2).is_err());
    }

    #[test]
    fn straggler_factor_is_max_over_participants() {
        assert_eq!(straggler_factor(&[]), 1.0);
        assert_eq!(straggler_factor(&[1.0, 1.0]), 1.0);
        assert_eq!(straggler_factor(&[1.0, 1.4, 1.2]), 1.4);
        // Faster-than-nominal groups never speed up the lockstep whole.
        assert_eq!(straggler_factor(&[0.5]), 1.0);
    }

    #[test]
    fn notation_matches_paper() {
        let c = CollectiveConfig::new(
            SchedulingPolicy::Lifo,
            vec![CollAlgo::Ring, CollAlgo::Rhd, CollAlgo::Dbt, CollAlgo::Dbt],
            4,
            MultiDimPolicy::Baseline,
        );
        assert_eq!(c.algo_notation(), "[RI, RHD, DBT, DBT]");
    }
}
