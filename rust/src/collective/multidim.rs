//! Multi-dimensional collective composition (paper §2.2 / BlueConnect [7]).
//!
//! A collective over a group that spans several network dimensions is
//! executed as a sequence of per-dimension phases. Two compositions are
//! searched by the paper ("Multi-dim Collective" knob):
//!
//! - **Baseline** — the hierarchical schedule of ASTRA-sim: run
//!   reduce-scatter phases inward (dim 0 .. dim D-1), each phase shrinking
//!   the live shard by its dimension size, then all-gather phases outward.
//!   Phases are strictly sequential for a given chunk.
//! - **BlueConnect** — decompose the all-reduce into per-dimension
//!   reduce-scatters and all-gathers and *pipeline* them across dimensions:
//!   with enough chunks in flight, the collective time approaches the
//!   slowest single dimension phase instead of the sum of all phases.
//!
//! Chunking: the payload is split into `chunks` equal pieces; consecutive
//! chunks pipeline through the phase sequence, so total time is
//! `sum(phases for one chunk) + (chunks-1) * bottleneck_phase`.

use super::algorithms::{alpha_beta_terms, CollAlgo, CollectiveKind};
use crate::topology::{DimCost, Topology};

/// Multi-dimensional composition policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MultiDimPolicy {
    Baseline,
    BlueConnect,
}

impl MultiDimPolicy {
    pub const ALL: [MultiDimPolicy; 2] = [MultiDimPolicy::Baseline, MultiDimPolicy::BlueConnect];

    pub fn name(&self) -> &'static str {
        match self {
            MultiDimPolicy::Baseline => "Baseline",
            MultiDimPolicy::BlueConnect => "BlueConnect",
        }
    }

    /// Figure 9's 1-based index (1=Baseline, 2=BlueConnect).
    pub fn index(&self) -> usize {
        match self {
            MultiDimPolicy::Baseline => 1,
            MultiDimPolicy::BlueConnect => 2,
        }
    }
}

/// One per-dimension phase of a multi-dimensional collective, with the
/// latency and bandwidth terms kept separate so alternative network
/// backends (`crate::netsim`) can re-rate the bandwidth term under
/// congestion while reusing the exact same schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseSpec {
    /// Index into the `dims`/`algos` arrays this phase runs on.
    pub span_dim: usize,
    /// Total latency debt of the phase (alpha steps × per-hop alpha), us.
    pub alpha_us: f64,
    /// Bytes crossing the per-NPU link during the phase.
    pub wire_bytes: f64,
}

impl PhaseSpec {
    /// Ideal (uncongested) duration of this phase on its dimension:
    /// the alpha debt plus the wire bytes at the dimension's full beta
    /// rate. Both the composition fold and the trace exporter's phase
    /// decomposition price phases through here.
    pub fn duration_us(&self, dim: &DimCost) -> f64 {
        self.alpha_us + self.wire_bytes / dim.beta_bytes_per_us
    }
}

fn phase_of(
    algo: CollAlgo,
    kind: CollectiveKind,
    dim: &DimCost,
    span_dim: usize,
    bytes: f64,
) -> PhaseSpec {
    if dim.npus <= 1 || bytes <= 0.0 {
        return PhaseSpec { span_dim, alpha_us: 0.0, wire_bytes: 0.0 };
    }
    let (steps, volume) = alpha_beta_terms(algo, kind, dim.npus);
    PhaseSpec { span_dim, alpha_us: steps * dim.alpha_us, wire_bytes: volume * bytes }
}

/// The per-dimension phase schedule for one chunk of a multi-dimensional
/// collective over `dims` (the dimensions the communicating group spans,
/// innermost first), with the per-dimension algorithm choice.
pub fn phase_plan(
    kind: CollectiveKind,
    algos: &[CollAlgo],
    dims: &[DimCost],
    chunk_bytes: f64,
) -> Vec<PhaseSpec> {
    let mut out = Vec::with_capacity(dims.len() * 2);
    phase_plan_into(kind, algos, dims, chunk_bytes, &mut out);
    out
}

/// Allocation-free variant of [`phase_plan`]: clears and fills a
/// caller-owned buffer, so DSE hot loops can reuse one allocation across
/// millions of collective pricings.
pub fn phase_plan_into(
    kind: CollectiveKind,
    algos: &[CollAlgo],
    dims: &[DimCost],
    chunk_bytes: f64,
    out: &mut Vec<PhaseSpec>,
) {
    assert_eq!(algos.len(), dims.len(), "one algorithm per spanned dimension");
    out.clear();
    match kind {
        CollectiveKind::AllReduce => {
            // Hierarchical schedule: RS inward over dims 0..D, then AG
            // outward. After the RS on dim d the live shard shrinks by n_d.
            let mut size = chunk_bytes;
            for (d, dim) in dims.iter().enumerate() {
                out.push(phase_of(algos[d], CollectiveKind::ReduceScatter, dim, d, size));
                size /= dim.npus as f64;
            }
            for (d, dim) in dims.iter().enumerate().rev() {
                size *= dim.npus as f64;
                out.push(phase_of(algos[d], CollectiveKind::AllGather, dim, d, size));
            }
        }
        CollectiveKind::ReduceScatter => {
            let mut size = chunk_bytes;
            for (d, dim) in dims.iter().enumerate() {
                out.push(phase_of(algos[d], kind, dim, d, size));
                size /= dim.npus as f64;
            }
        }
        CollectiveKind::AllGather => {
            // Gather outward: the shard grows through the dims.
            let total: f64 = dims.iter().map(|d| d.npus as f64).product();
            let mut size = chunk_bytes / total;
            for (d, dim) in dims.iter().enumerate().rev() {
                size *= dim.npus as f64;
                out.push(phase_of(algos[d], kind, dim, d, size));
            }
        }
        CollectiveKind::AllToAll => {
            // Personalized exchange phase per dimension on the full chunk.
            for (d, dim) in dims.iter().enumerate() {
                out.push(phase_of(algos[d], kind, dim, d, chunk_bytes));
            }
        }
    }
}

/// Compose per-phase durations into the collective's total time under a
/// multi-dim policy, with `chunks` pipelined pieces (each phase duration
/// must already be the *per-chunk* time).
pub fn compose_phases(policy: MultiDimPolicy, phases: &[f64], chunks: u32) -> f64 {
    compose_durations(policy, phases.iter().copied(), chunks)
}

/// Streaming core of [`compose_phases`]: folds the duration sequence into
/// (sum, bottleneck, largest-below-bottleneck) in one pass, so callers
/// never materialize a per-phase duration buffer.
fn compose_durations(
    policy: MultiDimPolicy,
    durations: impl Iterator<Item = f64>,
    chunks: u32,
) -> f64 {
    let chunks = chunks.max(1) as f64;
    let mut first = 0.0f64;
    let mut bottleneck = 0.0f64;
    let mut fill = 0.0f64; // largest duration strictly below the bottleneck
    for d in durations {
        first += d;
        if d > bottleneck {
            fill = bottleneck;
            bottleneck = d;
        } else if d < bottleneck && d > fill {
            fill = d;
        }
    }
    match policy {
        // Baseline: chunks pipeline through strictly sequential phases —
        // classic pipeline makespan: one full pass plus (chunks-1) times
        // the bottleneck stage.
        MultiDimPolicy::Baseline => first + (chunks - 1.0) * bottleneck,
        // BlueConnect decomposes the collective so each dimension's
        // RS/AG stream runs *concurrently* on its own links (not merely
        // pipelined): steady state is chunks x the bottleneck dimension,
        // and the fill/drain is the largest single non-bottleneck phase
        // (they overlap each other), not their sum.
        MultiDimPolicy::BlueConnect => bottleneck * chunks + fill,
    }
}

/// Dependency structure of one collective's chunk-level flow graph:
/// which earlier flows must *complete* before chunk `k`'s phase `p` may
/// start. Derived purely from the per-chunk phase durations, it encodes
/// each policy's pipeline discipline so that an event-driven drain of the
/// graph (`FlowSim::run_chunked`) reproduces [`compose_phases`]' closed
/// form exactly when nothing contends for the links (pinned by
/// `rust/tests/chunk_precedence.rs`):
///
/// - **Baseline** — an exclusive-stage flow shop: `(k, p)` waits for
///   `(k, p-1)` (phases are sequential within a chunk) and `(k-1, p)`
///   (chunk FIFO on each phase's dimension). Completion times obey
///   `C(k, p) = sum(d_0..=d_p) + k * max(d_0..=d_p)`, so the makespan is
///   `sum + (chunks-1) * bottleneck` — the Baseline closed form.
/// - **BlueConnect** — each phase streams its own chunk FIFO
///   concurrently; only the *designated bottleneck* phase (first index
///   of the maximal duration) of chunk `k` additionally waits for chunk
///   `k` on every strictly-faster "feeder" phase. Completion of the
///   bottleneck chain is `fill + (k+1) * bottleneck`, so the makespan is
///   `bottleneck * chunks + fill` — the BlueConnect closed form
///   (equal-peak phases are not feeders, matching the fold's strict
///   `d < bottleneck` fill update).
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkSchedule {
    policy: MultiDimPolicy,
    /// First index of the maximal per-chunk phase duration.
    bottleneck: usize,
    /// BlueConnect only: phases strictly faster than the bottleneck.
    feeders: Vec<usize>,
}

impl ChunkSchedule {
    /// Build the schedule for one collective from its per-chunk phase
    /// durations (ideal, uncongested — see [`PhaseSpec::duration_us`]).
    pub fn new(policy: MultiDimPolicy, durations: &[f64]) -> Self {
        let mut bottleneck = 0;
        let mut peak = f64::NEG_INFINITY;
        for (i, &d) in durations.iter().enumerate() {
            if d > peak {
                peak = d;
                bottleneck = i;
            }
        }
        let feeders = match policy {
            MultiDimPolicy::Baseline => Vec::new(),
            MultiDimPolicy::BlueConnect => durations
                .iter()
                .enumerate()
                .filter(|&(_, &d)| d < peak)
                .map(|(i, _)| i)
                .collect(),
        };
        Self { policy, bottleneck, feeders }
    }

    /// The designated bottleneck phase (first index of the maximal
    /// per-chunk duration).
    pub fn bottleneck(&self) -> usize {
        self.bottleneck
    }

    /// Visit every `(chunk, phase)` whose *completion* gates the start
    /// of chunk `k`'s phase `p`.
    pub fn deps(&self, k: u32, p: usize, mut visit: impl FnMut(u32, usize)) {
        if k > 0 {
            visit(k - 1, p);
        }
        match self.policy {
            MultiDimPolicy::Baseline => {
                if p > 0 {
                    visit(k, p - 1);
                }
            }
            MultiDimPolicy::BlueConnect => {
                if p == self.bottleneck {
                    for &q in &self.feeders {
                        visit(k, q);
                    }
                }
            }
        }
    }
}

/// Time (us) for a multi-dimensional collective of `bytes` per-NPU payload
/// over the given dimension subset, split into `chunks` pipelined pieces.
///
/// `dims`/`algos` must be the same length: the dimensions spanned by the
/// communicating group, innermost first, with each dimension's algorithm.
pub fn multidim_collective_time_us(
    kind: CollectiveKind,
    policy: MultiDimPolicy,
    algos: &[CollAlgo],
    dims: &[DimCost],
    bytes: f64,
    chunks: u32,
) -> f64 {
    assert_eq!(algos.len(), dims.len(), "one algorithm per spanned dimension");
    if dims.is_empty() || bytes <= 0.0 {
        return 0.0;
    }
    let chunks = chunks.max(1);
    let chunk_bytes = bytes / chunks as f64;
    PLAN_BUF.with(|buf| {
        let mut plan = buf.borrow_mut();
        phase_plan_into(kind, algos, dims, chunk_bytes, &mut plan);
        compose_durations(policy, plan.iter().map(|p| p.duration_us(&dims[p.span_dim])), chunks)
    })
}

thread_local! {
    // Reusable phase buffer for the DSE hot path: one collective pricing
    // per cache miss, millions per search, zero allocations after warmup.
    static PLAN_BUF: std::cell::RefCell<Vec<PhaseSpec>> = std::cell::RefCell::new(Vec::new());
}

/// Convenience: resolve the [`DimCost`]s for a contiguous span of topology
/// dimensions `[lo, hi)` — the common case where a parallelism group maps
/// onto whole topology dimensions.
pub fn dim_costs(topo: &Topology, lo: usize, hi: usize) -> Vec<DimCost> {
    topo.dims[lo..hi].iter().map(DimCost::from_dim).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DimKind, NetworkDim};

    fn dims2() -> Vec<DimCost> {
        vec![
            DimCost::from_dim(&NetworkDim::new(DimKind::Ring, 4, 200.0, 0.5)),
            DimCost::from_dim(&NetworkDim::new(DimKind::Switch, 8, 100.0, 1.0)),
        ]
    }

    const GB: f64 = 1e9;

    #[test]
    fn empty_dims_is_free() {
        let t = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::Baseline,
            &[],
            &[],
            GB,
            4,
        );
        assert_eq!(t, 0.0);
    }

    #[test]
    fn blueconnect_never_slower_than_baseline() {
        let dims = dims2();
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        for chunks in [1u32, 2, 4, 8, 16] {
            let base = multidim_collective_time_us(
                CollectiveKind::AllReduce,
                MultiDimPolicy::Baseline,
                &algos,
                &dims,
                GB,
                chunks,
            );
            let bc = multidim_collective_time_us(
                CollectiveKind::AllReduce,
                MultiDimPolicy::BlueConnect,
                &algos,
                &dims,
                GB,
                chunks,
            );
            assert!(bc <= base + 1e-9, "chunks={chunks}: bc={bc} base={base}");
        }
    }

    #[test]
    fn chunking_helps_baseline_pipelining() {
        let dims = dims2();
        let algos = [CollAlgo::Ring, CollAlgo::Ring];
        let t1 = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            GB,
            1,
        );
        let t8 = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            GB,
            8,
        );
        // With 8 chunks, non-bottleneck phases hide behind the bottleneck.
        assert!(t8 < t1, "t8={t8} t1={t1}");
    }

    #[test]
    fn too_many_chunks_hurts_via_alpha() {
        // Each chunk pays the full alpha; at some point more chunks lose.
        let dims = vec![DimCost::from_dim(&NetworkDim::new(DimKind::Ring, 8, 100.0, 50.0))];
        let algos = [CollAlgo::Ring];
        let small = 1e6;
        let t2 = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            small,
            2,
        );
        let t32 = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            small,
            32,
        );
        assert!(t32 > t2, "t32={t32} t2={t2}");
    }

    #[test]
    fn single_dim_matches_flat_cost_times_chunk_pipeline() {
        let dims = vec![dims2()[0]];
        let algos = [CollAlgo::Ring];
        let t = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            GB,
            1,
        );
        let flat = collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &dims[0], GB);
        assert!((t - flat).abs() < 1e-9);
    }

    #[test]
    fn rs_then_ag_equals_ar_for_hierarchical_ring() {
        let dims = dims2();
        let algos = [CollAlgo::Ring, CollAlgo::Ring];
        let ar = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            GB,
            1,
        );
        let rs = multidim_collective_time_us(
            CollectiveKind::ReduceScatter,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            GB,
            1,
        );
        let ag = multidim_collective_time_us(
            CollectiveKind::AllGather,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            GB,
            1,
        );
        assert!((ar - (rs + ag)).abs() < 1e-6, "ar={ar} rs+ag={}", rs + ag);
    }

    #[test]
    fn dim_costs_slices_topology() {
        let topo = Topology::from_arrays(
            &[DimKind::Ring, DimKind::FullyConnected, DimKind::Switch],
            &[4, 8, 4],
            &[100.0, 200.0, 300.0],
            &[1.0, 1.0, 1.0],
        );
        let c = dim_costs(&topo, 1, 3);
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].npus, 8);
        assert_eq!(c[1].npus, 4);
    }

    #[test]
    fn phase_plan_durations_recompose_to_total() {
        let dims = dims2();
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        for kind in CollectiveKind::ALL {
            for chunks in [1u32, 4] {
                let plan = phase_plan(kind, &algos, &dims, GB / chunks as f64);
                let durations: Vec<f64> = plan
                    .iter()
                    .map(|p| p.alpha_us + p.wire_bytes / dims[p.span_dim].beta_bytes_per_us)
                    .collect();
                for policy in MultiDimPolicy::ALL {
                    let composed = compose_phases(policy, &durations, chunks);
                    let direct =
                        multidim_collective_time_us(kind, policy, &algos, &dims, GB, chunks);
                    assert!(
                        (composed - direct).abs() < 1e-6,
                        "{kind} {} chunks={chunks}: {composed} vs {direct}",
                        policy.name()
                    );
                }
            }
        }
    }

    #[test]
    fn chunk_schedule_recurrence_matches_closed_form() {
        // Drain the precedence graph analytically (topological order,
        // each flow taking exactly its uncongested duration once its
        // deps complete) and pin the makespan to compose_phases. Covers
        // ties, a zero-duration phase, and single-phase plans.
        let duration_sets: Vec<Vec<f64>> = vec![
            vec![3.0, 7.0, 2.0],
            vec![5.0, 5.0],
            vec![4.0],
            vec![0.0, 6.0, 6.0, 1.0],
            vec![2.5, 0.0],
        ];
        for durations in &duration_sets {
            for chunks in [1u32, 2, 5, 16] {
                for policy in MultiDimPolicy::ALL {
                    let sched = ChunkSchedule::new(policy, durations);
                    let n = durations.len();
                    let mut done = vec![vec![0.0f64; n]; chunks as usize];
                    for k in 0..chunks {
                        // Non-bottleneck phases first: under BlueConnect
                        // the bottleneck waits on same-chunk feeders.
                        let mut order: Vec<usize> =
                            (0..n).filter(|&p| p != sched.bottleneck()).collect();
                        order.push(sched.bottleneck());
                        // Baseline needs in-chunk phase order instead.
                        if policy == MultiDimPolicy::Baseline {
                            order = (0..n).collect();
                        }
                        for p in order {
                            let mut start = 0.0f64;
                            sched.deps(k, p, |dk, dp| {
                                start = start.max(done[dk as usize][dp]);
                            });
                            done[k as usize][p] = start + durations[p];
                        }
                    }
                    let makespan = done
                        .iter()
                        .flat_map(|row| row.iter().copied())
                        .fold(0.0f64, f64::max);
                    let closed = compose_phases(policy, durations, chunks);
                    assert!(
                        (makespan - closed).abs() < 1e-9,
                        "{} chunks={chunks} durations={durations:?}: \
                         graph={makespan} closed={closed}",
                        policy.name()
                    );
                }
            }
        }
    }

    #[test]
    fn bigger_payload_costs_more() {
        let dims = dims2();
        let algos = [CollAlgo::Rhd, CollAlgo::Dbt];
        let a = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::BlueConnect,
            &algos,
            &dims,
            GB,
            4,
        );
        let b = multidim_collective_time_us(
            CollectiveKind::AllReduce,
            MultiDimPolicy::BlueConnect,
            &algos,
            &dims,
            4.0 * GB,
            4,
        );
        assert!(b > a);
    }
}
