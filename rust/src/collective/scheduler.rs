//! Chunk-level collective scheduler (paper's "Scheduling Policy" knob).
//!
//! When several collectives are outstanding at once (e.g. per-layer DP
//! gradient all-reduces issued back-to-back during the backward pass, as
//! in Themis [43]), the network must decide which pending *chunk* to
//! service next. The paper searches two policies:
//!
//! - **FIFO** — chunks drain in issue order: oldest collective first.
//!   Minimizes the completion time of the *first* collective.
//! - **LIFO** — newest first: prioritizes the most recently issued
//!   collective, which for backward-pass gradient collectives means the
//!   *earliest layers'* gradients (issued last) complete first — exactly
//!   what the next iteration's forward pass needs first.
//!
//! The scheduler is consumed by the discrete-event simulator (`sim`): each
//! network dimension is a serial resource; pending chunk-phases queue on
//! it and the policy picks the next one to occupy the link.

use std::collections::VecDeque;

/// Chunk scheduling policy ({LIFO, FIFO}).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedulingPolicy {
    Lifo,
    Fifo,
}

impl SchedulingPolicy {
    pub const ALL: [SchedulingPolicy; 2] = [SchedulingPolicy::Lifo, SchedulingPolicy::Fifo];

    pub fn name(&self) -> &'static str {
        match self {
            SchedulingPolicy::Lifo => "LIFO",
            SchedulingPolicy::Fifo => "FIFO",
        }
    }

    /// Figure 9's 1-based index (1=FIFO, 2=LIFO).
    pub fn index(&self) -> usize {
        match self {
            SchedulingPolicy::Fifo => 1,
            SchedulingPolicy::Lifo => 2,
        }
    }
}

/// A schedulable unit: one chunk-phase of a pending collective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkJob {
    /// Id of the owning collective (used to report completion).
    pub collective_id: u64,
    /// Duration this chunk-phase occupies the link (us).
    pub duration_us: f64,
    /// Issue order stamp (monotonic).
    pub seq: u64,
}

/// A serial link resource with a policy-ordered queue of chunk jobs.
///
/// `ChunkScheduler` is deliberately simple — one queue per network
/// dimension — matching the granularity at which the paper's knob acts.
#[derive(Debug, Clone)]
pub struct ChunkScheduler {
    policy: SchedulingPolicy,
    queue: VecDeque<ChunkJob>,
    next_seq: u64,
}

impl ChunkScheduler {
    pub fn new(policy: SchedulingPolicy) -> Self {
        Self { policy, queue: VecDeque::new(), next_seq: 0 }
    }

    pub fn policy(&self) -> SchedulingPolicy {
        self.policy
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// Enqueue one chunk-phase; returns its sequence stamp.
    pub fn push(&mut self, collective_id: u64, duration_us: f64) -> u64 {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.queue.push_back(ChunkJob { collective_id, duration_us, seq });
        seq
    }

    /// Pop the next job to service according to the policy.
    pub fn pop(&mut self) -> Option<ChunkJob> {
        match self.policy {
            SchedulingPolicy::Fifo => self.queue.pop_front(),
            SchedulingPolicy::Lifo => self.queue.pop_back(),
        }
    }

    /// Drain the whole queue serially, returning per-collective completion
    /// times (relative to `start_us`). This is the fast path used by the
    /// simulator when the link is idle and all jobs are known.
    pub fn drain_completions(&mut self, start_us: f64) -> Vec<(u64, f64)> {
        let mut t = start_us;
        let mut out = Vec::with_capacity(self.queue.len());
        while let Some(job) = self.pop() {
            t += job.duration_us;
            out.push((job.collective_id, t));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn jobs(s: &mut ChunkScheduler) {
        s.push(0, 10.0);
        s.push(1, 20.0);
        s.push(2, 5.0);
    }

    #[test]
    fn fifo_services_in_issue_order() {
        let mut s = ChunkScheduler::new(SchedulingPolicy::Fifo);
        jobs(&mut s);
        let ids: Vec<u64> = std::iter::from_fn(|| s.pop()).map(|j| j.collective_id).collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn lifo_services_newest_first() {
        let mut s = ChunkScheduler::new(SchedulingPolicy::Lifo);
        jobs(&mut s);
        let ids: Vec<u64> = std::iter::from_fn(|| s.pop()).map(|j| j.collective_id).collect();
        assert_eq!(ids, vec![2, 1, 0]);
    }

    #[test]
    fn drain_accumulates_durations() {
        let mut s = ChunkScheduler::new(SchedulingPolicy::Fifo);
        jobs(&mut s);
        let done = s.drain_completions(100.0);
        assert_eq!(done, vec![(0, 110.0), (1, 130.0), (2, 135.0)]);
        assert!(s.is_empty());
    }

    #[test]
    fn lifo_finishes_last_issued_first() {
        let mut s = ChunkScheduler::new(SchedulingPolicy::Lifo);
        jobs(&mut s);
        let done = s.drain_completions(0.0);
        // Collective 2 (newest) completes first at t=5.
        assert_eq!(done[0], (2, 5.0));
        // Total makespan identical to FIFO (policy changes order, not sum).
        assert!((done.last().unwrap().1 - 35.0).abs() < 1e-12);
    }

    #[test]
    fn seq_stamps_monotonic() {
        let mut s = ChunkScheduler::new(SchedulingPolicy::Fifo);
        let a = s.push(7, 1.0);
        let b = s.push(8, 1.0);
        assert!(b > a);
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn policy_indices_match_figure9_legend() {
        assert_eq!(SchedulingPolicy::Fifo.index(), 1);
        assert_eq!(SchedulingPolicy::Lifo.index(), 2);
    }
}
