//! Target workload definitions (paper Table 2).
//!
//! Four transformer-based models: GPT3-175B, GPT3-13B, ViT-Base and
//! ViT-Large. The paper's Table 2 rows are (layers, hidden dim, FFN dim,
//! sequence length, attention heads). Like the paper (Table 2 footnote) we
//! can simulate a reduced layer count and re-scale latency/memory in
//! post-processing — see [`ModelConfig::with_simulated_layers`].


/// Mixture-of-Experts configuration (paper §2.2: "All-to-All patterns
/// occur when each NPU generates and transfers dedicated chunks for all
/// other NPUs, such as gating functions in MoE models" [45]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeConfig {
    /// Experts per MoE layer.
    pub experts: u64,
    /// Tokens route to the top-k experts.
    pub top_k: u64,
    /// Every `frequency`-th layer is an MoE layer (1 = all layers).
    pub frequency: u64,
}

/// A transformer model as Table 2 parameterizes it.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Number of transformer layers (Table 2 row 1).
    pub layers: u64,
    /// Hidden (model) dimension D (row 2).
    pub hidden: u64,
    /// Feed-forward dimension F (row 3).
    pub ffn: u64,
    /// Sequence length S (row 4).
    pub seq: u64,
    /// Attention heads H (row 5).
    pub heads: u64,
    /// Layers actually simulated (paper simulates 4 and re-scales).
    pub simulated_layers: u64,
    /// Optional Mixture-of-Experts extension (None = dense, Table 2).
    pub moe: Option<MoeConfig>,
}

impl ModelConfig {
    pub fn new(name: &str, layers: u64, hidden: u64, ffn: u64, seq: u64, heads: u64) -> Self {
        Self {
            name: name.to_string(),
            layers,
            hidden,
            ffn,
            seq,
            heads,
            simulated_layers: layers,
            moe: None,
        }
    }

    /// Convert into a Mixture-of-Experts variant: every
    /// `frequency`-th layer's MLP is replaced by `experts` experts with
    /// top-`top_k` routing. Expert weights multiply the MLP parameter
    /// count; the gating all-to-all is injected by the WTG.
    pub fn with_moe(mut self, experts: u64, top_k: u64, frequency: u64) -> Self {
        assert!(experts >= 2 && top_k >= 1 && frequency >= 1);
        self.moe = Some(MoeConfig { experts, top_k, frequency });
        self.name = format!("{}-MoE{}x{}", self.name, experts, top_k);
        self
    }

    /// Fraction of layers that are MoE layers.
    pub fn moe_layer_fraction(&self) -> f64 {
        match self.moe {
            Some(m) => 1.0 / m.frequency as f64,
            None => 0.0,
        }
    }

    /// Simulate only `n` layers; latency/memory re-scale by
    /// [`Self::layer_scale`] in post-processing (Table 2 footnote).
    pub fn with_simulated_layers(mut self, n: u64) -> Self {
        self.simulated_layers = n.min(self.layers).max(1);
        self
    }

    /// Post-processing re-scale factor: full layers / simulated layers.
    pub fn layer_scale(&self) -> f64 {
        self.layers as f64 / self.simulated_layers as f64
    }

    /// A stable fingerprint of every field the Workload Trace Generator
    /// reads — the model half of the cross-evaluation trace cache key
    /// (`cosmic::dse::EvalCache`).
    pub fn fingerprint(&self) -> u64 {
        use std::hash::Hash;
        crate::util::hash64(|h| {
            self.name.hash(h);
            (self.layers, self.hidden, self.ffn, self.seq, self.heads).hash(h);
            self.simulated_layers.hash(h);
            self.moe.map(|m| (m.experts, m.top_k, m.frequency)).hash(h);
        })
    }

    /// Parameters of one transformer layer: attention (QKV + out
    /// projection) + MLP (up + down) + layernorms.
    pub fn params_per_layer(&self) -> u64 {
        let d = self.hidden;
        let f = self.ffn;
        let attn = 4 * d * d + 4 * d; // Wq,Wk,Wv,Wo + biases
        let mlp = 2 * d * f + d + f; // up/down + biases
        let norm = 4 * d; // 2 layernorms (gamma, beta)
        // MoE layers replicate the MLP per expert (averaged over the
        // frequency so total_params stays a simple product).
        let mlp = match self.moe {
            Some(m) => {
                let dense_layers = m.frequency - 1;
                (mlp * (dense_layers + m.experts)) / m.frequency
            }
            None => mlp,
        };
        attn + mlp + norm
    }

    /// Total model parameters (transformer body; embeddings excluded as
    /// they do not participate in the per-layer collectives we model).
    pub fn total_params(&self) -> u64 {
        self.layers * self.params_per_layer()
    }

    /// FLOPs of one layer's forward pass at global batch `b`:
    /// QKV (6·b·s·d²) + attention scores/context (4·b·s²·d)
    /// + output projection (2·b·s·d²) + MLP (4·b·s·d·f).
    pub fn layer_fwd_flops(&self, batch: u64) -> f64 {
        let b = batch as f64;
        let s = self.seq as f64;
        let d = self.hidden as f64;
        let f = self.ffn as f64;
        6.0 * b * s * d * d + 4.0 * b * s * s * d + 2.0 * b * s * d * d + 4.0 * b * s * d * f
    }

    /// Backward is the standard 2× forward.
    pub fn layer_bwd_flops(&self, batch: u64) -> f64 {
        2.0 * self.layer_fwd_flops(batch)
    }
}

/// Table 2 presets.
pub mod presets {
    use super::ModelConfig;

    pub fn gpt3_175b() -> ModelConfig {
        ModelConfig::new("GPT3-175B", 96, 12288, 49152, 2048, 96)
    }

    pub fn gpt3_13b() -> ModelConfig {
        ModelConfig::new("GPT3-13B", 40, 5140, 20560, 2048, 40)
    }

    pub fn vit_base() -> ModelConfig {
        ModelConfig::new("ViT-Base", 12, 768, 3072, 256, 12)
    }

    pub fn vit_large() -> ModelConfig {
        ModelConfig::new("ViT-Large", 24, 1024, 4096, 256, 16)
    }

    /// All four Table 2 workloads.
    pub fn all() -> Vec<ModelConfig> {
        vec![gpt3_175b(), gpt3_13b(), vit_base(), vit_large()]
    }

    /// Look a preset up by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        all().into_iter().find(|m| m.name.eq_ignore_ascii_case(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt3_175b_param_count_in_range() {
        // 96 layers of 12288-hidden, 4x FFN: body params ~173B. The famous
        // 175B includes embeddings; we exclude them, so expect 165-180B.
        let m = presets::gpt3_175b();
        let p = m.total_params() as f64;
        assert!(p > 1.6e11 && p < 1.85e11, "params = {p:.3e}");
    }

    #[test]
    fn gpt3_13b_param_count_in_range() {
        let m = presets::gpt3_13b();
        let p = m.total_params() as f64;
        assert!(p > 1.0e10 && p < 1.5e10, "params = {p:.3e}");
    }

    #[test]
    fn vit_base_params_near_86m() {
        // ViT-Base is ~86M with embeddings; transformer body ~85M.
        let p = presets::vit_base().total_params() as f64;
        assert!(p > 7.0e7 && p < 9.5e7, "params = {p:.3e}");
    }

    #[test]
    fn layer_scale_roundtrips() {
        let m = presets::gpt3_175b().with_simulated_layers(4);
        assert_eq!(m.simulated_layers, 4);
        assert!((m.layer_scale() - 24.0).abs() < 1e-12);
        // Scaling never below one simulated layer.
        let m = presets::vit_base().with_simulated_layers(0);
        assert_eq!(m.simulated_layers, 1);
    }

    #[test]
    fn fwd_flops_matches_6nd_rule_of_thumb() {
        // Standard estimate: fwd flops/token ~ 2 * params (plus attention
        // quadratic term). Check we are within 2x of 2*params*tokens.
        let m = presets::gpt3_175b();
        let batch = 1;
        let per_layer = m.layer_fwd_flops(batch);
        let total = per_layer * m.layers as f64;
        let rule = 2.0 * m.total_params() as f64 * (batch * m.seq) as f64;
        assert!(total > rule * 0.8 && total < rule * 2.5, "total={total:.3e} rule={rule:.3e}");
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let m = presets::vit_large();
        assert_eq!(m.layer_bwd_flops(8), 2.0 * m.layer_fwd_flops(8));
    }

    #[test]
    fn by_name_finds_presets() {
        assert!(presets::by_name("gpt3-175b").is_some());
        assert!(presets::by_name("ViT-Base").is_some());
        assert!(presets::by_name("nope").is_none());
    }

    #[test]
    fn table2_values() {
        let m = presets::gpt3_13b();
        assert_eq!((m.layers, m.hidden, m.ffn, m.seq, m.heads), (40, 5140, 20560, 2048, 40));
        let v = presets::vit_large();
        assert_eq!((v.layers, v.hidden, v.ffn, v.seq, v.heads), (24, 1024, 4096, 256, 16));
    }
}
