//! Parallelization strategies and their mapping onto the physical
//! topology (paper §2.1, Figure 1).
//!
//! The paper's workload knobs are DP, PP, SP and a weight-sharding flag
//! (Table 1/4); **TP is the residual** `NPUs / (DP·SP·PP)` — Table 6 lists
//! all four with their product equal to the NPU count, and the Table 1
//! constraint is `product(DP, SP, PP) ≤ NPUs`.
//!
//! Rank layout (innermost → outermost): **[TP, SP, DP, PP]**, ordered by
//! communication intensity — TP all-reduces every layer (most bytes, most
//! frequent), SP gathers activations, DP reduces gradients once per layer
//! per iteration, PP only passes boundary activations. Mapping the most
//! intense group innermost places it on the fastest network dimensions.
//!
//! [`group_span`] computes which topology dimensions (and what sub-extent
//! of each) a communicator group covers, which is what the collective cost
//! model consumes.

use crate::topology::{DimCost, Topology};

/// A parallelization strategy (the paper's "Workload Knob" row).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Parallelization {
    pub dp: u64,
    pub sp: u64,
    pub pp: u64,
    /// TP — derived, stored for convenience: `npus / (dp·sp·pp)`.
    pub tp: u64,
    /// ZeRO-style weight sharding over the (DP×SP) group ({0, 1}).
    pub weight_sharded: bool,
}

impl Parallelization {
    /// Build from the searched knobs, deriving TP from the NPU count.
    /// Fails if `dp·sp·pp` does not divide `npus` (the Table 1 constraint
    /// `product(DP,SP,PP) ≤ NPUs` plus divisibility).
    pub fn derive(npus: u64, dp: u64, sp: u64, pp: u64, weight_sharded: bool) -> Result<Self, String> {
        if dp == 0 || sp == 0 || pp == 0 {
            return Err("parallel degrees must be >= 1".into());
        }
        let denom = dp * sp * pp;
        if denom > npus {
            return Err(format!("product(DP,SP,PP) = {denom} exceeds NPUs = {npus}"));
        }
        if npus % denom != 0 {
            return Err(format!("DP*SP*PP = {denom} does not divide NPUs = {npus}"));
        }
        Ok(Self { dp, sp, pp, tp: npus / denom, weight_sharded })
    }

    pub fn npus(&self) -> u64 {
        self.dp * self.sp * self.pp * self.tp
    }

    /// Rank-layout strides, innermost first: [TP, SP, DP, PP].
    pub fn strides(&self) -> ParallelStrides {
        ParallelStrides {
            tp: 1,
            sp: self.tp,
            dp: self.tp * self.sp,
            pp: self.tp * self.sp * self.dp,
        }
    }

    pub fn validate(&self, npus: u64) -> Result<(), String> {
        if self.npus() != npus {
            return Err(format!(
                "parallelization covers {} NPUs but topology has {npus}",
                self.npus()
            ));
        }
        Ok(())
    }
}

impl std::fmt::Display for Parallelization {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "DP={} PP={} SP={} TP={} shard={}",
            self.dp, self.pp, self.sp, self.tp, self.weight_sharded as u8
        )
    }
}

/// Strides of each parallelism axis in the flattened rank space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ParallelStrides {
    pub tp: u64,
    pub sp: u64,
    pub dp: u64,
    pub pp: u64,
}

/// One topology dimension's share of a communicator group: the group has
/// `extent` distinct coordinates along topology dimension `dim`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DimExtent {
    pub dim: usize,
    pub extent: u64,
}

/// Which topology dimensions a communicator group of `size` members with
/// rank-space `stride` spans, and the extent within each.
///
/// Both the parallel degrees and the per-dim NPU counts are powers of two
/// in the paper's PsA (Tables 1/4), so group boundaries always align with
/// (sub-)dimension boundaries: a group occupying rank interval
/// `[stride, stride·size)` in multiplicative stride space intersects
/// topology dim `d` (spanning `[S_d, S_d·n_d)`) with extent
/// `min(stride·size, S_d·n_d) / max(stride, S_d)` when positive.
pub fn group_span(topo: &Topology, stride: u64, size: u64) -> Vec<DimExtent> {
    let mut spans = Vec::new();
    if size <= 1 {
        return spans;
    }
    let glo = stride;
    let ghi = stride * size;
    for (d, dim) in topo.dims.iter().enumerate() {
        let slo = topo.stride(d);
        let shi = slo * dim.npus;
        let lo = glo.max(slo);
        let hi = ghi.min(shi);
        if hi > lo {
            let extent = hi / lo;
            if extent > 1 {
                spans.push(DimExtent { dim: d, extent });
            }
        }
    }
    spans
}

/// Resolve a group span into per-dimension [`DimCost`]s (alpha/beta with
/// the *extent* as the group size along that dimension). The paired
/// second element is the topology dim index, used to pick the searched
/// per-dim collective algorithm.
pub fn group_dim_costs(topo: &Topology, stride: u64, size: u64) -> Vec<(DimCost, usize)> {
    group_span(topo, stride, size)
        .into_iter()
        .map(|e| {
            let mut c = DimCost::from_dim(&topo.dims[e.dim]);
            c.npus = e.extent;
            (c, e.dim)
        })
        .collect()
}

/// Enumerate all valid (DP, SP, PP) power-of-two triples for `npus` NPUs
/// given per-axis caps — the generator behind the paper's "286 options"
/// (Table 1) and the workload-only search space.
pub fn enumerate_parallelizations(
    npus: u64,
    pp_cap: u64,
    weight_shard_options: &[bool],
) -> Vec<Parallelization> {
    let mut out = Vec::new();
    let mut dp = 1;
    while dp <= npus {
        let mut sp = 1;
        while dp * sp <= npus {
            let mut pp = 1;
            while pp <= pp_cap && dp * sp * pp <= npus {
                if npus % (dp * sp * pp) == 0 {
                    for &ws in weight_shard_options {
                        if let Ok(p) = Parallelization::derive(npus, dp, sp, pp, ws) {
                            out.push(p);
                        }
                    }
                }
                pp *= 2;
            }
            sp *= 2;
        }
        dp *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::DimKind;

    fn topo_1024() -> Topology {
        Topology::from_arrays(
            &[DimKind::Ring, DimKind::FullyConnected, DimKind::Ring, DimKind::Switch],
            &[4, 8, 4, 8],
            &[375.0, 175.0, 150.0, 100.0],
            &[0.5, 0.5, 0.5, 0.5],
        )
    }

    #[test]
    fn derive_computes_tp_residual() {
        let p = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
        assert_eq!(p.tp, 4); // Table 5, Perf-per-BW/NPU column
        assert_eq!(p.npus(), 1024);
    }

    #[test]
    fn derive_rejects_overflow_and_nondivisible() {
        assert!(Parallelization::derive(1024, 2048, 1, 1, false).is_err());
        assert!(Parallelization::derive(1024, 3, 1, 1, false).is_err());
        assert!(Parallelization::derive(0, 1, 0, 1, false).is_err());
    }

    #[test]
    fn strides_follow_tp_sp_dp_pp_order() {
        let p = Parallelization::derive(1024, 2, 8, 1, true).unwrap(); // TP=64
        let s = p.strides();
        assert_eq!(s.tp, 1);
        assert_eq!(s.sp, 64);
        assert_eq!(s.dp, 512);
        assert_eq!(s.pp, 1024);
    }

    #[test]
    fn tp64_spans_first_two_dims_like_table6_expr1() {
        // Table 6 Expr 1: TP=64 on NPUs-per-dim [16,4,4,4]-like layouts —
        // the TP group should exactly cover the innermost dims.
        let topo = Topology::from_arrays(
            &[DimKind::Ring, DimKind::FullyConnected, DimKind::Ring, DimKind::FullyConnected],
            &[16, 4, 4, 4],
            &[50.0; 4],
            &[0.5; 4],
        );
        let p = Parallelization::derive(1024, 2, 8, 1, true).unwrap();
        assert_eq!(p.tp, 64);
        let span = group_span(&topo, p.strides().tp, p.tp);
        assert_eq!(span, vec![DimExtent { dim: 0, extent: 16 }, DimExtent { dim: 1, extent: 4 }]);
    }

    #[test]
    fn partial_dim_extent() {
        // Group of 2 with stride 1 inside a dim of 4: extent 2 on dim 0.
        let topo = topo_1024();
        let span = group_span(&topo, 1, 2);
        assert_eq!(span, vec![DimExtent { dim: 0, extent: 2 }]);
        // Group of 8 with stride 2: covers rest of dim0 (extent 2) and
        // half of dim1 (extent 4).
        let span = group_span(&topo, 2, 8);
        assert_eq!(
            span,
            vec![DimExtent { dim: 0, extent: 2 }, DimExtent { dim: 1, extent: 4 }]
        );
    }

    #[test]
    fn group_of_one_spans_nothing() {
        assert!(group_span(&topo_1024(), 1, 1).is_empty());
    }

    #[test]
    fn spans_product_equals_group_size() {
        let topo = topo_1024();
        for (stride, size) in [(1u64, 4u64), (1, 64), (4, 8), (32, 32), (1, 1024), (128, 8)] {
            let span = group_span(&topo, stride, size);
            let product: u64 = span.iter().map(|e| e.extent).product();
            assert_eq!(product, size, "stride={stride} size={size}");
        }
    }

    #[test]
    fn group_dim_costs_carry_extent_not_full_dim() {
        let topo = topo_1024();
        let costs = group_dim_costs(&topo, 1, 2);
        assert_eq!(costs.len(), 1);
        assert_eq!(costs[0].0.npus, 2);
        assert_eq!(costs[0].1, 0);
    }

    #[test]
    fn enumerate_matches_paper_286_count() {
        // Table 1: DP, SP in {1..1024}, PP in {1..1024}, product <= 1024
        // gives 286 (DP,PP,SP) combos. With pp_cap=1024 and one shard
        // option we should get exactly 286.
        let all = enumerate_parallelizations(1024, 1024, &[false]);
        assert_eq!(all.len(), 286);
    }

    #[test]
    fn enumerate_respects_pp_cap() {
        // Table 4 restricts PP to {1, 2, 4}.
        let all = enumerate_parallelizations(1024, 4, &[false, true]);
        assert!(all.iter().all(|p| p.pp <= 4));
        assert!(all.iter().any(|p| p.weight_sharded));
        // every entry covers all NPUs
        assert!(all.iter().all(|p| p.npus() == 1024));
    }
}
