//! Workload layer: models (Table 2), parallelization strategies
//! (§2.1), the Workload Trace Generator (§4.4), and the per-NPU memory
//! footprint model (§5.4).

pub mod memory;
pub mod models;
pub mod parallel;
pub mod trace;

pub use memory::{footprint, MemoryFootprint};
pub use models::ModelConfig;
pub use parallel::{
    enumerate_parallelizations, group_dim_costs, group_span, DimExtent, Parallelization,
};
pub use trace::{generate_trace, CommGroup, ExecutionMode, StageTrace, Trace, TraceOp};
