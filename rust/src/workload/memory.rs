//! Per-NPU memory footprint model (paper §2.4 / §5.4).
//!
//! The paper applies a hard constraint: "any parallelization strategy
//! resulting in a memory footprint exceeding 24 GB per NPU is considered
//! invalid and discarded". The footprint has three components:
//!
//! - **Model states** — weights (bf16), gradients (bf16) and Adam
//!   optimizer states (fp32 master + two fp32 moments = 12 B/param):
//!   16 bytes/param total, divided by `TP·PP`, and further by the DP×SP
//!   group when ZeRO weight sharding is on.
//! - **Activations** — stashed forward activations needed by backward:
//!   per layer `b·s·(10·D + 2·F)/TP` bytes (Megatron-style estimate with
//!   sequence-parallel sharding), times layers per stage, times the
//!   microbatches in flight (`min(m, PP)` for a GPipe-ish schedule).
//! - **KV cache** (inference) — `2·b·S·D/TP` bytes per layer.

use super::models::ModelConfig;
use super::parallel::Parallelization;
use super::trace::{ExecutionMode, BYTES_PER_ELEM};

/// Optimizer bytes per parameter (Adam: fp32 master + m + v).
pub const OPTIMIZER_BYTES_PER_PARAM: f64 = 12.0;
/// Gradient bytes per parameter (bf16).
pub const GRAD_BYTES_PER_PARAM: f64 = 2.0;

/// Footprint breakdown (bytes, per NPU, full model — already re-scaled
/// from the simulated layer count).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryFootprint {
    pub weights: f64,
    pub gradients: f64,
    pub optimizer: f64,
    pub activations: f64,
    pub kv_cache: f64,
}

impl MemoryFootprint {
    pub fn total(&self) -> f64 {
        self.weights + self.gradients + self.optimizer + self.activations + self.kv_cache
    }

    /// The paper's §5.4 validity check against a byte budget.
    pub fn fits(&self, budget_bytes: f64) -> bool {
        self.total() <= budget_bytes
    }
}

/// Compute the per-NPU footprint for `model` under `par` at global batch
/// `batch`.
pub fn footprint(
    model: &ModelConfig,
    par: &Parallelization,
    batch: u64,
    mode: ExecutionMode,
) -> MemoryFootprint {
    let params = model.total_params() as f64;
    let tp_pp = (par.tp * par.pp) as f64;
    let shard = if par.weight_sharded { (par.dp * par.sp) as f64 } else { 1.0 };

    let training = matches!(mode, ExecutionMode::Training);
    let weights = params * BYTES_PER_ELEM / (tp_pp * shard);
    let gradients = if training { params * GRAD_BYTES_PER_PARAM / (tp_pp * shard) } else { 0.0 };
    let optimizer =
        if training { params * OPTIMIZER_BYTES_PER_PARAM / (tp_pp * shard) } else { 0.0 };

    let b_local = (batch / par.dp).max(1) as f64;
    let s_local = model.seq as f64 / par.sp as f64;
    let d = model.hidden as f64;
    let f = model.ffn as f64;
    let layers_per_stage = (model.layers as f64 / par.pp as f64).ceil();

    // Microbatches in flight: GPipe stashes up to PP microbatches.
    let micro_b = if par.pp > 1 { 1.0 } else { b_local };
    let in_flight = if par.pp > 1 { (par.pp as f64).min(b_local) } else { 1.0 };

    let activations = if training {
        // Activation checkpointing (standard for the model scales of
        // Table 2): each layer stashes only its input (b·s·D elements);
        // one layer's full working set (~10·D + 2·F elements per token)
        // is live at a time and re-materialized in backward.
        let checkpoints = micro_b * in_flight * s_local * d * BYTES_PER_ELEM
            / par.tp as f64
            * layers_per_stage;
        let live = micro_b * s_local * (10.0 * d + 2.0 * f) * BYTES_PER_ELEM / par.tp as f64;
        checkpoints + live
    } else {
        // Inference: only the live layer's working set.
        b_local * s_local * (10.0 * d + 2.0 * f) * BYTES_PER_ELEM / par.tp as f64
    };

    let kv_cache = if training {
        0.0
    } else {
        2.0 * b_local * model.seq as f64 * d * BYTES_PER_ELEM / par.tp as f64 * layers_per_stage
    };

    MemoryFootprint { weights, gradients, optimizer, activations, kv_cache }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::MEM_LIMIT_BYTES;
    use crate::workload::models::presets;

    fn par(npus: u64, dp: u64, sp: u64, pp: u64, ws: bool) -> Parallelization {
        Parallelization::derive(npus, dp, sp, pp, ws).unwrap()
    }

    #[test]
    fn gpt3_175b_pure_dp_exceeds_budget() {
        // 175B x 16 B/param on one NPU is ~2.8 TB — way over 24 GB.
        let m = presets::gpt3_175b();
        let fp = footprint(&m, &par(1024, 1024, 1, 1, false), 2048, ExecutionMode::Training);
        assert!(!fp.fits(MEM_LIMIT_BYTES), "total={:.3e}", fp.total());
    }

    #[test]
    fn table5_config_fits_budget() {
        // Table 5 Perf-per-BW/NPU: DP=64 PP=1 SP=4 (TP=4), sharded=1.
        let m = presets::gpt3_175b();
        let fp = footprint(&m, &par(1024, 64, 4, 1, true), 2048, ExecutionMode::Training);
        assert!(fp.fits(MEM_LIMIT_BYTES), "total={:.3e}", fp.total());
    }

    #[test]
    fn sharding_divides_model_states() {
        let m = presets::gpt3_13b();
        let dense = footprint(&m, &par(64, 8, 2, 1, false), 64, ExecutionMode::Training);
        let shard = footprint(&m, &par(64, 8, 2, 1, true), 64, ExecutionMode::Training);
        let k = (8 * 2) as f64;
        assert!((dense.weights / shard.weights - k).abs() < 1e-9);
        assert!((dense.optimizer / shard.optimizer - k).abs() < 1e-9);
        // Activations unaffected by sharding.
        assert!((dense.activations - shard.activations).abs() < 1e-9);
    }

    #[test]
    fn tp_divides_model_states() {
        let m = presets::gpt3_13b();
        let tp2 = footprint(&m, &par(64, 32, 1, 1, false), 64, ExecutionMode::Training);
        let tp32 = footprint(&m, &par(64, 2, 1, 1, false), 64, ExecutionMode::Training);
        assert!((tp2.weights / tp32.weights - 16.0).abs() < 1e-9);
        assert!((tp2.optimizer / tp32.optimizer - 16.0).abs() < 1e-9);
        // Activations are invariant here: tokens-per-NPU is fixed by the
        // total model-parallel width (DP*TP constant at fixed NPUs).
        assert!((tp32.activations - tp2.activations).abs() / tp2.activations < 1e-9);
    }

    #[test]
    fn inference_has_kv_but_no_optimizer() {
        let m = presets::gpt3_175b();
        let fp = footprint(&m, &par(1024, 8, 8, 4, true), 1024, ExecutionMode::InferenceDecode);
        assert_eq!(fp.optimizer, 0.0);
        assert_eq!(fp.gradients, 0.0);
        assert!(fp.kv_cache > 0.0);
    }

    #[test]
    fn optimizer_dominates_unsharded_training() {
        let m = presets::gpt3_13b();
        let fp = footprint(&m, &par(64, 4, 1, 1, false), 64, ExecutionMode::Training);
        assert!(fp.optimizer > fp.weights);
        assert!((fp.optimizer / fp.weights - 6.0).abs() < 1e-9); // 12B vs 2B
    }

    #[test]
    fn bigger_batch_more_activations() {
        let m = presets::vit_large();
        let small = footprint(&m, &par(16, 16, 1, 1, false), 256, ExecutionMode::Training);
        let big = footprint(&m, &par(16, 16, 1, 1, false), 4096, ExecutionMode::Training);
        assert!(big.activations > small.activations);
        // Model states unchanged.
        assert_eq!(big.weights, small.weights);
    }

    #[test]
    fn total_is_sum_of_parts() {
        let m = presets::vit_base();
        let fp = footprint(&m, &par(16, 4, 2, 1, true), 256, ExecutionMode::Training);
        let sum = fp.weights + fp.gradients + fp.optimizer + fp.activations + fp.kv_cache;
        assert!((fp.total() - sum).abs() < 1e-9);
    }
}
