//! Workload Trace Generator (WTG) — paper §4.4.
//!
//! The paper's WTG keeps *symbolic* trace templates of the model
//! architecture — operator shapes in terms of {B, S, D, H} and
//! partitioning in terms of the workload knobs {dp, sp, tp, pp} — and
//! instantiates them into a concrete operator/collective trace once the
//! PSS supplies actual knob values. This module is that generator: given a
//! [`ModelConfig`] and a [`Parallelization`], it emits the per-pipeline-
//! stage trace of compute operators with collectives injected wherever a
//! tensor's producer and consumer NPUs differ.
//!
//! Collective injection rules (standard Megatron/ZeRO semantics):
//! - `tp > 1`: two activation all-reduces per layer forward (post-
//!   attention and post-MLP), two more in backward; payload `b·s·D` bytes.
//! - `sp > 1`: K/V all-gather over the SP group in attention forward,
//!   matching reduce-scatter in backward; payload `2·b·s·(D/tp)` bytes.
//! - `dp > 1`: per-layer gradient synchronization in backward —
//!   all-reduce of the layer's parameter shard, or, with weight sharding
//!   (ZeRO), reduce-scatter(grads) + all-gather(params); *overlappable*
//!   with remaining backward compute.
//! - `pp > 1`: point-to-point boundary activation transfer per
//!   microbatch between adjacent stages.

use super::models::ModelConfig;
use super::parallel::Parallelization;
use crate::collective::CollectiveKind;

/// Bytes per element for weights/activations (bf16).
pub const BYTES_PER_ELEM: f64 = 2.0;

/// Which communicator a collective runs over (resolved to topology
/// dimensions by `workload::parallel::group_dim_costs` at simulation
/// time using the strides of the [`Parallelization`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommGroup {
    Tp,
    Sp,
    Dp,
    /// The combined DP×SP group used for ZeRO weight sharding.
    DpSp,
}

/// One item of a pipeline stage's trace.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceOp {
    /// A compute operator with roofline inputs (per-NPU work).
    Compute { name: &'static str, flops: f64, bytes: f64 },
    /// A collective over `group`; `bytes` is per-NPU payload.
    /// `overlappable` collectives (DP gradient sync) may hide behind
    /// remaining backward compute; blocking ones (TP/SP) serialize.
    Collective {
        kind: CollectiveKind,
        group: CommGroup,
        bytes: f64,
        overlappable: bool,
        /// Layer index within the stage (for LIFO/FIFO completion order).
        layer: u64,
    },
    /// Pipeline boundary activation send to the next stage (per-NPU bytes).
    P2p { bytes: f64 },
}

/// Phase marker: ops of one microbatch's forward or backward pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Forward,
    Backward,
}

/// The instantiated trace for one pipeline stage and one microbatch.
#[derive(Debug, Clone, PartialEq)]
pub struct StageTrace {
    pub forward: Vec<TraceOp>,
    pub backward: Vec<TraceOp>,
    /// Layers hosted by this stage.
    pub layers: u64,
}

/// Complete instantiated workload trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// One entry per pipeline stage (all stages are homogeneous for the
    /// uniform-layer transformers of Table 2, so we store one and note
    /// the count — but keep the vec for future heterogeneous stages).
    pub stages: Vec<StageTrace>,
    /// Microbatches per iteration (GPipe-style schedule).
    pub microbatches: u64,
    /// Global batch size.
    pub batch: u64,
    /// Latency re-scale factor from simulating fewer layers (Table 2 *).
    pub layer_scale: f64,
}

/// Workload Trace Generator inputs beyond the model: training vs the
/// paper's §6.3 inference scenarios.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecutionMode {
    Training,
    /// Inference prefill: full-sequence forward only.
    InferencePrefill,
    /// Inference decode: single-token forward (S=1 activations, full KV).
    InferenceDecode,
}

/// Generate the trace (paper: "the WTG translates the trace template into
/// an actual trace to be simulated").
pub fn generate_trace(
    model: &ModelConfig,
    par: &Parallelization,
    batch: u64,
    mode: ExecutionMode,
) -> Result<Trace, String> {
    if batch < par.dp {
        return Err(format!("global batch {batch} smaller than DP degree {}", par.dp));
    }
    if model.layers < par.pp {
        return Err(format!("model has {} layers but PP={}", model.layers, par.pp));
    }
    let sim_layers = model.simulated_layers.max(par.pp);
    let layers_per_stage = (sim_layers + par.pp - 1) / par.pp;

    // Microbatch = 1 sample per DP replica (finest-grained pipeline).
    let local_batch = batch / par.dp;
    let microbatches = if par.pp > 1 { local_batch.max(1) } else { 1 };
    let micro_b = if par.pp > 1 { 1.0 } else { local_batch as f64 };

    let d = model.hidden as f64;
    let f = model.ffn as f64;
    let tp = par.tp as f64;
    let sp = par.sp as f64;
    let (s_full, s_local, decode) = match mode {
        ExecutionMode::Training | ExecutionMode::InferencePrefill => {
            (model.seq as f64, model.seq as f64 / sp, false)
        }
        // Decode: one new token per step; KV length = full sequence.
        ExecutionMode::InferenceDecode => (model.seq as f64, (1.0f64 / sp).max(1.0 / sp), true),
    };

    let mut forward = Vec::new();
    let mut backward = Vec::new();

    let act_bytes = micro_b * s_local * d * BYTES_PER_ELEM; // activation tensor per NPU
    let layer_param_bytes =
        model.params_per_layer() as f64 / tp * BYTES_PER_ELEM; // per-NPU weight shard

    for layer in 0..layers_per_stage {
        // ---- forward ----
        // QKV projection: 6·b·s·d² flops split over SP (rows) × TP (cols).
        let qkv_flops = 6.0 * micro_b * s_local * d * d / tp;
        let qkv_bytes = act_bytes + 3.0 * act_bytes / tp + 3.0 * d * d / tp * BYTES_PER_ELEM;
        forward.push(TraceOp::Compute { name: "qkv_proj", flops: qkv_flops, bytes: qkv_bytes });

        if par.sp > 1 && !decode {
            // Gather K/V across the sequence dimension for attention.
            forward.push(TraceOp::Collective {
                kind: CollectiveKind::AllGather,
                group: CommGroup::Sp,
                bytes: 2.0 * act_bytes / tp,
                overlappable: false,
                layer,
            });
        }

        // Attention scores + context: 4·b·s_local·S·d (KV length = full S).
        let attn_flops = 4.0 * micro_b * s_local * s_full * d / tp;
        let attn_bytes = 2.0 * micro_b * s_local * s_full * (model.heads as f64 / tp).max(1.0)
            * BYTES_PER_ELEM
            + 2.0 * act_bytes / tp;
        forward.push(TraceOp::Compute { name: "attention", flops: attn_flops, bytes: attn_bytes });

        // Output projection.
        let out_flops = 2.0 * micro_b * s_local * d * d / tp;
        let out_bytes = act_bytes / tp + act_bytes + d * d / tp * BYTES_PER_ELEM;
        forward.push(TraceOp::Compute { name: "out_proj", flops: out_flops, bytes: out_bytes });

        if par.tp > 1 {
            // Megatron f/g: all-reduce partial sums after attention block.
            forward.push(TraceOp::Collective {
                kind: CollectiveKind::AllReduce,
                group: CommGroup::Tp,
                bytes: act_bytes,
                overlappable: false,
                layer,
            });
        }

        // MoE gating: tokens scatter to their top-k experts across the
        // expert-parallel (= DP) group and gather back -- two all-to-all
        // collectives per MoE layer in forward (paper §2.2 / GShard).
        let is_moe_layer = model
            .moe
            .map(|m| layer % m.frequency == 0 && par.dp > 1)
            .unwrap_or(false);
        let moe_bytes = model
            .moe
            .map(|m| act_bytes * m.top_k as f64 / tp)
            .unwrap_or(0.0);
        if is_moe_layer {
            for _ in 0..2 {
                forward.push(TraceOp::Collective {
                    kind: CollectiveKind::AllToAll,
                    group: CommGroup::Dp,
                    bytes: moe_bytes,
                    overlappable: false,
                    layer,
                });
            }
        }

        // MLP up + down: 4·b·s·d·f flops (top-k experts' worth for MoE).
        let expert_mult = model.moe.map(|m| if is_moe_layer { m.top_k as f64 } else { 1.0 }).unwrap_or(1.0);
        let mlp_flops = 4.0 * micro_b * s_local * d * f / tp * expert_mult;
        let mlp_bytes =
            (2.0 * act_bytes + 2.0 * micro_b * s_local * f / tp * BYTES_PER_ELEM
                + 2.0 * d * f / tp * BYTES_PER_ELEM) * expert_mult;
        forward.push(TraceOp::Compute { name: "mlp", flops: mlp_flops, bytes: mlp_bytes });

        if par.tp > 1 {
            forward.push(TraceOp::Collective {
                kind: CollectiveKind::AllReduce,
                group: CommGroup::Tp,
                bytes: act_bytes,
                overlappable: false,
                layer,
            });
        }

        // ---- backward (training only) ----
        if matches!(mode, ExecutionMode::Training) {
            let fwd_layer_flops = qkv_flops + attn_flops + out_flops + mlp_flops;
            let fwd_layer_bytes = qkv_bytes + attn_bytes + out_bytes + mlp_bytes;
            backward.push(TraceOp::Compute {
                name: "layer_bwd",
                flops: 2.0 * fwd_layer_flops,
                bytes: 2.0 * fwd_layer_bytes,
            });
            if par.tp > 1 {
                for _ in 0..2 {
                    backward.push(TraceOp::Collective {
                        kind: CollectiveKind::AllReduce,
                        group: CommGroup::Tp,
                        bytes: act_bytes,
                        overlappable: false,
                        layer,
                    });
                }
            }
            if par.sp > 1 {
                backward.push(TraceOp::Collective {
                    kind: CollectiveKind::ReduceScatter,
                    group: CommGroup::Sp,
                    bytes: 2.0 * act_bytes / tp,
                    overlappable: false,
                    layer,
                });
            }
            if is_moe_layer {
                // Backward re-runs the token shuffle in reverse.
                for _ in 0..2 {
                    backward.push(TraceOp::Collective {
                        kind: CollectiveKind::AllToAll,
                        group: CommGroup::Dp,
                        bytes: moe_bytes,
                        overlappable: false,
                        layer,
                    });
                }
            }
            if par.dp > 1 || (par.weight_sharded && par.sp > 1) {
                if par.weight_sharded {
                    // ZeRO: reduce-scatter grads + all-gather params over
                    // the DP×SP group, overlappable with backward compute.
                    backward.push(TraceOp::Collective {
                        kind: CollectiveKind::ReduceScatter,
                        group: CommGroup::DpSp,
                        bytes: layer_param_bytes,
                        overlappable: true,
                        layer,
                    });
                    backward.push(TraceOp::Collective {
                        kind: CollectiveKind::AllGather,
                        group: CommGroup::DpSp,
                        bytes: layer_param_bytes,
                        overlappable: true,
                        layer,
                    });
                } else {
                    backward.push(TraceOp::Collective {
                        kind: CollectiveKind::AllReduce,
                        group: CommGroup::Dp,
                        bytes: layer_param_bytes,
                        overlappable: true,
                        layer,
                    });
                }
            }
        }
    }

    // Pipeline boundary transfer (per microbatch).
    if par.pp > 1 {
        forward.push(TraceOp::P2p { bytes: act_bytes });
        if matches!(mode, ExecutionMode::Training) {
            backward.push(TraceOp::P2p { bytes: act_bytes });
        }
    }

    let stage = StageTrace { forward, backward, layers: layers_per_stage };
    Ok(Trace {
        stages: vec![stage; par.pp as usize],
        microbatches,
        batch,
        layer_scale: model.layers as f64 / (layers_per_stage * par.pp) as f64,
    })
}

impl Trace {
    /// Total per-NPU compute flops across one full iteration (all stages'
    /// microbatches), before latency re-scaling.
    pub fn total_flops(&self) -> f64 {
        let per_micro: f64 = self
            .stages
            .iter()
            .map(|s| {
                s.forward
                    .iter()
                    .chain(s.backward.iter())
                    .map(|op| match op {
                        TraceOp::Compute { flops, .. } => *flops,
                        _ => 0.0,
                    })
                    .sum::<f64>()
            })
            .sum();
        per_micro * self.microbatches as f64
    }

    /// Total collective payload bytes (per NPU) issued per microbatch.
    pub fn total_comm_bytes(&self) -> f64 {
        self.stages
            .iter()
            .map(|s| {
                s.forward
                    .iter()
                    .chain(s.backward.iter())
                    .map(|op| match op {
                        TraceOp::Collective { bytes, .. } | TraceOp::P2p { bytes } => *bytes,
                        _ => 0.0,
                    })
                    .sum::<f64>()
            })
            .sum()
    }

    /// Count collectives of a given group per stage (test helper).
    pub fn count_group(&self, group: CommGroup) -> usize {
        self.stages
            .iter()
            .map(|s| {
                s.forward
                    .iter()
                    .chain(s.backward.iter())
                    .filter(|op| matches!(op, TraceOp::Collective { group: g, .. } if *g == group))
                    .count()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::models::presets;

    fn par(npus: u64, dp: u64, sp: u64, pp: u64, ws: bool) -> Parallelization {
        Parallelization::derive(npus, dp, sp, pp, ws).unwrap()
    }

    #[test]
    fn tp_collectives_injected_when_tp_gt_1() {
        let m = presets::gpt3_13b().with_simulated_layers(4);
        let t = generate_trace(&m, &par(64, 4, 1, 1, false), 64, ExecutionMode::Training).unwrap();
        // tp=16: 2 fwd + 2 bwd TP all-reduces per layer x 4 layers.
        assert_eq!(t.count_group(CommGroup::Tp), 16);
    }

    #[test]
    fn no_tp_collectives_when_tp_1() {
        let m = presets::vit_base().with_simulated_layers(4);
        let t = generate_trace(&m, &par(16, 16, 1, 1, false), 256, ExecutionMode::Training).unwrap();
        assert_eq!(t.count_group(CommGroup::Tp), 0);
        // dp collectives present instead
        assert_eq!(t.count_group(CommGroup::Dp), 4);
    }

    #[test]
    fn zero_shard_switches_dp_to_rs_ag_on_dpsp() {
        let m = presets::gpt3_13b().with_simulated_layers(2);
        let t = generate_trace(&m, &par(64, 8, 2, 1, true), 64, ExecutionMode::Training).unwrap();
        assert_eq!(t.count_group(CommGroup::Dp), 0);
        assert_eq!(t.count_group(CommGroup::DpSp), 4); // RS + AG per layer x2
    }

    #[test]
    fn sp_injects_gather_scatter() {
        let m = presets::gpt3_13b().with_simulated_layers(2);
        let t = generate_trace(&m, &par(64, 1, 8, 1, false), 64, ExecutionMode::Training).unwrap();
        assert_eq!(t.count_group(CommGroup::Sp), 4); // AG fwd + RS bwd per layer
    }

    #[test]
    fn pipeline_adds_p2p_and_microbatches() {
        let m = presets::gpt3_175b().with_simulated_layers(4);
        let t = generate_trace(&m, &par(512, 8, 4, 4, true), 2048, ExecutionMode::Training).unwrap();
        assert_eq!(t.stages.len(), 4);
        assert_eq!(t.microbatches, 2048 / 8);
        let has_p2p = t.stages[0].forward.iter().any(|o| matches!(o, TraceOp::P2p { .. }));
        assert!(has_p2p);
    }

    #[test]
    fn inference_has_no_backward() {
        let m = presets::gpt3_175b().with_simulated_layers(4);
        for mode in [ExecutionMode::InferencePrefill, ExecutionMode::InferenceDecode] {
            let t = generate_trace(&m, &par(1024, 8, 8, 4, true), 1024, mode).unwrap();
            assert!(t.stages.iter().all(|s| s.backward.is_empty()));
        }
    }

    #[test]
    fn decode_moves_far_fewer_bytes_than_prefill() {
        let m = presets::gpt3_175b().with_simulated_layers(4);
        let p = par(1024, 8, 1, 1, true);
        let pre =
            generate_trace(&m, &p, 1024, ExecutionMode::InferencePrefill).unwrap().total_comm_bytes();
        let dec =
            generate_trace(&m, &p, 1024, ExecutionMode::InferenceDecode).unwrap().total_comm_bytes();
        assert!(dec < pre / 100.0, "decode={dec:.3e} prefill={pre:.3e}");
    }

    #[test]
    fn flops_conserved_across_parallelizations() {
        // Total cluster flops (per-NPU flops x NPUs) should be ~invariant
        // to the (DP, TP) split for the same model+batch without SP/PP.
        let m = presets::gpt3_13b().with_simulated_layers(4);
        let batch = 512;
        let a = generate_trace(&m, &par(64, 64, 1, 1, false), batch, ExecutionMode::Training)
            .unwrap()
            .total_flops()
            * 64.0
            / 64.0; // per-NPU is already /dp via local batch
        let b = generate_trace(&m, &par(64, 8, 1, 1, false), batch, ExecutionMode::Training)
            .unwrap()
            .total_flops()
            * 8.0
            / 64.0
            * 8.0; // normalize: per-NPU x tp
        // a: dp=64 -> local batch 8, tp=1. b: dp=8 tp=8 -> local batch 64 / tp 8.
        let rel = (a - b).abs() / a;
        assert!(rel < 1e-9, "a={a:.3e} b={b:.3e}");
    }

    #[test]
    fn rejects_batch_smaller_than_dp() {
        let m = presets::vit_base();
        assert!(generate_trace(&m, &par(512, 512, 1, 1, false), 256, ExecutionMode::Training)
            .is_err());
    }

    #[test]
    fn layer_scale_reflects_simulated_layers() {
        let m = presets::gpt3_175b().with_simulated_layers(4);
        let t = generate_trace(&m, &par(64, 64, 1, 1, true), 2048, ExecutionMode::Training).unwrap();
        assert!((t.layer_scale - 24.0).abs() < 1e-12);
    }

    #[test]
    fn moe_layers_inject_all_to_all() {
        use crate::collective::CollectiveKind;
        let m = presets::gpt3_13b().with_simulated_layers(4).with_moe(8, 2, 2);
        let t = generate_trace(&m, &par(64, 8, 1, 1, true), 64, ExecutionMode::Training).unwrap();
        let a2a = t.stages[0]
            .forward
            .iter()
            .chain(t.stages[0].backward.iter())
            .filter(|op| matches!(op, TraceOp::Collective { kind: CollectiveKind::AllToAll, .. }))
            .count();
        // frequency 2 over 4 layers -> 2 MoE layers x (2 fwd + 2 bwd).
        assert_eq!(a2a, 8);
    }

    #[test]
    fn dense_model_has_no_all_to_all() {
        use crate::collective::CollectiveKind;
        let m = presets::gpt3_13b().with_simulated_layers(4);
        let t = generate_trace(&m, &par(64, 8, 1, 1, true), 64, ExecutionMode::Training).unwrap();
        let a2a = t.stages[0]
            .forward
            .iter()
            .chain(t.stages[0].backward.iter())
            .filter(|op| matches!(op, TraceOp::Collective { kind: CollectiveKind::AllToAll, .. }))
            .count();
        assert_eq!(a2a, 0);
    }

    #[test]
    fn moe_increases_params_and_flops() {
        let dense = presets::gpt3_13b();
        let moe = presets::gpt3_13b().with_moe(8, 2, 1);
        assert!(moe.total_params() > 3 * dense.total_params());
        let td = generate_trace(&dense.clone().with_simulated_layers(2), &par(64, 8, 1, 1, true), 64, ExecutionMode::Training).unwrap();
        let tm = generate_trace(&moe.clone().with_simulated_layers(2), &par(64, 8, 1, 1, true), 64, ExecutionMode::Training).unwrap();
        assert!(tm.total_flops() > td.total_flops());
        assert!(tm.total_comm_bytes() > td.total_comm_bytes());
    }

    #[test]
    fn moe_without_dp_has_no_gating_traffic() {
        use crate::collective::CollectiveKind;
        let m = presets::gpt3_13b().with_simulated_layers(2).with_moe(8, 2, 1);
        let t = generate_trace(&m, &par(64, 1, 1, 1, true), 64, ExecutionMode::Training).unwrap();
        let a2a = t.stages[0]
            .forward
            .iter()
            .filter(|op| matches!(op, TraceOp::Collective { kind: CollectiveKind::AllToAll, .. }))
            .count();
        assert_eq!(a2a, 0, "no expert-parallel group without DP");
    }

    #[test]
    fn dp_payload_shrinks_with_tp() {
        // Gradient all-reduce payload per NPU divides by TP.
        let m = presets::gpt3_13b().with_simulated_layers(1);
        let grab = |p: &Parallelization| {
            let t = generate_trace(&m, p, 64, ExecutionMode::Training).unwrap();
            t.stages[0]
                .backward
                .iter()
                .find_map(|op| match op {
                    TraceOp::Collective { group: CommGroup::Dp, bytes, .. } => Some(*bytes),
                    _ => None,
                })
                .unwrap()
        };
        let lo_tp = grab(&par(64, 32, 1, 1, false)); // tp=2
        let hi_tp = grab(&par(64, 2, 1, 1, false)); // tp=32
        assert!((lo_tp / hi_tp - 16.0).abs() < 1e-9);
    }
}
