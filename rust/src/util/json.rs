//! Minimal RFC 8259 JSON *validator* (no parse tree, no deps) used by
//! the CLI and CI smoke steps to check the documents `obs` emits —
//! Chrome traces and telemetry snapshots — without pulling in `serde`.

/// Maximum nesting depth accepted before bailing out (guards against
/// stack exhaustion on adversarial input).
const MAX_DEPTH: usize = 256;

/// Validate that `s` is exactly one well-formed JSON value (plus
/// whitespace). Returns the byte offset and a short message on error.
pub fn validate(s: &str) -> Result<(), String> {
    let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
    p.skip_ws();
    p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after top-level value"));
    }
    Ok(())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> String {
        format!("byte {}: {}", self.pos, msg)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<(), String> {
        if depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => self.string(),
            Some(b't') => self.literal(b"true"),
            Some(b'f') => self.literal(b"false"),
            Some(b'n') => self.literal(b"null"),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &[u8]) -> Result<(), String> {
        if self.bytes[self.pos..].starts_with(lit) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self, depth: usize) -> Result<(), String> {
        self.expect(b'{')?;
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            self.value(depth + 1)?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<(), String> {
        self.expect(b'[')?;
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(());
        }
        loop {
            self.skip_ws();
            self.value(depth + 1)?;
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(());
                }
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<(), String> {
        self.expect(b'"')?;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => {
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            for _ in 0..4 {
                                match self.peek() {
                                    Some(c) if c.is_ascii_hexdigit() => self.pos += 1,
                                    _ => return Err(self.err("bad \\u escape")),
                                }
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(_) => self.pos += 1,
            }
        }
    }

    fn number(&mut self) -> Result<(), String> {
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => self.digits(),
            _ => return Err(self.err("bad number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            match self.peek() {
                Some(b'0'..=b'9') => self.digits(),
                _ => return Err(self.err("bad fraction")),
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            match self.peek() {
                Some(b'0'..=b'9') => self.digits(),
                _ => return Err(self.err("bad exponent")),
            }
        }
        Ok(())
    }

    fn digits(&mut self) {
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::validate;

    #[test]
    fn accepts_well_formed_documents() {
        for ok in [
            "null",
            "true",
            " -12.5e+3 ",
            "\"a\\u00e9\\n\"",
            "[]",
            "{}",
            "[1, [2, {\"k\": [false, null]}]]",
            "{\"a\": {\"b\": [1.0, 2.5]}, \"c\": \"d\"}",
        ] {
            validate(ok).unwrap_or_else(|e| panic!("{ok:?} should validate: {e}"));
        }
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "nul",
            "01",
            "1.",
            "1e",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "{'a': 1}",
            "\"unterminated",
            "\"bad\\q\"",
            "\"bad\\u12g4\"",
            "[1] [2]",
            "{\"a\":1,}",
        ] {
            assert!(validate(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn rejects_excessive_nesting() {
        let deep = "[".repeat(300) + &"]".repeat(300);
        assert!(validate(&deep).is_err());
    }

    #[test]
    fn errors_carry_byte_offsets() {
        let err = validate("[1, 2, x]").unwrap_err();
        assert!(err.starts_with("byte 7:"), "{err}");
    }
}
