//! Lightweight property-testing harness (offline stand-in for `proptest`).
//!
//! [`check`] runs a property over `cases` random inputs drawn by the
//! caller's generator; on failure it retries with a simple linear "shrink"
//! (re-running the generator with smaller size hints is up to the caller —
//! here we report the failing seed so the case is exactly reproducible).
//!
//! ```no_run
//! use cosmic::util::prop::check;
//! use cosmic::util::Rng;
//!
//! check("addition commutes", 100, |rng: &mut Rng| {
//!     let a = rng.gen_range(1000) as i64;
//!     let b = rng.gen_range(1000) as i64;
//!     if a + b != b + a {
//!         return Err(format!("{a} + {b}"));
//!     }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `property` on `cases` seeded inputs; panic (with the failing seed)
/// on the first counterexample. Deterministic: seeds are `0..cases` mixed
/// with a fixed stream constant, so failures reproduce exactly.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = case.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xC0531C1C;
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Like [`check`] but collects all failures (useful when surveying a
/// known-flaky invariant); returns failure descriptions.
pub fn survey<F>(cases: u64, mut property: F) -> Vec<(u64, String)>
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut failures = Vec::new();
    for case in 0..cases {
        let seed = case.wrapping_mul(0x9E3779B97F4A7C15) ^ 0xC0531C1C;
        let mut rng = Rng::seed_from_u64(seed);
        if let Err(msg) = property(&mut rng) {
            failures.push((case, msg));
        }
    }
    failures
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", 50, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn survey_collects_failures() {
        let fails = survey(10, |rng| {
            if rng.gen_f64() < 2.0 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
        assert_eq!(fails.len(), 10);
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<u64> = Vec::new();
        check("record", 5, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = Vec::new();
        check("record", 5, |rng| {
            second.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
