//! Minimal data-parallel map over OS threads — the offline stand-in for
//! `rayon` (see DESIGN.md §Substitutions). Built on `std::thread::scope`
//! so the closure may borrow the caller's environment; work is pulled
//! from a shared atomic index, which balances the uneven per-item cost
//! of simulator evaluations.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` on up to `available_parallelism` threads,
/// preserving order. Falls back to a plain serial map for tiny inputs.
/// Panics in `f` propagate to the caller.
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(items.len());
    if threads <= 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<U>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    std::thread::scope(|s| {
        let next = &next;
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(move || {
                    let mut local: Vec<(usize, U)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, f(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, u) in h.join().expect("parallel_map worker panicked") {
                out[i] = Some(u);
            }
        }
    });
    out.into_iter().map(|o| o.expect("parallel_map missed a slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_singleton() {
        let none: Vec<u64> = vec![];
        assert!(parallel_map(&none, |&x| x).is_empty());
        assert_eq!(parallel_map(&[42u64], |&x| x + 1), vec![43]);
    }

    #[test]
    fn closure_may_borrow_environment() {
        let offset = 10u64;
        let out = parallel_map(&[1u64, 2, 3], |&x| x + offset);
        assert_eq!(out, vec![11, 12, 13]);
    }
}
