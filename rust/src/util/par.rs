//! Minimal data-parallel map over OS threads — the offline stand-in for
//! `rayon` (see DESIGN.md §Substitutions). Built on `std::thread::scope`
//! so the closure may borrow the caller's environment; work is pulled
//! from a shared atomic index, which balances the uneven per-item cost
//! of simulator evaluations.
//!
//! Panics are isolated per item: [`parallel_map_catch`] runs each call
//! under `catch_unwind`, so one poisoned evaluation surfaces as an
//! `Err` for its own slot instead of aborting the whole batch (the DSE
//! maps those to invalid outcomes and counts them — see
//! `Environment::eval_panics`). [`parallel_map`] keeps the original
//! propagate-the-panic contract on top of it.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Render a `catch_unwind` payload as the panic message.
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Map `f` over `items` on up to `available_parallelism` threads,
/// preserving order, with per-item panic isolation: a panic in `f(x)`
/// yields `Err(message)` in `x`'s slot while every other item completes
/// normally.
pub fn parallel_map_catch<T, U, F>(items: &[T], f: F) -> Vec<Result<U, String>>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    let run = |t: &T| catch_unwind(AssertUnwindSafe(|| f(t))).map_err(panic_message);
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(items.len());
    if threads <= 1 {
        return items.iter().map(run).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<Result<U, String>>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    std::thread::scope(|s| {
        let next = &next;
        let run = &run;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(move || {
                    let mut local: Vec<(usize, Result<U, String>)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        local.push((i, run(&items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            // Workers never unwind (every item runs under catch_unwind),
            // so a join failure is a bug, not a user panic.
            for (i, u) in h.join().expect("parallel_map worker died") {
                out[i] = Some(u);
            }
        }
    });
    out.into_iter().map(|o| o.expect("parallel_map missed a slot")).collect()
}

/// Map `f` over `items` on up to `available_parallelism` threads,
/// preserving order. Falls back to a plain serial map for tiny inputs.
/// Panics in `f` propagate to the caller (first panicking index wins).
pub fn parallel_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> U + Sync,
{
    parallel_map_catch(items, f)
        .into_iter()
        .map(|r| match r {
            Ok(u) => u,
            Err(msg) => panic!("parallel_map worker panicked: {msg}"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_singleton() {
        let none: Vec<u64> = vec![];
        assert!(parallel_map(&none, |&x| x).is_empty());
        assert_eq!(parallel_map(&[42u64], |&x| x + 1), vec![43]);
    }

    #[test]
    fn closure_may_borrow_environment() {
        let offset = 10u64;
        let out = parallel_map(&[1u64, 2, 3], |&x| x + offset);
        assert_eq!(out, vec![11, 12, 13]);
    }

    #[test]
    fn catch_isolates_panicking_items() {
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_map_catch(&items, |&x| {
            if x % 10 == 3 {
                panic!("boom at {x}");
            }
            x * 2
        });
        assert_eq!(out.len(), items.len());
        for (i, r) in out.iter().enumerate() {
            if i % 10 == 3 {
                let msg = r.as_ref().unwrap_err();
                assert!(msg.contains(&format!("boom at {i}")), "got {msg}");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i as u64 * 2);
            }
        }
    }

    #[test]
    fn catch_serial_path_isolates_too() {
        // Single-item input takes the serial fallback.
        let out = parallel_map_catch(&[7u64], |_| -> u64 { panic!("solo") });
        assert_eq!(out.len(), 1);
        assert!(out[0].as_ref().unwrap_err().contains("solo"));
    }

    #[test]
    #[should_panic(expected = "parallel_map worker panicked")]
    fn plain_map_still_propagates() {
        let items: Vec<u64> = (0..8).collect();
        let _ = parallel_map(&items, |&x| {
            if x == 5 {
                panic!("die");
            }
            x
        });
    }
}
