//! Small self-contained utilities standing in for crates that are not
//! available in this offline build (see DESIGN.md §Substitutions):
//! [`rng`] replaces `rand`/`rand_chacha`, [`prop`] replaces `proptest`,
//! [`stats`] provides the summary statistics the bench harness prints.

pub mod prop;
pub mod rng;
pub mod stats;

pub use rng::Rng;
