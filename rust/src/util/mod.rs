//! Small self-contained utilities standing in for crates that are not
//! available in this offline build (see DESIGN.md §Substitutions):
//! [`rng`] replaces `rand`/`rand_chacha`, [`prop`] replaces `proptest`,
//! [`par`] replaces `rayon`, [`stats`] provides the summary statistics
//! the bench harness prints, [`json`] replaces a JSON parser for
//! validating the documents `obs` emits.

pub mod json;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;

pub use par::{parallel_map, parallel_map_catch};
pub use rng::Rng;

/// Fold a stream of `Hash`ed fields into a stable 64-bit fingerprint —
/// the one place the create-hasher / hash-fields / finish boilerplate
/// lives (cache keys in `sim`, `dse::evalcache`, `netsim`, model and
/// topology fingerprints).
pub fn hash64(feed: impl FnOnce(&mut std::collections::hash_map::DefaultHasher)) -> u64 {
    use std::hash::Hasher;
    let mut h = std::collections::hash_map::DefaultHasher::new();
    feed(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use std::hash::Hash;

    #[test]
    fn hash64_is_stable_and_input_sensitive() {
        let a = super::hash64(|h| (1u64, "x").hash(h));
        let b = super::hash64(|h| (1u64, "x").hash(h));
        let c = super::hash64(|h| (2u64, "x").hash(h));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
