//! Small self-contained utilities standing in for crates that are not
//! available in this offline build (see DESIGN.md §Substitutions):
//! [`rng`] replaces `rand`/`rand_chacha`, [`prop`] replaces `proptest`,
//! [`par`] replaces `rayon`, [`stats`] provides the summary statistics
//! the bench harness prints.

pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;

pub use par::parallel_map;
pub use rng::Rng;
