//! Deterministic pseudo-random number generator.
//!
//! A 64-bit PCG-class generator (splitmix64-seeded xoshiro256**), small,
//! fast and reproducible across platforms — every agent takes an explicit
//! seed so DSE runs are exactly repeatable. Not cryptographic.

/// Splitmix64: used to expand the user seed into the xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — public-domain generator by Blackman & Vigna.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically; equal seeds give equal streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s }
    }

    /// Next raw 64 bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Panics if `n == 0`.
    /// Lemire's nearly-divisionless bounded sampling.
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample an index proportional to non-negative `weights` (roulette
    /// wheel). Falls back to uniform when all weights are ~zero.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 1e-300 {
            return self.gen_range(weights.len());
        }
        let mut target = self.gen_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Rng::seed_from_u64(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values should appear in 1000 draws");
    }

    #[test]
    fn gen_f64_unit_interval_roughly_uniform() {
        let mut r = Rng::seed_from_u64(3);
        let n = 10_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut r = Rng::seed_from_u64(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.08, "var={var}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::seed_from_u64(11);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..50 {
            assert_eq!(r.weighted_index(&w), 2);
        }
        // Degenerate all-zero weights: uniform fallback stays in range.
        let z = [0.0; 4];
        for _ in 0..50 {
            assert!(r.weighted_index(&z) < 4);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn bernoulli_extremes() {
        let mut r = Rng::seed_from_u64(17);
        assert!((0..100).all(|_| !r.gen_bool(0.0)));
        assert!((0..100).all(|_| r.gen_bool(1.0)));
    }
}
