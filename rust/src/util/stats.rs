//! Summary statistics used by the bench harness and the DSE history
//! reports (min/max/mean/percentiles/geomean over latency and reward
//! series).

/// Descriptive statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

/// Compute a [`Summary`]; returns `None` for an empty or all-NaN sample.
pub fn summarize(xs: &[f64]) -> Option<Summary> {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return None;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let mean = v.iter().sum::<f64>() / n as f64;
    Some(Summary {
        n,
        min: v[0],
        max: v[n - 1],
        mean,
        p50: percentile_sorted(&v, 50.0),
        p90: percentile_sorted(&v, 90.0),
        p99: percentile_sorted(&v, 99.0),
    })
}

/// Percentile by linear interpolation on a pre-sorted sample. An empty
/// sample yields NaN (a telemetry export must never panic on a
/// histogram nobody recorded into); use [`try_percentile_sorted`] to
/// branch on emptiness instead.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    try_percentile_sorted(sorted, p).unwrap_or(f64::NAN)
}

/// Percentile by linear interpolation on a pre-sorted sample; `None`
/// when the sample is empty.
pub fn try_percentile_sorted(sorted: &[f64], p: f64) -> Option<f64> {
    if sorted.is_empty() {
        return None;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    Some(if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    })
}

/// Geometric mean of strictly positive values (NaN/non-positive skipped).
pub fn geomean(xs: &[f64]) -> f64 {
    let v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite() && *x > 0.0).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    (v.iter().map(|x| x.ln()).sum::<f64>() / v.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn empty_and_nan_samples_are_none() {
        assert!(summarize(&[]).is_none());
        assert!(summarize(&[f64::NAN, f64::INFINITY]).is_none());
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile_sorted(&v, 50.0), 5.0);
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 10.0);
    }

    #[test]
    fn empty_percentile_is_nan_not_panic() {
        assert!(percentile_sorted(&[], 50.0).is_nan());
        assert_eq!(try_percentile_sorted(&[], 99.0), None);
        assert_eq!(try_percentile_sorted(&[7.0], 50.0), Some(7.0));
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert!(geomean(&[]).is_nan());
        // Non-positive values are skipped, not propagated.
        assert!((geomean(&[-1.0, 4.0, 4.0]) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = summarize(&[5.0, 1.0, 3.0, 2.0, 4.0]).unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 3.0);
    }
}
