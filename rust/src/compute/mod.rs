//! Compute-device substrate (paper §2.4).
//!
//! The paper models an NPU with three parameters — *peak-perf*,
//! *local-mem-bw*, and *memory-capacity* — and uses a simple roofline model
//! for per-operator runtime plus a capacity constraint that invalidates
//! parallelizations whose per-NPU footprint exceeds the budget (24 GB in
//! §5.4). We implement exactly that.


/// Memory budget per NPU beyond which a parallelization is invalid
/// (paper §5.4: "any parallelization strategy resulting in a memory
/// footprint exceeding 24 GB per NPU is considered invalid").
pub const MEM_LIMIT_BYTES: f64 = 24.0 * 1e9;

/// An NPU as the paper parameterizes it (Table 3's "Compute Knob").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeDevice {
    /// Peak compute throughput in TFLOP/s (Table 3 "Compute Performance").
    pub peak_tflops: f64,
    /// Local memory bandwidth in GB/s (Table 3 "Local Mem BW").
    pub local_mem_bw_gbps: f64,
    /// Memory capacity in GB.
    pub memory_capacity_gb: f64,
}

impl ComputeDevice {
    pub fn new(peak_tflops: f64, local_mem_bw_gbps: f64, memory_capacity_gb: f64) -> Self {
        Self { peak_tflops, local_mem_bw_gbps, memory_capacity_gb }
    }

    /// Roofline runtime (microseconds) of one operator:
    /// `max(flops / peak, bytes / mem_bw)`.
    ///
    /// `flops` is total floating-point operations, `bytes` is total HBM
    /// traffic (reads + writes). TFLOP/s = flops/us × 1e6;
    /// GB/s = bytes/us × 1e3.
    pub fn op_time_us(&self, flops: f64, bytes: f64) -> f64 {
        let compute_us = flops / (self.peak_tflops * 1e6);
        let memory_us = bytes / (self.local_mem_bw_gbps * 1e3);
        compute_us.max(memory_us)
    }

    /// Arithmetic-intensity ridge point (flops/byte): ops above this are
    /// compute-bound, below memory-bound.
    pub fn ridge_intensity(&self) -> f64 {
        (self.peak_tflops * 1e6) / (self.local_mem_bw_gbps * 1e3)
    }

    /// Whether an operator is compute-bound on this device.
    pub fn compute_bound(&self, flops: f64, bytes: f64) -> bool {
        bytes <= 0.0 || flops / bytes >= self.ridge_intensity()
    }

    /// Effective achieved TFLOP/s for an op (for utilization reporting).
    pub fn achieved_tflops(&self, flops: f64, bytes: f64) -> f64 {
        let t = self.op_time_us(flops, bytes);
        if t <= 0.0 {
            0.0
        } else {
            flops / (t * 1e6)
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.peak_tflops <= 0.0 {
            return Err("peak_tflops must be > 0".into());
        }
        if self.local_mem_bw_gbps <= 0.0 {
            return Err("local_mem_bw_gbps must be > 0".into());
        }
        if self.memory_capacity_gb <= 0.0 {
            return Err("memory_capacity_gb must be > 0".into());
        }
        Ok(())
    }
}

/// Table 3's three compute configurations.
pub mod presets {
    use super::ComputeDevice;

    /// System 1: TPUv5p-like (459 TFLOPS, 2765 GB/s).
    pub fn system1() -> ComputeDevice {
        ComputeDevice::new(459.0, 2765.0, 32.0)
    }

    /// System 2: the 4D-network cluster of [43] (10 TFLOPS, 50 GB/s).
    pub fn system2() -> ComputeDevice {
        ComputeDevice::new(10.0, 50.0, 32.0)
    }

    /// System 3: H100-like (900 TFLOPS, 3000 GB/s).
    pub fn system3() -> ComputeDevice {
        ComputeDevice::new(900.0, 3000.0, 32.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_op_hits_peak() {
        let d = ComputeDevice::new(100.0, 1000.0, 32.0);
        // 1e12 flops, tiny bytes: time = 1e12/(100e6) us = 1e4 us.
        let t = d.op_time_us(1e12, 1.0);
        assert!((t - 1e4).abs() < 1e-6);
        assert!(d.compute_bound(1e12, 1.0));
        assert!((d.achieved_tflops(1e12, 1.0) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn memory_bound_op_hits_bandwidth() {
        let d = ComputeDevice::new(100.0, 1000.0, 32.0);
        // 1 GB of traffic at 1000 GB/s = 1000 us, tiny flops.
        let t = d.op_time_us(1.0, 1e9);
        assert!((t - 1000.0).abs() < 1e-6);
        assert!(!d.compute_bound(1.0, 1e9));
    }

    #[test]
    fn ridge_point_separates_regimes() {
        let d = ComputeDevice::new(100.0, 1000.0, 32.0);
        let ridge = d.ridge_intensity(); // 1e8/1e6 = 100 flops/byte
        assert!((ridge - 100.0).abs() < 1e-9);
        // Exactly at ridge both roofs are equal.
        let flops = 1e10;
        let bytes = flops / ridge;
        let t = d.op_time_us(flops, bytes);
        assert!((t - flops / 1e8).abs() < 1e-6);
    }

    #[test]
    fn presets_match_table3() {
        assert_eq!(presets::system1().peak_tflops, 459.0);
        assert_eq!(presets::system1().local_mem_bw_gbps, 2765.0);
        assert_eq!(presets::system2().peak_tflops, 10.0);
        assert_eq!(presets::system2().local_mem_bw_gbps, 50.0);
        assert_eq!(presets::system3().peak_tflops, 900.0);
        assert_eq!(presets::system3().local_mem_bw_gbps, 3000.0);
    }

    #[test]
    fn validate_rejects_nonpositive() {
        assert!(ComputeDevice::new(0.0, 1.0, 1.0).validate().is_err());
        assert!(ComputeDevice::new(1.0, 0.0, 1.0).validate().is_err());
        assert!(ComputeDevice::new(1.0, 1.0, 0.0).validate().is_err());
        assert!(ComputeDevice::new(1.0, 1.0, 1.0).validate().is_ok());
    }

    #[test]
    fn zero_work_is_free() {
        let d = ComputeDevice::new(100.0, 1000.0, 32.0);
        assert_eq!(d.op_time_us(0.0, 0.0), 0.0);
    }

    #[test]
    fn faster_device_is_faster() {
        let slow = presets::system2();
        let fast = presets::system3();
        let (f, b) = (1e12, 1e9);
        assert!(fast.op_time_us(f, b) < slow.op_time_us(f, b));
    }
}
