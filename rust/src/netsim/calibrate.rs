//! Calibrate the flow-level fabric against the packet rung.
//!
//! The fluid model is the DSE's mid-fidelity workhorse; the packet rung
//! is its ground truth for queueing effects the fluid shares cannot see
//! (ECMP hash collisions, incast serialization granularity). This
//! module closes the loop: [`calibrate_flow_config`] drains a saturating
//! single-dimension sweep on both rungs and fits per-dimension
//! oversubscription factors so the cheap model reproduces the expensive
//! one's makespans.
//!
//! The fit is exact by construction for the sweep itself: a dimension
//! whose packet drain runs `r`× slower than the fluid drain gets its
//! oversubscription multiplied by `r` (capacity divided by `r`), which
//! rescales the fluid makespan to the packet one. On other traffic the
//! fitted config is an approximation — the point is that it is fitted
//! to queueing behavior rather than guessed.

use super::fabric::FlowLevelConfig;
use super::flow::{FlowSim, FlowSpec};
use super::packet::{PacketLevelConfig, PacketSim};
use crate::topology::Topology;

/// One dimension's packet-vs-fluid measurement.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationSample {
    /// Topology dimension index.
    pub dim: usize,
    /// Makespan of the sweep on the packet rung (us).
    pub packet_us: f64,
    /// Makespan of the same sweep on the fluid rung (us).
    pub flow_us: f64,
    /// `packet_us / flow_us` (1.0 when the fluid model already matches).
    pub ratio: f64,
    /// The fitted oversubscription factor (`base * ratio`, clamped to
    /// the fabric model's `>= 1` floor).
    pub fitted_oversubscription: f64,
}

/// Result of [`calibrate_flow_config`].
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationReport {
    /// Per-dimension measurements, one per topology dimension.
    pub samples: Vec<CalibrationSample>,
    /// The calibrated fabric: the packet config's fabric with
    /// `per_dim_oversubscription` replaced by the fitted factors.
    pub fitted: FlowLevelConfig,
}

impl CalibrationReport {
    /// The fitted per-dimension oversubscription factors, in dimension
    /// order.
    pub fn per_dim_oversubscription(&self) -> Vec<f64> {
        self.samples.iter().map(|s| s.fitted_oversubscription).collect()
    }
}

/// Fit a [`FlowLevelConfig`] against packet-level drains: for every
/// topology dimension, drain `concurrency` concurrent equal flows of
/// `bytes_per_flow` bytes through that dimension on both rungs and
/// scale the dimension's oversubscription by the observed
/// packet-to-fluid makespan ratio.
///
/// With `ecmp_width == 1` the two rungs agree (round-robin FIFO service
/// is work-conserving) and the fit is the identity; widths `> 1`
/// surface hash-collision hotspots as extra effective oversubscription.
pub fn calibrate_flow_config(
    topo: &Topology,
    packet: &PacketLevelConfig,
    concurrency: usize,
    bytes_per_flow: f64,
) -> CalibrationReport {
    let k = concurrency.max(1);
    let bytes = bytes_per_flow.max(packet.mtu_bytes.max(1.0));
    // Calibrate against the sanitized fabric — the same validation path
    // the backends construct through — so a struct-literal config with
    // out-of-range fields cannot skew the fit.
    let fabric = packet.fabric.sanitized();
    let packet = &PacketLevelConfig { fabric: fabric.clone(), ..packet.clone() };
    let psim = PacketSim::new(topo, packet);
    let fsim = FlowSim::new(fabric.dim_capacities(topo));
    let makespan = |finishes: &[f64]| finishes.iter().copied().fold(0.0, f64::max);
    let mut samples = Vec::with_capacity(topo.dims.len());
    for (d, nd) in topo.dims.iter().enumerate() {
        let chains: Vec<(f64, Vec<FlowSpec>)> = (0..k)
            .map(|_| (0.0, vec![FlowSpec { uses: vec![d], bytes, latency_us: 0.0 }]))
            .collect();
        let pkt: Vec<f64> = psim.run(&chains).iter().map(|r| r.finish_us).collect();
        let fluid: Vec<f64> = fsim.run(&chains).iter().map(|r| r.finish_us).collect();
        let packet_us = makespan(&pkt);
        let flow_us = makespan(&fluid);
        let ratio = if flow_us > 0.0 { packet_us / flow_us } else { 1.0 };
        let base = packet.fabric.oversubscription(nd.kind, d);
        samples.push(CalibrationSample {
            dim: d,
            packet_us,
            flow_us,
            ratio,
            fitted_oversubscription: (base * ratio).max(1.0),
        });
    }
    let mut fitted = packet.fabric.clone();
    fitted.per_dim_oversubscription =
        Some(samples.iter().map(|s| s.fitted_oversubscription).collect());
    CalibrationReport { samples, fitted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::DimKind;

    fn topo() -> Topology {
        Topology::from_arrays(
            &[DimKind::Ring, DimKind::Switch],
            &[4, 8],
            &[200.0, 100.0],
            &[0.5, 1.0],
        )
    }

    #[test]
    fn width_one_fit_is_the_identity() {
        let topo = topo();
        let packet = PacketLevelConfig::oversubscribed(4.0);
        let report = calibrate_flow_config(&topo, &packet, 6, 4e6);
        for s in &report.samples {
            assert!(
                (s.ratio - 1.0).abs() < 1e-6,
                "dim {}: ratio {} should be 1 at width 1",
                s.dim,
                s.ratio
            );
            let base = packet.fabric.oversubscription(topo.dims[s.dim].kind, s.dim);
            assert!((s.fitted_oversubscription - base).abs() < 1e-6 * base);
        }
    }

    #[test]
    fn ecmp_collisions_surface_as_extra_oversubscription() {
        let topo = topo();
        let packet = PacketLevelConfig::oversubscribed(4.0).with_ecmp_width(4);
        let report = calibrate_flow_config(&topo, &packet, 6, 4e6);
        // Ring dims have no path diversity: identity fit.
        assert!((report.samples[0].ratio - 1.0).abs() < 1e-6);
        // 6 flows hashed onto 4 equal-cost paths collide somewhere
        // (pigeonhole): the hot path serves >= 2 flows at cap/4, so the
        // packet drain runs >= 8/6 of the fluid one.
        assert!(
            report.samples[1].ratio > 1.2,
            "switch ratio {} should expose collisions",
            report.samples[1].ratio
        );
        assert!(
            report.samples[1].fitted_oversubscription
                > packet.fabric.oversubscription(DimKind::Switch, 1)
        );
    }

    #[test]
    fn fitted_fluid_reproduces_packet_makespans() {
        let topo = topo();
        let packet = PacketLevelConfig::oversubscribed(4.0).with_ecmp_width(4);
        let report = calibrate_flow_config(&topo, &packet, 6, 4e6);
        let fitted_sim = FlowSim::new(report.fitted.dim_capacities(&topo));
        for s in &report.samples {
            let chains: Vec<(f64, Vec<FlowSpec>)> = (0..6)
                .map(|_| (0.0, vec![FlowSpec { uses: vec![s.dim], bytes: 4e6, latency_us: 0.0 }]))
                .collect();
            let refit =
                fitted_sim.run(&chains).iter().map(|r| r.finish_us).fold(0.0, f64::max);
            assert!(
                (refit - s.packet_us).abs() < 0.05 * s.packet_us,
                "dim {}: fitted fluid {} vs packet {}",
                s.dim,
                refit,
                s.packet_us
            );
        }
    }

    #[test]
    fn calibration_is_deterministic() {
        let topo = topo();
        let packet = PacketLevelConfig::oversubscribed(2.0).with_ecmp_width(4).with_seed(11);
        let a = calibrate_flow_config(&topo, &packet, 8, 2e6);
        let b = calibrate_flow_config(&topo, &packet, 8, 2e6);
        assert_eq!(a, b);
    }
}
