//! Packet-level fidelity rung: per-port FIFO queueing, seeded ECMP
//! hashing, and incast serialization over the [`EventQueue`] engine.
//!
//! The third rung of the `netsim` ladder discretizes each collective
//! phase's flows into MTU-sized packets and pushes them through
//! per-(dimension, path) ports:
//!
//! - **Capacity.** A dimension's aggregate service rate equals the
//!   fluid model's effective capacity
//!   ([`FlowLevelConfig::dim_capacities`]), split evenly across its
//!   `ecmp_width` equal-cost paths (Switch dimensions only — direct
//!   Ring/Torus dimensions have no path diversity). With width 1 the
//!   packet rung is the fluid capacity model, packet-quantized: a
//!   single uncontended flow costs exactly `alpha + bytes/rate`, which
//!   is what pins the cross-fidelity conformance suite.
//! - **ECMP.** Every flow is pinned to one path by a pure hash of
//!   `(seed, chain, flow, dim)` — bit-reproducible, and order-preserving
//!   per flow (no packet reordering). Widths > 1 model hash collisions
//!   on an oversubscribed core: two flows colliding on one path share
//!   `cap/width` while another path idles, which is strictly pessimistic
//!   versus the fluid max-min share — the htsim-style ECMP effect.
//! - **Incast.** A port serves one packet at a time, FIFO; concurrent
//!   flows targeting the same port serialize packet by packet. Admission
//!   round-robins across the port's active flows and is bounded by
//!   `queue_depth` waiting packets (lossless backpressure), so service
//!   interleaves fairly — the quantized analogue of the max-min share.
//!
//! Blocking collectives run alone by definition; alone, FIFO
//! packetization at rate `r` serializes to exactly `bytes/r` per phase,
//! so [`PacketLevel::collective_time_us`] reuses the flow-level
//! congested closed form (the event simulation is reserved for the
//! concurrent gradient drain, where queueing actually bites).

use std::collections::VecDeque;
use std::hash::Hash;
use std::sync::Arc;

use super::backend::{
    collapse_per_layer, CollectiveCall, FidelityMode, FlowLevel, NetworkBackend, OverlapCall,
};
use super::engine::EventQueue;
use super::fabric::FlowLevelConfig;
use super::flow::FlowSpec;
use crate::collective::SchedulingPolicy;
use crate::obs::{tracks, TraceSink};
use crate::topology::{DimKind, Topology};
use crate::util::hash64;

/// Fabric + packet parameters of the packet rung — the
/// [`FlowLevelConfig`]-style configuration surface.
#[derive(Debug, Clone, PartialEq)]
pub struct PacketLevelConfig {
    /// The underlying fabric model: oversubscription and background
    /// load set each dimension's aggregate capacity, exactly as on the
    /// flow-level rung (so the two rungs agree when queueing is idle).
    pub fabric: FlowLevelConfig,
    /// Packet payload size in bytes; flows are cut into
    /// `ceil(bytes/mtu)` packets (the last one short).
    pub mtu_bytes: f64,
    /// Waiting packets admitted per port beyond the one in service —
    /// lossless backpressure bound on the ingress FIFO.
    pub queue_depth: usize,
    /// Equal-cost paths per Switch dimension. `1` (the default) is the
    /// aggregate-lane view that keeps the rung conformant with the
    /// fluid model; `> 1` splits the capacity and exposes hash
    /// collisions.
    pub ecmp_width: usize,
    /// Seed of the deterministic ECMP hash ([`ecmp_path`]).
    pub seed: u64,
    /// Event-count bound: flows larger than `max_packets_per_flow`
    /// MTUs coarsen to that many equal super-packets (byte
    /// conservation is preserved; only quantization granularity
    /// changes).
    pub max_packets_per_flow: usize,
}

impl Default for PacketLevelConfig {
    fn default() -> Self {
        Self {
            fabric: FlowLevelConfig::default(),
            mtu_bytes: 4096.0,
            queue_depth: 64,
            ecmp_width: 1,
            seed: 0xC051_1C,
            max_packets_per_flow: 4096,
        }
    }
}

impl PacketLevelConfig {
    /// Default packet parameters over an oversubscribed fabric.
    pub fn oversubscribed(factor: f64) -> Self {
        Self { fabric: FlowLevelConfig::oversubscribed(factor), ..Self::default() }
    }

    /// Replace the ECMP seed (builder style).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Replace the per-Switch-dimension path count (builder style).
    pub fn with_ecmp_width(mut self, width: usize) -> Self {
        self.ecmp_width = width;
        self
    }

    fn mtu(&self) -> f64 {
        self.mtu_bytes.max(1.0)
    }

    fn depth(&self) -> usize {
        self.queue_depth.max(1)
    }

    fn width_for(&self, kind: DimKind) -> usize {
        match kind {
            DimKind::Switch => self.ecmp_width.max(1),
            _ => 1,
        }
    }
}

/// The equal-cost path a flow is pinned to: a pure, seeded hash of the
/// flow's identity — bit-reproducible across runs and processes, and
/// constant per flow (so per-flow packet order is preserved).
pub fn ecmp_path(seed: u64, chain: usize, flow: usize, dim: usize, width: usize) -> usize {
    if width <= 1 {
        return 0;
    }
    let h = hash64(|h| {
        0x9AC7_u64.hash(h);
        seed.hash(h);
        chain.hash(h);
        flow.hash(h);
        dim.hash(h);
    });
    (h % width as u64) as usize
}

/// Completion record of one chain through [`PacketSim::run`].
#[derive(Debug, Clone, PartialEq)]
pub struct PacketChainResult {
    /// Absolute finish time of the chain's last flow (the chain's issue
    /// time when it has no flows).
    pub finish_us: f64,
    /// Bytes actually served across the chain's packets — equals the
    /// chain's total `FlowSpec::bytes` up to float residue (the
    /// conservation property tests pin this).
    pub served_bytes: f64,
    /// Packets served for this chain.
    pub packets: u64,
}

/// One served packet, in service order (recorded by
/// [`PacketSim::run_recorded`] for the FIFO/conservation properties).
#[derive(Debug, Clone, PartialEq)]
pub struct ServedPacket {
    pub chain: usize,
    /// Flow index within the chain.
    pub flow: usize,
    pub dim: usize,
    pub path: usize,
    /// Packet index within the flow (FIFO ports never invert these).
    pub index: u64,
    pub start_us: f64,
    pub finish_us: f64,
}

/// One flow's transmit window (activation to last packet served).
#[derive(Debug, Clone, PartialEq)]
pub struct FlowSpan {
    pub chain: usize,
    pub flow: usize,
    pub dim: usize,
    pub path: usize,
    pub start_us: f64,
    pub finish_us: f64,
}

/// One contiguous busy window of a port's server — the per-queue
/// occupancy spans the traced drain emits.
#[derive(Debug, Clone, PartialEq)]
pub struct PortWindow {
    pub dim: usize,
    pub path: usize,
    pub start_us: f64,
    pub end_us: f64,
    /// Packets served back to back within the window.
    pub packets: u64,
}

/// Trace-side observations of one packet drain ([`PacketSim::run_traced`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PacketTrace {
    pub flows: Vec<FlowSpan>,
    pub windows: Vec<PortWindow>,
}

/// The packet-level event simulator: chains of [`FlowSpec`]s (identical
/// semantics to [`super::flow::FlowSim`] — each flow pays its latency
/// *before* its data phase, flows within a chain are sequential) whose
/// data phases are discretized into packets served by per-(dim, path)
/// FIFO ports.
#[derive(Debug, Clone)]
pub struct PacketSim {
    /// Aggregate capacity per dimension (bytes/us), fluid-identical.
    caps: Vec<f64>,
    /// Equal-cost paths per dimension.
    widths: Vec<usize>,
    mtu: f64,
    depth: usize,
    seed: u64,
    max_packets: usize,
}

impl PacketSim {
    /// Build the per-port fabric for `topo` under `config`.
    pub fn new(topo: &Topology, config: &PacketLevelConfig) -> Self {
        Self {
            caps: config.fabric.dim_capacities(topo),
            widths: topo.dims.iter().map(|d| config.width_for(d.kind)).collect(),
            mtu: config.mtu(),
            depth: config.depth(),
            seed: config.seed,
            max_packets: config.max_packets_per_flow.max(1),
        }
    }

    /// `(packet count, full size, last size)` of one flow's data phase.
    fn packets_of(&self, bytes: f64) -> (u64, f64, f64) {
        if bytes <= 0.0 {
            return (0, 0.0, 0.0);
        }
        let raw = (bytes / self.mtu).ceil();
        if raw <= self.max_packets as f64 {
            let count = (raw as u64).max(1);
            (count, self.mtu, bytes - (count - 1) as f64 * self.mtu)
        } else {
            // Coarsen to equal super-packets: same bytes, same port
            // discipline, bounded event count.
            let count = self.max_packets as u64;
            let size = bytes / count as f64;
            (count, size, size)
        }
    }

    /// Run the chains to completion; one result per chain, in order.
    pub fn run(&self, chains: &[(f64, Vec<FlowSpec>)]) -> Vec<PacketChainResult> {
        self.run_inner(chains, None, None)
    }

    /// [`PacketSim::run`] that additionally records every served packet
    /// in service order.
    pub fn run_recorded(
        &self,
        chains: &[(f64, Vec<FlowSpec>)],
        record: &mut Vec<ServedPacket>,
    ) -> Vec<PacketChainResult> {
        self.run_inner(chains, Some(record), None)
    }

    /// [`PacketSim::run`] that additionally collects flow windows and
    /// coalesced per-port busy windows for the trace exporter.
    pub fn run_traced(
        &self,
        chains: &[(f64, Vec<FlowSpec>)],
        trace: &mut PacketTrace,
    ) -> Vec<PacketChainResult> {
        self.run_inner(chains, None, Some(trace))
    }

    fn run_inner(
        &self,
        chains: &[(f64, Vec<FlowSpec>)],
        record: Option<&mut Vec<ServedPacket>>,
        trace: Option<&mut PacketTrace>,
    ) -> Vec<PacketChainResult> {
        let mut port_base = Vec::with_capacity(self.widths.len());
        let mut ports: Vec<Port> = Vec::new();
        for (dim, &w) in self.widths.iter().enumerate() {
            port_base.push(ports.len());
            let rate = (self.caps.get(dim).copied().unwrap_or(0.0) / w as f64).max(1e-12);
            for path in 0..w {
                ports.push(Port {
                    dim,
                    path,
                    rate,
                    fifo: VecDeque::new(),
                    rr: VecDeque::new(),
                    in_service: None,
                    busy_start: 0.0,
                    busy_pkts: 0,
                });
            }
        }
        let mut engine = Engine {
            sim: self,
            chains,
            states: chains
                .iter()
                .map(|(issue, _)| ChainState {
                    finish_us: issue.max(0.0),
                    served_bytes: 0.0,
                    packets: 0,
                    next_flow: 0,
                })
                .collect(),
            flows: Vec::new(),
            ports,
            port_base,
            q: EventQueue::new(),
            record,
            trace,
        };
        for c in 0..chains.len() {
            let issue = chains[c].0.max(0.0);
            engine.start_next_flow(c, issue);
        }
        while let Some((t, ev)) = engine.q.pop() {
            match ev {
                Ev::Activate { chain } => engine.activate(chain, t),
                Ev::Serve { port } => engine.serve(port, t),
            }
        }
        engine
            .states
            .into_iter()
            .map(|s| PacketChainResult {
                finish_us: s.finish_us,
                served_bytes: s.served_bytes,
                packets: s.packets,
            })
            .collect()
    }
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// A chain's next flow finished paying its latency and starts
    /// injecting packets.
    Activate { chain: usize },
    /// A port's in-service packet completes.
    Serve { port: usize },
}

#[derive(Debug)]
struct ChainState {
    finish_us: f64,
    served_bytes: f64,
    packets: u64,
    next_flow: usize,
}

#[derive(Debug)]
struct FlowState {
    chain: usize,
    flow: usize,
    dim: usize,
    path: usize,
    count: u64,
    full: f64,
    last: f64,
    injected: u64,
    served: u64,
    activated_us: f64,
}

impl FlowState {
    fn pkt_size(&self, index: u64) -> f64 {
        if index + 1 == self.count {
            self.last
        } else {
            self.full
        }
    }
}

#[derive(Debug)]
struct Port {
    dim: usize,
    path: usize,
    rate: f64,
    /// Waiting packets, FIFO: `(flow id, size)`.
    fifo: VecDeque<(usize, f64)>,
    /// Flows with un-injected packets, round-robin admission order.
    rr: VecDeque<usize>,
    /// `(flow id, size, service start)` of the packet on the wire.
    in_service: Option<(usize, f64, f64)>,
    busy_start: f64,
    busy_pkts: u64,
}

struct Engine<'a> {
    sim: &'a PacketSim,
    chains: &'a [(f64, Vec<FlowSpec>)],
    states: Vec<ChainState>,
    flows: Vec<FlowState>,
    ports: Vec<Port>,
    port_base: Vec<usize>,
    q: EventQueue<Ev>,
    record: Option<&'a mut Vec<ServedPacket>>,
    trace: Option<&'a mut PacketTrace>,
}

impl Engine<'_> {
    /// Advance chain `c` to its next flow at time `t`: schedule the
    /// flow's activation after its latency, or finish the chain.
    fn start_next_flow(&mut self, c: usize, t: f64) {
        let specs = &self.chains[c].1;
        let idx = self.states[c].next_flow;
        if idx >= specs.len() {
            self.states[c].finish_us = t;
        } else {
            self.q.schedule_at(t + specs[idx].latency_us.max(0.0), Ev::Activate { chain: c });
        }
    }

    fn activate(&mut self, c: usize, t: f64) {
        let idx = self.states[c].next_flow;
        let spec = &self.chains[c].1[idx];
        let (count, full, last) = self.sim.packets_of(spec.bytes);
        let Some(&dim) = spec.uses.first() else {
            // No dimension (or see below, no data): latency-only flow.
            self.states[c].next_flow += 1;
            self.start_next_flow(c, t);
            return;
        };
        if count == 0 {
            self.states[c].next_flow += 1;
            self.start_next_flow(c, t);
            return;
        }
        let width = self.sim.widths.get(dim).copied().unwrap_or(1);
        let path = ecmp_path(self.sim.seed, c, idx, dim, width);
        let fid = self.flows.len();
        self.flows.push(FlowState {
            chain: c,
            flow: idx,
            dim,
            path,
            count,
            full,
            last,
            injected: 0,
            served: 0,
            activated_us: t,
        });
        let p = self.port_base[dim] + path;
        self.ports[p].rr.push_back(fid);
        self.fill(p);
        self.try_start(p, t);
    }

    /// Admit packets into port `p`'s FIFO, round-robin across its
    /// active flows, up to the backpressure bound.
    fn fill(&mut self, p: usize) {
        while self.ports[p].fifo.len() < self.sim.depth {
            let Some(&f) = self.ports[p].rr.front() else { break };
            let fs = &mut self.flows[f];
            let size = fs.pkt_size(fs.injected);
            fs.injected += 1;
            let exhausted = fs.injected == fs.count;
            let port = &mut self.ports[p];
            port.fifo.push_back((f, size));
            if exhausted {
                port.rr.pop_front();
            } else {
                port.rr.rotate_left(1);
            }
        }
    }

    /// Put the head-of-line packet on the wire if the port is idle.
    fn try_start(&mut self, p: usize, t: f64) {
        let port = &mut self.ports[p];
        if port.in_service.is_some() {
            return;
        }
        if let Some((f, size)) = port.fifo.pop_front() {
            if port.busy_pkts == 0 {
                port.busy_start = t;
            }
            port.busy_pkts += 1;
            port.in_service = Some((f, size, t));
            let rate = port.rate;
            self.q.schedule_at(t + size / rate, Ev::Serve { port: p });
        }
    }

    fn serve(&mut self, p: usize, t: f64) {
        let (f, size, start) = self.ports[p].in_service.take().expect("serve on idle port");
        let (chain, flow_idx, dim, path, served_index, activated) = {
            let fs = &mut self.flows[f];
            let idx = fs.served;
            fs.served += 1;
            (fs.chain, fs.flow, fs.dim, fs.path, idx, fs.activated_us)
        };
        if let Some(rec) = self.record.as_deref_mut() {
            rec.push(ServedPacket {
                chain,
                flow: flow_idx,
                dim,
                path,
                index: served_index,
                start_us: start,
                finish_us: t,
            });
        }
        self.states[chain].served_bytes += size;
        self.states[chain].packets += 1;
        if self.flows[f].served == self.flows[f].count {
            if let Some(trace) = self.trace.as_deref_mut() {
                trace.flows.push(FlowSpan {
                    chain,
                    flow: flow_idx,
                    dim,
                    path,
                    start_us: activated,
                    finish_us: t,
                });
            }
            self.states[chain].next_flow += 1;
            self.start_next_flow(chain, t);
        }
        self.fill(p);
        self.try_start(p, t);
        let port = &mut self.ports[p];
        if port.in_service.is_none() && port.busy_pkts > 0 {
            if let Some(trace) = self.trace.as_deref_mut() {
                trace.windows.push(PortWindow {
                    dim: port.dim,
                    path: port.path,
                    start_us: port.busy_start,
                    end_us: t,
                    packets: port.busy_pkts,
                });
            }
            port.busy_pkts = 0;
        }
    }
}

/// The packet-level [`NetworkBackend`].
///
/// Gradient drains run through [`PacketSim`]; blocking collectives use
/// the flow-level congested closed form (exact for a collective running
/// alone — see the module docs). Wrap in
/// [`crate::faults::FaultView`] for link-degraded pricing like any
/// other rung.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PacketLevel {
    pub config: PacketLevelConfig,
}

impl PacketLevel {
    pub fn new(config: PacketLevelConfig) -> Self {
        // Same single validation path as FlowLevel::new: struct-literal
        // fabrics are repaired once, at construction.
        Self { config: PacketLevelConfig { fabric: config.fabric.sanitized(), ..config } }
    }

    /// The flow-level twin over the same fabric: plans the per-phase
    /// flow chains and prices blocking collectives.
    fn planner(&self) -> FlowLevel {
        FlowLevel::new(self.config.fabric.clone())
    }

    fn chains_of(planner: &FlowLevel, jobs: &[OverlapCall<'_>]) -> Vec<(f64, Vec<FlowSpec>)> {
        jobs.iter().map(|j| (j.issue_us.max(0.0), planner.chain_of(&j.call))).collect()
    }
}

impl NetworkBackend for PacketLevel {
    fn name(&self) -> &'static str {
        "packet-level"
    }

    fn fidelity(&self) -> FidelityMode {
        FidelityMode::Packet
    }

    fn cache_tag(&self) -> u64 {
        // Fold every pricing input: the fabric (as the flow rung does)
        // plus the packet parameters, under a rung-distinct constant.
        hash64(|h| {
            0x9AC7_u64.hash(h);
            self.config.fabric.switch_oversubscription.to_bits().hash(h);
            self.config.fabric.background_load.to_bits().hash(h);
            self.config
                .fabric
                .per_dim_oversubscription
                .as_ref()
                .map(|v| v.iter().map(|f| f.to_bits()).collect::<Vec<u64>>())
                .hash(h);
            self.config
                .fabric
                .per_dim_background
                .as_ref()
                .map(|v| v.iter().map(|f| f.to_bits()).collect::<Vec<u64>>())
                .hash(h);
            self.config.mtu_bytes.to_bits().hash(h);
            self.config.queue_depth.hash(h);
            self.config.ecmp_width.hash(h);
            self.config.seed.hash(h);
            self.config.max_packets_per_flow.hash(h);
        })
    }

    fn with_dim_utilization(&self, util: &[f64]) -> Option<Arc<dyn NetworkBackend>> {
        // Per-port service rates derive from the fabric capacities, so
        // folding utilization into the fabric modulates every queue of
        // the affected dimension.
        Some(Arc::new(PacketLevel::new(PacketLevelConfig {
            fabric: self.config.fabric.clone().with_dim_background(util),
            ..self.config.clone()
        })))
    }

    fn collective_time_us(&self, call: &CollectiveCall<'_>) -> f64 {
        self.planner().collective_time_us(call)
    }

    fn drain_overlapped(
        &self,
        jobs: &[OverlapCall<'_>],
        _policy: SchedulingPolicy,
    ) -> Vec<(u64, f64)> {
        // Like the flow rung, the network multiplexes — admission
        // policy is moot; ports arbitrate FIFO at packet granularity.
        let Some(first) = jobs.first() else { return Vec::new() };
        let planner = self.planner();
        let chains = Self::chains_of(&planner, jobs);
        let results = PacketSim::new(first.call.topology, &self.config).run(&chains);
        collapse_per_layer(jobs.iter().zip(results.iter()).map(|(j, r)| (j.layer, r.finish_us)))
    }

    fn drain_overlapped_traced(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
        sink: &dyn TraceSink,
    ) -> Vec<(u64, f64)> {
        if !sink.enabled() {
            return self.drain_overlapped(jobs, policy);
        }
        let Some(first) = jobs.first() else { return Vec::new() };
        let planner = self.planner();
        let chains = Self::chains_of(&planner, jobs);
        let mut trace = PacketTrace::default();
        let results =
            PacketSim::new(first.call.topology, &self.config).run_traced(&chains, &mut trace);
        for fsp in &trace.flows {
            let layer = jobs[fsp.chain].layer;
            sink.span(
                tracks::net_dim(fsp.dim),
                &format!("grad L{layer} pkt flow {}", fsp.flow),
                fsp.start_us,
                fsp.finish_us,
            );
        }
        for w in &trace.windows {
            sink.span(
                tracks::net_queue(w.dim, w.path),
                &format!("queue busy ({} pkts)", w.packets),
                w.start_us,
                w.end_us,
            );
        }
        collapse_per_layer(jobs.iter().zip(results.iter()).map(|(j, r)| (j.layer, r.finish_us)))
    }

    fn phase_times_us(&self, call: &CollectiveCall<'_>) -> Vec<(usize, f64)> {
        self.planner().phase_times_us(call)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{CollAlgo, CollectiveKind, MultiDimPolicy};
    use crate::netsim::Analytical;
    use crate::topology::DimCost;

    fn spec(dim: usize, bytes: f64, latency_us: f64) -> FlowSpec {
        FlowSpec { uses: vec![dim], bytes, latency_us }
    }

    fn one_dim_sim(cap: f64) -> PacketSim {
        PacketSim {
            caps: vec![cap],
            widths: vec![1],
            mtu: 4096.0,
            depth: 64,
            seed: 7,
            max_packets: 4096,
        }
    }

    #[test]
    fn single_flow_alone_matches_fluid_rate() {
        let sim = one_dim_sim(100.0);
        let res = sim.run(&[(10.0, vec![spec(0, 1e6, 5.0)])]);
        let expect = 10.0 + 5.0 + 1e6 / 100.0;
        assert!(
            (res[0].finish_us - expect).abs() < 1e-6 * expect,
            "finish={} expect={expect}",
            res[0].finish_us
        );
        assert!((res[0].served_bytes - 1e6).abs() < 1e-6);
        assert_eq!(res[0].packets, (1e6_f64 / 4096.0).ceil() as u64);
    }

    #[test]
    fn empty_and_latency_only_chains() {
        let sim = one_dim_sim(100.0);
        let res = sim.run(&[
            (3.0, Vec::new()),
            (0.0, vec![spec(0, 0.0, 7.5)]),
            (0.0, vec![FlowSpec { uses: Vec::new(), bytes: 1e6, latency_us: 2.0 }]),
        ]);
        assert_eq!(res[0].finish_us, 3.0);
        assert_eq!(res[1].finish_us, 7.5);
        assert_eq!(res[2].finish_us, 2.0);
        assert!(res.iter().all(|r| r.packets == 0 || r.served_bytes > 0.0));
    }

    #[test]
    fn chain_flows_are_sequential_with_latency_before_data() {
        let sim = one_dim_sim(50.0);
        let res = sim.run(&[(0.0, vec![spec(0, 1e5, 2.0), spec(0, 2e5, 3.0)])]);
        let expect = 2.0 + 1e5 / 50.0 + 3.0 + 2e5 / 50.0;
        assert!(
            (res[0].finish_us - expect).abs() < 1e-6 * expect,
            "finish={} expect={expect}",
            res[0].finish_us
        );
    }

    #[test]
    fn incast_serializes_at_the_port() {
        let sim = one_dim_sim(100.0);
        let solo = sim.run(&[(0.0, vec![spec(0, 1e6, 0.0)])])[0].finish_us;
        let chains: Vec<(f64, Vec<FlowSpec>)> =
            (0..4).map(|_| (0.0, vec![spec(0, 1e6, 0.0)])).collect();
        let res = sim.run(&chains);
        let makespan = res.iter().map(|r| r.finish_us).fold(0.0, f64::max);
        assert!(
            (makespan - 4.0 * solo).abs() < 1e-3 * makespan,
            "makespan={makespan} expected ~{}",
            4.0 * solo
        );
        // Round-robin service: every flow finishes within one packet
        // service round of the others.
        let first = res.iter().map(|r| r.finish_us).fold(f64::INFINITY, f64::min);
        assert!(makespan - first <= 4.0 * 4096.0 / 100.0 + 1e-6);
    }

    #[test]
    fn coarsening_conserves_bytes() {
        let mut sim = one_dim_sim(1000.0);
        sim.max_packets = 256;
        let bytes = 3.5e9;
        let res = sim.run(&[(0.0, vec![spec(0, bytes, 0.0)])]);
        assert_eq!(res[0].packets, 256);
        assert!((res[0].served_bytes - bytes).abs() < 1e-6 * bytes);
        let expect = bytes / 1000.0;
        assert!((res[0].finish_us - expect).abs() < 1e-6 * expect);
    }

    #[test]
    fn queue_depth_is_work_conserving_for_one_flow() {
        let mut shallow = one_dim_sim(100.0);
        shallow.depth = 1;
        let deep = one_dim_sim(100.0);
        let chains = [(0.0, vec![spec(0, 1e6, 1.0)])];
        let a = shallow.run(&chains);
        let b = deep.run(&chains);
        assert!((a[0].finish_us - b[0].finish_us).abs() < 1e-9 * b[0].finish_us);
    }

    #[test]
    fn ecmp_assignment_is_reproducible_and_in_range() {
        for flow in 0..64 {
            let a = ecmp_path(42, 3, flow, 1, 4);
            let b = ecmp_path(42, 3, flow, 1, 4);
            assert_eq!(a, b);
            assert!(a < 4);
        }
        assert_eq!(ecmp_path(42, 0, 0, 0, 1), 0);
    }

    #[test]
    fn fifo_service_order_never_inverts() {
        let sim = one_dim_sim(100.0);
        let chains: Vec<(f64, Vec<FlowSpec>)> =
            (0..3).map(|i| (i as f64, vec![spec(0, 5e5, 0.5)])).collect();
        let mut record = Vec::new();
        sim.run_recorded(&chains, &mut record);
        assert!(!record.is_empty());
        let mut last_finish = 0.0;
        let mut per_flow: std::collections::HashMap<(usize, usize), u64> =
            std::collections::HashMap::new();
        for pkt in &record {
            assert!(pkt.finish_us >= last_finish - 1e-9, "port service overlapped");
            last_finish = pkt.finish_us;
            let next = per_flow.entry((pkt.chain, pkt.flow)).or_insert(0);
            assert_eq!(pkt.index, *next, "packet order inverted within a flow");
            *next += 1;
        }
    }

    fn topo() -> Topology {
        Topology::from_arrays(
            &[DimKind::Ring, DimKind::Switch],
            &[4, 8],
            &[200.0, 100.0],
            &[0.5, 1.0],
        )
    }

    fn span_of(topo: &Topology) -> Vec<(DimCost, usize)> {
        topo.dims.iter().enumerate().map(|(d, nd)| (DimCost::from_dim(nd), d)).collect()
    }

    fn call<'a>(
        topo: &'a Topology,
        span: &'a [(DimCost, usize)],
        algos: &'a [CollAlgo],
        bytes: f64,
        chunks: u32,
    ) -> CollectiveCall<'a> {
        CollectiveCall {
            kind: CollectiveKind::AllReduce,
            policy: MultiDimPolicy::Baseline,
            algos,
            span,
            topology: topo,
            bytes,
            chunks,
        }
    }

    #[test]
    fn uncontended_single_job_drain_matches_lower_rungs() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        for chunks in [1u32, 4] {
            let c = call(&topo, &span, &algos, 16e6, chunks);
            let job = OverlapCall { layer: 0, issue_us: 10.0, call: c };
            let a = Analytical.drain_overlapped(&[job], SchedulingPolicy::Fifo)[0].1;
            let f = FlowLevel::default().drain_overlapped(&[job], SchedulingPolicy::Fifo)[0].1;
            let p = PacketLevel::default().drain_overlapped(&[job], SchedulingPolicy::Fifo)[0].1;
            assert!((p - f).abs() < 1e-6 * f, "chunks={chunks}: packet={p} flow={f}");
            assert!((p - a).abs() < 1e-6 * a, "chunks={chunks}: packet={p} analytical={a}");
        }
    }

    #[test]
    fn blocking_collective_price_matches_flow_rung() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 64e6, 4);
        let p = PacketLevel::new(PacketLevelConfig::oversubscribed(4.0));
        let f = FlowLevel::new(FlowLevelConfig::oversubscribed(4.0));
        assert_eq!(p.collective_time_us(&c), f.collective_time_us(&c));
        assert_eq!(p.phase_times_us(&c), f.phase_times_us(&c));
    }

    #[test]
    fn ecmp_collisions_never_speed_up_a_switch_drain() {
        // Switch-only span: 6 identical single-flow chains on one
        // dimension. Hashing them onto 4 equal-cost paths puts >= 2 on
        // some path (pigeonhole) at cap/4 each, so the split drain can
        // only be slower than the aggregate FIFO port.
        let topo = topo();
        let span = vec![(DimCost::from_dim(&topo.dims[1]), 1)];
        let algos = [CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 16e6, 1);
        let jobs: Vec<OverlapCall> =
            (0..6).map(|l| OverlapCall { layer: l, issue_us: 0.0, call: c }).collect();
        let aggregate = PacketLevel::default();
        let split = PacketLevel::new(PacketLevelConfig::default().with_ecmp_width(4));
        let last = |drain: Vec<(u64, f64)>| drain.iter().map(|(_, t)| *t).fold(0.0, f64::max);
        let agg = last(aggregate.drain_overlapped(&jobs, SchedulingPolicy::Fifo));
        let ecmp = last(split.drain_overlapped(&jobs, SchedulingPolicy::Fifo));
        assert!(ecmp >= agg - 1e-6 * agg, "ecmp={ecmp} aggregate={agg}");
    }

    #[test]
    fn traced_drain_matches_untraced_and_emits_queue_spans() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 16e6, 2);
        let jobs: Vec<OverlapCall> =
            (0..3).map(|l| OverlapCall { layer: l, issue_us: l as f64 * 5.0, call: c }).collect();
        let backend = PacketLevel::new(PacketLevelConfig::oversubscribed(4.0));
        let plain = backend.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        let rec = crate::obs::Recorder::new();
        let traced = backend.drain_overlapped_traced(&jobs, SchedulingPolicy::Fifo, &rec);
        assert_eq!(plain, traced, "tracing must not perturb completions");
        let spans = rec.spans();
        assert!(spans.iter().all(|s| s.pid == tracks::NET_PID));
        assert!(spans.iter().any(|s| s.tid >= tracks::NET_QUEUE_BASE), "no queue spans");
        assert!(
            spans
                .iter()
                .any(|s| s.tid >= tracks::NET_DIM_BASE && s.tid < tracks::NET_QUEUE_BASE),
            "no flow spans"
        );
    }

    #[test]
    fn cache_tag_tracks_every_packet_parameter() {
        let base = PacketLevel::default();
        let variants = [
            PacketLevel::new(PacketLevelConfig::oversubscribed(4.0)),
            PacketLevel::new(PacketLevelConfig { mtu_bytes: 1500.0, ..Default::default() }),
            PacketLevel::new(PacketLevelConfig { queue_depth: 8, ..Default::default() }),
            PacketLevel::new(PacketLevelConfig::default().with_ecmp_width(4)),
            PacketLevel::new(PacketLevelConfig::default().with_seed(99)),
            PacketLevel::new(PacketLevelConfig {
                max_packets_per_flow: 64,
                ..Default::default()
            }),
        ];
        for v in &variants {
            assert_ne!(base.cache_tag(), v.cache_tag(), "{:?}", v.config);
        }
        assert_eq!(base.cache_tag(), PacketLevel::default().cache_tag());
    }
}
