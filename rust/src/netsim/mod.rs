//! `netsim` — the congestion-aware, event-driven network backend with
//! pluggable simulation fidelity.
//!
//! The original simulator priced every collective with closed-form
//! alpha-beta costs: ideal per-dimension bandwidth, no contention. That
//! keeps the DSE hot path fast but makes congestion-driven design points
//! — oversubscribed switch fabrics, co-tenant traffic, concurrent
//! gradient collectives fighting for the same dimension — invisible to
//! the search. This module adds a fidelity ladder behind one trait:
//!
//! - [`engine`] — the discrete-event core: a monotonic clock over a
//!   binary-heap event queue with deterministic tie-breaking.
//! - [`flow`] — a flow-level network model: flows cross topology
//!   dimensions, share capacity max-min fairly ([`maxmin_rates`]), and
//!   progress is re-rated at every flow start/finish event
//!   ([`FlowSim`]). An opt-in chunk-precedence mode
//!   ([`FlowLevelConfig::with_chunk_precedence`]) admits each
//!   collective's chunks as a per-(job, dim) FIFO dependency DAG
//!   ([`ChunkFlowSpec`]) instead of a steady-state bottleneck tail.
//! - [`fabric`] — what congests: switch oversubscription and co-tenant
//!   background load ([`FlowLevelConfig`]).
//! - [`backend`] — the [`NetworkBackend`] trait with the first two
//!   rungs, [`Analytical`] and [`FlowLevel`], selected by
//!   [`FidelityMode`].
//! - [`packet`] — the third rung, [`PacketLevel`]: flows discretized
//!   into MTU-sized packets served by per-port FIFO queues, with
//!   seeded deterministic ECMP across equal-cost paths and incast
//!   serialization at receiver ports ([`PacketLevelConfig`]).
//! - [`calibrate`] — fit [`FlowLevelConfig`] oversubscription factors
//!   against packet-level drains ([`calibrate_flow_config`]), so the
//!   cheap fluid rung tracks the expensive queueing rung.
//! - [`traffic`] — replayable multi-tenant traffic: per-dimension
//!   utilization time series ([`TrafficTrace`]: seeded constant /
//!   diurnal / bursty generators, JSON replay) applied underneath any
//!   rung by the [`TrafficView`] wrapper, time-varyingly — the
//!   trace-driven generalization of `background_load`.
//!
//! Select a backend on the simulator:
//!
//! ```no_run
//! use cosmic::netsim::{FidelityMode, FlowLevel, FlowLevelConfig};
//! use cosmic::sim::Simulator;
//! use std::sync::Arc;
//!
//! // Cheap analytical screening (the default):
//! let screen = Simulator::new();
//! // Congestion-aware re-ranking on a 4:1 oversubscribed fabric:
//! let rerank = Simulator::new().with_backend(Arc::new(FlowLevel::new(
//!     FlowLevelConfig::oversubscribed(4.0),
//! )));
//! // Or just flip the fidelity rung with defaults:
//! let flow = Simulator::new().with_fidelity(FidelityMode::FlowLevel);
//! # let _ = (screen, rerank, flow);
//! ```
//!
//! The same choice is exposed to search agents as the PsA "Network
//! Fidelity" parameter (`psa::builders::with_fidelity_param`), so a DSE
//! run can screen candidates analytically and re-rank finalists under
//! flow-level contention (`Environment::evaluate_with`).

pub mod backend;
pub mod calibrate;
pub mod engine;
pub mod fabric;
pub mod flow;
pub mod packet;
pub mod traffic;

pub use backend::{
    serial_drain, serial_drain_detailed, Analytical, CollectiveCall, FidelityMode, FlowLevel,
    NetworkBackend, OverlapCall,
};
pub use calibrate::{calibrate_flow_config, CalibrationReport, CalibrationSample};
pub use engine::EventQueue;
pub use fabric::FlowLevelConfig;
pub use flow::{
    maxmin_rates, ChainResult, ChunkFlowSpec, ChunkSegment, FlowSegment, FlowSim, FlowSpec,
};
pub use packet::{
    ecmp_path, FlowSpan, PacketChainResult, PacketLevel, PacketLevelConfig, PacketSim,
    PacketTrace, PortWindow, ServedPacket,
};
pub use traffic::{TrafficSuite, TrafficTrace, TrafficView};
