//! Pluggable network backends: the simulation-fidelity ladder.
//!
//! [`NetworkBackend`] is the seam between the end-to-end simulator and
//! the network model. Three rungs ship today:
//!
//! - [`Analytical`] — the closed-form alpha-beta path: collectives see
//!   ideal per-dimension bandwidth, and overlappable gradient
//!   collectives drain serially through the LIFO/FIFO scheduler. This
//!   reproduces the original simulator's numbers bit for bit.
//! - [`FlowLevel`] — the congestion-aware rung: per-phase bandwidth is
//!   re-rated by the fabric's oversubscription/background load
//!   ([`FlowLevelConfig`]), and concurrent overlappable collectives are
//!   simulated as event-driven flow chains sharing each dimension's
//!   capacity max-min fairly ([`super::flow::FlowSim`]).
//! - [`super::packet::PacketLevel`] — the packet-level rung: flows are
//!   discretized into MTU-sized packets served by per-port FIFO queues
//!   with seeded ECMP hashing and incast serialization
//!   ([`super::packet`]).

use std::fmt;
use std::sync::Arc;

use super::fabric::FlowLevelConfig;
use super::flow::{ChunkFlowSpec, ChunkSegment, FlowSegment, FlowSim, FlowSpec};
use crate::collective::{
    compose_phases, phase_plan, ChunkSchedule, CollAlgo, CollectiveKind, MultiDimPolicy,
    SchedulingPolicy,
};
use crate::obs::{tracks, TraceSink};
use crate::topology::{DimCost, Topology};

/// Which network model rung to simulate with — the PsA "Network
/// Fidelity" knob (see `psa::builders::with_fidelity_param`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FidelityMode {
    /// Closed-form alpha-beta costs; fastest, congestion-blind.
    Analytical,
    /// Flow-level max-min contention; slower, congestion-aware.
    FlowLevel,
    /// Packet-level FIFO queueing with ECMP and incast; slowest,
    /// queueing-aware.
    Packet,
}

impl FidelityMode {
    pub const ALL: [FidelityMode; 3] =
        [FidelityMode::Analytical, FidelityMode::FlowLevel, FidelityMode::Packet];

    pub fn name(&self) -> &'static str {
        match self {
            FidelityMode::Analytical => "Analytical",
            FidelityMode::FlowLevel => "FlowLevel",
            FidelityMode::Packet => "Packet",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "analytical" | "analytic" => Some(FidelityMode::Analytical),
            "flowlevel" | "flow-level" | "flow" => Some(FidelityMode::FlowLevel),
            "packet" | "packetlevel" | "packet-level" => Some(FidelityMode::Packet),
            _ => None,
        }
    }

    /// The default backend instance for this rung.
    pub fn default_backend(&self) -> Arc<dyn NetworkBackend> {
        match self {
            FidelityMode::Analytical => Arc::new(Analytical),
            FidelityMode::FlowLevel => Arc::new(FlowLevel::default()),
            FidelityMode::Packet => Arc::new(super::packet::PacketLevel::default()),
        }
    }
}

impl fmt::Display for FidelityMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One multi-dimensional collective resolved against the topology: the
/// communicator's per-dimension extents (`span`, innermost first, each
/// with its topology dimension index) plus the collective-stack knobs.
#[derive(Debug, Clone, Copy)]
pub struct CollectiveCall<'a> {
    pub kind: CollectiveKind,
    pub policy: MultiDimPolicy,
    /// One algorithm per spanned dimension (same order as `span`).
    pub algos: &'a [CollAlgo],
    /// `(alpha/beta with the group extent as npus, topology dim index)`.
    pub span: &'a [(DimCost, usize)],
    pub topology: &'a Topology,
    /// Per-NPU payload bytes.
    pub bytes: f64,
    pub chunks: u32,
}

/// One overlappable collective competing for the network during the
/// gradient-sync drain.
#[derive(Debug, Clone, Copy)]
pub struct OverlapCall<'a> {
    /// Layer index (completion times are collapsed per layer).
    pub layer: u64,
    /// Absolute issue time (us).
    pub issue_us: f64,
    pub call: CollectiveCall<'a>,
}

/// The network model behind the simulator. Implementations must be
/// stateless with respect to a single `run` (they may be shared across
/// threads by a DSE sweep).
pub trait NetworkBackend: fmt::Debug + Send + Sync {
    fn name(&self) -> &'static str;

    fn fidelity(&self) -> FidelityMode;

    /// A stable fingerprint of every backend-side input to collective
    /// pricing *beyond* the call itself (fidelity rung, fabric
    /// congestion parameters...). Two backends with the same tag must
    /// price identical calls identically — this scopes the cross-
    /// evaluation collective-cost cache (`cosmic::dse::EvalCache`).
    fn cache_tag(&self) -> u64;

    /// True when [`NetworkBackend::drain_overlapped`] is equivalent to
    /// pricing each job independently via
    /// [`NetworkBackend::collective_time_us`] and draining the durations
    /// serially with [`serial_drain`]. The simulator uses this to route
    /// per-job durations through its cross-evaluation memo instead of
    /// re-walking alpha-beta costs inside every drain.
    fn drain_is_serial(&self) -> bool {
        false
    }

    /// Time (us) of one blocking multi-dimensional collective.
    fn collective_time_us(&self, call: &CollectiveCall<'_>) -> f64;

    /// Drain concurrently-issued overlappable collectives; returns
    /// `(layer, completion time)` pairs, one per distinct layer
    /// (completion is the max over the layer's collectives), sorted by
    /// layer.
    ///
    /// Every job must reference the *same* topology (one drain = one
    /// cluster's network); implementations may resolve the fabric from
    /// any one job.
    fn drain_overlapped(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
    ) -> Vec<(u64, f64)>;

    /// [`NetworkBackend::drain_overlapped`] that additionally emits
    /// per-dimension occupancy spans into `sink`. Implementations must
    /// return the exact completions `drain_overlapped` would (tracing
    /// is observation, never perturbation); the default drops the sink.
    fn drain_overlapped_traced(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
        _sink: &dyn TraceSink,
    ) -> Vec<(u64, f64)> {
        self.drain_overlapped(jobs, policy)
    }

    /// Tracing decomposition of one *chunk* of a blocking collective:
    /// `(topology dim index, duration us)` per phase, in schedule
    /// order. Purely descriptive — pricing goes through
    /// [`NetworkBackend::collective_time_us`]. The default reports no
    /// detail.
    fn phase_times_us(&self, _call: &CollectiveCall<'_>) -> Vec<(usize, f64)> {
        Vec::new()
    }

    /// A copy of this backend with co-tenant utilization `util[d]`
    /// (fraction of dimension `d`'s bandwidth, `0.0..1.0`) folded into
    /// its fabric — the hook `netsim::traffic::TrafficView` shapes
    /// fabric-backed rungs through. Returns `None` when the rung has no
    /// fabric to fold into (the view then degrades spans and topology
    /// directly, `FaultView`-style).
    fn with_dim_utilization(&self, _util: &[f64]) -> Option<Arc<dyn NetworkBackend>> {
        None
    }
}

/// Collapse per-job completions into per-layer maxima, sorted by layer.
pub(crate) fn collapse_per_layer(pairs: impl IntoIterator<Item = (u64, f64)>) -> Vec<(u64, f64)> {
    let mut out: Vec<(u64, f64)> = Vec::new();
    for (layer, t) in pairs {
        match out.iter_mut().find(|(l, _)| *l == layer) {
            Some((_, e)) => {
                if t > *e {
                    *e = t;
                }
            }
            None => out.push((layer, t)),
        }
    }
    out.sort_by_key(|(l, _)| *l);
    out
}

/// Serial drain of jobs on one network resource: jobs arrive at their
/// issue times; whenever the resource frees, the scheduler picks the
/// next pending job per the policy (the original simulator's model).
///
/// Implemented as a sorted sweep over arrival times rather than a
/// general event heap: with one serial resource the next event is
/// always either the next arrival or the current job's completion.
pub fn serial_drain(
    jobs: &[(u64, f64, f64)], // (layer, issue_us, duration_us)
    policy: SchedulingPolicy,
) -> Vec<(u64, f64)> {
    collapse_per_layer(serial_drain_detailed(jobs, policy).into_iter().map(|(l, _, f)| (l, f)))
}

/// The sweep behind [`serial_drain`], returning every job's busy window
/// as `(layer, admission time, completion time)` in completion order —
/// the per-job detail the trace exporter draws as drain spans.
pub fn serial_drain_detailed(
    jobs: &[(u64, f64, f64)], // (layer, issue_us, duration_us)
    policy: SchedulingPolicy,
) -> Vec<(u64, f64, f64)> {
    let mut order: Vec<usize> = (0..jobs.len()).collect();
    order.sort_by(|&a, &b| jobs[a].1.partial_cmp(&jobs[b].1).unwrap());
    let mut pending: Vec<usize> = Vec::with_capacity(jobs.len());
    let mut done: Vec<(u64, f64, f64)> = Vec::with_capacity(jobs.len());
    let mut next_arrival = 0usize;
    let mut now;
    let mut busy_until = f64::NEG_INFINITY;
    let mut current: Option<(usize, f64)> = None; // (job, admission time)
    loop {
        // Advance to the next event: arrival or resource-free.
        let arrival_t = order.get(next_arrival).map(|&i| jobs[i].1.max(0.0));
        let free_t = current.map(|_| busy_until);
        now = match (arrival_t, free_t) {
            (Some(a), Some(f)) if a < f => {
                pending.push(order[next_arrival]);
                next_arrival += 1;
                a
            }
            (_, Some(f)) => {
                if let Some((i, start)) = current.take() {
                    done.push((jobs[i].0, start, f));
                }
                f
            }
            (Some(a), None) => {
                pending.push(order[next_arrival]);
                next_arrival += 1;
                a
            }
            (None, None) => break,
        };
        if current.is_none() && !pending.is_empty() {
            let idx = match policy {
                SchedulingPolicy::Fifo => 0,
                SchedulingPolicy::Lifo => pending.len() - 1,
            };
            let i = pending.remove(idx);
            current = Some((i, now));
            busy_until = now + jobs[i].2.max(0.0);
        }
    }
    done
}

/// The closed-form alpha-beta backend (the original simulator path).
#[derive(Debug, Clone, Copy, Default)]
pub struct Analytical;

thread_local! {
    // Scratch for projecting a span's DimCosts out of the (cost, dim)
    // pairs without a per-call allocation.
    static SPAN_DIMS: std::cell::RefCell<Vec<DimCost>> = std::cell::RefCell::new(Vec::new());
}

impl Analytical {
    fn call_time_us(call: &CollectiveCall<'_>) -> f64 {
        SPAN_DIMS.with(|buf| {
            let mut dims = buf.borrow_mut();
            dims.clear();
            dims.extend(call.span.iter().map(|(c, _)| *c));
            crate::collective::multidim_collective_time_us(
                call.kind,
                call.policy,
                call.algos,
                &dims,
                call.bytes,
                call.chunks,
            )
        })
    }
}

impl NetworkBackend for Analytical {
    fn name(&self) -> &'static str {
        "analytical"
    }

    fn fidelity(&self) -> FidelityMode {
        FidelityMode::Analytical
    }

    fn cache_tag(&self) -> u64 {
        // No backend-side state: every Analytical instance prices alike.
        0xA7A1
    }

    fn drain_is_serial(&self) -> bool {
        true
    }

    fn collective_time_us(&self, call: &CollectiveCall<'_>) -> f64 {
        Self::call_time_us(call)
    }

    fn drain_overlapped(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
    ) -> Vec<(u64, f64)> {
        // Jobs repeat the same collective once per layer; memoize
        // durations across the drain. The key covers every input the
        // cost depends on: span identity (algos are built alongside the
        // span, so the pointer covers both), kind, bytes, chunking and
        // composition policy.
        type MemoKey = (CollectiveKind, u64, usize, u32, MultiDimPolicy);
        let mut memo: Vec<(MemoKey, f64)> = Vec::with_capacity(4);
        let mut duration = |call: &CollectiveCall<'_>| -> f64 {
            let key: MemoKey = (
                call.kind,
                call.bytes.to_bits(),
                call.span.as_ptr() as usize,
                call.chunks,
                call.policy,
            );
            for (k, d) in memo.iter() {
                if *k == key {
                    return *d;
                }
            }
            let d = Self::call_time_us(call);
            memo.push((key, d));
            d
        };
        let tuples: Vec<(u64, f64, f64)> =
            jobs.iter().map(|j| (j.layer, j.issue_us, duration(&j.call))).collect();
        serial_drain(&tuples, policy)
    }

    fn phase_times_us(&self, call: &CollectiveCall<'_>) -> Vec<(usize, f64)> {
        if call.span.is_empty() || call.bytes <= 0.0 {
            return Vec::new();
        }
        let dims: Vec<DimCost> = call.span.iter().map(|(c, _)| *c).collect();
        phase_plan(call.kind, call.algos, &dims, call.bytes / call.chunks.max(1) as f64)
            .iter()
            .map(|p| (call.span[p.span_dim].1, p.duration_us(&dims[p.span_dim])))
            .collect()
    }
}

/// The congestion-aware flow-level backend.
///
/// Blocking collectives reuse the analytical phase schedule with each
/// phase's bandwidth term re-rated by the fabric's effective capacity
/// (oversubscription + background load) — identical to [`Analytical`]
/// when the fabric is uncongested. Overlappable gradient collectives are
/// simulated as concurrent flow chains (one flow per phase, plus a
/// steady-state chunk tail on the bottleneck phase) sharing each
/// dimension's capacity max-min fairly, so contention between layers'
/// gradient syncs — invisible to the serial analytical drain — shapes
/// the exposed tail.
///
/// With [`FlowLevelConfig::with_chunk_precedence`] enabled, the drain
/// models every chunk's every phase as its own flow in a per-(job, dim)
/// FIFO precedence DAG instead of collapsing the pipeline into a
/// steady-state tail: max-min shares are re-solved at each chunk
/// completion, so concurrent collectives' chunks interleave on shared
/// links. Off (the default) is bit-identical to the historical model.
#[derive(Debug, Clone, Default)]
pub struct FlowLevel {
    pub config: FlowLevelConfig,
}

impl FlowLevel {
    pub fn new(config: FlowLevelConfig) -> Self {
        // One validation path for every construction route: a struct-
        // literal fabric with NaN or sub-1 oversubscription is repaired
        // here, not at each read site. Identity on valid configs.
        Self { config: config.sanitized() }
    }

    /// The per-chunk phase schedule of one collective (the analytical
    /// plan — congestion does not change *what* is sent, only how fast).
    fn chunk_plan(call: &CollectiveCall<'_>) -> Vec<crate::collective::PhaseSpec> {
        let dims: Vec<DimCost> = call.span.iter().map(|(c, _)| *c).collect();
        phase_plan(call.kind, call.algos, &dims, call.bytes / call.chunks.max(1) as f64)
    }

    /// Duration of one phase at the congested rate of its dimension.
    fn congested_time(&self, call: &CollectiveCall<'_>, p: &crate::collective::PhaseSpec) -> f64 {
        let (cost, topo_dim) = call.span[p.span_dim];
        let rate = self.config.effective_rate(
            cost.beta_bytes_per_us,
            call.topology.dims[topo_dim].kind,
            topo_dim,
        );
        if p.wire_bytes > 0.0 { p.alpha_us + p.wire_bytes / rate } else { p.alpha_us }
    }

    /// Build the flow chain of one overlappable collective: one flow per
    /// phase of the first chunk, then a tail flow on the bottleneck
    /// phase carrying the remaining `chunks-1` pipelined pieces — alone
    /// on the fabric this reproduces the Baseline pipeline makespan
    /// exactly. The packet rung reuses the same chains (it discretizes
    /// *how* the bytes move, not *what* is sent).
    pub(crate) fn chain_of(&self, call: &CollectiveCall<'_>) -> Vec<FlowSpec> {
        let chunks = call.chunks.max(1);
        let plan = Self::chunk_plan(call);
        let mut specs: Vec<FlowSpec> = plan
            .iter()
            .map(|p| FlowSpec {
                uses: vec![call.span[p.span_dim].1],
                bytes: p.wire_bytes,
                latency_us: p.alpha_us,
            })
            .collect();
        if chunks > 1 && !plan.is_empty() {
            let (bi, _) = plan
                .iter()
                .map(|p| self.congested_time(call, p))
                .enumerate()
                .fold((0, f64::NEG_INFINITY), |acc, (i, t)| if t > acc.1 { (i, t) } else { acc });
            specs.push(FlowSpec {
                uses: vec![call.span[plan[bi].span_dim].1],
                bytes: (chunks - 1) as f64 * plan[bi].wire_bytes,
                latency_us: (chunks - 1) as f64 * plan[bi].alpha_us,
            });
        }
        specs
    }

    /// Build the chunk-precedence flow graph of one overlappable
    /// collective: every chunk's every phase becomes its own flow, wired
    /// into the [`ChunkSchedule`] dependency DAG (chunk FIFO within each
    /// phase, plus the policy's cross-phase edges). Flow `k * plan.len()
    /// + p` is chunk `k`, phase `p`. Alone on the fabric the graph's
    /// makespan equals the [`compose_phases`] closed form exactly — see
    /// `ChunkSchedule`'s recurrence proof — so the uncontended price
    /// still matches [`NetworkBackend::collective_time_us`].
    fn chunked_job_of(&self, call: &CollectiveCall<'_>) -> Vec<ChunkFlowSpec> {
        let chunks = call.chunks.max(1);
        let plan = Self::chunk_plan(call);
        if plan.is_empty() {
            return Vec::new();
        }
        let durations: Vec<f64> = plan.iter().map(|p| self.congested_time(call, p)).collect();
        let sched = ChunkSchedule::new(call.policy, &durations);
        let np = plan.len();
        let mut flows = Vec::with_capacity(np * chunks as usize);
        for k in 0..chunks {
            for (p, phase) in plan.iter().enumerate() {
                let mut deps = Vec::new();
                sched.deps(k, p, |dk, dp| deps.push(dk as usize * np + dp));
                flows.push(ChunkFlowSpec {
                    chunk: k,
                    phase: p,
                    dim: call.span[phase.span_dim].1,
                    bytes: phase.wire_bytes,
                    latency_us: phase.alpha_us,
                    deps,
                });
            }
        }
        flows
    }
}

impl NetworkBackend for FlowLevel {
    fn name(&self) -> &'static str {
        "flow-level"
    }

    fn fidelity(&self) -> FidelityMode {
        FidelityMode::FlowLevel
    }

    fn cache_tag(&self) -> u64 {
        // Pricing depends on the fabric's congestion parameters: fold
        // them into the tag so differently-configured flow backends
        // never share cross-evaluation cache entries.
        use std::hash::Hash;
        crate::util::hash64(|h| {
            0xF10Du64.hash(h);
            self.config.switch_oversubscription.to_bits().hash(h);
            self.config.background_load.to_bits().hash(h);
            self.config
                .per_dim_oversubscription
                .as_ref()
                .map(|v| v.iter().map(|f| f.to_bits()).collect::<Vec<u64>>())
                .hash(h);
            self.config
                .per_dim_background
                .as_ref()
                .map(|v| v.iter().map(|f| f.to_bits()).collect::<Vec<u64>>())
                .hash(h);
            // Chunk-precedence drains price overlap differently from the
            // steady-state model; the two modes must never share
            // memoized collective costs.
            self.config.chunk_precedence.hash(h);
        })
    }

    fn with_dim_utilization(&self, util: &[f64]) -> Option<Arc<dyn NetworkBackend>> {
        Some(Arc::new(FlowLevel::new(self.config.clone().with_dim_background(util))))
    }

    fn collective_time_us(&self, call: &CollectiveCall<'_>) -> f64 {
        if call.span.is_empty() || call.bytes <= 0.0 {
            return 0.0;
        }
        let phases: Vec<f64> =
            Self::chunk_plan(call).iter().map(|p| self.congested_time(call, p)).collect();
        compose_phases(call.policy, &phases, call.chunks)
    }

    fn drain_overlapped(
        &self,
        jobs: &[OverlapCall<'_>],
        _policy: SchedulingPolicy,
    ) -> Vec<(u64, f64)> {
        // In the flow-level model the network multiplexes: every pending
        // collective transmits at once at its max-min share, so the
        // LIFO/FIFO admission policy is moot.
        let Some(first) = jobs.first() else { return Vec::new() };
        let caps = self.config.dim_capacities(first.call.topology);
        if self.config.chunk_precedence {
            let cjobs: Vec<(f64, Vec<ChunkFlowSpec>)> = jobs
                .iter()
                .map(|j| (j.issue_us.max(0.0), self.chunked_job_of(&j.call)))
                .collect();
            let results = FlowSim::new(caps).run_chunked(&cjobs);
            return collapse_per_layer(
                jobs.iter().zip(results.iter()).map(|(j, r)| (j.layer, r.finish_us)),
            );
        }
        let chains: Vec<(f64, Vec<FlowSpec>)> = jobs
            .iter()
            .map(|j| (j.issue_us.max(0.0), self.chain_of(&j.call)))
            .collect();
        let results = FlowSim::new(caps).run(&chains);
        collapse_per_layer(
            jobs.iter().zip(results.iter()).map(|(j, r)| (j.layer, r.finish_us)),
        )
    }

    fn drain_overlapped_traced(
        &self,
        jobs: &[OverlapCall<'_>],
        _policy: SchedulingPolicy,
        sink: &dyn TraceSink,
    ) -> Vec<(u64, f64)> {
        let Some(first) = jobs.first() else { return Vec::new() };
        let caps = self.config.dim_capacities(first.call.topology);
        if self.config.chunk_precedence {
            let cjobs: Vec<(f64, Vec<ChunkFlowSpec>)> = jobs
                .iter()
                .map(|j| (j.issue_us.max(0.0), self.chunked_job_of(&j.call)))
                .collect();
            let mut segments: Vec<ChunkSegment> = Vec::new();
            let results = FlowSim::new(caps).run_chunked_recorded(&cjobs, &mut segments);
            if sink.enabled() {
                for seg in &segments {
                    let layer = jobs[seg.job].layer;
                    sink.span(
                        tracks::net_dim(seg.dim),
                        &format!("grad L{layer} c{} p{}", seg.chunk, seg.phase),
                        seg.start_us,
                        seg.finish_us,
                    );
                }
            }
            return collapse_per_layer(
                jobs.iter().zip(results.iter()).map(|(j, r)| (j.layer, r.finish_us)),
            );
        }
        let chains: Vec<(f64, Vec<FlowSpec>)> = jobs
            .iter()
            .map(|j| (j.issue_us.max(0.0), self.chain_of(&j.call)))
            .collect();
        let mut segments: Vec<FlowSegment> = Vec::new();
        let results = FlowSim::new(caps).run_recorded(&chains, &mut segments);
        if sink.enabled() {
            for seg in &segments {
                let layer = jobs[seg.chain].layer;
                for &dim in &seg.uses {
                    sink.span(
                        tracks::net_dim(dim),
                        &format!("grad L{layer} flow {}", seg.flow),
                        seg.start_us,
                        seg.finish_us,
                    );
                }
            }
        }
        collapse_per_layer(
            jobs.iter().zip(results.iter()).map(|(j, r)| (j.layer, r.finish_us)),
        )
    }

    fn phase_times_us(&self, call: &CollectiveCall<'_>) -> Vec<(usize, f64)> {
        if call.span.is_empty() || call.bytes <= 0.0 {
            return Vec::new();
        }
        Self::chunk_plan(call)
            .iter()
            .map(|p| (call.span[p.span_dim].1, self.congested_time(call, p)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::DimKind;

    fn topo() -> Topology {
        Topology::from_arrays(
            &[DimKind::Ring, DimKind::Switch],
            &[4, 8],
            &[200.0, 100.0],
            &[0.5, 1.0],
        )
    }

    fn span_of(topo: &Topology) -> Vec<(DimCost, usize)> {
        topo.dims
            .iter()
            .enumerate()
            .map(|(d, nd)| (DimCost::from_dim(nd), d))
            .collect()
    }

    fn call<'a>(
        topo: &'a Topology,
        span: &'a [(DimCost, usize)],
        algos: &'a [CollAlgo],
        bytes: f64,
        chunks: u32,
    ) -> CollectiveCall<'a> {
        CollectiveCall {
            kind: CollectiveKind::AllReduce,
            policy: MultiDimPolicy::Baseline,
            algos,
            span,
            topology: topo,
            bytes,
            chunks,
        }
    }

    #[test]
    fn uncongested_flow_level_equals_analytical() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let flow = FlowLevel::default();
        for chunks in [1u32, 2, 8] {
            let c = call(&topo, &span, &algos, 64e6, chunks);
            let a = Analytical.collective_time_us(&c);
            let f = flow.collective_time_us(&c);
            assert!((a - f).abs() < 1e-6 * a.max(1.0), "chunks={chunks}: {a} vs {f}");
        }
    }

    #[test]
    fn oversubscription_strictly_slows_switch_collectives() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 64e6, 4);
        let fair = FlowLevel::default().collective_time_us(&c);
        let congested = FlowLevel::new(FlowLevelConfig::oversubscribed(4.0))
            .collective_time_us(&c);
        assert!(congested > fair * 1.01, "congested={congested} fair={fair}");
    }

    #[test]
    fn background_load_slows_every_dim() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Ring];
        let c = call(&topo, &span, &algos, 64e6, 2);
        let idle = FlowLevel::default().collective_time_us(&c);
        let busy = FlowLevel::new(FlowLevelConfig::default().with_background_load(0.5))
            .collective_time_us(&c);
        assert!(busy > idle * 1.2, "busy={busy} idle={idle}");
    }

    #[test]
    fn single_job_drain_matches_serial_drain_uncongested() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 16e6, 1);
        let job = OverlapCall { layer: 0, issue_us: 10.0, call: c };
        let serial = Analytical.drain_overlapped(&[job], SchedulingPolicy::Fifo);
        let flow = FlowLevel::default().drain_overlapped(&[job], SchedulingPolicy::Fifo);
        assert_eq!(serial.len(), 1);
        assert_eq!(flow.len(), 1);
        assert!(
            (serial[0].1 - flow[0].1).abs() < 1e-6 * serial[0].1,
            "serial={} flow={}",
            serial[0].1,
            flow[0].1
        );
    }

    #[test]
    fn concurrent_jobs_finish_no_earlier_than_alone() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 16e6, 1);
        let flow = FlowLevel::default();
        let job0 = OverlapCall { layer: 0, issue_us: 0.0, call: c };
        let alone = flow.drain_overlapped(&[job0], SchedulingPolicy::Fifo);
        let jobs: Vec<OverlapCall> = (0..4)
            .map(|l| OverlapCall { layer: l, issue_us: 0.0, call: c })
            .collect();
        let together = flow.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        let last = together.iter().map(|(_, t)| *t).fold(0.0, f64::max);
        assert!(last >= alone[0].1 - 1e-9, "last={last} alone={}", alone[0].1);
    }

    #[test]
    fn serial_drain_fifo_vs_lifo_order() {
        let jobs = vec![(3u64, 0.0, 10.0), (2, 1.0, 10.0), (1, 2.0, 10.0)];
        let fifo = serial_drain(&jobs, SchedulingPolicy::Fifo);
        // FIFO: layer 3 done at 10, layer 2 at 20, layer 1 at 30.
        assert_eq!(fifo, vec![(1, 30.0), (2, 20.0), (3, 10.0)]);
        let lifo = serial_drain(&jobs, SchedulingPolicy::Lifo);
        // LIFO: 3 starts immediately (resource idle), then newest: 1, 2.
        assert_eq!(lifo, vec![(1, 20.0), (2, 30.0), (3, 10.0)]);
    }

    #[test]
    fn detailed_serial_drain_collapses_to_serial_drain() {
        let jobs = vec![(3u64, 0.0, 10.0), (2, 1.0, 10.0), (1, 2.0, 10.0), (1, 2.5, 4.0)];
        for policy in [SchedulingPolicy::Fifo, SchedulingPolicy::Lifo] {
            let detailed = serial_drain_detailed(&jobs, policy);
            assert_eq!(detailed.len(), jobs.len());
            for &(_, start, finish) in &detailed {
                assert!(start <= finish);
            }
            let collapsed =
                collapse_per_layer(detailed.into_iter().map(|(l, _, f)| (l, f)));
            assert_eq!(collapsed, serial_drain(&jobs, policy));
        }
    }

    #[test]
    fn traced_drain_matches_untraced_and_emits_dim_spans() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 16e6, 2);
        let jobs: Vec<OverlapCall> = (0..3)
            .map(|l| OverlapCall { layer: l, issue_us: l as f64 * 5.0, call: c })
            .collect();
        let flow = FlowLevel::new(FlowLevelConfig::oversubscribed(4.0));
        let plain = flow.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        let rec = crate::obs::Recorder::new();
        let traced = flow.drain_overlapped_traced(&jobs, SchedulingPolicy::Fifo, &rec);
        assert_eq!(plain, traced, "tracing must not perturb completions");
        let spans = rec.spans();
        assert!(!spans.is_empty());
        assert!(spans.iter().all(|s| s.pid == tracks::NET_PID));
        assert!(spans.iter().all(|s| s.tid >= tracks::NET_DIM_BASE));
    }

    #[test]
    fn phase_times_sum_to_baseline_single_chunk_cost() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 64e6, 1);
        for backend in [&Analytical as &dyn NetworkBackend, &FlowLevel::default()] {
            let phases = backend.phase_times_us(&c);
            assert!(!phases.is_empty());
            let sum: f64 = phases.iter().map(|(_, t)| t).sum();
            let total = backend.collective_time_us(&c);
            assert!((sum - total).abs() < 1e-6 * total.max(1.0), "{sum} vs {total}");
            for &(dim, _) in &phases {
                assert!(dim < topo.dims.len());
            }
        }
    }

    #[test]
    fn chunked_uncontended_drain_matches_closed_form() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        for policy in [MultiDimPolicy::Baseline, MultiDimPolicy::BlueConnect] {
            for chunks in [1u32, 2, 5, 16] {
                let mut c = call(&topo, &span, &algos, 64e6, chunks);
                c.policy = policy;
                for flow in [
                    FlowLevel::new(FlowLevelConfig::default().with_chunk_precedence(true)),
                    FlowLevel::new(
                        FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true),
                    ),
                ] {
                    let want = flow.collective_time_us(&c);
                    let job = OverlapCall { layer: 0, issue_us: 7.5, call: c };
                    let got = flow.drain_overlapped(&[job], SchedulingPolicy::Fifo);
                    assert_eq!(got.len(), 1);
                    let drained = got[0].1 - 7.5;
                    assert!(
                        (drained - want).abs() < 1e-6 * want.max(1.0),
                        "{policy:?} chunks={chunks}: drain={drained} closed={want}"
                    );
                }
            }
        }
    }

    #[test]
    fn chunk_precedence_folds_into_cache_tag() {
        let off = FlowLevel::default();
        let on = FlowLevel::new(FlowLevelConfig::default().with_chunk_precedence(true));
        assert_ne!(off.cache_tag(), on.cache_tag());
        let off4 = FlowLevel::new(FlowLevelConfig::oversubscribed(4.0));
        let on4 =
            FlowLevel::new(FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true));
        assert_ne!(off4.cache_tag(), on4.cache_tag());
        assert_ne!(on.cache_tag(), on4.cache_tag());
    }

    #[test]
    fn chunked_concurrent_jobs_finish_no_earlier_than_alone() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 16e6, 4);
        let flow =
            FlowLevel::new(FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true));
        let job0 = OverlapCall { layer: 0, issue_us: 0.0, call: c };
        let alone = flow.drain_overlapped(&[job0], SchedulingPolicy::Fifo);
        let jobs: Vec<OverlapCall> = (0..4)
            .map(|l| OverlapCall { layer: l, issue_us: 0.0, call: c })
            .collect();
        let together = flow.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        let last = together.iter().map(|(_, t)| *t).fold(0.0, f64::max);
        assert!(last >= alone[0].1 - 1e-9, "last={last} alone={}", alone[0].1);
    }

    #[test]
    fn chunked_traced_drain_matches_untraced_and_labels_chunks() {
        let topo = topo();
        let span = span_of(&topo);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&topo, &span, &algos, 16e6, 3);
        let jobs: Vec<OverlapCall> = (0..3)
            .map(|l| OverlapCall { layer: l, issue_us: l as f64 * 5.0, call: c })
            .collect();
        let flow =
            FlowLevel::new(FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true));
        let plain = flow.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        let rec = crate::obs::Recorder::new();
        let traced = flow.drain_overlapped_traced(&jobs, SchedulingPolicy::Fifo, &rec);
        assert_eq!(plain, traced, "tracing must not perturb completions");
        let spans = rec.spans();
        assert!(spans.len() >= 9, "expected per-chunk spans, got {}", spans.len());
        assert!(spans.iter().all(|s| s.pid == tracks::NET_PID));
        assert!(spans.iter().all(|s| s.tid >= tracks::NET_DIM_BASE));
        assert!(spans.iter().any(|s| s.name.contains("c2 p")), "chunk labels missing");
    }

    #[test]
    fn fidelity_mode_roundtrips() {
        for m in FidelityMode::ALL {
            assert_eq!(FidelityMode::from_name(m.name()), Some(m));
            assert_eq!(m.default_backend().fidelity(), m);
        }
        assert_eq!(FidelityMode::from_name("bogus"), None);
    }
}
