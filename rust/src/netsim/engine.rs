//! Minimal discrete-event engine: the `netsim` simulation core.
//!
//! A binary-heap event queue over `(time, seq, event)` with a monotonic
//! sequence number for deterministic FIFO tie-breaking at equal
//! timestamps. Time is `f64` microseconds; NaN times are rejected.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at an absolute simulation time.
#[derive(Debug, Clone)]
struct Scheduled<E> {
    time_us: f64,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time_us == other.time_us && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}

impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap; ties broken by insertion order.
        other
            .time_us
            .partial_cmp(&self.time_us)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// The event queue.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Scheduled<E>>,
    now_us: f64,
    next_seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), now_us: 0.0, next_seq: 0 }
    }

    /// Current simulation time (time of the last popped event).
    pub fn now_us(&self) -> f64 {
        self.now_us
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute time `time_us`. Panics on NaN or on
    /// scheduling into the past (a logic error in the caller).
    pub fn schedule_at(&mut self, time_us: f64, event: E) {
        assert!(!time_us.is_nan(), "NaN event time");
        assert!(
            time_us >= self.now_us,
            "scheduling into the past: {time_us} < {}",
            self.now_us
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { time_us, seq, event });
    }

    /// Schedule `event` `delay_us` from now.
    pub fn schedule_in(&mut self, delay_us: f64, event: E) {
        self.schedule_at(self.now_us + delay_us.max(0.0), event);
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|s| {
            self.now_us = s.time_us;
            (s.time_us, s.event)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, 1);
        q.schedule_at(5.0, 2);
        q.schedule_at(5.0, 3);
        let order: Vec<i32> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_with_pops() {
        let mut q = EventQueue::new();
        q.schedule_in(10.0, ());
        assert_eq!(q.now_us(), 0.0);
        q.pop();
        assert_eq!(q.now_us(), 10.0);
        q.schedule_in(5.0, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 15.0);
    }

    #[test]
    #[should_panic(expected = "scheduling into the past")]
    fn rejects_past_scheduling() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, ());
        q.pop();
        q.schedule_at(5.0, ());
    }

    #[test]
    fn negative_delay_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule_in(-3.0, "x");
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 0.0);
    }

    #[test]
    fn len_and_empty() {
        let mut q: EventQueue<()> = EventQueue::new();
        assert!(q.is_empty());
        q.schedule_in(1.0, ());
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
