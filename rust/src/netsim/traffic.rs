//! Replayable multi-tenant traffic traces: time-varying co-tenant load
//! for every fidelity rung.
//!
//! The fabric model's `background_load` scalar (see
//! [`super::fabric::FlowLevelConfig`]) freezes co-tenant traffic at one
//! uniform fraction. Real shared clusters breathe: diurnal batch waves,
//! bursty co-located jobs, per-pod hot spots. [`TrafficTrace`] captures
//! that as a per-dimension piecewise-constant utilization time series
//! (seeded generators or JSON replay), and [`TrafficView`] applies it
//! underneath any [`NetworkBackend`] rung, mirroring
//! `faults::FaultView`'s wrapper pattern:
//!
//! - **Fabric-backed rungs** (flow level, packet level) are rebuilt with
//!   the utilization folded into the fabric's per-dimension background
//!   channel ([`NetworkBackend::with_dim_utilization`]), so capacity
//!   scaling takes the exact same arithmetic path as
//!   `with_background_load` — a *uniform constant* trace reproduces the
//!   scalar background results bit for bit.
//! - **Fabric-less rungs** (analytical, or anything already wrapped in a
//!   `FaultView`) are degraded FaultView-style: span bandwidth terms and
//!   the topology's link rates scale by `1 - u`, with the same floating-
//!   point expressions `LinkFaults` bandwidth factors would use.
//!
//! Time-variation enters through *which window* is averaged: blocking
//! collectives (issued throughout the iteration) price against the
//! trace's period-mean utilization, while the overlappable gradient
//! drain refines in two passes — a period-mean pre-pass estimates the
//! drain window, then the final drain prices against the utilization
//! actually seen in `[first issue, estimated finish]`. For a constant
//! trace both windows average to the same bits, so the refinement is
//! exact there by construction.
//!
//! Wrapping is skipped entirely for nominal (all-zero) traces — the
//! no-traffic path stays bit-identical to the pre-traffic simulator,
//! hard-gated in `benches/eval_throughput.rs`.

use std::hash::Hash;
use std::sync::Arc;

use super::backend::{CollectiveCall, FidelityMode, NetworkBackend, OverlapCall};
use crate::collective::SchedulingPolicy;
use crate::obs::TraceSink;
use crate::topology::{DimCost, Topology};
use crate::util::{hash64, Rng};

/// Utilization ceiling: a co-tenant can never claim the full link (the
/// same 0.95 cap `FlowLevelConfig::background_load` clamps to).
pub const MAX_UTILIZATION: f64 = 0.95;

/// Seed salt mixed into every traffic generator, so a DSE seed and a
/// traffic seed of the same value do not correlate.
const TRAFFIC_SEED_SALT: u64 = 0x7AFC_5EED_0C0D_E077;

/// Suite member seeds: the same golden-ratio stride the fault-scenario
/// suites use.
const SUITE_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// A per-dimension piecewise-constant utilization time series. Each
/// dimension `d` holds samples `dims[d]`, each lasting `step_us`
/// microseconds, repeating periodically; `u(d, t)` is the fraction of
/// dimension `d`'s bandwidth consumed by co-tenant traffic at simulated
/// time `t`. Dimensions beyond `dims.len()` are idle (0.0).
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficTrace {
    /// Display label ("constant", "diurnal", "bursty", "replay", ...).
    profile: String,
    /// Duration of one sample (us).
    step_us: f64,
    /// Per-dimension utilization samples in `[0, MAX_UTILIZATION]`.
    dims: Vec<Vec<f64>>,
}

impl TrafficTrace {
    /// Build a trace from raw samples. Samples must be finite and in
    /// `[0, 1]`; values above [`MAX_UTILIZATION`] are clamped to it
    /// (a co-tenant cannot own the whole link), `step_us` must be a
    /// positive finite duration.
    pub fn new(profile: &str, step_us: f64, dims: Vec<Vec<f64>>) -> Result<Self, String> {
        if !step_us.is_finite() || step_us <= 0.0 {
            return Err(format!("traffic step_us must be positive and finite, got {step_us}"));
        }
        let mut clamped = dims;
        for (d, series) in clamped.iter_mut().enumerate() {
            for v in series.iter_mut() {
                if !v.is_finite() || *v < 0.0 || *v > 1.0 {
                    return Err(format!(
                        "traffic utilization for dim {d} must be finite and in [0, 1], got {v}"
                    ));
                }
                if *v > MAX_UTILIZATION {
                    *v = MAX_UTILIZATION;
                }
            }
        }
        Ok(Self { profile: profile.to_string(), step_us, dims: clamped })
    }

    /// The idle trace: no co-tenant traffic anywhere. Attaching it is a
    /// no-op ([`TrafficView::wrap`] skips the wrapper entirely).
    pub fn nominal() -> Self {
        Self { profile: "nominal".to_string(), step_us: 1.0, dims: Vec::new() }
    }

    /// A uniform trace: every dimension pinned at `util` forever — the
    /// exact analogue of `FlowLevelConfig::with_background_load(util)`.
    pub fn uniform(dims: usize, util: f64) -> Self {
        let u = util.clamp(0.0, MAX_UTILIZATION);
        Self {
            profile: "constant".to_string(),
            step_us: 1000.0,
            dims: vec![vec![u]; dims],
        }
    }

    /// Seeded constant profile: each dimension holds a flat level drawn
    /// from `[0.15, 0.65)`.
    pub fn constant(seed: u64, dims: usize) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ TRAFFIC_SEED_SALT);
        let series = (0..dims).map(|_| vec![0.15 + 0.5 * rng.gen_f64()]).collect();
        Self { profile: "constant".to_string(), step_us: 1000.0, dims: series }
    }

    /// Seeded diurnal profile: a sinusoidal day (24 bins of 50 ms of
    /// simulated time each) with per-dimension base, amplitude and
    /// phase.
    pub fn diurnal(seed: u64, dims: usize) -> Self {
        const BINS: usize = 24;
        let mut rng = Rng::seed_from_u64(seed ^ TRAFFIC_SEED_SALT);
        let series = (0..dims)
            .map(|_| {
                let base = 0.10 + 0.25 * rng.gen_f64();
                let amp = 0.10 + 0.35 * rng.gen_f64();
                let phase = rng.gen_f64() * std::f64::consts::TAU;
                (0..BINS)
                    .map(|k| {
                        let x = k as f64 / BINS as f64 * std::f64::consts::TAU + phase;
                        (base + amp * 0.5 * (1.0 + x.sin())).clamp(0.0, MAX_UTILIZATION)
                    })
                    .collect()
            })
            .collect();
        Self { profile: "diurnal".to_string(), step_us: 50_000.0, dims: series }
    }

    /// Seeded bursty profile: a two-state on/off Markov chain per
    /// dimension (64 bins of 10 ms), idle floor vs burst ceiling.
    pub fn bursty(seed: u64, dims: usize) -> Self {
        const BINS: usize = 64;
        let mut rng = Rng::seed_from_u64(seed ^ TRAFFIC_SEED_SALT);
        let series = (0..dims)
            .map(|_| {
                let p_on = 0.15 + 0.20 * rng.gen_f64();
                let p_off = 0.25 + 0.30 * rng.gen_f64();
                let high = (0.50 + 0.45 * rng.gen_f64()).clamp(0.0, MAX_UTILIZATION);
                let low = 0.05 * rng.gen_f64();
                let mut on = rng.gen_bool(0.5);
                (0..BINS)
                    .map(|_| {
                        let flip = if on { p_off } else { p_on };
                        if rng.gen_bool(flip) {
                            on = !on;
                        }
                        if on {
                            high
                        } else {
                            low
                        }
                    })
                    .collect()
            })
            .collect();
        Self { profile: "bursty".to_string(), step_us: 10_000.0, dims: series }
    }

    /// Build a named profile ("constant" | "diurnal" | "bursty" |
    /// "none") over `dims` topology dimensions.
    pub fn from_profile(profile: &str, seed: u64, dims: usize) -> Result<Self, String> {
        match profile.trim().to_ascii_lowercase().as_str() {
            "none" | "nominal" => Ok(Self::nominal()),
            "constant" => Ok(Self::constant(seed, dims)),
            "diurnal" => Ok(Self::diurnal(seed, dims)),
            "bursty" => Ok(Self::bursty(seed, dims)),
            other => Err(format!(
                "unknown traffic profile '{other}' (expected constant, diurnal, bursty or none)"
            )),
        }
    }

    /// Parse the replay format:
    /// `{"profile": "...", "step_us": 1000.0, "dims": [[0.1, 0.5], [0.0]]}`
    /// (`profile` optional, defaults to "replay"). Unknown keys are
    /// rejected so a typo'd trace file errors instead of silently
    /// replaying nothing.
    pub fn from_json(text: &str) -> Result<Self, String> {
        crate::util::json::validate(text).map_err(|e| format!("traffic trace: invalid JSON: {e}"))?;
        let mut p = JsonScan { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        p.expect(b'{')?;
        let mut profile: Option<String> = None;
        let mut step_us: Option<f64> = None;
        let mut dims: Option<Vec<Vec<f64>>> = None;
        loop {
            p.skip_ws();
            if p.eat(b'}') {
                break;
            }
            let key = p.string()?;
            p.skip_ws();
            p.expect(b':')?;
            p.skip_ws();
            match key.as_str() {
                "profile" => profile = Some(p.string()?),
                "step_us" => step_us = Some(p.number()?),
                "dims" => {
                    let mut outer = Vec::new();
                    p.expect(b'[')?;
                    loop {
                        p.skip_ws();
                        if p.eat(b']') {
                            break;
                        }
                        let mut inner = Vec::new();
                        p.expect(b'[')?;
                        loop {
                            p.skip_ws();
                            if p.eat(b']') {
                                break;
                            }
                            inner.push(p.number()?);
                            p.skip_ws();
                            p.eat(b',');
                        }
                        outer.push(inner);
                        p.skip_ws();
                        p.eat(b',');
                    }
                    dims = Some(outer);
                }
                other => {
                    return Err(format!(
                        "traffic trace: unknown key \"{other}\" (expected profile, step_us, dims)"
                    ))
                }
            }
            p.skip_ws();
            p.eat(b',');
        }
        let step = step_us.ok_or("traffic trace: missing \"step_us\"")?;
        let series = dims.ok_or("traffic trace: missing \"dims\"")?;
        Self::new(profile.as_deref().unwrap_or("replay"), step, series)
    }

    /// Serialize in the [`TrafficTrace::from_json`] replay format.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{{\"profile\":\"{}\",\"step_us\":{},\"dims\":[",
            self.profile, self.step_us
        ));
        for (d, series) in self.dims.iter().enumerate() {
            if d > 0 {
                out.push(',');
            }
            out.push('[');
            for (i, v) in series.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("{v}"));
            }
            out.push(']');
        }
        out.push_str("]}");
        out
    }

    /// The display label of this trace's generator.
    pub fn profile(&self) -> &str {
        &self.profile
    }

    /// Sample duration (us).
    pub fn step_us(&self) -> f64 {
        self.step_us
    }

    /// Number of dimensions carrying samples.
    pub fn num_dims(&self) -> usize {
        self.dims.len()
    }

    /// True when no sample anywhere is non-zero — attaching this trace
    /// changes nothing, and [`TrafficView::wrap`] skips the wrapper.
    pub fn is_nominal(&self) -> bool {
        self.dims.iter().all(|s| s.iter().all(|&v| v == 0.0))
    }

    /// Stable fingerprint of the series; `0` for nominal traces (so the
    /// no-traffic and nominal-trace cache keys coincide, like the
    /// fault-scenario convention).
    pub fn fingerprint(&self) -> u64 {
        if self.is_nominal() {
            return 0;
        }
        hash64(|h| {
            0x7AFC_u64.hash(h);
            self.step_us.to_bits().hash(h);
            self.dims.len().hash(h);
            for series in &self.dims {
                series.len().hash(h);
                for v in series {
                    v.to_bits().hash(h);
                }
            }
        })
    }

    /// Utilization of dimension `dim` at absolute time `t_us`
    /// (periodic; dimensions without samples are idle).
    pub fn utilization_at(&self, dim: usize, t_us: f64) -> f64 {
        let Some(series) = self.dims.get(dim) else { return 0.0 };
        match series.len() {
            0 => 0.0,
            1 => series[0],
            n => {
                let period = self.step_us * n as f64;
                let mut x = t_us % period;
                if x < 0.0 {
                    x += period;
                }
                let idx = ((x / self.step_us) as usize).min(n - 1);
                series[idx]
            }
        }
    }

    /// Mean utilization of `dim` over `[t0, t1)`. Exact (the stored
    /// sample bits, no integration residue) whenever the dimension's
    /// series is constant — the property the uniform-trace ≡
    /// `background_load` bit-identity gate leans on.
    pub fn mean_utilization(&self, dim: usize, t0: f64, t1: f64) -> f64 {
        let Some(series) = self.dims.get(dim) else { return 0.0 };
        let n = series.len();
        if n == 0 {
            return 0.0;
        }
        let first = series[0];
        if series.iter().all(|v| v.to_bits() == first.to_bits()) {
            return first;
        }
        if !(t1 > t0) || !t0.is_finite() || !t1.is_finite() {
            return self.utilization_at(dim, t0);
        }
        let period = self.step_us * n as f64;
        let span = t1 - t0;
        let full = (span / period).floor();
        let mut total = 0.0;
        if full >= 1.0 {
            total += full * series.iter().sum::<f64>() * self.step_us;
        }
        let mut t = t0 + full * period;
        while t < t1 {
            let mut x = t % period;
            if x < 0.0 {
                x += period;
            }
            let idx = ((x / self.step_us) as usize).min(n - 1);
            let seg_left = (idx as f64 + 1.0) * self.step_us - x;
            let dt = seg_left.min(t1 - t);
            if dt <= 0.0 {
                break;
            }
            total += series[idx] * dt;
            t += dt;
        }
        (total / span).clamp(0.0, MAX_UTILIZATION)
    }

    /// Per-dimension mean utilization over `[t0, t1)`, one entry per
    /// trace dimension.
    pub fn window_means(&self, t0: f64, t1: f64) -> Vec<f64> {
        (0..self.dims.len()).map(|d| self.mean_utilization(d, t0, t1)).collect()
    }

    /// Per-dimension mean utilization over one full period — what
    /// blocking collectives price against.
    pub fn period_means(&self) -> Vec<f64> {
        (0..self.dims.len())
            .map(|d| {
                let n = self.dims[d].len();
                self.mean_utilization(d, 0.0, self.step_us * n.max(1) as f64)
            })
            .collect()
    }

    /// The busy segments of `dim` overlapping `[t0, t1)`, as
    /// `(start, end, utilization)`, capped at `max_segments` (for the
    /// trace exporter — a long iteration over a fine trace must not
    /// blow up the span file).
    pub fn segments_in(
        &self,
        dim: usize,
        t0: f64,
        t1: f64,
        max_segments: usize,
    ) -> Vec<(f64, f64, f64)> {
        let Some(series) = self.dims.get(dim) else { return Vec::new() };
        let n = series.len();
        if n == 0 || !(t1 > t0) {
            return Vec::new();
        }
        let period = self.step_us * n as f64;
        let mut out = Vec::new();
        let mut t = t0;
        while t < t1 && out.len() < max_segments {
            let mut x = t % period;
            if x < 0.0 {
                x += period;
            }
            let idx = ((x / self.step_us) as usize).min(n - 1);
            let seg_left = (idx as f64 + 1.0) * self.step_us - x;
            let dt = seg_left.min(t1 - t);
            if dt <= 0.0 {
                break;
            }
            out.push((t, t + dt, series[idx]));
            t += dt;
        }
        out
    }
}

/// Minimal scanner for the replay format (the document is pre-validated
/// by `util::json::validate`, so this only extracts values).
struct JsonScan<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl JsonScan<'_> {
    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> bool {
        if self.bytes.get(self.pos) == Some(&b) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.eat(b) {
            Ok(())
        } else {
            Err(format!("traffic trace: expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let start = self.pos;
        while let Some(&c) = self.bytes.get(self.pos) {
            if c == b'"' {
                let s = String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned();
                self.pos += 1;
                return Ok(s);
            }
            // Escapes are legal JSON but pointless in this format's keys
            // and profile names; reject rather than mis-parse.
            if c == b'\\' {
                return Err("traffic trace: escape sequences are not supported".to_string());
            }
            self.pos += 1;
        }
        Err("traffic trace: unterminated string".to_string())
    }

    fn number(&mut self) -> Result<f64, String> {
        let start = self.pos;
        while matches!(
            self.bytes.get(self.pos),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| "traffic trace: bad number".to_string())?;
        text.parse::<f64>()
            .map_err(|_| format!("traffic trace: bad number \"{text}\" at byte {start}"))
    }
}

/// A replayable set of traffic conditions: the nominal (idle) trace
/// first, then `k` seeded members of one profile — the traffic analogue
/// of `faults::ScenarioSuite`, composing with the same robust
/// `Expected`/`WorstCase` aggregation.
#[derive(Debug, Clone)]
pub struct TrafficSuite {
    pub traces: Vec<Arc<TrafficTrace>>,
}

impl TrafficSuite {
    /// Nominal + `k` seeded traces of `profile` over `dims` dimensions.
    pub fn generate(profile: &str, seed: u64, k: usize, dims: usize) -> Result<Self, String> {
        let mut traces = vec![Arc::new(TrafficTrace::nominal())];
        for i in 1..=k as u64 {
            traces.push(Arc::new(TrafficTrace::from_profile(
                profile,
                seed ^ i.wrapping_mul(SUITE_STRIDE),
                dims,
            )?));
        }
        Ok(Self { traces })
    }

    pub fn len(&self) -> usize {
        self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.traces.is_empty()
    }

    /// Stable fingerprint over the member traces.
    pub fn fingerprint(&self) -> u64 {
        hash64(|h| {
            0x7AFC_u64.hash(h);
            self.traces.len().hash(h);
            for t in &self.traces {
                t.fingerprint().hash(h);
            }
        })
    }
}

/// Traffic-shaping wrapper around an inner backend. Construct via
/// [`TrafficView::wrap`], which skips wrapping entirely for nominal
/// traces (zero cost and maximal cache sharing when nothing is busy).
#[derive(Debug)]
pub struct TrafficView {
    inner: Arc<dyn NetworkBackend>,
    trace: Arc<TrafficTrace>,
    /// `inner` rebuilt with the period-mean utilization folded into its
    /// fabric; `None` when the inner rung has no fabric hook (then the
    /// FaultView-style span/topology degradation path applies).
    shaped: Option<Arc<dyn NetworkBackend>>,
    /// Per-dimension mean utilization over one trace period.
    period_mean: Vec<f64>,
}

impl TrafficView {
    /// Wrap `inner` under `trace`; returns `inner` unchanged when the
    /// trace is nominal.
    pub fn wrap(inner: Arc<dyn NetworkBackend>, trace: Arc<TrafficTrace>) -> Arc<dyn NetworkBackend> {
        if trace.is_nominal() {
            return inner;
        }
        let period_mean = trace.period_means();
        let shaped = inner.with_dim_utilization(&period_mean);
        Arc::new(Self { inner, trace, shaped, period_mean })
    }

    /// The shaped inner backend for a utilization vector — the cached
    /// period-mean instance when the bits match, a fresh rebuild
    /// otherwise.
    fn shaped_at(&self, util: &[f64]) -> Option<Arc<dyn NetworkBackend>> {
        if util == self.period_mean.as_slice() {
            self.shaped.clone()
        } else {
            self.inner.with_dim_utilization(util)
        }
    }

    /// Bandwidth factor of dimension `d` under `util` — the same
    /// expression `LinkFaults::bw_factor` degradation multiplies by, so
    /// the fallback path prices bit-identically to an equivalent
    /// uniform link derate.
    fn bw_factor(util: &[f64], d: usize) -> f64 {
        1.0 - util.get(d).copied().unwrap_or(0.0).clamp(0.0, MAX_UTILIZATION)
    }

    fn degraded_topology(util: &[f64], topo: &Topology) -> Topology {
        let mut t = topo.clone();
        for (d, dim) in t.dims.iter_mut().enumerate() {
            dim.bandwidth_gbps *= Self::bw_factor(util, d);
        }
        t
    }

    fn degraded_span(util: &[f64], span: &[(DimCost, usize)]) -> Vec<(DimCost, usize)> {
        span.iter()
            .map(|&(c, d)| {
                (
                    DimCost {
                        alpha_us: c.alpha_us,
                        beta_bytes_per_us: c.beta_bytes_per_us * Self::bw_factor(util, d),
                        npus: c.npus,
                    },
                    d,
                )
            })
            .collect()
    }

    /// Price a blocking call at `util` via the shaped fabric when the
    /// inner rung has one, else by span/topology degradation.
    fn call_at(&self, util: &[f64], call: &CollectiveCall<'_>) -> f64 {
        if let Some(shaped) = self.shaped_at(util) {
            return shaped.collective_time_us(call);
        }
        let topo = Self::degraded_topology(util, call.topology);
        let span = Self::degraded_span(util, call.span);
        self.inner.collective_time_us(&CollectiveCall { span: &span, topology: &topo, ..*call })
    }

    /// Drain at a fixed utilization vector, optionally traced. The
    /// fallback path interns degraded spans by source-span pointer,
    /// like `FaultView`, so pointer-memoizing inner backends keep their
    /// hit rate.
    fn drain_at(
        &self,
        util: &[f64],
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
        sink: Option<&dyn TraceSink>,
    ) -> Vec<(u64, f64)> {
        let Some(first) = jobs.first() else {
            return Vec::new();
        };
        if let Some(shaped) = self.shaped_at(util) {
            return match sink {
                Some(s) => shaped.drain_overlapped_traced(jobs, policy, s),
                None => shaped.drain_overlapped(jobs, policy),
            };
        }
        let topo = Self::degraded_topology(util, first.call.topology);
        let mut spans: Vec<(*const (DimCost, usize), Vec<(DimCost, usize)>)> = Vec::new();
        for j in jobs {
            let p = j.call.span.as_ptr();
            if !spans.iter().any(|(q, _)| *q == p) {
                spans.push((p, Self::degraded_span(util, j.call.span)));
            }
        }
        let degraded: Vec<OverlapCall<'_>> = jobs
            .iter()
            .map(|j| {
                let p = j.call.span.as_ptr();
                let span = &spans.iter().find(|(q, _)| *q == p).expect("span interned").1;
                OverlapCall {
                    layer: j.layer,
                    issue_us: j.issue_us,
                    call: CollectiveCall { span, topology: &topo, ..j.call },
                }
            })
            .collect();
        match sink {
            Some(s) => self.inner.drain_overlapped_traced(&degraded, policy, s),
            None => self.inner.drain_overlapped(&degraded, policy),
        }
    }

    /// The utilization the drain actually prices against: a period-mean
    /// pre-pass estimates the drain window, then the window's own mean
    /// is used. Constant series short-circuit to the same bits either
    /// way, so the refinement never perturbs uniform traces.
    fn refined_util(&self, jobs: &[OverlapCall<'_>], policy: SchedulingPolicy) -> Vec<f64> {
        let pass1 = self.drain_at(&self.period_mean, jobs, policy, None);
        let t0 = jobs.iter().map(|j| j.issue_us.max(0.0)).fold(f64::INFINITY, f64::min);
        let t1 = pass1.iter().map(|(_, t)| *t).fold(f64::NEG_INFINITY, f64::max);
        if t0.is_finite() && t1 > t0 {
            self.trace.window_means(t0, t1)
        } else {
            self.period_mean.clone()
        }
    }
}

impl NetworkBackend for TrafficView {
    fn name(&self) -> &'static str {
        "traffic-view"
    }

    fn fidelity(&self) -> FidelityMode {
        self.inner.fidelity()
    }

    fn cache_tag(&self) -> u64 {
        hash64(|h| {
            0x7AFC_u64.hash(h);
            self.inner.cache_tag().hash(h);
            self.trace.fingerprint().hash(h);
        })
    }

    fn drain_is_serial(&self) -> bool {
        // Never serial: the view must see whole drains to refine the
        // utilization window (durations depend on *when* jobs run).
        false
    }

    fn collective_time_us(&self, call: &CollectiveCall<'_>) -> f64 {
        self.call_at(&self.period_mean, call)
    }

    fn drain_overlapped(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
    ) -> Vec<(u64, f64)> {
        let util = self.refined_util(jobs, policy);
        self.drain_at(&util, jobs, policy, None)
    }

    fn drain_overlapped_traced(
        &self,
        jobs: &[OverlapCall<'_>],
        policy: SchedulingPolicy,
        sink: &dyn TraceSink,
    ) -> Vec<(u64, f64)> {
        let util = self.refined_util(jobs, policy);
        self.drain_at(&util, jobs, policy, Some(sink))
    }

    fn phase_times_us(&self, call: &CollectiveCall<'_>) -> Vec<(usize, f64)> {
        if let Some(shaped) = self.shaped_at(&self.period_mean) {
            return shaped.phase_times_us(call);
        }
        let topo = Self::degraded_topology(&self.period_mean, call.topology);
        let span = Self::degraded_span(&self.period_mean, call.span);
        self.inner.phase_times_us(&CollectiveCall { span: &span, topology: &topo, ..*call })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::{CollAlgo, CollectiveKind, MultiDimPolicy};
    use crate::netsim::{Analytical, FlowLevel, FlowLevelConfig};
    use crate::topology::DimKind;

    fn topo() -> Topology {
        Topology::from_arrays(
            &[DimKind::Ring, DimKind::Switch],
            &[4, 8],
            &[200.0, 100.0],
            &[0.5, 1.0],
        )
    }

    fn span_of(t: &Topology) -> Vec<(DimCost, usize)> {
        t.dims.iter().enumerate().map(|(d, dim)| (DimCost::from_dim(dim), d)).collect()
    }

    fn call<'a>(
        t: &'a Topology,
        span: &'a [(DimCost, usize)],
        algos: &'a [CollAlgo],
    ) -> CollectiveCall<'a> {
        CollectiveCall {
            kind: CollectiveKind::AllReduce,
            policy: MultiDimPolicy::Baseline,
            algos,
            span,
            topology: t,
            bytes: 8.0e6,
            chunks: 4,
        }
    }

    #[test]
    fn generators_are_seed_deterministic() {
        for profile in ["constant", "diurnal", "bursty"] {
            let a = TrafficTrace::from_profile(profile, 42, 3).unwrap();
            let b = TrafficTrace::from_profile(profile, 42, 3).unwrap();
            assert_eq!(a, b, "{profile} must be reproducible from its seed");
            let c = TrafficTrace::from_profile(profile, 43, 3).unwrap();
            assert_ne!(a.fingerprint(), c.fingerprint(), "{profile} seeds must differ");
            assert!(!a.is_nominal());
        }
        assert!(TrafficTrace::from_profile("none", 1, 3).unwrap().is_nominal());
        assert!(TrafficTrace::from_profile("bogus", 1, 3).is_err());
    }

    #[test]
    fn samples_stay_in_range() {
        for profile in ["constant", "diurnal", "bursty"] {
            let t = TrafficTrace::from_profile(profile, 7, 4).unwrap();
            for d in 0..t.num_dims() {
                for (s, e, u) in t.segments_in(d, 0.0, t.step_us() * 200.0, 1000) {
                    assert!(s < e);
                    assert!((0.0..=MAX_UTILIZATION).contains(&u), "{profile}: {u}");
                }
            }
        }
    }

    #[test]
    fn uniform_mean_is_exact_over_any_window() {
        let t = TrafficTrace::uniform(2, 0.37);
        for (t0, t1) in [(0.0, 1.0), (123.4, 98765.4), (0.0, 1e9), (5.0, 5.0)] {
            assert_eq!(t.mean_utilization(0, t0, t1).to_bits(), 0.37f64.to_bits());
            assert_eq!(t.mean_utilization(1, t0, t1).to_bits(), 0.37f64.to_bits());
        }
        assert_eq!(t.mean_utilization(9, 0.0, 1.0), 0.0, "unsampled dims are idle");
    }

    #[test]
    fn mean_integrates_piecewise_series() {
        let t = TrafficTrace::new("replay", 10.0, vec![vec![0.2, 0.6]]).unwrap();
        // One full period: (0.2 + 0.6) / 2.
        assert!((t.mean_utilization(0, 0.0, 20.0) - 0.4).abs() < 1e-12);
        // First half of the first segment only.
        assert!((t.mean_utilization(0, 0.0, 5.0) - 0.2).abs() < 1e-12);
        // [5, 15): half of each segment.
        assert!((t.mean_utilization(0, 5.0, 15.0) - 0.4).abs() < 1e-12);
        // Many periods plus a remainder stay bounded and sane.
        let m = t.mean_utilization(0, 0.0, 2015.0);
        assert!(m > 0.2 && m < 0.6);
        assert_eq!(t.utilization_at(0, 25.0), 0.6);
        assert_eq!(t.utilization_at(0, 45.0), 0.2);
    }

    #[test]
    fn json_replay_round_trips() {
        let t = TrafficTrace::new("replay", 1000.0, vec![vec![0.1, 0.5], vec![0.0]]).unwrap();
        let parsed = TrafficTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(t, parsed);
        crate::util::json::validate(&t.to_json()).unwrap();
        assert!(TrafficTrace::from_json("{\"step_us\": 10}").is_err(), "dims required");
        assert!(TrafficTrace::from_json("{\"bogus\": 1}").is_err(), "unknown keys rejected");
        assert!(
            TrafficTrace::from_json("{\"step_us\": 10, \"dims\": [[1.5]]}").is_err(),
            "utilization beyond 1 rejected"
        );
        assert!(TrafficTrace::from_json("not json").is_err());
    }

    #[test]
    fn nominal_traces_skip_the_wrapper() {
        let inner: Arc<dyn NetworkBackend> = Arc::new(Analytical);
        let wrapped = TrafficView::wrap(Arc::clone(&inner), Arc::new(TrafficTrace::nominal()));
        assert_eq!(wrapped.cache_tag(), inner.cache_tag());
        assert_eq!(wrapped.name(), inner.name());
        assert_eq!(TrafficTrace::nominal().fingerprint(), 0);
        assert_eq!(TrafficTrace::uniform(3, 0.0).fingerprint(), 0);
    }

    #[test]
    fn busy_traces_never_price_faster() {
        let t = topo();
        let span = span_of(&t);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&t, &span, &algos);
        let trace = Arc::new(TrafficTrace::diurnal(5, 2));
        for inner in [
            Arc::new(Analytical) as Arc<dyn NetworkBackend>,
            Arc::new(FlowLevel::default()) as Arc<dyn NetworkBackend>,
        ] {
            let idle = inner.collective_time_us(&c);
            let view = TrafficView::wrap(Arc::clone(&inner), Arc::clone(&trace));
            let busy = view.collective_time_us(&c);
            assert!(busy >= idle, "{}: busy {busy} < idle {idle}", inner.name());
        }
    }

    #[test]
    fn uniform_trace_on_flow_rung_is_bit_identical_to_background_load() {
        let t = topo();
        let span = span_of(&t);
        let algos = [CollAlgo::Ring, CollAlgo::Rhd];
        let c = call(&t, &span, &algos);
        let u = 0.4;
        let view = TrafficView::wrap(
            Arc::new(FlowLevel::default()),
            Arc::new(TrafficTrace::uniform(t.dims.len(), u)),
        );
        let background = FlowLevel::new(FlowLevelConfig::default().with_background_load(u));
        assert_eq!(
            view.collective_time_us(&c).to_bits(),
            background.collective_time_us(&c).to_bits()
        );
        let jobs: Vec<OverlapCall> = (0..3)
            .map(|l| OverlapCall { layer: l, issue_us: l as f64 * 5.0, call: c })
            .collect();
        assert_eq!(
            view.drain_overlapped(&jobs, SchedulingPolicy::Fifo),
            background.drain_overlapped(&jobs, SchedulingPolicy::Fifo)
        );
        assert_eq!(view.phase_times_us(&c), background.phase_times_us(&c));
    }

    #[test]
    fn cache_tag_tracks_trace_and_inner() {
        let inner: Arc<dyn NetworkBackend> = Arc::new(Analytical);
        let a = TrafficView::wrap(Arc::clone(&inner), Arc::new(TrafficTrace::diurnal(1, 2)));
        let b = TrafficView::wrap(Arc::clone(&inner), Arc::new(TrafficTrace::diurnal(2, 2)));
        let c = TrafficView::wrap(
            Arc::new(FlowLevel::default()),
            Arc::new(TrafficTrace::diurnal(1, 2)),
        );
        assert_ne!(a.cache_tag(), inner.cache_tag());
        assert_ne!(a.cache_tag(), b.cache_tag());
        assert_ne!(a.cache_tag(), c.cache_tag());
    }

    #[test]
    fn suite_generation_is_deterministic_with_nominal_head() {
        let a = TrafficSuite::generate("bursty", 9, 3, 2).unwrap();
        let b = TrafficSuite::generate("bursty", 9, 3, 2).unwrap();
        assert_eq!(a.len(), 4);
        assert!(a.traces[0].is_nominal());
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut fps: Vec<u64> = a.traces.iter().map(|t| t.fingerprint()).collect();
        fps.sort_unstable();
        fps.dedup();
        assert_eq!(fps.len(), 4, "suite members must be distinct");
        assert!(TrafficSuite::generate("bogus", 9, 2, 2).is_err());
    }
}
