//! Flow-level network simulation: max-min fair bandwidth sharing driven
//! by the discrete-event engine.
//!
//! The model is the classic fluid approximation used by flow-level
//! simulators (htsim's flow mode, MAD-Max's contention model): a *flow*
//! crosses a set of resources (here: topology dimensions), every active
//! flow receives its max-min fair rate, and rates are recomputed at each
//! flow start/finish event. Flows compose into *chains* — one flow per
//! collective phase, executed in sequence — so a multi-dimensional
//! collective is a chain of per-dimension flows, and concurrent
//! collectives contend wherever their chains occupy the same dimension
//! at the same time.

use super::engine::EventQueue;

/// One flow of a chain: a data transfer over a set of resources, paid
/// after a fixed latency (the collective phase's alpha term).
#[derive(Debug, Clone, PartialEq)]
pub struct FlowSpec {
    /// Resource ids (topology dimension indices) the flow crosses.
    pub uses: Vec<usize>,
    /// Payload bytes served at the flow's max-min rate.
    pub bytes: f64,
    /// Fixed latency (us) before the data phase starts.
    pub latency_us: f64,
}

/// Completion record for one chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChainResult {
    /// Absolute finish time (us) of the chain's last flow.
    pub finish_us: f64,
    /// Total bytes actually served across the chain (byte-conservation
    /// invariant: equals the sum of the chain's `FlowSpec::bytes`).
    pub served_bytes: f64,
}

/// One recorded data-phase occupancy, emitted by
/// [`FlowSim::run_recorded`]: chain `chain`'s flow number `flow` held
/// resources `uses` from `start_us` (after its alpha latency) until it
/// drained at `finish_us`. Recording never changes simulation results —
/// `run_recorded` and [`FlowSim::run`] share one core.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowSegment {
    pub chain: usize,
    pub flow: usize,
    pub uses: Vec<usize>,
    pub start_us: f64,
    pub finish_us: f64,
    pub bytes: f64,
}

/// Max-min fair rates by progressive bottleneck filling.
///
/// `uses[f]` lists the resource ids flow `f` crosses; `caps[r]` is the
/// capacity of resource `r` (bytes/us). Returns one rate per flow; flows
/// crossing no resource get `f64::INFINITY`. The result satisfies the
/// max-min certificate: every finite-rate flow has a *bottleneck*
/// resource that is fully allocated and on which no other flow receives
/// a higher rate.
pub fn maxmin_rates(uses: &[Vec<usize>], caps: &[f64]) -> Vec<f64> {
    let n = uses.len();
    let mut rates = vec![f64::INFINITY; n];
    let mut frozen: Vec<bool> = uses.iter().map(|u| u.is_empty()).collect();
    let mut remaining = caps.to_vec();
    loop {
        // Unfrozen-flow count per resource.
        let mut counts = vec![0usize; caps.len()];
        for (f, u) in uses.iter().enumerate() {
            if !frozen[f] {
                for &r in u {
                    counts[r] += 1;
                }
            }
        }
        // The bottleneck: the resource with the smallest fair share.
        let mut bottleneck: Option<(usize, f64)> = None;
        for r in 0..caps.len() {
            if counts[r] > 0 {
                let fair = (remaining[r] / counts[r] as f64).max(0.0);
                if bottleneck.map(|(_, b)| fair < b).unwrap_or(true) {
                    bottleneck = Some((r, fair));
                }
            }
        }
        let Some((r_min, fair)) = bottleneck else { break };
        for f in 0..n {
            if !frozen[f] && uses[f].contains(&r_min) {
                rates[f] = fair;
                frozen[f] = true;
                for &r in &uses[f] {
                    remaining[r] -= fair;
                }
            }
        }
        remaining[r_min] = 0.0; // kill fp residue
    }
    rates
}

/// The flow-level simulator: fixed resource capacities, chains in,
/// completion times out.
#[derive(Debug, Clone)]
pub struct FlowSim {
    /// Capacity (bytes/us) per resource id.
    pub caps: Vec<f64>,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Chain `chain` begins the data phase of its current flow.
    Start { chain: usize },
    /// Chain `chain`'s current flow drains; stale unless `epoch` matches.
    Finish { chain: usize, epoch: u64 },
}

impl FlowSim {
    pub fn new(caps: Vec<f64>) -> Self {
        Self { caps }
    }

    /// Run every chain to completion. `chains[i]` = (issue time, flow
    /// sequence). Returns one [`ChainResult`] per chain, same order.
    pub fn run(&self, chains: &[(f64, Vec<FlowSpec>)]) -> Vec<ChainResult> {
        self.run_impl(chains, None)
    }

    /// [`FlowSim::run`], additionally appending one [`FlowSegment`] per
    /// completed flow to `segments` (in completion order, which is
    /// deterministic for identical input).
    pub fn run_recorded(
        &self,
        chains: &[(f64, Vec<FlowSpec>)],
        segments: &mut Vec<FlowSegment>,
    ) -> Vec<ChainResult> {
        self.run_impl(chains, Some(segments))
    }

    fn run_impl(
        &self,
        chains: &[(f64, Vec<FlowSpec>)],
        mut segments: Option<&mut Vec<FlowSegment>>,
    ) -> Vec<ChainResult> {
        let n = chains.len();
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut step = vec![0usize; n]; // current flow index per chain
        let mut remaining = vec![0.0f64; n];
        let mut served = vec![0.0f64; n];
        let mut rate = vec![0.0f64; n];
        let mut active = vec![false; n];
        let mut flow_start = vec![0.0f64; n];
        let mut finish = vec![0.0f64; n];
        let mut epoch = 0u64;
        let mut last_t = 0.0f64;

        for (i, (issue, specs)) in chains.iter().enumerate() {
            let issue = issue.max(0.0);
            if specs.is_empty() {
                finish[i] = issue;
            } else {
                q.schedule_at(issue + specs[0].latency_us.max(0.0), Ev::Start { chain: i });
            }
        }

        while let Some((t, ev)) = q.pop() {
            // Advance every active flow to `t` at its last computed rate.
            let dt = t - last_t;
            if dt > 0.0 {
                for i in 0..n {
                    if active[i] && rate[i].is_finite() {
                        let d = (rate[i] * dt).min(remaining[i]);
                        remaining[i] -= d;
                        served[i] += d;
                    }
                }
                last_t = t;
            }

            match ev {
                Ev::Start { chain } => {
                    active[chain] = true;
                    flow_start[chain] = t;
                    remaining[chain] = chains[chain].1[step[chain]].bytes.max(0.0);
                }
                Ev::Finish { chain, epoch: e } => {
                    if e != epoch || !active[chain] {
                        continue; // stale event from a superseded rate set
                    }
                    // Credit any fp residue so bytes are conserved.
                    served[chain] += remaining[chain].max(0.0);
                    remaining[chain] = 0.0;
                    active[chain] = false;
                    if let Some(rec) = segments.as_mut() {
                        let spec = &chains[chain].1[step[chain]];
                        rec.push(FlowSegment {
                            chain,
                            flow: step[chain],
                            uses: spec.uses.clone(),
                            start_us: flow_start[chain],
                            finish_us: t,
                            bytes: spec.bytes.max(0.0),
                        });
                    }
                    step[chain] += 1;
                    if step[chain] < chains[chain].1.len() {
                        let lat = chains[chain].1[step[chain]].latency_us.max(0.0);
                        q.schedule_at(t + lat, Ev::Start { chain });
                    } else {
                        finish[chain] = t;
                    }
                }
            }

            // Re-waterfill and reschedule every active flow's finish.
            epoch += 1;
            let act: Vec<usize> = (0..n).filter(|&i| active[i]).collect();
            let uses: Vec<Vec<usize>> =
                act.iter().map(|&i| chains[i].1[step[i]].uses.clone()).collect();
            let rates = maxmin_rates(&uses, &self.caps);
            for (k, &i) in act.iter().enumerate() {
                rate[i] = rates[k];
                let dt_fin = if remaining[i] <= 0.0 {
                    0.0
                } else if rates[k].is_finite() && rates[k] > 0.0 {
                    remaining[i] / rates[k]
                } else if rates[k].is_infinite() {
                    0.0
                } else {
                    f64::INFINITY // starved flow: never finishes
                };
                if dt_fin.is_finite() {
                    q.schedule_at(t + dt_fin, Ev::Finish { chain: i, epoch });
                }
            }
        }

        (0..n)
            .map(|i| ChainResult { finish_us: finish[i], served_bytes: served[i] })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(dims: &[usize], bytes: f64, latency: f64) -> FlowSpec {
        FlowSpec { uses: dims.to_vec(), bytes, latency_us: latency }
    }

    #[test]
    fn single_flow_runs_at_capacity() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[(0.0, vec![flow(&[0], 1000.0, 2.0)])]);
        // 2us latency + 1000 bytes at 100 bytes/us = 12us.
        assert!((out[0].finish_us - 12.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[0].served_bytes - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
        ]);
        // Equal demands, equal shares: both finish at 2000/100 = 20us.
        for r in &out {
            assert!((r.finish_us - 20.0).abs() < 1e-9, "{}", r.finish_us);
        }
    }

    #[test]
    fn short_flow_releases_bandwidth_to_long_flow() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 500.0, 0.0)]),
            (0.0, vec![flow(&[0], 1500.0, 0.0)]),
        ]);
        // Shared at 50 each until the short one drains at t=10; the long
        // one then runs alone: 10 + (1500-500)/100 = 20.
        assert!((out[0].finish_us - 10.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[1].finish_us - 20.0).abs() < 1e-9, "{}", out[1].finish_us);
    }

    #[test]
    fn chains_serialize_their_own_flows() {
        let sim = FlowSim::new(vec![100.0, 50.0]);
        let out = sim.run(&[(
            0.0,
            vec![flow(&[0], 1000.0, 1.0), flow(&[1], 1000.0, 1.0)],
        )]);
        // 1 + 10 on dim 0, then 1 + 20 on dim 1.
        assert!((out[0].finish_us - 32.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[0].served_bytes - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_dims_do_not_contend() {
        let sim = FlowSim::new(vec![100.0, 100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
            (0.0, vec![flow(&[1], 1000.0, 0.0)]),
        ]);
        for r in &out {
            assert!((r.finish_us - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn late_arrival_shares_from_its_issue_time() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
            (5.0, vec![flow(&[0], 1000.0, 0.0)]),
        ]);
        // Flow 0 alone for 5us (500 bytes), then both share 50/50.
        // Flow 0 drains its remaining 500 at t = 5 + 10 = 15; flow 1 then
        // has 500 left alone: 15 + 5 = 20.
        assert!((out[0].finish_us - 15.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[1].finish_us - 20.0).abs() < 1e-9, "{}", out[1].finish_us);
    }

    #[test]
    fn empty_chain_finishes_at_issue() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[(7.5, vec![])]);
        assert_eq!(out[0].finish_us, 7.5);
        assert_eq!(out[0].served_bytes, 0.0);
    }

    #[test]
    fn maxmin_certificate_on_mixed_paths() {
        // f0 {A}, f1 {A,B}, f2 {B}; cap A=10, B=4.
        let rates = maxmin_rates(
            &[vec![0], vec![0, 1], vec![1]],
            &[10.0, 4.0],
        );
        assert!((rates[1] - 2.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[2] - 2.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[0] - 8.0).abs() < 1e-9, "{rates:?}");
    }

    #[test]
    fn maxmin_empty_uses_is_unbounded() {
        let rates = maxmin_rates(&[vec![]], &[1.0]);
        assert!(rates[0].is_infinite());
    }

    #[test]
    fn recorded_run_matches_plain_run_and_captures_segments() {
        let sim = FlowSim::new(vec![100.0, 50.0]);
        let chains = vec![
            (0.0, vec![flow(&[0], 1000.0, 1.0), flow(&[1], 1000.0, 1.0)]),
            (5.0, vec![flow(&[0], 500.0, 0.0)]),
        ];
        let plain = sim.run(&chains);
        let mut segments = Vec::new();
        let recorded = sim.run_recorded(&chains, &mut segments);
        assert_eq!(plain, recorded, "recording must not perturb results");
        // One segment per flow, each within its chain's lifetime.
        assert_eq!(segments.len(), 3);
        for seg in &segments {
            assert!(seg.start_us <= seg.finish_us, "{seg:?}");
            assert!(seg.finish_us <= recorded[seg.chain].finish_us + 1e-9, "{seg:?}");
        }
        // Chain 0's two flows are sequential on dims 0 then 1.
        let c0: Vec<&FlowSegment> = segments.iter().filter(|s| s.chain == 0).collect();
        assert_eq!(c0.len(), 2);
        assert_eq!(c0[0].uses, vec![0]);
        assert_eq!(c0[1].uses, vec![1]);
        assert!(c0[0].finish_us <= c0[1].start_us + 1e-9);
    }

    #[test]
    fn zero_byte_flow_costs_latency_only() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[(0.0, vec![flow(&[0], 0.0, 3.0)])]);
        assert!((out[0].finish_us - 3.0).abs() < 1e-9);
    }
}
