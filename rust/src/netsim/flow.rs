//! Flow-level network simulation: max-min fair bandwidth sharing driven
//! by the discrete-event engine.
//!
//! The model is the classic fluid approximation used by flow-level
//! simulators (htsim's flow mode, MAD-Max's contention model): a *flow*
//! crosses a set of resources (here: topology dimensions), every active
//! flow receives its max-min fair rate, and rates are recomputed at each
//! flow start/finish event. Flows compose into *chains* — one flow per
//! collective phase, executed in sequence — so a multi-dimensional
//! collective is a chain of per-dimension flows, and concurrent
//! collectives contend wherever their chains occupy the same dimension
//! at the same time.

use super::engine::EventQueue;

/// One flow of a chain: a data transfer over a set of resources, paid
/// after a fixed latency (the collective phase's alpha term).
#[derive(Debug, Clone, PartialEq)]
pub struct FlowSpec {
    /// Resource ids (topology dimension indices) the flow crosses.
    pub uses: Vec<usize>,
    /// Payload bytes served at the flow's max-min rate.
    pub bytes: f64,
    /// Fixed latency (us) before the data phase starts.
    pub latency_us: f64,
}

/// Completion record for one chain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChainResult {
    /// Absolute finish time (us) of the chain's last flow.
    pub finish_us: f64,
    /// Total bytes actually served across the chain (byte-conservation
    /// invariant: equals the sum of the chain's `FlowSpec::bytes`).
    pub served_bytes: f64,
}

/// One recorded data-phase occupancy, emitted by
/// [`FlowSim::run_recorded`]: chain `chain`'s flow number `flow` held
/// resources `uses` from `start_us` (after its alpha latency) until it
/// drained at `finish_us`. Recording never changes simulation results —
/// `run_recorded` and [`FlowSim::run`] share one core.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowSegment {
    pub chain: usize,
    pub flow: usize,
    pub uses: Vec<usize>,
    pub start_us: f64,
    pub finish_us: f64,
    pub bytes: f64,
}

/// One flow of a chunked precedence graph ([`FlowSim::run_chunked`]):
/// chunk `chunk`'s phase `phase` of one collective, occupying a single
/// topology dimension, gated on the *completion* of other flows of the
/// same job (`deps`, indices into the job's own flow list). The dep
/// lists come from [`crate::collective::ChunkSchedule`], which encodes
/// each multi-dim policy's pipeline discipline.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkFlowSpec {
    /// Chunk index within the collective (0-based).
    pub chunk: u32,
    /// Phase index within the chunk's phase plan.
    pub phase: usize,
    /// Topology dimension the flow occupies.
    pub dim: usize,
    /// Payload bytes served at the flow's allocated rate.
    pub bytes: f64,
    /// Fixed latency (us) paid after the deps complete, before data.
    pub latency_us: f64,
    /// Indices (into the same job's flow list) of the flows whose
    /// completion gates this flow's start.
    pub deps: Vec<usize>,
}

/// One recorded data-phase occupancy from [`FlowSim::run_chunked_recorded`]
/// — the per-chunk analogue of [`FlowSegment`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkSegment {
    pub job: usize,
    pub chunk: u32,
    pub phase: usize,
    pub dim: usize,
    pub start_us: f64,
    pub finish_us: f64,
    pub bytes: f64,
}

/// Max-min fair rates by progressive bottleneck filling.
///
/// `uses[f]` lists the resource ids flow `f` crosses; `caps[r]` is the
/// capacity of resource `r` (bytes/us). Returns one rate per flow; flows
/// crossing no resource get `f64::INFINITY`. The result satisfies the
/// max-min certificate: every finite-rate flow has a *bottleneck*
/// resource that is fully allocated and on which no other flow receives
/// a higher rate.
pub fn maxmin_rates(uses: &[Vec<usize>], caps: &[f64]) -> Vec<f64> {
    let n = uses.len();
    let mut rates = vec![f64::INFINITY; n];
    let mut frozen: Vec<bool> = uses.iter().map(|u| u.is_empty()).collect();
    let mut remaining = caps.to_vec();
    loop {
        // Unfrozen-flow count per resource.
        let mut counts = vec![0usize; caps.len()];
        for (f, u) in uses.iter().enumerate() {
            if !frozen[f] {
                for &r in u {
                    counts[r] += 1;
                }
            }
        }
        // The bottleneck: the resource with the smallest fair share.
        let mut bottleneck: Option<(usize, f64)> = None;
        for r in 0..caps.len() {
            if counts[r] > 0 {
                let fair = (remaining[r] / counts[r] as f64).max(0.0);
                if bottleneck.map(|(_, b)| fair < b).unwrap_or(true) {
                    bottleneck = Some((r, fair));
                }
            }
        }
        let Some((r_min, fair)) = bottleneck else { break };
        for f in 0..n {
            if !frozen[f] && uses[f].contains(&r_min) {
                rates[f] = fair;
                frozen[f] = true;
                for &r in &uses[f] {
                    remaining[r] -= fair;
                }
            }
        }
        remaining[r_min] = 0.0; // kill fp residue
    }
    rates
}

/// The flow-level simulator: fixed resource capacities, chains in,
/// completion times out.
#[derive(Debug, Clone)]
pub struct FlowSim {
    /// Capacity (bytes/us) per resource id.
    pub caps: Vec<f64>,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Chain `chain` begins the data phase of its current flow.
    Start { chain: usize },
    /// Chain `chain`'s current flow drains; stale unless `epoch` matches.
    Finish { chain: usize, epoch: u64 },
}

#[derive(Debug, Clone, Copy)]
enum CEv {
    /// Global flow `flow` begins its data phase (deps met, latency paid).
    Start { flow: usize },
    /// Global flow `flow` drains; stale unless `epoch` matches.
    Finish { flow: usize, epoch: u64 },
}

impl FlowSim {
    pub fn new(caps: Vec<f64>) -> Self {
        Self { caps }
    }

    /// Run every chain to completion. `chains[i]` = (issue time, flow
    /// sequence). Returns one [`ChainResult`] per chain, same order.
    pub fn run(&self, chains: &[(f64, Vec<FlowSpec>)]) -> Vec<ChainResult> {
        self.run_impl(chains, None)
    }

    /// [`FlowSim::run`], additionally appending one [`FlowSegment`] per
    /// completed flow to `segments` (in completion order, which is
    /// deterministic for identical input).
    pub fn run_recorded(
        &self,
        chains: &[(f64, Vec<FlowSpec>)],
        segments: &mut Vec<FlowSegment>,
    ) -> Vec<ChainResult> {
        self.run_impl(chains, Some(segments))
    }

    fn run_impl(
        &self,
        chains: &[(f64, Vec<FlowSpec>)],
        mut segments: Option<&mut Vec<FlowSegment>>,
    ) -> Vec<ChainResult> {
        let n = chains.len();
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut step = vec![0usize; n]; // current flow index per chain
        let mut remaining = vec![0.0f64; n];
        let mut served = vec![0.0f64; n];
        let mut rate = vec![0.0f64; n];
        let mut active = vec![false; n];
        let mut flow_start = vec![0.0f64; n];
        let mut finish = vec![0.0f64; n];
        let mut epoch = 0u64;
        let mut last_t = 0.0f64;

        for (i, (issue, specs)) in chains.iter().enumerate() {
            let issue = issue.max(0.0);
            if specs.is_empty() {
                finish[i] = issue;
            } else {
                q.schedule_at(issue + specs[0].latency_us.max(0.0), Ev::Start { chain: i });
            }
        }

        while let Some((t, ev)) = q.pop() {
            // Advance every active flow to `t` at its last computed rate.
            let dt = t - last_t;
            if dt > 0.0 {
                for i in 0..n {
                    if active[i] && rate[i].is_finite() {
                        let d = (rate[i] * dt).min(remaining[i]);
                        remaining[i] -= d;
                        served[i] += d;
                    }
                }
                last_t = t;
            }

            match ev {
                Ev::Start { chain } => {
                    active[chain] = true;
                    flow_start[chain] = t;
                    remaining[chain] = chains[chain].1[step[chain]].bytes.max(0.0);
                }
                Ev::Finish { chain, epoch: e } => {
                    if e != epoch || !active[chain] {
                        continue; // stale event from a superseded rate set
                    }
                    // Credit any fp residue so bytes are conserved.
                    served[chain] += remaining[chain].max(0.0);
                    remaining[chain] = 0.0;
                    active[chain] = false;
                    if let Some(rec) = segments.as_mut() {
                        let spec = &chains[chain].1[step[chain]];
                        rec.push(FlowSegment {
                            chain,
                            flow: step[chain],
                            uses: spec.uses.clone(),
                            start_us: flow_start[chain],
                            finish_us: t,
                            bytes: spec.bytes.max(0.0),
                        });
                    }
                    step[chain] += 1;
                    if step[chain] < chains[chain].1.len() {
                        let lat = chains[chain].1[step[chain]].latency_us.max(0.0);
                        q.schedule_at(t + lat, Ev::Start { chain });
                    } else {
                        finish[chain] = t;
                    }
                }
            }

            // Re-waterfill and reschedule every active flow's finish.
            epoch += 1;
            let act: Vec<usize> = (0..n).filter(|&i| active[i]).collect();
            let uses: Vec<Vec<usize>> =
                act.iter().map(|&i| chains[i].1[step[i]].uses.clone()).collect();
            let rates = maxmin_rates(&uses, &self.caps);
            for (k, &i) in act.iter().enumerate() {
                rate[i] = rates[k];
                let dt_fin = if remaining[i] <= 0.0 {
                    0.0
                } else if rates[k].is_finite() && rates[k] > 0.0 {
                    remaining[i] / rates[k]
                } else if rates[k].is_infinite() {
                    0.0
                } else {
                    f64::INFINITY // starved flow: never finishes
                };
                if dt_fin.is_finite() {
                    q.schedule_at(t + dt_fin, Ev::Finish { chain: i, epoch });
                }
            }
        }

        (0..n)
            .map(|i| ChainResult { finish_us: finish[i], served_bytes: served[i] })
            .collect()
    }

    /// Run a chunk-level precedence graph to completion. `jobs[i]` =
    /// (issue time, flow list); each flow starts once every dep has
    /// *completed* and its latency has been paid, so chunks of one
    /// collective genuinely interleave with chunks of concurrent
    /// collectives on shared dimensions. Returns one [`ChainResult`]
    /// per job (finish = the job's last flow completion).
    ///
    /// Rate rule: each dimension's capacity is split evenly among the
    /// *distinct jobs* holding at least one active flow on it; flows of
    /// the same job sharing a dimension each receive the full job share
    /// (an AllReduce plan visits every dimension twice — RS and AG — and
    /// in steady state chunk k+1's RS overlaps chunk k's AG on the same
    /// dimension; the closed form prices the bottleneck as the max
    /// *single* phase, i.e. full-duplex/disjoint directions, and this
    /// rule keeps the uncontended drain exactly conformant).
    pub fn run_chunked(&self, jobs: &[(f64, Vec<ChunkFlowSpec>)]) -> Vec<ChainResult> {
        self.run_chunked_impl(jobs, None)
    }

    /// [`FlowSim::run_chunked`], additionally appending one
    /// [`ChunkSegment`] per completed flow to `segments` (completion
    /// order; deterministic for identical input). Recording never
    /// perturbs results — both entry points share one core.
    pub fn run_chunked_recorded(
        &self,
        jobs: &[(f64, Vec<ChunkFlowSpec>)],
        segments: &mut Vec<ChunkSegment>,
    ) -> Vec<ChainResult> {
        self.run_chunked_impl(jobs, Some(segments))
    }

    fn run_chunked_impl(
        &self,
        jobs: &[(f64, Vec<ChunkFlowSpec>)],
        mut segments: Option<&mut Vec<ChunkSegment>>,
    ) -> Vec<ChainResult> {
        let nj = jobs.len();
        // Flatten to global flow ids, jobs contiguous (the distinct-job
        // counting below relies on that grouping).
        let mut flows: Vec<(usize, &ChunkFlowSpec)> = Vec::new();
        let mut offset = vec![0usize; nj];
        for (j, (_, fl)) in jobs.iter().enumerate() {
            offset[j] = flows.len();
            for s in fl {
                flows.push((j, s));
            }
        }
        let total = flows.len();

        // Pending-dep counts and reverse (dependent) edges.
        let mut pending = vec![0usize; total];
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); total];
        for f in 0..total {
            let (j, s) = flows[f];
            pending[f] = s.deps.len();
            for &d in &s.deps {
                debug_assert!(d < jobs[j].1.len(), "dep index out of range");
                dependents[offset[j] + d].push(f);
            }
        }

        let mut q: EventQueue<CEv> = EventQueue::new();
        let mut remaining = vec![0.0f64; total];
        let mut rate = vec![0.0f64; total];
        let mut active = vec![false; total];
        let mut start_t = vec![0.0f64; total];
        let mut served = vec![0.0f64; nj];
        let mut finish = vec![0.0f64; nj];
        let mut left: Vec<usize> = jobs.iter().map(|(_, fl)| fl.len()).collect();
        let mut epoch = 0u64;
        let mut last_t = 0.0f64;

        for (j, (issue, fl)) in jobs.iter().enumerate() {
            if fl.is_empty() {
                finish[j] = issue.max(0.0);
            }
        }
        for f in 0..total {
            if pending[f] == 0 {
                let (j, s) = flows[f];
                let issue = jobs[j].0.max(0.0);
                q.schedule_at(issue + s.latency_us.max(0.0), CEv::Start { flow: f });
            }
        }

        while let Some((t, ev)) = q.pop() {
            // Advance every active flow to `t` at its last computed rate.
            let dt = t - last_t;
            if dt > 0.0 {
                for f in 0..total {
                    if active[f] && rate[f].is_finite() {
                        let d = (rate[f] * dt).min(remaining[f]);
                        remaining[f] -= d;
                        served[flows[f].0] += d;
                    }
                }
                last_t = t;
            }

            match ev {
                CEv::Start { flow } => {
                    active[flow] = true;
                    start_t[flow] = t;
                    remaining[flow] = flows[flow].1.bytes.max(0.0);
                }
                CEv::Finish { flow, epoch: e } => {
                    if e != epoch || !active[flow] {
                        continue; // stale event from a superseded rate set
                    }
                    let (j, s) = flows[flow];
                    // Credit any fp residue so bytes are conserved.
                    served[j] += remaining[flow].max(0.0);
                    remaining[flow] = 0.0;
                    active[flow] = false;
                    if let Some(rec) = segments.as_mut() {
                        rec.push(ChunkSegment {
                            job: j,
                            chunk: s.chunk,
                            phase: s.phase,
                            dim: s.dim,
                            start_us: start_t[flow],
                            finish_us: t,
                            bytes: s.bytes.max(0.0),
                        });
                    }
                    left[j] -= 1;
                    if left[j] == 0 {
                        finish[j] = t;
                    }
                    // Release dependents whose last gate this was.
                    for &g in &dependents[flow] {
                        pending[g] -= 1;
                        if pending[g] == 0 {
                            let lat = flows[g].1.latency_us.max(0.0);
                            q.schedule_at(t + lat, CEv::Start { flow: g });
                        }
                    }
                }
            }

            // Re-allocate: distinct jobs active on each dimension split
            // its capacity evenly (see `run_chunked` docs), then every
            // active flow's finish is rescheduled under the new rates.
            epoch += 1;
            let mut jobs_on_dim = vec![0u32; self.caps.len()];
            let mut last_job = vec![usize::MAX; self.caps.len()];
            for f in 0..total {
                if active[f] {
                    let (j, s) = flows[f];
                    if last_job[s.dim] != j {
                        last_job[s.dim] = j;
                        jobs_on_dim[s.dim] += 1;
                    }
                }
            }
            for f in 0..total {
                if !active[f] {
                    continue;
                }
                let d = flows[f].1.dim;
                let r = self.caps[d] / jobs_on_dim[d].max(1) as f64;
                rate[f] = r;
                let dt_fin = if remaining[f] <= 0.0 {
                    0.0
                } else if r.is_finite() && r > 0.0 {
                    remaining[f] / r
                } else if r.is_infinite() {
                    0.0
                } else {
                    f64::INFINITY // dead link: the flow never finishes
                };
                if dt_fin.is_finite() {
                    q.schedule_at(t + dt_fin, CEv::Finish { flow: f, epoch });
                }
            }
        }

        (0..nj)
            .map(|j| ChainResult { finish_us: finish[j], served_bytes: served[j] })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(dims: &[usize], bytes: f64, latency: f64) -> FlowSpec {
        FlowSpec { uses: dims.to_vec(), bytes, latency_us: latency }
    }

    #[test]
    fn single_flow_runs_at_capacity() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[(0.0, vec![flow(&[0], 1000.0, 2.0)])]);
        // 2us latency + 1000 bytes at 100 bytes/us = 12us.
        assert!((out[0].finish_us - 12.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[0].served_bytes - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn two_flows_share_a_link_fairly() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
        ]);
        // Equal demands, equal shares: both finish at 2000/100 = 20us.
        for r in &out {
            assert!((r.finish_us - 20.0).abs() < 1e-9, "{}", r.finish_us);
        }
    }

    #[test]
    fn short_flow_releases_bandwidth_to_long_flow() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 500.0, 0.0)]),
            (0.0, vec![flow(&[0], 1500.0, 0.0)]),
        ]);
        // Shared at 50 each until the short one drains at t=10; the long
        // one then runs alone: 10 + (1500-500)/100 = 20.
        assert!((out[0].finish_us - 10.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[1].finish_us - 20.0).abs() < 1e-9, "{}", out[1].finish_us);
    }

    #[test]
    fn chains_serialize_their_own_flows() {
        let sim = FlowSim::new(vec![100.0, 50.0]);
        let out = sim.run(&[(
            0.0,
            vec![flow(&[0], 1000.0, 1.0), flow(&[1], 1000.0, 1.0)],
        )]);
        // 1 + 10 on dim 0, then 1 + 20 on dim 1.
        assert!((out[0].finish_us - 32.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[0].served_bytes - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_dims_do_not_contend() {
        let sim = FlowSim::new(vec![100.0, 100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
            (0.0, vec![flow(&[1], 1000.0, 0.0)]),
        ]);
        for r in &out {
            assert!((r.finish_us - 10.0).abs() < 1e-9);
        }
    }

    #[test]
    fn late_arrival_shares_from_its_issue_time() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[
            (0.0, vec![flow(&[0], 1000.0, 0.0)]),
            (5.0, vec![flow(&[0], 1000.0, 0.0)]),
        ]);
        // Flow 0 alone for 5us (500 bytes), then both share 50/50.
        // Flow 0 drains its remaining 500 at t = 5 + 10 = 15; flow 1 then
        // has 500 left alone: 15 + 5 = 20.
        assert!((out[0].finish_us - 15.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[1].finish_us - 20.0).abs() < 1e-9, "{}", out[1].finish_us);
    }

    #[test]
    fn empty_chain_finishes_at_issue() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[(7.5, vec![])]);
        assert_eq!(out[0].finish_us, 7.5);
        assert_eq!(out[0].served_bytes, 0.0);
    }

    #[test]
    fn maxmin_certificate_on_mixed_paths() {
        // f0 {A}, f1 {A,B}, f2 {B}; cap A=10, B=4.
        let rates = maxmin_rates(
            &[vec![0], vec![0, 1], vec![1]],
            &[10.0, 4.0],
        );
        assert!((rates[1] - 2.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[2] - 2.0).abs() < 1e-9, "{rates:?}");
        assert!((rates[0] - 8.0).abs() < 1e-9, "{rates:?}");
    }

    #[test]
    fn maxmin_empty_uses_is_unbounded() {
        let rates = maxmin_rates(&[vec![]], &[1.0]);
        assert!(rates[0].is_infinite());
    }

    #[test]
    fn recorded_run_matches_plain_run_and_captures_segments() {
        let sim = FlowSim::new(vec![100.0, 50.0]);
        let chains = vec![
            (0.0, vec![flow(&[0], 1000.0, 1.0), flow(&[1], 1000.0, 1.0)]),
            (5.0, vec![flow(&[0], 500.0, 0.0)]),
        ];
        let plain = sim.run(&chains);
        let mut segments = Vec::new();
        let recorded = sim.run_recorded(&chains, &mut segments);
        assert_eq!(plain, recorded, "recording must not perturb results");
        // One segment per flow, each within its chain's lifetime.
        assert_eq!(segments.len(), 3);
        for seg in &segments {
            assert!(seg.start_us <= seg.finish_us, "{seg:?}");
            assert!(seg.finish_us <= recorded[seg.chain].finish_us + 1e-9, "{seg:?}");
        }
        // Chain 0's two flows are sequential on dims 0 then 1.
        let c0: Vec<&FlowSegment> = segments.iter().filter(|s| s.chain == 0).collect();
        assert_eq!(c0.len(), 2);
        assert_eq!(c0[0].uses, vec![0]);
        assert_eq!(c0[1].uses, vec![1]);
        assert!(c0[0].finish_us <= c0[1].start_us + 1e-9);
    }

    #[test]
    fn zero_byte_flow_costs_latency_only() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run(&[(0.0, vec![flow(&[0], 0.0, 3.0)])]);
        assert!((out[0].finish_us - 3.0).abs() < 1e-9);
    }

    fn cflow(chunk: u32, phase: usize, dim: usize, bytes: f64, deps: &[usize]) -> ChunkFlowSpec {
        ChunkFlowSpec { chunk, phase, dim, bytes, latency_us: 0.0, deps: deps.to_vec() }
    }

    #[test]
    fn chunked_fifo_chain_serializes_chunks() {
        // Two chunks FIFO on one dim: 1000 bytes each at 100 bytes/us.
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run_chunked(&[(
            0.0,
            vec![cflow(0, 0, 0, 1000.0, &[]), cflow(1, 0, 0, 1000.0, &[0])],
        )]);
        assert!((out[0].finish_us - 20.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[0].served_bytes - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn chunked_distinct_jobs_share_a_dim() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run_chunked(&[
            (0.0, vec![cflow(0, 0, 0, 1000.0, &[])]),
            (0.0, vec![cflow(0, 0, 0, 1000.0, &[])]),
        ]);
        // Two jobs split the 100 bytes/us dim 50/50.
        for r in &out {
            assert!((r.finish_us - 20.0).abs() < 1e-9, "{}", r.finish_us);
        }
    }

    #[test]
    fn chunked_same_job_flows_do_not_self_contend() {
        // Dep-free flows of one job on one dim run at the full job
        // share (full-duplex RS/AG overlap — see run_chunked docs).
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run_chunked(&[(
            0.0,
            vec![cflow(0, 0, 0, 1000.0, &[]), cflow(0, 1, 0, 1000.0, &[])],
        )]);
        assert!((out[0].finish_us - 10.0).abs() < 1e-9, "{}", out[0].finish_us);
        assert!((out[0].served_bytes - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn chunked_dep_latency_paid_after_deps_complete() {
        // Phase 1 waits for phase 0, then pays its own 2us alpha.
        let sim = FlowSim::new(vec![100.0, 100.0]);
        let out = sim.run_chunked(&[(
            0.0,
            vec![
                ChunkFlowSpec {
                    chunk: 0,
                    phase: 0,
                    dim: 0,
                    bytes: 1000.0,
                    latency_us: 1.0,
                    deps: vec![],
                },
                ChunkFlowSpec {
                    chunk: 0,
                    phase: 1,
                    dim: 1,
                    bytes: 500.0,
                    latency_us: 2.0,
                    deps: vec![0],
                },
            ],
        )]);
        // 1 + 10 on dim 0, then 2 + 5 on dim 1 = 18.
        assert!((out[0].finish_us - 18.0).abs() < 1e-9, "{}", out[0].finish_us);
    }

    #[test]
    fn chunked_recorded_matches_plain_and_keeps_fifo_order() {
        let sim = FlowSim::new(vec![100.0]);
        let jobs = vec![
            (
                0.0,
                vec![
                    cflow(0, 0, 0, 800.0, &[]),
                    cflow(1, 0, 0, 800.0, &[0]),
                    cflow(2, 0, 0, 800.0, &[1]),
                ],
            ),
            (3.0, vec![cflow(0, 0, 0, 600.0, &[])]),
        ];
        let plain = sim.run_chunked(&jobs);
        let mut segs = Vec::new();
        let recorded = sim.run_chunked_recorded(&jobs, &mut segs);
        assert_eq!(plain, recorded, "recording must not perturb results");
        assert_eq!(segs.len(), 4);
        // Chunk FIFO within job 0: starts and finishes never invert.
        let j0: Vec<&ChunkSegment> = segs.iter().filter(|s| s.job == 0).collect();
        for w in j0.windows(2) {
            assert!(w[0].chunk < w[1].chunk, "{:?}", (w[0], w[1]));
            assert!(w[0].finish_us <= w[1].start_us + 1e-9, "{:?}", (w[0], w[1]));
        }
        // Byte conservation per job.
        assert!((recorded[0].served_bytes - 2400.0).abs() < 1e-9);
        assert!((recorded[1].served_bytes - 600.0).abs() < 1e-9);
    }

    #[test]
    fn chunked_empty_job_finishes_at_issue() {
        let sim = FlowSim::new(vec![100.0]);
        let out = sim.run_chunked(&[(4.5, vec![])]);
        assert_eq!(out[0].finish_us, 4.5);
        assert_eq!(out[0].served_bytes, 0.0);
    }
}
