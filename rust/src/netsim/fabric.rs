//! Fabric capacity model for the flow-level backend.
//!
//! The analytical cost model credits every dimension its full nominal
//! per-NPU link bandwidth. Real fabrics fall short of that in two ways
//! the `FlowLevel` backend can express:
//!
//! - **Oversubscription** — a Switch dimension whose crossbar (or leaf/
//!   spine uplinks) serves only `1/k` of the sum of its edge links. When
//!   all NPUs of the dimension drive at once — exactly what collectives
//!   do — each sees `bw / k`.
//! - **Background load** — a fraction of every link consumed by
//!   co-tenant traffic (other jobs, storage, control plane), modelled as
//!   a uniform utilization the simulated job cannot claim. The
//!   per-dimension variant (`per_dim_background`) is how
//!   `netsim::traffic::TrafficView` folds a traffic trace's window-mean
//!   utilization into the fabric.

use crate::topology::{DimKind, Topology};

/// Ceiling on co-tenant utilization: a background load can never claim
/// the whole link.
const MAX_BACKGROUND: f64 = 0.95;

/// Clamp one background-load fraction to its legal range; non-finite
/// values (the NaN a buggy caller could feed through a struct literal)
/// sanitize to idle rather than poisoning every capacity downstream.
fn sanitize_load(load: f64) -> f64 {
    if load.is_finite() {
        load.clamp(0.0, MAX_BACKGROUND)
    } else {
        0.0
    }
}

/// Clamp an oversubscription factor to the model's `>= 1` floor,
/// mapping non-finite garbage to the neutral factor.
fn sanitize_over(factor: f64) -> f64 {
    if factor.is_finite() {
        factor.max(1.0)
    } else {
        1.0
    }
}

/// Congestion parameters of the flow-level fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowLevelConfig {
    /// Oversubscription factor applied to every Switch dimension
    /// (`>= 1`; 1.0 = full bisection, the analytical assumption).
    pub switch_oversubscription: f64,
    /// Fraction of every link's bandwidth consumed by co-tenant traffic
    /// (`0.0..1.0`).
    pub background_load: f64,
    /// Optional per-dimension oversubscription override, outermost
    /// entries may be omitted (falls back to the kind-based default).
    pub per_dim_oversubscription: Option<Vec<f64>>,
    /// Optional per-dimension background-load override; entries beyond
    /// the vector fall back to the uniform `background_load`. This is
    /// the channel traffic traces shape capacities through, so a
    /// uniform trace takes the exact arithmetic path of
    /// `with_background_load`.
    pub per_dim_background: Option<Vec<f64>>,
    /// Chunk-level flow precedence: when on, the flow-level drain admits
    /// each collective's chunks as a per-(job, dim) FIFO precedence
    /// graph (`FlowSim::run_chunked`) instead of one steady-state
    /// aggregate flow per phase, so chunks of concurrent collectives
    /// genuinely interleave on shared dimensions. Off (the default) is
    /// bit-identical to the historical steady-state model.
    pub chunk_precedence: bool,
}

impl Default for FlowLevelConfig {
    fn default() -> Self {
        Self {
            switch_oversubscription: 1.0,
            background_load: 0.0,
            per_dim_oversubscription: None,
            per_dim_background: None,
            chunk_precedence: false,
        }
    }
}

impl FlowLevelConfig {
    /// An oversubscribed variant (factor applied to Switch dims).
    pub fn oversubscribed(factor: f64) -> Self {
        Self { switch_oversubscription: factor.max(1.0), ..Self::default() }
    }

    /// A multi-tenant variant: `load` of every link is already in use.
    pub fn with_background_load(mut self, load: f64) -> Self {
        self.background_load = sanitize_load(load);
        self
    }

    /// Toggle chunk-level flow precedence (see the field docs) —
    /// builder style.
    pub fn with_chunk_precedence(mut self, on: bool) -> Self {
        self.chunk_precedence = on;
        self
    }

    /// Fold a per-dimension utilization vector (a traffic trace's
    /// window mean) into this fabric: on every dimension the job keeps
    /// `(1 - bg) * (1 - u)` of the link. When one side is idle the
    /// other's fraction is used verbatim, so a trace over an otherwise
    /// idle fabric reproduces `with_background_load` bit for bit.
    pub fn with_dim_background(mut self, util: &[f64]) -> Self {
        let dims = util.len().max(self.per_dim_background.as_ref().map_or(0, |v| v.len()));
        let merged = (0..dims)
            .map(|d| {
                let bg = self.background_for(d);
                let u = sanitize_load(util.get(d).copied().unwrap_or(0.0));
                if bg == 0.0 {
                    u
                } else if u == 0.0 {
                    bg
                } else {
                    sanitize_load(1.0 - (1.0 - bg) * (1.0 - u))
                }
            })
            .collect();
        self.per_dim_background = Some(merged);
        self
    }

    /// The oversubscription factor of topology dimension `dim_idx`.
    pub fn oversubscription(&self, kind: DimKind, dim_idx: usize) -> f64 {
        sanitize_over(
            self.per_dim_oversubscription
                .as_ref()
                .and_then(|v| v.get(dim_idx))
                .copied()
                .unwrap_or(match kind {
                    DimKind::Switch => self.switch_oversubscription,
                    _ => 1.0,
                }),
        )
    }

    /// The background-load fraction seen by topology dimension
    /// `dim_idx` (per-dim override when present, else the uniform
    /// scalar), sanitized to `[0, 0.95]`.
    pub fn background_for(&self, dim_idx: usize) -> f64 {
        sanitize_load(
            self.per_dim_background
                .as_ref()
                .and_then(|v| v.get(dim_idx))
                .copied()
                .unwrap_or(self.background_load),
        )
    }

    /// A copy with every field pulled into its legal range: the single
    /// validation path every backend (and the calibrator) constructs
    /// through, so struct-literal configs cannot smuggle NaN or sub-1
    /// oversubscription past the builder clamps. Idempotent, and the
    /// identity on any already-valid config.
    pub fn sanitized(&self) -> Self {
        Self {
            switch_oversubscription: sanitize_over(self.switch_oversubscription),
            background_load: sanitize_load(self.background_load),
            per_dim_oversubscription: self
                .per_dim_oversubscription
                .as_ref()
                .map(|v| v.iter().map(|&x| sanitize_over(x)).collect()),
            per_dim_background: self
                .per_dim_background
                .as_ref()
                .map(|v| v.iter().map(|&x| sanitize_load(x)).collect()),
            chunk_precedence: self.chunk_precedence,
        }
    }

    /// Effective per-NPU service rate (bytes/us) on a dimension whose
    /// nominal link rate is `nominal_bytes_per_us`.
    pub fn effective_rate(
        &self,
        nominal_bytes_per_us: f64,
        kind: DimKind,
        dim_idx: usize,
    ) -> f64 {
        let over = self.oversubscription(kind, dim_idx);
        nominal_bytes_per_us * (1.0 - self.background_for(dim_idx)) / over
    }

    /// Per-dimension capacities (bytes/us, per NPU lane) for the whole
    /// topology — the resource table of the flow simulator.
    pub fn dim_capacities(&self, topo: &Topology) -> Vec<f64> {
        topo.dims
            .iter()
            .enumerate()
            .map(|(d, nd)| self.effective_rate(nd.bandwidth_gbps * 1e3, nd.kind, d))
            .collect()
    }

    /// True when this config cannot slow any transfer down (the
    /// flow-level model then matches the analytical one on single
    /// uncontended collectives).
    pub fn is_uncongested(&self) -> bool {
        self.background_load <= 0.0
            && self.switch_oversubscription <= 1.0
            && self
                .per_dim_oversubscription
                .as_ref()
                .map(|v| v.iter().all(|&x| x <= 1.0))
                .unwrap_or(true)
            && self
                .per_dim_background
                .as_ref()
                .map(|v| v.iter().all(|&x| x <= 0.0))
                .unwrap_or(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DimKind, NetworkDim};

    fn topo() -> Topology {
        Topology::new(vec![
            NetworkDim::new(DimKind::Ring, 4, 200.0, 0.5),
            NetworkDim::new(DimKind::Switch, 8, 100.0, 1.0),
        ])
    }

    #[test]
    fn default_is_uncongested_and_nominal() {
        let cfg = FlowLevelConfig::default();
        assert!(cfg.is_uncongested());
        let caps = cfg.dim_capacities(&topo());
        assert!((caps[0] - 200e3).abs() < 1e-6);
        assert!((caps[1] - 100e3).abs() < 1e-6);
    }

    #[test]
    fn oversubscription_hits_switch_dims_only() {
        let cfg = FlowLevelConfig::oversubscribed(4.0);
        assert!(!cfg.is_uncongested());
        let caps = cfg.dim_capacities(&topo());
        assert!((caps[0] - 200e3).abs() < 1e-6, "ring untouched");
        assert!((caps[1] - 25e3).abs() < 1e-6, "switch divided by 4");
    }

    #[test]
    fn background_load_scales_every_dim() {
        let cfg = FlowLevelConfig::default().with_background_load(0.5);
        let caps = cfg.dim_capacities(&topo());
        assert!((caps[0] - 100e3).abs() < 1e-6);
        assert!((caps[1] - 50e3).abs() < 1e-6);
    }

    #[test]
    fn per_dim_override_wins() {
        let cfg = FlowLevelConfig {
            per_dim_oversubscription: Some(vec![2.0]),
            ..FlowLevelConfig::default()
        };
        assert_eq!(cfg.oversubscription(DimKind::Ring, 0), 2.0);
        // Dim 1 falls back to the kind default.
        assert_eq!(cfg.oversubscription(DimKind::Switch, 1), 1.0);
    }

    #[test]
    fn factors_below_one_clamp_to_one() {
        let cfg = FlowLevelConfig::oversubscribed(0.5);
        assert_eq!(cfg.switch_oversubscription, 1.0);
        assert_eq!(cfg.oversubscription(DimKind::Switch, 3), 1.0);
    }

    #[test]
    fn dim_background_over_idle_fabric_matches_scalar_background_exactly() {
        let t = topo();
        let uniform = FlowLevelConfig::default().with_background_load(0.4);
        let per_dim = FlowLevelConfig::default().with_dim_background(&[0.4, 0.4]);
        let a = uniform.dim_capacities(&t);
        let b = per_dim.dim_capacities(&t);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "uniform vs per-dim must agree bitwise");
        }
        assert!(!per_dim.is_uncongested());
    }

    #[test]
    fn dim_background_composes_with_scalar_background() {
        let cfg = FlowLevelConfig::default().with_background_load(0.5).with_dim_background(&[0.5]);
        // Job keeps (1 - 0.5)(1 - 0.5) = 0.25 of the link.
        assert!((cfg.background_for(0) - 0.75).abs() < 1e-12);
        // Dims past the override fall back to the scalar.
        assert_eq!(cfg.background_for(1), 0.5);
        // Combined load saturates at the ceiling, never a dead link.
        let hot = FlowLevelConfig::default().with_background_load(0.9).with_dim_background(&[0.9]);
        assert_eq!(hot.background_for(0), 0.95);
    }

    #[test]
    fn sanitized_repairs_struct_literal_garbage() {
        let cfg = FlowLevelConfig {
            switch_oversubscription: f64::NAN,
            background_load: f64::NAN,
            per_dim_oversubscription: Some(vec![0.25, f64::INFINITY]),
            per_dim_background: Some(vec![-1.0, 2.0, f64::NAN]),
            chunk_precedence: true,
        };
        let s = cfg.sanitized();
        assert_eq!(s.switch_oversubscription, 1.0);
        assert_eq!(s.background_load, 0.0);
        assert_eq!(s.per_dim_oversubscription, Some(vec![1.0, 1.0]));
        assert_eq!(s.per_dim_background, Some(vec![0.0, 0.95, 0.0]));
        assert!(s.chunk_precedence, "mode flag passes through sanitization");
        // NaN background no longer reaches the capacity table even
        // before sanitizing (accessors clamp too).
        assert!(cfg.dim_capacities(&topo()).iter().all(|c| c.is_finite()));
        // Idempotent and the identity on valid configs.
        assert_eq!(s.sanitized(), s);
        let valid = FlowLevelConfig::oversubscribed(4.0).with_background_load(0.3);
        assert_eq!(valid.sanitized(), valid);
    }
}
