//! Fabric capacity model for the flow-level backend.
//!
//! The analytical cost model credits every dimension its full nominal
//! per-NPU link bandwidth. Real fabrics fall short of that in two ways
//! the `FlowLevel` backend can express:
//!
//! - **Oversubscription** — a Switch dimension whose crossbar (or leaf/
//!   spine uplinks) serves only `1/k` of the sum of its edge links. When
//!   all NPUs of the dimension drive at once — exactly what collectives
//!   do — each sees `bw / k`.
//! - **Background load** — a fraction of every link consumed by
//!   co-tenant traffic (other jobs, storage, control plane), modelled as
//!   a uniform utilization the simulated job cannot claim.

use crate::topology::{DimKind, Topology};

/// Congestion parameters of the flow-level fabric.
#[derive(Debug, Clone, PartialEq)]
pub struct FlowLevelConfig {
    /// Oversubscription factor applied to every Switch dimension
    /// (`>= 1`; 1.0 = full bisection, the analytical assumption).
    pub switch_oversubscription: f64,
    /// Fraction of every link's bandwidth consumed by co-tenant traffic
    /// (`0.0..1.0`).
    pub background_load: f64,
    /// Optional per-dimension oversubscription override, outermost
    /// entries may be omitted (falls back to the kind-based default).
    pub per_dim_oversubscription: Option<Vec<f64>>,
}

impl Default for FlowLevelConfig {
    fn default() -> Self {
        Self {
            switch_oversubscription: 1.0,
            background_load: 0.0,
            per_dim_oversubscription: None,
        }
    }
}

impl FlowLevelConfig {
    /// An oversubscribed variant (factor applied to Switch dims).
    pub fn oversubscribed(factor: f64) -> Self {
        Self { switch_oversubscription: factor.max(1.0), ..Self::default() }
    }

    /// A multi-tenant variant: `load` of every link is already in use.
    pub fn with_background_load(mut self, load: f64) -> Self {
        self.background_load = load.clamp(0.0, 0.95);
        self
    }

    /// The oversubscription factor of topology dimension `dim_idx`.
    pub fn oversubscription(&self, kind: DimKind, dim_idx: usize) -> f64 {
        self.per_dim_oversubscription
            .as_ref()
            .and_then(|v| v.get(dim_idx))
            .copied()
            .unwrap_or(match kind {
                DimKind::Switch => self.switch_oversubscription,
                _ => 1.0,
            })
            .max(1.0)
    }

    /// Effective per-NPU service rate (bytes/us) on a dimension whose
    /// nominal link rate is `nominal_bytes_per_us`.
    pub fn effective_rate(
        &self,
        nominal_bytes_per_us: f64,
        kind: DimKind,
        dim_idx: usize,
    ) -> f64 {
        let over = self.oversubscription(kind, dim_idx);
        nominal_bytes_per_us * (1.0 - self.background_load.clamp(0.0, 0.95)) / over
    }

    /// Per-dimension capacities (bytes/us, per NPU lane) for the whole
    /// topology — the resource table of the flow simulator.
    pub fn dim_capacities(&self, topo: &Topology) -> Vec<f64> {
        topo.dims
            .iter()
            .enumerate()
            .map(|(d, nd)| self.effective_rate(nd.bandwidth_gbps * 1e3, nd.kind, d))
            .collect()
    }

    /// True when this config cannot slow any transfer down (the
    /// flow-level model then matches the analytical one on single
    /// uncontended collectives).
    pub fn is_uncongested(&self) -> bool {
        self.background_load <= 0.0
            && self.switch_oversubscription <= 1.0
            && self
                .per_dim_oversubscription
                .as_ref()
                .map(|v| v.iter().all(|&x| x <= 1.0))
                .unwrap_or(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{DimKind, NetworkDim};

    fn topo() -> Topology {
        Topology::new(vec![
            NetworkDim::new(DimKind::Ring, 4, 200.0, 0.5),
            NetworkDim::new(DimKind::Switch, 8, 100.0, 1.0),
        ])
    }

    #[test]
    fn default_is_uncongested_and_nominal() {
        let cfg = FlowLevelConfig::default();
        assert!(cfg.is_uncongested());
        let caps = cfg.dim_capacities(&topo());
        assert!((caps[0] - 200e3).abs() < 1e-6);
        assert!((caps[1] - 100e3).abs() < 1e-6);
    }

    #[test]
    fn oversubscription_hits_switch_dims_only() {
        let cfg = FlowLevelConfig::oversubscribed(4.0);
        assert!(!cfg.is_uncongested());
        let caps = cfg.dim_capacities(&topo());
        assert!((caps[0] - 200e3).abs() < 1e-6, "ring untouched");
        assert!((caps[1] - 25e3).abs() < 1e-6, "switch divided by 4");
    }

    #[test]
    fn background_load_scales_every_dim() {
        let cfg = FlowLevelConfig::default().with_background_load(0.5);
        let caps = cfg.dim_capacities(&topo());
        assert!((caps[0] - 100e3).abs() < 1e-6);
        assert!((caps[1] - 50e3).abs() < 1e-6);
    }

    #[test]
    fn per_dim_override_wins() {
        let cfg = FlowLevelConfig {
            per_dim_oversubscription: Some(vec![2.0]),
            ..FlowLevelConfig::default()
        };
        assert_eq!(cfg.oversubscription(DimKind::Ring, 0), 2.0);
        // Dim 1 falls back to the kind default.
        assert_eq!(cfg.oversubscription(DimKind::Switch, 1), 1.0);
    }

    #[test]
    fn factors_below_one_clamp_to_one() {
        let cfg = FlowLevelConfig::oversubscribed(0.5);
        assert_eq!(cfg.switch_oversubscription, 1.0);
        assert_eq!(cfg.oversubscription(DimKind::Switch, 3), 1.0);
    }
}
