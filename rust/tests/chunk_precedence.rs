//! Chunk-level flow precedence, pinned by a closed-form conformance and
//! property suite.
//!
//! With `FlowLevelConfig::with_chunk_precedence(true)` the flow rung
//! admits each collective's chunks as a per-(job, dim) FIFO precedence
//! DAG instead of a steady-state bottleneck tail. This suite pins the
//! mode three ways:
//!
//! - **Closed-form conformance** — a single uncontended collective
//!   drained under chunk precedence must match the `compose_phases`
//!   closed form *exactly*, for both the Baseline and BlueConnect
//!   multi-dim compositions (the `ChunkSchedule` recurrence theorem).
//! - **Properties** (`util::prop`) — byte conservation, chunk-FIFO
//!   non-inversion within (job, phase), monotonicity in chunk count and
//!   concurrent-job count, and run-to-run determinism of the chunked
//!   event core.
//! - **Cache hygiene** — chunked and steady-state evaluations of the
//!   same design never share a memoized collective cost (the mode folds
//!   into the backend `cache_tag`, hence into `CollKey`), and the PsA
//!   "Chunk Precedence" knob's Off slot is bit-identical to a schema
//!   without the knob.

use cosmic::collective::{
    compose_phases, ChunkSchedule, CollAlgo, CollectiveKind, MultiDimPolicy, SchedulingPolicy,
};
use cosmic::dse::{Environment, Objective, WorkloadSpec};
use cosmic::harness::median_baseline_par;
use cosmic::netsim::{
    ChunkFlowSpec, ChunkSegment, CollectiveCall, FlowLevel, FlowLevelConfig, FlowSim,
    NetworkBackend, OverlapCall,
};
use cosmic::psa::{paper_table4_schema, with_chunk_precedence_param, with_fidelity_param};
use cosmic::pss::Pss;
use cosmic::sim::{presets, CollCostMemo, CollKey, LocalCollMemo};
use cosmic::topology::{DimCost, DimKind, Topology};
use cosmic::util::prop::check;
use cosmic::util::Rng;
use cosmic::workload::models::presets as wl;

fn topo() -> Topology {
    let kinds = [DimKind::Ring, DimKind::Switch];
    Topology::from_arrays(&kinds, &[4, 8], &[200.0, 100.0], &[0.5, 1.0])
}

fn span_of(topo: &Topology) -> Vec<(DimCost, usize)> {
    topo.dims.iter().enumerate().map(|(d, nd)| (DimCost::from_dim(nd), d)).collect()
}

// ---------------------------------------------------------------------------
// Closed-form conformance: uncontended chunked drain == compose_phases.
// ---------------------------------------------------------------------------

#[test]
fn uncontended_chunked_drain_matches_compose_phases_exactly() {
    let topo = topo();
    let span = span_of(&topo);
    let algos = [CollAlgo::Ring, CollAlgo::Rhd];
    let configs = [
        FlowLevelConfig::default().with_chunk_precedence(true),
        FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true),
        FlowLevelConfig::default().with_background_load(0.4).with_chunk_precedence(true),
    ];
    for config in configs {
        let flow = FlowLevel::new(config);
        for policy in [MultiDimPolicy::Baseline, MultiDimPolicy::BlueConnect] {
            for kind in [CollectiveKind::AllReduce, CollectiveKind::ReduceScatter] {
                for chunks in [1u32, 2, 5, 16] {
                    let c = CollectiveCall {
                        kind,
                        policy,
                        algos: &algos,
                        span: &span,
                        topology: &topo,
                        bytes: 48e6,
                        chunks,
                    };
                    // The closed form over the congested per-chunk phase
                    // durations — exactly what collective_time_us prices.
                    let durations: Vec<f64> =
                        flow.phase_times_us(&c).iter().map(|(_, t)| *t).collect();
                    let closed = compose_phases(policy, &durations, chunks);
                    let blocking = flow.collective_time_us(&c);
                    assert!(
                        (blocking - closed).abs() <= 1e-9 * closed.max(1.0),
                        "{policy:?}/{kind:?} chunks={chunks}: blocking {blocking} vs {closed}"
                    );
                    let issue = 12.25;
                    let job = OverlapCall { layer: 0, issue_us: issue, call: c };
                    let drain = flow.drain_overlapped(&[job], SchedulingPolicy::Fifo);
                    assert_eq!(drain.len(), 1);
                    let drained = drain[0].1 - issue;
                    assert!(
                        (drained - closed).abs() <= 1e-6 * closed.max(1.0),
                        "{policy:?}/{kind:?} chunks={chunks}: drain {drained} vs closed {closed}"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Properties of the chunked event core (util::prop).
// ---------------------------------------------------------------------------

/// Build one job's chunk-precedence flow DAG from a per-phase
/// `(dim, total bytes)` plan: `chunks` FIFO copies of the plan wired by
/// [`ChunkSchedule`], flow `k * plan.len() + p` being chunk `k` phase
/// `p`, each carrying `bytes / chunks`.
fn chunked_job(
    plan: &[(usize, f64)],
    caps: &[f64],
    chunks: u32,
    policy: MultiDimPolicy,
    latency_us: f64,
) -> Vec<ChunkFlowSpec> {
    let durations: Vec<f64> =
        plan.iter().map(|&(d, b)| b / chunks as f64 / caps[d]).collect();
    let sched = ChunkSchedule::new(policy, &durations);
    let np = plan.len();
    let mut flows = Vec::with_capacity(np * chunks as usize);
    for k in 0..chunks {
        for (p, &(dim, bytes)) in plan.iter().enumerate() {
            let mut deps = Vec::new();
            sched.deps(k, p, |dk, dp| deps.push(dk as usize * np + dp));
            flows.push(ChunkFlowSpec {
                chunk: k,
                phase: p,
                dim,
                bytes: bytes / chunks as f64,
                latency_us,
                deps,
            });
        }
    }
    flows
}

fn rand_policy(rng: &mut Rng) -> MultiDimPolicy {
    if rng.gen_range(2) == 0 {
        MultiDimPolicy::Baseline
    } else {
        MultiDimPolicy::BlueConnect
    }
}

#[test]
fn prop_chunked_bytes_are_conserved() {
    check("chunked byte conservation", 24, |rng| {
        let ndims = 2 + rng.gen_range(3);
        let caps: Vec<f64> = (0..ndims).map(|_| 50.0 + rng.gen_f64() * 150.0).collect();
        let policy = rand_policy(rng);
        let chunks = 1 + rng.gen_range(4) as u32;
        let jobs: Vec<(f64, Vec<ChunkFlowSpec>)> = (0..1 + rng.gen_range(3))
            .map(|_| {
                let plan: Vec<(usize, f64)> = (0..1 + rng.gen_range(3))
                    .map(|_| (rng.gen_range(ndims), 1e3 + rng.gen_f64() * 1e6))
                    .collect();
                (rng.gen_f64() * 10.0, chunked_job(&plan, &caps, chunks, policy, rng.gen_f64()))
            })
            .collect();
        let sent: f64 =
            jobs.iter().flat_map(|(_, fs)| fs.iter().map(|f| f.bytes)).sum();
        let mut segments: Vec<ChunkSegment> = Vec::new();
        let results = FlowSim::new(caps).run_chunked_recorded(&jobs, &mut segments);
        let served: f64 = results.iter().map(|r| r.served_bytes).sum();
        if (served - sent).abs() > 1e-9 * sent.max(1.0) {
            return Err(format!("served {served} bytes of {sent} sent"));
        }
        let flows: usize = jobs.iter().map(|(_, fs)| fs.len()).sum();
        if segments.len() != flows {
            return Err(format!("{} segments for {flows} flows", segments.len()));
        }
        let seg_bytes: f64 = segments.iter().map(|s| s.bytes).sum();
        if (seg_bytes - sent).abs() > 1e-9 * sent.max(1.0) {
            return Err(format!("segments carry {seg_bytes} bytes of {sent} sent"));
        }
        Ok(())
    });
}

#[test]
fn prop_chunk_fifo_never_inverts_within_a_job() {
    check("chunk FIFO non-inversion", 24, |rng| {
        let ndims = 2 + rng.gen_range(2);
        let caps: Vec<f64> = (0..ndims).map(|_| 50.0 + rng.gen_f64() * 150.0).collect();
        let policy = rand_policy(rng);
        let chunks = 2 + rng.gen_range(6) as u32;
        let jobs: Vec<(f64, Vec<ChunkFlowSpec>)> = (0..1 + rng.gen_range(3))
            .map(|_| {
                let plan: Vec<(usize, f64)> = (0..1 + rng.gen_range(3))
                    .map(|_| (rng.gen_range(ndims), 1e4 + rng.gen_f64() * 1e6))
                    .collect();
                (rng.gen_f64() * 5.0, chunked_job(&plan, &caps, chunks, policy, 0.0))
            })
            .collect();
        let mut segments: Vec<ChunkSegment> = Vec::new();
        FlowSim::new(caps).run_chunked_recorded(&jobs, &mut segments);
        // Within one (job, phase) lane, chunk k+1's data phase cannot
        // begin before chunk k has drained: completion-based FIFO.
        let mut last: Vec<((usize, usize), (u32, f64))> = Vec::new();
        let mut ordered = segments.clone();
        ordered.sort_by_key(|s| (s.job, s.phase, s.chunk));
        for seg in &ordered {
            let lane = (seg.job, seg.phase);
            match last.iter_mut().find(|(k, _)| *k == lane) {
                Some((_, (prev_chunk, prev_finish))) => {
                    if seg.chunk != *prev_chunk + 1 {
                        return Err(format!(
                            "lane {lane:?}: chunk {} follows {prev_chunk}",
                            seg.chunk
                        ));
                    }
                    if seg.start_us < *prev_finish - 1e-9 {
                        return Err(format!(
                            "lane {lane:?}: chunk {} started at {} before chunk {} drained at {}",
                            seg.chunk, seg.start_us, prev_chunk, prev_finish
                        ));
                    }
                    *prev_chunk = seg.chunk;
                    *prev_finish = seg.finish_us;
                }
                None => {
                    if seg.chunk != 0 {
                        return Err(format!("lane {lane:?} begins at chunk {}", seg.chunk));
                    }
                    last.push((lane, (0, seg.finish_us)));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_is_monotone_in_chunk_count() {
    // A lone zero-latency job on a flow shop (each phase its own dim):
    // Baseline T(K) = max + (sum - max)/K, BlueConnect T(K) = max +
    // fill/K — both non-increasing in K, so finer chunking never slows
    // an uncontended collective.
    check("chunk-count monotonicity", 16, |rng| {
        let phases = 1 + rng.gen_range(4);
        let caps: Vec<f64> = (0..phases).map(|_| 50.0 + rng.gen_f64() * 150.0).collect();
        let plan: Vec<(usize, f64)> =
            (0..phases).map(|p| (p, 1e4 + rng.gen_f64() * 1e6)).collect();
        let policy = rand_policy(rng);
        let sim = FlowSim::new(caps.clone());
        let mut prev = f64::INFINITY;
        for chunks in [1u32, 2, 4, 8, 16] {
            let jobs = vec![(0.0, chunked_job(&plan, &caps, chunks, policy, 0.0))];
            let t = sim.run_chunked(&jobs)[0].finish_us;
            if t > prev * (1.0 + 1e-9) {
                return Err(format!("{policy:?}: {chunks} chunks took {t} > {prev}"));
            }
            prev = t;
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_is_monotone_in_concurrent_job_count() {
    // Identical jobs issued together run in lockstep: every shared dim
    // splits evenly across the distinct jobs, so adding a tenant can
    // only stretch the makespan.
    check("job-count monotonicity", 12, |rng| {
        let ndims = 2 + rng.gen_range(2);
        let caps: Vec<f64> = (0..ndims).map(|_| 50.0 + rng.gen_f64() * 150.0).collect();
        let policy = rand_policy(rng);
        let chunks = 1 + rng.gen_range(4) as u32;
        let plan: Vec<(usize, f64)> = (0..1 + rng.gen_range(3))
            .map(|_| (rng.gen_range(ndims), 1e4 + rng.gen_f64() * 1e6))
            .collect();
        let latency = rng.gen_f64() * 2.0;
        let sim = FlowSim::new(caps.clone());
        let mut prev = 0.0;
        for n in [1usize, 2, 4] {
            let jobs: Vec<(f64, Vec<ChunkFlowSpec>)> = (0..n)
                .map(|_| (0.0, chunked_job(&plan, &caps, chunks, policy, latency)))
                .collect();
            let t = sim
                .run_chunked(&jobs)
                .iter()
                .map(|r| r.finish_us)
                .fold(0.0, f64::max);
            if t < prev * (1.0 - 1e-9) {
                return Err(format!("{policy:?}: {n} jobs finished at {t} < {prev}"));
            }
            prev = t;
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_runs_are_bit_deterministic() {
    check("chunked determinism", 16, |rng| {
        let ndims = 2 + rng.gen_range(3);
        let caps: Vec<f64> = (0..ndims).map(|_| 50.0 + rng.gen_f64() * 150.0).collect();
        let policy = rand_policy(rng);
        let chunks = 1 + rng.gen_range(6) as u32;
        let jobs: Vec<(f64, Vec<ChunkFlowSpec>)> = (0..1 + rng.gen_range(4))
            .map(|_| {
                let plan: Vec<(usize, f64)> = (0..1 + rng.gen_range(3))
                    .map(|_| (rng.gen_range(ndims), 1e3 + rng.gen_f64() * 1e6))
                    .collect();
                (rng.gen_f64() * 10.0, chunked_job(&plan, &caps, chunks, policy, rng.gen_f64()))
            })
            .collect();
        let sim = FlowSim::new(caps);
        let mut seg_a: Vec<ChunkSegment> = Vec::new();
        let mut seg_b: Vec<ChunkSegment> = Vec::new();
        let a = sim.run_chunked_recorded(&jobs, &mut seg_a);
        let b = sim.run_chunked_recorded(&jobs, &mut seg_b);
        for (x, y) in a.iter().zip(b.iter()) {
            if x.finish_us.to_bits() != y.finish_us.to_bits()
                || x.served_bytes.to_bits() != y.served_bytes.to_bits()
            {
                return Err(format!("results drifted: {x:?} vs {y:?}"));
            }
        }
        if seg_a != seg_b {
            return Err("segment streams drifted between identical runs".into());
        }
        // The plain entry point is the recorded one minus observation.
        let plain = sim.run_chunked(&jobs);
        if plain != a {
            return Err("recording perturbed the simulation".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Cache hygiene: the mode can never alias memoized costs.
// ---------------------------------------------------------------------------

#[test]
fn chunked_and_steady_backends_never_share_memoized_costs() {
    // Deliberate-collision regression: identical CollKeys except for the
    // backend tag must hit distinct memo entries — the chunk-precedence
    // bit folds into FlowLevel::cache_tag, so a chunked evaluation can
    // never be served a steady-state collective cost (or vice versa).
    let steady = FlowLevel::new(FlowLevelConfig::oversubscribed(4.0));
    let chunked =
        FlowLevel::new(FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true));
    assert_ne!(steady.cache_tag(), chunked.cache_tag());
    let key = |backend: u64| CollKey {
        backend,
        topology: 0x1111,
        algos: 0x2222,
        policy: MultiDimPolicy::Baseline,
        kind: CollectiveKind::AllReduce,
        stride: 1,
        size: 4,
        bytes: 64e6_f64.to_bits(),
        chunks: 4,
        scenario: 0,
        traffic: 0,
    };
    let mut memo = LocalCollMemo::default();
    let a = memo.cost_us(&key(steady.cache_tag()), &mut || 111.0);
    let b = memo.cost_us(&key(chunked.cache_tag()), &mut || 222.0);
    assert_eq!(a, 111.0);
    assert_eq!(b, 222.0, "chunked evaluation was served the steady-state memo entry");
    // And both hit their own entries on re-query.
    assert_eq!(memo.cost_us(&key(steady.cache_tag()), &mut || -1.0), 111.0);
    assert_eq!(memo.cost_us(&key(chunked.cache_tag()), &mut || -1.0), 222.0);
}

// ---------------------------------------------------------------------------
// The PsA "Chunk Precedence" knob end to end.
// ---------------------------------------------------------------------------

/// Environment over system1 with the fidelity + chunk-precedence knobs
/// appended and a congested flow fabric, so the knob has something to
/// change.
fn knob_env(with_knob: bool) -> Environment {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let spec = WorkloadSpec::training(model, 1024);
    let baseline = median_baseline_par(&cluster, &spec);
    let mut schema = with_fidelity_param(paper_table4_schema(
        cluster.npus(),
        cluster.topology.num_dims(),
    ));
    if with_knob {
        schema = with_chunk_precedence_param(schema);
    }
    let pss = Pss::new(schema, cluster, baseline);
    Environment::new(pss, vec![spec], Objective::PerfPerBwPerNpu)
        .with_flow_config(FlowLevelConfig::oversubscribed(4.0))
}

/// The baseline genome with the fidelity knob flipped to FlowLevel and
/// (when present) the chunk knob set to `chunk_slot`.
fn flow_genome(env: &Environment, with_knob: bool, chunk_slot: usize) -> Vec<usize> {
    let mut g = env.pss.baseline_genome();
    let n = g.len();
    if with_knob {
        g[n - 2] = 1; // Network Fidelity = FlowLevel
        g[n - 1] = chunk_slot; // Chunk Precedence
    } else {
        g[n - 1] = 1; // Network Fidelity = FlowLevel
    }
    g
}

#[test]
fn chunk_knob_off_is_bit_identical_to_a_schema_without_the_knob() {
    let bare = knob_env(false);
    let with = knob_env(true);
    let out_bare = bare.evaluate_nomemo(&flow_genome(&bare, false, 0));
    let out_with = with.evaluate_nomemo(&flow_genome(&with, true, 0));
    assert!(out_bare.invalid_reason.is_none(), "{:?}", out_bare.invalid_reason);
    assert!(out_with.invalid_reason.is_none(), "{:?}", out_with.invalid_reason);
    assert_eq!(
        out_bare.reward.to_bits(),
        out_with.reward.to_bits(),
        "the Off slot must price exactly like a knob-free schema"
    );
    let (a, b) = (&out_bare.reports, &out_with.reports);
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.latency_us.to_bits(), y.latency_us.to_bits());
    }
}

#[test]
fn chunked_evaluations_are_order_independent_of_steady_ones() {
    // Warm-cache regression: evaluating Off then On (shared cross-
    // evaluation cache) must match a cold On evaluation bit for bit —
    // a backend-tag collision between the modes would leak memoized
    // costs across and break this.
    let warm = knob_env(true);
    let g_off = flow_genome(&warm, true, 0);
    let g_on = flow_genome(&warm, true, 1);
    let _ = warm.evaluate_nomemo(&g_off);
    let warm_on = warm.evaluate_nomemo(&g_on);
    let cold = knob_env(true);
    let cold_on = cold.evaluate_nomemo(&g_on);
    assert!(warm_on.invalid_reason.is_none(), "{:?}", warm_on.invalid_reason);
    assert_eq!(
        warm_on.reward.to_bits(),
        cold_on.reward.to_bits(),
        "warm-cache chunked evaluation drifted from the cold one"
    );
    // And the mirrored order: On first, then Off, vs cold Off.
    let warm2 = knob_env(true);
    let _ = warm2.evaluate_nomemo(&g_on);
    let warm_off = warm2.evaluate_nomemo(&g_off);
    let cold_off = knob_env(true).evaluate_nomemo(&g_off);
    assert_eq!(
        warm_off.reward.to_bits(),
        cold_off.reward.to_bits(),
        "warm-cache steady evaluation drifted from the cold one"
    );
}
