//! End-to-end tests of the multi-tenant traffic subsystem: seeded
//! generator determinism, the nominal/uniform bit-identity pins on all
//! three fidelity rungs, load monotonicity, cache-tag distinctness
//! (including composition with fault views), JSON replay, traffic spans
//! in the trace timeline, and a traffic-aware search driven through the
//! public API.

use cosmic::agents::AgentKind;
use cosmic::dse::{DseConfig, DseRunner, Objective, RobustAggregate, WorkloadSpec};
use cosmic::faults::{FaultScenario, FaultView};
use cosmic::harness::make_env_traffic;
use cosmic::netsim::{
    Analytical, FidelityMode, FlowLevel, FlowLevelConfig, NetworkBackend, PacketLevelConfig,
    TrafficSuite, TrafficTrace, TrafficView,
};
use cosmic::obs::{tracks, Recorder};
use cosmic::pss::SearchScope;
use cosmic::sim::{presets, ClusterConfig, SimReport, Simulator};
use cosmic::util::prop::check;
use cosmic::workload::models::presets as wl;
use cosmic::workload::{ExecutionMode, ModelConfig, Parallelization};
use std::sync::Arc;

fn setup() -> (ClusterConfig, ModelConfig, Parallelization) {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).unwrap();
    (cluster, model, par)
}

fn run_with(
    sim: Simulator,
    cluster: &ClusterConfig,
    model: &ModelConfig,
    par: &Parallelization,
) -> SimReport {
    sim.run(cluster, model, par, 1024, ExecutionMode::Training).unwrap()
}

#[test]
fn prop_equal_seeds_reproduce_bit_identical_reports() {
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    check("traffic seed determinism", 12, |rng| {
        let seed = rng.next_u64() % 1000;
        let profile = ["constant", "diurnal", "bursty"][(seed % 3) as usize];
        let a = TrafficTrace::from_profile(profile, seed, dims).map_err(|e| e.to_string())?;
        let b = TrafficTrace::from_profile(profile, seed, dims).map_err(|e| e.to_string())?;
        if a.fingerprint() != b.fingerprint() {
            return Err(format!("{profile} seed {seed}: fingerprints differ"));
        }
        let ra = run_with(Simulator::new().with_traffic(Arc::new(a)), &cluster, &model, &par);
        let rb = run_with(Simulator::new().with_traffic(Arc::new(b)), &cluster, &model, &par);
        if ra.latency_us.to_bits() != rb.latency_us.to_bits() {
            return Err(format!("{profile} seed {seed}: latency not bit-identical"));
        }
        Ok(())
    });
}

#[test]
fn nominal_trace_matches_traffic_free_on_every_rung() {
    // The golden-corpus pin: attaching an idle trace must leave the
    // SimReport bit-identical on every fidelity rung.
    let (cluster, model, par) = setup();
    for fidelity in [FidelityMode::Analytical, FidelityMode::FlowLevel, FidelityMode::Packet] {
        let plain = run_with(Simulator::new().with_fidelity(fidelity), &cluster, &model, &par);
        let traced = run_with(
            Simulator::new().with_fidelity(fidelity).with_traffic(Arc::new(TrafficTrace::nominal())),
            &cluster,
            &model,
            &par,
        );
        assert_eq!(plain, traced, "{fidelity:?}: nominal trace perturbed the report");
    }
}

#[test]
fn uniform_trace_matches_background_load_on_fabric_rungs() {
    // A flat co-tenant at utilization u must price exactly like the
    // fabric's scalar background-load knob — same floating-point path,
    // bit for bit — on both fabric-backed rungs.
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    let util = 0.35;
    let flow_bg = run_with(
        Simulator::new().with_flow_config(FlowLevelConfig::default().with_background_load(util)),
        &cluster,
        &model,
        &par,
    );
    let flow_tr = run_with(
        Simulator::new()
            .with_fidelity(FidelityMode::FlowLevel)
            .with_traffic(Arc::new(TrafficTrace::uniform(dims, util))),
        &cluster,
        &model,
        &par,
    );
    assert_eq!(flow_bg.latency_us.to_bits(), flow_tr.latency_us.to_bits(), "flow rung diverged");
    assert_eq!(flow_bg, flow_tr);

    let pkt_bg = run_with(
        Simulator::new().with_packet_config(PacketLevelConfig {
            fabric: FlowLevelConfig::default().with_background_load(util),
            ..PacketLevelConfig::default()
        }),
        &cluster,
        &model,
        &par,
    );
    let pkt_tr = run_with(
        Simulator::new()
            .with_fidelity(FidelityMode::Packet)
            .with_traffic(Arc::new(TrafficTrace::uniform(dims, util))),
        &cluster,
        &model,
        &par,
    );
    assert_eq!(pkt_bg.latency_us.to_bits(), pkt_tr.latency_us.to_bits(), "packet rung diverged");
    assert_eq!(pkt_bg, pkt_tr);
}

#[test]
fn prop_heavier_traffic_never_speeds_up_any_rung() {
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    for fidelity in [FidelityMode::Analytical, FidelityMode::FlowLevel, FidelityMode::Packet] {
        let mut prev = 0.0f64;
        for util in [0.0, 0.2, 0.4, 0.6] {
            let rep = run_with(
                Simulator::new()
                    .with_fidelity(fidelity)
                    .with_traffic(Arc::new(TrafficTrace::uniform(dims, util))),
                &cluster,
                &model,
                &par,
            );
            assert!(
                rep.latency_us >= prev * (1.0 - 1e-9),
                "{fidelity:?}: latency shrank when util rose to {util}"
            );
            prev = rep.latency_us;
        }
    }
}

#[test]
fn cache_tags_distinguish_traffic_and_fault_wrapping() {
    // The memo-safety pin: every distinct wrapping (and wrapping order)
    // must present a distinct backend cache tag, so shared collective
    // memos never serve one tenant mix the other's costs.
    let dims = presets::system1().topology.num_dims();
    let base: Arc<dyn NetworkBackend> = Arc::new(FlowLevel::new(FlowLevelConfig::default()));
    let trace = Arc::new(TrafficTrace::from_profile("diurnal", 7, dims).unwrap());
    let other = Arc::new(TrafficTrace::from_profile("diurnal", 8, dims).unwrap());
    let faults = Arc::new(FaultScenario::from_seed(3, dims));

    let traffic = TrafficView::wrap(Arc::clone(&base), Arc::clone(&trace));
    let traffic_other = TrafficView::wrap(Arc::clone(&base), Arc::clone(&other));
    let faulted = FaultView::wrap(Arc::clone(&base), &faults.links);
    let both = TrafficView::wrap(FaultView::wrap(Arc::clone(&base), &faults.links), trace);
    let tags = [
        base.cache_tag(),
        traffic.cache_tag(),
        traffic_other.cache_tag(),
        faulted.cache_tag(),
        both.cache_tag(),
    ];
    for i in 0..tags.len() {
        for j in (i + 1)..tags.len() {
            assert_ne!(tags[i], tags[j], "tags {i} and {j} collide: {:016x}", tags[i]);
        }
    }
    // Analytical base wraps too, with its own distinct tag.
    let analytical = TrafficView::wrap(
        Arc::new(Analytical::default()),
        Arc::new(TrafficTrace::from_profile("bursty", 5, dims).unwrap()),
    );
    assert_ne!(analytical.cache_tag(), traffic.cache_tag());
}

#[test]
fn json_replay_reproduces_the_simulation() {
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    let trace = TrafficTrace::from_profile("bursty", 11, dims).unwrap();
    let json = trace.to_json();
    cosmic::util::json::validate(&json).unwrap();
    let replayed = TrafficTrace::from_json(&json).unwrap();
    assert_eq!(trace.fingerprint(), replayed.fingerprint());
    let live = run_with(Simulator::new().with_traffic(Arc::new(trace)), &cluster, &model, &par);
    let replay =
        run_with(Simulator::new().with_traffic(Arc::new(replayed)), &cluster, &model, &par);
    assert_eq!(live.latency_us.to_bits(), replay.latency_us.to_bits());
    assert_eq!(live, replay);
}

#[test]
fn traffic_spans_land_on_the_traffic_track() {
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    let rec = Arc::new(Recorder::new());
    Simulator::new()
        .with_traffic(Arc::new(TrafficTrace::from_profile("bursty", 9, dims).unwrap()))
        .with_trace_sink(Arc::clone(&rec))
        .run(&cluster, &model, &par, 1024, ExecutionMode::Training)
        .unwrap();
    let spans = rec.spans();
    let traffic_spans: Vec<_> = spans.iter().filter(|s| s.pid == tracks::TRAFFIC_PID).collect();
    assert!(!traffic_spans.is_empty(), "no spans on the co-tenant traffic track");
    assert!(traffic_spans.iter().all(|s| s.name.starts_with("co-tenant")));
    cosmic::util::json::validate(&cosmic::obs::chrome_trace_json(&spans)).unwrap();
}

#[test]
fn traffic_search_end_to_end() {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let mut env = make_env_traffic(
        cluster,
        vec![WorkloadSpec::training(model, 1024)],
        Objective::PerfPerBwPerNpu,
        "diurnal",
        7,
        2,
        RobustAggregate::Expected,
    )
    .unwrap();
    let cfg = DseConfig::new(AgentKind::Ga, 40, 42);
    let result = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
    assert_eq!(result.history.len(), 40);
    assert!(result.best_reward > 0.0, "traffic-aware search found no valid design");
    assert!(env.traffic_evals() > 0, "traffic mode never swept the suite");
    assert_eq!(env.eval_panics(), 0);
    let (suite, aggregate) = env.traffic_suite().expect("traffic mode is on");
    assert_eq!(suite.len(), 3); // nominal + 2 seeded
    assert_eq!(aggregate, RobustAggregate::Expected);
    assert!(!result.best_reports.is_empty());
}

#[test]
fn worst_case_traffic_bounds_expected_from_below() {
    let (cluster, model, _) = setup();
    let dims = cluster.topology.num_dims();
    let suite = || TrafficSuite::generate("bursty", 13, 3, dims).unwrap();
    let build = |aggregate| {
        cosmic::harness::make_env(
            presets::system1(),
            vec![WorkloadSpec::training(model.clone(), 1024)],
            Objective::PerfPerBwPerNpu,
        )
        .with_traffic_suite(suite(), aggregate)
    };
    let g = build(RobustAggregate::Expected).pss.baseline_genome();
    let expected = build(RobustAggregate::Expected).evaluate_nomemo(&g).reward;
    let worst = build(RobustAggregate::WorstCase).evaluate_nomemo(&g).reward;
    assert!(expected > 0.0 && worst > 0.0);
    assert!(worst <= expected, "min over traces exceeded their mean");
}
