//! Direct checks of the paper's quantitative claims (where our simulated
//! substrate can be expected to reproduce the *shape*; see
//! EXPERIMENTS.md for the full paper-vs-measured record).

use cosmic::psa::space::exhaustive_search_years;
use cosmic::psa::{design_space_size, paper_table1_schema};
use cosmic::sim::{presets, Simulator};
use cosmic::workload::models::presets as wl;
use cosmic::workload::{enumerate_parallelizations, ExecutionMode, Parallelization};

#[test]
fn claim_286_parallelization_combos() {
    // §3.2: "Parallelization dimensions (DP, PP, SP), each ranging
    // between (1,1,1024)…  already creates 286 potential options."
    assert_eq!(enumerate_parallelizations(1024, 1024, &[false]).len(), 286);
}

#[test]
fn claim_769e13_design_points() {
    // §3.2 / Table 1: ~7.69e13 total points for the 1,024-NPU 4D space.
    let n = design_space_size(&paper_table1_schema(1024, 4), 1024);
    assert!((n / 7.69e13 - 1.0).abs() < 0.01, "n = {n:.4e}");
}

#[test]
fn claim_244e6_years_exhaustive() {
    // §3.2: "an exhaustive search would require an impractical 2.44e6
    // years" at 1 s per design point.
    let n = design_space_size(&paper_table1_schema(1024, 4), 1024);
    let years = exhaustive_search_years(n, 1.0);
    assert!((years / 2.44e6 - 1.0).abs() < 0.02, "years = {years:.4e}");
}

#[test]
fn claim_table2_model_scales() {
    // Table 2 (+abstract): models "up to 175 billion parameters".
    let sizes: Vec<f64> =
        wl::all().iter().map(|m| m.total_params() as f64).collect();
    assert!(sizes[0] > 1.5e11 && sizes[0] < 2.0e11); // GPT3-175B
    assert!(sizes[1] > 1.0e10 && sizes[1] < 1.6e10); // GPT3-13B
    assert!(sizes[2] < 1.0e8); // ViT-Base
    assert!(sizes[3] > sizes[2] && sizes[3] < 4.0e8); // ViT-Large
}

#[test]
fn claim_table3_systems() {
    // Table 3 / §5.1: 512, 1,024 and 2,048 NPUs.
    assert_eq!(presets::system1().npus(), 512);
    assert_eq!(presets::system2().npus(), 1024);
    assert_eq!(presets::system3().npus(), 2048);
}

#[test]
fn claim_table5_designs_are_valid_and_good() {
    // Table 5's two discovered configurations must at least be *valid*
    // on System 2 and beat a pure-DP strawman.
    let sim = Simulator::new();
    let model = wl::gpt3_175b().with_simulated_layers(4);
    let base_topo = presets::system2();

    // Perf-per-BW/NPU column: DP=64 PP=1 SP=4, sharded.
    let t5_bw = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
    let r_bw = sim.run(&base_topo, &model, &t5_bw, 2048, ExecutionMode::Training);
    assert!(r_bw.is_ok(), "Table 5 BW config invalid: {:?}", r_bw.err());

    // Perf-per-cost column: DP=128 PP=1 SP=4, sharded.
    let t5_cost = Parallelization::derive(1024, 128, 4, 1, true).unwrap();
    let r_cost = sim.run(&base_topo, &model, &t5_cost, 2048, ExecutionMode::Training);
    assert!(r_cost.is_ok(), "Table 5 cost config invalid: {:?}", r_cost.err());

    // Strawman: unsharded DP=1024 (pure DP) must be memory-invalid.
    let straw = Parallelization::derive(1024, 1024, 1, 1, false).unwrap();
    assert!(sim.run(&base_topo, &model, &straw, 2048, ExecutionMode::Training).is_err());
}

#[test]
fn claim_inference_prefers_latency_optimized_collectives() {
    // §6.3: "latency-optimized collectives are preferred over
    // bandwidth-optimized ones due to the small message sizes during the
    // decode phase". Check the cost model agrees at decode-message
    // scale on System 2's dimensions.
    use cosmic::collective::{collective_time_us, CollAlgo, CollectiveKind};
    use cosmic::topology::DimCost;
    let topo = presets::system2().topology;
    let decode_msg = 64.0 * 1024.0; // tens of KB per decode collective
    for dim in &topo.dims {
        let d = DimCost::from_dim(dim);
        let ring = collective_time_us(CollAlgo::Ring, CollectiveKind::AllReduce, &d, decode_msg);
        let best_lat = [CollAlgo::Direct, CollAlgo::Rhd, CollAlgo::Dbt]
            .iter()
            .map(|a| collective_time_us(*a, CollectiveKind::AllReduce, &d, decode_msg))
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_lat <= ring,
            "dim {:?}: latency-optimized {best_lat} should beat ring {ring}",
            dim.kind
        );
    }
}

#[test]
fn claim_workload_spread_is_tens_of_x() {
    // Figure 4(a): 64.5x spread from parallelization alone on System 2.
    // Check the extremes analytically: the best valid parallelization is
    // many times faster than the worst valid one.
    let sim = Simulator::new();
    let model = wl::gpt3_175b().with_simulated_layers(4);
    let cluster = presets::system2();
    let mut lats = Vec::new();
    for p in enumerate_parallelizations(1024, 4, &[true]) {
        if p.dp > 2048 {
            continue;
        }
        if let Ok(r) = sim.run(&cluster, &model, &p, 2048, ExecutionMode::Training) {
            lats.push(r.latency_us);
        }
    }
    assert!(lats.len() > 10, "need a population of valid parallelizations");
    let min = lats.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = lats.iter().cloned().fold(0.0f64, f64::max);
    let spread = max / min;
    assert!(
        spread > 10.0,
        "workload spread should be tens of x (paper 64.5x), got {spread:.1}x"
    );
}

#[test]
fn claim_six_million_steps_feasible() {
    // §1: "more than six million steps across four search agents". Check
    // our throughput makes that tractable: at the measured >5k evals/s a
    // million steps is minutes, not years — sanity-check 2k steps < 5 s.
    use cosmic::agents::AgentKind;
    use cosmic::dse::{DseConfig, DseRunner, Objective, WorkloadSpec};
    use cosmic::harness::make_env;
    use cosmic::pss::SearchScope;
    let mut env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(wl::gpt3_175b().with_simulated_layers(4), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let t0 = std::time::Instant::now();
    let r = DseRunner::new(DseConfig::new(AgentKind::Ga, 2000, 1), SearchScope::FullStack)
        .run(&mut env);
    let secs = t0.elapsed().as_secs_f64();
    assert_eq!(r.history.len(), 2000);
    assert!(secs < 5.0, "2000 steps took {secs:.1}s — too slow for paper-scale DSE");
}
