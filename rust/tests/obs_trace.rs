//! Integration tests for the `obs` subsystem: Chrome-trace export
//! well-formedness, run-to-run determinism, zero perturbation of the
//! priced reports, and DSE search telemetry end to end.

use cosmic::agents::AgentKind;
use cosmic::dse::{DseConfig, DseRunner, Objective, SearchStrategy, WorkloadSpec};
use cosmic::harness::make_env;
use cosmic::netsim::FidelityMode;
use cosmic::obs::{chrome_events, chrome_trace_json, MetricsRegistry, Recorder, SearchObserver};
use cosmic::pss::SearchScope;
use cosmic::sim::{presets, SimReport, Simulator};
use cosmic::workload::models::presets as wl;
use cosmic::workload::{ExecutionMode, Parallelization};
use std::collections::HashMap;
use std::sync::Arc;

/// One traced training run on System 1 (GPT3-13B, 4 layers, DP=64).
fn traced_run(sim: Simulator) -> (Arc<Recorder>, SimReport) {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).unwrap();
    let rec = Arc::new(Recorder::new());
    let sim = sim.with_trace_sink(Arc::clone(&rec));
    let report = sim.run(&cluster, &model, &par, 1024, ExecutionMode::Training).unwrap();
    (rec, report)
}

#[test]
fn chrome_trace_is_balanced_monotone_and_valid() {
    let (rec, _) = traced_run(Simulator::new());
    let spans = rec.spans();
    assert!(!spans.is_empty());
    assert!(spans.iter().any(|s| s.name == "iteration"));
    assert!(spans.iter().any(|s| s.name.starts_with("fwd ")));
    assert!(spans.iter().any(|s| s.name.starts_with("grad sync")));

    // Every track's B/E events must balance with non-negative depth and
    // non-decreasing timestamps — the Perfetto loadability invariants.
    let events = chrome_events(&spans);
    let mut depth: HashMap<(u32, u32), i64> = HashMap::new();
    let mut last_ts: HashMap<(u32, u32), f64> = HashMap::new();
    for e in &events {
        let key = (e.pid, e.tid);
        let d = depth.entry(key).or_insert(0);
        match e.ph {
            'B' => *d += 1,
            'E' => *d -= 1,
            other => panic!("unexpected phase '{other}'"),
        }
        assert!(*d >= 0, "E without matching B on track {key:?}");
        let last = last_ts.entry(key).or_insert(f64::NEG_INFINITY);
        assert!(e.ts >= *last, "timestamps regressed on track {key:?}");
        *last = e.ts;
    }
    for (key, d) in depth {
        assert_eq!(d, 0, "unbalanced B/E events on track {key:?}");
    }

    let json = chrome_trace_json(&spans);
    cosmic::util::json::validate(&json).unwrap();
    assert!(json.contains("\"traceEvents\""));
    assert!(json.contains("process_name"), "PID metadata missing");
    assert!(json.contains("thread_name"), "TID metadata missing");
}

#[test]
fn repeated_runs_emit_identical_span_trees() {
    let (a, report_a) = traced_run(Simulator::new());
    let (b, report_b) = traced_run(Simulator::new());
    assert_eq!(report_a, report_b);
    assert_eq!(a.spans(), b.spans(), "span trees diverged across identical runs");
    assert_eq!(chrome_trace_json(&a.spans()), chrome_trace_json(&b.spans()));
}

#[test]
fn disabled_sink_report_is_bit_identical() {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).unwrap();
    let plain =
        Simulator::new().run(&cluster, &model, &par, 1024, ExecutionMode::Training).unwrap();
    let (rec, traced) = traced_run(Simulator::new());
    assert!(rec.span_count() > 0);
    assert_eq!(plain, traced, "attaching a recorder changed the report");
    assert_eq!(plain.latency_us.to_bits(), traced.latency_us.to_bits());
}

#[test]
fn flow_level_traced_run_matches_untraced_and_emits_network_spans() {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).unwrap();
    let untraced = Simulator::new()
        .with_fidelity(FidelityMode::FlowLevel)
        .run(&cluster, &model, &par, 1024, ExecutionMode::Training)
        .unwrap();
    let (rec, traced) = traced_run(Simulator::new().with_fidelity(FidelityMode::FlowLevel));
    assert_eq!(untraced, traced, "tracing perturbed the flow-level report");
    let spans = rec.spans();
    assert!(
        spans.iter().any(|s| s.pid == cosmic::obs::tracks::NET_PID),
        "flow-level run emitted no network-process spans"
    );
}

#[test]
fn histogram_quantiles_match_util_stats() {
    let m = MetricsRegistry::new();
    let mut values: Vec<f64> = (0..500).map(|i| ((i * 7919) % 1000) as f64).collect();
    for v in &values {
        m.observe("lat", *v);
    }
    let h = m.snapshot().histograms["lat"];
    values.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(h.count, 500);
    assert_eq!(h.p50, cosmic::util::stats::percentile_sorted(&values, 50.0));
    assert_eq!(h.p95, cosmic::util::stats::percentile_sorted(&values, 95.0));
    assert_eq!(h.p99, cosmic::util::stats::percentile_sorted(&values, 99.0));
}

#[test]
fn search_telemetry_end_to_end() {
    let mut env = make_env(
        presets::system1(),
        vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(2), 1024)],
        Objective::PerfPerBwPerNpu,
    );
    let obs = Arc::new(SearchObserver::new());
    let r = DseRunner::new(DseConfig::new(AgentKind::Ga, 25, 5), SearchScope::FullStack)
        .with_strategy(SearchStrategy::Staged { promote_top_k: 3 })
        .with_observer(Arc::clone(&obs))
        .run(&mut env);
    assert_eq!(r.history.len(), 25);
    let tl = obs.timeline();
    assert_eq!(tl.steps.len(), 25);
    assert_eq!(tl.finalists.len(), r.finalists.len());
    let m = obs.metrics.snapshot();
    let hits = m.counters.get("dse.evals.cache_hit").copied().unwrap_or(0);
    let misses = m.counters.get("dse.evals.cache_miss").copied().unwrap_or(0);
    assert_eq!(hits + misses, 25, "every step is either a memo hit or a miss");
    assert_eq!(m.counters.get("dse.evals.rung.analytical"), Some(&25));

    env.export_metrics(&obs.metrics);
    let snap = obs.metrics.snapshot();
    assert!(snap.counters.contains_key("evalcache.trace_evictions"));
    assert_eq!(snap.counters["env.flow_evals"], env.flow_evals());
    let json = obs.telemetry_json();
    cosmic::util::json::validate(&json).unwrap();
    assert!(json.contains("\"timeline\""));
    assert!(json.contains("\"genome_fp\""));
}
