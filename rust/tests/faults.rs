//! End-to-end tests of the fault-injection subsystem and the
//! resilience-aware DSE: seed determinism, nominal/fault-free
//! bit-identity, goodput monotonicity along severity ladders (via the
//! in-crate `util::prop` harness), fault spans in the trace timeline,
//! and a robust search driven through the public API.

use cosmic::agents::AgentKind;
use cosmic::dse::{DseConfig, DseRunner, Objective, RobustAggregate, WorkloadSpec};
use cosmic::faults::{FaultScenario, ScenarioSuite};
use cosmic::harness::make_env_robust;
use cosmic::obs::{tracks, Recorder};
use cosmic::pss::SearchScope;
use cosmic::sim::{presets, ClusterConfig, SimReport, Simulator};
use cosmic::util::prop::check;
use cosmic::workload::models::presets as wl;
use cosmic::workload::{ExecutionMode, ModelConfig, Parallelization};
use std::sync::Arc;

fn setup() -> (ClusterConfig, ModelConfig, Parallelization) {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).unwrap();
    (cluster, model, par)
}

fn run_with(
    cluster: &ClusterConfig,
    model: &ModelConfig,
    par: &Parallelization,
    scenario: Option<FaultScenario>,
) -> SimReport {
    let mut sim = Simulator::new();
    if let Some(s) = scenario {
        sim = sim.with_faults(Arc::new(s));
    }
    sim.run(cluster, model, par, 1024, ExecutionMode::Training).unwrap()
}

#[test]
fn prop_equal_seeds_reproduce_bit_identical_reports() {
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    check("fault seed determinism", 16, |rng| {
        let seed = rng.next_u64() % 1000;
        let a = FaultScenario::from_seed(seed, dims);
        let b = FaultScenario::from_seed(seed, dims);
        if a != b {
            return Err(format!("seed {seed}: scenarios differ"));
        }
        if a.fingerprint() != b.fingerprint() {
            return Err(format!("seed {seed}: fingerprints differ"));
        }
        let ra = run_with(&cluster, &model, &par, Some(a));
        let rb = run_with(&cluster, &model, &par, Some(b));
        if ra.latency_us.to_bits() != rb.latency_us.to_bits() {
            return Err(format!("seed {seed}: latency not bit-identical"));
        }
        let (ga, gb) = (ra.goodput.unwrap(), rb.goodput.unwrap());
        if ga.goodput_tflops.to_bits() != gb.goodput_tflops.to_bits() {
            return Err(format!("seed {seed}: goodput not bit-identical"));
        }
        Ok(())
    });
}

#[test]
fn nominal_scenario_matches_fault_free_bit_for_bit() {
    let (cluster, model, par) = setup();
    let plain = run_with(&cluster, &model, &par, None);
    let faulted = run_with(&cluster, &model, &par, Some(FaultScenario::nominal()));
    assert!(plain.goodput.is_none(), "fault-free runs must not grow a goodput record");
    let g = faulted.goodput.expect("nominal scenario still reports goodput");
    assert_eq!(g.efficiency, 1.0, "nominal efficiency must be exactly 1");
    assert_eq!(g.goodput_tflops.to_bits(), faulted.achieved_tflops.to_bits());
    // Everything else is bit-identical: the fault layer is zero-cost
    // when it degrades nothing.
    let mut stripped = faulted.clone();
    stripped.goodput = None;
    assert_eq!(plain, stripped);
}

#[test]
fn prop_goodput_monotone_along_severity_ladder() {
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    check("goodput monotone in severity", 10, |rng| {
        let base = FaultScenario::from_seed(rng.next_u64() % 512, dims);
        let mut prev_latency = 0.0f64;
        let mut prev_goodput = f64::INFINITY;
        for s in [0.0, 0.5, 1.0, 2.0] {
            let rep = run_with(&cluster, &model, &par, Some(base.scaled(s)));
            let g = rep.goodput.ok_or("missing goodput")?;
            if rep.latency_us < prev_latency * (1.0 - 1e-9) {
                return Err(format!("{}: latency shrank at severity {s}", base.name));
            }
            if g.goodput_tflops > prev_goodput * (1.0 + 1e-9) {
                return Err(format!("{}: goodput grew at severity {s}", base.name));
            }
            prev_latency = rep.latency_us;
            prev_goodput = g.goodput_tflops;
        }
        Ok(())
    });
}

#[test]
fn fault_spans_land_on_the_fault_track() {
    let (cluster, model, par) = setup();
    // Every seeded scenario has a finite MTBF, so at minimum the
    // failure-model span is always present when tracing is on.
    let scenario = FaultScenario::from_seed(3, cluster.topology.num_dims());
    let rec = Arc::new(Recorder::new());
    Simulator::new()
        .with_faults(Arc::new(scenario))
        .with_trace_sink(Arc::clone(&rec))
        .run(&cluster, &model, &par, 1024, ExecutionMode::Training)
        .unwrap();
    let spans = rec.spans();
    let fault_spans: Vec<_> = spans.iter().filter(|s| s.pid == tracks::FAULT_PID).collect();
    assert!(!fault_spans.is_empty(), "no spans on the fault-injection track");
    assert!(fault_spans.iter().any(|s| s.name.starts_with("failures:")));
    // The Chrome trace stays valid JSON with the new track present.
    cosmic::util::json::validate(&cosmic::obs::chrome_trace_json(&spans)).unwrap();
}

#[test]
fn robust_search_end_to_end() {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let mut env = make_env_robust(
        cluster,
        vec![WorkloadSpec::training(model, 1024)],
        Objective::PerfPerBwPerNpu,
        7,
        2,
        RobustAggregate::Expected,
    );
    let cfg = DseConfig::new(AgentKind::Ga, 60, 42);
    let result = DseRunner::new(cfg, SearchScope::FullStack).run(&mut env);
    assert_eq!(result.history.len(), 60);
    assert!(result.best_reward > 0.0, "robust search found no valid design");
    assert!(env.suite_evals() > 0, "robust mode never ran the suite");
    assert_eq!(env.eval_panics(), 0);
    // Best reports are the nominal scenario's, goodput attached.
    assert!(!result.best_reports.is_empty());
    let g = result.best_reports[0].goodput.expect("robust reports carry goodput");
    assert_eq!(g.efficiency, 1.0, "nominal-scenario reports anchor the breakdown");
    // The winner has a full per-scenario breakdown: nominal + 2 seeded.
    let suite = env.evaluate_suite(&result.best_genome, None).unwrap();
    assert_eq!(suite.scores.len(), 3);
    assert_eq!(suite.scores[0].scenario, "nominal");
    for s in &suite.scores[1..] {
        assert!(s.reward > 0.0, "{}: degraded scenario scored invalid", s.scenario);
        assert!(s.reward <= suite.scores[0].reward, "{}: faults sped things up", s.scenario);
        assert!(s.efficiency > 0.0 && s.efficiency <= 1.0);
    }
    // The aggregate the search optimized matches the breakdown.
    assert_eq!(suite.aggregate, RobustAggregate::Expected);
    let mean: f64 =
        suite.scores.iter().map(|s| s.reward).sum::<f64>() / suite.scores.len() as f64;
    assert_eq!(suite.reward.to_bits(), mean.to_bits());
}

#[test]
fn worst_case_bounds_expected_from_below() {
    let suite = ScenarioSuite::generate(11, 3, presets::system1().topology.num_dims());
    let build = |aggregate| {
        let cluster = presets::system1();
        let model = wl::gpt3_13b().with_simulated_layers(4);
        cosmic::harness::make_env(
            cluster,
            vec![WorkloadSpec::training(model, 1024)],
            Objective::PerfPerBwPerNpu,
        )
        .with_scenarios(suite.clone(), aggregate)
    };
    let expected_env = build(RobustAggregate::Expected);
    let worst_env = build(RobustAggregate::WorstCase);
    let g = expected_env.pss.baseline_genome();
    let expected = expected_env.evaluate_nomemo(&g).reward;
    let worst = worst_env.evaluate_nomemo(&g).reward;
    assert!(expected > 0.0 && worst > 0.0);
    assert!(worst <= expected, "min over scenarios exceeded their mean");
}
