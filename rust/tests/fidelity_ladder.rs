//! Cross-fidelity conformance suite pinning the netsim fidelity ladder:
//! the three rungs (Analytical, FlowLevel, PacketLevel) must agree
//! exactly where congestion cannot bite (a single uncontended flow),
//! order up the ladder where it can (oversubscribed switch fabrics
//! under incast), and the packet rung's mechanics — byte conservation,
//! per-port FIFO discipline, seeded ECMP, cache-tag-scoped determinism
//! — must hold over randomized workloads (`util::prop`). A small golden
//! corpus of end-to-end reports (one model x three fidelities x two
//! fault seeds) pins run-to-run bit-reproducibility.

use cosmic::collective::{CollAlgo, CollectiveKind, MultiDimPolicy, SchedulingPolicy};
use cosmic::faults::{FaultScenario, FaultView, LinkFaults};
use cosmic::netsim::{
    ecmp_path, Analytical, CollectiveCall, FidelityMode, FlowLevel, FlowLevelConfig, FlowSpec,
    NetworkBackend, OverlapCall, PacketLevel, PacketLevelConfig, PacketSim, TrafficTrace,
    TrafficView,
};
use cosmic::sim::{presets, ClusterConfig, Simulator};
use cosmic::topology::{DimCost, DimKind, Topology};
use cosmic::util::prop::check;
use cosmic::workload::models::presets as wl;
use cosmic::workload::{ExecutionMode, ModelConfig, Parallelization};
use std::sync::Arc;

fn topo() -> Topology {
    let kinds = [DimKind::Ring, DimKind::Switch];
    Topology::from_arrays(&kinds, &[4, 8], &[200.0, 100.0], &[0.5, 1.0])
}

fn span_of(topo: &Topology) -> Vec<(DimCost, usize)> {
    topo.dims.iter().enumerate().map(|(d, nd)| (DimCost::from_dim(nd), d)).collect()
}

/// Switch-only span: a single dimension, where FIFO-port makespans are
/// provably ordered (one shared resource, work conservation).
fn switch_span(topo: &Topology) -> Vec<(DimCost, usize)> {
    vec![(DimCost::from_dim(&topo.dims[1]), 1)]
}

fn call<'a>(
    topo: &'a Topology,
    span: &'a [(DimCost, usize)],
    algos: &'a [CollAlgo],
    bytes: f64,
    chunks: u32,
) -> CollectiveCall<'a> {
    CollectiveCall {
        kind: CollectiveKind::AllReduce,
        policy: MultiDimPolicy::Baseline,
        algos,
        span,
        topology: topo,
        bytes,
        chunks,
    }
}

fn makespan(pairs: Vec<(u64, f64)>) -> f64 {
    pairs.iter().map(|(_, t)| *t).fold(0.0, f64::max)
}

fn setup() -> (ClusterConfig, ModelConfig, Parallelization) {
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let par = Parallelization::derive(cluster.npus(), 64, 1, 1, true).unwrap();
    (cluster, model, par)
}

// ---------------------------------------------------------------------------
// Exact agreement where congestion cannot bite.
// ---------------------------------------------------------------------------

#[test]
fn uncontended_flow_costs_agree_on_all_three_rungs() {
    let topo = topo();
    let span = span_of(&topo);
    let algos = [CollAlgo::Ring, CollAlgo::Rhd];
    let rungs: [Arc<dyn NetworkBackend>; 3] = [
        Arc::new(Analytical),
        Arc::new(FlowLevel::default()),
        Arc::new(PacketLevel::default()),
    ];
    for chunks in [1u32, 4] {
        let c = call(&topo, &span, &algos, 16e6, chunks);
        let base = rungs[0].collective_time_us(&c);
        assert!(base > 0.0);
        for b in &rungs {
            let t = b.collective_time_us(&c);
            assert!(
                (t - base).abs() < 1e-6 * base,
                "chunks={chunks} {}: blocking {t} vs analytical {base}",
                b.name()
            );
        }
        let job = OverlapCall { layer: 0, issue_us: 10.0, call: c };
        let d0 = rungs[0].drain_overlapped(&[job], SchedulingPolicy::Fifo)[0].1;
        for b in &rungs {
            let d = b.drain_overlapped(&[job], SchedulingPolicy::Fifo)[0].1;
            assert!(
                (d - d0).abs() < 1e-6 * d0,
                "chunks={chunks} {}: drain {d} vs analytical {d0}",
                b.name()
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Ordering up the ladder where congestion does bite.
// ---------------------------------------------------------------------------

#[test]
fn contended_switch_drain_orders_up_the_ladder() {
    // Six identical chains on one 4:1 oversubscribed switch dimension:
    // the analytical rung prices each job at nominal rate, the fluid
    // rung shares a quartered capacity, and the packet rung serves the
    // same quartered port FIFO — so costs can only grow up the ladder.
    let topo = topo();
    let span = switch_span(&topo);
    let algos = [CollAlgo::Rhd];
    let c = call(&topo, &span, &algos, 16e6, 1);
    let jobs: Vec<OverlapCall> =
        (0..6).map(|l| OverlapCall { layer: l, issue_us: 0.0, call: c }).collect();
    let a = makespan(Analytical.drain_overlapped(&jobs, SchedulingPolicy::Fifo));
    let f = makespan(
        FlowLevel::new(FlowLevelConfig::oversubscribed(4.0))
            .drain_overlapped(&jobs, SchedulingPolicy::Fifo),
    );
    let p = makespan(
        PacketLevel::new(PacketLevelConfig::oversubscribed(4.0))
            .drain_overlapped(&jobs, SchedulingPolicy::Fifo),
    );
    assert!(f >= a - 1e-6 * a, "flow {f} came out below analytical {a}");
    // Packet-granular round-robin can overlap a chain's inter-phase
    // latency gap with another chain's service, undercutting the fully
    // synchronized fluid schedule by up to one packet time per phase —
    // a sub-0.1% effect here, hence the wider guard band.
    assert!(p >= f - 1e-3 * f, "packet {p} came out below flow {f}");
    assert!(f > 1.5 * a, "4:1 oversubscription failed to bite: flow {f} vs analytical {a}");
}

#[test]
fn contended_chunked_drain_orders_between_analytical_and_packet() {
    // Packet >= ChunkedFlow >= Analytical on a 4:1 oversubscribed switch
    // dimension. Reduce-Scatter visits each dimension exactly once, so a
    // chunked collective is a pure per-(job, dim) FIFO chain and the
    // ordering is tight (AllReduce revisits dims, where the chunked
    // model's full-duplex RS/AG overlap makes only a hedged comparison
    // sound — covered end to end below).
    let topo = topo();
    let span = switch_span(&topo);
    let algos = [CollAlgo::Rhd];
    let c = CollectiveCall {
        kind: CollectiveKind::ReduceScatter,
        policy: MultiDimPolicy::Baseline,
        algos: &algos,
        span: &span,
        topology: &topo,
        bytes: 16e6,
        chunks: 4,
    };
    let jobs: Vec<OverlapCall> =
        (0..6).map(|l| OverlapCall { layer: l, issue_us: 0.0, call: c }).collect();
    let a = makespan(Analytical.drain_overlapped(&jobs, SchedulingPolicy::Fifo));
    let cf = makespan(
        FlowLevel::new(FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true))
            .drain_overlapped(&jobs, SchedulingPolicy::Fifo),
    );
    let p = makespan(
        PacketLevel::new(PacketLevelConfig::oversubscribed(4.0))
            .drain_overlapped(&jobs, SchedulingPolicy::Fifo),
    );
    assert!(cf >= a - 1e-6 * a, "chunked flow {cf} came out below analytical {a}");
    assert!(cf > 1.5 * a, "4:1 oversubscription failed to bite: chunked {cf} vs analytical {a}");
    // Same packet-granularity guard band as the steady-state ordering
    // test above.
    assert!(p >= cf - 1e-3 * cf, "packet {p} came out below chunked flow {cf}");
}

#[test]
fn chunked_simulator_latency_is_hedged_against_the_analytical_screen() {
    // End to end (AllReduce gradient drains revisit dimensions), the
    // chunked flow rung on an oversubscribed fabric must not come out
    // meaningfully *faster* than the analytical screen — the same hedge
    // `simulator_latency_orders_up_the_ladder_end_to_end` applies to the
    // steady-state rungs.
    let (cluster, model, par) = setup();
    let run = |sim: Simulator| {
        sim.run(&cluster, &model, &par, 1024, ExecutionMode::Training).unwrap().latency_us
    };
    let a = run(Simulator::new());
    let cf = run(Simulator::new().with_flow_config(
        FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true),
    ));
    assert!(a > 0.0 && cf.is_finite());
    assert!(
        cf >= 0.95 * a,
        "chunked flow on an oversubscribed fabric came out faster: {cf} vs {a}"
    );
}

#[test]
fn simulator_latency_orders_up_the_ladder_end_to_end() {
    let (cluster, model, par) = setup();
    let run = |sim: Simulator| {
        sim.run(&cluster, &model, &par, 1024, ExecutionMode::Training).unwrap().latency_us
    };
    let a = run(Simulator::new());
    let f = run(Simulator::new().with_flow_config(FlowLevelConfig::oversubscribed(4.0)));
    let p = run(Simulator::new().with_packet_config(PacketLevelConfig::oversubscribed(4.0)));
    assert!(a > 0.0 && f.is_finite() && p.is_finite());
    // Multi-dimensional drains overlap phases across dims, so the
    // congested rungs are compared against the analytical screen with
    // the same hedge the staged-search acceptance test uses: they must
    // not come out meaningfully *faster*.
    assert!(f >= 0.95 * a, "flow-level on an oversubscribed fabric came out faster: {f} vs {a}");
    assert!(p >= 0.95 * a, "packet-level on an oversubscribed fabric came out faster: {p} vs {a}");
}

// ---------------------------------------------------------------------------
// Monotonicity at the packet rung.
// ---------------------------------------------------------------------------

#[test]
fn packet_makespan_is_monotone_in_background_load() {
    let topo = topo();
    let span = switch_span(&topo);
    let algos = [CollAlgo::Rhd];
    let c = call(&topo, &span, &algos, 16e6, 2);
    let jobs: Vec<OverlapCall> =
        (0..4).map(|l| OverlapCall { layer: l, issue_us: 0.0, call: c }).collect();
    let mut prev = 0.0;
    for load in [0.0, 0.3, 0.6] {
        let backend = PacketLevel::new(PacketLevelConfig {
            fabric: FlowLevelConfig::default().with_background_load(load),
            ..Default::default()
        });
        let m = makespan(backend.drain_overlapped(&jobs, SchedulingPolicy::Fifo));
        assert!(m >= prev - 1e-6 * m, "load {load}: makespan {m} fell below {prev}");
        prev = m;
    }
}

#[test]
fn packet_makespan_is_monotone_in_concurrent_flow_count() {
    let topo = topo();
    let span = switch_span(&topo);
    let algos = [CollAlgo::Rhd];
    let c = call(&topo, &span, &algos, 16e6, 1);
    let backend = PacketLevel::default();
    let mut prev = 0.0;
    for n in [1u64, 2, 4, 8] {
        let jobs: Vec<OverlapCall> =
            (0..n).map(|l| OverlapCall { layer: l, issue_us: 0.0, call: c }).collect();
        let m = makespan(backend.drain_overlapped(&jobs, SchedulingPolicy::Fifo));
        assert!(m >= prev - 1e-6 * m, "{n} flows: makespan {m} fell below {prev}");
        prev = m;
    }
}

// ---------------------------------------------------------------------------
// Packet-rung mechanics over randomized workloads.
// ---------------------------------------------------------------------------

#[test]
fn prop_packet_bytes_are_conserved() {
    let topo = topo();
    check("packet byte conservation", 24, |rng| {
        let config = PacketLevelConfig {
            mtu_bytes: [512.0, 1500.0, 4096.0][rng.gen_range(3)],
            queue_depth: 1 + rng.gen_range(64),
            ecmp_width: 1 + rng.gen_range(4),
            seed: rng.next_u64(),
            max_packets_per_flow: 16 + rng.gen_range(64),
            ..Default::default()
        };
        let sim = PacketSim::new(&topo, &config);
        let chains: Vec<(f64, Vec<FlowSpec>)> = (0..1 + rng.gen_range(4))
            .map(|_| {
                let flows = (0..1 + rng.gen_range(3))
                    .map(|_| FlowSpec {
                        uses: vec![rng.gen_range(2)],
                        bytes: rng.gen_f64() * 2e6,
                        latency_us: rng.gen_f64() * 3.0,
                    })
                    .collect();
                (rng.gen_f64() * 10.0, flows)
            })
            .collect();
        let sent: f64 = chains.iter().flat_map(|(_, fs)| fs.iter().map(|f| f.bytes)).sum();
        let served: f64 = sim.run(&chains).iter().map(|r| r.served_bytes).sum();
        if (served - sent).abs() > 1e-9 * sent.max(1.0) {
            return Err(format!("served {served} bytes of {sent} sent"));
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_port_service_never_inverts() {
    let topo = topo();
    check("per-port FIFO ordering", 16, |rng| {
        let config = PacketLevelConfig {
            ecmp_width: 1 + rng.gen_range(4),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let sim = PacketSim::new(&topo, &config);
        let chains: Vec<(f64, Vec<FlowSpec>)> = (0..2 + rng.gen_range(4))
            .map(|_| {
                let flow = FlowSpec {
                    uses: vec![rng.gen_range(2)],
                    bytes: 1e5 + rng.gen_f64() * 1e6,
                    latency_us: rng.gen_f64(),
                };
                (rng.gen_f64() * 5.0, vec![flow])
            })
            .collect();
        let mut served = Vec::new();
        sim.run_recorded(&chains, &mut served);
        if served.is_empty() {
            return Err("no packets served".into());
        }
        // Packets are recorded in service order: per port the service
        // intervals must tile without overlap, and per flow the packet
        // indexes must increase — a FIFO port never inverts them.
        let mut port_last: Vec<((usize, usize), f64)> = Vec::new();
        let mut flow_last: Vec<((usize, usize), u64)> = Vec::new();
        for p in &served {
            let port = (p.dim, p.path);
            match port_last.iter_mut().find(|(k, _)| *k == port) {
                Some((_, end)) => {
                    if p.start_us < *end - 1e-9 {
                        return Err(format!(
                            "port {port:?}: packet started at {} before previous finish {end}",
                            p.start_us
                        ));
                    }
                    *end = p.finish_us;
                }
                None => port_last.push((port, p.finish_us)),
            }
            let flow = (p.chain, p.flow);
            match flow_last.iter_mut().find(|(k, _)| *k == flow) {
                Some((_, idx)) => {
                    if p.index <= *idx {
                        return Err(format!("flow {flow:?}: index {} after {idx}", p.index));
                    }
                    *idx = p.index;
                }
                None => flow_last.push((flow, p.index)),
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ecmp_paths_are_reproducible_and_bounded() {
    check("ecmp path determinism", 200, |rng| {
        let seed = rng.next_u64();
        let chain = rng.gen_range(64);
        let flow = rng.gen_range(16);
        let dim = rng.gen_range(4);
        let width = rng.gen_range(6);
        let p = ecmp_path(seed, chain, flow, dim, width);
        if p != ecmp_path(seed, chain, flow, dim, width) {
            return Err(format!("path for seed {seed:#x} not reproducible"));
        }
        if width <= 1 && p != 0 {
            return Err(format!("width {width} must pin path 0, got {p}"));
        }
        if width > 1 && p >= width {
            return Err(format!("path {p} out of range for width {width}"));
        }
        Ok(())
    });
}

#[test]
fn prop_equal_cache_tags_mean_bit_identical_drains() {
    let topo = topo();
    let span = span_of(&topo);
    let algos = [CollAlgo::Ring, CollAlgo::Rhd];
    check("same tag, same drain", 12, |rng| {
        let config = PacketLevelConfig {
            mtu_bytes: [1500.0, 4096.0][rng.gen_range(2)],
            queue_depth: 1 + rng.gen_range(32),
            ecmp_width: 1 + rng.gen_range(4),
            seed: rng.next_u64() % 1000,
            ..Default::default()
        };
        let a = PacketLevel::new(config.clone());
        let b = PacketLevel::new(config);
        if a.cache_tag() != b.cache_tag() {
            return Err("equal configs hashed to different tags".into());
        }
        let bytes = 4e6 + rng.gen_f64() * 4e6;
        let chunks = (1 + rng.gen_range(4)) as u32;
        let c = call(&topo, &span, &algos, bytes, chunks);
        let jobs: Vec<OverlapCall> =
            (0..3).map(|l| OverlapCall { layer: l, issue_us: l as f64 * 2.0, call: c }).collect();
        let da = a.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        let db = b.drain_overlapped(&jobs, SchedulingPolicy::Fifo);
        if da.len() != db.len() {
            return Err(format!("drain lengths differ: {} vs {}", da.len(), db.len()));
        }
        for ((la, ta), (lb, tb)) in da.iter().zip(db.iter()) {
            if la != lb || ta.to_bits() != tb.to_bits() {
                return Err(format!("layer {la}: {ta} vs {tb} not bit-identical"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Cache-tag distinctness across the ladder (and its faulted views).
// ---------------------------------------------------------------------------

#[test]
fn cache_tags_are_pairwise_distinct_across_the_ladder() {
    let links = LinkFaults { bandwidth_factor: vec![0.5, 1.0], latency_factor: vec![1.0, 2.0] };
    let backends: Vec<(&str, Arc<dyn NetworkBackend>)> = vec![
        ("analytical", Arc::new(Analytical)),
        ("flow", Arc::new(FlowLevel::default())),
        ("flow-4x", Arc::new(FlowLevel::new(FlowLevelConfig::oversubscribed(4.0)))),
        (
            "chunked-flow",
            Arc::new(FlowLevel::new(FlowLevelConfig::default().with_chunk_precedence(true))),
        ),
        (
            "chunked-flow-4x",
            Arc::new(FlowLevel::new(
                FlowLevelConfig::oversubscribed(4.0).with_chunk_precedence(true),
            )),
        ),
        ("packet", Arc::new(PacketLevel::default())),
        ("packet-4x", Arc::new(PacketLevel::new(PacketLevelConfig::oversubscribed(4.0)))),
    ];
    let trace = Arc::new(TrafficTrace::uniform(2, 0.3));
    let mut tagged: Vec<(String, u64)> =
        backends.iter().map(|(n, b)| (n.to_string(), b.cache_tag())).collect();
    for (n, b) in &backends {
        let view = FaultView::wrap(Arc::clone(b), &links);
        tagged.push((format!("faulted-{n}"), view.cache_tag()));
        let shaped = TrafficView::wrap(Arc::clone(b), Arc::clone(&trace));
        tagged.push((format!("traffic-{n}"), shaped.cache_tag()));
    }
    for i in 0..tagged.len() {
        for j in i + 1..tagged.len() {
            assert_ne!(
                tagged[i].1,
                tagged[j].1,
                "{} and {} share a cache tag",
                tagged[i].0,
                tagged[j].0
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Golden corpus: run-to-run bit-reproducibility end to end.
// ---------------------------------------------------------------------------

fn corpus() -> Vec<String> {
    let (cluster, model, par) = setup();
    let dims = cluster.topology.num_dims();
    let fidelities = [FidelityMode::Analytical, FidelityMode::FlowLevel, FidelityMode::Packet];
    let mut out = Vec::new();
    let mut record = |name: &str, sim: Simulator, seed: u64| {
        let sim = sim.with_faults(Arc::new(FaultScenario::from_seed(seed, dims)));
        let rep = sim.run(&cluster, &model, &par, 1024, ExecutionMode::Training).unwrap();
        out.push(format!(
            "{}/seed{}: latency_bits={:016x} {:?}",
            name,
            seed,
            rep.latency_us.to_bits(),
            rep
        ));
    };
    for fid in fidelities {
        for seed in [3u64, 7] {
            record(fid.name(), Simulator::new().with_fidelity(fid), seed);
        }
    }
    // The chunk-precedence variant of the flow rung joins the corpus: a
    // fourth column pinning the per-chunk drain's bit-reproducibility.
    for seed in [3u64, 7] {
        record(
            "ChunkedFlow",
            Simulator::new()
                .with_flow_config(FlowLevelConfig::default().with_chunk_precedence(true)),
            seed,
        );
    }
    out
}

#[test]
fn golden_corpus_is_run_to_run_deterministic() {
    let first = corpus();
    let second = corpus();
    assert_eq!(
        first.len(),
        8,
        "one model x (three fidelities + chunked flow) x two fault seeds"
    );
    for (a, b) in first.iter().zip(second.iter()) {
        assert_eq!(a, b, "corpus entry drifted between identical runs");
    }
}
