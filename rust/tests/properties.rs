//! Property-based tests over the simulator and PsA invariants
//! (via the in-crate `util::prop` harness — see DESIGN.md
//! §Substitutions for why not `proptest`).

use cosmic::collective::{
    collective_time_us, multidim_collective_time_us, CollAlgo, CollectiveKind, MultiDimPolicy,
};
use cosmic::psa::paper_table4_schema;
use cosmic::pss::{Pss, SearchScope};
use cosmic::sim::{presets, Simulator};
use cosmic::topology::{DimCost, DimKind, NetworkDim, Topology};
use cosmic::util::prop::check;
use cosmic::util::Rng;
use cosmic::workload::models::presets as wl;
use cosmic::workload::{footprint, group_span, ExecutionMode, Parallelization};

fn random_topology(rng: &mut Rng) -> Topology {
    let dims = 1 + rng.gen_range(4);
    let kinds = [DimKind::Ring, DimKind::Switch, DimKind::FullyConnected];
    Topology::new(
        (0..dims)
            .map(|_| {
                NetworkDim::new(
                    *rng.choose(&kinds),
                    [2u64, 4, 8, 16][rng.gen_range(4)],
                    [50.0, 100.0, 200.0, 400.0][rng.gen_range(4)],
                    0.1 + rng.gen_f64() * 2.0,
                )
            })
            .collect(),
    )
}

#[test]
fn prop_collective_cost_nonnegative_and_monotone_in_bytes() {
    check("collective cost monotone", 300, |rng| {
        let dim = DimCost::from_dim(&NetworkDim::new(
            DimKind::Ring,
            [2u64, 4, 8, 16, 32][rng.gen_range(5)],
            50.0 + rng.gen_f64() * 450.0,
            rng.gen_f64() * 2.0,
        ));
        let algo = *rng.choose(&CollAlgo::ALL);
        let kind = *rng.choose(&CollectiveKind::ALL);
        let bytes = rng.gen_f64() * 1e9;
        let t1 = collective_time_us(algo, kind, &dim, bytes);
        let t2 = collective_time_us(algo, kind, &dim, bytes * 2.0);
        if t1 < 0.0 || t2 < 0.0 {
            return Err(format!("negative cost: {t1} {t2}"));
        }
        if t2 + 1e-9 < t1 {
            return Err(format!("not monotone in bytes: {t1} -> {t2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_blueconnect_never_slower_than_baseline() {
    check("blueconnect <= baseline", 300, |rng| {
        let topo = random_topology(rng);
        let dims: Vec<DimCost> = topo.dims.iter().map(DimCost::from_dim).collect();
        let algos: Vec<CollAlgo> =
            (0..dims.len()).map(|_| *rng.choose(&CollAlgo::ALL)).collect();
        let kind = *rng.choose(&CollectiveKind::ALL);
        let bytes = 1e3 + rng.gen_f64() * 1e9;
        let chunks = 1 + rng.gen_range(32) as u32;
        let base = multidim_collective_time_us(
            kind,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            bytes,
            chunks,
        );
        let bc = multidim_collective_time_us(
            kind,
            MultiDimPolicy::BlueConnect,
            &algos,
            &dims,
            bytes,
            chunks,
        );
        if bc > base + 1e-6 {
            return Err(format!("blueconnect {bc} > baseline {base} (chunks={chunks})"));
        }
        Ok(())
    });
}

#[test]
fn prop_group_span_product_equals_group_size() {
    check("group span covers group", 500, |rng| {
        let topo = random_topology(rng);
        let total = topo.total_npus();
        // stride and size as random powers of two with stride*size <= total
        let log_total = 63 - total.leading_zeros();
        let ls = rng.gen_range(log_total as usize + 1) as u32;
        let remaining = log_total - ls;
        let lg = rng.gen_range(remaining as usize + 1) as u32 + 1;
        let stride = 1u64 << ls;
        let size = (1u64 << lg).min(total / stride.max(1)).max(1);
        if stride * size > total || size < 2 {
            return Ok(()); // skip degenerate draw
        }
        let span = group_span(&topo, stride, size);
        let product: u64 = span.iter().map(|e| e.extent).product();
        if product != size {
            return Err(format!(
                "{} stride={stride} size={size}: span product {product}",
                topo.notation()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_monotone_in_sharding_and_tp() {
    check("memory monotone", 200, |rng| {
        let model = wl::all()[rng.gen_range(4)].clone();
        let npus = [64u64, 256, 1024][rng.gen_range(3)];
        let dp = 1u64 << rng.gen_range(5);
        let sp = 1u64 << rng.gen_range(3);
        if dp * sp > npus {
            return Ok(());
        }
        let batch = (dp * 4).max(256);
        let dense = Parallelization::derive(npus, dp, sp, 1, false).map_err(|e| e)?;
        let shard = Parallelization::derive(npus, dp, sp, 1, true).map_err(|e| e)?;
        let fd = footprint(&model, &dense, batch, ExecutionMode::Training).total();
        let fs = footprint(&model, &shard, batch, ExecutionMode::Training).total();
        if fs > fd + 1e-6 {
            return Err(format!("sharded {fs:.3e} > dense {fd:.3e} ({})", model.name));
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_latency_positive_and_bw_monotone() {
    let sim = Simulator::new();
    check("simulator sanity", 60, |rng| {
        let mut cluster = presets::by_index(1 + rng.gen_range(3)).unwrap();
        let npus = cluster.npus();
        let model = wl::all()[rng.gen_range(4)].clone().with_simulated_layers(2);
        let dp = (1u64 << rng.gen_range(7)).min(npus);
        let par = match Parallelization::derive(npus, dp, 1, 1, true) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let batch = 2048;
        let r1 = match sim.run(&cluster, &model, &par, batch, ExecutionMode::Training) {
            Ok(r) => r,
            Err(_) => return Ok(()), // invalid points are allowed
        };
        if !(r1.latency_us > 0.0 && r1.latency_us.is_finite()) {
            return Err(format!("bad latency {}", r1.latency_us));
        }
        // Doubling every link bandwidth must not hurt.
        for d in &mut cluster.topology.dims {
            d.bandwidth_gbps *= 2.0;
        }
        let r2 = sim.run(&cluster, &model, &par, batch, ExecutionMode::Training).unwrap();
        if r2.latency_us > r1.latency_us + 1e-6 {
            return Err(format!("more bw slower: {} -> {}", r1.latency_us, r2.latency_us));
        }
        Ok(())
    });
}

#[test]
fn prop_decoded_points_satisfy_constraints_and_materialize() {
    let pss = Pss::new(
        paper_table4_schema(1024, 4),
        presets::system2(),
        Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
    );
    let space = pss.build_space(SearchScope::FullStack);
    check("valid genomes materialize", 200, |rng| {
        let mut local = Rng::seed_from_u64(rng.next_u64());
        let Some(g) = space.random_valid_genome(&mut local, 500) else {
            return Ok(());
        };
        let point = space.schema.decode_valid(&g).map_err(|e| e)?;
        let (cluster, par) = pss.materialize(&point).map_err(|e| e)?;
        if cluster.npus() != par.npus() {
            return Err(format!("npus mismatch: {} vs {}", cluster.npus(), par.npus()));
        }
        cluster.validate().map_err(|e| e)?;
        Ok(())
    });
}

#[test]
fn prop_reward_zero_iff_invalid() {
    use cosmic::dse::{Environment, Objective, WorkloadSpec};
    let pss = Pss::new(
        paper_table4_schema(1024, 4),
        presets::system2(),
        Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
    );
    let env = Environment::new(
        pss,
        vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(2), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let space = env.pss.build_space(SearchScope::FullStack);
    check("reward zero iff invalid", 150, |rng| {
        let mut local = Rng::seed_from_u64(rng.next_u64());
        let g = space.random_genome(&mut local);
        let out = env.evaluate_uncached(&g);
        match (out.reward == 0.0, out.invalid_reason.is_some()) {
            (true, false) => Err("zero reward but no invalid reason".into()),
            (false, true) => Err("positive reward with invalid reason".into()),
            _ => Ok(()),
        }
    });
}
