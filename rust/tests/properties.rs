//! Property-based tests over the simulator and PsA invariants
//! (via the in-crate `util::prop` harness — see DESIGN.md
//! §Substitutions for why not `proptest`).

use cosmic::collective::{
    collective_time_us, multidim_collective_time_us, CollAlgo, CollectiveKind, MultiDimPolicy,
    SchedulingPolicy,
};
use cosmic::netsim::{maxmin_rates, EventQueue, FidelityMode, FlowSim, FlowSpec};
use cosmic::psa::paper_table4_schema;
use cosmic::pss::{Pss, SearchScope};
use cosmic::sim::{presets, ClusterConfig, Simulator};
use cosmic::topology::{DimCost, DimKind, NetworkDim, Topology};
use cosmic::util::prop::check;
use cosmic::util::Rng;
use cosmic::workload::models::presets as wl;
use cosmic::workload::{footprint, group_span, ExecutionMode, Parallelization};

fn random_topology(rng: &mut Rng) -> Topology {
    let dims = 1 + rng.gen_range(4);
    let kinds = [DimKind::Ring, DimKind::Switch, DimKind::FullyConnected];
    Topology::new(
        (0..dims)
            .map(|_| {
                NetworkDim::new(
                    *rng.choose(&kinds),
                    [2u64, 4, 8, 16][rng.gen_range(4)],
                    [50.0, 100.0, 200.0, 400.0][rng.gen_range(4)],
                    0.1 + rng.gen_f64() * 2.0,
                )
            })
            .collect(),
    )
}

#[test]
fn prop_collective_cost_nonnegative_and_monotone_in_bytes() {
    check("collective cost monotone", 300, |rng| {
        let dim = DimCost::from_dim(&NetworkDim::new(
            DimKind::Ring,
            [2u64, 4, 8, 16, 32][rng.gen_range(5)],
            50.0 + rng.gen_f64() * 450.0,
            rng.gen_f64() * 2.0,
        ));
        let algo = *rng.choose(&CollAlgo::ALL);
        let kind = *rng.choose(&CollectiveKind::ALL);
        let bytes = rng.gen_f64() * 1e9;
        let t1 = collective_time_us(algo, kind, &dim, bytes);
        let t2 = collective_time_us(algo, kind, &dim, bytes * 2.0);
        if t1 < 0.0 || t2 < 0.0 {
            return Err(format!("negative cost: {t1} {t2}"));
        }
        if t2 + 1e-9 < t1 {
            return Err(format!("not monotone in bytes: {t1} -> {t2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_blueconnect_never_slower_than_baseline() {
    check("blueconnect <= baseline", 300, |rng| {
        let topo = random_topology(rng);
        let dims: Vec<DimCost> = topo.dims.iter().map(DimCost::from_dim).collect();
        let algos: Vec<CollAlgo> =
            (0..dims.len()).map(|_| *rng.choose(&CollAlgo::ALL)).collect();
        let kind = *rng.choose(&CollectiveKind::ALL);
        let bytes = 1e3 + rng.gen_f64() * 1e9;
        let chunks = 1 + rng.gen_range(32) as u32;
        let base = multidim_collective_time_us(
            kind,
            MultiDimPolicy::Baseline,
            &algos,
            &dims,
            bytes,
            chunks,
        );
        let bc = multidim_collective_time_us(
            kind,
            MultiDimPolicy::BlueConnect,
            &algos,
            &dims,
            bytes,
            chunks,
        );
        if bc > base + 1e-6 {
            return Err(format!("blueconnect {bc} > baseline {base} (chunks={chunks})"));
        }
        Ok(())
    });
}

#[test]
fn prop_group_span_product_equals_group_size() {
    check("group span covers group", 500, |rng| {
        let topo = random_topology(rng);
        let total = topo.total_npus();
        // stride and size as random powers of two with stride*size <= total
        let log_total = 63 - total.leading_zeros();
        let ls = rng.gen_range(log_total as usize + 1) as u32;
        let remaining = log_total - ls;
        let lg = rng.gen_range(remaining as usize + 1) as u32 + 1;
        let stride = 1u64 << ls;
        let size = (1u64 << lg).min(total / stride.max(1)).max(1);
        if stride * size > total || size < 2 {
            return Ok(()); // skip degenerate draw
        }
        let span = group_span(&topo, stride, size);
        let product: u64 = span.iter().map(|e| e.extent).product();
        if product != size {
            return Err(format!(
                "{} stride={stride} size={size}: span product {product}",
                topo.notation()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_memory_monotone_in_sharding_and_tp() {
    check("memory monotone", 200, |rng| {
        let model = wl::all()[rng.gen_range(4)].clone();
        let npus = [64u64, 256, 1024][rng.gen_range(3)];
        let dp = 1u64 << rng.gen_range(5);
        let sp = 1u64 << rng.gen_range(3);
        if dp * sp > npus {
            return Ok(());
        }
        let batch = (dp * 4).max(256);
        let dense = Parallelization::derive(npus, dp, sp, 1, false).map_err(|e| e)?;
        let shard = Parallelization::derive(npus, dp, sp, 1, true).map_err(|e| e)?;
        let fd = footprint(&model, &dense, batch, ExecutionMode::Training).total();
        let fs = footprint(&model, &shard, batch, ExecutionMode::Training).total();
        if fs > fd + 1e-6 {
            return Err(format!("sharded {fs:.3e} > dense {fd:.3e} ({})", model.name));
        }
        Ok(())
    });
}

#[test]
fn prop_simulator_latency_positive_and_bw_monotone() {
    let sim = Simulator::new();
    check("simulator sanity", 60, |rng| {
        let mut cluster = presets::by_index(1 + rng.gen_range(3)).unwrap();
        let npus = cluster.npus();
        let model = wl::all()[rng.gen_range(4)].clone().with_simulated_layers(2);
        let dp = (1u64 << rng.gen_range(7)).min(npus);
        let par = match Parallelization::derive(npus, dp, 1, 1, true) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let batch = 2048;
        let r1 = match sim.run(&cluster, &model, &par, batch, ExecutionMode::Training) {
            Ok(r) => r,
            Err(_) => return Ok(()), // invalid points are allowed
        };
        if !(r1.latency_us > 0.0 && r1.latency_us.is_finite()) {
            return Err(format!("bad latency {}", r1.latency_us));
        }
        // Doubling every link bandwidth must not hurt.
        for d in &mut cluster.topology.dims {
            d.bandwidth_gbps *= 2.0;
        }
        let r2 = sim.run(&cluster, &model, &par, batch, ExecutionMode::Training).unwrap();
        if r2.latency_us > r1.latency_us + 1e-6 {
            return Err(format!("more bw slower: {} -> {}", r1.latency_us, r2.latency_us));
        }
        Ok(())
    });
}

#[test]
fn prop_decoded_points_satisfy_constraints_and_materialize() {
    let pss = Pss::new(
        paper_table4_schema(1024, 4),
        presets::system2(),
        Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
    );
    let space = pss.build_space(SearchScope::FullStack);
    check("valid genomes materialize", 200, |rng| {
        let mut local = Rng::seed_from_u64(rng.next_u64());
        let Some(g) = space.random_valid_genome(&mut local, 500) else {
            return Ok(());
        };
        let point = space.schema.decode_valid(&g).map_err(|e| e)?;
        let (cluster, par) = pss.materialize(&point).map_err(|e| e)?;
        if cluster.npus() != par.npus() {
            return Err(format!("npus mismatch: {} vs {}", cluster.npus(), par.npus()));
        }
        cluster.validate().map_err(|e| e)?;
        Ok(())
    });
}

// --- netsim event-engine and flow-model invariants ---

#[test]
fn prop_event_queue_pops_in_monotone_time_order() {
    check("event queue monotone", 300, |rng| {
        let mut q: EventQueue<usize> = EventQueue::new();
        let n = 1 + rng.gen_range(64);
        for i in 0..n {
            q.schedule_at(rng.gen_f64() * 1e6, i);
        }
        let mut last = f64::NEG_INFINITY;
        let mut popped = 0;
        while let Some((t, _)) = q.pop() {
            if t < last {
                return Err(format!("time went backwards: {last} -> {t}"));
            }
            if (q.now_us() - t).abs() > 0.0 {
                return Err("clock did not advance to popped event".into());
            }
            last = t;
            popped += 1;
        }
        if popped != n {
            return Err(format!("popped {popped} of {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_maxmin_rates_respect_capacity_and_bottleneck_certificate() {
    check("max-min fairness", 300, |rng| {
        let resources = 1 + rng.gen_range(4);
        let caps: Vec<f64> = (0..resources).map(|_| 10.0 + rng.gen_f64() * 990.0).collect();
        let flows = 1 + rng.gen_range(12);
        let uses: Vec<Vec<usize>> = (0..flows)
            .map(|_| {
                let k = 1 + rng.gen_range(resources);
                let mut dims: Vec<usize> = (0..resources).collect();
                // Take a random k-subset.
                for i in 0..k {
                    let j = i + rng.gen_range(resources - i);
                    dims.swap(i, j);
                }
                dims.truncate(k);
                dims
            })
            .collect();
        let rates = maxmin_rates(&uses, &caps);
        // (1) capacities respected.
        for r in 0..resources {
            let sum: f64 = uses
                .iter()
                .zip(&rates)
                .filter(|(u, _)| u.contains(&r))
                .map(|(_, x)| *x)
                .sum();
            if sum > caps[r] * (1.0 + 1e-9) + 1e-9 {
                return Err(format!("resource {r}: allocated {sum} > cap {}", caps[r]));
            }
        }
        // (2) max-min certificate: every flow has a saturated bottleneck
        // resource on which it receives the maximum rate.
        for (f, u) in uses.iter().enumerate() {
            let ok = u.iter().any(|&r| {
                let on_r: Vec<f64> = uses
                    .iter()
                    .zip(&rates)
                    .filter(|(v, _)| v.contains(&r))
                    .map(|(_, x)| *x)
                    .collect();
                let sum: f64 = on_r.iter().sum();
                let max = on_r.iter().cloned().fold(0.0, f64::max);
                sum >= caps[r] * (1.0 - 1e-9) - 1e-9 && rates[f] >= max * (1.0 - 1e-9)
            });
            if !ok {
                return Err(format!("flow {f} has no bottleneck: rates {rates:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_flow_sim_conserves_bytes_and_respects_latency() {
    check("flow sim byte conservation", 200, |rng| {
        let resources = 1 + rng.gen_range(4);
        let caps: Vec<f64> = (0..resources).map(|_| 10.0 + rng.gen_f64() * 990.0).collect();
        let chains: Vec<(f64, Vec<FlowSpec>)> = (0..1 + rng.gen_range(8))
            .map(|_| {
                let issue = rng.gen_f64() * 100.0;
                let specs: Vec<FlowSpec> = (0..1 + rng.gen_range(4))
                    .map(|_| FlowSpec {
                        uses: vec![rng.gen_range(resources)],
                        bytes: rng.gen_f64() * 1e6,
                        latency_us: rng.gen_f64() * 10.0,
                    })
                    .collect();
                (issue, specs)
            })
            .collect();
        let results = FlowSim::new(caps).run(&chains);
        for ((issue, specs), r) in chains.iter().zip(&results) {
            let want: f64 = specs.iter().map(|s| s.bytes).sum();
            let min_latency: f64 = specs.iter().map(|s| s.latency_us).sum();
            if (r.served_bytes - want).abs() > 1e-6 * want.max(1.0) {
                return Err(format!("served {} of {want} bytes", r.served_bytes));
            }
            if r.finish_us + 1e-9 < issue + min_latency {
                return Err(format!(
                    "finished {} before issue {} + latency {min_latency}",
                    r.finish_us, issue
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_flow_level_matches_analytical_on_single_flow_configs() {
    // One gradient collective at a time, chunks=1, uncongested fabric:
    // the flow-level rung must agree with the analytical one.
    let sim_a = Simulator::new();
    let sim_f = Simulator::new().with_fidelity(FidelityMode::FlowLevel);
    check("flow-level == analytical single-flow", 40, |rng| {
        let mut cluster: ClusterConfig = presets::by_index(1 + rng.gen_range(3)).unwrap();
        cluster.collectives.chunks = 1;
        cluster.collectives.scheduling =
            *rng.choose(&[SchedulingPolicy::Lifo, SchedulingPolicy::Fifo]);
        let npus = cluster.npus();
        let model = wl::all()[rng.gen_range(4)].clone().with_simulated_layers(1);
        let dp = (1u64 << (1 + rng.gen_range(6))).min(npus);
        // dense DP gradients (one all-reduce for the single layer) plus
        // TP blocking collectives from the residual.
        let par = match Parallelization::derive(npus, dp, 1, 1, false) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let batch = 2048;
        let (a, f) = match (
            sim_a.run(&cluster, &model, &par, batch, ExecutionMode::Training),
            sim_f.run(&cluster, &model, &par, batch, ExecutionMode::Training),
        ) {
            (Ok(a), Ok(f)) => (a, f),
            (Err(_), Err(_)) => return Ok(()), // invalid for both alike
            (a, f) => {
                return Err(format!("validity disagrees: {:?} vs {:?}", a.is_ok(), f.is_ok()))
            }
        };
        let rel = (a.latency_us - f.latency_us).abs() / a.latency_us.max(1e-12);
        if rel > 0.05 {
            return Err(format!(
                "latency diverged {:.2}%: analytical={} flow={}",
                rel * 100.0,
                a.latency_us,
                f.latency_us
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_congestion_never_speeds_up_collectives() {
    use cosmic::netsim::{CollectiveCall, FlowLevel, FlowLevelConfig, NetworkBackend};
    check("oversubscription monotone", 200, |rng| {
        let topo = random_topology(rng);
        let span: Vec<(DimCost, usize)> = topo
            .dims
            .iter()
            .enumerate()
            .map(|(d, nd)| (DimCost::from_dim(nd), d))
            .collect();
        let algos: Vec<CollAlgo> =
            (0..span.len()).map(|_| *rng.choose(&CollAlgo::ALL)).collect();
        let call = CollectiveCall {
            kind: *rng.choose(&CollectiveKind::ALL),
            policy: *rng.choose(&MultiDimPolicy::ALL),
            algos: &algos,
            span: &span,
            topology: &topo,
            bytes: 1e3 + rng.gen_f64() * 1e9,
            chunks: 1 + rng.gen_range(16) as u32,
        };
        let fair = FlowLevel::default().collective_time_us(&call);
        let factor = 1.0 + rng.gen_f64() * 7.0;
        let congested = FlowLevel::new(
            FlowLevelConfig::oversubscribed(factor).with_background_load(rng.gen_f64() * 0.5),
        )
        .collective_time_us(&call);
        if congested + 1e-9 < fair {
            return Err(format!("congested {congested} < fair {fair} (factor {factor})"));
        }
        Ok(())
    });
}

#[test]
fn prop_reward_zero_iff_invalid() {
    use cosmic::dse::{Environment, Objective, WorkloadSpec};
    let pss = Pss::new(
        paper_table4_schema(1024, 4),
        presets::system2(),
        Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
    );
    let env = Environment::new(
        pss,
        vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(2), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let space = env.pss.build_space(SearchScope::FullStack);
    check("reward zero iff invalid", 150, |rng| {
        let mut local = Rng::seed_from_u64(rng.next_u64());
        let g = space.random_genome(&mut local);
        let out = env.evaluate_uncached(&g);
        match (out.reward == 0.0, out.invalid_reason.is_some()) {
            (true, false) => Err("zero reward but no invalid reason".into()),
            (false, true) => Err("positive reward with invalid reason".into()),
            _ => Ok(()),
        }
    });
}
