//! Cross-language integration: the AOT-compiled JAX/Pallas artifacts,
//! executed through the PJRT CPU client from Rust, must agree with the
//! pure-Rust fallback implementations to f32 tolerance.
//!
//! Requires `make artifacts` (skips gracefully when artifacts are absent
//! so `cargo test` works on a fresh checkout).

use cosmic::agents::bo::Surrogate;
use cosmic::runtime::{
    cost_model_ref, CostBatch, CostModel, GpSurrogate, Runtime, BATCH, DIMS, GP_FEATURES, OPS,
};
use cosmic::util::Rng;
use std::path::{Path, PathBuf};

fn artifact_dir() -> Option<PathBuf> {
    for candidate in ["artifacts", "../artifacts"] {
        let p = Path::new(candidate);
        if p.join("cost_model.hlo.txt").exists() {
            return Some(p.to_path_buf());
        }
    }
    None
}

fn random_batch(seed: u64) -> CostBatch {
    let mut rng = Rng::seed_from_u64(seed);
    let mut b = CostBatch::zeros();
    for v in b.flops.iter_mut().chain(b.bytes.iter_mut()) {
        *v = (rng.gen_f64() * 1e6) as f32;
    }
    for v in b.steps.iter_mut() {
        *v = (rng.gen_f64() * 64.0) as f32;
    }
    for v in b.volume.iter_mut() {
        *v = (rng.gen_f64() * 1e6) as f32;
    }
    for v in b.alpha_us.iter_mut() {
        *v = (rng.gen_f64() * 10.0 + 0.01) as f32;
    }
    for v in b.beta.iter_mut() {
        *v = (rng.gen_f64() * 1e5 + 1.0) as f32;
    }
    b.peak_flops_us = 4.59e8;
    b.mem_bytes_us = 2.765e6;
    b
}

#[test]
fn cost_model_xla_matches_fallback() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let cm = CostModel::load(Some(&rt.client), &dir);
    assert!(cm.is_xla(), "artifact present but not loaded as XLA");
    for seed in [1u64, 7, 42] {
        let batch = random_batch(seed);
        let xla_out = cm.evaluate(&batch).expect("xla evaluate");
        let ref_out = cost_model_ref(&batch);
        assert_eq!(xla_out.len(), BATCH);
        for i in 0..BATCH {
            let (a, b) = (xla_out[i], ref_out[i]);
            let rel = (a - b).abs() / b.abs().max(1e-3);
            assert!(rel < 1e-4, "seed {seed} config {i}: xla={a} ref={b}");
        }
    }
}

#[test]
fn cost_model_xla_handles_zero_batch() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let cm = CostModel::load(Some(&rt.client), &dir);
    let out = cm.evaluate(&CostBatch::zeros()).unwrap();
    assert!(out.iter().all(|&x| x == 0.0));
}

#[test]
fn gp_surrogate_xla_matches_fallback() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let mut gp_xla = GpSurrogate::load(Some(&rt.client), &dir, 0.4);
    let mut gp_rust = GpSurrogate::load(None, &dir, 0.4);
    assert!(gp_xla.is_xla());
    assert!(!gp_rust.is_xla());

    let mut rng = Rng::seed_from_u64(9);
    let n = 12;
    let xs: Vec<Vec<f64>> =
        (0..n).map(|_| (0..GP_FEATURES).map(|_| rng.gen_f64()).collect()).collect();
    let ys: Vec<f64> = xs.iter().map(|x| x.iter().sum::<f64>() / GP_FEATURES as f64).collect();
    assert!(gp_xla.fit(&xs, &ys));
    assert!(gp_rust.fit(&xs, &ys));

    for _ in 0..10 {
        let q: Vec<f64> = (0..GP_FEATURES).map(|_| rng.gen_f64()).collect();
        let (mx, vx) = gp_xla.predict(&q);
        let (mr, vr) = gp_rust.predict(&q);
        assert!((mx - mr).abs() < 1e-3, "mean: xla={mx} rust={mr}");
        assert!((vx - vr).abs() < 1e-3, "var: xla={vx} rust={vr}");
    }
}

#[test]
fn bo_agent_runs_with_xla_surrogate() {
    use cosmic::agents::{Agent, BayesOpt};
    use cosmic::psa::paper_table4_schema;
    use cosmic::pss::{Pss, SearchScope};
    use cosmic::sim::presets;
    use cosmic::workload::Parallelization;

    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let rt = Runtime::cpu().unwrap();
    let gp = GpSurrogate::load(Some(&rt.client), &dir, 0.4);
    assert!(gp.is_xla());

    let pss = Pss::new(
        paper_table4_schema(1024, 4),
        presets::system2(),
        Parallelization::derive(1024, 64, 4, 1, true).unwrap(),
    );
    let space = pss.build_space(SearchScope::FullStack);
    let mut bo = BayesOpt::new(space, 16, 3).with_surrogate(Box::new(gp));
    bo.init_points = 4;
    for step in 0..8 {
        let proposals = bo.ask();
        assert!(!proposals.is_empty(), "step {step}");
        let results: Vec<_> =
            proposals.into_iter().map(|g| (g, 0.1 * (step as f64 + 1.0))).collect();
        bo.tell(&results);
    }
}

#[test]
fn batch_constants_are_consistent() {
    // Shape contract sanity (mirrors python/tests/test_model.py).
    assert_eq!(BATCH, 256);
    assert_eq!(OPS, 8);
    assert_eq!(DIMS, 4);
    assert_eq!(GP_FEATURES, 32);
}
