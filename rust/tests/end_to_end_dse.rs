//! Integration tests over the full DSE pipeline: PsA schema → PSS →
//! agents → environment → simulator, on the paper's systems/workloads.

use cosmic::agents::AgentKind;
use cosmic::dse::{DseConfig, DseRunner, Objective, SearchStrategy, WorkloadSpec};
use cosmic::harness::{make_env, make_env_with_fidelity, median_baseline_par, scoped_search};
use cosmic::netsim::{FidelityMode, FlowLevelConfig};
use cosmic::psa::{builders::names, Stack};
use cosmic::pss::SearchScope;
use cosmic::sim::presets;
use cosmic::workload::models::presets as wl;

#[test]
fn full_pipeline_all_agents_all_systems() {
    for sys in 1..=3usize {
        let cluster = presets::by_index(sys).unwrap();
        for agent in AgentKind::ALL {
            let mut env = make_env(
                cluster.clone(),
                vec![WorkloadSpec::training(wl::gpt3_13b().with_simulated_layers(2), 2048)],
                Objective::PerfPerBwPerNpu,
            );
            let r = DseRunner::new(DseConfig::new(agent, 30, sys as u64), SearchScope::FullStack)
                .run(&mut env);
            assert_eq!(r.history.len(), 30, "system {sys} agent {}", agent.name());
            assert!(
                r.best_reward > 0.0,
                "system {sys} agent {} found nothing valid",
                agent.name()
            );
        }
    }
}

#[test]
fn scoped_searches_respect_stack_freezing() {
    let mut env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(wl::gpt3_175b().with_simulated_layers(4), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let base = env.pss.baseline_genome();
    for (scope, frozen_stacks) in [
        (SearchScope::WorkloadOnly, vec![Stack::Collective, Stack::Network]),
        (SearchScope::CollectiveOnly, vec![Stack::Workload, Stack::Network]),
        (SearchScope::NetworkOnly, vec![Stack::Workload, Stack::Collective]),
        (SearchScope::CollectiveNetwork, vec![Stack::Workload]),
    ] {
        let r = scoped_search(&mut env, scope, AgentKind::Ga, 40, 9);
        if r.run.best_genome.is_empty() {
            continue;
        }
        for stack in frozen_stacks {
            for s in env.pss.schema.stack_slots(stack) {
                assert_eq!(
                    r.run.best_genome[s],
                    base[s],
                    "{}: slot {s} of frozen stack {stack:?} moved",
                    scope.name()
                );
            }
        }
    }
}

#[test]
fn full_stack_beats_or_ties_single_stacks_with_budget() {
    // The §6.1 headline in miniature: with a modest budget multiplier the
    // full-stack scope must not lose to any single stack (its space is a
    // strict superset).
    let model = wl::gpt3_175b().with_simulated_layers(4);
    let mut best_single = 0.0f64;
    for scope in
        [SearchScope::WorkloadOnly, SearchScope::CollectiveOnly, SearchScope::NetworkOnly]
    {
        let mut env = make_env(
            presets::system2(),
            vec![WorkloadSpec::training(model.clone(), 2048)],
            Objective::PerfPerBwPerNpu,
        );
        for agent in [AgentKind::Ga, AgentKind::Aco] {
            let r = scoped_search(&mut env, scope, agent, 300, 5);
            best_single = best_single.max(r.run.best_reward);
        }
    }
    let mut env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model, 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let mut best_full = 0.0f64;
    for agent in [AgentKind::Ga, AgentKind::Aco] {
        let r = scoped_search(&mut env, SearchScope::FullStack, agent, 1500, 5);
        best_full = best_full.max(r.run.best_reward);
    }
    assert!(
        best_full >= best_single * 0.95,
        "full-stack {best_full:.3e} clearly lost to best single-stack {best_single:.3e}"
    );
}

#[test]
fn fidelity_knob_searches_and_reranks_end_to_end() {
    // The netsim acceptance path: search with the PsA fidelity knob in
    // the action space, then re-rank the winner under flow-level
    // contention on an oversubscribed fabric.
    let model = wl::gpt3_13b().with_simulated_layers(2);
    let mut env = make_env_with_fidelity(
        presets::system2(),
        vec![WorkloadSpec::training(model, 2048)],
        Objective::PerfPerBwPerNpu,
    )
    .with_flow_config(FlowLevelConfig::oversubscribed(4.0));
    assert!(env.pss.schema.param(names::NET_FIDELITY).is_some());

    let r = DseRunner::new(DseConfig::new(AgentKind::Ga, 120, 7), SearchScope::FullStack)
        .run(&mut env);
    assert!(r.best_reward > 0.0, "search with fidelity knob found nothing valid");
    assert_eq!(r.best_genome.len(), env.pss.schema.genome_len());
    assert!(!r.best_reports.is_empty(), "winner's reports must re-materialize");

    // Re-rank the winner at both fidelities: congestion on a 4:1
    // oversubscribed switch fabric can only hurt.
    let screened = env.evaluate_with(&r.best_genome, FidelityMode::Analytical);
    let reranked = env.evaluate_with(&r.best_genome, FidelityMode::FlowLevel);
    assert!(screened.invalid_reason.is_none());
    assert!(reranked.invalid_reason.is_none());
    let lat = |o: &cosmic::dse::StepOutcome| -> f64 {
        o.reports.iter().map(|rep| rep.latency_us).sum()
    };
    // The winner may have searched its way onto a pure-ring fabric (no
    // oversubscribed switch dims), where the rungs agree; otherwise
    // congestion hurts. Either way flow-level must not come out
    // meaningfully *faster* than the analytical screen.
    assert!(
        lat(&reranked) >= lat(&screened) * 0.95,
        "flow-level on an oversubscribed fabric came out faster: {} vs {}",
        lat(&reranked),
        lat(&screened)
    );
}

#[test]
fn staged_search_meets_or_beats_analytical_rescored_at_flow() {
    // The staged acceptance claim: screening analytically and promoting
    // the running top-K to flow level must end at least as well (by
    // final flow-level reward) as analytical-only search re-scored at
    // flow level — with only promote_top_k flow-level simulations.
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let cfg = DseConfig::new(AgentKind::Ga, 150, 13);

    let mut analytical_env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model.clone(), 2048)],
        Objective::PerfPerBwPerNpu,
    )
    .with_flow_config(FlowLevelConfig::oversubscribed(4.0));
    let analytical = DseRunner::new(cfg, SearchScope::FullStack).run(&mut analytical_env);
    assert!(analytical.best_reward > 0.0);
    let rescored =
        analytical_env.evaluate_with(&analytical.best_genome, FidelityMode::FlowLevel).reward;

    let mut staged_env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model, 2048)],
        Objective::PerfPerBwPerNpu,
    )
    .with_flow_config(FlowLevelConfig::oversubscribed(4.0));
    let staged = DseRunner::new(cfg, SearchScope::FullStack)
        .with_strategy(SearchStrategy::Staged { promote_top_k: 8 })
        .run(&mut staged_env);

    assert!(
        staged.best_reward >= rescored,
        "staged flow reward {:.6e} < analytical-rescored {:.6e}",
        staged.best_reward,
        rescored
    );
    // The flow-level budget is the finalist count, a fraction of the
    // one-per-step budget a pure flow-level run would spend.
    assert!(staged.flow_evals <= 8, "staged spent {} flow evals", staged.flow_evals);
    assert!(!staged.finalists.is_empty());
    assert!(!staged.best_reports.is_empty(), "staged winner's reports must materialize");
}

#[test]
fn cache_enabled_evaluation_bit_identical_for_all_agents() {
    // Every genome any agent proposes must evaluate to the exact same
    // StepOutcome through the cross-evaluation cache as through the
    // cache-free path: caching must never perturb the search.
    let model = wl::gpt3_13b().with_simulated_layers(2);
    for agent in AgentKind::ALL {
        let cached_env = make_env(
            presets::system1(),
            vec![WorkloadSpec::training(model.clone(), 2048)],
            Objective::PerfPerBwPerNpu,
        );
        let fresh_env = make_env(
            presets::system1(),
            vec![WorkloadSpec::training(model.clone(), 2048)],
            Objective::PerfPerBwPerNpu,
        );
        let space = cached_env.pss.build_space(SearchScope::FullStack);
        let mut driver = agent.build(space, 31);
        for _round in 0..3 {
            let proposals = driver.ask();
            let mut results = Vec::with_capacity(proposals.len());
            for g in &proposals {
                let cached = cached_env.evaluate_nomemo(g);
                let uncached = fresh_env.evaluate_uncached(g);
                assert_eq!(
                    cached,
                    uncached,
                    "{}: cached evaluation diverged from uncached",
                    agent.name()
                );
                assert_eq!(cached.reward.to_bits(), uncached.reward.to_bits());
                // The memoized path must agree on reward and validity too.
                let memoized = cached_env.evaluate(g);
                assert_eq!(memoized.reward.to_bits(), uncached.reward.to_bits());
                assert_eq!(memoized.invalid_reason, uncached.invalid_reason);
                results.push((g.clone(), cached.reward));
            }
            driver.tell(&results);
        }
        let stats = cached_env.eval_cache_stats();
        assert!(
            stats.trace_hits + stats.coll_hits > 0,
            "{}: cross-eval cache never hit",
            agent.name()
        );
    }
}

#[test]
fn median_baseline_is_valid_for_every_system_and_model() {
    use cosmic::sim::Simulator;
    use cosmic::workload::ExecutionMode;
    let sim = Simulator::new();
    for sys in 1..=3usize {
        let cluster = presets::by_index(sys).unwrap();
        for model in wl::all() {
            let model = model.with_simulated_layers(4);
            let spec = WorkloadSpec::training(model.clone(), 2048);
            let par = median_baseline_par(&cluster, &spec);
            let run = sim.run(&cluster, &model, &par, 2048, ExecutionMode::Training);
            assert!(run.is_ok(), "system {sys} model {}: baseline {par} invalid", model.name);
        }
    }
}

#[test]
fn objectives_disagree_on_best_designs() {
    // Table 5's point: the two regularizers pull toward different
    // configurations. Verify the best genomes differ (same seeds).
    let model = wl::gpt3_175b().with_simulated_layers(4);
    let mut bests = Vec::new();
    for obj in [Objective::PerfPerBwPerNpu, Objective::PerfPerNetworkCost] {
        let mut env = make_env(
            presets::system2(),
            vec![WorkloadSpec::training(model.clone(), 2048)],
            obj,
        );
        let r = scoped_search(&mut env, SearchScope::FullStack, AgentKind::Ga, 600, 77);
        bests.push(r.run.best_genome);
    }
    assert_ne!(bests[0], bests[1], "objectives should steer to different designs");
}

#[test]
fn deterministic_runs_reproduce_exactly() {
    let model = wl::vit_base().with_simulated_layers(4);
    let run = |seed| {
        let mut env = make_env(
            presets::system1(),
            vec![WorkloadSpec::training(model.clone(), 1024)],
            Objective::PerfPerBwPerNpu,
        );
        DseRunner::new(DseConfig::new(AgentKind::Aco, 80, seed), SearchScope::FullStack)
            .run(&mut env)
    };
    let a = run(123);
    let b = run(123);
    assert_eq!(a.best_reward, b.best_reward);
    assert_eq!(a.best_genome, b.best_genome);
    assert_eq!(a.steps_to_peak, b.steps_to_peak);
    let c = run(124);
    // Different seed explores differently (not a hard guarantee, but a
    // near-certain one for an 80-step stochastic search).
    assert!(a.best_genome != c.best_genome || a.best_reward != c.best_reward);
}

#[test]
fn inference_weighted_workloads_shift_the_design() {
    use cosmic::workload::ExecutionMode;
    let gpt = wl::gpt3_175b().with_simulated_layers(4);
    let mut best = Vec::new();
    for decode_weight in [512.0, 1.0] {
        let workloads = vec![
            WorkloadSpec::inference(gpt.clone(), 64, ExecutionMode::InferencePrefill, 1.0),
            WorkloadSpec::inference(gpt.clone(), 64, ExecutionMode::InferenceDecode, decode_weight),
        ];
        let mut env = make_env(presets::system2(), workloads, Objective::PerfPerBwPerNpu);
        let r = scoped_search(&mut env, SearchScope::CollectiveNetwork, AgentKind::Aco, 400, 3);
        assert!(r.run.best_reward > 0.0);
        best.push(r.run.best_genome);
    }
    // Not asserting inequality strictly (could coincide), but both must
    // decode to materializable designs.
    for g in &best {
        assert!(!g.is_empty());
    }
}
