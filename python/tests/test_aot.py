"""AOT path: lowering produces valid HLO text that XLA can re-parse and
execute with the same numerics as the eager graphs."""

import pathlib
import sys

import numpy as np

import jax
from jax._src.lib import xla_client as xc

from compile import aot, model
from .test_model import gp_inputs


def _roundtrip_outputs(fn, specs, args):
    """Lower fn -> HLO text -> re-parse -> execute via jax.jit.

    The text is re-parsed with ``hlo_module_from_text`` to prove the
    artifact survives the text interchange (the same parser path the
    Rust runtime's ``HloModuleProto::from_text_file`` uses); numerics are
    checked by executing the jitted graph, which compiles the identical
    HLO. The full cross-language execute is covered by
    ``rust/tests/xla_runtime.rs``.
    """
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text, "HLO text should contain an entry computation"
    module = xc._xla.hlo_module_from_text(text)
    assert module is not None
    outs = jax.jit(fn)(*args)
    return [np.asarray(o) for o in outs]


def test_cost_model_hlo_text_is_nonempty_and_parseable(tmp_path):
    aot.build(tmp_path)
    for name in aot.ARTIFACTS:
        text = (tmp_path / name).read_text()
        assert len(text) > 1000, f"{name} suspiciously small"
        assert "ENTRY" in text


def test_build_is_idempotent(tmp_path):
    aot.build(tmp_path)
    first = {n: (tmp_path / n).read_text() for n in aot.ARTIFACTS}
    aot.build(tmp_path)
    second = {n: (tmp_path / n).read_text() for n in aot.ARTIFACTS}
    assert first == second


def test_gp_roundtrip_numerics():
    inputs = gp_inputs(n_real=6, seed=4)
    eager_mean, eager_var = model.gp_surrogate(*inputs)
    outs = _roundtrip_outputs(model.gp_surrogate, model.gp_surrogate_specs(), inputs)
    np.testing.assert_allclose(outs[0], np.asarray(eager_mean), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(outs[1], np.asarray(eager_var), rtol=1e-4, atol=1e-4)


def test_cost_model_roundtrip_numerics():
    rng = np.random.default_rng(0)
    specs = model.cost_model_specs()
    args = [rng.uniform(0.5, 2.0, s.shape).astype(np.float32) for s in specs]
    (eager,) = model.cost_model(*args)
    outs = _roundtrip_outputs(model.cost_model, specs, args)
    np.testing.assert_allclose(outs[0], np.asarray(eager), rtol=1e-4, atol=1e-4)
