"""Custom-call-free linalg kernels vs numpy/LAPACK references."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import linalg


def spd(n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(0, 1, (n, n)).astype(np.float32)
    return a @ a.T + n * np.eye(n, dtype=np.float32)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([2, 3, 8, 16, 64]), seed=st.integers(0, 10_000))
def test_cholesky_matches_numpy(n, seed):
    k = spd(n, seed)
    l = np.asarray(linalg.cholesky(k))
    l_ref = np.linalg.cholesky(k)
    np.testing.assert_allclose(l, l_ref, rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([2, 8, 32]), seed=st.integers(0, 10_000))
def test_cho_solve_solves(n, seed):
    k = spd(n, seed)
    rng = np.random.default_rng(seed + 1)
    b = rng.normal(0, 1, n).astype(np.float32)
    l = linalg.cholesky(k)
    x = np.asarray(linalg.cho_solve(l, b))
    np.testing.assert_allclose(k @ x, b, rtol=1e-2, atol=1e-2)


def test_solve_lower_matrix_rhs():
    k = spd(16, 3)
    l = np.asarray(linalg.cholesky(k))
    rng = np.random.default_rng(4)
    b = rng.normal(0, 1, (16, 5)).astype(np.float32)
    y = np.asarray(linalg.solve_lower(l, b))
    np.testing.assert_allclose(l @ y, b, rtol=1e-3, atol=1e-3)


def test_solve_upper_t_matrix_rhs():
    k = spd(16, 5)
    l = np.asarray(linalg.cholesky(k))
    rng = np.random.default_rng(6)
    b = rng.normal(0, 1, 16).astype(np.float32)
    x = np.asarray(linalg.solve_upper_t(l, b))
    np.testing.assert_allclose(l.T @ x, b, rtol=1e-3, atol=1e-3)


def test_cholesky_lower_triangular():
    l = np.asarray(linalg.cholesky(spd(8, 9)))
    assert np.allclose(np.triu(l, 1), 0.0)
