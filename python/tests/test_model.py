"""L2 correctness: the GP surrogate graph and the cost-model wrapper."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import gp_posterior_ref
from compile.model import (
    GP_FEATURES,
    GP_QUERY,
    GP_TRAIN,
    cost_model,
    cost_model_specs,
    gp_surrogate,
    gp_surrogate_specs,
)


def gp_inputs(n_real=8, seed=0, lengthscale=0.4, noise=1e-4):
    rng = np.random.default_rng(seed)
    f32 = np.float32
    x = np.zeros((GP_TRAIN, GP_FEATURES), f32)
    y = np.zeros((GP_TRAIN,), f32)
    mask = np.zeros((GP_TRAIN,), f32)
    x[:n_real] = rng.uniform(0, 1, (n_real, GP_FEATURES)).astype(f32)
    y[:n_real] = rng.normal(0, 1, n_real).astype(f32)
    mask[:n_real] = 1.0
    xq = np.zeros((GP_QUERY, GP_FEATURES), f32)
    xq[:n_real] = rng.uniform(0, 1, (n_real, GP_FEATURES)).astype(f32)
    return x, y, mask, xq, np.array([lengthscale], f32), np.array([noise], f32)


def test_gp_matches_reference():
    inputs = gp_inputs(n_real=10, seed=3)
    mean, var = gp_surrogate(*inputs)
    mean_ref, var_ref = gp_posterior_ref(*inputs)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref), rtol=1e-4, atol=1e-4)


def test_gp_interpolates_training_points():
    x, y, mask, _, ls, noise = gp_inputs(n_real=6, seed=5, noise=1e-5)
    # Query exactly the training points.
    xq = x.copy()
    mean, var = gp_surrogate(x, y, mask, xq, ls, noise)
    mean = np.asarray(mean)[:6]
    var = np.asarray(var)[:6]
    np.testing.assert_allclose(mean, y[:6], atol=0.05)
    assert np.all(var < 0.05)


def test_gp_variance_bounds():
    inputs = gp_inputs(n_real=4, seed=9)
    _, var = gp_surrogate(*inputs)
    var = np.asarray(var)
    assert np.all(var > 0)
    assert np.all(var <= 1.0 + 1e-5)


def test_gp_padding_inert():
    x, y, mask, xq, ls, noise = gp_inputs(n_real=5, seed=1)
    x2 = x.copy()
    x2[10:] = 0.77  # garbage in padded rows
    m1, v1 = gp_surrogate(x, y, mask, xq, ls, noise)
    m2, v2 = gp_surrogate(x2, y, mask, xq, ls, noise)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    n_real=st.integers(2, GP_TRAIN),
    seed=st.integers(0, 10_000),
    ls=st.sampled_from([0.1, 0.3, 0.5, 1.0]),
)
def test_gp_reference_agreement_hypothesis(n_real, seed, ls):
    inputs = gp_inputs(n_real=n_real, seed=seed, lengthscale=ls)
    mean, var = gp_surrogate(*inputs)
    mean_ref, var_ref = gp_posterior_ref(*inputs)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(mean_ref), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_ref), rtol=1e-3, atol=1e-3)


def test_cost_model_wrapper_shapes():
    specs = cost_model_specs()
    args = [np.ones(s.shape, np.float32) for s in specs]
    (total,) = cost_model(*args)
    assert np.asarray(total).shape == (specs[0].shape[0],)


def test_spec_shapes_match_rust_constants():
    """Shape contract with rust/src/runtime/fallback.rs."""
    cm = cost_model_specs()
    assert cm[0].shape == (256, 8)
    assert cm[2].shape == (256, 4)
    gp = gp_surrogate_specs()
    assert gp[0].shape == (64, 32)
    assert gp[3].shape == (64, 32)


@pytest.mark.parametrize("n_real", [1, GP_TRAIN])
def test_gp_edge_population_sizes(n_real):
    inputs = gp_inputs(n_real=n_real, seed=2)
    mean, var = gp_surrogate(*inputs)
    assert np.all(np.isfinite(np.asarray(mean)))
    assert np.all(np.isfinite(np.asarray(var)))
