"""L1 correctness: Pallas roofline kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: the kernel
that ends up inside the AOT artifact must agree with ``ref.py`` on
every input we can throw at it -- fixed cases, seeded random sweeps, and
hypothesis-generated shapes/values.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.ref import roofline_cost_ref
from compile.kernels.roofline import BATCH, DIMS, OPS, roofline_cost


def make_inputs(rng, scale=1e6):
    f32 = np.float32
    return (
        rng.uniform(0, scale, (BATCH, OPS)).astype(f32),
        rng.uniform(0, scale, (BATCH, OPS)).astype(f32),
        rng.uniform(0, 64, (BATCH, DIMS)).astype(f32),
        rng.uniform(0, scale, (BATCH, DIMS)).astype(f32),
        rng.uniform(0.01, 10, (BATCH, DIMS)).astype(f32),
        rng.uniform(1, 1e5, (BATCH, DIMS)).astype(f32),
        np.array([1e8], dtype=f32),
        np.array([1e6], dtype=f32),
    )


def test_zero_inputs_cost_zero():
    zeros = (
        np.zeros((BATCH, OPS), np.float32),
        np.zeros((BATCH, OPS), np.float32),
        np.zeros((BATCH, DIMS), np.float32),
        np.zeros((BATCH, DIMS), np.float32),
        np.zeros((BATCH, DIMS), np.float32),
        np.ones((BATCH, DIMS), np.float32),
        np.array([1.0], np.float32),
        np.array([1.0], np.float32),
    )
    out = np.asarray(roofline_cost(*zeros))
    assert out.shape == (BATCH,)
    np.testing.assert_array_equal(out, 0.0)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 17, 42])
def test_kernel_matches_ref_random(seed):
    rng = np.random.default_rng(seed)
    inputs = make_inputs(rng)
    got = np.asarray(roofline_cost(*inputs))
    want = np.asarray(roofline_cost_ref(*inputs))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


def test_roofline_max_semantics():
    """A compute-bound row must cost flops/peak; memory-bound bytes/membw."""
    rng = np.random.default_rng(7)
    inputs = list(make_inputs(rng))
    # Zero out comm terms.
    for i in (2, 3):
        inputs[i] = np.zeros_like(inputs[i])
    inputs[4] = np.zeros_like(inputs[4])
    # Row 0: all compute-bound (huge flops, tiny bytes).
    inputs[0][0, :] = 1e9
    inputs[1][0, :] = 1.0
    out = np.asarray(roofline_cost(*inputs))
    expect = OPS * 1e9 / inputs[6][0]
    np.testing.assert_allclose(out[0], expect, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1.0, 1e3, 1e6, 1e9]),
    peak=st.sampled_from([1e6, 1e8, 4.59e8]),
    membw=st.sampled_from([5e4, 1e6, 2.765e6]),
)
def test_kernel_matches_ref_hypothesis(seed, scale, peak, membw):
    rng = np.random.default_rng(seed)
    inputs = list(make_inputs(rng, scale=scale))
    inputs[6] = np.array([peak], np.float32)
    inputs[7] = np.array([membw], np.float32)
    got = np.asarray(roofline_cost(*inputs))
    want = np.asarray(roofline_cost_ref(*inputs))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)
    assert np.all(np.isfinite(got))
    assert np.all(got >= 0)


def test_monotone_in_flops():
    rng = np.random.default_rng(11)
    inputs = list(make_inputs(rng))
    base = np.asarray(roofline_cost(*inputs))
    inputs[0] = inputs[0] * 2.0
    more = np.asarray(roofline_cost(*inputs))
    assert np.all(more >= base - 1e-3)
