"""L2 compute graphs, AOT-lowered to HLO text for the Rust runtime.

Two graphs:

- ``cost_model`` -- the batched analytical cost estimator. Wraps the L1
  Pallas roofline kernel (``kernels/roofline.py``); the Rust DSE uses it
  to score candidate-configuration batches before running the detailed
  discrete-event simulation.
- ``gp_surrogate`` -- the BO agent's Gaussian-process posterior
  (fit + predict in one call: masked RBF kernel, Cholesky solve,
  posterior mean/variance at a padded query batch).

Both use fixed shapes (AOT requires static shapes); padding + masks
handle variable problem sizes. Python never runs at DSE time -- these
lower once in ``aot.py`` and the Rust runtime executes the artifacts.
"""

import jax
import jax.numpy as jnp

from .kernels import linalg
from .kernels.roofline import BATCH, DIMS, OPS, roofline_cost

# GP artifact shapes -- keep in sync with rust/src/runtime/fallback.rs.
GP_TRAIN = 64
GP_QUERY = 64
GP_FEATURES = 32


def cost_model(flops, bytes_, steps, volume, alpha_us, beta, peak, membw):
    """Batched candidate scoring. Returns a 1-tuple (jax AOT convention).

    Args (all f32):
        flops, bytes_:      [BATCH, OPS]   per-operator roofline inputs
        steps, volume,
        alpha_us, beta:     [BATCH, DIMS]  per-dimension alpha-beta inputs
        peak, membw:        [1]            device roofline constants
    """
    total = roofline_cost(flops, bytes_, steps, volume, alpha_us, beta, peak, membw)
    return (total,)


def cost_model_specs():
    """ShapeDtypeStructs for lowering cost_model."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((BATCH, OPS), f32),
        jax.ShapeDtypeStruct((BATCH, OPS), f32),
        jax.ShapeDtypeStruct((BATCH, DIMS), f32),
        jax.ShapeDtypeStruct((BATCH, DIMS), f32),
        jax.ShapeDtypeStruct((BATCH, DIMS), f32),
        jax.ShapeDtypeStruct((BATCH, DIMS), f32),
        jax.ShapeDtypeStruct((1,), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )


def gp_surrogate(x_train, y, mask, x_query, lengthscale, noise):
    """GP posterior (mean, var) at the queries.

    Matches ``kernels.ref.gp_posterior_ref`` and the Rust fallback:
    masked RBF kernel; diagonal jitter ``noise + 1e-6`` plus ``1.0`` on
    padded rows; Cholesky solves; ``var = max(1 - v.v, 1e-9)``.

    Args (all f32):
        x_train:     [GP_TRAIN, GP_FEATURES]  normalized genomes (padded)
        y:           [GP_TRAIN]               centered rewards
        mask:        [GP_TRAIN]               1.0 = real row, 0.0 = padding
        x_query:     [GP_QUERY, GP_FEATURES]  query genomes (padded)
        lengthscale: [1]
        noise:       [1]
    """
    ls2 = 2.0 * lengthscale[0] * lengthscale[0]
    d2 = jnp.sum((x_train[:, None, :] - x_train[None, :, :]) ** 2, axis=-1)
    k = jnp.exp(-d2 / ls2) * mask[:, None] * mask[None, :]
    diag = noise[0] + 1e-6 + (1.0 - mask) * 1.0
    k = k + jnp.diag(diag)

    # Custom-call-free factorization (kernels/linalg.py): jnp.linalg /
    # jax.scipy lower to LAPACK custom-calls the Rust-side XLA rejects.
    l = linalg.cholesky(k)
    ym = y * mask
    alpha = linalg.cho_solve(l, ym)

    d2q = jnp.sum((x_train[:, None, :] - x_query[None, :, :]) ** 2, axis=-1)
    kq = jnp.exp(-d2q / ls2) * mask[:, None]  # [train, query]
    mean = kq.T @ alpha
    v = linalg.solve_lower(l, kq)  # [train, query]
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-9)
    return (mean, var)


def gp_surrogate_specs():
    """ShapeDtypeStructs for lowering gp_surrogate."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((GP_TRAIN, GP_FEATURES), f32),
        jax.ShapeDtypeStruct((GP_TRAIN,), f32),
        jax.ShapeDtypeStruct((GP_TRAIN,), f32),
        jax.ShapeDtypeStruct((GP_QUERY, GP_FEATURES), f32),
        jax.ShapeDtypeStruct((1,), f32),
        jax.ShapeDtypeStruct((1,), f32),
    )
