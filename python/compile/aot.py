"""AOT lowering: JAX graphs -> HLO text artifacts for the Rust runtime.

HLO *text* is the interchange format, NOT ``lowered.compile().serialize()``
and NOT the serialized HloModuleProto: jax >= 0.5 emits protos with
64-bit instruction ids which the xla crate's bundled XLA (xla_extension
0.5.1) rejects (``proto.id() <= INT_MAX``); the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot [--out-dir ../artifacts]

Idempotent: artifacts are only rewritten when inputs are newer (the
Makefile also guards this), so ``make artifacts`` is a no-op on a built
tree and python never runs on the request path.
"""

import argparse
import pathlib
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACTS = {
    "cost_model.hlo.txt": (model.cost_model, model.cost_model_specs),
    "gp_surrogate.hlo.txt": (model.gp_surrogate, model.gp_surrogate_specs),
}


def build(out_dir: pathlib.Path) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    for name, (fn, specs) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs())
        text = to_hlo_text(lowered)
        path = out_dir / name
        path.write_text(text)
        print(f"wrote {len(text):>9} chars to {path}")


def main() -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--out-dir",
        default="../artifacts",
        help="artifact output directory (default: ../artifacts)",
    )
    parser.add_argument(
        "--out",
        default=None,
        help="legacy single-file mode: ignored, directory build is canonical",
    )
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out).parent if args.out else pathlib.Path(args.out_dir)
    build(out_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
