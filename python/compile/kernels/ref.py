"""Pure-jnp oracles for the Pallas kernel and the GP surrogate.

These are the correctness references: ``test_kernel.py`` asserts the
Pallas kernel matches ``roofline_cost_ref`` across randomized and
hypothesis-generated inputs, and ``test_model.py`` checks the GP graph
against ``gp_posterior_ref``. The Rust fallback
(``rust/src/runtime/fallback.rs``) implements the same equations.
"""

import jax.numpy as jnp


def roofline_cost_ref(flops, bytes_, steps, volume, alpha_us, beta, peak, membw):
    """Reference for kernels.roofline.roofline_cost (same signature)."""
    compute = jnp.sum(jnp.maximum(flops / peak[0], bytes_ / membw[0]), axis=1)
    comm = jnp.sum(steps * alpha_us + volume / beta, axis=1)
    return compute + comm


def gp_posterior_ref(x_train, y, mask, x_query, lengthscale, noise):
    """Reference GP posterior (mean, var) with masked padding rows.

    Must match both ``model.gp_surrogate`` and the Rust fallback:
    - RBF kernel ``exp(-|a-b|^2 / (2 l^2))`` masked by row validity;
    - diagonal gets ``noise + 1e-6``, plus ``1.0`` on padded rows;
    - ``var = max(1 - v.v, 1e-9)`` with ``v = L^-1 k_q``.
    """
    ls2 = 2.0 * lengthscale[0] * lengthscale[0]
    d2 = jnp.sum((x_train[:, None, :] - x_train[None, :, :]) ** 2, axis=-1)
    k = jnp.exp(-d2 / ls2) * mask[:, None] * mask[None, :]
    diag = noise[0] + 1e-6 + (1.0 - mask) * 1.0
    k = k + jnp.diag(diag)

    l = jnp.linalg.cholesky(k)
    ym = y * mask
    alpha = jnp.linalg.solve(k, ym)

    d2q = jnp.sum((x_train[:, None, :] - x_query[None, :, :]) ** 2, axis=-1)
    kq = jnp.exp(-d2q / ls2) * mask[:, None]  # [train, query]
    mean = kq.T @ alpha
    v = jnp.linalg.solve(l, kq)  # [train, query]
    var = jnp.maximum(1.0 - jnp.sum(v * v, axis=0), 1e-9)
    return mean, var
