"""L1 Pallas kernel: batched roofline + alpha-beta collective cost.

The DSE inner loop scores thousands of candidate cluster configurations;
the analytical pre-filter evaluates, for a batch of ``BATCH`` candidates
with ``OPS`` operator classes and ``DIMS`` network dimensions:

    total[i] = sum_k max(flops[i,k]/peak, bytes[i,k]/membw)          (roofline)
             + sum_d (steps[i,d] * alpha[i,d] + volume[i,d]/beta[i,d])  (alpha-beta)

Shapes are fixed at AOT time (see ``SHAPES``) and must match the Rust
side (``rust/src/runtime/fallback.rs``).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is blocked along
the batch axis in ``BLOCK`` rows so one block's operands --
``BLOCK*(2*OPS + 4*DIMS) * 4 B`` = 128*(16+16)*4 = 16 KiB -- sit
comfortably in VMEM; the reduction over ops/dims is VPU elementwise work
with a single fused max. ``interpret=True`` everywhere: the CPU PJRT
client cannot run Mosaic custom-calls, and correctness is what the AOT
path needs (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed artifact shapes -- keep in sync with rust/src/runtime/fallback.rs.
BATCH = 256
OPS = 8
DIMS = 4
BLOCK = 128  # batch rows per Pallas block

SHAPES = {
    "flops": (BATCH, OPS),
    "bytes": (BATCH, OPS),
    "steps": (BATCH, DIMS),
    "volume": (BATCH, DIMS),
    "alpha_us": (BATCH, DIMS),
    "beta": (BATCH, DIMS),
}


def _cost_kernel(flops_ref, bytes_ref, steps_ref, volume_ref, alpha_ref,
                 beta_ref, peak_ref, membw_ref, out_ref):
    """One block: BLOCK candidate rows, full OPS/DIMS width."""
    peak = peak_ref[0]
    membw = membw_ref[0]
    compute_us = jnp.maximum(flops_ref[...] / peak, bytes_ref[...] / membw)
    compute_total = jnp.sum(compute_us, axis=1)
    comm_us = steps_ref[...] * alpha_ref[...] + volume_ref[...] / beta_ref[...]
    comm_total = jnp.sum(comm_us, axis=1)
    out_ref[...] = compute_total + comm_total


@functools.partial(jax.jit, static_argnames=())
def roofline_cost(flops, bytes_, steps, volume, alpha_us, beta, peak, membw):
    """Batched analytical cost (microseconds) per candidate config.

    ``peak``/``membw`` arrive as shape-(1,) f32 arrays (flops/us and
    bytes/us) so the whole computation stays shape-polymorphic-free for
    AOT lowering.
    """
    grid = (BATCH // BLOCK,)
    return pl.pallas_call(
        _cost_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, OPS), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, OPS), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, DIMS), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, DIMS), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, DIMS), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, DIMS), lambda i: (i, 0)),
            # Scalars broadcast to every block.
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((BATCH,), jnp.float32),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(flops, bytes_, steps, volume, alpha_us, beta, peak, membw)
