"""Custom-call-free dense linear algebra for AOT artifacts.

``jnp.linalg.cholesky`` / ``jax.scipy.linalg.solve_triangular`` lower to
LAPACK *custom-calls* on CPU (API_VERSION_TYPED_FFI), which the Rust
runtime's XLA (xla_extension 0.5.1) cannot execute. These replacements
lower to pure HLO (while-loops + dynamic slices) so the GP artifact runs
on any PJRT backend.

All routines assume static square shapes — fine for the fixed-shape AOT
artifacts.
"""

import jax
import jax.numpy as jnp


def cholesky(k):
    """Lower-triangular Cholesky factor of an SPD matrix.

    Right-looking algorithm: one ``fori_loop`` over columns, each step a
    masked rank-1 Schur-complement update — O(n) HLO while-iterations of
    O(n^2) vector work.
    """
    k = jnp.asarray(k)
    n = k.shape[0]
    rows = jnp.arange(n)

    def body(j, carry):
        a, l = carry
        d = jnp.sqrt(jnp.maximum(a[j, j], 1e-30))
        col = jnp.where(rows >= j, a[:, j] / d, 0.0)
        l = l.at[:, j].set(col)
        a = a - jnp.outer(col, col)
        return (a, l)

    _, l = jax.lax.fori_loop(0, n, body, (k, jnp.zeros_like(k)))
    return l


def solve_lower(l, b):
    """Solve ``L y = b`` (forward substitution). ``b``: [n] or [n, m]."""
    l, b = jnp.asarray(l), jnp.asarray(b)
    n = l.shape[0]
    y0 = jnp.zeros_like(b)

    def body(i, y):
        # y[j] == 0 for j >= i, so the full dot only picks up j < i.
        acc = l[i, :] @ y
        yi = (b[i] - acc) / l[i, i]
        return y.at[i].set(yi)

    return jax.lax.fori_loop(0, n, body, y0)


def solve_upper_t(l, b):
    """Solve ``L^T x = b`` (backward substitution on the transpose)."""
    l, b = jnp.asarray(l), jnp.asarray(b)
    n = l.shape[0]
    x0 = jnp.zeros_like(b)

    def body(k, x):
        i = n - 1 - k
        acc = l[:, i] @ x  # only rows j > i contribute (x[j>i] set)
        xi = (b[i] - acc) / l[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, n, body, x0)


def cho_solve(l, b):
    """Solve ``L L^T x = b`` given the Cholesky factor."""
    return solve_upper_t(l, solve_lower(l, b))
