//! Figure 8 — scalability on System 3 (2,048 NPUs): workload-only vs
//! full-stack DSE for ViT-Large and GPT3-175B across global batch sizes
//! 1,024–16,384, normalized to the full-stack result at batch 1,024.
//!
//! Paper shape: full-stack always beats workload-only; the benefit is
//! larger for GPT3-175B (≥4.19×) than ViT-Large (≥1.71×) — bigger
//! models on bigger clusters gain more from co-design.

use cosmic::agents::AgentKind;
use cosmic::dse::{Objective, WorkloadSpec};
use cosmic::harness::{make_env, print_table, scoped_search};
use cosmic::pss::SearchScope;
use cosmic::sim::presets;
use cosmic::workload::models::presets as wl;
use std::time::Instant;

const STEPS: u64 = 400;
// Full-stack gets a larger (still sub-proportionate) budget for its
// ~1e5x larger space, as in the Figure 6/7 benches.
const FULL_STEPS: u64 = 2000;
const BATCHES: [u64; 5] = [1024, 2048, 4096, 8192, 16384];

fn best_reward(scope: SearchScope, model: &cosmic::workload::ModelConfig, batch: u64) -> f64 {
    let mut env = make_env(
        presets::system3(),
        vec![WorkloadSpec::training(model.clone(), batch)],
        Objective::PerfPerBwPerNpu,
    );
    let steps = if scope == SearchScope::FullStack { FULL_STEPS } else { STEPS };
    let mut best = 0.0f64;
    for (i, agent) in [AgentKind::Ga, AgentKind::Aco, AgentKind::Bo].iter().enumerate() {
        let r = scoped_search(&mut env, scope, *agent, steps, 800 + i as u64 + batch);
        best = best.max(r.run.best_reward);
    }
    best
}

fn main() {
    let started = Instant::now();
    for model in [wl::vit_large().with_simulated_layers(4), wl::gpt3_175b().with_simulated_layers(4)]
    {
        let mut rows = Vec::new();
        let mut ratios = Vec::new();
        let mut norm = None;
        for batch in BATCHES {
            let full = best_reward(SearchScope::FullStack, &model, batch);
            let wl_only = best_reward(SearchScope::WorkloadOnly, &model, batch);
            let norm_base = *norm.get_or_insert(full);
            let ratio = full / wl_only.max(1e-300);
            ratios.push(ratio);
            rows.push(vec![
                format!("{batch}"),
                format!("{:.3}", full / norm_base),
                format!("{:.3}", wl_only / norm_base),
                format!("{ratio:.2}x"),
            ]);
        }
        print_table(
            &format!("Figure 8: {} on System 3 (2048 NPUs)", model.name),
            &[
                "global batch",
                "full-stack (norm. to batch-1024 full)",
                "workload-only (norm.)",
                "full/workload benefit",
            ],
            &rows,
        );
        let always_wins = ratios.iter().all(|r| *r >= 1.0);
        println!(
            "full-stack beats workload-only at every batch: {}",
            if always_wins { "OK" } else { "MISMATCH" }
        );
        println!(
            "min benefit {:.2}x, max benefit {:.2}x (paper: ViT-L 1.71-3.75x, GPT3 4.19-5.05x)",
            ratios.iter().cloned().fold(f64::INFINITY, f64::min),
            ratios.iter().cloned().fold(0.0, f64::max)
        );
    }
    println!("\nbench wall time: {:.2}s", started.elapsed().as_secs_f64());
}
