//! Table 6 — partial-stack co-design use cases.
//!
//! - **Expr 1**: workload+network co-design, collectives fixed, jointly
//!   optimizing an *ensemble* of all four Table 2 models (the paper's
//!   "Multi-Model" observation column). Paper shape: COSMIC grows TP to
//!   cut the ensemble memory footprint, aligns NPUs-per-dim with the TP
//!   group, and keeps weight sharding on.
//! - **Expr 2.1 / 2.2**: collective+network co-design with the workload
//!   parallelization fixed, for GPT3-175B *inference* — 2.1 Chat
//!   (decode-heavy: 1 prefill + 512 decode steps) and 2.2 QA
//!   (prefill-heavy: 1 prefill + 32 decode steps). Paper shape:
//!   latency-optimized collectives (DI/RHD/DBT) win over Ring; small
//!   chunk counts for prefill pipelining.

use cosmic::agents::AgentKind;
use cosmic::dse::{Objective, WorkloadSpec};
use cosmic::harness::{make_env, print_table, scoped_search};
use cosmic::pss::SearchScope;
use cosmic::sim::presets;
use cosmic::workload::models::presets as wl;
use cosmic::workload::ExecutionMode;
use std::time::Instant;

const STEPS: u64 = 800;

struct ExprResult {
    label: &'static str,
    cluster: cosmic::sim::ClusterConfig,
    par: cosmic::workload::Parallelization,
    reward: f64,
}

fn run_expr(
    label: &'static str,
    workloads: Vec<WorkloadSpec>,
    scope: SearchScope,
) -> ExprResult {
    let mut env = make_env(presets::system2(), workloads, Objective::PerfPerBwPerNpu);
    let mut best: Option<(Vec<usize>, f64)> = None;
    for (i, agent) in [AgentKind::Ga, AgentKind::Aco, AgentKind::Bo].iter().enumerate() {
        let r = scoped_search(&mut env, scope, *agent, STEPS, 600 + i as u64);
        if best.as_ref().map(|(_, b)| r.run.best_reward > *b).unwrap_or(true)
            && !r.run.best_genome.is_empty()
        {
            best = Some((r.run.best_genome, r.run.best_reward));
        }
    }
    let (genome, reward) = best.expect("no design found");
    let point = env.pss.schema.decode(&genome).unwrap();
    let (cluster, par) = env.pss.materialize(&point).unwrap();
    ExprResult { label, cluster, par, reward }
}

fn main() {
    let started = Instant::now();
    let four_layers = |m: cosmic::workload::ModelConfig| m.with_simulated_layers(4);

    // Expr 1: multi-model training, workload+network free, collectives fixed.
    let expr1 = run_expr(
        "Expr 1 (Multi-Model)",
        wl::all().into_iter().map(|m| WorkloadSpec::training(four_layers(m), 1024)).collect(),
        SearchScope::WorkloadNetwork,
    );

    // Expr 2: inference, collective+network free, workload fixed.
    let gpt = four_layers(wl::gpt3_175b());
    let chat = vec![
        WorkloadSpec::inference(gpt.clone(), 64, ExecutionMode::InferencePrefill, 1.0),
        WorkloadSpec::inference(gpt.clone(), 64, ExecutionMode::InferenceDecode, 512.0),
    ];
    let qa = vec![
        WorkloadSpec::inference(gpt.clone(), 64, ExecutionMode::InferencePrefill, 1.0),
        WorkloadSpec::inference(gpt.clone(), 64, ExecutionMode::InferenceDecode, 32.0),
    ];
    let expr21 = run_expr("Expr 2.1 (Chat)", chat, SearchScope::CollectiveNetwork);
    let expr22 = run_expr("Expr 2.2 (QA)", qa, SearchScope::CollectiveNetwork);

    let exprs = [&expr1, &expr21, &expr22];
    let mut rows = Vec::new();
    let knob = |name: &str, f: &dyn Fn(&ExprResult) -> String| {
        let mut row = vec![name.to_string()];
        for e in exprs {
            row.push(f(e));
        }
        row
    };
    rows.push(knob("Topology", &|e| e.cluster.topology.notation()));
    rows.push(knob("NPUs-count", &|e| {
        format!("{:?}", e.cluster.topology.dims.iter().map(|d| d.npus).collect::<Vec<_>>())
    }));
    rows.push(knob("Bandwidth per Link", &|e| {
        format!("{:?}", e.cluster.topology.dims.iter().map(|d| d.bandwidth_gbps).collect::<Vec<_>>())
    }));
    rows.push(knob("Scheduling Policy", &|e| e.cluster.collectives.scheduling.name().into()));
    rows.push(knob("Chunks per Collective", &|e| format!("{}", e.cluster.collectives.chunks)));
    rows.push(knob("Collective Algorithm", &|e| e.cluster.collectives.algo_notation()));
    rows.push(knob("Multi-dim Collective", &|e| e.cluster.collectives.multidim.name().into()));
    rows.push(knob("Number of NPUs", &|e| format!("{}", e.cluster.npus())));
    rows.push(knob("DP, PP, SP, TP", &|e| {
        format!("{}, {}, {}, {}", e.par.dp, e.par.pp, e.par.sp, e.par.tp)
    }));
    rows.push(knob("Weight Sharded", &|e| format!("{}", e.par.weight_sharded as u8)));
    rows.push(knob("(best reward)", &|e| format!("{:.3e}", e.reward)));
    print_table(
        "Table 6: co-design use cases (System 2 base)",
        &["knob", expr1.label, expr21.label, expr22.label],
        &rows,
    );

    // Shape checks.
    println!(
        "\nExpr 1 TP grows beyond baseline 16 to fit the ensemble (paper: TP=64): TP={} -> {}",
        expr1.par.tp,
        if expr1.par.tp >= 16 { "OK" } else { "DIFFERS" }
    );
    for e in [&expr21, &expr22] {
        let ring_dims = e
            .cluster
            .collectives
            .algorithms
            .iter()
            .filter(|a| matches!(a, cosmic::collective::CollAlgo::Ring))
            .count();
        println!(
            "{}: latency-optimized collectives dominate (Ring on {}/{} dims; paper avoids Ring): {}",
            e.label,
            ring_dims,
            e.cluster.collectives.algorithms.len(),
            if ring_dims <= 2 { "OK" } else { "DIFFERS" }
        );
    }
    println!("\nbench wall time: {:.2}s", started.elapsed().as_secs_f64());
}
