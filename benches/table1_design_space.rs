//! Table 1 — the PsA schema's design-space cardinality for a 4D network
//! with 1,024 NPUs, and the §3.2 exhaustive-search infeasibility
//! estimate (paper: ≈7.69e13 points, ≈2.44e6 years at 1 s/point).

use cosmic::harness::print_table;
use cosmic::psa::space::exhaustive_search_years;
use cosmic::psa::{design_space_size, paper_table1_schema};
use cosmic::workload::enumerate_parallelizations;
use std::time::Instant;

fn main() {
    let started = Instant::now();
    let npus = 1024;
    let dims = 4;
    let schema = paper_table1_schema(npus, dims);

    let mut rows = Vec::new();
    for p in &schema.params {
        rows.push(vec![
            p.name.clone(),
            p.stack.name().to_string(),
            format!("{}", p.domain.cardinality()),
            format!("{}", p.dims),
            format!("{}", p.cardinality()),
        ]);
    }
    let combos = enumerate_parallelizations(npus, npus, &[false]).len();
    rows.push(vec![
        "(DP,SP,PP) constrained combos".into(),
        "workload".into(),
        "-".into(),
        "-".into(),
        format!("{combos}"),
    ]);
    print_table(
        "Table 1: PsA schema cardinalities (1,024 NPUs, 4D network)",
        &["knob", "stack", "|domain|", "dims", "#points"],
        &rows,
    );

    let total = design_space_size(&schema, npus);
    let years = exhaustive_search_years(total, 1.0);
    println!("\ntotal #points: {total:.4e}   (paper: 7.69e13)");
    println!("exhaustive search @1s/point: {years:.3e} years (paper: 2.44e6)");
    println!(
        "workload combos = {combos} (paper: 286) -> {}",
        if combos == 286 { "EXACT" } else { "MISMATCH" }
    );
    let ok = (total / 7.69e13 - 1.0).abs() < 0.01;
    println!("total matches paper to <1%: {}", if ok { "OK" } else { "MISMATCH" });
    println!("\nbench wall time: {:.3}s", started.elapsed().as_secs_f64());
}
