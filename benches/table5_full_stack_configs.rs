//! Table 5 — the full-stack designs COSMIC discovers on System 2
//! (1,024 NPUs) for GPT3-175B under the two optimization targets,
//! printed in the paper's knob layout.
//!
//! Paper shape: the two targets produce *different* network
//! configurations (BW/NPU prefers lean ring-heavy fabrics; network-cost
//! tolerates switches when they pay for themselves), both pick
//! weight-sharded parallelizations, and bandwidth settles at the low
//! end (50 GB/s per dim in the paper).

use cosmic::agents::AgentKind;
use cosmic::dse::{Objective, WorkloadSpec};
use cosmic::harness::{make_env, print_table, scoped_search};
use cosmic::psa::builders::names;
use cosmic::pss::SearchScope;
use cosmic::sim::presets;
use cosmic::workload::models::presets as wl;
use std::time::Instant;

const STEPS: u64 = 1000;

fn main() {
    let started = Instant::now();
    let mut columns = Vec::new();
    for objective in [Objective::PerfPerBwPerNpu, Objective::PerfPerNetworkCost] {
        let mut env = make_env(
            presets::system2(),
            vec![WorkloadSpec::training(wl::gpt3_175b().with_simulated_layers(4), 2048)],
            objective,
        );
        let mut best: Option<(Vec<usize>, f64)> = None;
        for (i, agent) in AgentKind::ALL.iter().enumerate() {
            let r = scoped_search(&mut env, SearchScope::FullStack, *agent, STEPS, 500 + i as u64);
            if best.as_ref().map(|(_, b)| r.run.best_reward > *b).unwrap_or(true)
                && !r.run.best_genome.is_empty()
            {
                best = Some((r.run.best_genome.clone(), r.run.best_reward));
            }
        }
        let (genome, reward) = best.expect("search found nothing");
        let point = env.pss.schema.decode(&genome).unwrap();
        let (cluster, par) = env.pss.materialize(&point).unwrap();
        columns.push((objective, point, cluster, par, reward));
    }

    let mut rows = Vec::new();
    let knob = |name: &str, f: &dyn Fn(usize) -> String| {
        let mut row = vec![name.to_string()];
        for i in 0..2 {
            row.push(f(i));
        }
        row
    };
    rows.push(knob("DP", &|i| format!("{}", columns[i].3.dp)));
    rows.push(knob("PP", &|i| format!("{}", columns[i].3.pp)));
    rows.push(knob("SP", &|i| format!("{}", columns[i].3.sp)));
    rows.push(knob("TP (derived)", &|i| format!("{}", columns[i].3.tp)));
    rows.push(knob("Weight Sharded", &|i| format!("{}", columns[i].3.weight_sharded as u8)));
    rows.push(knob("Scheduling Policy", &|i| {
        columns[i].2.collectives.scheduling.name().to_string()
    }));
    rows.push(knob("Collective Algorithm", &|i| columns[i].2.collectives.algo_notation()));
    rows.push(knob("Chunks per Collective", &|i| format!("{}", columns[i].2.collectives.chunks)));
    rows.push(knob("Multi-dim Collective", &|i| {
        columns[i].2.collectives.multidim.name().to_string()
    }));
    rows.push(knob("Topology", &|i| columns[i].2.topology.notation()));
    rows.push(knob("NPUs per Dim", &|i| {
        format!("{:?}", columns[i].2.topology.dims.iter().map(|d| d.npus).collect::<Vec<_>>())
    }));
    rows.push(knob("Bandwidth per Dim", &|i| {
        format!(
            "{:?}",
            columns[i].2.topology.dims.iter().map(|d| d.bandwidth_gbps).collect::<Vec<_>>()
        )
    }));
    rows.push(knob("(best reward)", &|i| format!("{:.3e}", columns[i].4)));
    print_table(
        "Table 5: COSMIC full-stack designs for GPT3-175B on System 2",
        &["knob", "Perf per BW/NPU", "Perf per Network Cost"],
        &rows,
    );

    // Shape checks vs the paper's Table 5.
    let shard_both = columns.iter().all(|c| c.3.weight_sharded);
    println!("\nboth targets pick weight sharding (paper: yes): {}", if shard_both { "OK" } else { "DIFFERS" });
    let nets_differ = columns[0].2.topology.notation() != columns[1].2.topology.notation()
        || columns[0].2.collectives.algo_notation() != columns[1].2.collectives.algo_notation();
    println!(
        "targets produce different network/collective configs (paper: yes): {}",
        if nets_differ { "OK" } else { "DIFFERS" }
    );
    let bw_low: bool = columns[0]
        .2
        .topology
        .dims
        .iter()
        .all(|d| d.bandwidth_gbps <= 200.0);
    println!(
        "BW/NPU target drives bandwidth toward the low end (paper: all 50): {}",
        if bw_low { "OK" } else { "DIFFERS" }
    );
    let _ = names::DP;
    println!("\nbench wall time: {:.2}s", started.elapsed().as_secs_f64());
}
