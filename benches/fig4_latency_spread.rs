//! Figure 4 — latency spreads across design-space scopes.
//!
//! Reproduces: (a) workload-only spread for GPT3-175B on System 2
//! (paper: 64.5×), (b) workload+network, (c) workload+collective,
//! (d) full-stack (paper: up to 103×), (e) workload-only GPT3-13B,
//! (f) workload-only ViT-Large, (g) full-stack ViT-Large,
//! (h) full-stack ViT-Base.
//!
//! We report min/max latency over a random valid sample per scope; the
//! paper's claim is the *shape*: spreads are large (tens of ×) and the
//! full-stack spread exceeds the workload-only spread.

use cosmic::dse::{Objective, WorkloadSpec};
use cosmic::harness::{latency_spread, make_env, print_table};
use cosmic::pss::SearchScope;
use cosmic::sim::presets;
use cosmic::workload::models::presets as wl;
use std::time::Instant;

fn main() {
    let started = Instant::now();
    let samples = 3000;
    let cases: Vec<(&str, cosmic::workload::ModelConfig, u64, SearchScope)> = vec![
        ("(a) GPT3-175B workload-only", wl::gpt3_175b(), 2048, SearchScope::WorkloadOnly),
        ("(b) GPT3-175B workload+network", wl::gpt3_175b(), 2048, SearchScope::WorkloadNetwork),
        ("(c) GPT3-175B workload+collective", wl::gpt3_175b(), 2048, SearchScope::WorkloadCollective),
        ("(d) GPT3-175B full-stack", wl::gpt3_175b(), 2048, SearchScope::FullStack),
        ("(e) GPT3-13B workload-only", wl::gpt3_13b(), 2048, SearchScope::WorkloadOnly),
        ("(f) ViT-Large workload-only", wl::vit_large(), 2048, SearchScope::WorkloadOnly),
        ("(g) ViT-Large full-stack", wl::vit_large(), 2048, SearchScope::FullStack),
        ("(h) ViT-Base full-stack", wl::vit_base(), 2048, SearchScope::FullStack),
    ];

    let mut rows = Vec::new();
    let mut spread_by_label = Vec::new();
    for (label, model, batch, scope) in cases {
        let env = make_env(
            presets::system2(),
            vec![WorkloadSpec::training(model.with_simulated_layers(4), batch)],
            Objective::RawLatency,
        );
        let (min, max, n) = latency_spread(&env, scope, samples, 0xF164);
        let spread = if min > 0.0 && min.is_finite() { max / min } else { f64::NAN };
        spread_by_label.push((label.to_string(), spread));
        rows.push(vec![
            label.to_string(),
            format!("{n}"),
            format!("{:.1}", min / 1e3),
            format!("{:.1}", max / 1e3),
            format!("{spread:.1}x"),
        ]);
    }
    print_table(
        "Figure 4: latency spread per scope (System 2, random valid samples)",
        &["case", "valid", "min latency (ms)", "max latency (ms)", "spread"],
        &rows,
    );

    // Shape assertions the paper implies.
    let get = |tag: &str| {
        spread_by_label
            .iter()
            .find(|(l, _)| l.starts_with(tag))
            .map(|(_, s)| *s)
            .unwrap_or(f64::NAN)
    };
    let wl_only = get("(a)");
    let full = get("(d)");
    println!("\nshape checks:");
    println!(
        "  workload-only spread large (paper 64.5x): {:.1}x -> {}",
        wl_only,
        if wl_only > 10.0 { "OK" } else { "WEAK" }
    );
    println!(
        "  full-stack spread >= workload-only (paper 103x vs 64.5x): {:.1}x vs {:.1}x -> {}",
        full,
        wl_only,
        if full >= wl_only { "OK" } else { "MISMATCH" }
    );
    println!("\nbench wall time: {:.2}s", started.elapsed().as_secs_f64());
}
