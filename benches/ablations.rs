//! Ablations of the design choices DESIGN.md calls out — the knobs the
//! paper searches but never isolates:
//!
//! 1. **Chunks per collective** (1–32): pipelining vs alpha overhead.
//! 2. **LIFO vs FIFO** gradient scheduling: exposed-tail reduction.
//! 3. **Baseline vs BlueConnect** multi-dim composition.
//! 4. **Collective algorithm** (RI/DI/RHD/DBT) across message sizes —
//!    the latency/bandwidth crossover that drives §6.3's inference
//!    observation.
//! 5. **Pareto frontier** latency-vs-cost over a random design sample
//!    (multi-objective view of the §6.4 diversity claim).

use cosmic::collective::{
    collective_time_us, multidim_collective_time_us, CollAlgo, CollectiveKind, MultiDimPolicy,
    SchedulingPolicy,
};
use cosmic::dse::pareto::{hypervolume_2d, pareto_frontier, ParetoPoint};
use cosmic::dse::{network_cost, Objective, WorkloadSpec};
use cosmic::harness::{make_env, print_table};
use cosmic::pss::SearchScope;
use cosmic::sim::{presets, Simulator};
use cosmic::topology::DimCost;
use cosmic::util::Rng;
use cosmic::workload::models::presets as wl;
use cosmic::workload::{ExecutionMode, Parallelization};
use std::time::Instant;

fn main() {
    let started = Instant::now();
    // Ablations 1-3 use a communication-heavy operating point (fast
    // System 1 compute, large DP -> big gradient payloads): the knobs
    // under ablation act on communication, which System 2's weak compute
    // (10 TFLOPS) hides completely.
    let cluster = presets::system1();
    let model = wl::gpt3_13b().with_simulated_layers(4);
    let par = Parallelization::derive(512, 256, 1, 1, true).unwrap();
    let sim = Simulator::new();

    // --- 1. chunk-count sweep ---
    let mut rows = Vec::new();
    for chunks in [1u32, 2, 4, 8, 16, 32] {
        let mut c = cluster.clone();
        c.collectives.chunks = chunks;
        let r = sim.run(&c, &model, &par, 4096, ExecutionMode::Training).unwrap();
        rows.push(vec![
            format!("{chunks}"),
            format!("{:.1}", r.latency_us / 1e3),
            format!("{:.1}", r.comm_exposed_us / 1e3),
        ]);
    }
    print_table(
        "Ablation 1: chunks per collective (GPT3-13B, System 1, DP=256)",
        &["chunks", "latency (ms)", "exposed grad sync (ms)"],
        &rows,
    );

    // --- 2. LIFO vs FIFO ---
    // Needs an exposed gradient tail: tiny per-NPU compute (ViT-Base,
    // one sample per replica) with full-model gradient collectives.
    let mut rows = Vec::new();
    let vit = wl::vit_base().with_simulated_layers(12);
    let vit_par = Parallelization::derive(512, 512, 1, 1, true).unwrap();
    for policy in [SchedulingPolicy::Fifo, SchedulingPolicy::Lifo] {
        let mut c = cluster.clone();
        c.collectives.scheduling = policy;
        let r = sim.run(&c, &vit, &vit_par, 512, ExecutionMode::Training).unwrap();
        rows.push(vec![
            policy.name().to_string(),
            format!("{:.3}", r.latency_us / 1e3),
            format!("{:.3}", r.comm_exposed_us / 1e3),
        ]);
    }
    print_table(
        "Ablation 2: gradient-sync scheduling policy (ViT-Base, DP=512, batch 512)",
        &["policy", "latency (ms)", "exposed tail (ms)"],
        &rows,
    );

    // --- 3. Baseline vs BlueConnect ---
    let mut rows = Vec::new();
    for md in [MultiDimPolicy::Baseline, MultiDimPolicy::BlueConnect] {
        let mut c = cluster.clone();
        c.collectives.multidim = md;
        let r = sim.run(&c, &model, &par, 4096, ExecutionMode::Training).unwrap();
        rows.push(vec![
            md.name().to_string(),
            format!("{:.2}", r.latency_us / 1e3),
            format!("{:.2}", r.comm_blocking_us / 1e3),
        ]);
    }
    print_table(
        "Ablation 3: multi-dim collective composition",
        &["policy", "latency (ms)", "blocking comm (ms)"],
        &rows,
    );

    // --- 4. algorithm x message-size crossover ---
    let dim = DimCost::from_dim(&presets::system2().topology.dims[3]); // System 2's SW dim
    let mut rows = Vec::new();
    for exp in [3usize, 5, 7, 9] {
        let bytes = 10f64.powi(exp as i32);
        let mut row = vec![format!("1e{exp} B")];
        let mut best = (f64::INFINITY, CollAlgo::Ring);
        for algo in CollAlgo::ALL {
            let t = collective_time_us(algo, CollectiveKind::AllReduce, &dim, bytes);
            if t < best.0 {
                best = (t, algo);
            }
            row.push(format!("{t:.2}"));
        }
        row.push(best.1.short().to_string());
        rows.push(row);
    }
    print_table(
        "Ablation 4: all-reduce time (us) by algorithm vs message size (8-NPU SW dim)",
        &["payload", "RI", "DI", "RHD", "DBT", "winner"],
        &rows,
    );
    println!("(the small-message rows are why §6.3's inference designs avoid Ring)");

    // sanity print for blueconnect multidim on one composed case
    let s2 = presets::system2();
    let dims: Vec<DimCost> = s2.topology.dims.iter().map(DimCost::from_dim).collect();
    let algos = &s2.collectives.algorithms;
    let t_base = multidim_collective_time_us(
        CollectiveKind::AllReduce,
        MultiDimPolicy::Baseline,
        algos,
        &dims,
        1e9,
        4,
    );
    let t_bc = multidim_collective_time_us(
        CollectiveKind::AllReduce,
        MultiDimPolicy::BlueConnect,
        algos,
        &dims,
        1e9,
        4,
    );
    println!("\n1 GB 4D all-reduce: baseline {t_base:.0} us vs BlueConnect {t_bc:.0} us");

    // --- 5. latency-vs-cost Pareto frontier over a random sample ---
    let env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(wl::gpt3_175b().with_simulated_layers(4), 2048)],
        Objective::RawLatency,
    );
    let space = env.pss.build_space(SearchScope::FullStack);
    let mut rng = Rng::seed_from_u64(31);
    let mut points = Vec::new();
    let mut designs = Vec::new();
    while points.len() < 400 {
        let Some(g) = space.random_valid_genome(&mut rng, 500) else { continue };
        let Some(lat) = env.latency_us(&g) else { continue };
        let point = env.pss.schema.decode(&g).unwrap();
        let (c, _) = env.pss.materialize(&point).unwrap();
        let cost = network_cost(&c.topology);
        points.push(ParetoPoint::new(designs.len(), vec![lat, cost]));
        designs.push(g);
    }
    let frontier = pareto_frontier(&points);
    let ref_pt = (
        points.iter().map(|p| p.metrics[0]).fold(0.0, f64::max),
        points.iter().map(|p| p.metrics[1]).fold(0.0, f64::max),
    );
    println!(
        "\nAblation 5: Pareto frontier latency-vs-$ over 400 random designs: \
         {} non-dominated points, hypervolume {:.3e}",
        frontier.len(),
        hypervolume_2d(&frontier, ref_pt)
    );
    for p in frontier.iter().take(8) {
        println!("  latency {:>12.1} ms   network cost {:>12.0} $", p.metrics[0] / 1e3, p.metrics[1]);
    }

    println!("\nbench wall time: {:.2}s", started.elapsed().as_secs_f64());
}
