//! Hot-path micro-benchmarks (the §Perf deliverable's measurement tool).
//!
//! Times the three layers' hot paths:
//! - L3: `Simulator::run` and `Environment::evaluate_uncached` per design
//!   point (the DSE inner loop) — target ≥10k points/min on one core;
//! - L2/L1 via PJRT: one XLA `cost_model` batch (256 candidates) vs the
//!   equivalent 256 Rust-fallback evaluations;
//! - GP surrogate: XLA vs Rust fit+predict round.

use cosmic::dse::{Objective, WorkloadSpec};
use cosmic::harness::make_env;
use cosmic::runtime::{cost_model_ref, CostBatch, CostModel, Runtime, BATCH};
use cosmic::sim::{presets, Simulator};
use cosmic::util::Rng;
use cosmic::workload::models::presets as wl;
use cosmic::workload::{ExecutionMode, Parallelization};
use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

fn time_it<F: FnMut()>(iters: u64, mut f: F) -> f64 {
    let start = Instant::now();
    for _ in 0..iters {
        f();
    }
    start.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    println!("=== sim_hotpath: per-layer hot-path timings ===\n");

    // --- L3: simulator ---
    let cluster = presets::system2();
    let model = wl::gpt3_175b().with_simulated_layers(4);
    let par = Parallelization::derive(1024, 64, 4, 1, true).unwrap();
    let sim = Simulator::new();
    let t = time_it(2000, || {
        black_box(sim.run(&cluster, &model, &par, 2048, ExecutionMode::Training).unwrap());
    });
    println!("Simulator::run (GPT3-175B/4L, System 2): {:>10.1} us/point  ({:.0} points/s)", t * 1e6, 1.0 / t);

    let env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model.clone(), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let genome = env.pss.baseline_genome();
    let t = time_it(2000, || {
        black_box(env.evaluate_uncached(&genome));
    });
    println!("Environment::evaluate_uncached:          {:>10.1} us/point  ({:.0} points/s)", t * 1e6, 1.0 / t);

    // Random-genome evaluation (includes decode + constraint checking).
    let space = env.pss.build_space(cosmic::pss::SearchScope::FullStack);
    let mut rng = Rng::seed_from_u64(1);
    let genomes: Vec<Vec<usize>> =
        (0..256).filter_map(|_| space.random_valid_genome(&mut rng, 500)).collect();
    let mut i = 0;
    let t = time_it(2000, || {
        black_box(env.evaluate_uncached(&genomes[i % genomes.len()]));
        i += 1;
    });
    println!("  (random valid genomes):                {:>10.1} us/point  ({:.0} points/s)", t * 1e6, 1.0 / t);

    // Batch evaluation: serial loop vs the thread-fanned evaluate_batch
    // (both on cold caches so every genome is a real evaluation).
    let serial_env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model.clone(), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let t0 = Instant::now();
    for g in &genomes {
        black_box(serial_env.evaluate(g));
    }
    let t_serial = t0.elapsed().as_secs_f64();
    let batch_env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model.clone(), 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let t0 = Instant::now();
    black_box(batch_env.evaluate_batch(&genomes));
    let t_batch = t0.elapsed().as_secs_f64();
    println!(
        "evaluate_batch ({} genomes):            serial {:.3}s vs batch {:.3}s = {:.2}x speedup",
        genomes.len(),
        t_serial,
        t_batch,
        t_serial / t_batch.max(1e-9)
    );

    // --- L2/L1: XLA cost model vs fallback ---
    let mut batch = CostBatch::zeros();
    let mut rng = Rng::seed_from_u64(2);
    for v in batch.flops.iter_mut().chain(batch.bytes.iter_mut()) {
        *v = (rng.gen_f64() * 1e6) as f32;
    }
    batch.peak_flops_us = 1e7;
    batch.mem_bytes_us = 5e4;

    let t_ref = time_it(200, || {
        black_box(cost_model_ref(&batch));
    });
    println!("\ncost_model fallback (256 configs):       {:>10.1} us/batch ({:.2} us/config)", t_ref * 1e6, t_ref * 1e6 / BATCH as f64);

    match Runtime::cpu() {
        Ok(rt) => {
            let cm = CostModel::load(Some(&rt.client), Path::new("artifacts"));
            if cm.is_xla() {
                // warmup
                let _ = cm.evaluate(&batch).unwrap();
                let t_xla = time_it(200, || {
                    black_box(cm.evaluate(&batch).unwrap());
                });
                println!("cost_model XLA artifact (256 configs):   {:>10.1} us/batch ({:.2} us/config)", t_xla * 1e6, t_xla * 1e6 / BATCH as f64);
                println!("  XLA/fallback ratio: {:.2}x", t_xla / t_ref);
            } else {
                println!("cost_model XLA artifact: not built (run `make artifacts`)");
            }

            // GP surrogate round.
            use cosmic::agents::bo::Surrogate;
            let mut gp_rust = cosmic::runtime::GpSurrogate::load(None, Path::new("artifacts"), 0.4);
            let mut gp_xla =
                cosmic::runtime::GpSurrogate::load(Some(&rt.client), Path::new("artifacts"), 0.4);
            let xs: Vec<Vec<f64>> = (0..32)
                .map(|_| (0..32).map(|_| rng.gen_f64()).collect())
                .collect();
            let ys: Vec<f64> = (0..32).map(|_| rng.gen_f64()).collect();
            gp_rust.fit(&xs, &ys);
            gp_xla.fit(&xs, &ys);
            let q: Vec<f64> = (0..32).map(|_| rng.gen_f64()).collect();
            let t_rust = time_it(100, || {
                black_box(gp_rust.predict(&q));
            });
            println!("\ngp predict rust fallback:                {:>10.1} us", t_rust * 1e6);
            if gp_xla.is_xla() {
                let _ = gp_xla.predict(&q);
                let t_xla = time_it(100, || {
                    black_box(gp_xla.predict(&q));
                });
                println!("gp predict XLA artifact:                 {:>10.1} us", t_xla * 1e6);
            }
        }
        Err(e) => println!("PJRT unavailable ({e:#}); skipping XLA timings"),
    }

    // --- end-to-end DSE throughput ---
    use cosmic::agents::AgentKind;
    use cosmic::dse::{DseConfig, DseRunner};
    let mut env = make_env(
        presets::system2(),
        vec![WorkloadSpec::training(model, 2048)],
        Objective::PerfPerBwPerNpu,
    );
    let start = Instant::now();
    let steps = 2000;
    let r = DseRunner::new(DseConfig::new(AgentKind::Ga, steps, 9), cosmic::pss::SearchScope::FullStack)
        .run(&mut env);
    let wall = start.elapsed().as_secs_f64();
    println!(
        "\nend-to-end GA DSE: {steps} steps in {wall:.2}s = {:.0} steps/s (best {:.3e})",
        steps as f64 / wall,
        r.best_reward
    );
}
